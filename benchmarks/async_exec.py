"""Overlapped & asynchronous execution benchmarks (``--only async``).

Three measurements:

  * ``async/overlap_*`` — wall-clock of the in-mesh step under
    ``reduce_mode="serial" | "overlap" | "overlap_eager"`` on an 8-device
    CPU mesh (spawned as a subprocess with a forced host device fleet,
    the same trick tests/test_distributed.py uses).  On CPU the psum is a
    memcpy, so overlap is reported for structure validation, not gated —
    the scheduling win needs real interconnect latency to hide.
  * ``async/step_*`` — the gated number: per-step wall-clock of the
    barrier-free ``AsyncEngine`` (refresh r of K shards per step, stale
    fold for the rest) against the synchronous serial map-reduce step on
    the same 8-device mesh and data.  The async step maps r/K of the
    rows, so its speedup is honest work reduction (bounded-staleness
    gradients are the price; docs/training.md quantifies it).  Gate:
    >= 1.15x at n >= 512k with refresh=1.
  * ``async/straggler_*`` — goodput under straggler injection in the
    established host-simulated idiom (gp_common/fig5/fig7): each shard is
    slowed by ``straggler_factor`` with probability ``rate`` per
    iteration.  The synchronous iteration waits for max(shard times) —
    it stalls whenever ANY shard straggles (prob 1-(1-rate)^K) — while
    the async step stalls only when the ONE refreshed shard straggles
    (prob rate).  Goodput = fresh rows folded per second; the curve
    reproduces the paper's fig. 7 shape: graceful async degradation
    vs collapsing synchronous throughput as the failure rate grows.
"""
from __future__ import annotations

import argparse
import os
import subprocess
import sys
import time

import numpy as np


# --------------------------------------------------------------------------
# worker: runs inside the subprocess with an 8-device host fleet
# --------------------------------------------------------------------------

def _worker(n: int, m: int, shards: int, chunk: int, iters: int,
            refresh_sweep, staleness: int) -> None:
    import jax
    import jax.numpy as jnp

    from repro.core.distributed import DistributedGP
    from repro.distributed.async_stats import AsyncEngine
    from repro.launch.mesh import make_compat_mesh

    from .gp_common import default_hyp

    assert len(jax.devices()) == shards, \
        f"worker expected {shards} devices, got {len(jax.devices())}"
    q, d = 2, 1
    rng = np.random.default_rng(0)
    x = rng.standard_normal((n, q))
    y = rng.standard_normal((n, d))
    hyp = default_hyp(q)
    z = jnp.asarray(rng.standard_normal((m, q)))
    nf = jnp.asarray(float(n))

    def timed(fn, *args):
        out = fn(*args)
        jax.block_until_ready(out)      # warm (compile)
        ts = []
        for _ in range(iters):
            t0 = time.perf_counter()
            out = fn(*args)
            jax.block_until_ready(out)
            ts.append(time.perf_counter() - t0)
        return float(np.median(ts))

    mesh = make_compat_mesh((shards,), ("data",))
    t_modes = {}
    for mode in ("serial", "overlap", "overlap_eager"):
        eng = DistributedGP(mesh, chunk_size=chunk, reduce_mode=mode)
        data, w = eng.put_data(y=y, mu=x)
        vg = eng.make_value_and_grad(d)
        ones = jnp.ones((eng.n_shards,))
        t_modes[mode] = timed(vg, hyp, z, data["mu"], None, data["y"], w,
                              ones, nf)
        print(f"ROW,async/overlap_mode={mode}_n={n},"
              f"{t_modes[mode] * 1e6:.3f},"
              f"vs_serial={t_modes['serial'] / t_modes[mode]:.2f}x")

    # --- barrier-free async step vs the serial synchronous step ------------
    per = n // shards
    shard_data = [{"y": y[k * per:(k + 1) * per],
                   "mu": x[k * per:(k + 1) * per]} for k in range(shards)]
    for r in refresh_sweep:
        eng_a = AsyncEngine(shard_data, d=d, staleness=staleness, refresh=r,
                            chunk_size=chunk)
        for _ in range(-(-shards // r)):   # populate every shard + warm jit
            eng_a.step(hyp, z)
        ts = []
        for _ in range(iters):
            t0 = time.perf_counter()
            v, g = eng_a.step(hyp, z)
            jax.block_until_ready(v)
            ts.append(time.perf_counter() - t0)
        t_async = float(np.median(ts))
        speedup = t_modes["serial"] / t_async
        print(f"ROW,async/step_refresh={r}_n={n}_shards={shards},"
              f"{t_async * 1e6:.3f},speedup={speedup:.2f}x")
        if r == min(refresh_sweep) and n >= 512_000:
            assert speedup >= 1.15, \
                f"async step speedup {speedup:.2f}x below the 1.15x gate"


# --------------------------------------------------------------------------
# host-simulated straggler goodput (runs in the parent process)
# --------------------------------------------------------------------------

def _straggler_goodput(n: int, shards: int, rates, factor: float,
                       iters: int, m: int):
    """Goodput (fresh rows folded per second) of sync vs async iterations
    under per-iteration straggler injection — host-simulated (one thunk
    per shard, timed individually, gp_common idiom)."""
    import jax
    import jax.numpy as jnp

    from repro.core.bound import collapsed_bound
    from repro.core.stats import Stats
    from repro.distributed.async_stats import AsyncStatsAccumulator

    from .gp_common import default_hyp, make_shard_fn, split_shards

    q, d = 2, 1
    rng = np.random.default_rng(2)
    x = rng.standard_normal((n, q))
    y = rng.standard_normal((n, d))
    hyp = default_hyp(q)
    z = jnp.asarray(rng.standard_normal((m, q)))
    fn = make_shard_fn(hyp, z, d, latent=False)
    shard_list = split_shards(y, x, None, shards)
    per = n // shards

    def collapse(st):
        b = collapsed_bound(hyp, z, st._replace(n=jnp.asarray(float(n))),
                            d)
        jax.block_until_ready(b)
        return b

    # warm the map and collapse jits, then calibrate the straggler sleep
    # off the warm map time (floored so it dominates per-step host
    # overhead even at smoke sizes)
    parts = [fn(*sh) for sh in shard_list]
    tot = parts[0]
    for p in parts[1:]:
        tot = Stats(*(a + b for a, b in zip(tot, p)))
    collapse(tot)                       # warms map + fold + collapse jits
    t0 = time.perf_counter()
    for _ in range(3):
        jax.block_until_ready(fn(*shard_list[0]).D)
    t_map = (time.perf_counter() - t0) / 3
    sleep_s = max(t_map * (factor - 1.0), 0.005)

    rows = []
    srng = np.random.default_rng(7)
    for rate in rates:
        # synchronous: every shard maps, the iteration waits for the max
        t_sync = []
        for _ in range(iters):
            times = []
            parts = []
            for sh in shard_list:
                t1 = time.perf_counter()
                if srng.uniform() < rate:
                    time.sleep(sleep_s)
                st = fn(*sh)
                jax.block_until_ready(st.D)
                times.append(time.perf_counter() - t1)
                parts.append(st)
            t1 = time.perf_counter()
            tot = parts[0]
            for p in parts[1:]:
                tot = Stats(*(a + b for a, b in zip(tot, p)))
            collapse(tot)
            t_sync.append(max(times) + (time.perf_counter() - t1))
        g_sync = n / float(np.mean(t_sync))

        # async: refresh ONE shard, fold it against the stale rest
        acc = AsyncStatsAccumulator(staleness=2 * shards, reweight="drop")
        for k, sh in enumerate(shard_list):
            acc.push(k, fn(*sh), stamp=0)
        t_async = []
        for it in range(iters * shards):
            k = it % shards
            t1 = time.perf_counter()
            if srng.uniform() < rate:
                time.sleep(sleep_s)
            st = fn(*shard_list[k])
            jax.block_until_ready(st.D)
            acc.push(k, st, stamp=it + 1)
            collapse(acc.read(it + 1))
            t_async.append(time.perf_counter() - t1)
        g_async = per / float(np.mean(t_async))

        ratio = g_async / g_sync
        rows.append((f"async/straggler_rate={rate}_sync",
                     float(np.mean(t_sync)) * 1e6,
                     f"goodput={g_sync:.0f}rows/s"))
        rows.append((f"async/straggler_rate={rate}_async",
                     float(np.mean(t_async)) * 1e6,
                     f"goodput={g_async:.0f}rows/s ratio={ratio:.2f}x"))
        print(f"  straggler rate={rate:4.2f}  sync={g_sync:10.0f} rows/s  "
              f"async={g_async:10.0f} rows/s  ratio={ratio:.2f}x")
    return rows


# --------------------------------------------------------------------------
# the benchmark target
# --------------------------------------------------------------------------

def async_exec(n: int = 524_288, m: int = 32, shards: int = 8,
               chunk: int = 4096, iters: int = 3,
               refresh_sweep=(1, 2, 4, 8), staleness: int = 16,
               straggler_rates=(0.0, 0.1, 0.3),
               straggler_factor: float = 8.0, straggler_iters: int = 10,
               n_strag: int = 20_000):
    """Async/overlap execution benchmark.  The mesh comparison runs in a
    subprocess (forced ``shards``-device host fleet, so the parent keeps
    its single-device view); the straggler goodput curve is simulated
    in-process.  Returns the usual (name, us_per_call, derived) rows."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                        f" --xla_force_host_platform_device_count={shards}")
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (os.path.abspath(src), env.get("PYTHONPATH", "")) if p)
    cmd = [sys.executable, "-m", "benchmarks.async_exec", "--worker",
           f"--n={n}", f"--m={m}", f"--shards={shards}", f"--chunk={chunk}",
           f"--iters={iters}", f"--staleness={staleness}",
           "--refresh=" + ",".join(str(r) for r in refresh_sweep)]
    proc = subprocess.run(cmd, env=env, capture_output=True, text=True,
                          cwd=os.path.dirname(os.path.dirname(
                              os.path.abspath(__file__))))
    rows = []
    for line in proc.stdout.splitlines():
        if line.startswith("ROW,"):
            name, us, derived = line[4:].split(",", 2)
            rows.append((name, float(us), derived))
            print(f"  {name}: {float(us) / 1e3:.1f} ms  {derived}")
        elif line.strip():
            print(f"  [worker] {line}")
    if proc.returncode != 0:
        sys.stderr.write(proc.stderr)
        raise RuntimeError(
            f"async worker failed (exit {proc.returncode})")

    rows.extend(_straggler_goodput(n_strag, shards, straggler_rates,
                                   straggler_factor, straggler_iters, m))
    # fig. 7 shape: the async/sync goodput ratio must GROW with the
    # straggler rate (async degrades gracefully, sync waits for the max)
    ratios = [float(r[2].split("ratio=")[1][:-1]) for r in rows
              if "ratio=" in r[2]]
    if len(ratios) >= 2:
        assert ratios[-1] >= ratios[0], \
            f"straggler ratio curve not increasing: {ratios}"
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--worker", action="store_true")
    ap.add_argument("--n", type=int, default=524_288)
    ap.add_argument("--m", type=int, default=32)
    ap.add_argument("--shards", type=int, default=8)
    ap.add_argument("--chunk", type=int, default=4096)
    ap.add_argument("--iters", type=int, default=3)
    ap.add_argument("--staleness", type=int, default=16)
    ap.add_argument("--refresh", type=str, default="1,2,4,8")
    args = ap.parse_args()
    refresh = tuple(int(r) for r in args.refresh.split(","))
    if args.worker:
        _worker(args.n, args.m, args.shards, args.chunk, args.iters,
                refresh, args.staleness)
    else:
        async_exec(n=args.n, m=args.m, shards=args.shards, chunk=args.chunk,
                   iters=args.iters, refresh_sweep=refresh,
                   staleness=args.staleness)


if __name__ == "__main__":
    main()
