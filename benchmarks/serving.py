"""Serving-path benchmark: the predict subsystem vs the per-call q(u) path.

Before the serving subsystem, every ``SGPR.predict`` call re-ran the q(u)
factor solves (``optimal_qu``: chol(Kmm), chol(B), two triangular solve
chains) un-jitted and then the un-jitted predictive math — per request.
The ``serve`` subsystem does the factor work once (``extract_state``) and
answers queries with a jitted block-scan of matmuls.

Three measurements:
  * legacy    — the old per-call path (un-jitted ``optimal_qu`` +
                ``bound.predict`` per request), the baseline;
  * cold      — state extraction + first (compiling) engine call: the
                server-startup cost, paid once;
  * warm      — steady-state engine latency/throughput (queries/sec) across
                a sweep of query batch sizes t and inducing counts m, under
                both kernel backends (the fused Pallas predict kernel runs
                in interpret mode off-TPU — correctness/structure proxy;
                the HBM-traffic win shows on TPU).

Parity of every path against ``bound.predict`` is asserted as it runs.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bound as bound_mod
from repro.core.stats import partial_stats
from repro.serve import PredictEngine, extract_state

from .gp_common import default_hyp


def _fit_state(rng, n, m, q, d):
    """A 'trained' posterior without the fit cost: stats at default hypers."""
    hyp = default_hyp(q)
    x = jnp.asarray(rng.standard_normal((n, q)))
    y = jnp.asarray(rng.standard_normal((n, d)))
    z = jnp.asarray(rng.standard_normal((m, q)))
    stats = partial_stats(hyp, z, y, x, s=None, latent=False)
    return hyp, z, stats


def _median_time(fn, iters):
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def predict_serving(n=20_000, q=3, d=2, m_sweep=(32, 64, 128),
                    t_sweep=(128, 512, 2048, 8192), block=512, iters=5):
    """Query throughput vs batch size and vs m, XLA vs Pallas backend,
    cold (extract state) vs warm (cached state) vs the legacy per-call
    q(u) path."""
    rng = np.random.default_rng(3)
    rows = []

    for m in m_sweep:
        hyp, z, stats = _fit_state(rng, n, m, q, d)
        t_mid = t_sweep[len(t_sweep) // 2]
        xs_mid = jnp.asarray(rng.standard_normal((t_mid, q)))

        # -- legacy: factor solves + predictive math per call, un-jitted ----
        def legacy_call(xs):
            qu = bound_mod.optimal_qu(hyp, z, stats)
            return bound_mod.predict(hyp, z, qu, xs)

        mean_ref, var_ref = jax.block_until_ready(legacy_call(xs_mid))
        t_legacy = _median_time(lambda: legacy_call(xs_mid), iters)

        # -- cold: extraction + first (compiling) engine call ---------------
        t0 = time.perf_counter()
        state = jax.block_until_ready(extract_state(hyp, z, stats))
        eng = PredictEngine(state, block_size=block)
        jax.block_until_ready(eng.predict(xs_mid))
        t_cold = time.perf_counter() - t0
        rows.append((f"predict/cold_m={m}", t_cold * 1e6,
                     f"extract+compile+first_call_t={t_mid}"))

        # -- warm parity + throughput at the midpoint batch -----------------
        mean, var = eng.predict(xs_mid)
        rel = float(jnp.max(jnp.abs(mean - mean_ref)) /
                    jnp.max(jnp.abs(mean_ref)))
        assert rel < 1e-8, f"serving mean diverged: rel={rel:.2e}"
        assert float(jnp.max(jnp.abs(var - var_ref))) < 1e-8
        t_warm = _median_time(lambda: eng.predict(xs_mid), iters)
        speedup = t_legacy / t_warm
        rows.append((f"predict/legacy_m={m}_t={t_mid}", t_legacy * 1e6,
                     f"qps={t_mid / t_legacy:.0f}"))
        rows.append((f"predict/warm_m={m}_t={t_mid}", t_warm * 1e6,
                     f"qps={t_mid / t_warm:.0f};speedup_vs_legacy={speedup:.1f}x"))
        print(f"  m={m:4d} t={t_mid}: legacy {t_legacy * 1e3:8.2f} ms/call "
              f"({t_mid / t_legacy:8.0f} q/s)   warm {t_warm * 1e3:8.2f} ms "
              f"({t_mid / t_warm:8.0f} q/s)   {speedup:5.1f}x   "
              f"cold {t_cold * 1e3:.0f} ms")

    # -- batch-size sweep at the midpoint m, both backends ------------------
    m = m_sweep[len(m_sweep) // 2]
    hyp, z, stats = _fit_state(rng, n, m, q, d)
    state = extract_state(hyp, z, stats)
    qu = bound_mod.optimal_qu(hyp, z, stats)
    for backend in ("xla", "pallas"):
        eng = PredictEngine(state, block_size=block, kernel_backend=backend)
        for t in t_sweep:
            xs = jnp.asarray(rng.standard_normal((t, q)))
            mean_ref, _ = bound_mod.predict(hyp, z, qu, xs)
            mean, _ = eng.predict(xs)   # compile + parity
            rel = float(jnp.max(jnp.abs(mean - mean_ref)) /
                        jnp.max(jnp.abs(mean_ref)))
            tol = 1e-4 if jax.default_backend() == "tpu" else 1e-8
            assert rel < tol, f"[{backend}] t={t} diverged: rel={rel:.2e}"
            dt = _median_time(lambda: eng.predict(xs), iters)
            rows.append((f"predict/{backend}_m={m}_t={t}", dt * 1e6,
                         f"qps={t / dt:.0f}"))
            print(f"  [{backend}] m={m} t={t:>6}: {dt * 1e3:8.2f} ms/batch  "
                  f"{t / dt:10.0f} q/s")
    return rows
