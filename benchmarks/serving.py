"""Serving-path benchmark: the predict subsystem vs the per-call q(u) path.

Before the serving subsystem, every ``SGPR.predict`` call re-ran the q(u)
factor solves (``optimal_qu``: chol(Kmm), chol(B), two triangular solve
chains) un-jitted and then the un-jitted predictive math — per request.
The ``serve`` subsystem does the factor work once (``extract_state``) and
answers queries with a jitted block-scan of matmuls.

Three measurements:
  * legacy    — the old per-call path (un-jitted ``optimal_qu`` +
                ``bound.predict`` per request), the baseline;
  * cold      — state extraction + first (compiling) engine call: the
                server-startup cost, paid once;
  * warm      — steady-state engine latency/throughput (queries/sec) across
                a sweep of query batch sizes t and inducing counts m, under
                both kernel backends (the fused Pallas predict kernel runs
                in interpret mode off-TPU — correctness/structure proxy;
                the HBM-traffic win shows on TPU).

Parity of every path against ``bound.predict`` is asserted as it runs.
"""
from __future__ import annotations

import asyncio
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bound as bound_mod
from repro.core.stats import partial_stats
from repro.serve import (Frontend, MultiPredictEngine, PredictEngine,
                         QueueFull, SLOExceeded, extract_state, stack_states)

from .gp_common import default_hyp


def _fit_state(rng, n, m, q, d):
    """A 'trained' posterior without the fit cost: stats at default hypers."""
    hyp = default_hyp(q)
    x = jnp.asarray(rng.standard_normal((n, q)))
    y = jnp.asarray(rng.standard_normal((n, d)))
    z = jnp.asarray(rng.standard_normal((m, q)))
    stats = partial_stats(hyp, z, y, x, s=None, latent=False)
    return hyp, z, stats


def _median_time(fn, iters):
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def predict_serving(n=20_000, q=3, d=2, m_sweep=(32, 64, 128),
                    t_sweep=(128, 512, 2048, 8192), block=512, iters=5):
    """Query throughput vs batch size and vs m, XLA vs Pallas backend,
    cold (extract state) vs warm (cached state) vs the legacy per-call
    q(u) path."""
    rng = np.random.default_rng(3)
    rows = []

    for m in m_sweep:
        hyp, z, stats = _fit_state(rng, n, m, q, d)
        t_mid = t_sweep[len(t_sweep) // 2]
        xs_mid = jnp.asarray(rng.standard_normal((t_mid, q)))

        # -- legacy: factor solves + predictive math per call, un-jitted ----
        def legacy_call(xs):
            qu = bound_mod.optimal_qu(hyp, z, stats)
            return bound_mod.predict(hyp, z, qu, xs)

        mean_ref, var_ref = jax.block_until_ready(legacy_call(xs_mid))
        t_legacy = _median_time(lambda: legacy_call(xs_mid), iters)

        # -- cold: extraction + first (compiling) engine call ---------------
        t0 = time.perf_counter()
        state = jax.block_until_ready(extract_state(hyp, z, stats))
        eng = PredictEngine(state, block_size=block)
        jax.block_until_ready(eng.predict(xs_mid))
        t_cold = time.perf_counter() - t0
        rows.append((f"predict/cold_m={m}", t_cold * 1e6,
                     f"extract+compile+first_call_t={t_mid}"))

        # -- warm parity + throughput at the midpoint batch -----------------
        mean, var = eng.predict(xs_mid)
        rel = float(jnp.max(jnp.abs(mean - mean_ref)) /
                    jnp.max(jnp.abs(mean_ref)))
        assert rel < 1e-8, f"serving mean diverged: rel={rel:.2e}"
        assert float(jnp.max(jnp.abs(var - var_ref))) < 1e-8
        t_warm = _median_time(lambda: eng.predict(xs_mid), iters)
        speedup = t_legacy / t_warm
        rows.append((f"predict/legacy_m={m}_t={t_mid}", t_legacy * 1e6,
                     f"qps={t_mid / t_legacy:.0f}"))
        rows.append((f"predict/warm_m={m}_t={t_mid}", t_warm * 1e6,
                     f"qps={t_mid / t_warm:.0f};speedup_vs_legacy={speedup:.1f}x"))
        print(f"  m={m:4d} t={t_mid}: legacy {t_legacy * 1e3:8.2f} ms/call "
              f"({t_mid / t_legacy:8.0f} q/s)   warm {t_warm * 1e3:8.2f} ms "
              f"({t_mid / t_warm:8.0f} q/s)   {speedup:5.1f}x   "
              f"cold {t_cold * 1e3:.0f} ms")

    # -- batch-size sweep at the midpoint m, both backends ------------------
    m = m_sweep[len(m_sweep) // 2]
    hyp, z, stats = _fit_state(rng, n, m, q, d)
    state = extract_state(hyp, z, stats)
    qu = bound_mod.optimal_qu(hyp, z, stats)
    for backend in ("xla", "pallas"):
        eng = PredictEngine(state, block_size=block, kernel_backend=backend)
        for t in t_sweep:
            xs = jnp.asarray(rng.standard_normal((t, q)))
            mean_ref, _ = bound_mod.predict(hyp, z, qu, xs)
            mean, _ = eng.predict(xs)   # compile + parity
            rel = float(jnp.max(jnp.abs(mean - mean_ref)) /
                        jnp.max(jnp.abs(mean_ref)))
            tol = 1e-4 if jax.default_backend() == "tpu" else 1e-8
            assert rel < tol, f"[{backend}] t={t} diverged: rel={rel:.2e}"
            dt = _median_time(lambda: eng.predict(xs), iters)
            rows.append((f"predict/{backend}_m={m}_t={t}", dt * 1e6,
                         f"qps={t / dt:.0f}"))
            print(f"  [{backend}] m={m} t={t:>6}: {dt * 1e3:8.2f} ms/batch  "
                  f"{t / dt:10.0f} q/s")
    return rows


def serving_extensions(n=20_000, q=3, d=2, m=64, t=1024, block=256,
                       s_sweep=(1, 8, 32, 128),
                       dtypes=("float64", "float32", "float16", "bfloat16"),
                       n_models_sweep=(1, 2, 4, 8), iters=5):
    """The PR-5 serving surface: posterior sampling throughput vs S, state
    bytes / accuracy / qps vs storage dtype (the quantization trade-off
    table in docs/serving.md), and ensemble qps vs fleet size through the
    one-executable MultiPredictEngine vs N separate engines."""
    rng = np.random.default_rng(5)
    rows = []
    hyp, z, stats = _fit_state(rng, n, m, q, d)
    state = extract_state(hyp, z, stats)
    xs = jnp.asarray(rng.standard_normal((t, q)))
    import jax.random as jrandom

    # -- posterior sampling: draws/sec vs number of samples S ---------------
    eng = PredictEngine(state, block_size=block)
    mean_ref, var_ref = eng.predict(xs)   # also warms the predict program
    key = jrandom.PRNGKey(0)
    for s_n in s_sweep:
        smp = eng.sample(xs, s_n, key)                    # compile
        # sanity: empirical mean within a loose MC bound of the posterior
        err = float(jnp.max(jnp.abs(smp.mean(0) - mean_ref)))
        bound = 8.0 * float(jnp.max(jnp.sqrt(var_ref))) / max(s_n, 2) ** 0.5
        assert err < bound, f"S={s_n}: sample mean off ({err:.3f}>{bound:.3f})"
        dt_s = _median_time(lambda: eng.sample(xs, s_n, key), iters)
        rows.append((f"serve_ext/sample_S={s_n}_t={t}", dt_s * 1e6,
                     f"draws_per_s={s_n * t / dt_s:.0f}"))
        print(f"  sample S={s_n:4d} t={t}: {dt_s * 1e3:8.2f} ms/batch  "
              f"{s_n * t / dt_s:12.0f} f-draws/s")

    # -- quantized states: bytes vs accuracy vs qps -------------------------
    m64, v64 = (jnp.asarray(a, jnp.float64) for a in (mean_ref, var_ref))
    scale = float(jnp.std(m64))
    for dname in dtypes:
        qstate = state.astype(dname)
        qeng = PredictEngine(qstate, block_size=block)
        mq, vq = qeng.predict(xs)                         # compile + parity
        rmse = float(jnp.sqrt(jnp.mean(
            (mq.astype(jnp.float64) - m64) ** 2))) / scale
        var_rmse = float(jnp.sqrt(jnp.mean(
            (vq.astype(jnp.float64) - v64) ** 2)))
        dt_q = _median_time(lambda: qeng.predict(xs), iters)
        rows.append((f"serve_ext/dtype_{dname}", dt_q * 1e6,
                     f"state_bytes={qstate.nbytes};rel_rmse={rmse:.2e};"
                     f"var_rmse={var_rmse:.2e};qps={t / dt_q:.0f}"))
        print(f"  dtype {dname:>8}: {qstate.nbytes / 1024:8.1f} KiB  "
              f"rel_rmse={rmse:.2e}  var_rmse={var_rmse:.2e}  "
              f"{t / dt_q:10.0f} q/s (compute {qeng.compute_dtype})")

    # -- multi-model engine: one executable vs N separate engines -----------
    for n_models in n_models_sweep:
        fleet = [extract_state(
            {k: (v + 0.01 * i if k == "log_sf2" else v)
             for k, v in hyp.items()}, z, stats) for i in range(n_models)]
        meng = MultiPredictEngine(stack_states(fleet), block_size=block)
        mm, _ = meng.predict(xs)                          # compile
        np.testing.assert_allclose(np.asarray(mm[0]), np.asarray(m64),
                                   rtol=1e-8, atol=1e-10)
        dt_m = _median_time(lambda: meng.predict(xs), iters)
        singles = [PredictEngine(s, block_size=block) for s in fleet]
        for s_eng in singles:
            s_eng.predict(xs)                             # compile each
        dt_n = _median_time(
            lambda: [s_eng.predict(xs) for s_eng in singles], iters)
        rows.append((f"serve_ext/ensemble_N={n_models}", dt_m * 1e6,
                     f"qps={t / dt_m:.0f};speedup_vs_{n_models}_engines="
                     f"{dt_n / dt_m:.2f}x"))
        print(f"  ensemble N={n_models}: vmap {dt_m * 1e3:8.2f} ms  "
              f"{n_models} engines {dt_n * 1e3:8.2f} ms  "
              f"({dt_n / dt_m:4.2f}x)")
    return rows


# -- the serving front-end under open-loop load -----------------------------

async def _poisson_load(fe: Frontend, queries, interarrival, deadline_ms):
    """Open-loop arrivals: submit query i at its scheduled absolute time
    regardless of completions (the load does not slow down because the
    server is struggling — the honest regime, vs closed-loop generators
    that flatter a saturated server).  Returns per-request records
    ``(status, latency_s, x, result)``."""

    async def one(x):
        t0 = time.monotonic()
        try:
            r = await fe.submit(x, deadline_ms=deadline_ms)
        except (SLOExceeded, QueueFull) as e:
            return (type(e).__name__, time.monotonic() - t0, x, None)
        lat = time.monotonic() - t0
        ok = lat * 1e3 <= deadline_ms
        return ("ok" if ok else "late", lat, x, r)

    start = time.monotonic()
    tasks = []
    t_next = 0.0
    for x, gap in zip(queries, interarrival):
        delay = start + t_next - time.monotonic()
        if delay > 0:
            await asyncio.sleep(delay)
        tasks.append(asyncio.ensure_future(one(x)))
        t_next += gap
    return await asyncio.gather(*tasks)


def _goodput_stats(records, duration):
    ok = [r for r in records if r[0] == "ok"]
    lats = np.asarray([r[1] for r in records if r[3] is not None])
    by_status = {}
    for r in records:
        by_status[r[0]] = by_status.get(r[0], 0) + 1
    return {
        "offered": len(records),
        "goodput_rps": len(ok) / duration,
        "p50_ms": float(np.percentile(lats, 50) * 1e3) if lats.size else np.nan,
        "p99_ms": float(np.percentile(lats, 99) * 1e3) if lats.size else np.nan,
        "by_status": by_status,
    }


def frontend_serving(n=8_000, q=3, d=2, m=64, block=64, t_req=8,
                     deadline_ms=50.0, duration_s=2.0, overload=4.0,
                     max_wait_ms=2.0, batch_blocks=8, swap_every_ms=150.0,
                     seed=11):
    """The micro-batching front-end under open-loop Poisson load
    (docs/serving.md "Request batching & SLOs"): goodput and p50/p99
    latency at ``overload``x the naive per-request path's capacity, naive
    vs continuous batching, plus a mid-load hot-swap correctness gate —
    zero dropped and zero wrong-state responses, every response verified
    bitwise against a direct engine call on its generation's state."""
    rng = np.random.default_rng(seed)
    rows = []
    hyp, z, stats = _fit_state(rng, n, m, q, d)
    state_a = extract_state(hyp, z, stats)
    hyp_b = {k: (v + 0.05 if k == "log_sf2" else v) for k, v in hyp.items()}
    state_b = extract_state(hyp_b, z, stats)

    # -- calibrate: the naive path's sequential capacity --------------------
    async def calibrate():
        fe = Frontend(PredictEngine(state_a, block_size=block),
                      max_wait_ms=0.0, max_batch_requests=1).start()
        fe.warmup()
        xs = rng.standard_normal((t_req, q))
        await fe.submit(xs)                      # warm end-to-end
        t0 = time.perf_counter()
        reps = 50
        for _ in range(reps):
            await fe.submit(xs)
        dt = (time.perf_counter() - t0) / reps
        await fe.stop()
        return dt

    t_naive = asyncio.run(calibrate())
    rate = overload / t_naive
    n_req = int(rate * duration_s) + 1
    queries = [rng.standard_normal((t_req, q)) for _ in range(n_req)]
    interarrival = rng.exponential(1.0 / rate, size=n_req)
    print(f"  naive service time {t_naive * 1e3:.2f} ms/req -> offered load "
          f"{rate:.0f} req/s ({overload:.0f}x naive capacity), "
          f"deadline {deadline_ms:.0f} ms")

    # -- naive vs batched under the same offered load -----------------------
    async def run_path(batched: bool):
        fe = Frontend(
            PredictEngine(state_a, block_size=block),
            max_wait_ms=max_wait_ms if batched else 0.0,
            max_batch_rows=batch_blocks * block if batched else block,
            max_batch_requests=None if batched else 1).start()
        fe.warmup()                              # compile all batch shapes
        await fe.submit(queries[0])              # warm end-to-end
        recs = await _poisson_load(fe, queries, interarrival, deadline_ms)
        await fe.stop()
        return recs, fe.metrics.summary()

    stats_by_path = {}
    for name, batched in (("naive", False), ("batched", True)):
        recs, summ = asyncio.run(run_path(batched))
        st = _goodput_stats(recs, duration_s)
        stats_by_path[name] = st
        rows.append((f"frontend/{name}_rate={rate:.0f}",
                     st["p99_ms"] * 1e3,
                     f"goodput_rps={st['goodput_rps']:.0f};"
                     f"p50_ms={st['p50_ms']:.2f};p99_ms={st['p99_ms']:.2f};"
                     f"statuses={st['by_status']};"
                     f"mean_batch={summ['mean_batch_requests']:.1f}"))
        print(f"  {name:>8}: goodput {st['goodput_rps']:8.0f} req/s   "
              f"p50 {st['p50_ms']:7.2f} ms  p99 {st['p99_ms']:7.2f} ms   "
              f"{st['by_status']}   mean batch "
              f"{summ['mean_batch_requests']:.1f} req")
    gain = (stats_by_path["batched"]["goodput_rps"]
            / max(stats_by_path["naive"]["goodput_rps"], 1e-9))
    assert gain >= 3.0, (
        f"continuous batching should sustain >= 3x the per-request goodput "
        f"under {overload:.0f}x overload, got {gain:.2f}x")
    assert (stats_by_path["batched"]["p99_ms"]
            <= stats_by_path["naive"]["p99_ms"]), (
        "batched p99 should not exceed the saturated per-request p99")
    rows.append(("frontend/goodput_gain", 0.0,
                 f"batched_vs_naive={gain:.2f}x"))

    # -- mid-load hot swap: zero dropped, zero wrong-state ------------------
    async def run_swap():
        fe = Frontend(PredictEngine(state_a, block_size=block),
                      max_wait_ms=max_wait_ms,
                      max_batch_rows=batch_blocks * block).start()
        fe.warmup()                              # compile all batch shapes
        await fe.submit(queries[0])              # warm end-to-end
        states = {fe.generation: state_a}
        stop_swapping = asyncio.Event()

        async def swapper():
            flip = [state_b, state_a]
            k = 0
            while not stop_swapping.is_set():
                try:
                    await asyncio.wait_for(stop_swapping.wait(),
                                           timeout=swap_every_ms / 1e3)
                except asyncio.TimeoutError:
                    pass
                else:
                    break
                gen = fe.swap_state(flip[k % 2])
                states[gen] = flip[k % 2]
                k += 1
            return k

        sw = asyncio.ensure_future(swapper())
        # moderate load: half the overload, so the queue stays live but sane
        gaps = rng.exponential(2.0 * t_naive / overload, size=n_req)
        recs = await _poisson_load(fe, queries, gaps, deadline_ms)
        stop_swapping.set()
        n_swaps = await sw
        await fe.stop()
        return recs, states, n_swaps

    recs, states, n_swaps = asyncio.run(run_swap())
    ref_engines = {g: PredictEngine(s, block_size=block)
                   for g, s in states.items()}
    served = [r for r in recs if r[3] is not None]
    wrong = 0
    for _, _, x, res in served:
        ref_m, ref_v = ref_engines[res.generation].predict(x)
        if not (np.array_equal(res.mean, np.asarray(ref_m))
                and np.array_equal(res.var, np.asarray(ref_v))):
            wrong += 1
    dropped = len(recs) - len(served) - sum(
        1 for r in recs if r[0] in ("SLOExceeded", "QueueFull"))
    gens = sorted({r[3].generation for r in served})
    print(f"  hot swap: {n_swaps} swaps mid-load, {len(served)} responses "
          f"across generations {gens}: {wrong} wrong-state, "
          f"{dropped} dropped")
    assert n_swaps >= 1, "swap section never swapped — lengthen duration_s"
    assert wrong == 0, f"{wrong} responses mismatched their generation's state"
    assert dropped == 0, f"{dropped} requests vanished without a typed error"
    rows.append(("frontend/hot_swap", 0.0,
                 f"swaps={n_swaps};responses={len(served)};"
                 f"generations={len(gens)};wrong_state={wrong};"
                 f"dropped={dropped}"))
    return rows
