"""Serving-path benchmark: the predict subsystem vs the per-call q(u) path.

Before the serving subsystem, every ``SGPR.predict`` call re-ran the q(u)
factor solves (``optimal_qu``: chol(Kmm), chol(B), two triangular solve
chains) un-jitted and then the un-jitted predictive math — per request.
The ``serve`` subsystem does the factor work once (``extract_state``) and
answers queries with a jitted block-scan of matmuls.

Three measurements:
  * legacy    — the old per-call path (un-jitted ``optimal_qu`` +
                ``bound.predict`` per request), the baseline;
  * cold      — state extraction + first (compiling) engine call: the
                server-startup cost, paid once;
  * warm      — steady-state engine latency/throughput (queries/sec) across
                a sweep of query batch sizes t and inducing counts m, under
                both kernel backends (the fused Pallas predict kernel runs
                in interpret mode off-TPU — correctness/structure proxy;
                the HBM-traffic win shows on TPU).

Parity of every path against ``bound.predict`` is asserted as it runs.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bound as bound_mod
from repro.core.stats import partial_stats
from repro.serve import (MultiPredictEngine, PredictEngine, extract_state,
                         stack_states)

from .gp_common import default_hyp


def _fit_state(rng, n, m, q, d):
    """A 'trained' posterior without the fit cost: stats at default hypers."""
    hyp = default_hyp(q)
    x = jnp.asarray(rng.standard_normal((n, q)))
    y = jnp.asarray(rng.standard_normal((n, d)))
    z = jnp.asarray(rng.standard_normal((m, q)))
    stats = partial_stats(hyp, z, y, x, s=None, latent=False)
    return hyp, z, stats


def _median_time(fn, iters):
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def predict_serving(n=20_000, q=3, d=2, m_sweep=(32, 64, 128),
                    t_sweep=(128, 512, 2048, 8192), block=512, iters=5):
    """Query throughput vs batch size and vs m, XLA vs Pallas backend,
    cold (extract state) vs warm (cached state) vs the legacy per-call
    q(u) path."""
    rng = np.random.default_rng(3)
    rows = []

    for m in m_sweep:
        hyp, z, stats = _fit_state(rng, n, m, q, d)
        t_mid = t_sweep[len(t_sweep) // 2]
        xs_mid = jnp.asarray(rng.standard_normal((t_mid, q)))

        # -- legacy: factor solves + predictive math per call, un-jitted ----
        def legacy_call(xs):
            qu = bound_mod.optimal_qu(hyp, z, stats)
            return bound_mod.predict(hyp, z, qu, xs)

        mean_ref, var_ref = jax.block_until_ready(legacy_call(xs_mid))
        t_legacy = _median_time(lambda: legacy_call(xs_mid), iters)

        # -- cold: extraction + first (compiling) engine call ---------------
        t0 = time.perf_counter()
        state = jax.block_until_ready(extract_state(hyp, z, stats))
        eng = PredictEngine(state, block_size=block)
        jax.block_until_ready(eng.predict(xs_mid))
        t_cold = time.perf_counter() - t0
        rows.append((f"predict/cold_m={m}", t_cold * 1e6,
                     f"extract+compile+first_call_t={t_mid}"))

        # -- warm parity + throughput at the midpoint batch -----------------
        mean, var = eng.predict(xs_mid)
        rel = float(jnp.max(jnp.abs(mean - mean_ref)) /
                    jnp.max(jnp.abs(mean_ref)))
        assert rel < 1e-8, f"serving mean diverged: rel={rel:.2e}"
        assert float(jnp.max(jnp.abs(var - var_ref))) < 1e-8
        t_warm = _median_time(lambda: eng.predict(xs_mid), iters)
        speedup = t_legacy / t_warm
        rows.append((f"predict/legacy_m={m}_t={t_mid}", t_legacy * 1e6,
                     f"qps={t_mid / t_legacy:.0f}"))
        rows.append((f"predict/warm_m={m}_t={t_mid}", t_warm * 1e6,
                     f"qps={t_mid / t_warm:.0f};speedup_vs_legacy={speedup:.1f}x"))
        print(f"  m={m:4d} t={t_mid}: legacy {t_legacy * 1e3:8.2f} ms/call "
              f"({t_mid / t_legacy:8.0f} q/s)   warm {t_warm * 1e3:8.2f} ms "
              f"({t_mid / t_warm:8.0f} q/s)   {speedup:5.1f}x   "
              f"cold {t_cold * 1e3:.0f} ms")

    # -- batch-size sweep at the midpoint m, both backends ------------------
    m = m_sweep[len(m_sweep) // 2]
    hyp, z, stats = _fit_state(rng, n, m, q, d)
    state = extract_state(hyp, z, stats)
    qu = bound_mod.optimal_qu(hyp, z, stats)
    for backend in ("xla", "pallas"):
        eng = PredictEngine(state, block_size=block, kernel_backend=backend)
        for t in t_sweep:
            xs = jnp.asarray(rng.standard_normal((t, q)))
            mean_ref, _ = bound_mod.predict(hyp, z, qu, xs)
            mean, _ = eng.predict(xs)   # compile + parity
            rel = float(jnp.max(jnp.abs(mean - mean_ref)) /
                        jnp.max(jnp.abs(mean_ref)))
            tol = 1e-4 if jax.default_backend() == "tpu" else 1e-8
            assert rel < tol, f"[{backend}] t={t} diverged: rel={rel:.2e}"
            dt = _median_time(lambda: eng.predict(xs), iters)
            rows.append((f"predict/{backend}_m={m}_t={t}", dt * 1e6,
                         f"qps={t / dt:.0f}"))
            print(f"  [{backend}] m={m} t={t:>6}: {dt * 1e3:8.2f} ms/batch  "
                  f"{t / dt:10.0f} q/s")
    return rows


def serving_extensions(n=20_000, q=3, d=2, m=64, t=1024, block=256,
                       s_sweep=(1, 8, 32, 128),
                       dtypes=("float64", "float32", "float16", "bfloat16"),
                       n_models_sweep=(1, 2, 4, 8), iters=5):
    """The PR-5 serving surface: posterior sampling throughput vs S, state
    bytes / accuracy / qps vs storage dtype (the quantization trade-off
    table in docs/serving.md), and ensemble qps vs fleet size through the
    one-executable MultiPredictEngine vs N separate engines."""
    rng = np.random.default_rng(5)
    rows = []
    hyp, z, stats = _fit_state(rng, n, m, q, d)
    state = extract_state(hyp, z, stats)
    xs = jnp.asarray(rng.standard_normal((t, q)))
    import jax.random as jrandom

    # -- posterior sampling: draws/sec vs number of samples S ---------------
    eng = PredictEngine(state, block_size=block)
    mean_ref, var_ref = eng.predict(xs)   # also warms the predict program
    key = jrandom.PRNGKey(0)
    for s_n in s_sweep:
        smp = eng.sample(xs, s_n, key)                    # compile
        # sanity: empirical mean within a loose MC bound of the posterior
        err = float(jnp.max(jnp.abs(smp.mean(0) - mean_ref)))
        bound = 8.0 * float(jnp.max(jnp.sqrt(var_ref))) / max(s_n, 2) ** 0.5
        assert err < bound, f"S={s_n}: sample mean off ({err:.3f}>{bound:.3f})"
        dt_s = _median_time(lambda: eng.sample(xs, s_n, key), iters)
        rows.append((f"serve_ext/sample_S={s_n}_t={t}", dt_s * 1e6,
                     f"draws_per_s={s_n * t / dt_s:.0f}"))
        print(f"  sample S={s_n:4d} t={t}: {dt_s * 1e3:8.2f} ms/batch  "
              f"{s_n * t / dt_s:12.0f} f-draws/s")

    # -- quantized states: bytes vs accuracy vs qps -------------------------
    m64, v64 = (jnp.asarray(a, jnp.float64) for a in (mean_ref, var_ref))
    scale = float(jnp.std(m64))
    for dname in dtypes:
        qstate = state.astype(dname)
        qeng = PredictEngine(qstate, block_size=block)
        mq, vq = qeng.predict(xs)                         # compile + parity
        rmse = float(jnp.sqrt(jnp.mean(
            (mq.astype(jnp.float64) - m64) ** 2))) / scale
        var_rmse = float(jnp.sqrt(jnp.mean(
            (vq.astype(jnp.float64) - v64) ** 2)))
        dt_q = _median_time(lambda: qeng.predict(xs), iters)
        rows.append((f"serve_ext/dtype_{dname}", dt_q * 1e6,
                     f"state_bytes={qstate.nbytes};rel_rmse={rmse:.2e};"
                     f"var_rmse={var_rmse:.2e};qps={t / dt_q:.0f}"))
        print(f"  dtype {dname:>8}: {qstate.nbytes / 1024:8.1f} KiB  "
              f"rel_rmse={rmse:.2e}  var_rmse={var_rmse:.2e}  "
              f"{t / dt_q:10.0f} q/s (compute {qeng.compute_dtype})")

    # -- multi-model engine: one executable vs N separate engines -----------
    for n_models in n_models_sweep:
        fleet = [extract_state(
            {k: (v + 0.01 * i if k == "log_sf2" else v)
             for k, v in hyp.items()}, z, stats) for i in range(n_models)]
        meng = MultiPredictEngine(stack_states(fleet), block_size=block)
        mm, _ = meng.predict(xs)                          # compile
        np.testing.assert_allclose(np.asarray(mm[0]), np.asarray(m64),
                                   rtol=1e-8, atol=1e-10)
        dt_m = _median_time(lambda: meng.predict(xs), iters)
        singles = [PredictEngine(s, block_size=block) for s in fleet]
        for s_eng in singles:
            s_eng.predict(xs)                             # compile each
        dt_n = _median_time(
            lambda: [s_eng.predict(xs) for s_eng in singles], iters)
        rows.append((f"serve_ext/ensemble_N={n_models}", dt_m * 1e6,
                     f"qps={t / dt_m:.0f};speedup_vs_{n_models}_engines="
                     f"{dt_n / dt_m:.2f}x"))
        print(f"  ensemble N={n_models}: vmap {dt_m * 1e3:8.2f} ms  "
              f"{n_models} engines {dt_n * 1e3:8.2f} ms  "
              f"({dt_n / dt_m:4.2f}x)")
    return rows
