"""Benchmark harness — one function per paper table/figure.
Prints ``name,us_per_call,derived`` CSV rows (plus human summaries).

  PYTHONPATH=src python -m benchmarks.run [--only fig2 fig7 ...] [--fast]
"""
import argparse
import sys

from . import async_exec, figures, kernelzoo, online, serving, streaming


ALL = {
    "async": async_exec.async_exec,
    "fig2": figures.fig2_scaling_cores,
    "fig3": figures.fig3_scaling_data,
    "fig4": figures.fig4_parity,
    "fig5": figures.fig5_load_distribution,
    "fig7": figures.fig7_node_failure,
    "usps": figures.usps_reconstruction,
    "psi2": figures.psi2_variants,
    "lm": figures.lm_train_microbench,
    "stream": streaming.streaming_map,
    "regmap": streaming.reg_map_backends,
    "svi": streaming.svi_map,
    "predict": serving.predict_serving,
    "serve_ext": serving.serving_extensions,
    "frontend": serving.frontend_serving,
    "kernelzoo": kernelzoo.kernel_zoo,
    "online": online.online_updates,
}

FAST_ARGS = {
    "async": dict(n=16_384, m=16, chunk=512, iters=2, refresh_sweep=(1, 4),
                  staleness=16, straggler_rates=(0.0, 0.4),
                  straggler_factor=6.0, straggler_iters=4, n_strag=4_096),
    "fig2": dict(n=4000, iters=2),
    "fig3": dict(iters=2),
    "fig4": dict(n=200, iters=40),
    "fig5": dict(n=8000, iters=3),
    "fig7": dict(n=150, iters=40),
    "usps": dict(n_small=150, n_big=500, iters=50),
    "psi2": dict(n=2048, iters=2),
    "lm": dict(steps=3),
    "stream": dict(n_parity=4000, n_big=60_000, m=48, block=1024,
                   budget_gb=0.5, iters=2, host_n0=40_000,
                   host_mults=(1, 2, 4), host_chunk=1024, host_bpc=8),
    "regmap": dict(n=4096, m=32, block=1024, iters=2),
    "svi": dict(n=4096, m=32, block=256, iters=2, batch_sweep=(1, 2, 4, 8),
                n_mults=(1, 2)),
    "predict": dict(n=4096, m_sweep=(16, 32), t_sweep=(64, 256, 1024),
                    block=128, iters=2),
    "serve_ext": dict(n=4096, m=32, t=256, block=64, s_sweep=(1, 8, 32),
                      n_models_sweep=(1, 2, 4), iters=2),
    "frontend": dict(n=4096, m=32, block=32, t_req=4, duration_s=1.0,
                     overload=4.0, swap_every_ms=100.0),
    "kernelzoo": dict(n=4096, m=32, t=512, block=512, iters=2),
    "online": dict(m=16, k=8, n_sweep=(1_000, 4_000), k_sweep=(1, 8),
                   iters=2),
}


def main() -> None:
    ap = argparse.ArgumentParser(
        formatter_class=argparse.RawDescriptionHelpFormatter,
        epilog="--only targets: " + " ".join(ALL))
    ap.add_argument("--only", nargs="*", default=None, choices=list(ALL),
                    metavar="TARGET",
                    help="benchmarks to run (default: all; see list below)")
    ap.add_argument("--fast", action="store_true")
    args = ap.parse_args()
    names = args.only or list(ALL)
    rows = []
    for name in names:
        print(f"== {name} ==")
        kwargs = FAST_ARGS.get(name, {}) if args.fast else {}
        try:
            rows.extend(ALL[name](**kwargs))
        except Exception as e:  # noqa: BLE001
            import traceback
            traceback.print_exc()
            rows.append((f"{name}/FAILED", 0.0, repr(e)))
    print("\nname,us_per_call,derived")
    for r in rows:
        print(f"{r[0]},{r[1]:.3f},{r[2]}")
    if any("FAILED" in r[0] for r in rows):
        sys.exit(1)


if __name__ == "__main__":
    main()
