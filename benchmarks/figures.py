"""One benchmark per paper table/figure. Each returns a list of CSV rows
(name, us_per_call, derived) plus prints a human summary."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import BayesianGPLVM
from repro.core import gp_kernels as gpk
from repro.core.scg import scg
from repro.core.stats import partial_stats
from repro.core.bound import collapsed_bound
from repro.data.synthetic import (drop_pixels, oilflow_like, sines_dataset,
                                  usps_like)
from repro.distributed.fault import FailureSimulator, StepTimer

from .gp_common import (default_hyp, make_shard_fn, mapreduce_iteration,
                        split_shards)
# fig. 7's companion: straggler goodput + overlapped/async step timing
# live in their own module (subprocess-based mesh sizing) — re-exported
# here so figure-oriented callers find the whole fault/async family.
from .async_exec import async_exec  # noqa: F401,E402


def fig2_scaling_cores(n=20_000, m=64, iters=3):
    """Paper fig 2: fixed dataset, increasing cores. Reports the parallel
    iteration time (max shard time + reduce) per core count."""
    rng = np.random.default_rng(0)
    y, lat = sines_dataset(rng, n=n, noise=0.05)
    mu = np.hstack([lat, 0.1 * rng.standard_normal((n, 1))])
    s = np.full((n, 2), 0.3)
    hyp = default_hyp(2)
    z = jnp.asarray(rng.standard_normal((m, 2)))
    rows = []
    t1 = None
    for k in (1, 2, 4, 8, 16):
        fn = make_shard_fn(hyp, z, y.shape[1], latent=True)
        shards = split_shards(y, mu, s, k)
        _ = mapreduce_iteration(fn, shards, hyp, z, y.shape[1])  # warm up jit
        ts = []
        for _ in range(iters):
            _, t = mapreduce_iteration(fn, shards, hyp, z, y.shape[1])
            ts.append(t["t_map_parallel"] + t["t_reduce_global"])
        t_par = float(np.median(ts))
        t1 = t1 or t_par
        rows.append((f"fig2/cores={k}", t_par * 1e6,
                     f"speedup={t1 / t_par:.2f}x"))
        print(f"  cores={k:3d}  t/iter={t_par * 1e3:8.1f} ms  "
              f"speedup={t1 / t_par:5.2f}x (ideal {k}x)")
    return rows


def fig3_scaling_data(m=64, iters=3):
    """Paper fig 3: data and cores scaled together (weak scaling); plus the
    sequential (GPy-analogue) time on the largest size."""
    rng = np.random.default_rng(1)
    rows = []
    t0 = None
    for n, k in ((5_000, 1), (10_000, 2), (20_000, 4), (40_000, 8),
                 (80_000, 16)):
        y, lat = sines_dataset(rng, n=n, noise=0.05)
        mu = np.hstack([lat, 0.1 * rng.standard_normal((n, 1))])
        s = np.full((n, 2), 0.3)
        hyp = default_hyp(2)
        z = jnp.asarray(rng.standard_normal((m, 2)))
        fn = make_shard_fn(hyp, z, y.shape[1], latent=True)
        shards = split_shards(y, mu, s, k)
        _ = mapreduce_iteration(fn, shards, hyp, z, y.shape[1])
        ts = []
        for _ in range(iters):
            _, t = mapreduce_iteration(fn, shards, hyp, z, y.shape[1])
            ts.append(t["t_map_parallel"] + t["t_reduce_global"])
        t_par = float(np.median(ts))
        t0 = t0 or t_par
        rows.append((f"fig3/n={n}_cores={k}", t_par * 1e6,
                     f"vs_first={t_par / t0:.2f}x"))
        print(f"  n={n:6d} cores={k:3d}  t/iter={t_par * 1e3:8.1f} ms  "
              f"({t_par / t0:4.2f}x of smallest; ideal 1.0x)")
    # sequential GPy-analogue on the largest dataset
    y, lat = sines_dataset(rng, n=80_000, noise=0.05)
    mu = np.hstack([lat, 0.1 * rng.standard_normal((80_000, 1))])
    s = np.full((80_000, 2), 0.3)
    hyp = default_hyp(2)
    z = jnp.asarray(rng.standard_normal((m, 2)))
    fn = make_shard_fn(hyp, z, y.shape[1], latent=True)
    shards = split_shards(y, mu, s, 1)
    _, t = mapreduce_iteration(fn, shards, hyp, z, y.shape[1])
    _, t = mapreduce_iteration(fn, shards, hyp, z, y.shape[1])
    rows.append(("fig3/sequential_n=80000", (t["t_map_total"]
                                             + t["t_reduce_global"]) * 1e6,
                 "GPy-analogue"))
    print(f"  sequential n=80000: {(t['t_map_total'] + t['t_reduce_global']) * 1e3:.1f} ms")
    return rows


def fig4_parity(n=400, iters=120):
    """Paper fig 4: distributed vs reference implementation on oil-flow.
    Parity of the optimised bound + the 'effectively low-dimensional ARD'
    finding. The reference is the sequential engine (GPy analogue); the
    distributed bound must agree to float tolerance at every checkpoint."""
    rng = np.random.default_rng(2)
    y, labels = oilflow_like(rng, n=n)
    lv = BayesianGPLVM(y, q=6, num_inducing=24, seed=0)
    b0 = lv.log_bound()

    # distributed evaluation of the same objective (host map-reduce, k=8)
    hyp = lv.params["hyp"]
    z = lv.params["z"]
    mu = np.asarray(lv.params["mu"])
    s = np.exp(np.asarray(lv.params["log_s"]))
    fn = make_shard_fn(hyp, z, y.shape[1], latent=True)
    shards = split_shards(y, mu, s, 8)
    b_dist, _ = mapreduce_iteration(fn, shards, hyp, z, y.shape[1])
    print(f"  bound(sequential)={b0:.4f} bound(distributed)={b_dist:.4f} "
          f"|diff|={abs(b_dist - b0):.2e}")

    lv.fit(max_iters=iters)
    w = np.sort(lv.ard_weights())[::-1]
    eff_dims = int(np.sum(w > 0.1 * w[0]))
    print(f"  optimised bound={lv.log_bound():.2f}; ARD weights={np.round(w, 3)}"
          f" -> {eff_dims} effective dims (paper: ~1-2 for oil-flow)")
    return [("fig4/bound_parity_absdiff", abs(b_dist - b0) * 1e6,
             f"bound={b0:.2f}"),
            ("fig4/effective_dims", float(eff_dims), f"of q={6}")]


def fig5_load_distribution(n=40_000, k=16, iters=10):
    """Paper fig 5: min/mean/max per-shard map times + straggler overhead
    (paper reports max ~3.7% over mean)."""
    rng = np.random.default_rng(3)
    y, lat = sines_dataset(rng, n=n, noise=0.05)
    mu = np.hstack([lat, 0.1 * rng.standard_normal((n, 1))])
    s = np.full((n, 2), 0.3)
    hyp = default_hyp(2)
    z = jnp.asarray(rng.standard_normal((64, 2)))
    fn = make_shard_fn(hyp, z, y.shape[1], latent=True)
    shards = split_shards(y, mu, s, k)
    timer = StepTimer()
    _ = mapreduce_iteration(fn, shards, hyp, z, y.shape[1])
    for _ in range(iters):
        _, t = mapreduce_iteration(fn, shards, hyp, z, y.shape[1])
        timer.record(t["shard_times"])
    s_ = timer.summary()
    print(f"  per-shard map time: min={s_['min'] * 1e3:.2f} "
          f"mean={s_['mean'] * 1e3:.2f} max={s_['max'] * 1e3:.2f} ms; "
          f"straggler overhead={s_['straggler_overhead'] * 100:.1f}% "
          f"(paper: 3.7%)")
    return [("fig5/straggler_overhead_pct",
             s_["straggler_overhead"] * 100, f"k={k}")]


def fig7_node_failure(n=300, nodes=10, iters=150):
    """Paper fig 7: optimise under 0/1/2% per-iteration node failures,
    plus the beyond-paper rescaled variant at 1%."""
    rng = np.random.default_rng(4)
    y, _ = oilflow_like(rng, n=n)
    d = y.shape[1]
    rows = []
    results = {}
    for rate, mode in ((0.0, "drop"), (0.01, "drop"), (0.02, "drop"),
                       (0.01, "rescale")):
        lv = BayesianGPLVM(y, q=4, num_inducing=20, seed=0)
        sim = FailureSimulator(nodes, rate, seed=7)
        from jax.flatten_util import ravel_pytree
        flat0, unravel = ravel_pytree(lv.params)

        def fg(xf):
            p = unravel(jnp.asarray(xf))
            mask = np.repeat(sim.mask(), n // nodes + 1)[:n]
            total_w = float(mask.sum())
            w = jnp.asarray(mask)
            if mode == "rescale":
                w = w * (n / max(total_w, 1.0))

            def neg(p_):
                st = partial_stats(p_["hyp"], p_["z"], jnp.asarray(y),
                                   p_["mu"], s=jnp.exp(p_["log_s"]),
                                   weights=w, latent=True)
                st = st._replace(n=jnp.asarray(float(n)))
                return -collapsed_bound(p_["hyp"], p_["z"], st, d)

            v, g = jax.value_and_grad(neg)(p)
            gf, _ = ravel_pytree(g)
            return float(v), np.asarray(gf, np.float64)

        res = scg(fg, np.asarray(flat0, np.float64), max_iters=iters)
        lv.params = jax.tree.map(jnp.asarray, unravel(jnp.asarray(res.x)))
        final = lv.log_bound()
        w_ard = np.sort(lv.ard_weights())[::-1]
        results[(rate, mode)] = final
        tag = f"{rate * 100:.0f}%/{mode}"
        print(f"  failure {tag:>12}: final bound={final:10.2f}  "
              f"ARD top2={np.round(w_ard[:2], 3)}")
        rows.append((f"fig7/bound_rate={rate}_{mode}", final, f"iters={iters}"))
    # paper's qualitative claim: failures hurt the final bound
    assert results[(0.0, "drop")] >= results[(0.02, "drop")] - 1e-6
    return rows


def usps_reconstruction(n_small=400, n_big=1600, iters=150):
    """Paper §4.5: USPS-style digit reconstruction with 34% dropped pixels;
    more data should improve mean reconstruction error (paper: 5.9%)."""
    rng = np.random.default_rng(5)
    y_all, labels = usps_like(rng, n=n_big + 50)
    y_test = y_all[n_big:]
    y_masked, observed = drop_pixels(rng, y_test, frac=0.34)
    errs = {}
    for tag, ntr in (("small", n_small), ("big", n_big)):
        lv = BayesianGPLVM(y_all[:ntr], q=8, num_inducing=30, seed=0)
        lv.fit(max_iters=iters)
        rec = lv.reconstruct(y_masked, observed, iters=40)
        err = float(np.mean(np.abs(rec[:, ~observed]
                                   - y_test[:, ~observed])))
        errs[tag] = err
        print(f"  n={ntr:5d}: mean abs recon err (missing px) = {err:.4f}")
    gain = (errs["small"] - errs["big"]) / max(errs["small"], 1e-9) * 100
    print(f"  more-data improvement: {gain:.1f}% (paper: 5.9%)")
    return [("usps/recon_err_small", errs["small"], f"n={n_small}"),
            ("usps/recon_err_big", errs["big"], f"n={n_big}"),
            ("usps/more_data_gain_pct", gain, "paper=5.9")]


def psi2_variants(n=8192, m=128, q=4, iters=3):
    """Kernel-level bench: naive broadcast vs chunked vs MXU-matmul psi2
    (the §Perf GP hillclimb, CPU proxy timings)."""
    rng = np.random.default_rng(6)
    hyp = default_hyp(q)
    z = jnp.asarray(rng.standard_normal((m, q)))
    mu = jnp.asarray(rng.standard_normal((n, q)))
    s = jnp.asarray(rng.uniform(0.05, 0.5, (n, q)))
    w = jnp.ones((n,))

    def naive():
        return jnp.einsum("i,iab->ab", w, gpk.psi2_per_point(hyp, z, mu, s))

    fns = {
        "naive": jax.jit(naive),
        "chunked": jax.jit(lambda: gpk.psi2_chunked(hyp, z, mu, s, chunk=512)),
        "mxu": jax.jit(lambda: gpk.psi2_mxu(hyp, z, mu, s, w, chunk=512)),
    }
    rows = []
    ref = None
    for name, fn in fns.items():
        out = jax.block_until_ready(fn())
        ts = []
        for _ in range(iters):
            t0 = time.perf_counter()
            jax.block_until_ready(fn())
            ts.append(time.perf_counter() - t0)
        t = float(np.median(ts))
        if ref is None:
            ref = out
            err = 0.0
        else:
            err = float(jnp.max(jnp.abs(out - ref)) / jnp.max(jnp.abs(ref)))
        rows.append((f"psi2/{name}", t * 1e6, f"relerr={err:.1e}"))
        print(f"  psi2[{name:8}]: {t * 1e3:8.2f} ms  relerr={err:.1e}")
    return rows


def lm_train_microbench(arch="llama3.2-1b", steps=5):
    """Reduced-config LM train-step timing (tokens/s on this CPU)."""
    from repro.configs import all_configs
    from repro.optim.adam import AdamConfig
    from repro.train import steps as steps_mod
    from repro.data.tokens import TokenStream

    cfg = all_configs()[arch].reduced()
    b, t = 4, 128
    stream = TokenStream(cfg.vocab_size, t, b, seed=0)
    state, _ = steps_mod.init_train_state(cfg, jax.random.PRNGKey(0))
    ts_fn = jax.jit(steps_mod.make_train_step(cfg, AdamConfig()))
    state, _ = ts_fn(state, stream.batch(0))      # compile
    t0 = time.perf_counter()
    for i in range(1, steps + 1):
        state, metrics = ts_fn(state, stream.batch(i))
    jax.block_until_ready(metrics["loss"])
    dt = (time.perf_counter() - t0) / steps
    tok_s = b * t / dt
    print(f"  {arch} reduced: {dt * 1e3:.1f} ms/step, {tok_s:,.0f} tok/s")
    return [(f"lm/{arch}_step", dt * 1e6, f"{tok_s:.0f} tok/s")]


# Beyond-paper serving benchmarks (`--only predict` / `--only serve_ext` /
# `--only frontend`): live in serving.py but are re-exported here so the
# figure/bench namespace stays one-stop.
from .serving import (frontend_serving, predict_serving,  # noqa: E402,F401
                      serving_extensions)
