"""Online-update benchmark: incremental fold/refresh vs full rescan.

The claim under test is the continual-learning cost model:

  * absorbing a k-point block incrementally — ``stats.fold_stats`` on the
    reduced statistics plus the rank-k factor refresh
    (``serve.online.update_state``, O(m²k)) — costs the SAME regardless of
    how many points the posterior already summarises (flat in n);
  * the alternative, a retrain-style full rescan (re-map every point, then
    refactorise: ``partial_stats`` + ``extract_state``), is linear in n;
  * the refresh itself scales linearly in the block size k (the rank of
    the Cholesky update), never cubically in m.

Rows: ``online/update_n=...`` (incremental, swept over history size),
``online/rescan_n=...`` (the full-rescan baseline over the same sweep, with
the incremental speedup in the derived column), and ``online/refresh_k=...``
(refresh cost vs block size).  The derived column of the last update row
reports flatness: incremental time at the largest n over the smallest n
(≈1 when the cost model holds; the rescan ratio grows like the data).
"""
from __future__ import annotations

import numpy as np

import jax.numpy as jnp

from repro.core.stats import fold_stats, partial_stats
from repro.serve import extract_state
from repro.serve.online import update_state

from .gp_common import default_hyp
from .serving import _median_time


def _posterior(rng, n, m, q, d):
    hyp = default_hyp(q)
    x = jnp.asarray(rng.standard_normal((n, q)))
    y = jnp.asarray(rng.standard_normal((n, d)))
    z = jnp.asarray(rng.standard_normal((m, q)))
    stats = partial_stats(hyp, z, y, x, s=None, latent=False)
    return hyp, x, y, z, stats, extract_state(hyp, z, stats)


def online_updates(q=3, d=2, m=48, k=32,
                   n_sweep=(2_000, 8_000, 32_000, 128_000),
                   k_sweep=(1, 8, 32, 128), iters=5):
    """Update latency vs history size (incremental must stay flat while the
    rescan grows linearly) and refresh cost vs block size k."""
    rng = np.random.default_rng(11)
    rows = []
    xb = jnp.asarray(rng.standard_normal((k, q)))
    yb = jnp.asarray(rng.standard_normal((k, d)))

    # -- update latency vs history size n -----------------------------------
    t_inc = {}
    for n in n_sweep:
        hyp, x, y, z, stats, state = _posterior(rng, n, m, q, d)
        delta = partial_stats(hyp, z, yb, xb, s=None, latent=False)

        def incremental():
            folded = fold_stats(stats, delta)
            res = update_state(state, xb, yb)
            assert not res.fallback
            return folded.C, res.state.chol_sigma

        x_all = jnp.concatenate([x, xb])
        y_all = jnp.concatenate([y, yb])

        def rescan():
            st = partial_stats(hyp, z, y_all, x_all, s=None, latent=False)
            return extract_state(hyp, z, st).chol_sigma

        # parity while we're here: both routes land on the same factors
        np.testing.assert_allclose(
            np.asarray(update_state(state, xb, yb).state.chol_sigma),
            np.asarray(rescan()), rtol=1e-7, atol=1e-8)

        incremental(); rescan()          # warm both compile caches
        t_i = _median_time(incremental, iters)
        t_r = _median_time(rescan, iters)
        t_inc[n] = t_i
        rows.append((f"online/update_n={n}", t_i * 1e6,
                     f"incremental k={k} m={m}"))
        rows.append((f"online/rescan_n={n}", t_r * 1e6,
                     f"speedup={t_r / t_i:.1f}x"))
        print(f"  n={n:>7}: incremental {t_i * 1e3:8.2f} ms   "
              f"rescan {t_r * 1e3:8.2f} ms   ({t_r / t_i:6.1f}x)")

    flat = t_inc[max(n_sweep)] / t_inc[min(n_sweep)]
    rows.append((f"online/update_flatness_n={min(n_sweep)}..{max(n_sweep)}",
                 flat, "incremental t(max n)/t(min n); ~1 = flat in history"))
    print(f"  incremental flatness across {min(n_sweep)}->{max(n_sweep)}: "
          f"{flat:.2f}x (rescan would be ~{max(n_sweep) / min(n_sweep)}x)")

    # -- refresh cost vs block size k ---------------------------------------
    n_fix = n_sweep[0]
    _, _, _, _, _, state = _posterior(rng, n_fix, m, q, d)
    for kk in k_sweep:
        xk = jnp.asarray(rng.standard_normal((kk, q)))
        yk = jnp.asarray(rng.standard_normal((kk, d)))
        update_state(state, xk, yk)      # warm the per-(m, k) compile cache
        t_k = _median_time(lambda: update_state(state, xk, yk).state.c2,
                           iters)
        rows.append((f"online/refresh_k={kk}", t_k * 1e6,
                     f"{t_k / kk * 1e6:.1f} us/rank (m={m})"))
        print(f"  k={kk:>4}: refresh {t_k * 1e3:8.2f} ms "
              f"({t_k / kk * 1e6:8.1f} us per rank)")

    return rows
