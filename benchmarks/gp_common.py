"""Shared benchmark helpers: a host-side Map-Reduce harness that measures
per-shard map times the way the paper measures per-thread times.

This container has ONE physical core, so true thread-parallel speedup is
unmeasurable. The paper's own metric separates (a) time inside the two
Map-Reduce functions from (b) total time. We measure each shard's map
wall-clock individually and report the parallel-iteration time as
``max(shard times) + reduce + global`` — the exact quantity the paper's
figs. 2/3/5 plot (the reduce is the rate-limited barrier). The sequential
baseline is the same computation unsharded (the GPy analogue).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.bound import collapsed_bound
from repro.core.stats import Stats, partial_stats


def make_shard_fn(hyp, z, d, latent):
    """Jitted per-shard map: (y, mu, s) -> Stats (+ grads optional)."""

    def f(y, mu, s):
        return partial_stats(hyp, z, y, mu, s=s, latent=latent)

    return jax.jit(f)


def mapreduce_iteration(shard_fn, shards, hyp, z, d):
    """One paper iteration: per-shard map (timed individually), reduce,
    global bound. Returns (bound, times dict)."""
    times = []
    parts = []
    for (y, mu, s) in shards:
        t0 = time.perf_counter()
        st = shard_fn(y, mu, s)
        jax.block_until_ready(st.D)
        times.append(time.perf_counter() - t0)
        parts.append(st)
    t0 = time.perf_counter()
    st_tot = parts[0]
    for p in parts[1:]:
        st_tot = Stats(*(a + b for a, b in zip(st_tot, p)))
    bound = collapsed_bound(hyp, z, st_tot, d)
    jax.block_until_ready(bound)
    t_reduce = time.perf_counter() - t0
    return float(bound), {
        "shard_times": times,
        "t_map_parallel": max(times),   # paper's parallel wall-clock
        "t_map_total": sum(times),      # total compute (sequential analogue)
        "t_reduce_global": t_reduce,
    }


def split_shards(y, mu, s, k):
    ys = np.array_split(y, k)
    ms = np.array_split(mu, k)
    ss = np.array_split(s, k) if s is not None else [None] * k
    return [(jnp.asarray(a), jnp.asarray(b),
             None if c is None else jnp.asarray(c))
            for a, b, c in zip(ys, ms, ss)]


def default_hyp(q):
    return {"log_sf2": jnp.asarray(0.2), "log_ell": jnp.zeros(q),
            "log_beta": jnp.asarray(2.0)}
