"""Streaming map-step benchmark: the memory wall the chunked engine removes.

The monolithic GPLVM map materialises a transient (n, m, m, q) broadcast
(~65 KB/row at m=64, q=2, f64), so per-device memory — not compute — caps
the shard size.  The chunked map (``stats.partial_stats_chunked``) scans
fixed-size blocks into a constant-size carry, so its footprint is flat in n.

Three measurements:
  * parity     — streamed vs monolithic collapsed bound at a feasible n
                 (must agree to ~1e-10 rtol in float64);
  * memwall    — compiled temp bytes (XLA memory_analysis) of both programs
                 across a sweep of n: monolithic grows linearly, streamed
                 stays flat;
  * bigshard   — a shard size whose monolithic temp footprint exceeds the
                 memory budget (would OOM a device with that budget): only
                 the streaming path is run, timed end-to-end.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.bound import collapsed_bound
from repro.core.stats import partial_stats, partial_stats_chunked
from repro.kernels.reg_stats import reg_stats_fn_for_engine

from .gp_common import default_hyp


def _temp_bytes(fn, *avals) -> int | None:
    """Compiled temp bytes, or None where memory_analysis is unsupported
    (older JAX / some backends) — callers skip the memory rows then.
    Compile errors propagate: only the analysis call is allowed to fail."""
    compiled = jax.jit(fn).lower(*avals).compile()
    try:
        mem = compiled.memory_analysis()
    except (AttributeError, NotImplementedError):
        return None
    t = getattr(mem, "temp_size_in_bytes", None) if mem is not None else None
    return None if t is None else int(t)


def _mk_data(rng, n, m, q, d):
    y = rng.standard_normal((n, d))
    mu = rng.standard_normal((n, q))
    s = rng.uniform(0.1, 0.5, (n, q))
    z = jnp.asarray(rng.standard_normal((m, q)))
    return jnp.asarray(y), jnp.asarray(mu), jnp.asarray(s), z


def _rss_bytes() -> int:
    """Current resident set size (Linux /proc; no psutil dependency)."""
    import os

    with open("/proc/self/statm") as f:
        return int(f.read().split()[1]) * os.sysconf("SC_PAGE_SIZE")


def host_stream(n0=250_000, n_mults=(1, 2, 4), m=48, chunk=4096,
                bpc=4, iters=3):
    """Host-streaming ingestion: RSS flat in n, throughput vs in-memory.

    Two measurements on the ``DistributedGP`` streamed path (the data never
    exists as a host array — ``flight_like`` computes each chunk on demand,
    standing in for a memory-mapped >RAM file):

      * rss sweep   — full exact streamed pass at n0, 2 n0, 4 n0: host RSS
                      growth across the sweep must stay O(chunk), not O(n)
                      (an in-memory ingest of the 4 n0 endpoint would add
                      ~n * 80 bytes);
      * throughput  — streamed ingestion (chunk staging overlapped with the
                      fold by the double-buffered prefetcher) vs in-memory
                      ingestion (``put_data`` shard + transfer, then one
                      ``reduced_stats``) of the same host-resident rows:
                      streamed must hold >= 0.9x of the in-memory rows/s.
    """
    from repro.core.distributed import DistributedGP
    from repro.data.synthetic import flight_like
    from repro.launch.mesh import make_compat_mesh

    q, d = 8, 1
    rng = np.random.default_rng(0)
    hyp = default_hyp(q)
    z = jnp.asarray(rng.standard_normal((m, q)))
    n_dev = len(jax.devices())
    mesh = make_compat_mesh((n_dev,), ("data",))
    eng = DistributedGP(mesh, data_axes=("data",), latent=False,
                        chunk_size=chunk)
    rows = []

    # -- rss sweep: streamed pass at growing n, host memory flat ------------
    rss_deltas = {}
    for mult in n_mults:
        n = n0 * mult
        stream = eng.put_data(stream=flight_like(n=n, seed=3),
                              blocks_per_chunk=bpc)
        eng.streamed_stats(hyp, z, stream)          # warm-up/compile pass
        r0 = _rss_bytes()
        st = eng.streamed_stats(hyp, z, stream)
        jax.block_until_ready(st)
        rss_deltas[n] = _rss_bytes() - r0
        rows.append((f"hoststream/rss_n={n}", 0.0,
                     f"rss_delta_bytes={rss_deltas[n]}"))
        print(f"  n={n:>9,d}: streamed pass rss delta "
              f"{rss_deltas[n] / 2**20:+7.1f} MiB "
              f"(in-memory ingest would add ~{n * (q + d + 1) * 8 / 2**20:.0f} MiB)")
    n_hi, n_lo = n0 * n_mults[-1], n0 * n_mults[0]
    # Flat in n: going 1x -> 4x must not add memory proportional to the
    # extra rows (allow chunk-scale slack + 32 MiB allocator noise).
    slack = 32 * 2**20 + 4 * bpc * chunk * (q + d + 1) * 8 * n_dev
    assert rss_deltas[n_hi] - rss_deltas[n_lo] < slack, (
        f"streamed RSS grew with n: {rss_deltas}")

    # -- throughput: streamed vs in-memory ingestion of identical rows ------
    # Both sides start from host-resident arrays (the streamed side through
    # the BlockStream/ArraySource chunk path a memory-mapped file would
    # take), so the race is pad+transfer+map-reduce either way.
    raw = flight_like(n=n0, seed=3).read(0, n0)
    fmask = jnp.ones((eng.n_shards,))
    red = eng.reduced_stats(d=d)

    def ingest_inmem():
        data, w = eng.put_data(y=raw["y"], mu=raw["mu"])
        return red(hyp, z, data["y"], data["mu"], None, w, fmask)

    jax.block_until_ready(ingest_inmem())
    t_mem = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(ingest_inmem())
        t_mem.append(time.perf_counter() - t0)
    stream = eng.put_data(stream=raw, blocks_per_chunk=bpc)
    jax.block_until_ready(eng.streamed_stats(hyp, z, stream))
    t_str = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(eng.streamed_stats(hyp, z, stream))
        t_str.append(time.perf_counter() - t0)
    # Best-of-iters: the gate asks "can the streamed path keep up", so score
    # capability, not machine contention — the prefetch thread makes the
    # streamed side disproportionately sensitive to background CPU load.
    dt_mem, dt_str = float(min(t_mem)), float(min(t_str))
    ratio = dt_mem / dt_str
    rows.append((f"hoststream/throughput_n={n0}", dt_str * 1e6,
                 f"inmem_us={dt_mem * 1e6:.0f};streamed_x={ratio:.3f}"))
    print(f"  throughput n={n0:,}: in-memory {n0 / dt_mem:,.0f} rows/s, "
          f"streamed {n0 / dt_str:,.0f} rows/s ({ratio:.2f}x in-memory)")
    assert ratio >= 0.9, (
        f"streamed ingestion only {ratio:.2f}x of in-memory (need >= 0.9)")
    return rows


def streaming_map(n_parity=20_000, n_big=200_000, m=64, q=2, d=2,
                  block=2048, budget_gb=2.0, iters=3,
                  host_n0=250_000, host_mults=(1, 2, 4), host_chunk=4096,
                  host_bpc=4):
    rng = np.random.default_rng(0)
    hyp = default_hyp(q)
    rows = []

    def mono_bound(y, mu, s, z):
        st = partial_stats(hyp, z, y, mu, s=s, latent=True)
        return collapsed_bound(hyp, z, st, d)

    def stream_bound(y, mu, s, z):
        st = partial_stats_chunked(hyp, z, y, mu, s=s, latent=True,
                                   block_size=block)
        return collapsed_bound(hyp, z, st, d)

    # -- parity: streamed == monolithic bound in f64 ------------------------
    y, mu, s, z = _mk_data(rng, n_parity, m, q, d)
    b_mono = float(jax.jit(mono_bound)(y, mu, s, z))
    b_stream = float(jax.jit(stream_bound)(y, mu, s, z))
    rel = abs(b_stream - b_mono) / abs(b_mono)
    assert rel < 1e-8, f"streamed bound diverged: rel={rel:.2e}"
    rows.append((f"stream/parity_n={n_parity}", 0.0, f"rel_err={rel:.2e}"))
    print(f"  parity n={n_parity}: mono={b_mono:.6f} stream={b_stream:.6f} "
          f"rel={rel:.2e}")

    # -- memory wall: compiled temp bytes vs n ------------------------------
    f64 = jnp.float64
    for n in (n_parity, 2 * n_parity, 4 * n_parity):
        avals = (jax.ShapeDtypeStruct((n, d), f64),
                 jax.ShapeDtypeStruct((n, q), f64),
                 jax.ShapeDtypeStruct((n, q), f64),
                 jax.ShapeDtypeStruct((m, q), f64))
        t_mono = _temp_bytes(mono_bound, *avals)
        t_stream = _temp_bytes(stream_bound, *avals)
        if t_mono is None or t_stream is None:
            print("  (memory_analysis unsupported here — skipping the "
                  "memory-wall and big-shard sections)")
            rows.append(("stream/memwall", 0.0, "SKIPPED:no_memory_analysis"))
            return rows
        rows.append((f"stream/temp_bytes_n={n}", 0.0,
                     f"mono={t_mono};stream={t_stream}"))
        print(f"  n={n:>8d}  temp mono={t_mono / 2**20:9.1f} MiB   "
              f"stream={t_stream / 2**20:9.1f} MiB")

    # -- the big shard: only the streaming path fits the budget -------------
    budget = int(budget_gb * 2**30)
    avals = (jax.ShapeDtypeStruct((n_big, d), f64),
             jax.ShapeDtypeStruct((n_big, q), f64),
             jax.ShapeDtypeStruct((n_big, q), f64),
             jax.ShapeDtypeStruct((m, q), f64))
    t_mono_big = _temp_bytes(mono_bound, *avals)
    t_stream_big = _temp_bytes(stream_bound, *avals)
    assert t_mono_big is not None and t_stream_big is not None
    assert t_mono_big > budget > t_stream_big, (
        f"budget {budget} must separate mono {t_mono_big} from "
        f"stream {t_stream_big}; tune n_big/budget_gb")
    y, mu, s, z = _mk_data(rng, n_big, m, q, d)
    fn = jax.jit(stream_bound)
    b = float(fn(y, mu, s, z))  # warm up + prove it actually runs
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(y, mu, s, z))
        ts.append(time.perf_counter() - t0)
    dt = float(np.median(ts))
    rows.append((f"stream/bigshard_n={n_big}", dt * 1e6,
                 f"bound={b:.4f};mono_temp={t_mono_big};"
                 f"stream_temp={t_stream_big};budget={budget}"))
    print(f"  big shard n={n_big}: monolithic needs "
          f"{t_mono_big / 2**30:.2f} GiB temp (> {budget_gb:.1f} GiB budget "
          f"-> OOM); streamed needs {t_stream_big / 2**20:.1f} MiB and ran "
          f"in {dt * 1e3:.0f} ms/iter (bound={b:.2f})")

    # -- host streaming: RSS flat in n, throughput vs in-memory -------------
    rows.extend(host_stream(n0=host_n0, n_mults=host_mults, m=m,
                            chunk=host_chunk, bpc=host_bpc, iters=iters))
    return rows


def svi_map(n=32_768, m=48, q=2, d=1, block=1024, iters=5,
            batch_sweep=(1, 2, 4, 8, 16), n_mults=(1, 2, 4)):
    """Minibatch-stochastic (SVI) map step: per-step cost is O(B), flat in n.

    Two sweeps of the jitted per-step (value, grad) of the stochastic
    negative bound (``partial_stats_chunked(batch_blocks=B)`` + collapsed
    bound), against the exact-scan baseline, under both kernel backends
    (fused reg_stats runs in interpret mode off-TPU):

      * B sweep at fixed n  — step time grows with B (the exact scan is the
        B = nb endpoint);
      * n sweep at fixed B  — step time stays flat while the exact scan
        grows linearly: the memory-wall result of ``--only stream``, now
        for per-step *compute*.

    The per-step key is an argument of the jitted function (no recompile
    per step), exactly how ``fit_svi`` / ``make_gp_train_step`` drive it.
    """
    rng = np.random.default_rng(11)
    hyp = default_hyp(q)
    rows = []
    fused_fn = reg_stats_fn_for_engine(block_n=128, block_m=32)

    def step_time(n_rows, batch_blocks, reg_stats_fn):
        x = jnp.asarray(rng.standard_normal((n_rows, q)))
        y = jnp.asarray(rng.standard_normal((n_rows, d)))
        z = jnp.asarray(rng.standard_normal((m, q)))

        def neg(hyp_, z_, key):
            st = partial_stats_chunked(hyp_, z_, y, x, s=None, latent=False,
                                       reg_stats_fn=reg_stats_fn,
                                       block_size=block,
                                       batch_blocks=batch_blocks, key=key)
            return -collapsed_bound(hyp_, z_, st, d)

        vg = jax.jit(jax.value_and_grad(neg, argnums=(0, 1)))
        keys = [jax.random.PRNGKey(i) for i in range(iters + 1)]
        jax.block_until_ready(vg(hyp, z, keys[0]))       # compile
        ts = []
        for k in keys[1:]:
            t0 = time.perf_counter()
            jax.block_until_ready(vg(hyp, z, k))
            ts.append(time.perf_counter() - t0)
        return float(np.median(ts))

    nb = -(-n // block)
    for backend, fn in (("xla", None), ("pallas", fused_fn)):
        # -- B sweep at fixed n: per-step time follows B --------------------
        t_exact = step_time(n, None, fn)
        print(f"  [{backend}] n={n} (nb={nb} blocks): exact scan "
              f"{t_exact * 1e3:8.2f} ms/step")
        rows.append((f"svi/{backend}_exact_n={n}", t_exact * 1e6,
                     f"nb={nb}"))
        for B in batch_sweep:
            if B >= nb:
                continue
            t_b = step_time(n, B, fn)
            rows.append((f"svi/{backend}_B={B}_n={n}", t_b * 1e6,
                         f"frac_of_exact={t_b / t_exact:.3f}"))
            print(f"  [{backend}]   B={B:>3}: {t_b * 1e3:8.2f} ms/step "
                  f"({t_b / t_exact:5.1%} of exact)")
        # -- n sweep at fixed B: per-step time flat in n --------------------
        B = batch_sweep[len(batch_sweep) // 2]
        base = None
        for mult in n_mults:
            n_i = n * mult
            t_b = step_time(n_i, B, fn)
            t_e = t_exact if mult == 1 else step_time(n_i, None, fn)
            base = base or t_b
            rows.append((f"svi/{backend}_B={B}_nsweep_n={n_i}", t_b * 1e6,
                         f"exact_us={t_e * 1e6:.1f};vs_n1={t_b / base:.2f}"))
            print(f"  [{backend}]   n={n_i:>8} B={B}: svi "
                  f"{t_b * 1e3:8.2f} ms/step (x{t_b / base:4.2f} of n={n})  "
                  f"exact {t_e * 1e3:8.2f} ms/step")
    return rows


def reg_map_backends(n=20_000, m=64, q=3, d=2, block=2048, iters=3):
    """Regression map step, XLA vs fused-Pallas backend: wall-clock time and
    compiled peak temp bytes per backend, plus bound parity.

    Off-TPU the fused kernel runs in interpret mode (Pallas lowered through
    XLA on host), so the CPU timing is a correctness/footprint proxy — the
    HBM-traffic win (the (n, m) slab never leaving VMEM) shows on TPU.
    """
    rng = np.random.default_rng(7)
    hyp = default_hyp(q)
    x = jnp.asarray(rng.standard_normal((n, q)))
    y = jnp.asarray(rng.standard_normal((n, d)))
    z = jnp.asarray(rng.standard_normal((m, q)))
    fused_fn = reg_stats_fn_for_engine(block_n=128, block_m=32)

    def mk_map(reg_stats_fn, block_size):
        def f(y_, x_, z_):
            return partial_stats_chunked(hyp, z_, y_, x_, s=None,
                                         latent=False,
                                         reg_stats_fn=reg_stats_fn,
                                         block_size=block_size)
        return f

    backends = {
        "xla_mono": mk_map(None, None),
        "xla_stream": mk_map(None, block),
        "fused_stream": mk_map(fused_fn, block),
    }
    f64 = jnp.float64
    avals = (jax.ShapeDtypeStruct((n, d), f64),
             jax.ShapeDtypeStruct((n, q), f64),
             jax.ShapeDtypeStruct((m, q), f64))
    rows = []
    bound_ref = None
    # Off-TPU the fused kernel interprets in the caller's f64 (f64-level
    # parity); on TPU it computes in f32, so parity is f32-level there.
    fused_tol = 1e-4 if jax.default_backend() == "tpu" else 1e-8
    for name, fn in backends.items():
        jfn = jax.jit(fn)
        st = jax.block_until_ready(jfn(y, x, z))
        bound = float(collapsed_bound(hyp, z, st, d))
        if bound_ref is None:
            bound_ref = bound
        rel = abs(bound - bound_ref) / abs(bound_ref)
        tol = fused_tol if name.startswith("fused") else 1e-8
        assert rel < tol, f"{name} bound diverged: rel={rel:.2e}"
        ts = []
        for _ in range(iters):
            t0 = time.perf_counter()
            jax.block_until_ready(jfn(y, x, z))
            ts.append(time.perf_counter() - t0)
        dt = float(np.median(ts))
        tb = _temp_bytes(fn, *avals)
        tb_s = "n/a" if tb is None else str(tb)
        rows.append((f"regmap/{name}_n={n}", dt * 1e6,
                     f"temp_bytes={tb_s};bound_rel={rel:.1e}"))
        print(f"  {name:>13}: map {dt * 1e3:8.2f} ms/iter  "
              f"temp={'n/a' if tb is None else f'{tb / 2**20:.1f} MiB'}  "
              f"bound_rel={rel:.1e}")
    return rows
