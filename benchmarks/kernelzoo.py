"""Kernel-zoo benchmark: map-step and predict throughput per covariance
expression, both kernel backends.

The compositional kernel layer dispatches the fused Pallas fast path only
for the full-width SE-ARD expression; every other expression runs the
generic XLA fallback (its own analytic forms or Gauss-Hermite quadrature).
This sweep measures what that dispatch decision costs:

  * map step  — the chunked regression map (``partial_stats_chunked``) per
    expression, XLA dense vs the engine shim (fused Pallas for SE, generic
    fallback otherwise): the fused-SE vs generic gap is the price of a
    non-SE covariance on the training path.
  * psi map   — the GPLVM (latent) map per expression: analytic psi
    (SE/Linear/disjoint compositions) vs quadrature psi (Matern32/Periodic),
    the analytic-vs-quadrature gap.
  * predict   — warm serving throughput per expression through
    ``PredictEngine`` under both backends.

Parity between the two backends is asserted as it runs.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import covariance as cov
from repro.core.stats import partial_stats_chunked
from repro.kernels.reg_stats import reg_stats_fn_for_engine
from repro.serve import PredictEngine, extract_state


def _zoo(q):
    half = tuple(range(q // 2)) or (0,)
    rest = tuple(range(q // 2, q)) or (0,)
    return {
        "se": cov.SEARD(),
        "matern32": cov.Matern32(quad_order=5),
        "linear": cov.Linear(),
        "periodic": cov.Periodic(quad_order=5),
        "sum": cov.Sum(cov.SEARD(dims=half), cov.Linear(dims=rest)),
        "product": cov.Product(cov.SEARD(dims=half),
                               cov.Matern32(dims=rest, quad_order=5)),
    }


def _hyp_for(kernel, q):
    hyp = jax.tree.map(lambda v: jnp.asarray(v, jnp.float64),
                       kernel.default_hyp(q))
    hyp["log_beta"] = jnp.asarray(np.log(100.0))
    return hyp


def _median_time(fn, iters):
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def kernel_zoo(n=30_000, q=2, d=2, m=64, t=4096, block=1024, iters=3):
    """Per-expression map/psi/predict timing and the fused-SE gap."""
    rng = np.random.default_rng(11)
    x = jnp.asarray(rng.standard_normal((n, q)))
    y = jnp.asarray(rng.standard_normal((n, d)))
    s = jnp.asarray(rng.uniform(0.05, 0.3, (n, q)))
    z = jnp.asarray(rng.standard_normal((m, q)))
    xs = jnp.asarray(rng.standard_normal((t, q)))
    rows = []
    map_times: dict[str, float] = {}

    for name, kern in _zoo(q).items():
        hyp = _hyp_for(kern, q)

        # -- regression map step: XLA dense vs the engine shim --------------
        shim = reg_stats_fn_for_engine(block_n=128, block_m=32, kernel=kern)

        @jax.jit
        def map_xla(hyp_, kern_=kern):
            return partial_stats_chunked(hyp_, z, y, x, s=None, latent=False,
                                         block_size=block, kernel=kern_)

        @jax.jit
        def map_shim(hyp_, shim_=shim):
            return partial_stats_chunked(hyp_, z, y, x, s=None, latent=False,
                                         block_size=block, reg_stats_fn=shim_)

        st_x = jax.block_until_ready(map_xla(hyp))
        st_s = jax.block_until_ready(map_shim(hyp))
        rel = float(jnp.max(jnp.abs(st_s.D - st_x.D)) /
                    (jnp.max(jnp.abs(st_x.D)) + 1e-30))
        tol = 1e-4 if jax.default_backend() == "tpu" else 1e-8
        assert rel < tol, f"{name}: shim map diverged rel={rel:.2e}"
        dt_x = _median_time(lambda: map_xla(hyp), iters)
        dt_s = _median_time(lambda: map_shim(hyp), iters)
        map_times[name] = dt_s
        fused = "fused_se" if cov.is_fused_se(kern) else "generic"
        rows.append((f"kernelzoo/map_xla_{name}", dt_x * 1e6,
                     f"rows_per_s={n / dt_x:.0f}"))
        rows.append((f"kernelzoo/map_shim_{name}", dt_s * 1e6,
                     f"path={fused};rows_per_s={n / dt_s:.0f}"))
        print(f"  map  {name:>9}: xla {dt_x * 1e3:8.2f} ms  "
              f"shim[{fused}] {dt_s * 1e3:8.2f} ms  "
              f"({n / dt_s:10.0f} rows/s)")

        # -- GPLVM (psi) map: analytic vs quadrature route -------------------
        @jax.jit
        def map_psi(hyp_, kern_=kern):
            return partial_stats_chunked(hyp_, z, y, x, s=s, latent=True,
                                         block_size=block, kernel=kern_)

        jax.block_until_ready(map_psi(hyp))
        dt_p = _median_time(lambda: map_psi(hyp), iters)
        route = "analytic" if kern.analytic_psi() else "quadrature"
        rows.append((f"kernelzoo/psi_map_{name}", dt_p * 1e6,
                     f"route={route};rows_per_s={n / dt_p:.0f}"))
        print(f"  psi  {name:>9}: [{route:>10}] {dt_p * 1e3:8.2f} ms  "
              f"({n / dt_p:10.0f} rows/s)")

        # -- serving predict throughput, both engine backends ----------------
        st = jax.block_until_ready(map_shim(hyp))
        state = extract_state(hyp, z, st, kernel=kern)
        ref = None
        for backend in ("xla", "pallas"):
            eng = PredictEngine(state, block_size=min(block, 512),
                                kernel_backend=backend)
            mean, var = eng.predict(xs)                  # compile + parity
            if ref is None:
                ref = mean
            else:
                relp = float(jnp.max(jnp.abs(mean - ref)) /
                             (jnp.max(jnp.abs(ref)) + 1e-30))
                assert relp < tol, f"{name}/{backend}: rel={relp:.2e}"
            dt = _median_time(lambda: eng.predict(xs), iters)
            rows.append((f"kernelzoo/predict_{backend}_{name}", dt * 1e6,
                         f"qps={t / dt:.0f}"))
            print(f"  pred {name:>9} [{backend:>6}]: {dt * 1e3:8.2f} ms  "
                  f"({t / dt:10.0f} q/s)")

    # -- the headline number: fused SE vs the generic fallbacks -------------
    se_t = map_times["se"]
    for name, dt in map_times.items():
        if name == "se":
            continue
        rows.append((f"kernelzoo/map_gap_{name}", dt * 1e6,
                     f"vs_fused_se={dt / se_t:.2f}x"))
        print(f"  gap  {name:>9}: {dt / se_t:5.2f}x fused-SE map time")
    return rows
