"""Continual learning: ingest new data into a served posterior, no retrain.

The paper's sufficient statistics are additive across data blocks, so a
fitted model can absorb (or forget) a block by folding constant-size
statistics — ``SGPR.update`` / ``SGPR.forget`` — while the serving factors
refresh by a rank-k Cholesky update in O(m²k), never re-scanning history
and never refactorising the m×m system.  See docs/serving.md
("Continual learning").

  PYTHONPATH=src python examples/online_update.py
"""
import time

import numpy as np

from repro.core import SGPR


def stream(rng, k):
    """The next k points of the sine stream the model is learning."""
    x = rng.uniform(-3, 3, size=(k, 1))
    y = np.sin(2.0 * x) + 0.3 * np.cos(5.0 * x) + 0.1 * rng.standard_normal((k, 1))
    return x, y


def main():
    rng = np.random.default_rng(0)

    # -- day 0: fit on the history so far -----------------------------------
    x0, y0 = stream(rng, 400)
    model = SGPR(x0, y0, num_inducing=20, seed=0)
    model.fit(max_iters=60)
    xs = np.linspace(-3, 3, 200)[:, None]
    model.predict(xs)                      # build + warm the serving engine
    print(f"fitted on n={model.n}; bound={model.log_bound():.2f}")

    # -- the ingest-update-serve loop ---------------------------------------
    # Each arriving block folds in O(k·m²): statistics add, factors take a
    # rank-k update, and the live engine swaps to the refreshed state with
    # zero recompilation.  Parameters stay put (re-fit whenever you like —
    # the folded statistics give the exact bound on ALL data seen).
    blocks = []
    for step in range(3):
        xb, yb = stream(rng, 50)
        t0 = time.perf_counter()
        blocks.append(model.update(xb, yb))
        dt = (time.perf_counter() - t0) * 1e3
        print(f"ingested block {blocks[-1]} (k=50) in {dt:.1f} ms "
              f"-> n={model.n}, bound={model.log_bound():.2f}")

    # Parity: the incrementally updated posterior == retraining-free full
    # rebuild on everything seen so far (same hypers/inducing points).
    ref = SGPR(np.asarray(model.x), np.asarray(model.y), num_inducing=20,
               z=np.asarray(model.params["z"]))
    ref.params = model.params
    m_inc, v_inc = model.predict(xs)
    m_ref, v_ref = ref.predict(xs)
    err = float(np.max(np.abs(m_inc - m_ref)))
    print(f"incremental vs full-rescan posterior: max |Δmean| = {err:.2e}")
    assert err < 1e-8, "incremental update drifted from the exact posterior"

    # -- forget: remove a block (e.g. data retention) exactly ---------------
    model.forget(blocks[1])
    print(f"forgot block {blocks[1]} -> n={model.n}, "
          f"blocks held={model.num_blocks}, bound={model.log_bound():.2f}")

    # -- warm-start re-fit on the enlarged dataset --------------------------
    res = model.fit(max_iters=20)
    print(f"warm re-fit: bound={-res.f:.2f} in {res.n_iters} SCG iters")


if __name__ == "__main__":
    main()
