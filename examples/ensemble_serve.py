"""Serving an ensemble: N models, one executable; quantized wire states;
posterior samples.

The PR-5 serving surface end-to-end: fit a small fleet of SGPRs (bootstrap
resamples of one dataset), extract each model's constant-size
``PredictiveState``, quantize them to bf16 for shipping (the state is the
ONLY artifact a server needs), restore from disk, stack the fleet into one
batched pytree and serve every model per query through a single vmap-ed
block-scan executable — then draw posterior function samples from one of
the models.  See docs/serving.md.

  PYTHONPATH=src python examples/ensemble_serve.py
"""
import tempfile

import numpy as np

import jax

from repro.core import SGPR
from repro.serve import (MultiPredictEngine, PredictEngine, load_state,
                         save_state, stack_states)

N_MODELS = 3


def main():
    rng = np.random.default_rng(0)
    n = 400
    x = rng.uniform(-3, 3, size=(n, 1))
    true_f = lambda t: np.sin(2.0 * t) + 0.3 * np.cos(5.0 * t)  # noqa: E731
    y = true_f(x) + 0.1 * rng.standard_normal((n, 1))

    # -- training side: a bootstrap fleet, quantized for the wire -----------
    ckpt_dir = tempfile.mkdtemp(prefix="ensemble_serve_")
    for k in range(N_MODELS):
        idx = rng.choice(n, n, replace=True)            # bootstrap resample
        model = SGPR(x[idx], y[idx], num_inducing=20, seed=k)
        model.fit(max_iters=60)
        state16 = model.predictive_state().astype("bfloat16")
        save_state(f"{ckpt_dir}/model_{k}", state16, metadata={"member": k})
        if k == 0:
            # Sampling re-factorises query covariances, which sub-f32
            # storage rounding can make indefinite — so the member we
            # intend to draw functions from also ships a sampling-grade
            # f32 state (still half the f64 bytes).
            save_state(f"{ckpt_dir}/model_0_f32",
                       model.predictive_state().astype("float32"))
        print(f"member {k}: bound={model.log_bound():9.2f}  "
              f"state={state16.nbytes / 1024:.1f} KiB (bf16 wire format)")

    # -- serving side: restore the fleet, serve it from ONE executable ------
    fleet = [load_state(f"{ckpt_dir}/model_{k}")[0] for k in range(N_MODELS)]
    engine = MultiPredictEngine(stack_states(fleet), block_size=128)
    print(f"fleet engine: {engine.n_models} models, storage "
          f"{engine.state.z.dtype}, compute {engine.compute_dtype}")

    xs = np.linspace(-3, 3, 500)[:, None]
    mean, var = engine.predict(xs, include_noise=False)   # (N, t, d), (N, t)
    mu, v = engine.predict_mixture(xs)                    # ensemble moments
    rmse = float(np.sqrt(np.mean((np.asarray(mu) - true_f(xs)) ** 2)))
    print(f"ensemble of {N_MODELS} over {xs.shape[0]} queries: mixture RMSE "
          f"vs noiseless truth {rmse:.4f}")
    assert rmse < 0.2, "ensemble serving degraded"
    spread = float(np.mean(np.asarray(mean).std(axis=0)))
    print(f"between-member spread (mean over queries): {spread:.4f}")
    assert np.isfinite(np.asarray(v)).all() and (np.asarray(v) > 0).all()

    # -- posterior samples from member 0's sampling-grade f32 state ---------
    state0, _ = load_state(f"{ckpt_dir}/model_0_f32")
    eng0 = PredictEngine(state0, block_size=128)
    draws = eng0.sample(xs, 64, jax.random.PRNGKey(0))
    emp = np.asarray(draws).mean(axis=0)
    m0, v0 = (np.asarray(a) for a in eng0.predict(xs))
    # Monte-Carlo sanity: 6 standard errors of the 64-draw mean estimator.
    gap = float(np.max(np.abs(emp - m0)))
    bound = 6.0 * float(np.sqrt(v0.max() / draws.shape[0]))
    print(f"64 posterior draws from member 0: max |sample mean - posterior "
          f"mean| = {gap:.3f} (MC bound {bound:.3f})")
    assert gap < bound, "posterior samples drifted from the posterior mean"
    print("ensemble served, sampled, and sanity-checked — OK")


if __name__ == "__main__":
    main()
