"""Serving a trained sparse GP: extract state once, answer queries forever.

The paper's re-parametrisation means a fitted model compresses into a
constant-size ``PredictiveState`` — kernel hyper-parameters, inducing
inputs, and the precomputed q(u) factor solves.  A serving process loads
that state from disk (never the training data) and answers query batches
through the jitted block engine.  See docs/serving.md.

  PYTHONPATH=src python examples/serve_sgpr.py
"""
import tempfile

import numpy as np

from repro.core import SGPR
from repro.serve import PredictEngine, load_state, save_state


def main():
    rng = np.random.default_rng(0)
    n = 600
    x = rng.uniform(-3, 3, size=(n, 1))
    f = np.sin(2.0 * x) + 0.3 * np.cos(5.0 * x)
    y = f + 0.1 * rng.standard_normal((n, 1))

    # -- training side: fit, extract, persist -------------------------------
    model = SGPR(x, y, num_inducing=25, seed=0)
    model.fit(max_iters=80)
    state = model.predictive_state()
    n_factors = sum(a.size for a in (state.chol_kmm, state.chol_sigma,
                                     state.c2, state.a_mean, state.g))
    print(f"fitted bound: {model.log_bound():10.2f}; state: m={state.m} "
          f"q={state.q} d={state.d} (~{n_factors * 8 / 1024:.1f} KiB of factors)")
    ckpt_dir = tempfile.mkdtemp(prefix="serve_sgpr_")
    path = save_state(f"{ckpt_dir}/pstate", state, metadata={"example": "sgpr"})
    print(f"state saved to {path}")

    # -- serving side: restart from disk alone ------------------------------
    loaded, meta = load_state(f"{ckpt_dir}/pstate")
    engine = PredictEngine(loaded, block_size=128)
    print(f"state loaded (metadata={meta}); engine: block_size=128")

    xs = np.linspace(-3, 3, 500)[:, None]          # pads 500 -> 512
    mean, var = engine.predict(xs, include_noise=False)
    true = np.sin(2.0 * xs) + 0.3 * np.cos(5.0 * xs)
    rmse = float(np.sqrt(np.mean((np.asarray(mean) - true) ** 2)))
    print(f"batched predict over {xs.shape[0]} queries: RMSE vs noiseless "
          f"truth {rmse:.4f}")
    assert rmse < 0.2, "serving-path predictions degraded"

    # Round-trip sanity: the served posterior == the model's own predict.
    m_model, v_model = model.predict(xs)
    assert np.allclose(np.asarray(mean), m_model, rtol=1e-9, atol=1e-11)
    assert np.allclose(np.asarray(var), v_model, rtol=1e-8, atol=1e-10)
    print("served mean/var match the training-side predict — OK")


if __name__ == "__main__":
    main()
