"""Minibatch-stochastic (SVI) sparse GP regression on the streaming engine.

The exact bound scans every row block per optimiser step (O(n) per step);
the SVI mode visits ``batch_blocks`` random blocks and reweights, so a step
costs O(batch_blocks * chunk_size) no matter how large n grows — Hensman
et al.'s "GPs for Big Data" estimator on this repo's block machinery.  See
docs/training.md for the derivation and tuning guidance.

  PYTHONPATH=src python examples/svi_sgpr.py
"""
import numpy as np

from repro.core import SGPR


def main():
    rng = np.random.default_rng(0)
    n = 4000
    x = rng.uniform(-3, 3, size=(n, 1))
    f = np.sin(2.0 * x) + 0.3 * np.cos(5.0 * x)
    y = f + 0.1 * rng.standard_normal((n, 1))

    # 32 blocks of 128 rows; each SVI step touches 4 of them (512 rows),
    # an 8x cheaper step than the exact scan.
    model = SGPR(x, y, num_inducing=30, seed=0,
                 chunk_size=128, batch_blocks=4)
    print(f"n={n}, blocks of {model.chunk_size} rows, "
          f"{model.batch_blocks} blocks/step")
    print(f"initial exact bound: {model.log_bound():10.2f}")

    res = model.fit_svi(steps=300, lr=2e-2, seed=0, verbose=True)
    print(f"final exact bound:   {model.log_bound():10.2f}  "
          f"({res.n_steps} Adam steps, each scanning "
          f"{model.batch_blocks}/{-(-n // model.chunk_size)} blocks)")

    xs = np.linspace(-3, 3, 200)[:, None]
    mean, var = model.predict(xs, include_noise=False)
    true = np.sin(2.0 * xs) + 0.3 * np.cos(5.0 * xs)
    rmse = float(np.sqrt(np.mean((mean - true) ** 2)))
    sigma = float(1.0 / np.sqrt(np.exp(model.params["hyp"]["log_beta"])))
    print(f"test RMSE vs noiseless truth: {rmse:.4f} "
          f"(noise sd used to generate: 0.100, learned: {sigma:.3f})")


if __name__ == "__main__":
    main()
