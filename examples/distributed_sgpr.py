"""Distributed sparse-GP inference on a multi-device mesh, with a node
failure mid-optimisation (the paper's §3.2 + §5.2 in one script).

Run with a placeholder fleet (this is the paper's Map-Reduce on 8 'nodes'):

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      PYTHONPATH=src python examples/distributed_sgpr.py
"""
import jax
import jax.numpy as jnp
import numpy as np
from jax.flatten_util import ravel_pytree

from repro.core import DistributedGP
from repro.core.scg import scg
from repro.distributed.fault import FailureSimulator
from repro.launch.mesh import make_compat_mesh


def main():
    n_dev = len(jax.devices())
    mesh = make_compat_mesh((n_dev,), ("data",))
    print(f"mesh: {n_dev} data shards")

    rng = np.random.default_rng(0)
    n = 4000
    x = rng.uniform(-3, 3, size=(n, 2))
    y = (np.sin(x @ np.array([[1.2], [-0.7]]))
         + 0.1 * rng.standard_normal((n, 1)))
    z0 = x[rng.choice(n, 32, replace=False)]
    params = {
        "hyp": {"log_sf2": jnp.asarray(0.0), "log_ell": jnp.zeros(2),
                "log_beta": jnp.asarray(2.0)},
        "z": jnp.asarray(z0),
    }

    # chunk_size streams each shard's map in 128-row blocks: per-device
    # memory is O(128 * m) regardless of how many rows the shard holds
    # (drop it to None to get the monolithic map — same bound either way).
    eng = DistributedGP(mesh, data_axes=("data",), latent=False,
                        failure_mode="rescale", chunk_size=128)
    data, w = eng.put_data(y=y, mu=x)
    vg = eng.make_value_and_grad(d=1, argnums=(0, 1))
    nf = jnp.asarray(float(n))
    sim = FailureSimulator(eng.n_shards, rate=0.01, seed=3)

    flat0, unravel = ravel_pytree(params)
    it = [0]

    def fg(xf):
        p = unravel(jnp.asarray(xf))
        fmask = jnp.asarray(sim.mask())       # 1% node failures/iteration
        v, (gh, gz) = vg(p["hyp"], p["z"], data["mu"], None, data["y"], w,
                         fmask, nf)
        gf, _ = ravel_pytree({"hyp": gh, "z": gz})
        it[0] += 1
        return float(v), np.asarray(gf, np.float64)

    v0, _ = fg(np.asarray(flat0))
    print(f"initial bound: {-v0:10.2f}")
    res = scg(fg, np.asarray(flat0, np.float64), max_iters=100)
    print(f"final bound:   {-res.f:10.2f}  "
          f"({res.n_evals} map-reduce rounds, node failures @1%/iter, "
          f"rescaled partial sums)")


if __name__ == "__main__":
    main()
