"""Bayesian GPLVM dimensionality reduction (the paper's fig. 4 workflow).

Fits a GPLVM on the oil-flow-like dataset, reports the ARD-selected
effective dimensionality and 2-D embedding separation by class.

  PYTHONPATH=src python examples/gplvm_embedding.py
"""
import numpy as np

from repro.core import BayesianGPLVM
from repro.data.synthetic import oilflow_like


def main():
    rng = np.random.default_rng(0)
    y, labels = oilflow_like(rng, n=500)
    model = BayesianGPLVM(y, q=8, num_inducing=30, seed=0)
    print(f"initial bound: {model.log_bound():10.2f}")
    model.fit(max_iters=250)
    print(f"final bound:   {model.log_bound():10.2f}")

    w = model.ard_weights()
    order = np.argsort(w)[::-1]
    print("ARD weights (sorted):", np.round(np.sort(w)[::-1], 4))
    eff = int(np.sum(w > 0.1 * w.max()))
    print(f"effective latent dimensionality: {eff} of q=8")

    # class separation in the top-2 ARD dims (silhouette-like score)
    emb = model.latent_mean()[:, order[:2]]
    mus = np.stack([emb[labels == c].mean(0) for c in range(3)])
    within = np.mean([np.linalg.norm(emb[labels == c]
                                     - mus[c], axis=1).mean()
                      for c in range(3)])
    between = np.mean([np.linalg.norm(mus[i] - mus[j])
                       for i in range(3) for j in range(i + 1, 3)])
    print(f"class separation (between/within): {between / within:.2f}x")
    np.save("/tmp/gplvm_embedding.npy", emb)
    print("embedding saved to /tmp/gplvm_embedding.npy")


if __name__ == "__main__":
    main()
