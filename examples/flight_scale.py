"""Paper §5 at full scale: 2M-row flight-delay regression, streamed from host.

The flagship experiment of the paper trains a sparse GP on 2 million flight
records.  This script reproduces that *shape* end-to-end without ever
holding the dataset in memory: ``data.synthetic.flight_like`` is a
chunk-addressable generator (a stand-in for a 2M-row file on disk), the
engine folds its blocks through ``streamed_svi_value_and_grad`` — per-step
cost and per-shard memory are O(batch * chunk), flat in n — and serving
answers a query stream through ``PredictEngine.predict_stream``.

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      PYTHONPATH=src python examples/flight_scale.py

  # CI smoke (~seconds): 20k rows, 10 steps
  PYTHONPATH=src python examples/flight_scale.py --tiny
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import DistributedGP
from repro.data.synthetic import flight_like
from repro.launch.mesh import make_compat_mesh
from repro.serve.engine import PredictEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=2_000_000)
    ap.add_argument("--m", type=int, default=64, help="inducing points")
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--chunk", type=int, default=2048)
    ap.add_argument("--batch-chunks", type=int, default=4)
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke: 20k rows, 10 steps, small blocks")
    args = ap.parse_args()
    if args.tiny:
        args.n, args.m, args.steps = 20_000, 16, 10
        args.chunk, args.batch_chunks = 256, 2

    n_dev = len(jax.devices())
    mesh = make_compat_mesh((n_dev,), ("data",))
    eng = DistributedGP(mesh, data_axes=("data",), latent=False,
                        chunk_size=args.chunk)

    src = flight_like(n=args.n, seed=0)
    stream = eng.put_data(stream=src, blocks_per_chunk=1)
    print(f"flight_like n={args.n:,} q=8  ->  {stream.n_chunks} chunks of "
          f"{stream.chunk_rows} rows across {eng.n_shards} shards "
          f"(host holds one chunk at a time)")

    # Inducing inputs from the first chunk's covariates; delay target d=1.
    first = src.read(0, max(args.m, 256))
    rng = np.random.default_rng(0)
    z0 = first["mu"][rng.choice(first["mu"].shape[0], args.m, replace=False)]
    hyp = {"log_sf2": jnp.asarray(0.0), "log_ell": jnp.zeros(8),
           "log_beta": jnp.asarray(1.0)}
    z = jnp.asarray(z0)

    # SVI over the stream: each step folds batch_chunks random chunks.
    # Adam (as in SGPR.fit_svi) — raw bound gradients scale with n, so
    # plain SGD would need an n-dependent learning rate.
    step = eng.streamed_svi_value_and_grad(d=1,
                                           batch_chunks=args.batch_chunks)
    lr, b1, b2, eps = 2e-2, 0.9, 0.999, 1e-8
    params = (hyp, z)
    mom = jax.tree.map(jnp.zeros_like, params)
    vel = jax.tree.map(jnp.zeros_like, params)
    key = jax.random.PRNGKey(1)
    t0 = time.perf_counter()
    for i in range(args.steps):
        key, sub = jax.random.split(key)
        v, grads = step(params[0], params[1], stream, sub)
        mom = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, mom, grads)
        vel = jax.tree.map(lambda s, g: b2 * s + (1 - b2) * g * g, vel, grads)
        t = i + 1
        params = jax.tree.map(
            lambda p, m, s: p - lr * (m / (1 - b1 ** t))
            / (jnp.sqrt(s / (1 - b2 ** t)) + eps), params, mom, vel)
        if i % max(1, args.steps // 6) == 0 or i == args.steps - 1:
            print(f"  step {i:>4d}: stochastic bound {-float(v):14.1f}")
    hyp, z = params
    dt = time.perf_counter() - t0
    rows_seen = args.steps * args.batch_chunks * stream.chunk_rows
    print(f"{args.steps} SVI steps in {dt:.1f}s "
          f"({rows_seen / dt:,.0f} rows/s touched)")

    # Exact streamed bound: one full pass, still O(chunk) host memory.
    bound = eng.streamed_bound(hyp, z, stream, d=1)
    print(f"exact streamed bound over all {args.n:,} rows: {float(bound):,.1f}")

    # Serve a query stream against the streamed posterior.
    state = eng.streamed_predictive_state(hyp, z, stream)
    serve = PredictEngine(state, block_size=min(args.chunk, 512))
    q_src = flight_like(n=10 * 4096 if not args.tiny else 4096, seed=99)
    queries = (q_src.read(i, min(i + 4096, q_src.n))["mu"]
               for i in range(0, q_src.n, 4096))
    truth = (q_src.read(i, min(i + 4096, q_src.n))["y"]
             for i in range(0, q_src.n, 4096))
    se = w = 0.0
    for (mean, _), yt in zip(serve.predict_stream(queries), truth):
        se += float(np.sum((np.asarray(mean) - yt) ** 2))
        w += yt.size
    print(f"served {int(w):,} streamed queries: "
          f"RMSE vs noisy delays {np.sqrt(se / w):.3f} "
          f"(generator noise floor ~0.21)")


if __name__ == "__main__":
    main()
