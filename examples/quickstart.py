"""Quickstart: sparse GP regression with the re-parametrised bound.

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import SGPR


def main():
    rng = np.random.default_rng(0)
    n = 500
    x = rng.uniform(-3, 3, size=(n, 1))
    f = np.sin(2.0 * x) + 0.3 * np.cos(5.0 * x)
    y = f + 0.1 * rng.standard_normal((n, 1))

    model = SGPR(x, y, num_inducing=30, seed=0)
    print(f"initial bound: {model.log_bound():10.2f}")
    model.fit(max_iters=150, verbose=True)

    xs = np.linspace(-3, 3, 200)[:, None]
    mean, var = model.predict(xs, include_noise=False)
    true = np.sin(2.0 * xs) + 0.3 * np.cos(5.0 * xs)
    rmse = float(np.sqrt(np.mean((mean - true) ** 2)))
    sigma = float(1.0 / np.sqrt(np.exp(model.params["hyp"]["log_beta"])))
    print(f"test RMSE vs noiseless truth: {rmse:.4f} "
          f"(noise sd used to generate: 0.100, learned: {sigma:.3f})")
    inside = np.mean(np.abs(mean - true) <= 2 * np.sqrt(var)[:, None])
    print(f"2-sigma coverage of the truth: {inside * 100:.1f}%")


if __name__ == "__main__":
    main()
