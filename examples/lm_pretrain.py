"""End-to-end LM pre-training driver with checkpoint/restart (deliverable
(b)'s end-to-end example): trains a reduced llama3.2-style model for a few
hundred steps on the synthetic token stream, checkpointing every 50 steps,
then kills and resumes to demonstrate fault-tolerant restart.

  PYTHONPATH=src python examples/lm_pretrain.py [--steps 200]
"""
import argparse
import pathlib
import shutil

from repro.launch.train import main as train_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--arch", default="llama3.2-1b")
    args = ap.parse_args()

    ckdir = pathlib.Path("/tmp/lm_pretrain_ckpt")
    shutil.rmtree(ckdir, ignore_errors=True)

    half = args.steps // 2
    print(f"=== phase 1: train to step {half}, checkpoint every 50 ===")
    train_main(["--arch", args.arch, "--reduced", "--steps", str(half),
                "--batch", "8", "--seq", "128",
                "--ckpt-dir", str(ckdir), "--ckpt-every", "50"])

    print("\n=== simulated crash; phase 2: resume from latest checkpoint ===")
    losses = train_main(["--arch", args.arch, "--reduced",
                         "--steps", str(args.steps),
                         "--batch", "8", "--seq", "128",
                         "--ckpt-dir", str(ckdir), "--ckpt-every", "50"])
    print(f"\ntrained {args.steps} steps total across a restart; "
          f"final loss {losses[-1]:.4f}")


if __name__ == "__main__":
    main()
