"""A serving deployment in one file: fit, queue, burst, hot-swap, report.

``serve_sgpr.py`` ends where a deployment begins — with an engine that
answers one padded batch per call.  This example runs the production layer
on top (docs/serving.md "Request batching & SLOs"): an async ``Frontend``
that coalesces concurrent requests into the engine's block batches,
enforces per-request deadlines, and atomically hot-swaps a re-fitted
state mid-traffic.  Every response is checked bitwise against a direct
engine call on the state of the generation it was served under.

  PYTHONPATH=src python examples/serve_frontend.py
"""
import asyncio
import tempfile

import numpy as np

from repro.core import SGPR
from repro.serve import Frontend, PredictEngine, load_state, save_state


def fit_state(rng, wiggle):
    n = 400
    x = rng.uniform(-3, 3, size=(n, 1))
    y = np.sin(wiggle * x) + 0.1 * rng.standard_normal((n, 1))
    model = SGPR(x, y, num_inducing=20, seed=0)
    model.fit(max_iters=60)
    return model.predictive_state()


async def serve(state_a, ckpt_b, rng):
    engine = PredictEngine(state_a, block_size=128)
    async with Frontend(engine, max_wait_ms=2.0, max_batch_rows=128,
                        default_deadline_ms=250.0) as fe:
        n_shapes = fe.warmup()        # pre-compile every padded batch size
        print(f"frontend up: block 128, batches <= 128 rows, "
              f"{n_shapes} shapes warmed")

        # -- a concurrent burst: 60 clients, mixed request sizes ------------
        queries = [rng.uniform(-3, 3, size=(rng.integers(1, 9), 1))
                   for _ in range(60)]
        results = await asyncio.gather(*[fe.submit(x) for x in queries])
        c = fe.metrics.summary()["counters"]
        print(f"burst: {len(results)} requests answered in {c['flushes']} "
              f"flushes (mean batch "
              f"{c['flushed_requests'] / c['flushes']:.1f} requests)")
        assert c["flushes"] < len(results), "burst should coalesce"

        # -- hot swap mid-flight: new requests see the new generation -------
        load = [asyncio.ensure_future(fe.submit(x)) for x in queries[:20]]
        gen = fe.swap_state(ckpt_b)   # restored from the checkpoint sidecar
        after = await fe.submit(queries[0])
        inflight = await asyncio.gather(*load)
        print(f"hot swap -> generation {gen}; in-flight requests answered "
              f"on generations {sorted({r.generation for r in inflight})}, "
              f"new request on {after.generation}")
        assert after.generation == gen
        assert len(inflight) == 20, "a swap must not drop in-flight requests"

        # -- every response is bitwise its generation's engine answer -------
        engines = {0: PredictEngine(state_a, block_size=128),
                   gen: PredictEngine(load_state(ckpt_b)[0], block_size=128)}
        for x, res in zip(queries, list(results) + list(inflight)):
            ref_m, ref_v = engines[res.generation].predict(x)
            assert np.array_equal(res.mean, np.asarray(ref_m))
            assert np.array_equal(res.var, np.asarray(ref_v))
        print("all responses bitwise-match their generation's state — OK")

        summ = fe.metrics.summary()
        print(f"SLO summary: p50 wait {summ['wait']['p50'] * 1e3:.2f} ms, "
              f"p99 e2e {summ['e2e']['p99'] * 1e3:.2f} ms, "
              f"goodput {summ['goodput_rps']:.0f} req/s, "
              f"pad fraction {summ['pad_fraction']:.2f}")
        lo = fe.load_summary()
        print(f"engine load (per flush): min {lo['min'] * 1e3:.2f} ms, "
              f"mean {lo['mean'] * 1e3:.2f} ms, max {lo['max'] * 1e3:.2f} ms")
        assert summ["counters"]["completed"] == 81    # 60 + 20 + 1, none lost


def main():
    rng = np.random.default_rng(7)
    print("fitting generation-0 and generation-1 models ...")
    state_a = fit_state(rng, wiggle=2.0)
    state_b = fit_state(rng, wiggle=2.4)       # the "re-fit" to roll out
    ckpt_dir = tempfile.mkdtemp(prefix="serve_frontend_")
    ckpt_b = save_state(f"{ckpt_dir}/refit", state_b)
    asyncio.run(serve(state_a, str(ckpt_b), rng))


if __name__ == "__main__":
    main()
