"""Kernel zoo walkthrough: a composite covariance end-to-end.

Fits a function with a linear trend plus a smooth bump using
``Sum(SEARD(dims=(0,)), Linear(dims=(1,)))``, compares it against the
default SE-ARD, then serves the fitted posterior — the kernel spec rides
in the checkpoint sidecar, so the reload needs no model code.

  PYTHONPATH=src python examples/kernel_zoo.py
"""
import os
import tempfile

import numpy as np

from repro.core import SEARD, SGPR, Linear, Sum
from repro.serve import PredictEngine, load_state, save_state, state_from_model


def main():
    rng = np.random.default_rng(0)
    n = 400
    # dim 0 drives a smooth nonlinearity, dim 1 a pure linear trend.
    x = rng.uniform(-3, 3, size=(n, 2))
    f = np.sin(2.0 * x[:, :1]) + 0.8 * x[:, 1:]
    y = f + 0.1 * rng.standard_normal((n, 1))

    kern = Sum(SEARD(dims=(0,)), Linear(dims=(1,)))
    print(f"kernel spec: {kern}")

    model = SGPR(x, y, num_inducing=30, kernel=kern, seed=0)
    model.fit(max_iters=100)
    se = SGPR(x, y, num_inducing=30, seed=0)
    se.fit(max_iters=100)
    print(f"bound  Sum(SE0, Linear1): {model.log_bound():10.2f}")
    print(f"bound  SE-ARD (default) : {se.log_bound():10.2f}")

    xs = rng.uniform(-3, 3, size=(200, 2))
    true = np.sin(2.0 * xs[:, :1]) + 0.8 * xs[:, 1:]
    for name, mdl in (("composite", model), ("se-ard", se)):
        mean, _ = mdl.predict(xs)
        rmse = float(np.sqrt(np.mean((mean - true) ** 2)))
        print(f"test RMSE [{name:>9}]: {rmse:.4f}")

    # Serving round-trip: the sidecar carries the kernel spec.
    state = state_from_model(model)
    with tempfile.TemporaryDirectory() as td:
        path = os.path.join(td, "zoo_state.npz")
        save_state(path, state)
        loaded, meta = load_state(path)
        print(f"restored kernel from sidecar: {loaded.kernel}")
        eng = PredictEngine(loaded, block_size=64)
        mean, var = eng.predict(xs)
        rmse = float(np.sqrt(np.mean((np.asarray(mean) - true) ** 2)))
        print(f"served RMSE (reloaded state): {rmse:.4f}  "
              f"(mean var {float(np.mean(var)):.4f})")


if __name__ == "__main__":
    main()
