"""Offline markdown link checker for README.md + docs/ (the CI docs job).

Checks every inline markdown link ``[text](target)`` whose target is a
relative path: the file must exist (anchors are stripped; pure-anchor and
http(s)/mailto links are skipped — the job must pass without network).

Usage: python tools/check_docs_links.py README.md docs [more files/dirs...]
Exits 1 listing every broken link.
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

# Inline links (images too — the leading "!" is irrelevant to the target).
# The target may contain spaces or be <angle-bracketed>; fenced code blocks
# are stripped before matching.
_LINK = re.compile(r"\[[^\]]*\]\(([^)]+)\)")
_TITLE = re.compile(r'^(.*?)\s+"[^"]*"$')
_FENCE = re.compile(r"```.*?```", re.DOTALL)


def md_files(args: list[str]) -> list[Path]:
    out: list[Path] = []
    for a in args:
        p = Path(a)
        if p.is_dir():
            out.extend(sorted(p.rglob("*.md")))
        else:
            out.append(p)
    return out


def check(files: list[Path]) -> list[str]:
    errors = []
    for f in files:
        if not f.exists():
            errors.append(f"{f}: file does not exist")
            continue
        text = _FENCE.sub("", f.read_text(encoding="utf-8"))
        for target in _LINK.findall(text):
            target = target.strip()
            if target.startswith("<") and target.endswith(">"):
                target = target[1:-1]
            target = _TITLE.sub(r"\1", target)   # drop optional "title"
            if target.startswith(("http://", "https://", "mailto:", "#")):
                continue
            rel = target.split("#", 1)[0]
            if not rel:
                continue
            dest = (f.parent / rel).resolve()
            if not dest.exists():
                errors.append(f"{f}: broken link -> {target}")
    return errors


def main() -> int:
    args = sys.argv[1:] or ["README.md", "docs"]
    files = md_files(args)
    if not files:
        print("no markdown files found", file=sys.stderr)
        return 1
    errors = check(files)
    for e in errors:
        print(e, file=sys.stderr)
    print(f"checked {len(files)} file(s): "
          f"{'FAIL' if errors else 'ok'} ({len(errors)} broken)")
    return 1 if errors else 0


if __name__ == "__main__":
    raise SystemExit(main())
