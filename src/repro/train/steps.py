"""train_step / serve_step builders shared by the trainer, benchmarks, and
the multi-pod dry-run (which lowers these exact functions).

TrainState = {params, opt {m, v, step}}. The builders return pure functions
suitable for jax.jit with in/out shardings derived from the model's logical
spec tree (distributed/sharding.py).
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig, ShapeSpec
from ..models import transformer as tf
from ..optim import adam as adam_mod

Array = jax.Array


def init_train_state(cfg: ModelConfig, key) -> tuple[dict, dict]:
    """Returns (state, spec tree matching state)."""
    params, pspecs = tf.init_params(cfg, key)
    opt = adam_mod.init_opt_state(params)
    state = {"params": params, "opt": opt}
    specs = {"params": pspecs,
             "opt": {"m": pspecs, "v": pspecs, "step": ()}}
    return state, specs


def make_train_step(cfg: ModelConfig, adam_cfg: adam_mod.AdamConfig | None = None,
                    compression=None):
    adam_cfg = adam_cfg or adam_mod.AdamConfig()

    def train_step(state, batch):
        def loss_fn(params):
            return tf.forward_train(cfg, params, batch)

        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(state["params"])
        if compression is not None:
            grads = compression(grads)
        new_params, new_opt, opt_metrics = adam_mod.adam_update(
            adam_cfg, state["params"], grads, state["opt"])
        metrics = {**metrics, **opt_metrics}
        return {"params": new_params, "opt": new_opt}, metrics

    return train_step


def make_gp_train_step(mesh, d: int, *, data_axes=("data",),
                       latent: bool = False, failure_mode: str = "drop",
                       psi2_fn=None, reg_stats_fn=None,
                       chunk_size: int | None = None,
                       kernel_backend: str = "xla",
                       batch_blocks: int | None = None, argnums=(0, 1),
                       kernel=None, reduce_mode: str = "serial"):
    """Distributed GP map-reduce analogue of ``make_train_step``.

    Returns ``(engine, step)`` where ``step`` is the jitted
    (value, grad) of the negative collapsed bound —
    ``step(hyp, z, mu, s, y, w, fmask, n_full)`` with shapes
    ``hyp`` (log-space dict), ``z`` (m, q), ``mu`` (n_pad, q), ``s``
    (n_pad, q) or None, ``y`` (n_pad, d), ``w`` (n_pad,), ``fmask``
    (n_shards,), ``n_full`` scalar.

    ``chunk_size`` (default None = monolithic) streams each shard's map in
    fixed-size row blocks so per-device memory is O(chunk_size),
    independent of the shard's row count (see ``core.distributed`` for the
    streaming memory model).  ``kernel_backend="pallas"`` ("xla" default)
    routes each block's hot accumulation through the fused Pallas kernels
    (``kernels.reg_stats`` / ``kernels.psi_stats``).

    ``batch_blocks`` (default None = exact bound; requires ``chunk_size``)
    switches to the minibatch-stochastic (SVI) bound: each shard samples
    that many of its row blocks per step and reweights, so per-step compute
    is O(batch_blocks * chunk_size) per shard, flat in n.  The step then
    takes one extra trailing argument — a fresh ``jax.random.PRNGKey``:
    ``step(hyp, z, mu, s, y, w, fmask, n_full, key)`` — and returns an
    unbiased stochastic estimate (see docs/training.md).

    ``kernel`` (default None = SE-ARD) picks the covariance expression
    (``core.covariance``); ``hyp`` must then carry that expression's
    parameter tree (``init_utils.default_hyp_for`` builds one).

    ``reduce_mode`` ("serial" default; "overlap" / "overlap_eager",
    requires ``chunk_size``) selects the overlapped per-block reduce —
    the collective for one scan block rides behind the next block's
    compute instead of serialising after the whole map (see
    ``core.distributed.DistributedGP``).
    """
    from ..core.distributed import DistributedGP

    eng = DistributedGP(mesh, data_axes=data_axes, latent=latent,
                        failure_mode=failure_mode, psi2_fn=psi2_fn,
                        reg_stats_fn=reg_stats_fn, chunk_size=chunk_size,
                        kernel_backend=kernel_backend,
                        batch_blocks=batch_blocks, kernel=kernel,
                        reduce_mode=reduce_mode)
    return eng, eng.make_value_and_grad(d, argnums=argnums)


def make_gp_async_step(shards, d: int, *, staleness: int = 2,
                       reweight: str = "drop", refresh: int = 1,
                       failure=None, timer=None,
                       chunk_size: int | None = None,
                       batch_blocks: int | None = None,
                       latent: bool = False, kernel=None,
                       clip: float | None = None):
    """Barrier-free async analogue of :func:`make_gp_train_step`.

    Returns ``(engine, step)`` where ``engine`` is a
    ``distributed.async_stats.AsyncEngine`` over host-simulated
    ``shards`` (list of ``{"y", "mu", optional "s"/"w"}`` dicts, ragged
    row counts allowed) and ``step(hyp, z, key=None) -> (neg_bound,
    (g_hyp, g_z))``.  Each step refreshes only ``refresh`` alive shards
    (round-robin; ``failure`` — a ``fault.FailureSimulator`` — vetoes
    dead ones) and folds the others' stale contributions, bounded at
    ``staleness`` steps and reweighted per ``reweight``
    ("drop"/"rescale"/"probs" — see ``distributed.async_stats``).
    Per-step map cost is O(refresh · n_k m²) instead of O(K · n_k m²).

    ``clip`` bounds the returned gradient's global norm — recommended for
    plain SGD on stale folds (see ``AsyncEngine``); ``None`` returns raw
    gradients.
    """
    from ..distributed.async_stats import AsyncEngine

    eng = AsyncEngine(shards, d, staleness=staleness, reweight=reweight,
                      refresh=refresh, failure=failure, timer=timer,
                      chunk_size=chunk_size, batch_blocks=batch_blocks,
                      latent=latent, kernel=kernel, clip=clip)
    return eng, eng.step


def make_gp_update_step(mesh, d: int, *, data_axes=("data",),
                        latent: bool = False, psi2_fn=None,
                        reg_stats_fn=None, chunk_size: int | None = None,
                        kernel_backend: str = "xla", kernel=None):
    """Distributed *online-update* step builder — the continual-learning
    analogue of :func:`make_gp_train_step`.

    Returns ``(engine, fold_step)`` where ``fold_step(base_stats, hyp, z,
    y_new, mu_new, s_new, w_new, fmask) -> Stats`` absorbs a new sharded
    data block into already-reduced statistics: shards map their slice of
    the block locally (exact scan), one constant-size psum reduces, and
    the replicated base folds in (``stats.fold_stats``).  Cost is
    independent of how much history ``base_stats`` summarises.  Pair with
    ``engine.update_predictive_state`` (rank-k factor refresh, no
    collectives) to move the serving state, and ``stats.downdate_stats``
    to forget.  No ``batch_blocks``: fold/downdate identities require the
    exact (unscaled) block statistics — SVI belongs to training steps.
    """
    from ..core.distributed import DistributedGP

    eng = DistributedGP(mesh, data_axes=data_axes, latent=latent,
                        psi2_fn=psi2_fn, reg_stats_fn=reg_stats_fn,
                        chunk_size=chunk_size, kernel_backend=kernel_backend,
                        kernel=kernel)
    return eng, eng.update_stats_fn(d)


def make_prefill_step(cfg: ModelConfig):
    def prefill_step(params, batch):
        return tf.forward_prefill(cfg, params, batch)

    return prefill_step


def make_serve_step(cfg: ModelConfig):
    def serve_step(params, caches, tokens_t, pos):
        return tf.decode_step(cfg, params, caches, tokens_t, pos)

    return serve_step


# ---------------------------------------------------------------------------
# abstract inputs for dry-run lowering (ShapeDtypeStruct, no allocation)
# ---------------------------------------------------------------------------

def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict[str, Any]:
    """Abstract batch for (cfg, shape). Training/prefill: full sequences;
    decode: one new token + the KV/state cache at shape.seq_len."""
    b, t = shape.global_batch, shape.seq_len
    sds = jax.ShapeDtypeStruct
    if shape.kind in ("train", "prefill"):
        batch = {"tokens": sds((b, t), jnp.int32),
                 "labels": sds((b, t), jnp.int32)}
        if cfg.family == "encdec":
            batch["frames"] = sds((b, cfg.num_frames, cfg.d_model),
                                  jnp.bfloat16)
        if shape.kind == "prefill":
            del batch["labels"]
        return batch
    # decode: tokens (B,1) + pos + caches
    caches = jax.eval_shape(lambda: tf.init_decode_cache(cfg, b, t))
    return {"tokens_t": sds((b, 1), jnp.int32),
            "pos": sds((b,), jnp.int32),
            "caches": caches}


_CACHE_LOGICAL = {
    # decode-cache leaf name -> logical axes (rank-matched, padded with None)
    "k": ("batch", "seq_shard", "kv_heads", None),
    "v": ("batch", "seq_shard", "kv_heads", None),
    "pos": ("batch", None),
    "c_kv": ("batch", "seq_shard", None),
    "k_rope": ("batch", "seq_shard", None),
    "ssd": ("batch", "heads", None, None),
    "conv": ("batch", None, "inner"),
    "h": ("batch", "lru"),
    "xk": ("batch", None, "kv_heads", None),
    "xv": ("batch", None, "kv_heads", None),
}


def cache_specs(cfg: ModelConfig, caches_sds) -> Any:
    """Logical-axes tree matching an (abstract) decode-cache pytree."""

    def one_fixed(path, leaf):
        name = None
        for p in reversed(path):
            if hasattr(p, "key"):
                name = p.key
                break
        base = _CACHE_LOGICAL.get(name, ())
        if leaf.ndim == len(base):
            return tuple(base)
        if leaf.ndim == len(base) + 1:      # stacked over layers
            return ("layers",) + tuple(base)
        return (None,) * leaf.ndim

    import jax.tree_util as jtu
    return jtu.tree_map_with_path(one_fixed, caches_sds)


def batch_specs(cfg: ModelConfig, batch_sds) -> Any:
    """Logical axes for a train/prefill/decode input batch."""
    out = {}
    for k, v in batch_sds.items():
        if k == "caches":
            out[k] = cache_specs(cfg, v)
        elif k == "frames":
            out[k] = ("batch", None, None)
        elif k == "pos":
            out[k] = ("batch",)
        else:  # tokens / labels / tokens_t
            out[k] = ("batch", None)[:v.ndim] if v.ndim else ()
            out[k] = tuple(out[k]) + (None,) * (v.ndim - len(out[k]))
    return out


def abstract_state(cfg: ModelConfig) -> tuple[dict, dict]:
    """(ShapeDtypeStruct train state, matching logical spec tree)."""
    state_shapes = jax.eval_shape(
        functools.partial(_init_state_nokey, cfg))
    # spec tree must be built concretely (it is plain metadata)
    _, specs = _specs_only(cfg)
    return state_shapes, specs


def _init_state_nokey(cfg):
    state, _ = init_train_state(cfg, jax.random.PRNGKey(0))
    return state


@functools.lru_cache(maxsize=None)
def _specs_cache():
    return {}


def _specs_only(cfg):
    cache = _specs_cache()
    if cfg.name not in cache:
        # Build specs via an abstract init (no device allocation).
        def f():
            _, pspecs = tf.init_params(cfg, jax.random.PRNGKey(0))
            return pspecs

        # specs are static metadata produced during tracing; evaluate the
        # init abstractly and capture specs from a side channel.
        holder = {}

        def g():
            params, pspecs = tf.init_params(cfg, jax.random.PRNGKey(0))
            holder["specs"] = pspecs
            return params

        jax.eval_shape(g)
        pspecs = holder["specs"]
        cache[cfg.name] = {
            "params": pspecs,
            "opt": {"m": pspecs, "v": pspecs, "step": ()},
        }
    return None, cache[cfg.name]
