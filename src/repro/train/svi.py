"""Stochastic (SVI) optimisation loop for the minibatch-reweighted bound.

A deliberately tiny Adam-on-a-pytree driver shared by ``SGPR.fit_svi``,
``BayesianGPLVM.fit_svi``, the SVI example, and the ``--only svi``
benchmark.  It is *not* the LM substrate's AdamW (``optim/adam.py``): GP
hyper-parameters live in float64 and must stay there (the collapsed bound's
Cholesky factors are f64), so the moments here are kept in each leaf's own
dtype and nothing round-trips through f32.  No weight decay either — decay
on log-hyper-parameters or inducing inputs would silently bias the model.

The objective contract matches what the engines hand out: a jitted
``neg_vg(params, key) -> (value, grads)`` where ``value`` is an *unbiased
stochastic estimate* of the negative bound (see ``stats.
partial_stats_chunked(batch_blocks=...)``).  One fresh fold of the run key
is consumed per step — the caller never touches key plumbing.

SCG (the exact-bound optimiser used by ``fit``) is unusable here: its line
searches compare function values across calls, which a resampled minibatch
objective breaks.  Plain first-order steps with a constant rate are the
standard SVI recipe (Hensman et al., arXiv:1309.6835).
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

Array = jax.Array


class SVIResult(NamedTuple):
    params: dict        # optimised parameter pytree
    history: list       # per-step stochastic estimates of the NEGATIVE bound
    n_steps: int


def adam_init(params):
    """Zero first/second moments, matching each leaf's shape *and dtype*."""
    zeros = lambda p: jnp.zeros(jnp.shape(p), jnp.result_type(p))
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def adam_step(params, grads, opt, lr: float, b1: float = 0.9,
              b2: float = 0.999, eps: float = 1e-8):
    """One dtype-preserving Adam update. Returns (new_params, new_opt)."""
    t = opt["step"] + 1
    tf = t.astype(jnp.float64)
    b1c = 1.0 - b1 ** tf
    b2c = 1.0 - b2 ** tf

    def upd(p, g, m, v):
        m2 = b1 * m + (1.0 - b1) * g
        v2 = b2 * v + (1.0 - b2) * g * g
        # The bias corrections are f64 scalars; cast the delta back so an
        # f32 leaf stays f32 (the dtype-preserving contract above).
        delta = (lr * (m2 / b1c) / (jnp.sqrt(v2 / b2c) + eps)).astype(p.dtype)
        return p - delta, m2, v2

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(opt["m"])
    flat_v = tdef.flatten_up_to(opt["v"])
    out = [upd(p, g, m, v)
           for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    return (tdef.unflatten([o[0] for o in out]),
            {"m": tdef.unflatten([o[1] for o in out]),
             "v": tdef.unflatten([o[2] for o in out]),
             "step": t})


def svi_fit(
    neg_vg: Callable,
    params: dict,
    key: Array,
    steps: int = 200,
    lr: float = 1e-2,
    callback: Callable | None = None,
) -> SVIResult:
    """Run ``steps`` Adam updates on a stochastic objective.

    Args:
      neg_vg: ``(params, key) -> (neg_bound_estimate, grads)`` — typically
        ``jax.jit(jax.value_and_grad(...))`` over a ``batch_blocks`` map.
      params: initial parameter pytree (any nesting; leaves are arrays).
      key: run PRNG key; step i uses ``jax.random.fold_in(key, i)`` so runs
        are reproducible and steps are independent.
      steps / lr: Adam step count and (constant) learning rate.
      callback: optional ``callback(step, value, params)`` for logging.
    """
    opt = adam_init(params)
    jstep = jax.jit(adam_step, static_argnames=("lr", "b1", "b2", "eps"))
    history = []
    for i in range(steps):
        v, g = neg_vg(params, jax.random.fold_in(key, i))
        params, opt = jstep(params, g, opt, lr=lr)
        history.append(float(v))
        if callback is not None:
            callback(i, float(v), params)
    return SVIResult(params=params, history=history, n_steps=steps)
