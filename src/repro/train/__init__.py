from . import steps
