from . import steps, svi
