import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

"""Multi-pod dry-run: lower + compile every (architecture x input shape)
on the production meshes with 512 placeholder host devices, and record the
numbers the roofline analysis needs.

  PYTHONPATH=src python -m repro.launch.dryrun --mesh single --archs qwen2-1.5b
  PYTHONPATH=src python -m repro.launch.dryrun --mesh multi            # all
  PYTHONPATH=src python -m repro.launch.dryrun --gp                    # GP cells

Writes one JSON per cell to artifacts/dryrun/<mesh>/<arch>__<shape>.json:
memory_analysis, cost_analysis (FLOPs/bytes), and collective bytes parsed
from the optimised HLO. Failures (sharding mismatch, OOM at compile) are
bugs in the system — the run exits non-zero listing them.
"""
import argparse  # noqa: E402
import json  # noqa: E402
import pathlib  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import SHAPES, all_configs, cells, GP_CONFIGS  # noqa: E402
from repro.distributed import sharding as shlib  # noqa: E402
from repro.launch.hlo_analyzer import analyze  # noqa: E402
from repro.launch.hlo_stats import collective_bytes, cost_stats, memory_stats  # noqa: E402
from repro.launch.mesh import gp_data_axes, make_production_mesh  # noqa: E402
from repro.train import steps  # noqa: E402

ART = pathlib.Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"


def _save(out_dir: pathlib.Path, tag: str, stats: dict):
    """Write the JSON + a gzipped HLO dump for offline re-analysis."""
    import gzip
    hlo = stats.pop("_hlo_text", None)
    (out_dir / f"{tag}.json").write_text(json.dumps(stats))
    if hlo is not None:
        with gzip.open(out_dir / f"{tag}.hlo.gz", "wt") as f:
            f.write(hlo)


def _shardings_for(specs, sds, mesh):
    return shlib.tree_shardings(specs, sds, mesh)


def lower_cell(arch: str, shape_name: str, mesh, variant: str = "baseline"):
    """Lower + compile one (arch, shape) on ``mesh``; returns stats dict."""
    import dataclasses
    cfg = all_configs()[arch]
    # perf-variant knobs (see EXPERIMENTS.md §Perf)
    for v in variant.split("+"):
        if v == "flash":
            cfg = dataclasses.replace(cfg, use_flash=True)
        elif v == "a2a_int8":
            cfg = dataclasses.replace(cfg, moe_dispatch_dtype="int8")
        elif v == "cap10":
            cfg = dataclasses.replace(cfg, capacity_factor=1.0)
        elif v == "noremat":
            cfg = dataclasses.replace(cfg, remat=False)
        elif v == "remat_dots":
            cfg = dataclasses.replace(cfg, remat_policy="dots")
    shape = SHAPES[shape_name]
    t0 = time.time()
    with shlib.use_mesh(mesh):
        state_sds, specs = steps.abstract_state(cfg)
        state_sh = _shardings_for(specs, state_sds, mesh)
        batch_sds = steps.input_specs(cfg, shape)
        b_specs = steps.batch_specs(cfg, batch_sds)
        batch_sh = _shardings_for(b_specs, batch_sds, mesh)

        if shape.kind == "train":
            fn = steps.make_train_step(cfg)
            jitted = jax.jit(fn, in_shardings=(state_sh, batch_sh),
                             out_shardings=(state_sh, None))
            lowered = jitted.lower(state_sds, batch_sds)
        elif shape.kind == "prefill":
            fn = steps.make_prefill_step(cfg)
            jitted = jax.jit(fn, in_shardings=(state_sh["params"], batch_sh))
            lowered = jitted.lower(state_sds["params"], batch_sds)
        else:  # decode
            fn = steps.make_serve_step(cfg)
            jitted = jax.jit(
                fn,
                in_shardings=(state_sh["params"], batch_sh["caches"],
                              batch_sh["tokens_t"], batch_sh["pos"]),
                out_shardings=(None, batch_sh["caches"]))
            lowered = jitted.lower(state_sds["params"], batch_sds["caches"],
                                   batch_sds["tokens_t"], batch_sds["pos"])
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    hlo = compiled.as_text()
    stats = {
        "arch": arch, "shape": shape_name, "variant": variant,
        "mesh": dict(mesh.shape), "kind": shape.kind,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "cost": cost_stats(compiled),
        "memory": memory_stats(compiled),
        "collectives_flat": collective_bytes(hlo),
        "analyzed": analyze(hlo),       # trip-count-weighted (see hlo_analyzer)
        "n_devices": mesh.size,
        "_hlo_text": hlo,
    }
    print(f"  memory_analysis: {stats['memory']}")
    print(f"  cost_analysis(raw): {stats['cost']}")
    print(f"  analyzed(weighted): flops={stats['analyzed']['flops']:.3e} "
          f"bytes={stats['analyzed']['bytes']:.3e} "
          f"coll={stats['analyzed']['collectives'].get('total', 0):.3e}")
    return stats


def lower_gp_cell(name: str, mesh, variant: str = "mxu"):
    """Lower + compile the distributed GP bound+grad (the paper's step)."""
    from repro.core import gp_kernels as gpk
    from repro.core.distributed import DistributedGP

    gp = GP_CONFIGS[name]
    axes = gp_data_axes(mesh)
    psi2_fn = None            # "naive": paper-faithful per-point broadcast
    if variant == "mxu":      # beyond-paper MXU-matmul reformulation
        def psi2_fn(hyp, z, mu, s, w):
            return gpk.psi2_mxu(hyp, z, mu, s, w, chunk=512)
    elif variant == "sym":    # + exploit Psi2 symmetry (~2x less pair work)
        def psi2_fn(hyp, z, mu, s, w):
            return gpk.psi2_mxu_sym(hyp, z, mu, s, w, chunk=512, tile=64)

    t0 = time.time()
    eng = DistributedGP(mesh, data_axes=axes, latent=gp.latent,
                        psi2_fn=psi2_fn)
    n_pad = -(-gp.n // eng.n_shards) * eng.n_shards
    f32 = jnp.float32
    sds = jax.ShapeDtypeStruct
    hyp = {"log_sf2": sds((), f32), "log_ell": sds((gp.q,), f32),
           "log_beta": sds((), f32)}
    z = sds((gp.m, gp.q), f32)
    mu = sds((n_pad, gp.q), f32)
    s = sds((n_pad, gp.q), f32) if gp.latent else None
    y = sds((n_pad, gp.d), f32)
    w = sds((n_pad,), f32)
    fmask = sds((eng.n_shards,), f32)
    nf = sds((), f32)

    data_sh = NamedSharding(mesh, P(axes))
    rep = NamedSharding(mesh, P())
    argnums = (0, 1, 2, 3) if gp.latent else (0, 1)
    bound = eng.bound_fn(gp.d)

    def neg(hyp_, z_, mu_, s_, y_, w_, fm_, n_):
        return -bound(hyp_, z_, y_, mu_, s_, w_, fm_, n_)

    vg = jax.value_and_grad(neg, argnums=argnums)
    in_sh = (jax.tree.map(lambda _: rep, hyp), rep, data_sh,
             (data_sh if gp.latent else None), data_sh, data_sh, rep, rep)
    jitted = jax.jit(vg, in_shardings=in_sh)
    lowered = jitted.lower(hyp, z, mu, s, y, w, fmask, nf)
    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower

    hlo = compiled.as_text()
    stats = {
        "arch": f"gp:{name}", "shape": f"n{gp.n}_m{gp.m}", "variant": variant,
        "mesh": dict(mesh.shape), "kind": "gp_step",
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "cost": cost_stats(compiled),
        "memory": memory_stats(compiled),
        "collectives_flat": collective_bytes(hlo),
        "analyzed": analyze(hlo),
        "n_devices": mesh.size,
        "_hlo_text": hlo,
    }
    print(f"  memory_analysis: {stats['memory']}")
    print(f"  cost_analysis(raw): {stats['cost']}")
    print(f"  analyzed(weighted): flops={stats['analyzed']['flops']:.3e} "
          f"bytes={stats['analyzed']['bytes']:.3e} "
          f"coll={stats['analyzed']['collectives'].get('total', 0):.3e}")
    return stats


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="both")
    ap.add_argument("--archs", nargs="*", default=None)
    ap.add_argument("--shapes", nargs="*", default=None)
    ap.add_argument("--gp", action="store_true", help="GP cells only")
    ap.add_argument("--gp-names", nargs="*", default=None)
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--out", default=str(ART))
    args = ap.parse_args()

    out_root = pathlib.Path(args.out)
    meshes = {"single": [False], "multi": [True], "both": [False, True]}
    failures = []
    for multi in meshes[args.mesh]:
        mesh_name = "multi" if multi else "single"
        out_dir = out_root / mesh_name
        out_dir.mkdir(parents=True, exist_ok=True)
        mesh = make_production_mesh(multi_pod=multi)

        if args.gp:
            names = args.gp_names or list(GP_CONFIGS)
            for name in names:
                tag = f"gp_{name}__{args.variant}"
                print(f"[{mesh_name}] {tag}")
                try:
                    st = lower_gp_cell(name, mesh, args.variant)
                    _save(out_dir, tag, st)
                except Exception as e:  # noqa: BLE001
                    traceback.print_exc()
                    failures.append((mesh_name, tag, repr(e)))
            continue

        cfgs = all_configs()
        archs = args.archs or sorted(cfgs)
        for arch in archs:
            for shape_name in cells(cfgs[arch]):
                if args.shapes and shape_name not in args.shapes:
                    continue
                tag = f"{arch}__{shape_name}"
                if args.variant != "baseline":
                    tag += f"__{args.variant}"
                fp = out_dir / f"{tag}.json"
                if fp.exists():
                    print(f"[{mesh_name}] {tag} (cached)")
                    continue
                print(f"[{mesh_name}] {tag}")
                try:
                    st = lower_cell(arch, shape_name, mesh, args.variant)
                    _save(out_dir, tag, st)
                except Exception as e:  # noqa: BLE001
                    traceback.print_exc()
                    failures.append((mesh_name, tag, repr(e)))

    if failures:
        print("\nFAILURES:")
        for f in failures:
            print(" ", f)
        raise SystemExit(1)
    print("\nDRY-RUN COMPLETE")


if __name__ == "__main__":
    main()
