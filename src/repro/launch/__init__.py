"""Launch layer: production meshes, multi-pod dry-run, roofline analysis,
training driver, and report assembly. dryrun.py must stay import-light —
its first statement pins XLA_FLAGS before jax initialises."""
