"""Parse collective traffic and cost stats out of a compiled executable.

``cost_analysis()`` has FLOPs and bytes but NOT collective bytes; those are
regex-harvested from the optimised HLO (``compiled.as_text()`` — post-SPMD
partitioning, so the collectives are the real ones).
"""
from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.  %x = (f32[8,128]{1,0}, f32[4]{0}) all-reduce-start(...)
_OP_RE = re.compile(
    r"=\s*(\([^)]*\)|\S+?)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")
_SHAPE_RE = re.compile(r"(f64|f32|f16|bf16|s64|u64|s32|u32|s16|u16|s8|u8|"
                       r"pred|c64|c128|f8e4m3fn|f8e5m2|s4|u4)\[([0-9,]*)\]")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Sum result-shape bytes per collective kind (skipping -done ops so
    async pairs are counted once). Returns {kind: bytes, 'total': bytes,
    'count': n_ops}."""
    out: dict = defaultdict(int)
    count = 0
    for line in hlo_text.splitlines():
        if "-done" in line:
            continue
        m = _OP_RE.search(line)
        if not m:
            continue
        shape_txt, kind = m.group(1), m.group(2)
        b = _shape_bytes(shape_txt)
        out[kind] += b
        count += 1
    out["total"] = sum(v for k, v in out.items() if k in _COLLECTIVES)
    out["count"] = count
    return dict(out)


def normalize_cost_analysis(ca) -> dict:
    """``compiled.cost_analysis()`` returns a dict on newer JAX but a
    one-element list of dicts on older versions — normalize to a dict."""
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return dict(ca) if ca else {}


def cost_stats(compiled) -> dict:
    ca = normalize_cost_analysis(compiled.cost_analysis())
    return {
        "flops": float(ca.get("flops", 0.0)),
        "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
        "transcendentals": float(ca.get("transcendentals", 0.0)),
    }


def memory_stats(compiled) -> dict:
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return {}
    keys = ["argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "generated_code_size_in_bytes",
            "alias_size_in_bytes"]
    out = {}
    for k in keys:
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = int(v)
    if out:
        out["total_bytes"] = (out.get("argument_size_in_bytes", 0)
                              + out.get("output_size_in_bytes", 0)
                              + out.get("temp_size_in_bytes", 0)
                              - out.get("alias_size_in_bytes", 0))
    return out
