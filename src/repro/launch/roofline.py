"""Roofline analysis over the dry-run artifacts.

  PYTHONPATH=src python -m repro.launch.roofline [--mesh single] [--md]

Per (arch x shape) cell, derive the three roofline terms from the
trip-count-weighted HLO analysis (launch/hlo_analyzer — raw cost_analysis
counts loop bodies once and is reported for reference only):

  compute    = FLOPs_per_device / peak_FLOPs          [s]
  memory     = bytes_per_device / HBM_bw              [s]
  collective = collective_bytes_per_device / link_bw  [s]

Hardware: TPU v5e — 197 TF/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
The analyzed numbers come from the per-device SPMD program, so they are
already per-chip. MODEL_FLOPS = 6*N_active*tokens (train) or
2*N_active*tokens (fwd-only), and the MODEL/HLO ratio flags remat or
redundant-compute waste.
"""
from __future__ import annotations

import argparse
import json
import pathlib

PEAK_FLOPS = 197e12       # bf16 / chip
HBM_BW = 819e9            # B/s / chip
LINK_BW = 50e9            # B/s / link (brief's figure)

ART = pathlib.Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"


def _active_params(cfg) -> tuple[int, int]:
    """(total params, active-per-token params) from the config, analytic."""
    d = cfg.d_model
    v = cfg.vocab_size
    emb = v * d * (1 if cfg.tie_embeddings else 2)
    total = emb
    active = emb
    for g in cfg.blocks:
        per = 0
        per_active = 0
        if g.mixer in ("attn", "lattn"):
            dh = cfg.head_dim or d // cfg.num_heads
            a = d * cfg.num_heads * dh * 2 + d * cfg.num_kv_heads * dh * 2
            per += a
            per_active += a
        elif g.mixer == "mla":
            qk = cfg.qk_nope_head_dim + cfg.qk_rope_head_dim
            a = (d * cfg.q_lora_rank + cfg.q_lora_rank * cfg.num_heads * qk
                 + d * (cfg.kv_lora_rank + cfg.qk_rope_head_dim)
                 + cfg.kv_lora_rank * cfg.num_heads
                 * (cfg.qk_nope_head_dim + cfg.v_head_dim)
                 + cfg.num_heads * cfg.v_head_dim * d)
            per += a
            per_active += a
        elif g.mixer == "ssd":
            d_inner = cfg.ssm_expand * d
            n = cfg.ssm_state_dim
            a = d * (2 * d_inner + 2 * n + d_inner // cfg.ssm_head_dim) \
                + d_inner * d
            per += a
            per_active += a
        elif g.mixer == "rglru":
            lru = cfg.lru_width or d
            a = d * lru * 2 + lru * lru * 2 + lru * d
            per += a
            per_active += a
        if g.ffn == "mlp":
            mult = 3 if cfg.mlp_type == "swiglu" else 2
            a = mult * d * cfg.d_ff
            per += a
            per_active += a
        elif g.ffn == "moe":
            routed = 3 * d * cfg.moe_d_ff
            per += cfg.num_experts * routed + d * cfg.num_experts
            per_active += cfg.experts_per_token * routed
            if cfg.num_shared_experts:
                sh = 3 * d * (cfg.num_shared_experts * cfg.moe_d_ff)
                per += sh
                per_active += sh
        total += per * g.count
        active += per_active * g.count
    if cfg.family == "encdec":
        dh = cfg.head_dim or d // cfg.num_heads
        enc = cfg.encoder_layers * (
            d * cfg.num_heads * dh * 2 + d * cfg.num_kv_heads * dh * 2
            + 2 * d * cfg.d_ff)
        xattn = sum(g.count for g in cfg.blocks) * (
            d * cfg.num_heads * dh * 2 + d * cfg.num_kv_heads * dh * 2)
        total += enc + xattn
        active += enc + xattn
    return total, active


def model_flops(cfg, shape, n_dev: int) -> float:
    """Analytic useful FLOPs per device per step (attention included)."""
    _, act = _active_params(cfg)
    b, t = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        tokens = b * t
        f = 6.0 * act * tokens
        f += _attn_flops(cfg, b, t, t, train=True)
    elif shape.kind == "prefill":
        tokens = b * t
        f = 2.0 * act * tokens
        f += _attn_flops(cfg, b, t, t, train=False)
    else:  # decode: one token against a length-t cache
        f = 2.0 * act * b
        f += _attn_flops(cfg, b, 1, t, train=False)
    return f / n_dev


def _attn_flops(cfg, b, t_q, t_kv, train: bool) -> float:
    mult = 3.0 if train else 1.0       # fwd + ~2x bwd
    f = 0.0
    for g in cfg.blocks:
        if g.mixer in ("attn", "lattn", "mla"):
            if g.mixer == "mla":
                dh_qk = cfg.qk_nope_head_dim + cfg.qk_rope_head_dim
                dh_v = cfg.v_head_dim
            else:
                dh_qk = dh_v = cfg.head_dim or cfg.d_model // cfg.num_heads
            kv = t_kv
            if g.mixer == "lattn" and cfg.local_window:
                kv = min(cfg.local_window, t_kv)
            # causal halves the average context for full self-attention
            eff = kv / 2.0 if (t_q == t_kv and g.mixer != "lattn") else kv
            f += g.count * 2.0 * b * cfg.num_heads * t_q * eff \
                * (dh_qk + dh_v) * mult
    return f


def load_cells(mesh: str, variant: str | None = None) -> list[dict]:
    d = ART / mesh
    out = []
    for fp in sorted(d.glob("*.json")):
        cell = json.loads(fp.read_text())
        if variant is None or cell.get("variant") in (variant, None):
            out.append(cell)
    return out


def roofline_row(cell: dict) -> dict:
    an = cell["analyzed"]
    fl = an["flops"]
    by = an["bytes"]
    co = an["collectives"].get("total", 0.0)
    t_c = fl / PEAK_FLOPS
    t_m = by / HBM_BW
    t_l = co / LINK_BW
    dom = max(("compute", t_c), ("memory", t_m), ("collective", t_l),
              key=lambda kv: kv[1])
    row = {
        "arch": cell["arch"], "shape": cell["shape"],
        "variant": cell.get("variant", "baseline"),
        "compute_s": t_c, "memory_s": t_m, "collective_s": t_l,
        "dominant": dom[0], "bound_s": dom[1],
        "flops_dev": fl, "bytes_dev": by, "coll_dev": co,
        "raw_cost_flops": cell["cost"]["flops"],
        "mem_args_GB": cell["memory"].get("argument_size_in_bytes", 0) / 1e9,
        "mem_temp_GB": cell["memory"].get("temp_size_in_bytes", 0) / 1e9,
        "n_devices": cell["n_devices"],
    }
    # model flops + fraction
    if cell["arch"].startswith("gp:"):
        from repro.configs import GP_CONFIGS
        gp = GP_CONFIGS[cell["arch"][3:]]
        # paper's map-step cost O(n m^2 q) (+ psi1/grad): value+grad ~ 3x fwd
        mf = 3.0 * gp.n * gp.m * gp.m * (2.0 * gp.q + 4.0) / cell["n_devices"]
        row["model_flops_dev"] = mf
        row["model_over_hlo"] = mf / fl if fl else 0.0
        row["roofline_frac"] = (mf / PEAK_FLOPS) / dom[1] if dom[1] else 0.0
    else:
        from repro.configs import SHAPES, all_configs
        cfg = all_configs()[cell["arch"]]
        mf = model_flops(cfg, SHAPES[cell["shape"]], cell["n_devices"])
        row["model_flops_dev"] = mf
        row["model_over_hlo"] = mf / fl if fl else 0.0
        # roofline fraction: useful flops at peak vs the bound time
        row["roofline_frac"] = (mf / PEAK_FLOPS) / dom[1] if dom[1] else 0.0
    return row


def render_md(rows: list[dict]) -> str:
    hdr = ("| arch | shape | variant | compute s | memory s | coll s | "
           "dominant | model/HLO | roofline frac |\n"
           "|---|---|---|---|---|---|---|---|---|")
    lines = [hdr]
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['variant']} "
            f"| {r['compute_s']:.3e} | {r['memory_s']:.3e} "
            f"| {r['collective_s']:.3e} | **{r['dominant']}** "
            f"| {r.get('model_over_hlo', float('nan')):.2f} "
            f"| {r.get('roofline_frac', float('nan')):.3f} |")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--variant", default=None)
    ap.add_argument("--md", action="store_true")
    ap.add_argument("--json-out", default="")
    args = ap.parse_args()
    from repro.configs import load_all
    load_all()
    rows = [roofline_row(c) for c in load_cells(args.mesh, args.variant)]
    if args.json_out:
        pathlib.Path(args.json_out).write_text(json.dumps(rows, indent=1))
    if args.md:
        print(render_md(rows))
    else:
        for r in rows:
            print(f"{r['arch']:>22} {r['shape']:>12} {r['variant']:>9} "
                  f"C {r['compute_s']:.2e}  M {r['memory_s']:.2e}  "
                  f"L {r['collective_s']:.2e}  -> {r['dominant']:<10} "
                  f"frac {r.get('roofline_frac', float('nan')):.3f}")


if __name__ == "__main__":
    main()
