"""Assemble EXPERIMENTS.md's generated sections from dry-run artifacts.

  PYTHONPATH=src python -m repro.launch.report
"""
from __future__ import annotations

import json
import pathlib

from repro.configs import load_all
from repro.launch.roofline import (HBM_BW, LINK_BW, PEAK_FLOPS, load_cells,
                                   roofline_row)

ROOT = pathlib.Path(__file__).resolve().parents[3]


def fmt_s(x: float) -> str:
    if x == 0:
        return "0"
    if x >= 0.1:
        return f"{x:.2f}"
    if x >= 1e-3:
        return f"{x * 1e3:.2f}m"
    return f"{x * 1e6:.1f}u"


def roofline_table(mesh: str) -> str:
    rows = [roofline_row(c) for c in load_cells(mesh)]
    rows.sort(key=lambda r: (r["arch"].startswith("gp:"), r["arch"],
                             r["shape"], r["variant"]))
    out = ["| arch | shape | variant | compute [s] | memory [s] | "
           "collective [s] | dominant | MODEL/HLO flops | roofline frac | "
           "one-line next step |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        hint = _hint(r)
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['variant']} "
            f"| {fmt_s(r['compute_s'])} | {fmt_s(r['memory_s'])} "
            f"| {fmt_s(r['collective_s'])} | {r['dominant']} "
            f"| {r.get('model_over_hlo', 0):.2f} "
            f"| {r.get('roofline_frac', 0):.3f} | {hint} |")
    return "\n".join(out)


def _hint(r) -> str:
    if r["shape"].startswith("decode") or r["shape"].startswith("long"):
        return "bandwidth-bound by nature; int8 KV next"
    if r["dominant"] == "collective":
        return "overlap/quantise the dominant gather"
    if r["dominant"] == "memory":
        return "larger fusions / fp8 activations"
    return "near-roofline; tune block shapes"


def perf_compare() -> str:
    """Before/after table for the hillclimbed cells across artifact dirs."""
    dirs = {
        "v0 (pre-fix baseline)": ROOT / "artifacts" / "dryrun_v0" / "single",
        "current": ROOT / "artifacts" / "dryrun" / "single",
    }
    cells = [
        "qwen3-moe-235b-a22b__train_4k",
        "qwen3-moe-235b-a22b__train_4k__a2a_int8",
        "qwen3-moe-235b-a22b__train_4k__a2a_int8+cap10",
        "deepseek-v2-236b__train_4k",
        "deepseek-v2-236b__train_4k__a2a_int8",
        "deepseek-v2-236b__train_4k__noremat",
        "gp_gplvm-synth-100k__naive",
        "gp_gplvm-synth-100k__mxu",
        "gp_gplvm-synth-100k__sym",
        "gp_sgpr-synth-1m__naive",
        "gp_sgpr-synth-1m__mxu",
        "gp_sgpr-synth-1m__sym",
    ]
    out = ["| cell | artifacts | compute [s] | memory [s] | collective [s] "
           "| dominant |",
           "|---|---|---|---|---|---|"]
    for cell in cells:
        for tag, d in dirs.items():
            fp = d / f"{cell}.json"
            if not fp.exists():
                continue
            c = json.loads(fp.read_text())
            a = c["analyzed"]
            t_c = a["flops"] / PEAK_FLOPS
            t_m = a["bytes"] / HBM_BW
            t_l = a["collectives"].get("total", 0) / LINK_BW
            dom = max(("compute", t_c), ("memory", t_m),
                      ("collective", t_l), key=lambda kv: kv[1])[0]
            out.append(f"| {cell} | {tag} | {fmt_s(t_c)} | {fmt_s(t_m)} "
                       f"| {fmt_s(t_l)} | {dom} |")
    return "\n".join(out)


def multi_pod_summary() -> str:
    rows = [roofline_row(c) for c in load_cells("multi")]
    rows.sort(key=lambda r: (r["arch"], r["shape"]))
    out = ["| arch | shape | collective [s] (512 chips) | dominant | "
           "mem args [GB/chip] |",
           "|---|---|---|---|---|"]
    for r in rows:
        out.append(f"| {r['arch']} | {r['shape']} "
                   f"| {fmt_s(r['collective_s'])} | {r['dominant']} "
                   f"| {r['mem_args_GB']:.2f} |")
    return "\n".join(out)


def main():
    load_all()
    md = (ROOT / "EXPERIMENTS.md").read_text()
    begin, end = "<!-- ROOFLINE:BEGIN -->", "<!-- ROOFLINE:END -->"
    gen = (
        f"{begin}\n\n### Single-pod (16×16 = 256 chips), per-device terms\n\n"
        + roofline_table("single")
        + "\n\n### §Perf before/after (hillclimbed cells)\n\n"
        + perf_compare()
        + "\n\n### Multi-pod (2×16×16 = 512 chips) — pod axis shards\n\n"
        + multi_pod_summary()
        + f"\n\n(regenerate: `PYTHONPATH=src python -m repro.launch.report`)\n"
        + end)
    pre = md.split(begin)[0]
    post = md.split(end)[1]
    (ROOT / "EXPERIMENTS.md").write_text(pre + gen + post)
    print("EXPERIMENTS.md updated")


if __name__ == "__main__":
    main()
