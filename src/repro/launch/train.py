"""End-to-end LM training driver with checkpoint/restart.

  PYTHONPATH=src python -m repro.launch.train --arch qwen2-1.5b --reduced \
      --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/run1

Restart-safe: the data stream is (seed, step)-addressed, so resuming from
step k replays the exact token stream; checkpoints rotate atomically. On a
real fleet this binary runs per-process with jax.distributed.initialize();
on this container it runs the same code on the local device (and the
dry-run proves the production mesh shards).
"""
import argparse
import pathlib
import time

import jax

from repro.checkpoint import checkpoint as ckpt
from repro.configs import all_configs
from repro.data.tokens import TokenStream
from repro.optim.adam import AdamConfig
from repro.optim import compression as comp
from repro.train import steps


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = all_configs()[args.arch]
    if args.reduced:
        cfg = cfg.reduced()
    stream = TokenStream(cfg.vocab_size, args.seq, args.batch, seed=0)

    state, _ = steps.init_train_state(cfg, jax.random.PRNGKey(0))
    err = comp.init_error_state(state["params"]) if args.compress_grads else None
    start_step = 0

    ckdir = pathlib.Path(args.ckpt_dir) if args.ckpt_dir else None
    if ckdir and (last := ckpt.latest(ckdir)) is not None:
        state, meta = ckpt.restore(last, state)
        start_step = int(meta["step"])
        print(f"resumed from {last} at step {start_step}")

    adam_cfg = AdamConfig(lr=args.lr, warmup_steps=max(args.steps // 20, 5))

    if args.compress_grads:
        # carry error-feedback state inside the step (functional)
        def step_fn(carry, batch):
            st, e = carry

            def compress(grads):
                nonlocal_holder["out"] = None
                g2, e2 = comp.compress_with_feedback(grads, e)
                nonlocal_holder["err"] = e2
                return g2

            nonlocal_holder = {}
            ts = steps.make_train_step(cfg, adam_cfg, compression=compress)
            st2, m = ts(st, batch)
            return (st2, nonlocal_holder["err"]), m

        jit_step = jax.jit(step_fn)
        carry = (state, err)
    else:
        jit_step = jax.jit(steps.make_train_step(cfg, adam_cfg))
        carry = state

    saver = ckpt.AsyncCheckpointer()
    losses = []
    t0 = time.time()
    for it in range(start_step, args.steps):
        batch = stream.batch(it)
        if args.compress_grads:
            carry, metrics = jit_step(carry, batch)
            state = carry[0]
        else:
            carry, metrics = jit_step(carry, batch)
            state = carry
        losses.append(float(metrics["loss"]))
        if it % args.log_every == 0 or it == args.steps - 1:
            dt = time.time() - t0
            print(f"step {it:5d} loss {losses[-1]:.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"({dt / max(it - start_step + 1, 1):.2f}s/step)")
        if ckdir and (it + 1) % args.ckpt_every == 0:
            saver.save(ckdir / f"ckpt_step{it + 1}", state,
                       {"step": it + 1, "loss": losses[-1]})
    saver.wait()
    if ckdir:
        ckpt.save(ckdir / f"ckpt_step{args.steps}", state,
                  {"step": args.steps, "loss": losses[-1]})
    print(f"final loss {losses[-1]:.4f} (start {losses[0]:.4f})")
    return losses


if __name__ == "__main__":
    main()
