"""Production mesh definition (the brief's contract).

A FUNCTION, not a module-level constant — importing this module never
touches jax device state. Single pod: (data=16, model=16) = 256 chips;
multi-pod: (pod=2, data=16, model=16) = 512 chips.

``make_compat_mesh`` is the version-compat constructor every caller must
route through: ``jax.sharding.AxisType`` / the ``axis_types=`` kwarg only
exist on newer JAX, and very old JAX lacks ``jax.make_mesh`` entirely.
"""
from __future__ import annotations

import inspect
import math
from typing import Sequence

import jax


def make_compat_mesh(shape: Sequence[int], axis_names: Sequence[str]):
    """Build a device mesh across JAX versions.

    Prefers ``jax.make_mesh(..., axis_types=(AxisType.Auto, ...))`` (newer
    JAX), falls back to plain ``jax.make_mesh`` when ``AxisType`` or the
    kwarg is missing, and finally to a hand-rolled ``jax.sharding.Mesh``
    over ``jax.devices()`` when ``jax.make_mesh`` itself is absent.
    """
    shape = tuple(shape)
    axis_names = tuple(axis_names)
    axis_type = getattr(jax.sharding, "AxisType", None)
    if hasattr(jax, "make_mesh"):
        # Scope the fallback to the known drift (the kwarg's existence)
        # rather than a bare except TypeError, which would also swallow
        # genuine caller errors and re-raise something unrelated.
        if axis_type is not None and _accepts_axis_types(jax.make_mesh):
            return jax.make_mesh(
                shape, axis_names,
                axis_types=(axis_type.Auto,) * len(axis_names))
        return jax.make_mesh(shape, axis_names)
    import numpy as np  # pragma: no cover - ancient-JAX fallback

    n = math.prod(shape)
    devices = np.asarray(jax.devices()[:n]).reshape(shape)
    return jax.sharding.Mesh(devices, axis_names)


def _accepts_axis_types(make_mesh) -> bool:
    try:
        return "axis_types" in inspect.signature(make_mesh).parameters
    except (TypeError, ValueError):  # signature not introspectable
        return False


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_compat_mesh(shape, axes)


def make_gp_mesh(*, multi_pod: bool = False):
    """The GP map-reduce uses every chip as a data shard (the paper's 1-D
    decomposition); same device fleet, flat data axis factored per pod."""
    return make_production_mesh(multi_pod=multi_pod)


def gp_data_axes(mesh) -> tuple[str, ...]:
    """GP shards n over ALL mesh axes (512-way at multi-pod)."""
    return tuple(mesh.axis_names)
