"""Production mesh definition (the brief's contract).

A FUNCTION, not a module-level constant — importing this module never
touches jax device state. Single pod: (data=16, model=16) = 256 chips;
multi-pod: (pod=2, data=16, model=16) = 512 chips.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_gp_mesh(*, multi_pod: bool = False):
    """The GP map-reduce uses every chip as a data shard (the paper's 1-D
    decomposition); same device fleet, flat data axis factored per pod."""
    return make_production_mesh(multi_pod=multi_pod)


def gp_data_axes(mesh) -> tuple[str, ...]:
    """GP shards n over ALL mesh axes (512-way at multi-pod)."""
    return tuple(mesh.axis_names)
