"""Re-run the HLO analyzer over saved .hlo.gz dumps (no recompilation).

  PYTHONPATH=src python -m repro.launch.reanalyze [--mesh single]
"""
import argparse
import gzip
import json
import pathlib

from repro.launch.hlo_analyzer import analyze
from repro.launch.dryrun import ART


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="single")
    args = ap.parse_args()
    d = pathlib.Path(ART) / args.mesh
    for gz in sorted(d.glob("*.hlo.gz")):
        jp = d / (gz.name[: -len(".hlo.gz")] + ".json")
        if not jp.exists():
            continue
        stats = json.loads(jp.read_text())
        with gzip.open(gz, "rt") as f:
            stats["analyzed"] = analyze(f.read())
        jp.write_text(json.dumps(stats))
        a = stats["analyzed"]
        print(f"{gz.name[:-7]:>50}: flops={a['flops']:.3e} "
              f"bytes={a['bytes']:.3e} "
              f"coll={a['collectives'].get('total', 0):.3e}")


if __name__ == "__main__":
    main()
