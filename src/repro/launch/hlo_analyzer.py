"""Trip-count-aware HLO cost analyzer.

XLA's ``compiled.cost_analysis()`` counts every while-loop body ONCE,
regardless of trip count (verified in tests/test_hlo_analyzer.py). Since
this framework scans layer stacks, chunked attention, and the chunked CE
loss, raw cost_analysis can under-report FLOPs by 10-100x. This module
re-derives FLOPs / HBM bytes / collective bytes from the optimised HLO
text with loop weighting:

  weight(computation) = product of trip counts of enclosing while loops
  trip count          = the s32 constant compared against the induction
                        variable in the loop's condition computation
                        (lax.scan lowers to 0..K step 1)

FLOPs: dots (2 * result_elems * contraction), convolutions (2 * result *
kernel_footprint), elementwise (result_elems), reduce (input_elems),
cholesky/triangular-solve custom-calls (m^3/3, n m^2).
Bytes: operands + results of HBM-visible instructions (anything NOT inside
a fused computation), weighted.
Collectives: result bytes per op kind, weighted — catching per-layer
all_to_alls inside scans that a flat regex misses.
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(
    r"(f64|f32|f16|bf16|s64|u64|s32|u32|s16|u16|s8|u8|pred|c64|c128|"
    r"f8e4m3fn|f8e5m2|s4|u4)\[([0-9,]*)\]")

_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "abs",
    "negate", "select", "compare", "and", "or", "xor", "clamp", "sign",
    "floor", "ceil", "round-nearest-afz", "remainder",
}
_TRANSCENDENTAL = {"exponential", "log", "log-plus-one", "tanh", "rsqrt",
                   "sqrt", "power", "logistic", "sine", "cosine", "atan2",
                   "cbrt", "erf", "exponential-minus-one"}
_COLLECTIVES = {"all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute"}


def _shape_elems_bytes(txt: str) -> tuple[int, int]:
    elems = 0
    nbytes = 0
    for dt, dims in _SHAPE_RE.findall(txt):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        elems += n
        nbytes += n * _DTYPE_BYTES[dt]
    return elems, nbytes


@dataclass
class Instr:
    name: str
    shape_txt: str
    opcode: str
    operands: list[str]
    attrs: str
    line: str


@dataclass
class Computation:
    name: str
    params: dict = field(default_factory=dict)   # name -> shape text
    instrs: list = field(default_factory=list)
    is_entry: bool = False


_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\((.*)\)\s*->")
_NAME_EQ = re.compile(r"^(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*")
_OPCODE = re.compile(r"\s*([\w\-]+)\(")
_OPERAND = re.compile(r"%([\w\.\-]+)")


def _balanced(s: str, start: int) -> int:
    """Index one past the paren group opening at s[start] == '('."""
    depth = 0
    for i in range(start, len(s)):
        if s[i] == "(":
            depth += 1
        elif s[i] == ")":
            depth -= 1
            if depth == 0:
                return i + 1
    return len(s)


def _parse_instr(line: str) -> Instr | None:
    m = _NAME_EQ.match(line)
    if not m:
        return None
    name = m.group(1)
    rest = line[m.end():]
    # shape: either a (possibly commented) tuple or a single token
    if rest.startswith("("):
        end = _balanced(rest, 0)
        shape_txt = rest[:end]
        rest = rest[end:]
    else:
        sp = rest.find(" ")
        if sp < 0:
            return None
        shape_txt = rest[:sp]
        rest = rest[sp:]
    om = _OPCODE.match(rest)
    if not om:
        return None
    opcode = om.group(1)
    op_start = om.end() - 1
    op_end = _balanced(rest, op_start)
    operand_txt = rest[op_start + 1:op_end - 1]
    attrs = rest[op_end:]
    ops = _OPERAND.findall(operand_txt)
    return Instr(name, shape_txt, opcode, ops, attrs, line)


def parse_hlo(text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    for raw in text.splitlines():
        line = raw.strip()
        if not line or line.startswith("//") or line.startswith("HloModule"):
            continue
        if line == "}" or line == "})":
            cur = None
            continue
        if cur is None:
            m = _COMP_HDR.match(line)
            if m and line.rstrip().endswith("{"):
                cur = Computation(name=m.group(2), is_entry=bool(m.group(1)))
                # parse params: "a: f32[2,3], b: (f32[1], s32[])"
                sig = m.group(3)
                for pm in re.finditer(r"([\w\.\-]+):\s*((?:\([^)]*\))|"
                                      r"[\w\[\]{},]+)", sig):
                    cur.params[pm.group(1)] = pm.group(2)
                comps[cur.name] = cur
            continue
        ins = _parse_instr(line)
        if ins is not None:
            cur.instrs.append(ins)
    return comps


def _trip_count(cond: Computation) -> int | None:
    """lax.scan condition: induction var `compare` LT a constant."""
    const_vals = {}
    for ins in cond.instrs:
        cm = re.search(r"constant\((\d+)\)", ins.line)
        if cm and ins.shape_txt.strip().startswith(("s32", "u32", "s64")):
            const_vals[ins.name] = int(cm.group(1))
    for ins in cond.instrs:
        if "direction=LT" in ins.attrs or "direction=LT" in ins.line:
            for op in ins.operands:
                if op in const_vals:
                    return const_vals[op]
    # fallback: single integer constant in the condition
    if len(const_vals) == 1:
        return next(iter(const_vals.values()))
    return None


def _symbol_shapes(comp: Computation) -> dict[str, str]:
    table = dict(comp.params)
    for ins in comp.instrs:
        table[ins.name] = ins.shape_txt
    return table


def _dot_flops(ins: Instr, table: dict[str, str]) -> float:
    res_elems, _ = _shape_elems_bytes(ins.shape_txt)
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ins.attrs)
    if not m or not ins.operands:
        return 2.0 * res_elems
    lhs_shape = table.get(ins.operands[0], "")
    sm = _SHAPE_RE.search(lhs_shape)
    if not sm:
        return 2.0 * res_elems
    dims = [int(d) for d in sm.group(2).split(",")] if sm.group(2) else []
    contract = 1
    for di in m.group(1).split(","):
        if di != "" and int(di) < len(dims):
            contract *= dims[int(di)]
    return 2.0 * res_elems * contract


def _conv_flops(ins: Instr, table: dict[str, str]) -> float:
    res_elems, _ = _shape_elems_bytes(ins.shape_txt)
    if len(ins.operands) > 1:
        k_elems, _ = _shape_elems_bytes(table.get(ins.operands[1], ""))
        fg = re.search(r"feature_group_count=(\d+)", ins.attrs)
        g = int(fg.group(1)) if fg else 1
        out_feat = 1  # approximation: per-output-element cost
        return 2.0 * res_elems * max(k_elems // max(g, 1), 1) / max(out_feat, 1)
    return 2.0 * res_elems


def analyze(text: str) -> dict:
    comps = parse_hlo(text)
    entry = next((c for c in comps.values() if c.is_entry), None)
    if entry is None:
        return {"error": "no entry computation"}

    # classify computations referenced by fusions (not HBM-visible)
    fused_names = set()
    for c in comps.values():
        for ins in c.instrs:
            if ins.opcode == "fusion":
                cm = re.search(r"calls=%?([\w\.\-]+)", ins.attrs)
                if cm:
                    fused_names.add(cm.group(1))

    totals = defaultdict(float)
    coll = defaultdict(float)
    unresolved = [0]
    visited_stack = set()

    # ops that move no data (metadata/aliasing only)
    skip_bytes = {"tuple", "get-tuple-element", "bitcast", "parameter",
                  "constant", "after-all", "partition-id", "replica-id",
                  "domain", "opt-barrier", "while", "conditional", "call"}
    # ops whose HBM traffic is ~2x their RESULT (read slice + write result),
    # not their full operand (e.g. dynamic-slice of stacked scan weights)
    result_only = {"broadcast", "iota", "slice", "dynamic-slice", "reshape",
                   "gather"}
    slicing = {"dynamic-slice", "slice", "gather"}

    def _fusion_bytes(ins, table) -> float:
        """Fusion traffic: result + per-operand reads, where an operand
        whose only internal uses are slicing ops counts the sliced bytes."""
        _, b = _shape_elems_bytes(ins.shape_txt)
        cm = re.search(r"calls=%?([\w\.\-]+)", ins.attrs)
        called = comps.get(cm.group(1)) if cm else None
        pnames = list(called.params) if called else []
        for i, o in enumerate(ins.operands):
            _, ob = _shape_elems_bytes(table.get(o, ""))
            if called and i < len(pnames):
                uses = [u for u in called.instrs
                        if pnames[i] in u.operands]
                if uses and all(u.opcode in slicing for u in uses):
                    ob = sum(_shape_elems_bytes(u.shape_txt)[1]
                             for u in uses)
            b += ob
        return b

    def visit(comp: Computation, weight: float, in_fusion: bool):
        if comp.name in visited_stack:     # cycle guard
            return
        visited_stack.add(comp.name)
        table = _symbol_shapes(comp)
        for ins in comp.instrs:
            op = ins.opcode
            res_elems, res_bytes = _shape_elems_bytes(ins.shape_txt)
            # ---- flops ----
            if op == "dot":
                totals["flops"] += weight * _dot_flops(ins, table)
            elif op == "convolution":
                totals["flops"] += weight * _conv_flops(ins, table)
            elif op in _TRANSCENDENTAL:
                totals["flops"] += weight * res_elems
                totals["transcendentals"] += weight * res_elems
            elif op in _ELEMENTWISE:
                totals["flops"] += weight * res_elems
            elif op == "reduce" or op == "reduce-window":
                in_elems = 0
                for o in ins.operands[:1]:
                    e, _ = _shape_elems_bytes(table.get(o, ""))
                    in_elems += e
                totals["flops"] += weight * max(in_elems, res_elems)
            elif op == "custom-call":
                if "Cholesky" in ins.line or "potrf" in ins.line:
                    e, _ = _shape_elems_bytes(ins.shape_txt)
                    m = int(e ** 0.5)
                    totals["flops"] += weight * (m ** 3) / 3.0
                elif "TriangularSolve" in ins.line or "trsm" in ins.line:
                    totals["flops"] += weight * res_elems * (res_elems ** 0.5)
            # ---- bytes (HBM-visible only) ----
            if not in_fusion and op not in skip_bytes:
                if op == "fusion":
                    b = _fusion_bytes(ins, table)
                elif op == "dynamic-update-slice":
                    # read + write of the update slice only (aliased buffer)
                    _, ub = _shape_elems_bytes(
                        table.get(ins.operands[1], "")
                        if len(ins.operands) > 1 else "")
                    b = 2 * ub
                elif op in result_only:
                    b = 2 * res_bytes
                else:
                    b = res_bytes
                    for o in ins.operands:
                        _, ob = _shape_elems_bytes(table.get(o, ""))
                        b += ob
                totals["bytes"] += weight * b
            # ---- collectives ----
            base = op[:-6] if op.endswith("-start") else op
            if base in _COLLECTIVES and not op.endswith("-done"):
                coll[base] += weight * res_bytes
            # ---- recurse ----
            if op == "fusion":
                cm = re.search(r"calls=%?([\w\.\-]+)", ins.attrs)
                if cm and cm.group(1) in comps:
                    visit(comps[cm.group(1)], weight, True)
            elif op == "while":
                bm = re.search(r"body=%?([\w\.\-]+)", ins.attrs)
                cm = re.search(r"condition=%?([\w\.\-]+)", ins.attrs)
                trips = None
                if cm and cm.group(1) in comps:
                    trips = _trip_count(comps[cm.group(1)])
                if trips is None:
                    trips = 1
                    unresolved[0] += 1
                if bm and bm.group(1) in comps:
                    visit(comps[bm.group(1)], weight * trips, in_fusion)
            elif op in ("call", "conditional", "async-start"):
                for cm in re.finditer(
                        r"(?:to_apply|branch_computations=\{|called_computations=\{|calls)"
                        r"=?%?([\w\.\-]+)", ins.attrs):
                    if cm.group(1) in comps:
                        visit(comps[cm.group(1)], weight, in_fusion)
        visited_stack.discard(comp.name)

    visit(entry, 1.0, False)
    coll["total"] = sum(v for k, v in coll.items() if k in _COLLECTIVES)
    return {
        "flops": totals["flops"],
        "bytes": totals["bytes"],
        "transcendentals": totals["transcendentals"],
        "collectives": dict(coll),
        "unresolved_loops": unresolved[0],
    }
