"""repro — distributed variational sparse-GP/GPLVM inference (NIPS 2014)
plus the multi-arch LM substrate and TPU launch/roofline tooling.

GP inference follows the paper in float64 (collapsed-bound Cholesky math is
ill-conditioned in f32); x64 is enabled globally and the LM substrate passes
explicit f32/bf16 dtypes everywhere.
"""
import jax

jax.config.update("jax_enable_x64", True)

__version__ = "1.0.0"
