"""Synthetic datasets reproducing the paper's experimental setups.

* ``sines_dataset`` — the paper §4.2/fig 1 data: a 1D latent space mapped to
  3D observations "through linear functions with sines superimposed". Used
  for the 100k scaling runs and the latent-recovery check.
* ``oilflow_like`` — a 12-D, 3-class multiphase-flow stand-in with the same
  shape/statistics role as the oil-flow set of Titsias & Lawrence (fig 4):
  3 well-separated low-dimensional regimes embedded nonlinearly in 12-D.
  (The original data file is not redistributable; benchmarks treat this as
  a drop-in with identical dimensions n=1000, d=12, 3 classes.)
* ``usps_like`` — 16x16 synthetic digit-ish images (d=256) for the §4.5
  reconstruction experiment when the real USPS file is unavailable.
"""
from __future__ import annotations

import numpy as np


def sines_dataset(rng: np.random.Generator, n: int = 100_000,
                  noise: float = 0.05):
    """1D latent -> 3D: linear + superimposed sines (paper fig 1). Returns
    (Y (n,3), latent (n,1))."""
    t = rng.uniform(-3.0, 3.0, size=(n, 1))
    w = np.array([[0.8, -0.6, 0.4]])
    a = np.array([[1.2, 0.9, 1.5]])
    ph = np.array([[0.0, 1.1, 2.3]])
    y = t @ w + np.sin(1.7 * t @ a + ph)
    y = y + noise * rng.standard_normal(y.shape)
    return y, t


def oilflow_like(rng: np.random.Generator, n: int = 1000):
    """12-D, 3-class nonlinear embedding of a 2-D latent. Returns (Y, labels)."""
    labels = rng.integers(0, 3, size=n)
    centres = np.array([[-2.0, 0.0], [2.0, 0.5], [0.0, 2.2]])
    lat = centres[labels] + 0.35 * rng.standard_normal((n, 2))
    w1 = rng.standard_normal((2, 12)) * 0.9
    w2 = rng.standard_normal((2, 12)) * 0.7
    y = np.tanh(lat @ w1) + np.sin(lat @ w2) + 0.05 * rng.standard_normal((n, 12))
    return y, labels


def usps_like(rng: np.random.Generator, n: int = 4649, side: int = 16):
    """Synthetic 'digit' images: smooth strokes per class on a 16x16 grid.
    Returns (Y in [0,1]^(n,256), labels 0..9)."""
    labels = rng.integers(0, 10, size=n)
    yy, xx = np.mgrid[0:side, 0:side].astype(np.float64) / (side - 1)
    imgs = np.zeros((n, side, side))
    for i, c in enumerate(labels):
        # class-dependent stroke: parametric curve + per-sample jitter
        t = np.linspace(0, 1, 40)
        a = 0.6 + 0.04 * c + 0.02 * rng.standard_normal()
        b = 0.2 + 0.07 * c + 0.02 * rng.standard_normal()
        cx = 0.5 + 0.35 * np.cos(2 * np.pi * (a * t + 0.1 * c))
        cy = 0.5 + 0.35 * np.sin(2 * np.pi * (b * t + 0.05 * c))
        img = np.zeros((side, side))
        for px, py in zip(cx, cy):
            img += np.exp(-(((xx - px) ** 2 + (yy - py) ** 2) / 0.006))
        imgs[i] = img / img.max()
    return imgs.reshape(n, -1), labels


def drop_pixels(rng: np.random.Generator, y: np.ndarray, frac: float = 0.34):
    """Paper §4.5: drop a fraction of pixels; returns (y_masked, observed_mask).
    The same pixel mask is applied to every image (a fixed missing-sensor
    pattern), matching the reconstruction protocol."""
    d = y.shape[1]
    observed = np.ones(d, dtype=bool)
    observed[rng.choice(d, size=int(frac * d), replace=False)] = False
    return y * observed[None, :], observed
