"""Synthetic datasets reproducing the paper's experimental setups.

* ``sines_dataset`` — the paper §4.2/fig 1 data: a 1D latent space mapped to
  3D observations "through linear functions with sines superimposed". Used
  for the 100k scaling runs and the latent-recovery check.
* ``oilflow_like`` — a 12-D, 3-class multiphase-flow stand-in with the same
  shape/statistics role as the oil-flow set of Titsias & Lawrence (fig 4):
  3 well-separated low-dimensional regimes embedded nonlinearly in 12-D.
  (The original data file is not redistributable; benchmarks treat this as
  a drop-in with identical dimensions n=1000, d=12, 3 classes.)
* ``usps_like`` — 16x16 synthetic digit-ish images (d=256) for the §4.5
  reconstruction experiment when the real USPS file is unavailable.
"""
from __future__ import annotations

import numpy as np


def sines_dataset(rng: np.random.Generator, n: int = 100_000,
                  noise: float = 0.05):
    """1D latent -> 3D: linear + superimposed sines (paper fig 1). Returns
    (Y (n,3), latent (n,1))."""
    t = rng.uniform(-3.0, 3.0, size=(n, 1))
    w = np.array([[0.8, -0.6, 0.4]])
    a = np.array([[1.2, 0.9, 1.5]])
    ph = np.array([[0.0, 1.1, 2.3]])
    y = t @ w + np.sin(1.7 * t @ a + ph)
    y = y + noise * rng.standard_normal(y.shape)
    return y, t


def oilflow_like(rng: np.random.Generator, n: int = 1000):
    """12-D, 3-class nonlinear embedding of a 2-D latent. Returns (Y, labels)."""
    labels = rng.integers(0, 3, size=n)
    centres = np.array([[-2.0, 0.0], [2.0, 0.5], [0.0, 2.2]])
    lat = centres[labels] + 0.35 * rng.standard_normal((n, 2))
    w1 = rng.standard_normal((2, 12)) * 0.9
    w2 = rng.standard_normal((2, 12)) * 0.7
    y = np.tanh(lat @ w1) + np.sin(lat @ w2) + 0.05 * rng.standard_normal((n, 12))
    return y, labels


def usps_like(rng: np.random.Generator, n: int = 4649, side: int = 16):
    """Synthetic 'digit' images: smooth strokes per class on a 16x16 grid.
    Returns (Y in [0,1]^(n,256), labels 0..9)."""
    labels = rng.integers(0, 10, size=n)
    yy, xx = np.mgrid[0:side, 0:side].astype(np.float64) / (side - 1)
    imgs = np.zeros((n, side, side))
    for i, c in enumerate(labels):
        # class-dependent stroke: parametric curve + per-sample jitter
        t = np.linspace(0, 1, 40)
        a = 0.6 + 0.04 * c + 0.02 * rng.standard_normal()
        b = 0.2 + 0.07 * c + 0.02 * rng.standard_normal()
        cx = 0.5 + 0.35 * np.cos(2 * np.pi * (a * t + 0.1 * c))
        cy = 0.5 + 0.35 * np.sin(2 * np.pi * (b * t + 0.05 * c))
        img = np.zeros((side, side))
        for px, py in zip(cx, cy):
            img += np.exp(-(((xx - px) ** 2 + (yy - py) ** 2) / 0.006))
        imgs[i] = img / img.max()
    return imgs.reshape(n, -1), labels


def flight_like(n: int = 2_000_000, noise: float = 0.2, seed: int = 0):
    """Flight-delay-style regression at paper §5 scale, *chunk-addressable*.

    The paper's flagship run is GP regression on 2M flight records with 8
    covariates (month, day-of-month, day-of-week, departure/arrival time,
    airtime, distance, plane age) predicting delay.  This generator mimics
    that shape — q = 8 covariates with flight-like ranges, a nonlinear
    smooth delay surface plus heteroscedastic-ish noise — **without ever
    materialising the dataset**: it returns a ``data.stream``-protocol
    source whose ``read(start, stop)`` computes rows on demand,
    deterministically per row index (counter-based ``Philox`` streams
    seeded by ``seed``), so a 2M-row (or 2B-row) "file" costs O(window)
    host memory.  Fields: ``mu`` (n, 8) covariates, ``y`` (n, 1) delays.
    """
    from .stream import SyntheticSource

    def make_chunk(start: int, stop: int) -> dict:
        k = stop - start
        # Counter-based bit generator: jump to absolute row `start` so any
        # window is reproducible independently of read order (the stream
        # protocol's purity requirement).  Exactly 16 uniform draws per row
        # (8 covariates, 2 for Box-Muller noise, 6 spare) keeps the per-row
        # stride equal to the advance stride, so overlapping windows see
        # identical rows.  (standard_normal would break this: the ziggurat
        # consumes a data-dependent number of draws.)  Philox.advance counts
        # 128-bit counter blocks = 4 uint64 draws each, so 16 draws/row is
        # 4 blocks/row.
        bg = np.random.Philox(key=seed)
        bg = bg.advance(start * 4)
        r = np.random.Generator(bg)
        u = r.random((k, 16))
        eps = np.sqrt(-2.0 * np.log1p(-u[:, 8])) * np.cos(2 * np.pi * u[:, 9])
        x = np.empty((k, 8))
        x[:, 0] = 1 + np.floor(12 * u[:, 0])        # month
        x[:, 1] = 1 + np.floor(31 * u[:, 1])        # day of month
        x[:, 2] = 1 + np.floor(7 * u[:, 2])         # day of week
        x[:, 3] = 24.0 * u[:, 3]                    # departure hour
        x[:, 4] = 24.0 * u[:, 4]                    # arrival hour
        x[:, 5] = 30 + 570 * u[:, 5]                # airtime (min)
        x[:, 6] = 100 + 4800 * u[:, 6]              # distance (mi)
        x[:, 7] = 50 * u[:, 7]                      # plane age (yr)
        # Smooth nonlinear delay surface on standardised covariates.
        s = (x - _FLIGHT_MEAN) / _FLIGHT_STD
        f = (np.sin(1.3 * s[:, 3]) + 0.7 * np.cos(0.9 * s[:, 4])
             + 0.5 * s[:, 5] * np.exp(-0.5 * s[:, 6] ** 2)
             + 0.3 * np.tanh(s[:, 0] + 0.5 * s[:, 2]) - 0.2 * s[:, 7])
        y = f + noise * (1.0 + 0.3 * np.abs(s[:, 5])) * eps
        return {"mu": s, "y": y[:, None]}

    return SyntheticSource(n, make_chunk,
                           fields={"mu": (8,), "y": (1,)})


# Population moments of the flight_like covariate columns (uniform/discrete
# ranges above) — fixed constants so standardisation is row-independent.
_FLIGHT_MEAN = np.array([6.5, 16.0, 4.0, 12.0, 12.0, 315.0, 2500.0, 25.0])
_FLIGHT_STD = np.array([3.45, 8.94, 2.0, 6.93, 6.93, 164.5, 1385.6, 14.4])


def drop_pixels(rng: np.random.Generator, y: np.ndarray, frac: float = 0.34):
    """Paper §4.5: drop a fraction of pixels; returns (y_masked, observed_mask).
    The same pixel mask is applied to every image (a fixed missing-sensor
    pattern), matching the reconstruction protocol."""
    d = y.shape[1]
    observed = np.ones(d, dtype=bool)
    observed[rng.choice(d, size=int(frac * d), replace=False)] = False
    return y * observed[None, :], observed
