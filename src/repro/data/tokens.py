"""Sharded synthetic token pipeline for the LM substrate.

Deterministic, seekable, and restart-safe: a (seed, step) pair fully
determines a batch, so checkpoint resume replays the exact stream without
storing data state beyond the step counter. Sequences follow a Zipfian
unigram mixed with a repeating-ngram process so the loss has learnable
structure (models must beat the unigram entropy).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


def zipf_logits(vocab_size: int, alpha: float = 1.2) -> np.ndarray:
    ranks = np.arange(1, vocab_size + 1, dtype=np.float64)
    p = 1.0 / ranks**alpha
    return np.log(p / p.sum())


class TokenStream:
    """Stateless-per-step synthetic LM data. ``batch(step)`` -> tokens/labels."""

    def __init__(self, vocab_size: int, seq_len: int, global_batch: int,
                 seed: int = 0, alpha: float = 1.2):
        self.vocab_size = vocab_size
        self.seq_len = seq_len
        self.global_batch = global_batch
        self.seed = seed
        self._logits = jnp.asarray(zipf_logits(vocab_size, alpha), jnp.float32)

    def batch(self, step: int) -> dict[str, Array]:
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed), step)
        k1, k2, k3 = jax.random.split(key, 3)
        b, t = self.global_batch, self.seq_len
        base = jax.random.categorical(k1, self._logits, shape=(b, t + 1))
        # inject copy-structure: with p=0.5 per row, second half repeats first
        half = (t + 1) // 2
        rep = jnp.concatenate([base[:, :half], base[:, :t + 1 - half]], axis=1)
        use_rep = jax.random.bernoulli(k2, 0.5, (b, 1))
        seq = jnp.where(use_rep, rep, base)
        return {
            "tokens": seq[:, :-1].astype(jnp.int32),
            "labels": seq[:, 1:].astype(jnp.int32),
        }

    def host_batch(self, step: int) -> dict[str, np.ndarray]:
        return {k: np.asarray(v) for k, v in self.batch(step).items()}
