"""Data substrate: synthetic generators matching the paper's experiments and
a sharded token pipeline for the LM architectures."""
from . import synthetic, tokens

__all__ = ["synthetic", "tokens"]
