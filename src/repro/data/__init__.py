"""Data substrate: synthetic generators matching the paper's experiments, a
sharded token pipeline for the LM architectures, and the host-streaming
block-ingestion layer (``data.stream``: memmap/synthetic sources, shard-major
fixed-shape chunking, double-buffered H2D prefetch)."""
from . import stream, synthetic, tokens

__all__ = ["stream", "synthetic", "tokens"]
