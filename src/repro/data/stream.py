"""Host-streaming ingestion: block sources, shard-major chunking, prefetch.

The engines' in-memory path (``DistributedGP.put_data`` staging the whole
padded dataset, ``PredictEngine`` staging the whole padded query batch)
caps the reproduction at device/host RAM.  This module removes that cap
for both directions of the pipeline:

  * **Block sources** — a minimal random-access protocol (``n``, ``fields``,
    ``read(start, stop)``) over host data that never has to be resident at
    once: in-memory arrays (:class:`ArraySource`, the parity reference),
    memory-mapped ``.npy``/uncompressed ``.npz`` files
    (:class:`MemmapSource` — the npz members are mmapped in place through
    their zip offsets, no extraction), and deterministic chunk-addressable
    generators (:class:`SyntheticSource` — data that is *computed*, so host
    RSS is O(chunk) at any n, the >RAM benchmark regime).
  * **Shard-major chunking** (:class:`BlockStream`) — fixed-shape padded
    ``(block, weights)`` chunks laid out so that chunk ``c`` carries scan
    blocks ``[c·bpc, (c+1)·bpc)`` of EVERY shard's contiguous row range.
    Each shard therefore sees exactly the rows, in exactly the block
    partition and order, that ``pad_and_shard`` + the in-device
    ``lax.scan`` would give it — which is what makes streamed ingestion
    *bitwise* equal to the in-memory path (tests/test_stream_ingest.py),
    not merely close.
  * **Double-buffered prefetch** (:func:`prefetch`) — a bounded
    background-thread map that stages chunk ``i+1`` (host assembly +
    ``jax.device_put`` onto the mesh sharding) while the caller computes
    on chunk ``i``.  Jitted XLA programs release the GIL while executing,
    so host-side read/assembly genuinely overlaps device compute.

Training threads this through ``DistributedGP.put_data(stream=...)`` /
``streamed_stats`` / ``streamed_value_and_grad`` (host-fed outer loop over
``stats.partial_stats_chunked(init=...)``, shard memory O(block) in n) and
serving through ``PredictEngine.predict_stream`` / ``sample_stream``
(per-chunk results, the padded query set never materialises).  See
docs/training.md ("Streaming from disk") and docs/serving.md.
"""
from __future__ import annotations

import pathlib
import queue
import threading
import zipfile
from typing import Callable, Iterable, Iterator

import numpy as np

__all__ = [
    "ArraySource", "MemmapSource", "SyntheticSource", "as_source",
    "BlockStream", "prefetch", "stage_to_device", "padded_rows",
    "open_npz_memmaps",
]


# -- block sources -----------------------------------------------------------
#
# A source is anything with:
#   n: int                              total real rows
#   fields: dict[str, tuple]            field name -> trailing shape
#   read(start, stop) -> dict[str, np.ndarray]   rows [start, stop), 0<=start
#                                       <=stop<=n, each (stop-start,)+trailing
#
# ``read`` must be cheap for any window (random access): the SVI chunk
# sampler and the two-pass streamed gradient both re-read arbitrary chunks.


class ArraySource:
    """In-memory dict-of-arrays source — the parity/testing reference, and
    what ``as_source`` wraps a plain dict into."""

    def __init__(self, arrs: dict):
        if not arrs:
            raise ValueError("ArraySource needs at least one field")
        self._arrs = {k: np.asarray(v) for k, v in arrs.items()}
        ns = {a.shape[0] for a in self._arrs.values()}
        if len(ns) != 1:
            raise ValueError(f"fields disagree on leading dim: {ns}")
        self.n = ns.pop()
        self.fields = {k: a.shape[1:] for k, a in self._arrs.items()}

    def read(self, start: int, stop: int) -> dict:
        return {k: a[start:stop] for k, a in self._arrs.items()}


def open_npz_memmaps(path) -> dict:
    """Memory-map every member of an *uncompressed* ``.npz`` in place.

    ``np.savez`` stores members ZIP_STORED (no deflate), so each embedded
    ``.npy`` is a contiguous byte range of the archive: seek past the zip
    local header, parse the npy header, and ``np.memmap`` the payload at
    its absolute offset.  Compressed members (``np.savez_compressed``)
    cannot be mapped — they fall back to a full in-memory load, which
    keeps small files working but forfeits the O(chunk) residency.
    """
    path = pathlib.Path(path)
    out = {}
    with zipfile.ZipFile(path) as zf:
        infos = {i.filename: i for i in zf.infolist()}
        for name, info in infos.items():
            key = name[:-4] if name.endswith(".npy") else name
            if info.compress_type != zipfile.ZIP_STORED:
                out[key] = np.load(path)[key]     # compressed: load fallback
                continue
            with open(path, "rb") as f:
                # Local file header: 30 fixed bytes + name + extra field
                # (the extra field can differ from the central directory's,
                # so it must be read from the local header itself).
                f.seek(info.header_offset + 26)
                name_len = int.from_bytes(f.read(2), "little")
                extra_len = int.from_bytes(f.read(2), "little")
                data_off = info.header_offset + 30 + name_len + extra_len
                f.seek(data_off)
                version = np.lib.format.read_magic(f)
                shape, fortran, dtype = np.lib.format._read_array_header(
                    f, version)
                payload_off = f.tell()
            out[key] = np.memmap(path, dtype=dtype, mode="r", shape=shape,
                                 offset=payload_off,
                                 order="F" if fortran else "C")
    return out


class MemmapSource:
    """Memory-mapped file-backed source: rows live in the page cache, not
    the process heap — reading a window touches O(window) bytes.

    Construct from per-field ``.npy`` paths (``MemmapSource({"y": "y.npy",
    "mu": "x.npy"})``) or a single ``.npz`` via :meth:`from_npz`.
    """

    def __init__(self, paths_or_arrays: dict):
        arrs = {}
        for k, v in paths_or_arrays.items():
            if isinstance(v, (str, pathlib.Path)):
                arrs[k] = np.load(v, mmap_mode="r")
            else:
                arrs[k] = v                     # already array-like / memmap
        self._src = ArraySource(arrs)
        self.n = self._src.n
        self.fields = self._src.fields

    @classmethod
    def from_npz(cls, path) -> "MemmapSource":
        return cls(open_npz_memmaps(path))

    def read(self, start: int, stop: int) -> dict:
        # np.asarray materialises just the window (memmap slices are lazy).
        return {k: np.asarray(v) for k, v in self._src.read(start, stop).items()}


class SyntheticSource:
    """Chunk-addressable generator source: rows are *computed* on demand by
    ``make_chunk(start, stop) -> dict``, deterministically per window, so a
    2M-row dataset occupies O(chunk) host memory (examples/flight_scale.py).

    ``make_chunk`` must be pure in (start, stop): the same window always
    yields the same rows (the SVI sampler and the streamed gradient's
    second pass re-read windows).  ``fields`` is probed with an empty-able
    1-row window unless given explicitly.
    """

    def __init__(self, n: int, make_chunk: Callable[[int, int], dict],
                 fields: dict | None = None):
        if n < 0:
            raise ValueError(f"n must be >= 0, got {n}")
        self.n = n
        self._make = make_chunk
        if fields is None:
            probe = make_chunk(0, min(1, n)) if n else {}
            fields = {k: np.asarray(v).shape[1:] for k, v in probe.items()}
        self.fields = dict(fields)

    def read(self, start: int, stop: int) -> dict:
        out = {k: np.asarray(v) for k, v in self._make(start, stop).items()}
        for k, v in out.items():
            if v.shape[0] != stop - start:
                raise ValueError(
                    f"make_chunk returned {v.shape[0]} rows for field {k!r}, "
                    f"expected {stop - start}")
        return out


def as_source(obj):
    """Coerce to a block source: dict of arrays -> ArraySource; an existing
    source (or BlockStream, unwrapped) passes through."""
    if isinstance(obj, BlockStream):
        return obj.source
    if isinstance(obj, dict):
        return ArraySource(obj)
    if hasattr(obj, "read") and hasattr(obj, "n") and hasattr(obj, "fields"):
        return obj
    raise TypeError(
        f"cannot stream from {type(obj).__name__}: expected a dict of "
        "arrays or an object with (n, fields, read)")


# -- shard-major fixed-shape chunking ---------------------------------------

def padded_rows(n: int, mult: int) -> int:
    """Padded leading dim: next multiple of ``mult`` >= max(n, 1) — the
    single source of truth shared with ``distributed.pad_and_shard``, so a
    stream's padded layout matches the staged one row-for-row.  n = 0 still
    yields one full multiple (a shape-static all-padding block) rather than
    empty arrays."""
    return max(n + (-n) % mult, mult)


class BlockStream:
    """Fixed-shape padded chunks of a source, in shard-major layout.

    The padded row space is the one ``pad_and_shard`` builds: ``n_pad =
    padded_rows(n, n_shards·block_size)`` rows, shard k owning the
    contiguous range ``[k·rps, (k+1)·rps)`` (``rps = n_pad / n_shards``),
    real rows first, zero-weight padding at the global tail.  Chunk ``c``
    then carries, for EVERY shard, its local scan blocks ``[c·bpc,
    (c+1)·bpc)`` — concatenated shard-by-shard into one
    ``(n_shards·bpc·block_size, ...)`` host array that ``jax.device_put``
    with the engine's data sharding splits back into per-shard block runs.

    Because each shard sees its in-memory rows in its in-memory block
    partition and order, folding the chunks through
    ``partial_stats_chunked(init=carry)`` reproduces the staged engine's
    scan *bitwise* — the layout is the parity contract, not an
    optimisation.  All assembly is host-side numpy over ``source.read``
    windows: O(chunk) resident regardless of n.

    Args:
      source: a block source (``as_source`` coercible).
      n_shards: mesh data-shard count (``DistributedGP.n_shards``).
      block_size: rows per device scan block (the engine's ``chunk_size``).
      blocks_per_chunk: scan blocks per shard per chunk — the H2D transfer
        granularity.  Larger chunks amortise dispatch; smaller chunks bound
        host memory and sharpen SVI sampling granularity.
    """

    def __init__(self, source, n_shards: int = 1, block_size: int = 1024,
                 blocks_per_chunk: int = 1):
        if n_shards < 1 or block_size < 1 or blocks_per_chunk < 1:
            raise ValueError(
                "n_shards, block_size and blocks_per_chunk must be >= 1, "
                f"got {n_shards}, {block_size}, {blocks_per_chunk}")
        self.source = as_source(source)
        self.n_shards = n_shards
        self.block_size = block_size
        self.n = self.source.n
        self.fields = dict(self.source.fields)
        self.n_pad = padded_rows(self.n, n_shards * block_size)
        self.rows_per_shard = self.n_pad // n_shards
        self.blocks_per_shard = self.rows_per_shard // block_size
        # Chunks never overshoot a shard's row range: an oversized
        # blocks_per_chunk clamps to the whole shard (one chunk), keeping
        # every chunk's per-shard block sequence a prefix-run of the
        # in-memory scan's (the bitwise-parity contract).
        blocks_per_chunk = min(blocks_per_chunk, self.blocks_per_shard)
        self.blocks_per_chunk = blocks_per_chunk
        self.n_chunks = -(-self.blocks_per_shard // blocks_per_chunk)
        # Rows per shard per chunk / total chunk rows (fixed for all chunks;
        # the tail chunk tops up with zero-weight blocks).
        self.shard_chunk_rows = blocks_per_chunk * block_size
        self.chunk_rows = n_shards * self.shard_chunk_rows

    def field_dtype(self, k):
        """Host dtype of field ``k`` (probed from a 0/1-row read)."""
        win = self.source.read(0, 0 if self.n == 0 else 1)
        return np.asarray(win[k]).dtype

    def chunk(self, c: int):
        """Assemble chunk ``c`` -> ``(dict of (chunk_rows, ...) arrays,
        weights (chunk_rows,))``; weights are 1.0 exactly on real rows."""
        if not 0 <= c < max(self.n_chunks, 1):
            raise IndexError(f"chunk {c} out of range ({self.n_chunks})")
        out = {}
        w = np.zeros((self.chunk_rows,), np.float64)
        reads = []      # (dst_start, src_start, src_stop) real-row windows
        for k_sh in range(self.n_shards):
            lo = k_sh * self.rows_per_shard + c * self.shard_chunk_rows
            hi = min(lo + self.shard_chunk_rows,
                     (k_sh + 1) * self.rows_per_shard)
            real_hi = min(hi, self.n)           # padding = global tail rows
            if real_hi > lo:
                dst = k_sh * self.shard_chunk_rows
                reads.append((dst, lo, real_hi))
                w[dst:dst + (real_hi - lo)] = 1.0
        for k, trail in self.fields.items():
            # q(X) variances pad with 1s (log-safe), everything else 0s —
            # the pad_and_shard convention.
            cval = 1.0 if k in ("s", "S") else 0.0
            out[k] = np.full((self.chunk_rows,) + tuple(trail), cval,
                             dtype=self.field_dtype(k))
        for dst, lo, hi in reads:
            data = self.source.read(lo, hi)
            for k in self.fields:
                out[k][dst:dst + (hi - lo)] = data[k]
        return out, w

    def __len__(self) -> int:
        return self.n_chunks

    def __iter__(self) -> Iterator:
        return (self.chunk(c) for c in range(self.n_chunks))

    def chunks(self, indices: Iterable[int] | None = None) -> Iterator:
        """Iterate chunks — all of them, or an explicit index subset (the
        SVI sampler path)."""
        idx = range(self.n_chunks) if indices is None else indices
        return (self.chunk(int(c)) for c in idx)


# -- double-buffered prefetch ------------------------------------------------

class _PrefetchDone:
    pass


class _PrefetchError:
    def __init__(self, exc):
        self.exc = exc


def prefetch(it: Iterable, fn: Callable | None = None, depth: int = 2):
    """Map ``fn`` over ``it`` in a background thread, ``depth`` items ahead.

    The returned generator yields ``fn(item)`` in order.  With ``fn`` doing
    host assembly + ``jax.device_put`` (:func:`stage_to_device`), item
    ``i+1``'s read/pad/H2D overlaps the caller's device compute on item
    ``i`` — jitted programs release the GIL while XLA executes, so the
    overlap is real on a single host.  ``depth`` bounds how many staged
    items exist at once (2 = classic double buffering).  Worker exceptions
    re-raise at the consumer's next pull; abandoning the generator
    (``close`` / GC) unblocks and stops the worker.
    """
    if depth < 1:
        raise ValueError(f"depth must be >= 1, got {depth}")
    q: queue.Queue = queue.Queue(maxsize=depth)
    stop = threading.Event()

    def _worker():
        try:
            for item in it:
                staged = item if fn is None else fn(item)
                while not stop.is_set():
                    try:
                        q.put(staged, timeout=0.1)
                        break
                    except queue.Full:
                        continue
                if stop.is_set():
                    return
            q.put(_PrefetchDone())
        except BaseException as e:  # noqa: BLE001 — relayed to the consumer
            try:
                q.put(_PrefetchError(e), timeout=1.0)
            except queue.Full:
                pass

    t = threading.Thread(target=_worker, daemon=True,
                         name="repro-stream-prefetch")
    t.start()

    def _gen():
        try:
            while True:
                item = q.get()
                if isinstance(item, _PrefetchDone):
                    return
                if isinstance(item, _PrefetchError):
                    raise item.exc
                yield item
        finally:
            stop.set()

    return _gen()


def stage_to_device(sharding=None):
    """A ``prefetch`` fn staging ``(arrays_dict, weights)`` chunks onto the
    device(s): ``jax.device_put`` each field (and the weight vector) with
    the given sharding (e.g. ``DistributedGP.data_sharding()``), or onto
    the default device when None."""
    import jax

    def _stage(chunk):
        arrs, w = chunk
        if sharding is None:
            return ({k: jax.device_put(v) for k, v in arrs.items()},
                    jax.device_put(w))
        return ({k: jax.device_put(v, sharding) for k, v in arrs.items()},
                jax.device_put(w, sharding))

    return _stage
