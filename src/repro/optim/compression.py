"""int8 error-feedback gradient compression (beyond-paper DP optimisation).

Before the data-parallel all-reduce, gradients are quantised to int8 with a
per-tensor scale; the quantisation residual is carried to the next step
(error feedback, Seide et al. 2014 / Karimireddy et al. 2019), which keeps
SGD/Adam convergence. 4x less DP all-reduce traffic; enable per-config when
the roofline says the step is DP-collective-bound.

``compress_fn`` plugs into train.steps.make_train_step(compression=...):
it simulates the wire format (quantise -> dequantise) and maintains the
error state functionally.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_int8(x):
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale


def init_error_state(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress_with_feedback(grads, err):
    """Returns (wire-equivalent grads, new error state)."""

    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        q, scale = quantize_int8(g32)
        deq = dequantize_int8(q, scale)
        return deq.astype(g.dtype), g32 - deq

    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = tdef.flatten_up_to(err)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (tdef.unflatten([o[0] for o in out]),
            tdef.unflatten([o[1] for o in out]))


def wire_bytes(grads, compressed: bool) -> int:
    tot = 0
    for g in jax.tree.leaves(grads):
        tot += g.size * (1 if compressed else 4)
    return tot
