"""AdamW from scratch (no optax): pytree-native, f32 moments, bf16-safe."""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamConfig(NamedTuple):
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100


def init_opt_state(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def _schedule(cfg: AdamConfig, step):
    warm = jnp.minimum(1.0, (step + 1) / max(cfg.warmup_steps, 1))
    return cfg.lr * warm


def global_norm(tree):
    sq = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
             for g in jax.tree.leaves(tree))
    return jnp.sqrt(sq)


def adam_update(cfg: AdamConfig, params, grads, state):
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    lr = _schedule(cfg, state["step"])
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32) * scale
        m2 = cfg.b1 * m + (1 - cfg.b1) * g32
        v2 = cfg.b2 * v + (1 - cfg.b2) * g32 * g32
        mhat = m2 / b1c
        vhat = v2 / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if cfg.weight_decay and p.ndim >= 2:   # decay matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        p2 = p.astype(jnp.float32) - lr * delta
        return p2.astype(p.dtype), m2, v2

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state["m"])
    flat_v = tdef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}, {
        "grad_norm": gnorm, "lr": lr}
