from . import adam
from .adam import AdamConfig, adam_update, init_opt_state
