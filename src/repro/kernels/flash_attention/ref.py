"""Pure-jnp oracle for flash attention (materialised softmax)."""
from __future__ import annotations

import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool = True, scale: float | None = None):
    """q: (B,H,T,Dh); k/v: (B,Hkv,S,Dh). Dense reference in f32."""
    b, h, t, dh = q.shape
    hkv = k.shape[1]
    group = h // hkv
    scale = (dh ** -0.5) if scale is None else scale
    kr = jnp.repeat(k, group, axis=1)
    vr = jnp.repeat(v, group, axis=1)
    s = jnp.einsum("bhtd,bhsd->bhts", q.astype(jnp.float32),
                   kr.astype(jnp.float32)) * scale
    if causal:
        tt, ss = s.shape[-2:]
        mask = jnp.tril(jnp.ones((tt, ss), bool), k=ss - tt)
        s = jnp.where(mask[None, None], s, -jnp.inf)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    return jnp.einsum("bhts,bhsd->bhtd", p, vr.astype(jnp.float32)).astype(q.dtype)
