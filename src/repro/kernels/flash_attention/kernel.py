"""Pallas TPU flash attention (streaming softmax) for LM prefill.

Grid (batch, q_heads, q_blocks, kv_blocks) with the kv dimension innermost;
running max / normaliser / accumulator live in VMEM scratch across kv steps
(the classic online-softmax recurrence). GQA is handled for free in the
BlockSpec index_map: kv operands index head ``h // group`` so grouped KV is
never materialised per q-head.

Causal masking skips fully-masked kv blocks via ``pl.when`` (no compute
issued for the upper triangle beyond the diagonal block).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1.0e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
               scale: float, causal: bool, block_q: int, block_k: int,
               kv_len: int, q_offset: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    def _step():
        q = q_ref[0, 0].astype(jnp.float32)          # (bq, dh)
        k = k_ref[0, 0].astype(jnp.float32)          # (bk, dh)
        v = v_ref[0, 0].astype(jnp.float32)          # (bk, dh)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # (bq, bk)

        # mask: causal upper triangle and kv padding beyond kv_len
        col = ki * block_k + jax.lax.broadcasted_iota(jnp.int32,
                                                      (block_q, block_k), 1)
        valid = col < kv_len
        if causal:
            # queries are suffix-aligned to the kv axis (decode convention):
            # query row r attends to cols <= r + q_offset
            row = qi * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0)
            valid = valid & (col <= row + q_offset)
        s = jnp.where(valid, s, NEG_INF)

        m_prev = m_scr[...]                          # (bq, 1)
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)                       # (bq, bk)
        corr = jnp.exp(m_prev - m_new)               # (bq, 1)
        l_new = corr * l_scr[...] + jnp.sum(p, axis=1, keepdims=True)
        acc_scr[...] = corr * acc_scr[...] + jax.lax.dot(
            p, v, precision=jax.lax.Precision.HIGHEST)
        m_scr[...] = m_new
        l_scr[...] = l_new

    if causal:
        # Skip kv blocks entirely above the diagonal of this q block.
        pl.when((ki * block_k) <= (qi * block_q + block_q - 1 + q_offset))(_step)
    else:
        _step()

    @pl.when(ki == nk - 1)
    def _finish():
        l = l_scr[...]
        l = jnp.where(l == 0.0, 1.0, l)              # fully-masked rows -> 0
        o_ref[0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True, scale: float | None = None,
                    block_q: int = 128, block_k: int = 128,
                    kv_len: int | None = None, q_len: int | None = None,
                    interpret: bool = False):
    """q: (B, H, T, Dh); k/v: (B, Hkv, S, Dh) with H % Hkv == 0. -> (B, H, T, Dh).

    Pre-padded: T % block_q == 0, S % block_k == 0 handled by ops.py;
    ``kv_len``/``q_len`` are the TRUE lengths — masking makes padding inert.
    Causal queries are suffix-aligned: true query row r sees kv cols
    <= r + (kv_len - q_len).
    """
    b, h, t, dh = q.shape
    _, hkv, s_len, _ = k.shape
    assert h % hkv == 0
    group = h // hkv
    scale = (dh ** -0.5) if scale is None else scale
    kv_len = s_len if kv_len is None else kv_len
    q_len = t if q_len is None else q_len
    q_offset = kv_len - q_len
    grid = (b, h, t // block_q, s_len // block_k)

    kernel = functools.partial(
        _fa_kernel, scale=scale, causal=causal, block_q=block_q,
        block_k=block_k, kv_len=kv_len, q_offset=q_offset)

    from jax.experimental.pallas import tpu as pltpu
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, dh),
                         lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
            pl.BlockSpec((1, 1, block_k, dh),
                         lambda bi, hi, qi, ki, g=group: (bi, hi // g, ki, 0)),
            pl.BlockSpec((1, 1, block_k, dh),
                         lambda bi, hi, qi, ki, g=group: (bi, hi // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, dh),
                               lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, t, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, dh), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
