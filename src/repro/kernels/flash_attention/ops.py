"""jit'd wrapper: padding + backend selection for the flash kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import kernel as _k


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit,
                   static_argnames=("causal", "block_q", "block_k", "interpret"))
def flash_attention(q, k, v, causal: bool = True, block_q: int = 128,
                    block_k: int = 128, interpret: bool | None = None):
    """Padded/sliced flash attention. q (B,H,T,Dh), kv (B,Hkv,S,Dh)."""
    interpret = (not _on_tpu()) if interpret is None else interpret
    t, s_len = q.shape[2], k.shape[2]
    pad_t = (-t) % block_q
    pad_s = (-s_len) % block_k
    if pad_t:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad_t), (0, 0)))
    if pad_s:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_s), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_s), (0, 0)))
    out = _k.flash_attention(q, k, v, causal=causal, block_q=block_q,
                             block_k=block_k, kv_len=s_len, q_len=t,
                             interpret=interpret)
    return out[:, :, :t, :]
