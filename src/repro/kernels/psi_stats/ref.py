"""Pure-jnp oracle for the psi-statistics kernels.

Independent of the Pallas code path; mirrors the closed forms in
``repro.core.gp_kernels`` (which are themselves validated against
Monte-Carlo in tests/test_psi_stats.py).
"""
from __future__ import annotations

import jax.numpy as jnp


def psi1_ref(log_sf2, log_ell, z, mu, s):
    """(n, m) <k(x_i, z_m)>_q."""
    ell2 = jnp.exp(2.0 * log_ell)
    sf2 = jnp.exp(log_sf2)
    denom = ell2[None, :] + s
    lognorm = -0.5 * jnp.sum(jnp.log1p(s / ell2[None, :]), axis=-1)
    d = mu[:, None, :] - z[None, :, :]
    expo = -0.5 * jnp.sum(d * d / denom[:, None, :], axis=-1)
    return sf2 * jnp.exp(lognorm[:, None] + expo)


def psi2_ref(log_sf2, log_ell, z, mu, s, w):
    """(m, m) weighted Sum_i <k(x_i,z_m) k(x_i,z_m')>_q."""
    ell2 = jnp.exp(2.0 * log_ell)
    sf2 = jnp.exp(log_sf2)
    dz = z[:, None, :] - z[None, :, :]
    static = -0.25 * jnp.sum(dz * dz / ell2, axis=-1)
    zbar = 0.5 * (z[:, None, :] + z[None, :, :])
    denom = ell2[None, :] + 2.0 * s
    lognorm = -0.5 * jnp.sum(jnp.log1p(2.0 * s / ell2[None, :]), axis=-1)
    d = mu[:, None, None, :] - zbar[None, :, :, :]
    expo = -jnp.sum(d * d / denom[:, None, None, :], axis=-1)
    vals = (sf2 * sf2) * jnp.exp(lognorm[:, None, None] + static[None] + expo)
    return jnp.einsum("i,iab->ab", w, vals)
