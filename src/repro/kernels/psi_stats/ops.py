"""jit'd public wrappers around the psi-statistics Pallas kernels.

Handles padding to tile boundaries (all pads are NEUTRAL — padded latent
dims carry mu=s=z=0, ell2=1; padded data rows carry w=0; padded inducing
rows are sliced off the output), backend selection (interpret=True off-TPU),
and the hyper-parameter plumbing from the core library's log-space dict.

``psi2`` carries a ``custom_vjp`` (``pallas_call`` has no VJP on this JAX
version): forward is the Pallas kernel, backward recomputes through the
MXU-matmul XLA reformulation (``gp_kernels.psi2_mxu``) — so the kernel can
sit inside ``jax.grad`` of the bound (the engine's ``kernel_backend=
"pallas"`` path).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ...core import gp_kernels as gpk
from .._common import on_tpu as _on_tpu
from .._common import pad_to as _pad_to
from . import kernel as _k


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2))
def _psi2(block_n, block_m, interpret, hyp, z, mu, s, w):
    return _psi2_fwd_impl(block_n, block_m, interpret, hyp, z, mu, s, w)


def _psi2_fwd_impl(block_n, block_m, interpret, hyp, z, mu, s, w):
    m = z.shape[0]
    f32 = jnp.float32
    ell2 = jnp.exp(2.0 * hyp["log_ell"]).astype(f32)[None, :]       # (1, q)
    sf4 = jnp.exp(2.0 * hyp["log_sf2"]).astype(f32)[None, None]     # (1, 1)

    q_pad = 8
    ell2 = _pad_to(ell2, q_pad, 1, value=1.0)
    z_p = _pad_to(_pad_to(z.astype(f32), q_pad, 1), block_m, 0)
    mu_p = _pad_to(_pad_to(mu.astype(f32), q_pad, 1), block_n, 0)
    s_p = _pad_to(_pad_to(s.astype(f32), q_pad, 1), block_n, 0)
    w_p = _pad_to(w.astype(f32)[:, None], block_n, 0)

    out = _k.psi2_pallas(ell2, sf4, z_p, mu_p, s_p, w_p,
                         block_n=block_n, block_m=block_m,
                         interpret=interpret)
    return out[:m, :m]


def _psi2_vjp_fwd(block_n, block_m, interpret, hyp, z, mu, s, w):
    out = _psi2_fwd_impl(block_n, block_m, interpret, hyp, z, mu, s, w)
    return out, (hyp, z, mu, s, w)


def _psi2_vjp_bwd(block_n, block_m, interpret, res, ct):
    del block_n, block_m, interpret
    # Backward recompute via the XLA MXU reformulation; chunk=256 bounds the
    # live (chunk, m^2) intermediate under the streaming engine's blocks.
    out, vjp = jax.vjp(
        lambda h, zz, mm, ss, ww: gpk.psi2_mxu(h, zz, mm, ss, ww, chunk=256),
        *res)
    return vjp(jnp.asarray(ct, out.dtype))


_psi2.defvjp(_psi2_vjp_fwd, _psi2_vjp_bwd)


@functools.partial(jax.jit, static_argnames=("block_n", "block_m", "interpret"))
def psi2(hyp: dict, z, mu, s, w, block_n: int = 128, block_m: int = 64,
         interpret: bool | None = None):
    """Weighted Psi2 = sum_i w_i <K_mi K_im> via the Pallas kernel. (m, m)."""
    interpret = (not _on_tpu()) if interpret is None else interpret
    return _psi2(block_n, block_m, interpret, hyp, z, mu, s, w)


@functools.partial(jax.jit, static_argnames=("block_n", "block_m", "interpret"))
def psi1(hyp: dict, z, mu, s, block_n: int = 256, block_m: int = 128,
         interpret: bool | None = None):
    """Psi1 = <K_nm> via the Pallas kernel. (n, m)."""
    interpret = (not _on_tpu()) if interpret is None else interpret
    n, m = mu.shape[0], z.shape[0]
    f32 = jnp.float32
    ell2 = jnp.exp(2.0 * hyp["log_ell"]).astype(f32)[None, :]
    sf2 = jnp.exp(hyp["log_sf2"]).astype(f32)[None, None]

    q_pad = 8
    ell2 = _pad_to(ell2, q_pad, 1, value=1.0)
    z_p = _pad_to(_pad_to(z.astype(f32), q_pad, 1), block_m, 0)
    mu_p = _pad_to(_pad_to(mu.astype(f32), q_pad, 1), block_n, 0)
    s_p = _pad_to(_pad_to(s.astype(f32), q_pad, 1), block_n, 0)

    out = _k.psi1_pallas(ell2, sf2, z_p, mu_p, s_p,
                         block_n=block_n, block_m=block_m, interpret=interpret)
    return out[:n, :m]


def psi2_fn_for_engine(block_n: int = 128, block_m: int = 64, kernel=None):
    """Adapter matching core.stats.partial_stats(psi2_fn=...) signature.

    Dispatch shim for the compositional kernel layer: the fused Pallas
    kernel computes the SE-ARD closed form, so the full-width SE-ARD
    expression (the default) gets the fast path; any other expression runs
    its own ``Kernel.psi2`` (analytic or quadrature) through XLA — same
    signature, parity covered by tests/test_kernel_zoo.py.
    """
    from ...core.covariance import as_kernel, is_fused_se

    kernel = as_kernel(kernel)
    if is_fused_se(kernel):
        def fn(hyp, z, mu, s, w):
            return psi2(hyp, z, mu, s, w, block_n=block_n, block_m=block_m)
    else:
        def fn(hyp, z, mu, s, w):
            return kernel.psi2(hyp, z, mu, s, w)

    return fn
