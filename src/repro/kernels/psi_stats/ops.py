"""jit'd public wrappers around the psi-statistics Pallas kernels.

Handles padding to tile boundaries (all pads are NEUTRAL — padded latent
dims carry mu=s=z=0, ell2=1; padded data rows carry w=0; padded inducing
rows are sliced off the output), backend selection (interpret=True off-TPU),
and the hyper-parameter plumbing from the core library's log-space dict.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import kernel as _k


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_to(x, mult, axis, value=0.0):
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


@functools.partial(jax.jit, static_argnames=("block_n", "block_m", "interpret"))
def psi2(hyp: dict, z, mu, s, w, block_n: int = 128, block_m: int = 64,
         interpret: bool | None = None):
    """Weighted Psi2 = sum_i w_i <K_mi K_im> via the Pallas kernel. (m, m)."""
    interpret = (not _on_tpu()) if interpret is None else interpret
    m = z.shape[0]
    f32 = jnp.float32
    ell2 = jnp.exp(2.0 * hyp["log_ell"]).astype(f32)[None, :]       # (1, q)
    sf4 = jnp.exp(2.0 * hyp["log_sf2"]).astype(f32)[None, None]     # (1, 1)

    q_pad = 8
    ell2 = _pad_to(ell2, q_pad, 1, value=1.0)
    z_p = _pad_to(_pad_to(z.astype(f32), q_pad, 1), block_m, 0)
    mu_p = _pad_to(_pad_to(mu.astype(f32), q_pad, 1), block_n, 0)
    s_p = _pad_to(_pad_to(s.astype(f32), q_pad, 1), block_n, 0)
    w_p = _pad_to(w.astype(f32)[:, None], block_n, 0)

    out = _k.psi2_pallas(ell2, sf4, z_p, mu_p, s_p, w_p,
                         block_n=block_n, block_m=block_m,
                         interpret=interpret)
    return out[:m, :m]


@functools.partial(jax.jit, static_argnames=("block_n", "block_m", "interpret"))
def psi1(hyp: dict, z, mu, s, block_n: int = 256, block_m: int = 128,
         interpret: bool | None = None):
    """Psi1 = <K_nm> via the Pallas kernel. (n, m)."""
    interpret = (not _on_tpu()) if interpret is None else interpret
    n, m = mu.shape[0], z.shape[0]
    f32 = jnp.float32
    ell2 = jnp.exp(2.0 * hyp["log_ell"]).astype(f32)[None, :]
    sf2 = jnp.exp(hyp["log_sf2"]).astype(f32)[None, None]

    q_pad = 8
    ell2 = _pad_to(ell2, q_pad, 1, value=1.0)
    z_p = _pad_to(_pad_to(z.astype(f32), q_pad, 1), block_m, 0)
    mu_p = _pad_to(_pad_to(mu.astype(f32), q_pad, 1), block_n, 0)
    s_p = _pad_to(_pad_to(s.astype(f32), q_pad, 1), block_n, 0)

    out = _k.psi1_pallas(ell2, sf2, z_p, mu_p, s_p,
                         block_n=block_n, block_m=block_m, interpret=interpret)
    return out[:n, :m]


def psi2_fn_for_engine(block_n: int = 128, block_m: int = 64):
    """Adapter matching core.stats.partial_stats(psi2_fn=...) signature."""

    def fn(hyp, z, mu, s, w):
        return psi2(hyp, z, mu, s, w, block_n=block_n, block_m=block_m)

    return fn
