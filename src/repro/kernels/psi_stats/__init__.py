from . import kernel, ops, ref
from .ops import psi1, psi2, psi2_fn_for_engine

__all__ = ["kernel", "ops", "ref", "psi1", "psi2", "psi2_fn_for_engine"]
