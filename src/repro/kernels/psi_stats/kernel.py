"""Pallas TPU kernels for the psi-statistics map step — the paper's hot spot.

The paper's cost model for the map step is O(n m^2 q) elementwise work per
data shard (their §2.1/§3.2). A mechanical port would materialise the
(n, m, m, q) broadcast — hostile to both VMEM and the MXU. We instead
re-factor the exponent so the inner loops become matrix multiplies
(TPU-native, MXU-aligned), which is the hardware adaptation of the paper's
insight:

  psi2 exponent (per point i, inducing pair (a,b), latent dim q):
    E[i,ab] = static[ab] + lognorm_i - sum_q (mu_iq - zbar_abq)^2 / den_iq
    with den_iq = ell_q^2 + 2 s_iq, zbar = (z_a + z_b)/2.
  Expanding the square decouples i from (ab):
    E = alpha_i + M_i. @ Zb.ab,
    M  = [2 mu/den, -1/den]               (n, 2q)
    Zb = [zbar; zbar^2] (per ab column)   (2q, m^2)
  so the kernel is two MXU matmuls + exp + one reduce matmul (w^T exp(E)),
  tiled (block_n x block_m x block_m) so every operand lives in VMEM.

psi1 uses the same trick one order lower.

Tiling contract (enforced/padded by ops.py):
  n % block_n == 0, m % block_m == 0, q % q_pad == 0, all >= TPU lane rules.
  q is padded NEUTRALLY: padded dims carry mu=s=z=0, ell2=1, which
  contributes exactly 0 to every exponent term.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


# ---------------------------------------------------------------------------
# psi2: D = sum_i w_i <K_mi K_im>  — grid (a_tiles, b_tiles, n_tiles)
# ---------------------------------------------------------------------------

def _psi2_kernel(ell2_ref, sf4_ref, za_ref, zb_ref, mu_ref, s_ref, w_ref,
                 out_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    ell2 = ell2_ref[0, :]                      # (q,)
    mu = mu_ref[...]                           # (bn, q)
    s = s_ref[...]                             # (bn, q)
    w = w_ref[...]                             # (bn, 1)
    za = za_ref[...]                           # (bm, q)
    zb = zb_ref[...]                           # (bm, q)
    bm = za.shape[0]

    den = ell2[None, :] + 2.0 * s              # (bn, q)
    inv_den = 1.0 / den
    # lognorm_i = -0.5 sum_q log(den/ell2)
    lognorm = -0.5 * jnp.sum(jnp.log(den) - jnp.log(ell2)[None, :], axis=1)
    alpha = lognorm - jnp.sum(mu * mu * inv_den, axis=1)          # (bn,)
    m_mat = jnp.concatenate([2.0 * mu * inv_den, -inv_den], axis=1)  # (bn, 2q)

    zbar = 0.5 * (za[:, None, :] + zb[None, :, :])                # (bm, bm, q)
    zb_mat = jnp.concatenate([zbar, zbar * zbar], axis=-1)        # (bm, bm, 2q)
    zb_mat = zb_mat.reshape(bm * bm, -1).T                        # (2q, bm*bm)

    dz = za[:, None, :] - zb[None, :, :]
    static = -0.25 * jnp.sum(dz * dz / ell2[None, None, :], axis=-1)
    static = static.reshape(1, bm * bm)                           # (1, bm*bm)

    e = alpha[:, None] + jax.lax.dot(m_mat, zb_mat,
                                     precision=jax.lax.Precision.HIGHEST)
    p = jnp.exp(e + static)                                       # (bn, bm*bm)
    acc = jax.lax.dot(w.T, p, precision=jax.lax.Precision.HIGHEST)
    out_ref[...] += sf4_ref[0, 0] * acc.reshape(bm, bm)


def psi2_pallas(ell2, sf4, z, mu, s, w, *, block_n=128, block_m=64,
                interpret=False):
    """w-weighted Psi2 (m, m). All inputs pre-padded (see ops.py).

    ell2: (1, q) f32; sf4: (1, 1) f32; z: (m, q); mu/s: (n, q); w: (n, 1).
    """
    n, q = mu.shape
    m = z.shape[0]
    assert n % block_n == 0 and m % block_m == 0
    grid = (m // block_m, m // block_m, n // block_n)
    return pl.pallas_call(
        _psi2_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, q), lambda a, b, k: (0, 0)),            # ell2
            pl.BlockSpec((1, 1), lambda a, b, k: (0, 0)),            # sf4
            pl.BlockSpec((block_m, q), lambda a, b, k: (a, 0)),      # z_a
            pl.BlockSpec((block_m, q), lambda a, b, k: (b, 0)),      # z_b
            pl.BlockSpec((block_n, q), lambda a, b, k: (k, 0)),      # mu
            pl.BlockSpec((block_n, q), lambda a, b, k: (k, 0)),      # s
            pl.BlockSpec((block_n, 1), lambda a, b, k: (k, 0)),      # w
        ],
        out_specs=pl.BlockSpec((block_m, block_m), lambda a, b, k: (a, b)),
        out_shape=jax.ShapeDtypeStruct((m, m), jnp.float32),
        interpret=interpret,
    )(ell2, sf4, z, z, mu, s, w)


# ---------------------------------------------------------------------------
# psi1: (n, m) <K_im> — grid (n_tiles, m_tiles)
# ---------------------------------------------------------------------------

def _psi1_kernel(ell2_ref, sf2_ref, z_ref, mu_ref, s_ref, out_ref):
    ell2 = ell2_ref[0, :]
    mu = mu_ref[...]                            # (bn, q)
    s = s_ref[...]                              # (bn, q)
    z = z_ref[...]                              # (bm, q)

    den = ell2[None, :] + s
    inv_den = 1.0 / den
    lognorm = -0.5 * jnp.sum(jnp.log(den) - jnp.log(ell2)[None, :], axis=1)
    alpha = lognorm - 0.5 * jnp.sum(mu * mu * inv_den, axis=1)      # (bn,)
    m_mat = jnp.concatenate([mu * inv_den, -0.5 * inv_den], axis=1)  # (bn, 2q)
    zb_mat = jnp.concatenate([z, z * z], axis=1).T                   # (2q, bm)
    e = alpha[:, None] + jax.lax.dot(m_mat, zb_mat,
                                     precision=jax.lax.Precision.HIGHEST)
    out_ref[...] = sf2_ref[0, 0] * jnp.exp(e)


def psi1_pallas(ell2, sf2, z, mu, s, *, block_n=256, block_m=128,
                interpret=False):
    """Psi1 (n, m). Inputs pre-padded."""
    n, q = mu.shape
    m = z.shape[0]
    assert n % block_n == 0 and m % block_m == 0
    grid = (n // block_n, m // block_m)
    return pl.pallas_call(
        _psi1_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, q), lambda i, j: (0, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
            pl.BlockSpec((block_m, q), lambda i, j: (j, 0)),
            pl.BlockSpec((block_n, q), lambda i, j: (i, 0)),
            pl.BlockSpec((block_n, q), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_n, block_m), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, m), jnp.float32),
        interpret=interpret,
    )(ell2, sf2, z, mu, s)
