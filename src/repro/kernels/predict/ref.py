"""Pure-jnp oracle for the fused serving predict kernel.

Independent of both the Pallas code path and ``repro.core.gp_kernels``;
states the two serving statistics directly from the SE-ARD kernel
definition and the precomputed state contractions.
"""
from __future__ import annotations

import jax.numpy as jnp


def predict_ref(log_sf2, log_ell, z, a_mean, g, x):
    """(mean (t, d), quad (t,)) of the serving map against state (a_mean, g)."""
    ell = jnp.exp(log_ell)
    sf2 = jnp.exp(log_sf2)
    dd = x[:, None, :] / ell - z[None, :, :] / ell
    ksm = sf2 * jnp.exp(-0.5 * jnp.sum(dd * dd, axis=-1))     # (t, m)
    mean = ksm @ a_mean
    quad = jnp.sum((ksm @ g) * ksm, axis=1)
    return mean, quad
