"""Pallas TPU kernel for the fused serving predict step.

Serving needs two statistics of the query kernel slab ``ksm = k(X*, Z)``
against the frozen predictive state (``serve/posterior.py``):

    mean = ksm @ a_mean                      (t, d)
    quad = rowsum((ksm @ g) * ksm)           (t,)    var = k** - quad

A mechanical XLA lowering materialises the (t, m) slab in HBM and re-reads
it for each contraction.  This kernel evaluates ``ksm`` tile-by-tile in VMEM
and folds both statistics in the same grid pass — the serving twin of
``kernels/reg_stats`` (same ARD exponent refactoring: one MXU matmul + exp
per tile), but **forward-only**: prediction is never differentiated, so
there is no ``custom_vjp`` and no backward recompute.

Grid ``(t_tiles, a_tiles, b_tiles)`` — t outermost so each output block's
reduction visits are consecutive (the revolving-accumulator contract):
  quad block (t,) accumulates over every (a, b) cell:  (ka Gab) . kb ;
  mean block (t,) accumulates only on the b == 0 sweep: ka @ A_a.

Tiling contract (enforced/padded by ops.py):
  t % block_t == 0, m % block_m == 0, q and d padded to multiples of 8.
  Padding is NEUTRAL: padded latent dims carry x=z=0, inv_ell2=1 (zero
  exponent contribution); padded inducing rows carry zero rows/cols of
  ``g`` and ``a_mean`` (their nonzero kernel columns multiply zeros);
  padded query rows compute garbage that ops.py slices off.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _predict_kernel(inv_ref, sf2_ref, za_ref, zb_ref, x_ref, g_ref, a_ref,
                    mean_ref, quad_ref):
    a_i = pl.program_id(1)
    b_i = pl.program_id(2)
    first_b = b_i == 0
    first_ab = jnp.logical_and(a_i == 0, first_b)

    @pl.when(first_ab)
    def _init():
        mean_ref[...] = jnp.zeros_like(mean_ref)
        quad_ref[...] = jnp.zeros_like(quad_ref)

    inv = inv_ref[0, :]                                       # (q,)
    sf2 = sf2_ref[0, 0]
    x = x_ref[...]                                            # (bt, q)

    alpha = -0.5 * jnp.sum(x * x * inv[None, :], axis=1)      # (bt,)
    m_mat = jnp.concatenate(
        [x * inv[None, :],
         jnp.broadcast_to(-0.5 * inv[None, :], x.shape)], axis=1)  # (bt, 2q)

    def k_tile(z):                                            # (bm, q) -> (bt, bm)
        zc = jnp.concatenate([z, z * z], axis=1).T            # (2q, bm)
        e = alpha[:, None] + jax.lax.dot(
            m_mat, zc, precision=jax.lax.Precision.HIGHEST)
        return sf2 * jnp.exp(e)

    ka = k_tile(za_ref[...])
    kb = k_tile(zb_ref[...])

    tmp = jax.lax.dot(ka, g_ref[...],
                      precision=jax.lax.Precision.HIGHEST)    # (bt, bm)
    quad_ref[...] += jnp.sum(tmp * kb, axis=1, keepdims=True)

    @pl.when(first_b)
    def _acc_mean():
        mean_ref[...] += jax.lax.dot(ka, a_ref[...],
                                     precision=jax.lax.Precision.HIGHEST)


def predict_pallas(inv_ell2, sf2, z, x, a_mean, g, *, block_t=128,
                   block_m=64, interpret=False):
    """Fused (mean, quad) serving statistics. All inputs pre-padded (ops.py).

    inv_ell2: (1, q); sf2: (1, 1); z: (m, q); x: (t, q); a_mean: (m, d);
    g: (m, m).  Returns (mean (t, d), quad (t, 1)) in the input dtype.
    """
    t, q = x.shape
    m = z.shape[0]
    d = a_mean.shape[1]
    assert t % block_t == 0 and m % block_m == 0
    dt = x.dtype
    grid = (t // block_t, m // block_m, m // block_m)
    return pl.pallas_call(
        _predict_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, q), lambda i, a, b: (0, 0)),            # inv_ell2
            pl.BlockSpec((1, 1), lambda i, a, b: (0, 0)),            # sf2
            pl.BlockSpec((block_m, q), lambda i, a, b: (a, 0)),      # z_a
            pl.BlockSpec((block_m, q), lambda i, a, b: (b, 0)),      # z_b
            pl.BlockSpec((block_t, q), lambda i, a, b: (i, 0)),      # x
            pl.BlockSpec((block_m, block_m), lambda i, a, b: (a, b)),  # g
            pl.BlockSpec((block_m, d), lambda i, a, b: (a, 0)),      # a_mean
        ],
        out_specs=[
            pl.BlockSpec((block_t, d), lambda i, a, b: (i, 0)),      # mean
            pl.BlockSpec((block_t, 1), lambda i, a, b: (i, 0)),      # quad
        ],
        out_shape=[
            jax.ShapeDtypeStruct((t, d), dt),
            jax.ShapeDtypeStruct((t, 1), dt),
        ],
        interpret=interpret,
    )(inv_ell2, sf2, z, z, x, g, a_mean)
