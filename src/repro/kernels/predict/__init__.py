from . import kernel, ops, ref
from .ops import predict_fn_for_engine, predict_stats

__all__ = ["kernel", "ops", "ref", "predict_fn_for_engine", "predict_stats"]
