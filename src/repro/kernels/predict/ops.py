"""jit'd public wrapper around the fused serving predict Pallas kernel.

Handles padding to tile boundaries (all pads are NEUTRAL — padded latent
dims carry x=z=0, inv_ell2=1; padded inducing rows carry zero ``g`` rows/
cols and zero ``a_mean`` rows; padded query rows are sliced off the
outputs), backend selection (interpret=True off-TPU), and the
hyper-parameter plumbing from the core library's log-space dict.

Precision contract: on TPU the kernel computes in f32 (MXU-native); under
interpret mode it keeps the caller's dtype, so the CI parity tests run the
exact f64 math of the XLA serving path.

Differentiation: none — prediction is a forward-only path (the serving
discipline), so unlike ``reg_stats``/``psi_stats`` there is no
``custom_vjp`` here.  Anything that needs gradients through a prediction
(e.g. the GPLVM reconstruction inner loop) uses the XLA
``serve.posterior.predict_mean_var`` instead.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ...core import gp_kernels as gpk
from .._common import on_tpu as _on_tpu
from .._common import pad_to as _pad_to
from . import kernel as _k


@functools.partial(jax.jit, static_argnames=("block_t", "block_m", "interpret",
                                             "compute_dtype"))
def predict_stats(hyp: dict, z, a_mean, g, x, block_t: int = 128,
                  block_m: int = 64, interpret: bool | None = None,
                  compute_dtype=None):
    """Fused serving statistics via the Pallas kernel.

    Returns ``(mean, quad)``: ``ksm @ a_mean`` (t, d) and
    ``rowsum((ksm @ g) * ksm)`` (t,) — without materialising ``ksm`` in HBM.

    ``compute_dtype`` pins the tile dtype (the serving engines pass their
    accumulation width so quantized bf16/f16 states run f32 tiles rather
    than half-precision arithmetic); ``None`` keeps the historical default.
    """
    interpret = (not _on_tpu()) if interpret is None else interpret
    t, d = x.shape[0], a_mean.shape[1]
    if compute_dtype is not None:
        # Caller-pinned width, clamped to what the backend runs: sub-f32
        # never reaches the tiles, and on TPU (non-interpret) the MXU
        # precision contract stays f32 even for an f64 request.
        dt = jnp.dtype(compute_dtype)
        if dt.itemsize < 4 or (not interpret and dt.itemsize > 4):
            dt = jnp.dtype(jnp.float32)
    else:
        # f32 on the MXU; caller dtype (f64 in this repo) under interpret.
        dt = x.dtype if interpret else jnp.float32
    inv_ell2 = jnp.exp(-2.0 * hyp["log_ell"]).astype(dt)[None, :]   # (1, q)
    sf2 = jnp.exp(hyp["log_sf2"]).astype(dt)[None, None]            # (1, 1)

    pad8 = 8
    inv_p = _pad_to(inv_ell2, pad8, 1, value=1.0)
    z_p = _pad_to(_pad_to(z.astype(dt), pad8, 1), block_m, 0)
    x_p = _pad_to(_pad_to(x.astype(dt), pad8, 1), block_t, 0)
    a_p = _pad_to(_pad_to(a_mean.astype(dt), pad8, 1), block_m, 0)
    g_p = _pad_to(_pad_to(g.astype(dt), block_m, 0), block_m, 1)

    mean, quad = _k.predict_pallas(inv_p, sf2, z_p, x_p, a_p, g_p,
                                   block_t=block_t, block_m=block_m,
                                   interpret=interpret)
    return mean[:t, :d], quad[:t, 0]


def predict_fn_for_engine(block_t: int = 128, block_m: int = 64,
                          compute_dtype=None, kernel=None):
    """Adapter matching serve.engine's per-block fn: (state, x) -> (mean, var).

    ``compute_dtype`` threads the engine's accumulation width into the tile
    dtype (see :func:`predict_stats`); outputs are returned in the query
    dtype either way.

    Dispatch shim for the compositional kernel layer: the fused Pallas
    kernel evaluates the SE-ARD cross-covariance in its tiles, so the
    full-width SE-ARD expression (the default) gets the fast path; any
    other expression falls back to the XLA serving math
    (``serve.posterior.predict_mean_var``) — same per-block contract.
    """
    from ...core.covariance import as_kernel, is_fused_se

    kernel = as_kernel(kernel)
    cdt = None if compute_dtype is None else jnp.dtype(compute_dtype)

    if not is_fused_se(kernel):
        def fn(state, x):
            from ...serve.posterior import predict_mean_var
            mean, var = predict_mean_var(state, x)
            return mean.astype(x.dtype), var.astype(x.dtype)

        return fn

    def fn(state, x):
        mean, quad = predict_stats(state.hyp, state.z, state.a_mean, state.g,
                                   x, block_t=block_t, block_m=block_m,
                                   compute_dtype=cdt)
        var = gpk.se_kdiag(state.hyp, x) - quad
        return mean.astype(x.dtype), var.astype(x.dtype)

    return fn
