"""Pallas TPU kernels for the paper-optimised hot spots.

psi_stats        — the paper's Map-step (O(n m^2 q)) as MXU matmuls
flash_attention  — streaming-softmax attention for LM prefill
Each package: kernel.py (pl.pallas_call + BlockSpec), ops.py (jit wrapper,
padding, backend select), ref.py (pure-jnp oracle).
"""
