"""Pallas TPU kernels for the paper-optimised hot spots.

psi_stats        — the GPLVM Map-step (O(n m^2 q) psi2/psi1) as MXU matmuls
reg_stats        — the regression Map-step: knm eval + b/C/D contractions
                   fused in one VMEM pass
predict          — the serving step: ksm eval + mean/var contractions fused
                   in one VMEM pass (forward-only, no custom_vjp)
flash_attention  — streaming-softmax attention for LM prefill
Each package: kernel.py (pl.pallas_call + BlockSpec), ops.py (jit wrapper,
padding, backend select, custom_vjp where grads are needed), ref.py
(pure-jnp oracle).
See docs/kernels.md for the shared tiling contract.
"""
