"""Helpers shared by the kernel packages' ops wrappers (the tiling
contract's padding + backend selection — see docs/kernels.md)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def pad_to(x, mult, axis, value=0.0):
    """Pad ``axis`` up to a multiple of ``mult`` with ``value`` (neutral
    padding — the caller picks the value that contributes zero)."""
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)
