"""Pallas TPU kernel for the fused regression map step — the SGPR hot path.

The regression map (`stats.partial_stats`, ``s is None``) needs three
statistics of the kernel slab ``knm = k(X, Z)``:

    b = Sum_i w_i k_ii          ()        (psi0 sum; sf2 * Sum w for SE)
    C = knm^T (w . Y)           (m, d)
    D = (knm . w)^T knm         (m, m)

A mechanical XLA lowering materialises the full (n, m) slab in HBM and
re-reads it for each contraction — three round trips of O(n m) bytes. This
kernel evaluates ``knm`` tile-by-tile in VMEM and folds all three statistics
in the same grid pass, so the slab never exists outside VMEM.

The ARD exponent uses the psi-stats refactoring trick one order lower than
psi2: with ``inv_q = 1/ell_q^2``,

    E[i, a] = -1/2 Sum_q (x_iq - z_aq)^2 inv_q
            = alpha_i + M_i. @ Zc.a,
    alpha_i = -1/2 Sum_q x_iq^2 inv_q
    M       = [x * inv, -inv/2]           (n, 2q)
    Zc      = [z; z^2] (per column a)     (2q, m)

so each tile is one MXU matmul + exp, and the contractions are two more MXU
matmuls ((bm, bn) @ (bn, bm) and (bm, bn) @ (bn, d)).

Grid (a_tiles, b_tiles, n_tiles), n innermost so every output block's
reduction visits are consecutive (the revolving-accumulator contract):
  D block (a, b) accumulates over n;
  C block (a, 0) accumulates only on the b == 0 sweep;
  b_stat (1, 1)  accumulates only on the a == b == 0 sweep.

Tiling contract (enforced/padded by ops.py):
  n % block_n == 0, m % block_m == 0, q and d padded to multiples of 8.
  Padding is NEUTRAL: padded latent dims carry x=z=0, inv_ell2=1 (zero
  exponent contribution); padded data rows carry w=0 (zero weight kills all
  three statistics); padded y columns are 0; padded inducing rows are
  sliced off the outputs.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _reg_stats_kernel(inv_ref, sf2_ref, za_ref, zb_ref, x_ref, y_ref, w_ref,
                      b_ref, c_ref, d_ref):
    a_i = pl.program_id(0)
    b_i = pl.program_id(1)
    k = pl.program_id(2)
    first_b = b_i == 0
    first_ab = jnp.logical_and(a_i == 0, first_b)

    @pl.when(jnp.logical_and(first_ab, k == 0))
    def _init_b():
        b_ref[...] = jnp.zeros_like(b_ref)

    @pl.when(jnp.logical_and(first_b, k == 0))
    def _init_c():
        c_ref[...] = jnp.zeros_like(c_ref)

    @pl.when(k == 0)
    def _init_d():
        d_ref[...] = jnp.zeros_like(d_ref)

    inv = inv_ref[0, :]                                       # (q,)
    sf2 = sf2_ref[0, 0]
    x = x_ref[...]                                            # (bn, q)
    w = w_ref[...]                                            # (bn, 1)

    alpha = -0.5 * jnp.sum(x * x * inv[None, :], axis=1)      # (bn,)
    m_mat = jnp.concatenate(
        [x * inv[None, :],
         jnp.broadcast_to(-0.5 * inv[None, :], x.shape)], axis=1)  # (bn, 2q)

    def k_tile(z):                                            # (bm, q) -> (bn, bm)
        zc = jnp.concatenate([z, z * z], axis=1).T            # (2q, bm)
        e = alpha[:, None] + jax.lax.dot(
            m_mat, zc, precision=jax.lax.Precision.HIGHEST)
        return sf2 * jnp.exp(e)

    ka = k_tile(za_ref[...])
    kb = k_tile(zb_ref[...])

    d_ref[...] += jax.lax.dot((ka * w).T, kb,
                              precision=jax.lax.Precision.HIGHEST)

    @pl.when(first_b)
    def _acc_c():
        c_ref[...] += jax.lax.dot(ka.T, w * y_ref[...],
                                  precision=jax.lax.Precision.HIGHEST)

    @pl.when(first_ab)
    def _acc_b():
        b_ref[0, 0] += sf2 * jnp.sum(w)


def reg_stats_pallas(inv_ell2, sf2, z, x, y, w, *, block_n=128, block_m=64,
                     interpret=False):
    """Fused (b, C, D) regression statistics. All inputs pre-padded (ops.py).

    inv_ell2: (1, q); sf2: (1, 1); z: (m, q); x: (n, q); y: (n, d); w: (n, 1).
    Returns (b (1, 1), C (m, d), D (m, m)) in the input dtype.
    """
    n, q = x.shape
    m = z.shape[0]
    d = y.shape[1]
    assert n % block_n == 0 and m % block_m == 0
    dt = x.dtype
    grid = (m // block_m, m // block_m, n // block_n)
    return pl.pallas_call(
        _reg_stats_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, q), lambda a, b, k: (0, 0)),            # inv_ell2
            pl.BlockSpec((1, 1), lambda a, b, k: (0, 0)),            # sf2
            pl.BlockSpec((block_m, q), lambda a, b, k: (a, 0)),      # z_a
            pl.BlockSpec((block_m, q), lambda a, b, k: (b, 0)),      # z_b
            pl.BlockSpec((block_n, q), lambda a, b, k: (k, 0)),      # x
            pl.BlockSpec((block_n, d), lambda a, b, k: (k, 0)),      # y
            pl.BlockSpec((block_n, 1), lambda a, b, k: (k, 0)),      # w
        ],
        out_specs=[
            pl.BlockSpec((1, 1), lambda a, b, k: (0, 0)),            # b
            pl.BlockSpec((block_m, d), lambda a, b, k: (a, 0)),      # C
            pl.BlockSpec((block_m, block_m), lambda a, b, k: (a, b)),  # D
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, 1), dt),
            jax.ShapeDtypeStruct((m, d), dt),
            jax.ShapeDtypeStruct((m, m), dt),
        ],
        interpret=interpret,
    )(inv_ell2, sf2, z, z, x, y, w)
