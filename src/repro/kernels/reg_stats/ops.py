"""jit'd public wrapper around the fused regression-stats Pallas kernel.

Handles padding to tile boundaries (all pads are NEUTRAL — padded latent
dims carry x=z=0, inv_ell2=1; padded data rows carry w=0; padded y columns
are 0; padded inducing rows are sliced off the outputs), backend selection
(interpret=True off-TPU), and the hyper-parameter plumbing from the core
library's log-space dict.

Precision contract: on TPU the kernel computes in f32 (MXU-native); under
interpret mode it keeps the caller's dtype, so the CI parity tests run the
exact f64 math of the XLA path.

Differentiation: ``pallas_call`` has no VJP on this JAX version, so the op
carries a ``custom_vjp`` — forward is the fused kernel, backward recomputes
the (block, m) slab with the same XLA ops as the monolithic path
(``stats.partial_stats``'s ``s is None`` branch). Under the chunked map the
op sees block-sized operands, so the backward's slab stays O(block * m).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ...core.stats import reg_stats_dense
from .._common import on_tpu as _on_tpu
from .._common import pad_to as _pad_to
from . import kernel as _k


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2))
def _reg_stats(block_n, block_m, interpret, hyp, z, x, y, w):
    return _fwd_impl(block_n, block_m, interpret, hyp, z, x, y, w)


def _fwd_impl(block_n, block_m, interpret, hyp, z, x, y, w):
    m, d = z.shape[0], y.shape[1]
    # f32 on the MXU; caller dtype (f64 in this repo) under interpret.
    dt = x.dtype if interpret else jnp.float32
    inv_ell2 = jnp.exp(-2.0 * hyp["log_ell"]).astype(dt)[None, :]   # (1, q)
    sf2 = jnp.exp(hyp["log_sf2"]).astype(dt)[None, None]            # (1, 1)

    pad8 = 8
    inv_p = _pad_to(inv_ell2, pad8, 1, value=1.0)
    z_p = _pad_to(_pad_to(z.astype(dt), pad8, 1), block_m, 0)
    x_p = _pad_to(_pad_to(x.astype(dt), pad8, 1), block_n, 0)
    y_p = _pad_to(_pad_to(y.astype(dt), pad8, 1), block_n, 0)
    w_p = _pad_to(w.astype(dt)[:, None], block_n, 0)

    b, c, d_stat = _k.reg_stats_pallas(inv_p, sf2, z_p, x_p, y_p, w_p,
                                       block_n=block_n, block_m=block_m,
                                       interpret=interpret)
    return b[0, 0], c[:m, :d], d_stat[:m, :m]


def _vjp_fwd(block_n, block_m, interpret, hyp, z, x, y, w):
    out = _fwd_impl(block_n, block_m, interpret, hyp, z, x, y, w)
    return out, (hyp, z, x, y, w)


def _vjp_bwd(block_n, block_m, interpret, res, cts):
    del block_n, block_m, interpret
    out, vjp = jax.vjp(reg_stats_dense, *res)
    # Forward may have run in f32 (TPU); match the reference dtypes.
    cts = tuple(jnp.asarray(c, o.dtype) for c, o in zip(cts, out))
    return vjp(cts)


_reg_stats.defvjp(_vjp_fwd, _vjp_bwd)


@functools.partial(jax.jit, static_argnames=("block_n", "block_m", "interpret"))
def reg_stats(hyp: dict, z, x, y, w, block_n: int = 128, block_m: int = 64,
              interpret: bool | None = None):
    """Fused regression map statistics via the Pallas kernel.

    Returns ``(b, C, D)``: the psi0 sum (), ``knm^T (w . Y)`` (m, d) and
    ``(knm . w)^T knm`` (m, m) — without materialising ``knm`` in HBM.
    """
    interpret = (not _on_tpu()) if interpret is None else interpret
    return _reg_stats(block_n, block_m, interpret, hyp, z, x, y, w)


def reg_stats_fn_for_engine(block_n: int = 128, block_m: int = 64,
                            kernel=None):
    """Adapter matching core.stats.partial_stats(reg_stats_fn=...) signature.

    Dispatch shim for the compositional kernel layer: the fused Pallas
    kernel is specialised to the full-width SE-ARD covariance, so that
    expression (the default) gets the fast path; any other expression gets
    a generic XLA fallback with identical signature and semantics (parity
    asserted in tests/test_kernel_zoo.py).
    """
    from ...core.covariance import as_kernel, is_fused_se

    kernel = as_kernel(kernel)
    if is_fused_se(kernel):
        def fn(hyp, z, x, y, w):
            return reg_stats(hyp, z, x, y, w, block_n=block_n,
                             block_m=block_m)
    else:
        def fn(hyp, z, x, y, w):
            return reg_stats_dense(hyp, z, x, y, w, kernel=kernel)

    return fn
