"""Pure-jnp oracle for the fused regression-statistics kernel.

Independent of both the Pallas code path and ``repro.core.gp_kernels``;
states the three statistics directly from the SE-ARD kernel definition.
"""
from __future__ import annotations

import jax.numpy as jnp


def reg_stats_ref(log_sf2, log_ell, z, x, y, w):
    """(b (), C (m, d), D (m, m)) of the weighted regression map step."""
    ell = jnp.exp(log_ell)
    sf2 = jnp.exp(log_sf2)
    d = x[:, None, :] / ell - z[None, :, :] / ell
    knm = sf2 * jnp.exp(-0.5 * jnp.sum(d * d, axis=-1))       # (n, m)
    b = sf2 * jnp.sum(w)                                      # k_ii = sf2 (SE)
    c = knm.T @ (w[:, None] * y)
    d_stat = (knm * w[:, None]).T @ knm
    return b, c, d_stat
