from . import kernel, ops, ref
from .ops import reg_stats, reg_stats_fn_for_engine

__all__ = ["kernel", "ops", "ref", "reg_stats", "reg_stats_fn_for_engine"]
