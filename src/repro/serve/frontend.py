"""Async micro-batching serving front-end: requests in, engine blocks out.

The engines (:mod:`serve.engine`) are libraries — they answer one padded
batch per call.  A deployment faces the opposite shape: many concurrent
requests of arbitrary size that must become the engine's fixed
``block_size`` batches without any request waiting behind a full rescan.
:class:`Frontend` is that layer:

  * **Continuous micro-batching** — a single dispatch loop pulls requests
    off a bounded queue and coalesces them until the batch is full
    (``max_batch_rows``, rounded up to the engine's
    ``n_shards * block_size`` padding multiple) or the oldest request has
    waited ``max_wait_ms``, then flushes.  Requests are concatenated raw
    and padded **once** by ``engine.pad_queries`` — nothing already padded
    is ever re-padded, and predictions are row-local, so each response is
    bitwise what a direct ``engine.predict`` call returns for that request
    (property-tested in tests/test_frontend.py).
  * **Admission control & deadlines** — a full queue rejects at submit
    with :class:`QueueFull` (backpressure, the open-loop-honest failure
    mode); a request whose deadline passes before dispatch fails fast with
    :class:`SLOExceeded` and never occupies engine time.  A request that
    was dispatched in time but finished late is still answered — flagged
    ``late`` in the metrics, never dropped.
  * **SLO accounting** — every request feeds the constant-memory
    :class:`~repro.serve.slo.SLOMetrics` (wait / engine / e2e sketches);
    per-flush engine wall times also feed a
    :class:`~repro.distributed.fault.StepTimer`, so serving flushes report
    the same min/mean/max load summary the training loop uses.
  * **Zero-downtime hot swap** — :meth:`Frontend.swap_state` atomically
    replaces the engine's state (or one slot of a
    :class:`~repro.serve.engine.MultiPredictEngine` fleet) while requests
    are in flight.  The fence is a ``(generation, compute_state, noise)``
    tuple read once per flush: in-flight batches complete against the
    state they were dispatched with, every response carries the generation
    it was served under, and no request is ever dropped by a swap.

The engine call runs in a worker thread (``run_in_executor``) so the event
loop keeps accepting requests while XLA computes.  All request-path methods
(``submit``/``start``/``stop``) belong to one event loop; ``swap_state``
may be called from any thread (the fence tuple is replaced atomically).
"""
from __future__ import annotations

import asyncio
import pathlib
import time
from dataclasses import dataclass
from typing import NamedTuple

import numpy as np

from ..distributed.fault import StepTimer
from .engine import MultiPredictEngine, PredictEngine
from .posterior import PredictiveState, load_state
from .slo import SLOMetrics


class FrontendError(RuntimeError):
    """Base class for front-end request failures."""


class QueueFull(FrontendError):
    """Admission control: the bounded request queue cannot take this
    request now — retry with backoff or shed load upstream."""


class SLOExceeded(FrontendError):
    """The request's deadline expired before it could be dispatched; it
    was failed fast (no engine time spent) — never silently dropped."""


class ServeResult(NamedTuple):
    """One answered request.  ``mean``/``var`` are numpy, shaped exactly as
    ``engine.predict`` returns for this request's rows ((t, d)/(t,) single
    model; (N, t, d)/(N, t) fleet).  ``generation`` is the hot-swap fence
    value the serving state carried when this batch was dispatched."""

    mean: np.ndarray
    var: np.ndarray
    generation: int


@dataclass
class _Request:
    x: np.ndarray
    include_noise: bool
    enqueue: float            # monotonic seconds
    deadline: float | None    # monotonic seconds, absolute
    future: asyncio.Future


_CLOSE = object()   # queue sentinel: drain and stop


class Frontend:
    """Continuous micro-batching front-end over a predict engine.

    Args:
      engine: a :class:`PredictEngine` or :class:`MultiPredictEngine`.
      max_batch_rows: flush as soon as a batch holds this many rows
        (rounded up to the engine's ``n_shards * block_size`` padding
        multiple, so a full flush is pad-free).  A hard cap: a request
        that would push past it heads the next batch instead — only a
        single request larger than the cap ever exceeds it (it flushes
        alone, on a batch shape :meth:`warmup` did not pre-compile).
        Default: one padding multiple.
      max_wait_ms: flush no later than this after the *oldest* queued
        request arrived — the latency/throughput knob (0 dispatches every
        request immediately).
      max_queue_rows: admission bound on rows accepted but not yet
        dispatched; beyond it ``submit`` raises :class:`QueueFull`.
      max_batch_requests: optional cap on requests per flush (1 = the
        naive per-request baseline the benchmark compares against).
      default_deadline_ms: deadline applied when ``submit`` passes none
        (``None`` = no deadline).
      metrics / timer: bring-your-own :class:`SLOMetrics` /
        :class:`StepTimer` (e.g. shared across front-ends); fresh ones by
        default.
    """

    def __init__(self, engine: PredictEngine | MultiPredictEngine, *,
                 max_batch_rows: int | None = None, max_wait_ms: float = 2.0,
                 max_queue_rows: int = 65536,
                 max_batch_requests: int | None = None,
                 default_deadline_ms: float | None = None,
                 metrics: SLOMetrics | None = None,
                 timer: StepTimer | None = None):
        if max_wait_ms < 0:
            raise ValueError(f"max_wait_ms must be >= 0, got {max_wait_ms}")
        if max_queue_rows < 1:
            raise ValueError(
                f"max_queue_rows must be >= 1, got {max_queue_rows}")
        if max_batch_requests is not None and max_batch_requests < 1:
            raise ValueError(
                f"max_batch_requests must be >= 1, got {max_batch_requests}")
        self.engine = engine
        self._multi = isinstance(engine, MultiPredictEngine)
        self._row_mult = engine.block_size * engine.n_shards
        if max_batch_rows is None:
            max_batch_rows = self._row_mult
        if max_batch_rows < 1:
            raise ValueError(
                f"max_batch_rows must be >= 1, got {max_batch_rows}")
        # Round up to the padding multiple: a "full" batch never pads.
        self.max_batch_rows = -(-max_batch_rows // self._row_mult) * self._row_mult
        self.max_wait = max_wait_ms / 1e3
        self.max_queue_rows = max_queue_rows
        self.max_batch_requests = max_batch_requests
        self.default_deadline = (None if default_deadline_ms is None
                                 else default_deadline_ms / 1e3)
        self.metrics = metrics if metrics is not None else SLOMetrics()
        self.timer = timer if timer is not None else StepTimer()
        self._np_dtype = np.dtype(engine.compute_dtype)
        self._q = engine.state.z.shape[-1]
        self._d = engine.state.c2.shape[-1]
        self._queue: asyncio.Queue = asyncio.Queue()
        self._queued_rows = 0
        self._generation = 0
        # The hot-swap fence: replaced as ONE tuple so a flush that reads it
        # once can never pair an old generation with a new state (or the
        # wrong generation's noise term).
        self._current = (0, engine.compute_state,
                         self._noise_of(engine.compute_state))
        self._task: asyncio.Task | None = None
        self._closed = False

    # -- lifecycle ----------------------------------------------------------
    def start(self) -> "Frontend":
        """Start the dispatch loop on the running event loop (idempotent)."""
        if self._task is None:
            self._closed = False
            self._task = asyncio.get_running_loop().create_task(
                self._dispatch_loop(), name="serve-frontend-dispatch")
        return self

    async def stop(self) -> None:
        """Drain — every accepted request is flushed and answered — then
        stop the dispatch loop.  ``start`` may be called again after."""
        if self._task is None:
            return
        self._closed = True          # reject new submits while draining
        self._queue.put_nowait(_CLOSE)
        await self._task
        self._task = None

    async def __aenter__(self) -> "Frontend":
        return self.start()

    async def __aexit__(self, *exc) -> None:
        await self.stop()

    def warmup(self) -> int:
        """Pre-compile every padded batch shape the dispatch loop can
        produce (each multiple of the padding multiple up to
        ``max_batch_rows``).  The jitted block scan specialises on the
        padded row count, so without warmup the first flush at each new
        size pays its XLA compile mid-traffic — enough to blow a
        millisecond-scale SLO for everything queued behind it.  Blocking;
        call before taking load.  Returns the number of shapes compiled."""
        cstate = self._current[1]
        n = 0
        for rows in range(self._row_mult, self.max_batch_rows + 1,
                          self._row_mult):
            self._run_batch(cstate, np.zeros((rows, self._q), self._np_dtype))
            n += 1
        return n

    # -- the request path ---------------------------------------------------
    @property
    def generation(self) -> int:
        """The hot-swap fence: bumped by every :meth:`swap_state`."""
        return self._generation

    @property
    def queued_rows(self) -> int:
        """Rows accepted but not yet dispatched (the admission meter)."""
        return self._queued_rows

    def load_summary(self) -> dict:
        """Per-flush engine-time min/mean/max + straggler overhead — the
        same ``StepTimer`` summary the training loop reports."""
        return self.timer.summary()

    async def submit(self, x, *, include_noise: bool = False,
                     deadline_ms: float | None = None) -> ServeResult:
        """Enqueue one request of ``(t, q)`` queries (a 1-d ``(q,)`` array
        is one row) and await its :class:`ServeResult`.

        Raises :class:`QueueFull` immediately when admission fails and
        :class:`SLOExceeded` when the deadline passes before dispatch.
        """
        if self._task is None or self._closed:
            raise FrontendError(
                "Frontend is not running — use `async with Frontend(...)` "
                "or call start() first" if self._task is None
                else "Frontend is draining — no new requests")
        x = np.asarray(x, self._np_dtype)
        if x.ndim == 1:
            x = x[None, :]
        if x.ndim != 2 or x.shape[1] != self._q:
            raise ValueError(
                f"expected queries of shape (t, {self._q}), got {x.shape}")
        t = x.shape[0]
        if t == 0:
            # An empty request is answered inline: nothing to batch.
            gen = self._current[0]
            if self._multi:
                n = self.engine.n_models
                return ServeResult(np.zeros((n, 0, self._d), self._np_dtype),
                                   np.zeros((n, 0), self._np_dtype), gen)
            return ServeResult(np.zeros((0, self._d), self._np_dtype),
                               np.zeros((0,), self._np_dtype), gen)
        if self._queued_rows + t > self.max_queue_rows:
            self.metrics.observe_reject_queue_full()
            raise QueueFull(
                f"request of {t} rows rejected: {self._queued_rows} of "
                f"{self.max_queue_rows} queue rows already in use")
        now = time.monotonic()
        dl = deadline_ms / 1e3 if deadline_ms is not None else self.default_deadline
        req = _Request(x=x, include_noise=include_noise, enqueue=now,
                       deadline=None if dl is None else now + dl,
                       future=asyncio.get_running_loop().create_future())
        self._queued_rows += t
        self.metrics.observe_admit()
        self._queue.put_nowait(req)
        return await req.future

    # -- hot swap -----------------------------------------------------------
    def swap_state(self, state_or_path, slot: int | None = None) -> int:
        """Atomically replace the served state while requests are in flight;
        returns the new generation (the fence value responses will carry).

        ``state_or_path`` is a :class:`PredictiveState` or a checkpoint path
        (restored via the dtype-tagged sidecar, ``serve.load_state`` — a
        rollout host needs no model code).  ``slot`` selects one model of a
        :class:`MultiPredictEngine` fleet (``swap_slot``); ``None`` replaces
        the whole state.  In-flight batches complete against the state they
        were dispatched with — the dispatch loop reads the
        ``(generation, state)`` fence once per flush — so no response ever
        mixes generations and no request is dropped by a swap.
        """
        state = state_or_path
        if isinstance(state, (str, pathlib.Path)):
            state, _ = load_state(state)
        if slot is None:
            self.engine.swap_state(state)
        else:
            if not self._multi:
                raise ValueError(
                    "slot= is only meaningful for a MultiPredictEngine fleet")
            self.engine.swap_slot(slot, state)
        self._generation += 1
        cstate = self.engine.compute_state
        self._current = (self._generation, cstate, self._noise_of(cstate))
        return self._generation

    # -- the dispatch loop --------------------------------------------------
    async def _dispatch_loop(self) -> None:
        q = self._queue
        draining = False
        held: _Request | None = None     # dequeued but didn't fit last batch
        while True:
            if held is not None:
                req, held = held, None
            elif draining:
                if q.empty():
                    break
                req = q.get_nowait()
            else:
                req = await q.get()
            if req is _CLOSE:
                draining = True
                continue
            batch = [req]
            rows = req.x.shape[0]
            flush_by = req.enqueue + self.max_wait
            while rows < self.max_batch_rows and (
                    self.max_batch_requests is None
                    or len(batch) < self.max_batch_requests):
                if not q.empty():
                    # Greedy drain: whatever is already queued coalesces
                    # into this batch at zero extra latency — under backlog
                    # the batcher must not flush singletons just because
                    # the oldest request's wait budget is spent.
                    nxt = q.get_nowait()
                elif draining:
                    break
                else:
                    delay = flush_by - time.monotonic()
                    if delay <= 0:
                        break
                    try:
                        nxt = await asyncio.wait_for(q.get(), timeout=delay)
                    except asyncio.TimeoutError:
                        break
                if nxt is _CLOSE:
                    draining = True
                    continue
                if rows + nxt.x.shape[0] > self.max_batch_rows:
                    # Would overshoot the batch bound (and land on a batch
                    # shape warmup never compiled) — it heads the next batch
                    # instead.  Only a request alone may exceed the bound.
                    held = nxt
                    break
                batch.append(nxt)
                rows += nxt.x.shape[0]
            await self._flush(batch)

    async def _flush(self, batch: list[_Request]) -> None:
        now = time.monotonic()
        live = []
        for r in batch:
            self._queued_rows -= r.x.shape[0]
            if r.future.cancelled():
                self.metrics.observe_cancelled()
                continue
            if r.deadline is not None and now > r.deadline:
                self.metrics.observe_expired()
                r.future.set_exception(SLOExceeded(
                    f"deadline expired {1e3 * (now - r.deadline):.2f} ms "
                    f"before dispatch (waited "
                    f"{1e3 * (now - r.enqueue):.2f} ms in queue)"))
                continue
            live.append(r)
        if not live:
            return                       # a zero-row flush is a no-op
        gen, cstate, noise = self._current   # the hot-swap fence, read ONCE
        for r in live:
            self.metrics.observe_wait(now - r.enqueue)
        xcat = np.concatenate([r.x for r in live], axis=0)
        rows = xcat.shape[0]
        pad_rows = (-rows) % self._row_mult
        t0 = time.perf_counter()
        mean, var = await asyncio.get_running_loop().run_in_executor(
            None, self._run_batch, cstate, xcat)
        engine_s = time.perf_counter() - t0
        self.timer.record([engine_s])
        self.metrics.observe_flush(len(live), rows, pad_rows, engine_s)
        done = time.monotonic()
        lo = 0
        for r in live:
            hi = lo + r.x.shape[0]
            m_i, v_i = mean[..., lo:hi, :], var[..., lo:hi]
            lo = hi
            if r.include_noise:
                v_i = v_i + noise
            if not r.future.cancelled():
                r.future.set_result(ServeResult(m_i, v_i, gen))
            late = r.deadline is not None and done > r.deadline
            self.metrics.observe_complete(done - r.enqueue, late=late)

    def _run_batch(self, cstate, xcat: np.ndarray):
        """Worker-thread body: pad once, run the jitted block scan against
        the fenced state snapshot, slice the pad off, pull to host.

        The padding is plain numpy and the engine is entered through ONE
        jitted call + one ``device_get`` — every un-jitted jax op in here
        is a GIL release/re-acquire, and under load each re-acquire can
        wait a full switch interval behind the busy event-loop thread, so
        op count in this thread is latency, not style.  (Sharded engines
        keep the ``pad_queries`` path: their pad must also place shards.)
        """
        import jax

        t = xcat.shape[0]
        if self.engine.mesh is not None:
            xq, _ = self.engine.pad_queries(xcat)
        else:
            pad = (-t) % self._row_mult
            if pad:
                xq = np.zeros((t + pad, xcat.shape[1]), xcat.dtype)
                xq[:t] = xcat
            else:
                xq = xcat
        mean, var = jax.device_get(self.engine.run_blocks(xq, cstate))
        return mean[..., :t, :], var[..., :t]

    def _noise_of(self, cstate) -> np.ndarray:
        """1/beta from a state snapshot — the same values the engine's
        ``include_noise`` adds, so noisy responses stay bitwise too.
        Computed once per generation (at fence build), never per flush."""
        import jax.numpy as jnp

        nv = np.asarray(jnp.exp(-cstate.hyp["log_beta"]))
        return nv[..., None] if self._multi else nv
