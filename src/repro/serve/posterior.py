"""Frozen predictive state — the training→serving handoff.

The paper's re-parametrisation means that after the map-reduce over data,
*everything* a prediction needs is a constant-size function of the reduced
statistics: the kernel hyper-parameters, the inducing inputs Z, and the
factors of the optimal q(u).  None of it depends on the query.  A server
therefore never has to see training data — it loads a
:class:`PredictiveState` and answers queries with matmuls only.

:func:`extract_state` performs every query-independent solve exactly once:

    L  = chol(Kmm)                       (the ``optimal_qu`` factors)
    LB = chol(I + b L^-1 D L^-T)         (whitened chol(Sigma), Sigma=Kmm+bD)
    c2 = LB^-1 L^-1 C                    (the q(u) mean solve)

and then folds them into two *serving contractions* so the per-query hot
path (``serve.engine``, ``kernels/predict``) contains no triangular solves
at all:

    a_mean = b L^-T LB^-T c2             (m, d)   mean = K*m @ a_mean
    g      = Kmm^-1 - Sigma^-1           (m, m)   var  = k** - rowsum((K*m @ g) * K*m)

Both forms are algebraically identical to ``core.bound.predict`` (which
re-derives them from ``QU`` per call); parity is tested to f64 precision in
``tests/test_serving.py``.

``save``/``load`` go through the existing checkpoint layer
(``repro.checkpoint``), so a serving process can start from an ``.npz`` +
sidecar pair without importing any training machinery state.
"""
from __future__ import annotations

import functools
import pathlib
from typing import NamedTuple

import jax
import jax.numpy as jnp
import jax.scipy.linalg as jsl

from .. import checkpoint as ckpt
from ..core import gp_kernels as gpk
from ..core.bound import DEFAULT_JITTER, _chol_kmm
from ..core.stats import Stats

Array = jax.Array


class PredictiveState(NamedTuple):
    """Everything prediction needs, none of it query-dependent.

    A frozen pytree: jit-traceable, psum/device_put-able, checkpointable.
    ``chol_kmm``/``chol_sigma``/``c2`` are the raw q(u) factors (kept so the
    state can reconstruct ``optimal_qu`` quantities, e.g. for posterior
    sampling); ``a_mean``/``g`` are the precomputed serving contractions the
    engines actually use per query.
    """

    hyp: dict          # {"log_sf2": (), "log_ell": (q,), "log_beta": ()}
    z: Array           # (m, q) inducing inputs
    chol_kmm: Array    # (m, m) L = chol(Kmm + jitter)
    chol_sigma: Array  # (m, m) LB = chol(I + b L^-1 D L^-T)
    c2: Array          # (m, d) LB^-1 L^-1 C (whitened info vector)
    a_mean: Array      # (m, d) b L^-T LB^-T c2
    g: Array           # (m, m) Kmm^-1 - Sigma^-1 (PSD explained-variance)

    @property
    def m(self) -> int:
        return self.z.shape[0]

    @property
    def q(self) -> int:
        return self.z.shape[1]

    @property
    def d(self) -> int:
        return self.c2.shape[1]


@functools.partial(jax.jit, static_argnames=())
def extract_state(hyp: dict, z: Array, stats: Stats,
                  jitter: float = DEFAULT_JITTER) -> PredictiveState:
    """One-time extraction: all query-independent factorizations and solves.

    Same math as ``core.bound.optimal_qu`` plus the two serving
    contractions.  O(m^3) once; afterwards every predict is O(t m (m + d)).
    """
    beta = jnp.exp(hyp["log_beta"])
    m = z.shape[0]
    L = _chol_kmm(hyp, z, jitter)
    LiD = jsl.solve_triangular(L, stats.D, lower=True)
    W = jsl.solve_triangular(L, LiD.T, lower=True).T
    Bmat = jnp.eye(m, dtype=z.dtype) + beta * W
    LB = jnp.linalg.cholesky(Bmat)
    LiC = jsl.solve_triangular(L, stats.C, lower=True)
    c2 = jsl.solve_triangular(LB, LiC, lower=True)

    eye = jnp.eye(m, dtype=z.dtype)
    Li = jsl.solve_triangular(L, eye, lower=True)        # L^-1
    LBi = jsl.solve_triangular(LB, eye, lower=True)      # LB^-1
    v1 = Li.T                                            # L^-T
    v2 = v1 @ LBi.T                                      # L^-T LB^-T
    a_mean = beta * (v2 @ c2)
    g = v1 @ v1.T - v2 @ v2.T                            # Kmm^-1 - Sigma^-1
    return PredictiveState(hyp=hyp, z=z, chol_kmm=L, chol_sigma=LB, c2=c2,
                           a_mean=a_mean, g=g)


def state_from_model(model) -> PredictiveState:
    """Extract from a fitted sequential model (``SGPR``/``BayesianGPLVM``):
    runs the model's exact map-reduce once for the reduced Stats, then
    :func:`extract_state`."""
    return extract_state(model.params["hyp"], model.params["z"],
                         model._stats(), jitter=model.jitter)


# -- query-side math (the XLA serving path; engine.py scans it per block) ---

def predict_mean_var(state: PredictiveState, xstar: Array):
    """Diag-variance predictive posterior at ``xstar`` (t, q) — matmuls only.

    Returns ``(mean (t, d), var (t,))`` — noise-free; callers add ``1/beta``
    for ``include_noise``.  Differentiable in ``xstar`` (plain jnp), which
    the GPLVM reconstruction path relies on.
    """
    ksm = gpk.ard_kernel(state.hyp, xstar, state.z)          # (t, m)
    mean = ksm @ state.a_mean
    quad = jnp.sum((ksm @ state.g) * ksm, axis=1)
    var = gpk.ard_kdiag(state.hyp, xstar) - quad
    return mean, var


def predict_full_cov(state: PredictiveState, xstar: Array):
    """Full predictive covariance: ``(mean (t, d), cov (t, t))``, noise-free.

    Cross-covariances couple every query pair, so this is computed in one
    piece rather than through the block engine — the small-t mode.
    """
    ksm = gpk.ard_kernel(state.hyp, xstar, state.z)
    mean = ksm @ state.a_mean
    kss = gpk.ard_kernel(state.hyp, xstar, xstar)
    cov = kss - ksm @ state.g @ ksm.T
    return mean, cov


# -- persistence (the existing checkpoint layer) ----------------------------

def save_state(path: str | pathlib.Path, state: PredictiveState,
               metadata: dict | None = None) -> pathlib.Path:
    """Atomic write via ``repro.checkpoint.save``; shape metadata rides in
    the sidecar so :func:`load_state` needs no template.  The keys
    ``m``/``q``/``d``/``dtype`` are reserved for that restore template —
    user ``metadata`` may not shadow them."""
    reserved = {"m", "q", "d", "dtype"}
    clash = reserved & set(metadata or ())
    if clash:
        raise ValueError(
            f"metadata keys {sorted(clash)} are reserved for the restore "
            "template — rename them")
    meta = {**(metadata or {}), "m": state.m, "q": state.q, "d": state.d,
            "dtype": str(state.z.dtype)}
    return ckpt.save(path, state, metadata=meta)


def load_state(path: str | pathlib.Path) -> tuple[PredictiveState, dict]:
    """Restore a :class:`PredictiveState` (plus user metadata) from disk.

    Builds the restore template from the sidecar's (m, q, d) — no model, no
    training data, no fitted object required on the serving host.
    """
    import json

    meta = json.loads(pathlib.Path(path).with_suffix(".json").read_text())
    md = meta["metadata"]
    m, q, d = md["m"], md["q"], md["d"]
    dt = jnp.dtype(md.get("dtype", "float64"))

    def sds(*shape):
        return jax.ShapeDtypeStruct(shape, dt)

    like = PredictiveState(
        hyp={"log_sf2": sds(), "log_ell": sds(q), "log_beta": sds()},
        z=sds(m, q), chol_kmm=sds(m, m), chol_sigma=sds(m, m),
        c2=sds(m, d), a_mean=sds(m, d), g=sds(m, m))
    state, md_out = ckpt.restore(path, like)
    return PredictiveState(*jax.tree.map(jnp.asarray, tuple(state))), md_out
