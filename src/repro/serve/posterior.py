"""Frozen predictive state — the training→serving handoff.

The paper's re-parametrisation means that after the map-reduce over data,
*everything* a prediction needs is a constant-size function of the reduced
statistics: the kernel hyper-parameters, the inducing inputs Z, and the
factors of the optimal q(u).  None of it depends on the query.  A server
therefore never has to see training data — it loads a
:class:`PredictiveState` and answers queries with matmuls only.

:func:`extract_state` performs every query-independent solve exactly once:

    L  = chol(Kmm)                       (the ``optimal_qu`` factors)
    LB = chol(I + b L^-1 D L^-T)         (whitened chol(Sigma), Sigma=Kmm+bD)
    c2 = LB^-1 L^-1 C                    (the q(u) mean solve)

and then folds them into two *serving contractions* so the per-query hot
path (``serve.engine``, ``kernels/predict``) contains no triangular solves
at all:

    a_mean = b L^-T LB^-T c2             (m, d)   mean = K*m @ a_mean
    g      = Kmm^-1 - Sigma^-1           (m, m)   var  = k** - rowsum((K*m @ g) * K*m)

Both forms are algebraically identical to ``core.bound.predict`` (which
re-derives them from ``QU`` per call); parity is tested to f64 precision in
``tests/test_serving.py``.

``save``/``load`` go through the existing checkpoint layer
(``repro.checkpoint``), so a serving process can start from an ``.npz`` +
sidecar pair without importing any training machinery state.
"""
from __future__ import annotations

import dataclasses
import functools
import pathlib

import jax
import jax.numpy as jnp
import jax.scipy.linalg as jsl

from .. import checkpoint as ckpt
from ..core import covariance as cov
from ..core.bound import DEFAULT_JITTER, _chol_kmm
from ..core.stats import Stats

Array = jax.Array


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class PredictiveState:
    """Everything prediction needs, none of it query-dependent.

    A frozen pytree: jit-traceable, psum/device_put-able, checkpointable.
    ``chol_kmm``/``chol_sigma``/``c2`` are the raw q(u) factors (kept so the
    state can reconstruct ``optimal_qu`` quantities, e.g. for posterior
    sampling); ``a_mean``/``g`` are the precomputed serving contractions the
    engines actually use per query.

    ``kernel`` is the covariance *expression* (``core.covariance``) — static
    pytree metadata, not an array leaf, so the flattened checkpoint layout
    is unchanged from the pre-compositional NamedTuple and old ``.npz``
    files keep loading.  It rides in the sidecar as a spec string; a server
    restores the right covariance with no model code.
    """

    hyp: dict          # kernel expression's log-space tree + {"log_beta"}
    z: Array           # (m, q) inducing inputs
    chol_kmm: Array    # (m, m) L = chol(Kmm + jitter)
    chol_sigma: Array  # (m, m) LB = chol(I + b L^-1 D L^-T)
    c2: Array          # (m, d) LB^-1 L^-1 C (whitened info vector)
    a_mean: Array      # (m, d) b L^-T LB^-T c2
    g: Array           # (m, m) Kmm^-1 - Sigma^-1 (PSD explained-variance)
    kernel: cov.Kernel = dataclasses.field(
        default=cov.SE_ARD, metadata=dict(static=True))

    @property
    def m(self) -> int:
        return self.z.shape[0]

    @property
    def q(self) -> int:
        return self.z.shape[1]

    @property
    def d(self) -> int:
        return self.c2.shape[1]

    @property
    def dtype(self) -> jnp.dtype:
        return self.z.dtype

    def astype(self, dtype) -> "PredictiveState":
        """Quantize (or widen) every leaf — hypers included — to ``dtype``.

        The state is the only artifact shipped to servers, so its dtype is
        the wire/disk format: ``state.astype(jnp.bfloat16)`` halves (vs f32)
        or quarters (vs f64) the bytes.  Engines built on a low-precision
        state upcast it once to their ``compute_dtype`` (f32 by default for
        sub-f32 states), so the accuracy loss is the storage rounding, not
        half-precision arithmetic — measured in ``benchmarks.run --only
        serve_ext`` and budgeted in tests/test_serving_quant.py.
        """
        dtype = jnp.dtype(dtype)
        return jax.tree.map(lambda a: jnp.asarray(a, dtype), self)

    @property
    def nbytes(self) -> int:
        """Total bytes of the serialized state (what ships to a server)."""
        return int(sum(a.size * a.dtype.itemsize
                       for a in jax.tree.leaves(self)))


@functools.partial(jax.jit, static_argnames=("kernel",))
def extract_state(hyp: dict, z: Array, stats: Stats,
                  jitter: float = DEFAULT_JITTER,
                  kernel: cov.Kernel | None = None) -> PredictiveState:
    """One-time extraction: all query-independent factorizations and solves.

    Same math as ``core.bound.optimal_qu`` plus the two serving
    contractions.  O(m^3) once; afterwards every predict is O(t m (m + d)).
    ``kernel`` (static; None = SE-ARD) is frozen into the state.
    """
    kernel = cov.as_kernel(kernel)
    beta = jnp.exp(hyp["log_beta"])
    m = z.shape[0]
    L = _chol_kmm(hyp, z, jitter, kernel)
    LiD = jsl.solve_triangular(L, stats.D, lower=True)
    W = jsl.solve_triangular(L, LiD.T, lower=True).T
    Bmat = jnp.eye(m, dtype=z.dtype) + beta * W
    LB = jnp.linalg.cholesky(Bmat)
    LiC = jsl.solve_triangular(L, stats.C, lower=True)
    c2 = jsl.solve_triangular(LB, LiC, lower=True)

    eye = jnp.eye(m, dtype=z.dtype)
    Li = jsl.solve_triangular(L, eye, lower=True)        # L^-1
    LBi = jsl.solve_triangular(LB, eye, lower=True)      # LB^-1
    v1 = Li.T                                            # L^-T
    v2 = v1 @ LBi.T                                      # L^-T LB^-T
    a_mean = beta * (v2 @ c2)
    g = v1 @ v1.T - v2 @ v2.T                            # Kmm^-1 - Sigma^-1
    return PredictiveState(hyp=hyp, z=z, chol_kmm=L, chol_sigma=LB, c2=c2,
                           a_mean=a_mean, g=g, kernel=kernel)


def state_from_model(model) -> PredictiveState:
    """Extract from a fitted sequential model (``SGPR``/``BayesianGPLVM``):
    runs the model's exact map-reduce once for the reduced Stats, then
    :func:`extract_state`.  The model's covariance expression (``kernel``
    attribute; SE-ARD when absent) is frozen into the state."""
    return extract_state(model.params["hyp"], model.params["z"],
                         model._stats(), jitter=model.jitter,
                         kernel=getattr(model, "kernel", None))


# -- query-side math (the XLA serving path; engine.py scans it per block) ---

def predict_mean_var(state: PredictiveState, xstar: Array):
    """Diag-variance predictive posterior at ``xstar`` (t, q) — matmuls only.

    Returns ``(mean (t, d), var (t,))`` — noise-free; callers add ``1/beta``
    for ``include_noise``.  Differentiable in ``xstar`` (plain jnp), which
    the GPLVM reconstruction path relies on.
    """
    ksm = state.kernel.K(state.hyp, xstar, state.z)          # (t, m)
    mean = ksm @ state.a_mean
    quad = jnp.sum((ksm @ state.g) * ksm, axis=1)
    var = state.kernel.kdiag(state.hyp, xstar) - quad
    return mean, var


def predict_full_cov(state: PredictiveState, xstar: Array):
    """Full predictive covariance: ``(mean (t, d), cov (t, t))``, noise-free.

    Cross-covariances couple every query pair, so this is computed in one
    piece rather than through the block engine — the small-t mode.
    """
    ksm = state.kernel.K(state.hyp, xstar, state.z)
    mean = ksm @ state.a_mean
    kss = state.kernel.K(state.hyp, xstar, xstar)
    covm = kss - ksm @ state.g @ ksm.T
    return mean, covm


# -- posterior sampling -----------------------------------------------------

def _mean_cov_from_factors(state: PredictiveState, xstar: Array):
    """Joint moments via the STORED CHOL FACTORS, not the ``g`` contraction.

    cov = kss − a1ᵀa1 + a2ᵀa2 with a1 = L⁻¹ Km*, a2 = L_B⁻¹ a1 — the
    ``core.bound.predict`` full-cov form.  Algebraically identical to
    :func:`predict_full_cov`, but every intermediate stays O(kss) in
    magnitude, whereas ``g = Kmm⁻¹ − Σ⁻¹`` has O(cond(Kmm)) entries whose
    contraction cancels catastrophically — fine for a variance *diagonal*
    read once, fatal for a matrix that must stay PSD enough to factor.
    """
    ksm = state.kernel.K(state.hyp, xstar, state.z)
    mean = ksm @ state.a_mean
    a1 = jsl.solve_triangular(state.chol_kmm, ksm.T, lower=True)
    a2 = jsl.solve_triangular(state.chol_sigma, a1, lower=True)
    kss = state.kernel.K(state.hyp, xstar, xstar)
    covm = kss - a1.T @ a1 + a2.T @ a2
    return mean, covm


def _jittered_chol(state: PredictiveState, covm: Array, t: int,
                   jitter: float, include_noise: bool) -> Array:
    """chol(cov + jitter·vs·I [+ I/beta]) — the sampling factor.

    The jitter follows the ``_chol_kmm`` convention (scaled by the kernel's
    signal variance so it is unit-free).  It also makes the factor
    well-defined on padded query blocks, where the duplicated x=0 pad rows
    make ``cov`` exactly singular.
    """
    vs = state.kernel.variance_scale(state.hyp)
    diag = jitter * vs + jnp.asarray(1e-12, covm.dtype)
    if include_noise:
        diag = diag + jnp.exp(-state.hyp["log_beta"])
    return jnp.linalg.cholesky(covm + diag * jnp.eye(t, dtype=covm.dtype))


def sample_block(state: PredictiveState, x_blk: Array, key: Array,
                 num_samples: int, jitter: float = DEFAULT_JITTER,
                 include_noise: bool = False) -> Array:
    """Joint posterior samples over one query block: (num_samples, t, d).

    Draws f* ~ N(mean, cov) from the block's full predictive covariance via
    a jittered Cholesky of the stored-factor form — the per-block body that
    ``PredictEngine.sample`` scans.  Output dims share the covariance (the
    SGPR predictive factorises over d), so one (t, t) factor serves all d
    columns of standard-normal draws.

    The moments and the factor are computed in f64 regardless of the
    engine's compute dtype (draws are cast back): the covariance of nearby
    queries is near-singular by nature, and the repo's global x64 policy
    exists precisely because this Cholesky math is ill-conditioned in f32.

    Because the factor is lower-triangular, sample row i depends only on
    covariance rows 0..i — so the leading rows of a padded block are
    *identical* to what an unpadded call would draw with the same key (pad
    rows can never leak into real samples; property-tested in
    tests/test_serving_sampling.py).
    """
    if jnp.dtype(state.z.dtype).itemsize < 4:
        raise ValueError(
            "sampling rebuilds the predictive covariance from the stored "
            "chol factors, and sub-f32 storage rounding can make it "
            "indefinite beyond any reasonable jitter (the Cholesky would "
            "silently return NaN draws) — sample from an f32/f64 "
            "PredictiveState; quantized states serve mean/var only "
            "(docs/serving.md)")
    out_dtype = x_blk.dtype
    f64 = jnp.dtype(jnp.float64)
    st = state if jnp.dtype(state.z.dtype) == f64 else state.astype(f64)
    mean, cov = _mean_cov_from_factors(st, x_blk.astype(f64))
    t = x_blk.shape[0]
    lc = _jittered_chol(st, cov, t, jitter, include_noise)
    eps = jax.random.normal(key, (num_samples, t, mean.shape[1]), dtype=f64)
    return (mean[None] + jnp.einsum("ij,sjd->sid", lc, eps)).astype(out_dtype)


def sample_joint(state: PredictiveState, xstar: Array, key: Array,
                 num_samples: int, jitter: float = DEFAULT_JITTER,
                 include_noise: bool = False) -> Array:
    """One-piece joint samples over *all* queries: (num_samples, t, d).

    The small-t analogue of :func:`predict_full_cov` — cross-covariances
    couple every query pair, O(t²) memory and O(t³) factor.  For large
    batches use ``PredictEngine.sample``, which draws jointly within each
    fixed-size block and independently across blocks.
    """
    return sample_block(state, jnp.asarray(xstar, state.z.dtype), key,
                        num_samples, jitter=jitter,
                        include_noise=include_noise)


# -- persistence (the existing checkpoint layer) ----------------------------

def save_state(path: str | pathlib.Path, state: PredictiveState,
               metadata: dict | None = None) -> pathlib.Path:
    """Atomic write via ``repro.checkpoint.save``; shape metadata rides in
    the sidecar so :func:`load_state` needs no template.  The keys
    ``m``/``q``/``d``/``dtype``/``kernel`` are reserved for that restore
    template — user ``metadata`` may not shadow them.  The covariance
    expression serialises as its JSON spec, so a serving host rebuilds the
    exact kernel with no model code."""
    reserved = {"m", "q", "d", "dtype", "kernel"}
    clash = reserved & set(metadata or ())
    if clash:
        raise ValueError(
            f"metadata keys {sorted(clash)} are reserved for the restore "
            "template — rename them")
    meta = {**(metadata or {}), "m": state.m, "q": state.q, "d": state.d,
            "dtype": str(state.z.dtype), "kernel": state.kernel.to_spec()}
    return ckpt.save(path, state, metadata=meta)


def load_state(path: str | pathlib.Path) -> tuple[PredictiveState, dict]:
    """Restore a :class:`PredictiveState` (plus user metadata) from disk.

    Builds the restore template from the sidecar's (m, q, d) and kernel
    spec — no model, no training data, no fitted object required on the
    serving host.  Pre-compositional checkpoints carry no ``kernel`` key
    and restore as SE-ARD (what they were trained with).
    """
    import json

    meta = json.loads(pathlib.Path(path).with_suffix(".json").read_text())
    md = meta["metadata"]
    m, q, d = md["m"], md["q"], md["d"]
    dt = jnp.dtype(md.get("dtype", "float64"))
    kernel = cov.kernel_from_spec(md.get("kernel", {"kind": "se"}))

    def sds(*shape):
        return jax.ShapeDtypeStruct(shape, dt)

    def shape_tree(shapes):
        return jax.tree.map(lambda sh: sds(*sh), shapes,
                            is_leaf=lambda x: isinstance(x, tuple))

    like = PredictiveState(
        hyp={**shape_tree(kernel.hyp_shapes(q)), "log_beta": sds()},
        z=sds(m, q), chol_kmm=sds(m, m), chol_sigma=sds(m, m),
        c2=sds(m, d), a_mean=sds(m, d), g=sds(m, m), kernel=kernel)
    state, md_out = ckpt.restore(path, like)
    return jax.tree.map(jnp.asarray, state), md_out
