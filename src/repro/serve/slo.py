"""Streaming SLO accounting for the serving front-end — constant memory.

A production front-end answers an unbounded request stream, so its latency
accounting must not grow with it.  Two pieces:

  * :class:`QuantileSketch` — a geometric-bucket (HDR-style) histogram:
    values land in buckets whose edges grow by ``1 + 2*rel_err``, so any
    quantile is answered with bounded *relative* error from a fixed-size
    ``int64`` count vector (~1.1k buckets at the 1 µs – 10 min / 1% default).
    Exact count/sum/min/max ride alongside; sketches with the same layout
    ``merge`` (multi-frontend aggregation).
  * :class:`SLOMetrics` — the per-request phase accounting the front-end
    feeds: **wait** (enqueue → dispatch), **engine** (one entry per flush,
    the jitted block-scan wall time), **e2e** (enqueue → response), plus
    admission/SLO counters.  ``summary()`` renders the headline numbers
    (p50/p99 per phase, throughput vs goodput); ``snapshot()`` freezes a
    deep copy for offline diffing or merging across servers.

Nothing here imports jax — the accounting must stay cheap enough to run in
the event loop between flushes.
"""
from __future__ import annotations

import copy
import math
import time

import numpy as np


class QuantileSketch:
    """Streaming quantiles over non-negative values in constant memory.

    Buckets are geometric: bucket k covers ``[low * g^k, low * g^(k+1))``
    with ``g = 1 + 2*rel_err``; reporting a bucket's geometric midpoint
    bounds the relative error of any in-range quantile by ``~rel_err``.
    Values below ``low`` (including exact zeros) land in an underflow
    bucket reported as the exact running min; values at or above ``high``
    land in an overflow bucket reported as the exact running max.
    """

    def __init__(self, low: float = 1e-6, high: float = 600.0,
                 rel_err: float = 0.01):
        if not 0.0 < low < high:
            raise ValueError(f"need 0 < low < high, got {low}, {high}")
        if not 0.0 < rel_err < 1.0:
            raise ValueError(f"rel_err must be in (0, 1), got {rel_err}")
        self.low, self.high, self.rel_err = float(low), float(high), float(rel_err)
        self._log_g = math.log1p(2.0 * rel_err)
        nbins = int(math.ceil(math.log(self.high / self.low) / self._log_g))
        # [0] underflow, [1..nbins] geometric, [-1] overflow
        self._counts = np.zeros(nbins + 2, dtype=np.int64)
        self._count = 0
        self._sum = 0.0
        self._min = math.inf
        self._max = -math.inf

    def add(self, value: float) -> None:
        v = float(value)
        if not math.isfinite(v) or v < 0.0:
            raise ValueError(f"sketch values must be finite and >= 0, got {v}")
        self._count += 1
        self._sum += v
        self._min = min(self._min, v)
        self._max = max(self._max, v)
        if v < self.low:
            idx = 0
        elif v >= self.high:
            idx = len(self._counts) - 1
        else:
            idx = 1 + int(math.log(v / self.low) / self._log_g)
            idx = min(idx, len(self._counts) - 2)   # fp edge at high
        self._counts[idx] += 1

    @property
    def count(self) -> int:
        return self._count

    @property
    def mean(self) -> float:
        return self._sum / self._count if self._count else math.nan

    @property
    def min(self) -> float:
        return self._min if self._count else math.nan

    @property
    def max(self) -> float:
        return self._max if self._count else math.nan

    def quantile(self, q: float) -> float:
        """Nearest-rank quantile with ``<= rel_err`` relative error (exact
        min/max for the under/overflow buckets); NaN when empty."""
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"quantile must be in [0, 1], got {q}")
        if self._count == 0:
            return math.nan
        rank = max(1, math.ceil(q * self._count))
        idx = int(np.searchsorted(np.cumsum(self._counts), rank))
        if idx == 0:
            return self._min
        if idx == len(self._counts) - 1:
            return self._max
        # geometric midpoint of bucket idx-1, clamped to the observed range
        rep = self.low * math.exp((idx - 0.5) * self._log_g)
        return min(max(rep, self._min), self._max)

    def merge(self, other: "QuantileSketch") -> "QuantileSketch":
        """Fold another sketch (same layout) into this one; returns self."""
        if (self.low, self.high, self.rel_err) != (other.low, other.high,
                                                   other.rel_err):
            raise ValueError("can only merge sketches with identical "
                             "(low, high, rel_err) layouts")
        self._counts += other._counts
        self._count += other._count
        self._sum += other._sum
        self._min = min(self._min, other._min)
        self._max = max(self._max, other._max)
        return self

    def summary(self) -> dict:
        return {"count": self.count, "mean": self.mean,
                "p50": self.quantile(0.50), "p99": self.quantile(0.99),
                "max": self.max}


_COUNTERS = ("submitted", "completed", "late", "rejected_queue_full",
             "expired", "cancelled", "flushes", "flushed_requests",
             "flushed_rows", "padded_rows")


class SLOMetrics:
    """Per-request serving accounting with constant memory.

    Counter semantics: every ``submitted`` (i.e. admitted) request ends as
    exactly one of ``completed`` (a ``late`` completion still completes — it
    missed its deadline *after* dispatch and is flagged, never dropped),
    ``expired`` (deadline passed before dispatch — the typed ``SLOExceeded``
    fail-fast), or ``cancelled``.  ``rejected_queue_full`` counts requests
    turned away at admission (never enqueued, so never ``submitted``).
    Goodput counts completions that met their deadline.
    """

    def __init__(self, low: float = 1e-6, high: float = 600.0,
                 rel_err: float = 0.01):
        self.wait = QuantileSketch(low, high, rel_err)
        self.engine = QuantileSketch(low, high, rel_err)
        self.e2e = QuantileSketch(low, high, rel_err)
        self.counters = dict.fromkeys(_COUNTERS, 0)
        self._t0 = time.monotonic()
        self._frozen_elapsed: float | None = None

    # -- the front-end's feed ----------------------------------------------
    def observe_admit(self) -> None:
        self.counters["submitted"] += 1

    def observe_reject_queue_full(self) -> None:
        self.counters["rejected_queue_full"] += 1

    def observe_expired(self) -> None:
        self.counters["expired"] += 1

    def observe_cancelled(self) -> None:
        self.counters["cancelled"] += 1

    def observe_wait(self, seconds: float) -> None:
        self.wait.add(seconds)

    def observe_flush(self, n_requests: int, rows: int, pad_rows: int,
                      engine_seconds: float) -> None:
        self.counters["flushes"] += 1
        self.counters["flushed_requests"] += n_requests
        self.counters["flushed_rows"] += rows
        self.counters["padded_rows"] += pad_rows
        self.engine.add(engine_seconds)

    def observe_complete(self, e2e_seconds: float, late: bool = False) -> None:
        self.counters["completed"] += 1
        self.counters["late"] += bool(late)
        self.e2e.add(e2e_seconds)

    # -- reading ------------------------------------------------------------
    @property
    def elapsed(self) -> float:
        if self._frozen_elapsed is not None:
            return self._frozen_elapsed
        return time.monotonic() - self._t0

    def snapshot(self) -> "SLOMetrics":
        """A frozen deep copy (sketches included): diff two snapshots for a
        window, or ``merge`` snapshots from several front-ends."""
        snap = copy.deepcopy(self)
        snap._frozen_elapsed = self.elapsed
        return snap

    def merge(self, other: "SLOMetrics") -> "SLOMetrics":
        """Fold another front-end's metrics into this one; returns self."""
        self.wait.merge(other.wait)
        self.engine.merge(other.engine)
        self.e2e.merge(other.e2e)
        for k in self.counters:
            self.counters[k] += other.counters[k]
        return self

    def summary(self) -> dict:
        """Headline numbers: per-phase count/mean/p50/p99/max (seconds),
        the raw counters, and derived throughput (completions/s), goodput
        (in-deadline completions/s), mean batch size, and pad waste."""
        c = self.counters
        el = max(self.elapsed, 1e-12)
        staged = c["flushed_rows"] + c["padded_rows"]
        return {
            "elapsed_s": self.elapsed,
            "counters": dict(c),
            "wait": self.wait.summary(),
            "engine": self.engine.summary(),
            "e2e": self.e2e.summary(),
            "throughput_rps": c["completed"] / el,
            "goodput_rps": (c["completed"] - c["late"]) / el,
            "mean_batch_requests": (c["flushed_requests"] / c["flushes"]
                                    if c["flushes"] else math.nan),
            "pad_fraction": c["padded_rows"] / staged if staged else 0.0,
        }
