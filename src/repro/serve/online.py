"""Incremental PredictiveState refresh — the serve side of online updates.

``core.stats.fold_stats`` makes the *statistics* of a new (or forgotten)
block an O(m²) add; this module makes the *serving factors* an O(m²k)
refresh, so a live server can ingest events and keep answering queries
without ever re-scanning history or refactorising from scratch.

With the hyper-parameters and inducing inputs fixed (an online update moves
the data, not the model), ``L = chol(Kmm)`` is unchanged and a block of k
points perturbs the whitened system by exactly a rank-k term:

    B' = B ± V Vᵀ,      V = √β · L⁻¹ Knmᵀ diag(√w)        (m, k)

so every stored factor refreshes without an m×m factorisation:

    LB'     rank-k Cholesky update/downdate of LB          O(m²k)
    c2'     LB'⁻¹ (LB c2 ± L⁻¹ ΔC)                         O(m²(k+d))
    a_mean' β L⁻ᵀ LB'⁻ᵀ c2'                                O(m²d)
    g'      g ± Z T⁻¹ Zᵀ  (Woodbury on B; T is k×k)        O(m²k + k³)

The happy path never calls ``cholesky`` on an m×m matrix — only on the k×k
Woodbury capacitance ``T`` (trace-asserted in tests/test_chol_update.py).

Downdates are guarded: an indefinite or ill-conditioned rank-k downdate
(removing a block that was never folded, or one that carries almost all of
the model's information) trips the ``cond_tol`` pivot guard in
``core.chol_update`` — or surfaces as a non-finite k×k factor — and the
refresh falls back to a full O(m³) refactorisation of ``B'`` from the
stored factors, exactly the rebuild ``extract_state`` would do.  The
fallback is reported, not raised (``RefreshResult.fallback``), because it
is a slow path, not an error.

The orchestration here is deliberately *eager* — the heavy pieces
(``block_update_factors``, the rank-k sweeps in ``core.chol_update``,
``_woodbury_correction``, ``_finish``) are individually jitted and cached
per shape, but the guard is a host-side branch, so the compiled happy path
never contains the fallback's m×m Cholesky (the k×k capacitance factor in
``_correction_from`` stays eager on purpose: it is the one runtime
``cholesky`` call the tests trace).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp
import jax.scipy.linalg as jsl

from ..core import chol_update
from ..core.chol_update import DEFAULT_COND_TOL

Array = jax.Array


class RefreshResult(NamedTuple):
    """An incremental refresh outcome: the new state plus how it was made.

    ``fallback`` is True when the guarded rank-k path was abandoned for the
    full refactorisation (ill-conditioned/indefinite downdate) — useful for
    serving telemetry and asserted on directly by the tests.
    """

    state: "object"      # serve.posterior.PredictiveState
    fallback: bool


@jax.jit
def block_update_factors(state, x_new: Array, y_new: Array,
                         weights: Array | None = None):
    """The rank-k quantities a block contributes: ``(V, dC)``.

    ``V = √β L⁻¹ Knmᵀ diag(√w)`` (m, k) — the whitened block columns whose
    outer product is the perturbation of ``B``; ``dC = Knmᵀ diag(w) Y``
    (m, d) — the block's information-vector delta.  Zero-weight rows
    (padding) produce zero columns, which the rank-k sweeps treat as exact
    no-ops, so padded blocks refresh bit-identically to unpadded ones.
    """
    dt = state.z.dtype
    x_new = jnp.asarray(x_new, dt)
    y_new = jnp.asarray(y_new, dt)
    k = x_new.shape[0]
    w = (jnp.ones((k,), dt) if weights is None
         else jnp.asarray(weights, dt))
    beta = jnp.exp(state.hyp["log_beta"])
    knm = state.kernel.K(state.hyp, x_new, state.z)           # (k, m)
    dC = knm.T @ (w[:, None] * y_new)                         # (m, d)
    U = jsl.solve_triangular(state.chol_kmm, knm.T * jnp.sqrt(w)[None, :],
                             lower=True)                      # (m, k)
    return jnp.sqrt(beta) * U, dC


@jax.jit
def _finish(state, LB_new: Array, LiC_new: Array, g_new: Array):
    """Re-derive the downstream serving contractions from refreshed factors."""
    beta = jnp.exp(state.hyp["log_beta"])
    c2 = jsl.solve_triangular(LB_new, LiC_new, lower=True)
    t1 = jsl.solve_triangular(LB_new.T, c2, lower=False)
    a_mean = beta * jsl.solve_triangular(state.chol_kmm.T, t1, lower=False)
    return dataclasses.replace(state, chol_sigma=LB_new, c2=c2,
                               a_mean=a_mean, g=g_new)


@jax.jit
def _woodbury_correction(state, V: Array):
    """``(Z T⁻¹ Zᵀ, T_chol)`` for ``B' = B ± V Vᵀ``: the rank-k change of
    ``Σ⁻¹`` (hence of ``g = Kmm⁻¹ − Σ⁻¹``), using the *pre-update* LB.

    For an update (``T = I + Vᵀ B⁻¹ V``) the correction is *added* to g;
    for a downdate (``T = I − Vᵀ B⁻¹ V``) it is *subtracted*.  Returns the
    k×k Cholesky of T so the caller can check it stayed finite (a failed T
    means the downdate was not PD — same condition the pivot guard tracks).
    """
    LB = state.chol_sigma
    y1 = jsl.solve_triangular(LB, V, lower=True)
    Y = jsl.solve_triangular(LB.T, y1, lower=False)           # B⁻¹ V
    Z = jsl.solve_triangular(state.chol_kmm.T, Y, lower=False)  # L⁻ᵀ B⁻¹ V
    return y1, Y, Z


def _correction_from(y1: Array, Z: Array, sign: float):
    k = Z.shape[1]
    T = jnp.eye(k, dtype=Z.dtype) + sign * (y1.T @ y1)
    # k×k only — never the full m×m system (tests/test_chol_update.py
    # monkeypatches cholesky to enforce this).
    Tc = jnp.linalg.cholesky(T)
    S = jsl.solve_triangular(Tc, Z.T, lower=True)             # (k, m)
    return S.T @ S, Tc


def _refactorize(state, V: Array, LiC_new: Array, sign: float):
    """The guarded fallback: rebuild ``LB' = chol(B ± V Vᵀ)`` and ``g``
    densely from the stored factors — O(m³), exact, always PSD-safe when
    the downdate itself is legitimate."""
    LB = state.chol_sigma
    m = LB.shape[0]
    Bmat = LB @ LB.T + sign * (V @ V.T)
    Bmat = 0.5 * (Bmat + Bmat.T)
    LB_new = jnp.linalg.cholesky(Bmat)
    eye = jnp.eye(m, dtype=LB.dtype)
    Li = jsl.solve_triangular(state.chol_kmm, eye, lower=True)
    LBi = jsl.solve_triangular(LB_new, eye, lower=True)
    v1 = Li.T
    v2 = v1 @ LBi.T
    g = v1 @ v1.T - v2 @ v2.T
    return _finish(state, LB_new, LiC_new, g)


def refresh_state(state, x_new: Array, y_new: Array,
                  weights: Array | None = None, sign: float = 1.0,
                  cond_tol: float = DEFAULT_COND_TOL) -> RefreshResult:
    """Refresh every serving factor for a folded (+1) / forgotten (−1)
    block of k points in O(m²(k+d)), with a guarded O(m³) fallback.

    The state's (hyp, z, chol_kmm) are unchanged — an online update moves
    data, not parameters; after a ``fit`` the deltas must be recomputed and
    the state re-extracted (``SGPR.update`` handles this by going through
    the model's invalidation path).
    """
    if jnp.dtype(state.z.dtype).itemsize < 4:
        raise ValueError(
            "incremental refresh runs Cholesky-update math on the stored "
            "factors; sub-f32 (quantized) states cannot carry it — refresh "
            "the full-precision master state and re-quantize "
            "(docs/serving.md)")
    if sign not in (1.0, -1.0):
        raise ValueError(f"sign must be +1.0 or -1.0, got {sign}")
    V, dC = block_update_factors(state, x_new, y_new, weights)
    LiC = state.chol_sigma @ state.c2 + sign * jsl.solve_triangular(
        state.chol_kmm, dC, lower=True)

    if sign > 0:
        LB_new, ok = chol_update.chol_update_rank_k(state.chol_sigma, V,
                                                    cond_tol=cond_tol)
    else:
        LB_new, ok = chol_update.chol_downdate_rank_k(state.chol_sigma, V,
                                                      cond_tol=cond_tol)
    if bool(ok):
        y1, _, Z = _woodbury_correction(state, V)
        corr, Tc = _correction_from(y1, Z, sign)
        if bool(jnp.all(jnp.isfinite(Tc))
                & jnp.all(jnp.diagonal(Tc) > 0)):
            g_new = state.g + sign * corr
            return RefreshResult(_finish(state, LB_new, LiC, g_new), False)
    return RefreshResult(_refactorize(state, V, LiC, sign), True)


def update_state(state, x_new: Array, y_new: Array,
                 weights: Array | None = None,
                 cond_tol: float = DEFAULT_COND_TOL) -> RefreshResult:
    """Absorb a new block into the serving state (pair with
    ``stats.fold_stats`` on the training side)."""
    return refresh_state(state, x_new, y_new, weights, sign=1.0,
                         cond_tol=cond_tol)


def downdate_state(state, x_old: Array, y_old: Array,
                   weights: Array | None = None,
                   cond_tol: float = DEFAULT_COND_TOL) -> RefreshResult:
    """Forget a previously folded block (pair with
    ``stats.downdate_stats``).  Ill-conditioned or indefinite removals take
    the guarded fallback (``RefreshResult.fallback``)."""
    return refresh_state(state, x_old, y_old, weights, sign=-1.0,
                         cond_tol=cond_tol)
