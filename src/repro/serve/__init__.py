"""Posterior serving subsystem: training state -> frozen predictive state ->
batched/sharded low-latency predict, sample, and multi-model engines.

  posterior   PredictiveState (frozen pytree of query-independent factors;
              ``astype`` quantizes it — the wire format shipped to servers),
              extract_state, save_state/load_state (checkpoint layer, dtype-
              tagged sidecar), predict_mean_var / predict_full_cov (the XLA
              query math), sample_block / sample_joint (jittered-chol draws)
  engine      PredictEngine: jitted fixed-block lax.scan predict + posterior
              ``sample`` (per-block joint draws, per-block PRNG keys riding
              with the query shards), optional mesh sharding, xla|pallas
              backend, configurable compute_dtype, include_noise/full_cov;
              MultiPredictEngine: N stacked states vmap-served from one
              executable (stack_states, mixture_moments)
  online      incremental PredictiveState refresh for online updates:
              update_state / downdate_state (rank-k Cholesky update of the
              stored factors, O(m²k), guarded fallback to refactorisation)
              — paired with ``PredictEngine.ingest``/``forget``/
              ``swap_state`` for the ingest-update-serve loop
  frontend    Frontend: the production request path — async continuous
              micro-batching over a bounded queue (coalesce concurrent
              requests into the engine's padded block shapes, flush on
              batch-full or max_wait_ms), admission control + per-request
              deadlines (typed QueueFull / SLOExceeded, never silent), and
              zero-downtime hot state swap with a generation fence
  slo         constant-memory serving SLO accounting: QuantileSketch
              (geometric-bucket streaming p50/p99) + SLOMetrics
              (wait/engine/e2e phases, throughput vs goodput, snapshot/merge)

See docs/serving.md for the serving guide and tuning tables.
"""
from . import engine, frontend, online, posterior, slo
from .engine import (MultiPredictEngine, PredictEngine, mixture_moments,
                     stack_states)
from .frontend import (Frontend, FrontendError, QueueFull, ServeResult,
                       SLOExceeded)
from .online import (RefreshResult, downdate_state, refresh_state,
                     update_state)
from .posterior import (PredictiveState, extract_state, load_state,
                        predict_full_cov, predict_mean_var, sample_block,
                        sample_joint, save_state, state_from_model)
from .slo import QuantileSketch, SLOMetrics

__all__ = [
    "engine", "frontend", "online", "posterior", "slo",
    "Frontend", "FrontendError", "MultiPredictEngine", "PredictEngine",
    "PredictiveState", "QuantileSketch", "QueueFull", "RefreshResult",
    "SLOExceeded", "SLOMetrics", "ServeResult", "downdate_state",
    "extract_state", "load_state", "mixture_moments", "predict_full_cov",
    "predict_mean_var", "refresh_state", "sample_block", "sample_joint",
    "save_state", "stack_states", "state_from_model", "update_state",
]
