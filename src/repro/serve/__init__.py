"""Posterior serving subsystem: training state -> frozen predictive state ->
batched/sharded low-latency predict engine.

  posterior   PredictiveState (frozen pytree of query-independent factors),
              extract_state, save_state/load_state (checkpoint layer),
              predict_mean_var / predict_full_cov (the XLA query math)
  engine      PredictEngine: jitted fixed-block lax.scan predict, optional
              mesh sharding, xla|pallas backend, include_noise/full_cov

See docs/serving.md for the serving guide and tuning table.
"""
from . import engine, posterior
from .engine import PredictEngine
from .posterior import (PredictiveState, extract_state, load_state,
                        predict_full_cov, predict_mean_var, save_state,
                        state_from_model)

__all__ = [
    "engine", "posterior", "PredictEngine", "PredictiveState",
    "extract_state", "load_state", "predict_full_cov", "predict_mean_var",
    "save_state", "state_from_model",
]
