"""Batched, sharded, low-latency predict engine over a PredictiveState.

Serving shape of the problem: a stream of query batches of varying size
against one frozen :class:`~repro.serve.posterior.PredictiveState`.  The
engine turns that into a shape-static jitted program:

  * **Fixed-size query blocks** — queries are padded up to a multiple of
    ``block_size`` (times ``n_shards`` on a mesh), mirroring
    ``distributed.pad_and_shard``; pad rows are zeros, compute garbage, and
    are sliced off before returning, so only ``ceil(t / block_size)``
    distinct program shapes ever compile.
  * **``lax.scan`` over blocks** — one block's (block, m) kernel slab is
    live at a time, so serving memory is O(block·m + m² + m·d) regardless
    of the batch size.
  * **Optional mesh sharding** — with ``mesh=``, query blocks shard across
    the data axes while the state is replicated (``shard_map``); each device
    scans its own slice and no collective is needed (predictions are
    row-local, the serving analogue of the paper's zero-communication map).
  * **Backend switch** — ``kernel_backend="pallas"`` routes each block
    through the fused ``kernels/predict`` op (ksm evaluated tile-by-tile in
    VMEM, mean/var contractions fused in the same pass); ``"xla"`` (default)
    runs the same math as two matmuls.

The per-query hot path contains no factorizations and no triangular solves
— those happened once at ``extract_state`` time.  ``include_noise`` adds
``1/beta`` outside the jitted program (one vector add), so both variants
share one compiled executable.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from ..core.distributed import num_shards, shard_map
from . import posterior

Array = jax.Array


class PredictEngine:
    """Jitted block-scan (optionally mesh-sharded) predict over a frozen state.

    Args:
      state: a :class:`~repro.serve.posterior.PredictiveState`.
      block_size: rows per scan block. Queries are padded up to a multiple
        of ``n_shards * block_size``; smaller blocks mean less padding waste
        on small batches, larger blocks amortise scan overhead on big ones
        (tuning table in docs/serving.md).
      mesh / data_axes: if given, shard query batches across these mesh axes
        with the state replicated on every device.
      kernel_backend: "xla" (default) or "pallas" (the fused
        ``kernels/predict`` op; forward-only — serving never differentiates).
      donate: donate the padded query buffer to the jitted program
        (``donate_argnums``) so XLA may reuse it for outputs. Off by default
        — some backends (CPU) cannot honour it and warn.
    """

    def __init__(self, state: posterior.PredictiveState,
                 block_size: int = 256, mesh=None, data_axes=("data",),
                 kernel_backend: str = "xla", donate: bool = False):
        if block_size < 1:
            raise ValueError(f"block_size must be >= 1, got {block_size}")
        if kernel_backend not in ("xla", "pallas"):
            raise ValueError(
                f"kernel_backend must be 'xla' or 'pallas', got {kernel_backend!r}")
        self.block_size = block_size
        self.mesh = mesh
        self.data_axes = tuple(data_axes)
        self.kernel_backend = kernel_backend
        self.donate = donate
        self.n_shards = 1 if mesh is None else num_shards(mesh, self.data_axes)

        if kernel_backend == "pallas":
            from ..kernels.predict import predict_fn_for_engine
            # Match the kernel's query tile to the scan block so no block is
            # zero-padded up to a larger tile inside the op (capped at 128 —
            # one MXU-rows worth — for big scan blocks; min sublane is 8).
            block_t = min(128, block_size + (-block_size) % 8)
            self._block_fn = predict_fn_for_engine(block_t=block_t)
        else:
            self._block_fn = posterior.predict_mean_var

        if mesh is not None:
            self._data_spec = P(self.data_axes)
            self._rep_spec = P()
            state = jax.device_put(state, NamedSharding(mesh, self._rep_spec))
        self.state = state

        def scan_blocks(st, xq):
            # (t_local, q) -> block-scan -> ((t_local, d), (t_local,))
            t_local = xq.shape[0]
            nb = t_local // self.block_size
            xb = xq.reshape(nb, self.block_size, xq.shape[1])

            def body(carry, x_blk):
                return carry, self._block_fn(st, x_blk)

            _, (mean, var) = lax.scan(body, None, xb)
            return mean.reshape(t_local, -1), var.reshape(t_local)

        if mesh is None:
            run = scan_blocks
        else:
            run = shard_map(scan_blocks, mesh=mesh,
                            in_specs=(self._rep_spec, self._data_spec),
                            out_specs=(self._data_spec, self._data_spec))
        self._run = jax.jit(run, donate_argnums=(1,) if donate else ())
        self._run_full = jax.jit(posterior.predict_full_cov)

    # -- the serving entry points -------------------------------------------
    def pad_queries(self, xstar) -> tuple[Array, int]:
        """Pad (t, q) queries up to a multiple of ``n_shards * block_size``
        with zero rows (mirroring ``pad_and_shard``); returns (padded, t)."""
        xq = jnp.asarray(xstar, self.state.z.dtype)
        t = xq.shape[0]
        mult = self.n_shards * self.block_size
        pad = (-t) % mult
        if pad:
            xq = jnp.pad(xq, ((0, pad), (0, 0)))
        elif self.donate and xq is xstar:
            # No pad/cast copy was made, so the caller's own buffer would be
            # donated (and deleted) — donation may only eat an engine-owned
            # buffer.
            xq = jnp.array(xq, copy=True)
        if self.mesh is not None:
            xq = jax.device_put(xq, NamedSharding(self.mesh, self._data_spec))
        return xq, t

    def predict(self, xstar, include_noise: bool = False):
        """Batched diag-variance prediction: ``(mean (t, d), var (t,))``."""
        xq, t = self.pad_queries(xstar)
        mean, var = self._run(self.state, xq)
        mean, var = mean[:t], var[:t]
        if include_noise:
            var = var + jnp.exp(-self.state.hyp["log_beta"])
        return mean, var

    def predict_full_cov(self, xstar, include_noise: bool = False):
        """Full-covariance mode: ``(mean (t, d), cov (t, t))``.  Computed in
        one piece (cross-covariances couple all query pairs) — the small-t
        mode; it bypasses the block scan and the mesh."""
        xq = jnp.asarray(xstar, self.state.z.dtype)
        mean, cov = self._run_full(self.state, xq)
        if include_noise:
            cov = cov + jnp.exp(-self.state.hyp["log_beta"]) * jnp.eye(
                xq.shape[0], dtype=cov.dtype)
        return mean, cov

    def __call__(self, xstar, include_noise: bool = False,
                 full_cov: bool = False):
        if full_cov:
            return self.predict_full_cov(xstar, include_noise=include_noise)
        return self.predict(xstar, include_noise=include_noise)

    def predict_np(self, xstar, include_noise: bool = False):
        """predict + device_get — the convenience wrapper the sequential
        models' ``.predict`` delegates to."""
        mean, var = self.predict(xstar, include_noise=include_noise)
        return np.asarray(mean), np.asarray(var)
