"""Batched, sharded, low-latency predict/sample engines over PredictiveStates.

Serving shape of the problem: a stream of query batches of varying size
against one (or N) frozen :class:`~repro.serve.posterior.PredictiveState`.
The engines turn that into shape-static jitted programs:

  * **Fixed-size query blocks** — queries are padded up to a multiple of
    ``block_size`` (times ``n_shards`` on a mesh), mirroring
    ``distributed.pad_and_shard``; pad rows are zeros, compute garbage, and
    are sliced off before returning, so only ``ceil(t / block_size)``
    distinct program shapes ever compile.
  * **``lax.scan`` over blocks** — one block's (block, m) kernel slab is
    live at a time, so serving memory is O(block·m + m² + m·d) regardless
    of the batch size.
  * **Optional mesh sharding** — with ``mesh=``, query blocks shard across
    the data axes while the state is replicated (``shard_map``); each device
    scans its own slice and no collective is needed (predictions — and
    posterior samples, whose per-block PRNG keys ride along with the query
    shards — are row-local, the serving analogue of the paper's
    zero-communication map).
  * **Backend switch** — ``kernel_backend="pallas"`` routes each block
    through the fused ``kernels/predict`` op (ksm evaluated tile-by-tile in
    VMEM, mean/var contractions fused in the same pass); ``"xla"`` (default)
    runs the same math as two matmuls.
  * **Quantized states** — a low-precision state (``state.astype(bf16)``,
    the wire format shipped to servers) is upcast **once** at engine build
    to ``compute_dtype`` (f32 by default for sub-f32 states), so every
    contraction accumulates at full width and the only accuracy loss is the
    storage rounding.

The per-query hot path contains no factorizations and no triangular solves
— those happened once at ``extract_state`` time.  (``sample`` is the one
exception: it re-factorises each block's (block, block) predictive
covariance, which is query-dependent and cannot be precomputed.)
``include_noise`` adds ``1/beta`` outside the jitted program (one vector
add), so both variants share one compiled executable.

:class:`MultiPredictEngine` serves N same-shape states (an ensemble or an
A/B fleet) from ONE compiled executable by stacking them into a single
batched pytree (:func:`stack_states`) and ``vmap``-ing the block scan over
the model axis — the forward-path specialisation Dai et al. (2014) exploit
for GPU-accelerated GP prediction.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from ..core.bound import DEFAULT_JITTER
from ..core.distributed import num_shards, shard_map
from . import posterior

Array = jax.Array


def _resolve_compute_dtype(state_dtype, compute_dtype):
    """Engine compute width: explicit > state's own (f32/f64) > f32 floor.

    Sub-f32 states (bf16/f16) are a *storage* format — computing in them
    would add half-precision arithmetic error on top of the storage
    rounding, so they default to f32 accumulation.
    """
    if compute_dtype is not None:
        return jnp.dtype(compute_dtype)
    sdt = jnp.dtype(state_dtype)
    return sdt if sdt.itemsize >= 4 else jnp.dtype(jnp.float32)


def _make_scan_blocks(block_fn, block_size: int):
    """(state, (t_local, q)) -> block-scan -> ((t_local, d), (t_local,))."""

    def scan_blocks(st, xq):
        t_local = xq.shape[0]
        nb = t_local // block_size
        xb = xq.reshape(nb, block_size, xq.shape[1])

        def body(carry, x_blk):
            return carry, block_fn(st, x_blk)

        _, (mean, var) = lax.scan(body, None, xb)
        # Explicit trailing dim: a -1 cannot be inferred from a size-0 array,
        # and t_local == 0 (an empty flush) must stay a no-op, not an error.
        return mean.reshape(t_local, mean.shape[-1]), var.reshape(t_local)

    return scan_blocks


class PredictEngine:
    """Jitted block-scan (optionally mesh-sharded) predict/sample engine.

    Args:
      state: a :class:`~repro.serve.posterior.PredictiveState` (any float
        dtype — quantized states are upcast once to ``compute_dtype``).
      block_size: rows per scan block. Queries are padded up to a multiple
        of ``n_shards * block_size``; smaller blocks mean less padding waste
        on small batches, larger blocks amortise scan overhead on big ones
        (tuning table in docs/serving.md).  ``sample`` draws *jointly*
        within each block and independently across blocks, so it is also
        the correlation length of the sampled functions.
      mesh / data_axes: if given, shard query batches across these mesh axes
        with the state replicated on every device.
      kernel_backend: "xla" (default) or "pallas" (the fused
        ``kernels/predict`` op; forward-only — serving never differentiates).
      donate: donate the padded query buffer to the jitted predict program
        (``donate_argnums``) so XLA may reuse it for outputs. Off by default
        — some backends (CPU) cannot honour it and warn.
      compute_dtype: dtype every contraction runs in.  ``None`` (default)
        keeps f32/f64 states as-is and lifts bf16/f16 states to f32.
        ``sample`` needs a Cholesky per block, so it requires f32+.
      sample_jitter: diagonal jitter (scaled by sf2, the ``_chol_kmm``
        convention) added to each block covariance before its Cholesky in
        ``sample``.
    """

    def __init__(self, state: posterior.PredictiveState,
                 block_size: int = 256, mesh=None, data_axes=("data",),
                 kernel_backend: str = "xla", donate: bool = False,
                 compute_dtype=None, sample_jitter: float = DEFAULT_JITTER):
        if block_size < 1:
            raise ValueError(f"block_size must be >= 1, got {block_size}")
        if kernel_backend not in ("xla", "pallas"):
            raise ValueError(
                f"kernel_backend must be 'xla' or 'pallas', got {kernel_backend!r}")
        self.block_size = block_size
        self.mesh = mesh
        self.data_axes = tuple(data_axes)
        self.kernel_backend = kernel_backend
        self.donate = donate
        self.sample_jitter = sample_jitter
        self.n_shards = 1 if mesh is None else num_shards(mesh, self.data_axes)
        self.compute_dtype = _resolve_compute_dtype(state.z.dtype,
                                                    compute_dtype)

        if kernel_backend == "pallas":
            from ..kernels.predict import predict_fn_for_engine
            # Match the kernel's query tile to the scan block so no block is
            # zero-padded up to a larger tile inside the op (capped at 128 —
            # one MXU-rows worth — for big scan blocks; min sublane is 8).
            block_t = min(128, block_size + (-block_size) % 8)
            self._block_fn = predict_fn_for_engine(
                block_t=block_t, compute_dtype=self.compute_dtype,
                kernel=state.kernel)
        else:
            self._block_fn = posterior.predict_mean_var

        # The stored artifact stays as given (``.state``); all programs run
        # on the compute-width copy, made once here.
        self.state = state
        cstate = (state if jnp.dtype(state.z.dtype) == self.compute_dtype
                  else state.astype(self.compute_dtype))
        if mesh is not None:
            self._data_spec = P(self.data_axes)
            self._rep_spec = P()
            cstate = jax.device_put(cstate, NamedSharding(mesh, self._rep_spec))
        self._cstate = cstate

        run = _make_scan_blocks(self._block_fn, self.block_size)
        if mesh is not None:
            run = shard_map(run, mesh=mesh,
                            in_specs=(self._rep_spec, self._data_spec),
                            out_specs=(self._data_spec, self._data_spec))
        self._run = jax.jit(run, donate_argnums=(1,) if donate else ())
        self._run_full = jax.jit(posterior.predict_full_cov)
        self._sample_progs: dict = {}   # (num_samples, include_noise) -> fn

    # -- the serving entry points -------------------------------------------
    def pad_queries(self, xstar) -> tuple[Array, int]:
        """Pad (t, q) queries up to a multiple of ``n_shards * block_size``
        with zero rows (mirroring ``pad_and_shard``); returns (padded, t)."""
        xq = jnp.asarray(xstar, self.compute_dtype)
        t = xq.shape[0]
        mult = self.n_shards * self.block_size
        pad = (-t) % mult
        if pad:
            xq = jnp.pad(xq, ((0, pad), (0, 0)))
        elif self.donate and xq is xstar:
            # No pad/cast copy was made, so the caller's own buffer would be
            # donated (and deleted) — donation may only eat an engine-owned
            # buffer.
            xq = jnp.array(xq, copy=True)
        if self.mesh is not None:
            xq = jax.device_put(xq, NamedSharding(self.mesh, self._data_spec))
        return xq, t

    def _noise_var(self):
        return jnp.exp(-self._cstate.hyp["log_beta"])

    @property
    def compute_state(self):
        """The compute-width (upcast, device-placed) state the jitted
        programs consume.  ``swap_state`` replaces it wholesale, so a caller
        that reads it ONCE and passes it to :meth:`run_blocks` is fenced
        against concurrent swaps — in-flight batches complete against the
        state they were dispatched with (``serve.frontend`` relies on this).
        """
        return self._cstate

    def run_blocks(self, xq: Array, cstate=None):
        """Run the jitted block scan on an ALREADY padded/staged query
        buffer — ``xq`` must be what :meth:`pad_queries` returned (a
        multiple of ``n_shards * block_size`` rows in ``compute_dtype``),
        so whoever assembled the batch pads exactly once.  ``cstate`` pins
        the program to a specific :attr:`compute_state` snapshot (hot-swap
        fencing); ``None`` serves the engine's current state.  Returns the
        *padded* ``(mean, var)`` — callers slice off the pad rows.
        """
        return self._run(self._cstate if cstate is None else cstate, xq)

    # -- online updates (ingest-update-serve) -------------------------------
    def swap_state(self, state: posterior.PredictiveState) -> None:
        """Atomically replace the served state with a same-shape one —
        zero recompilation (the jitted programs take the state as an
        argument, so identical shapes/dtypes hit the existing executables).

        This is the serving half of an online update: refresh the factors
        incrementally (``serve.online``) or re-extract after a re-fit, then
        swap the result in while the engine keeps answering queries.
        """
        if state.kernel != self.state.kernel:
            raise ValueError(
                "swap_state needs the same kernel expression "
                f"({self.state.kernel} vs {state.kernel}) — build a new "
                "engine for a different covariance")
        for a, b in zip(jax.tree.leaves(self.state), jax.tree.leaves(state)):
            if a.shape != b.shape:
                raise ValueError(
                    "swap_state needs identical leaf shapes (same m, q, d) "
                    f"— got {a.shape} vs {b.shape}; build a new engine for "
                    "a reshaped state")
        self.state = state
        cstate = (state if jnp.dtype(state.z.dtype) == self.compute_dtype
                  else state.astype(self.compute_dtype))
        if self.mesh is not None:
            cstate = jax.device_put(
                cstate, NamedSharding(self.mesh, self._rep_spec))
        self._cstate = cstate

    def ingest(self, x_new, y_new, weights=None):
        """Absorb a block of k observations into the served posterior in
        O(m²k) — rank-k factor refresh (``serve.online.update_state``) +
        :meth:`swap_state` — without touching history or recompiling.
        Returns the refresh info (``online.RefreshResult``); the engine
        serves the refreshed state from the moment this returns.

        Note this moves the *posterior*, not the hyper-parameters: it is
        the serving mirror of ``SGPR.update`` (which also folds the
        training-side Stats so a later re-fit starts exact).
        """
        from . import online
        res = online.update_state(self.state, x_new, y_new, weights)
        self.swap_state(res.state)
        return res

    def forget(self, x_old, y_old, weights=None):
        """Remove a previously ingested block from the served posterior —
        rank-k downdate with the guarded refactorisation fallback
        (``serve.online.downdate_state``) + :meth:`swap_state`.  Returns
        the refresh info (inspect ``.fallback`` for telemetry)."""
        from . import online
        res = online.downdate_state(self.state, x_old, y_old, weights)
        self.swap_state(res.state)
        return res

    def predict(self, xstar, include_noise: bool = False):
        """Batched diag-variance prediction: ``(mean (t, d), var (t,))``."""
        xq, t = self.pad_queries(xstar)
        if t == 0:
            # An empty batch (a serving front-end's deadline flush with zero
            # live rows) is a no-op, never a shape error.
            return (jnp.zeros((0, self.state.c2.shape[-1]), self.compute_dtype),
                    jnp.zeros((0,), self.compute_dtype))
        mean, var = self._run(self._cstate, xq)
        mean, var = mean[:t], var[:t]
        if include_noise:
            var = var + self._noise_var()
        return mean, var

    def predict_full_cov(self, xstar, include_noise: bool = False):
        """Full-covariance mode: ``(mean (t, d), cov (t, t))``.  Computed in
        one piece (cross-covariances couple all query pairs) — the small-t
        mode; it bypasses the block scan and the mesh."""
        xq = jnp.asarray(xstar, self.compute_dtype)
        mean, cov = self._run_full(self._cstate, xq)
        if include_noise:
            cov = cov + self._noise_var() * jnp.eye(xq.shape[0],
                                                    dtype=cov.dtype)
        return mean, cov

    def __call__(self, xstar, include_noise: bool = False,
                 full_cov: bool = False):
        if full_cov:
            return self.predict_full_cov(xstar, include_noise=include_noise)
        return self.predict(xstar, include_noise=include_noise)

    def predict_np(self, xstar, include_noise: bool = False):
        """predict + device_get — the convenience wrapper the sequential
        models' ``.predict`` delegates to."""
        mean, var = self.predict(xstar, include_noise=include_noise)
        return np.asarray(mean), np.asarray(var)

    # -- streaming serving --------------------------------------------------
    def predict_stream(self, queries, include_noise: bool = False,
                       prefetch_depth: int = 2):
        """Serve an *iterator* of query batches: yields one ``(mean, var)``
        pair per batch, in order, without ever materialising the union of
        the batches on device — the engine's working set stays one padded
        batch regardless of how long the request stream runs.

        Batch ``i+1``'s staging (pad + ``device_put``, sharded on a mesh
        engine) happens in a background thread while the jitted block-scan
        computes batch ``i`` (``data.stream.prefetch`` double buffering),
        so H2D transfer hides behind compute.  Each yielded pair is
        bitwise what :meth:`predict` returns for that batch.
        """
        from ..data.stream import prefetch

        staged = prefetch(iter(queries), self.pad_queries,
                          depth=prefetch_depth)
        for xq, t in staged:
            mean, var = self._run(self._cstate, xq)
            mean, var = mean[:t], var[:t]
            if include_noise:
                var = var + self._noise_var()
            yield mean, var

    def sample_stream(self, queries, num_samples: int, key,
                      include_noise: bool = False, prefetch_depth: int = 2):
        """Streaming :meth:`sample`: yields ``(num_samples, t_i, d)`` draws
        per query batch with the same double-buffered staging as
        :meth:`predict_stream`.

        Per-block PRNG keys are ``fold_in(key, global_block_index)`` where
        the block index runs over the *concatenated* stream — each batch
        advances the offset by its padded block count.  When every batch's
        row count is a multiple of ``n_shards * block_size`` the blocks of
        the stream are exactly the blocks of the one-shot call, so the
        concatenated draws are bitwise ``sample(concat(batches))``; ragged
        batches still get valid independent per-block draws, just under a
        different key assignment (their padding shifts later offsets).
        """
        if num_samples < 1:
            raise ValueError(f"num_samples must be >= 1, got {num_samples}")
        if self.compute_dtype.itemsize < 4 \
                or jnp.dtype(self.state.z.dtype).itemsize < 4:
            raise ValueError(
                "sample_stream has the same f32+ state/compute requirement "
                "as sample (per-block Cholesky; docs/serving.md)")
        from ..data.stream import prefetch

        key = jnp.asarray(key)
        prog = self._sample_prog(int(num_samples), bool(include_noise))
        offset = 0
        staged = prefetch(iter(queries), self.pad_queries,
                          depth=prefetch_depth)
        for xq, t in staged:
            nb = xq.shape[0] // self.block_size
            keys = jax.vmap(lambda i: jax.random.fold_in(key, i))(
                offset + jnp.arange(nb))
            if self.mesh is not None:
                keys = jax.device_put(
                    keys, NamedSharding(self.mesh, self._data_spec))
            yield prog(self._cstate, xq, keys)[:, :t, :]
            offset += nb

    # -- posterior sampling -------------------------------------------------
    def _sample_prog(self, num_samples: int, include_noise: bool):
        """Compile (and cache) the block-scan sampling program for one
        (num_samples, include_noise) pair — everything else is shared."""
        cache_key = (num_samples, include_noise)
        prog = self._sample_progs.get(cache_key)
        if prog is not None:
            return prog
        bs, jit_ = self.block_size, self.sample_jitter

        def scan_sample(st, xq, keys):
            # (t_local, q), (nb_local, 2) -> (num_samples, t_local, d)
            t_local = xq.shape[0]
            nb = t_local // bs
            xb = xq.reshape(nb, bs, xq.shape[1])

            def body(carry, inp):
                x_blk, k = inp
                return carry, posterior.sample_block(
                    st, x_blk, k, num_samples, jitter=jit_,
                    include_noise=include_noise)

            _, smp = lax.scan(body, None, (xb, keys))   # (nb, S, bs, d)
            smp = jnp.swapaxes(smp, 0, 1)               # (S, nb, bs, d)
            return smp.reshape(num_samples, t_local, smp.shape[-1])

        if self.mesh is None:
            run = scan_sample
        else:
            run = shard_map(
                scan_sample, mesh=self.mesh,
                in_specs=(self._rep_spec, self._data_spec, self._data_spec),
                out_specs=P(None, self.data_axes))
        prog = jax.jit(run)
        self._sample_progs[cache_key] = prog
        return prog

    def sample(self, xstar, num_samples: int, key,
               include_noise: bool = False) -> Array:
        """Posterior function draws: ``(num_samples, t, d)``.

        Samples are *jointly* distributed within each query block (drawn
        from the block's full predictive covariance via a jittered
        Cholesky) and independent across blocks — ``block_size`` is the
        correlation length.  For exact joint draws over every query, keep
        ``t <= block_size`` or use ``serve.posterior.sample_joint``.

        Block i consumes ``fold_in(key, i)`` — a function of the *global*
        block index only, not of the padded block count — and the keys ride
        along with the query shards.  A mesh-sharded engine therefore draws
        bit-identical samples to a single-device one (whose padding differs)
        and needs no collective.  Same key, same queries → same samples;
        distinct keys → independent draws.
        """
        if num_samples < 1:
            raise ValueError(f"num_samples must be >= 1, got {num_samples}")
        if self.compute_dtype.itemsize < 4:
            raise ValueError(
                "sample needs a Cholesky per block — build the engine with "
                f"compute_dtype=f32/f64, not {self.compute_dtype}")
        if jnp.dtype(self.state.z.dtype).itemsize < 4:
            raise ValueError(
                "sample re-factorises each block's predictive covariance, "
                "and sub-f32 storage rounding (bf16/f16 quantization of g) "
                "can make it indefinite beyond any reasonable jitter — "
                "ship an f32/f64 PredictiveState for sampling; quantized "
                "states serve mean/var only (docs/serving.md)")
        xq, t = self.pad_queries(xstar)
        key = jnp.asarray(key)
        keys = jax.vmap(lambda i: jax.random.fold_in(key, i))(
            jnp.arange(xq.shape[0] // self.block_size))
        if self.mesh is not None:
            keys = jax.device_put(keys,
                                  NamedSharding(self.mesh, self._data_spec))
        prog = self._sample_prog(int(num_samples), bool(include_noise))
        return prog(self._cstate, xq, keys)[:, :t, :]


# -- multi-model serving ----------------------------------------------------

def stack_states(states) -> posterior.PredictiveState:
    """Stack N same-shape PredictiveStates into one batched pytree.

    Every leaf gains a leading model axis of size N; the result is what
    :class:`MultiPredictEngine` vmaps over.  States must agree on every
    leaf's shape and dtype (same m, q, d, and storage width — ``astype``
    first if the fleet is mixed-precision).
    """
    states = list(states)
    if not states:
        raise ValueError("stack_states needs at least one PredictiveState")
    ref_kernel = states[0].kernel
    for s in states[1:]:
        # The kernel spec is static pytree metadata: a mismatch would
        # surface as an opaque treedef error inside tree.map, so check it
        # explicitly first.
        if s.kernel != ref_kernel:
            raise ValueError(
                "all PredictiveStates must share one kernel expression to "
                f"stack: {ref_kernel} vs {s.kernel}")
    ref_leaves = jax.tree.leaves(states[0])
    for s in states[1:]:
        for a, b in zip(ref_leaves, jax.tree.leaves(s)):
            if a.shape != b.shape or a.dtype != b.dtype:
                raise ValueError(
                    "all PredictiveStates must share leaf shapes/dtypes to "
                    f"stack: {a.shape}/{a.dtype} vs {b.shape}/{b.dtype}")
    return jax.tree.map(lambda *ls: jnp.stack(ls), *states)


def mixture_moments(mean: Array, var: Array) -> tuple[Array, Array]:
    """Ensemble (equal-weight mixture) moments from per-model predictions.

    ``mean`` (N, t, d), ``var`` (N, t) -> (mean (t, d), var (t, d)): the
    mixture variance is the mean within-model variance plus the spread of
    the per-model means (per output dim).  Within-model variances are
    clamped at 0 first — quantized (bf16/f16) states can round a
    near-zero ``k** − quad`` slightly negative; a no-op at full precision.
    """
    mu = jnp.mean(mean, axis=0)
    v = (jnp.mean(jnp.maximum(var, 0), axis=0)[:, None]
         + jnp.var(mean, axis=0))
    return mu, v


class MultiPredictEngine:
    """Serve N same-shape PredictiveStates from one compiled executable.

    The states are stacked into a single batched pytree and the block scan
    is ``vmap``-ed over the model axis, so an ensemble or an A/B fleet
    shares one jitted program (and, on a mesh, one replicated state buffer)
    instead of N engines with N executables.  Queries are answered by every
    model at once: ``predict`` returns ``(mean (N, t, d), var (N, t))``.

    Args:
      states: a sequence of PredictiveStates (stacked here), or an
        already-stacked state with a leading model axis (e.g. from
        :func:`stack_states`, or a previous engine's ``.state``).
      block_size / mesh / data_axes / donate / compute_dtype: as
        :class:`PredictEngine` — queries shard over the mesh, the stacked
        state is replicated, predictions stay row-local (no collective).

    XLA-backend only: the fused Pallas predict op is per-model, and batching
    the model axis into its grid is not in its tiling contract.
    """

    def __init__(self, states, block_size: int = 256, mesh=None,
                 data_axes=("data",), kernel_backend: str = "xla",
                 donate: bool = False, compute_dtype=None):
        if block_size < 1:
            raise ValueError(f"block_size must be >= 1, got {block_size}")
        if kernel_backend != "xla":
            raise ValueError(
                "MultiPredictEngine is XLA-only (the fused Pallas predict "
                f"kernel is per-model), got {kernel_backend!r}")
        self.kernel_backend = kernel_backend
        if isinstance(states, posterior.PredictiveState):
            stacked = states
        else:
            stacked = stack_states(states)
        if stacked.z.ndim != 3:
            raise ValueError(
                "expected a stacked state with a leading model axis, got "
                f"z of shape {stacked.z.shape}")
        self.n_models = stacked.z.shape[0]
        self.block_size = block_size
        self.mesh = mesh
        self.data_axes = tuple(data_axes)
        self.donate = donate
        self.n_shards = 1 if mesh is None else num_shards(mesh, self.data_axes)
        self.compute_dtype = _resolve_compute_dtype(stacked.z.dtype,
                                                    compute_dtype)

        self.state = stacked
        cstate = (stacked if jnp.dtype(stacked.z.dtype) == self.compute_dtype
                  else stacked.astype(self.compute_dtype))
        if mesh is not None:
            self._data_spec = P(self.data_axes)
            self._rep_spec = P()
            cstate = jax.device_put(cstate, NamedSharding(mesh, self._rep_spec))
        self._cstate = cstate

        scan = _make_scan_blocks(posterior.predict_mean_var, self.block_size)
        run = jax.vmap(scan, in_axes=(0, None))   # over the model axis
        if mesh is not None:
            out = P(None, self.data_axes)
            run = shard_map(run, mesh=mesh,
                            in_specs=(self._rep_spec, self._data_spec),
                            out_specs=(out, out))
        self._run = jax.jit(run, donate_argnums=(1,) if donate else ())

    # `pad_queries` / `run_blocks` / `compute_state` are identical to the
    # single-model engine's (the state argument is simply the stacked tree).
    pad_queries = PredictEngine.pad_queries
    run_blocks = PredictEngine.run_blocks
    compute_state = PredictEngine.compute_state

    # -- hot swap -----------------------------------------------------------
    def swap_state(self, states) -> None:
        """Atomically replace the whole fleet with same-shape states (an
        already-stacked state or a sequence of N) — zero recompilation,
        mirroring :meth:`PredictEngine.swap_state`."""
        stacked = (states if isinstance(states, posterior.PredictiveState)
                   else stack_states(states))
        if stacked.kernel != self.state.kernel:
            raise ValueError(
                "swap_state needs the same kernel expression "
                f"({self.state.kernel} vs {stacked.kernel}) — build a new "
                "engine for a different covariance")
        for a, b in zip(jax.tree.leaves(self.state), jax.tree.leaves(stacked)):
            if a.shape != b.shape:
                raise ValueError(
                    "swap_state needs identical leaf shapes (same N, m, q, d)"
                    f" — got {a.shape} vs {b.shape}; build a new engine for "
                    "a reshaped fleet")
        self.state = stacked
        cstate = (stacked if jnp.dtype(stacked.z.dtype) == self.compute_dtype
                  else stacked.astype(self.compute_dtype))
        if self.mesh is not None:
            cstate = jax.device_put(
                cstate, NamedSharding(self.mesh, self._rep_spec))
        self._cstate = cstate

    def swap_slot(self, index: int, state: posterior.PredictiveState) -> None:
        """Replace ONE model of the fleet in place (an A/B rollout: ship a
        new state into slot ``index`` while the other N-1 keep serving) —
        same zero-recompile contract as :meth:`swap_state`."""
        if not 0 <= index < self.n_models:
            raise ValueError(
                f"slot {index} out of range for a fleet of {self.n_models}")
        if state.kernel != self.state.kernel:
            raise ValueError(
                "swap_slot needs the same kernel expression "
                f"({self.state.kernel} vs {state.kernel})")
        for a, b in zip(jax.tree.leaves(self.state), jax.tree.leaves(state)):
            if a.shape[1:] != b.shape:
                raise ValueError(
                    "swap_slot needs a state matching the fleet's per-model "
                    f"leaf shapes — got {b.shape} for a slot of {a.shape[1:]}")
        stacked = jax.tree.map(
            lambda big, one: big.at[index].set(jnp.asarray(one, big.dtype)),
            self.state, state)
        self.swap_state(stacked)

    def predict(self, xstar, include_noise: bool = False):
        """All models answer the batch: ``(mean (N, t, d), var (N, t))``."""
        xq, t = self.pad_queries(xstar)
        if t == 0:
            n, d = self.n_models, self.state.c2.shape[-1]
            return (jnp.zeros((n, 0, d), self.compute_dtype),
                    jnp.zeros((n, 0), self.compute_dtype))
        mean, var = self._run(self._cstate, xq)
        mean, var = mean[:, :t], var[:, :t]
        if include_noise:
            var = var + jnp.exp(-self._cstate.hyp["log_beta"])[:, None]
        return mean, var

    def __call__(self, xstar, include_noise: bool = False):
        return self.predict(xstar, include_noise=include_noise)

    def predict_mixture(self, xstar, include_noise: bool = False):
        """Equal-weight ensemble moments: ``(mean (t, d), var (t, d))``."""
        mean, var = self.predict(xstar, include_noise=include_noise)
        return mixture_moments(mean, var)
