"""Compositional covariance expressions with psi-statistics dispatch.

The paper's decoupled bound is derived for *any* kernel whose expectations
against a diagonal Gaussian q(X) — the psi statistics — are tractable.  This
module turns the covariance into a swappable **expression**: primitive
kernels are frozen dataclasses (hashable static metadata, safe to close over
in jitted programs and to hang off a :class:`~repro.serve.posterior.
PredictiveState` as pytree aux data) exposing one uniform interface

    K(hyp, a, b)            (n, m)  cross-covariance
    kdiag(hyp, a)           (n,)    diag(K_aa)
    psi0(hyp, mu, s)        (n,)    <k(x_i, x_i)>_q
    psi1(hyp, z, mu, s)     (n, m)  <k(x_i, z_m)>_q
    psi2_per_point(...)     (n, m, m)
    psi2(hyp, z, mu, s, w)  (m, m)  Sum_i w_i <k(x_i,z_a) k(x_i,z_b)>_q

with hyper-parameters carried in the same log-space dict the rest of the
repo uses.  Primitives read their own keys (``log_sf2``/``log_ell``/...)
and ignore others (``log_beta`` rides in the same top-level dict);
combinators nest each child's parameters under ``"k0"``, ``"k1"``, ... so
one pytree carries the whole expression's parameters.

Psi statistics are **analytic where a closed form exists** (`SE-ARD`,
`Linear`, and disjoint-dims compositions) and fall back to tensor-product
**Gauss–Hermite quadrature** otherwise (`Matern32`, `Periodic`,
overlapping-dims compositions) — the GPflow-expectations dispatch pattern.
Combinator dispatch is structural:

  * ``Sum.psi0/psi1`` are exact by linearity of expectation, whatever the
    children do.
  * ``Sum.psi2`` cross terms ``<k_i(x,z_a) k_j(x,z_b)>`` factor into
    ``psi1_i ⊗ psi1_j`` (the product-of-expectations identity) when the two
    children act on **disjoint** ``dims`` — under a diagonal q(X) those
    coordinates are independent.  Overlapping children quadrature the
    composite expression instead (exact to quadrature order).
  * ``Product`` psi stats factor the same way for pairwise-disjoint
    children, else quadrature.

Quadrature integrates only over the expression's ``support_dims`` (the
union of active dims), with a tensor-product grid — O(order^|dims|) nodes,
fine for the low-dimensional latent spaces the GPLVM targets; keep
``quad_order`` modest and dims few (docs/kernels.md#kernel-zoo).

Serialisation: ``to_spec()`` / :func:`kernel_from_spec` round-trip an
expression through a JSON-able dict (the checkpoint sidecar format), so a
serving process restores the right covariance with no model code.
"""
from __future__ import annotations

import dataclasses
import json
from dataclasses import dataclass
from typing import ClassVar

import jax
import jax.numpy as jnp
import numpy as np

from . import gp_kernels as gpk

Array = jax.Array

# -- registry ----------------------------------------------------------------

_REGISTRY: dict[str, type] = {}


def register_kernel(name: str):
    """Class decorator: add a kernel expression class to the spec registry."""

    def wrap(cls):
        cls.kind = name
        _REGISTRY[name] = cls
        return cls

    return wrap


def kernel_names() -> tuple[str, ...]:
    """Registered expression kinds (primitives + combinators)."""
    return tuple(sorted(_REGISTRY))


# -- Gauss–Hermite quadrature fallback ---------------------------------------

def _gh_grid(n_dims: int, order: int):
    """Tensor-product Gauss–Hermite grid for E_{t~N(0,I)}[f(t)] over
    ``n_dims`` dims: returns unit-Gaussian nodes (J, n_dims) and weights
    (J,) with J = order**n_dims.  Static (numpy, trace-time)."""
    t, w = np.polynomial.hermite.hermgauss(order)   # ∫ e^{-t²} f(t) dt
    t = t * np.sqrt(2.0)                            # unit-Gaussian nodes
    w = w / np.sqrt(np.pi)
    grids = np.meshgrid(*([t] * n_dims), indexing="ij")
    nodes = np.stack([g.ravel() for g in grids], axis=-1)
    ws = np.ones((order ** n_dims,))
    for g in np.meshgrid(*([w] * n_dims), indexing="ij"):
        ws = ws * g.ravel()
    return nodes, ws


def _gh_points(kernel: "Kernel", mu: Array, s: Array):
    """Sample points of q(X) on the kernel's support dims: returns
    ``(xs (n, J, q), ws (J,))`` with non-support dims pinned at mu (the
    kernel never reads them)."""
    n, q = mu.shape
    dims = kernel.support_dims(q)
    nodes, ws = _gh_grid(len(dims), kernel.quad_order)
    nodes = jnp.asarray(nodes, mu.dtype)            # (J, |dims|)
    ws = jnp.asarray(ws, mu.dtype)
    idx = jnp.asarray(dims)
    sd = jnp.sqrt(s[:, idx])                        # (n, |dims|)
    xs = jnp.broadcast_to(mu[:, None, :], (n, nodes.shape[0], q))
    vals = mu[:, None, idx] + sd[:, None, :] * nodes[None, :, :]
    return xs.at[:, :, idx].set(vals), ws


def psi0_quad(kernel: "Kernel", hyp: dict, mu: Array, s: Array) -> Array:
    """<k(x_i, x_i)> by Gauss–Hermite quadrature: (n,)."""
    xs, ws = _gh_points(kernel, mu, s)
    n, j, q = xs.shape
    kd = kernel.kdiag(hyp, xs.reshape(n * j, q)).reshape(n, j)
    return kd @ ws


def psi1_quad(kernel: "Kernel", hyp: dict, z: Array, mu: Array,
              s: Array) -> Array:
    """<k(x_i, z_m)> by Gauss–Hermite quadrature: (n, m)."""
    xs, ws = _gh_points(kernel, mu, s)
    n, j, q = xs.shape
    k = kernel.K(hyp, xs.reshape(n * j, q), z).reshape(n, j, -1)
    return jnp.einsum("j,njm->nm", ws, k)


def psi2_per_point_quad(kernel: "Kernel", hyp: dict, z: Array, mu: Array,
                        s: Array) -> Array:
    """<k(x_i, z_a) k(x_i, z_b)> by Gauss–Hermite quadrature: (n, m, m)."""
    xs, ws = _gh_points(kernel, mu, s)
    n, j, q = xs.shape
    k = kernel.K(hyp, xs.reshape(n * j, q), z).reshape(n, j, -1)
    return jnp.einsum("j,nja,njb->nab", ws, k, k)


# -- the expression interface ------------------------------------------------

@dataclass(frozen=True)
class Kernel:
    """Base covariance expression.  Frozen/hashable: instances are static
    *structure* — all numbers live in the ``hyp`` dict pytree."""

    kind: ClassVar[str] = "?"

    # Every expression carries a quadrature order for its fallback psi
    # stats; analytic expressions never consult it.
    quad_order: ClassVar[int] = 11

    # -- covariance ---------------------------------------------------------
    def K(self, hyp: dict, a: Array, b: Array) -> Array:
        raise NotImplementedError

    def kdiag(self, hyp: dict, a: Array) -> Array:
        raise NotImplementedError

    # -- psi statistics (defaults: quadrature fallback) ---------------------
    def psi0(self, hyp: dict, mu: Array, s: Array) -> Array:
        return psi0_quad(self, hyp, mu, s)

    def psi1(self, hyp: dict, z: Array, mu: Array, s: Array) -> Array:
        return psi1_quad(self, hyp, z, mu, s)

    def psi2_per_point(self, hyp: dict, z: Array, mu: Array,
                       s: Array) -> Array:
        return psi2_per_point_quad(self, hyp, z, mu, s)

    def psi2(self, hyp: dict, z: Array, mu: Array, s: Array,
             w: Array) -> Array:
        """Weighted Psi2 (the D statistic).  The default contracts the
        per-point form — exactly what the pre-refactor map step did."""
        p2 = self.psi2_per_point(hyp, z, mu, s)
        return jnp.einsum("i,iab->ab", w, p2)

    # -- structure metadata -------------------------------------------------
    def support_dims(self, q: int) -> tuple[int, ...]:
        """Input dims this expression reads (quadrature integrates these)."""
        dims = getattr(self, "dims", None)
        return tuple(range(q)) if dims is None else tuple(dims)

    def analytic_psi(self) -> bool:
        """True when ALL psi statistics use closed forms (no quadrature)."""
        return False

    def variance_scale(self, hyp: dict) -> Array:
        """An O(signal-variance) scalar for jitter scaling (unit-free
        Cholesky jitter, the ``_chol_kmm`` convention)."""
        raise NotImplementedError

    # -- hyper-parameters ---------------------------------------------------
    def hyp_shapes(self, q: int) -> dict:
        """Shape tree of this expression's parameter subtree (checkpoint
        restore templates; ``log_beta`` is model-level, not included)."""
        raise NotImplementedError

    def default_hyp(self, q: int, var_y: float = 1.0) -> dict:
        """Data-driven init of the parameter subtree (numpy, host-side)."""
        raise NotImplementedError

    # -- serialisation ------------------------------------------------------
    def to_spec(self) -> dict:
        """JSON-able structural spec; :func:`kernel_from_spec` inverts it."""
        out = {"kind": self.kind}
        for f in dataclasses.fields(self):
            v = getattr(self, f.name)
            if f.name == "parts":
                v = [p.to_spec() for p in v]
            elif isinstance(v, tuple):
                v = list(v)
            out[f.name] = v
        return out

    def __str__(self) -> str:
        return json.dumps(self.to_spec())


def _as_dims(dims) -> tuple[int, ...] | None:
    return None if dims is None else tuple(int(d) for d in dims)


def _sl(a: Array, dims: tuple[int, ...] | None) -> Array:
    """Slice the active dims off the trailing axis (no-op when None, so the
    default full-width path stays bitwise-identical to the legacy one)."""
    return a if dims is None else a[..., jnp.asarray(dims)]


def _q_eff(q: int, dims) -> int:
    return q if dims is None else len(dims)


# -- primitives --------------------------------------------------------------

@register_kernel("se")
@dataclass(frozen=True)
class SEARD(Kernel):
    """Squared-exponential ARD — the paper's kernel; all psi stats closed
    form (delegates to the ``gp_kernels`` SE math, so the default expression
    reproduces the legacy path bitwise)."""

    dims: tuple[int, ...] | None = None

    def __post_init__(self):
        object.__setattr__(self, "dims", _as_dims(self.dims))

    def K(self, hyp, a, b):
        return gpk.se_kernel(hyp, _sl(a, self.dims), _sl(b, self.dims))

    def kdiag(self, hyp, a):
        return gpk.se_kdiag(hyp, _sl(a, self.dims))

    def psi0(self, hyp, mu, s):
        return gpk.se_psi0(hyp, _sl(mu, self.dims), _sl(s, self.dims))

    def psi1(self, hyp, z, mu, s):
        return gpk.se_psi1(hyp, _sl(z, self.dims), _sl(mu, self.dims),
                           _sl(s, self.dims))

    def psi2_per_point(self, hyp, z, mu, s):
        return gpk.psi2_per_point(hyp, _sl(z, self.dims), _sl(mu, self.dims),
                                  _sl(s, self.dims))

    def analytic_psi(self):
        return True

    def variance_scale(self, hyp):
        return jnp.exp(hyp["log_sf2"])

    def hyp_shapes(self, q):
        return {"log_sf2": (), "log_ell": (_q_eff(q, self.dims),)}

    def default_hyp(self, q, var_y=1.0):
        qe = _q_eff(q, self.dims)
        return {"log_sf2": np.log(var_y),
                "log_ell": np.ones((qe,)) * 0.5 * np.log(max(qe, 1))}


@register_kernel("matern32")
@dataclass(frozen=True)
class Matern32(Kernel):
    """Matérn-3/2 with ARD lengthscales: ``sf2 (1 + √3 r) exp(−√3 r)`` with
    ``r² = Σ_q d_q²/ℓ_q²``.  No closed-form psi statistics (the |r| kink) —
    psi0/1/2 run the Gauss–Hermite fallback at ``quad_order``."""

    dims: tuple[int, ...] | None = None
    quad_order: int = 11

    def __post_init__(self):
        object.__setattr__(self, "dims", _as_dims(self.dims))

    def K(self, hyp, a, b):
        ell = jnp.exp(hyp["log_ell"])
        sf2 = jnp.exp(hyp["log_sf2"])
        r2 = gpk.sqdist(_sl(a, self.dims) / ell, _sl(b, self.dims) / ell)
        # Safe sqrt: clamp keeps the derivative finite at coincident points.
        r = jnp.sqrt(jnp.maximum(r2, 1e-36))
        sr3 = jnp.sqrt(3.0) * r
        return sf2 * (1.0 + sr3) * jnp.exp(-sr3)

    def kdiag(self, hyp, a):
        sf2 = jnp.exp(hyp["log_sf2"])
        return jnp.full(a.shape[:-1], sf2, dtype=a.dtype)

    def psi0(self, hyp, mu, s):
        # <k(x,x)> = sf2 exactly (stationary kernel) — skip the quadrature.
        del s
        sf2 = jnp.exp(hyp["log_sf2"])
        return jnp.full(mu.shape[:-1], sf2, dtype=mu.dtype)

    def variance_scale(self, hyp):
        return jnp.exp(hyp["log_sf2"])

    def hyp_shapes(self, q):
        return {"log_sf2": (), "log_ell": (_q_eff(q, self.dims),)}

    def default_hyp(self, q, var_y=1.0):
        qe = _q_eff(q, self.dims)
        return {"log_sf2": np.log(var_y),
                "log_ell": np.ones((qe,)) * 0.5 * np.log(max(qe, 1))}


@register_kernel("linear")
@dataclass(frozen=True)
class Linear(Kernel):
    """Linear (dot-product) kernel with per-dim variances:
    ``k(x, x') = Σ_q sv2_q x_q x'_q``.  All psi stats closed form under a
    diagonal q(X): second moments of a Gaussian are analytic."""

    dims: tuple[int, ...] | None = None

    def __post_init__(self):
        object.__setattr__(self, "dims", _as_dims(self.dims))

    def _sv2(self, hyp):
        return jnp.exp(hyp["log_sv2"])

    def K(self, hyp, a, b):
        return (_sl(a, self.dims) * self._sv2(hyp)) @ _sl(b, self.dims).T

    def kdiag(self, hyp, a):
        ad = _sl(a, self.dims)
        return jnp.sum(self._sv2(hyp) * ad * ad, axis=-1)

    def psi0(self, hyp, mu, s):
        mud, sd = _sl(mu, self.dims), _sl(s, self.dims)
        return jnp.sum(self._sv2(hyp) * (mud * mud + sd), axis=-1)

    def psi1(self, hyp, z, mu, s):
        del s
        return (_sl(mu, self.dims) * self._sv2(hyp)) @ _sl(z, self.dims).T

    def psi2_per_point(self, hyp, z, mu, s):
        # <k(x,za) k(x,zb)> = (zaᵀΛμ)(zbᵀΛμ) + zaᵀ Λ diag(S) Λ zb
        sv2 = self._sv2(hyp)
        zd, mud, sd = _sl(z, self.dims), _sl(mu, self.dims), _sl(s, self.dims)
        p1 = (mud * sv2) @ zd.T                               # (n, m)
        t1 = p1[:, :, None] * p1[:, None, :]
        t2 = jnp.einsum("aq,nq,bq->nab", zd, (sv2 * sv2) * sd, zd)
        return t1 + t2

    def analytic_psi(self):
        return True

    def variance_scale(self, hyp):
        return jnp.mean(self._sv2(hyp))

    def hyp_shapes(self, q):
        return {"log_sv2": (_q_eff(q, self.dims),)}

    def default_hyp(self, q, var_y=1.0):
        qe = _q_eff(q, self.dims)
        return {"log_sv2": np.full((qe,), np.log(var_y / max(qe, 1)))}


@register_kernel("periodic")
@dataclass(frozen=True)
class Periodic(Kernel):
    """Exp-sine-squared (MacKay) kernel, ARD per dim:
    ``k = sf2 exp(−2 Σ_q sin²(π d_q / p_q) / ℓ_q²)``.  Psi statistics via
    Gauss–Hermite quadrature (the sin² warp has no Gaussian closed form)."""

    dims: tuple[int, ...] | None = None
    quad_order: int = 11

    def __post_init__(self):
        object.__setattr__(self, "dims", _as_dims(self.dims))

    def K(self, hyp, a, b):
        ell2 = jnp.exp(2.0 * hyp["log_ell"])
        per = jnp.exp(hyp["log_period"])
        sf2 = jnp.exp(hyp["log_sf2"])
        d = _sl(a, self.dims)[:, None, :] - _sl(b, self.dims)[None, :, :]
        sin2 = jnp.sin(jnp.pi * d / per) ** 2
        return sf2 * jnp.exp(-2.0 * jnp.sum(sin2 / ell2, axis=-1))

    def kdiag(self, hyp, a):
        sf2 = jnp.exp(hyp["log_sf2"])
        return jnp.full(a.shape[:-1], sf2, dtype=a.dtype)

    def psi0(self, hyp, mu, s):
        del s
        sf2 = jnp.exp(hyp["log_sf2"])
        return jnp.full(mu.shape[:-1], sf2, dtype=mu.dtype)

    def variance_scale(self, hyp):
        return jnp.exp(hyp["log_sf2"])

    def hyp_shapes(self, q):
        qe = _q_eff(q, self.dims)
        return {"log_sf2": (), "log_ell": (qe,), "log_period": (qe,)}

    def default_hyp(self, q, var_y=1.0):
        qe = _q_eff(q, self.dims)
        return {"log_sf2": np.log(var_y), "log_ell": np.zeros((qe,)),
                "log_period": np.zeros((qe,))}


# -- combinators -------------------------------------------------------------

def _sub(hyp: dict, i: int) -> dict:
    return hyp[f"k{i}"]


def _pairwise_disjoint(parts) -> bool:
    """True when every child declares ``dims`` and no dim is shared — the
    condition under which a diagonal q(X) makes the children independent
    random functions of x, so cross-expectations factor."""
    seen: set[int] = set()
    for p in parts:
        dims = getattr(p, "dims", None)
        if dims is None:
            return False
        if seen & set(dims):
            return False
        seen |= set(dims)
    return True


@dataclass(frozen=True, init=False)
class _Combinator(Kernel):
    parts: tuple[Kernel, ...]
    quad_order: int

    def __init__(self, *parts: Kernel, quad_order: int = 11):
        if len(parts) < 2:
            raise ValueError(
                f"{type(self).__name__} needs >= 2 child kernels, got "
                f"{len(parts)}")
        object.__setattr__(self, "parts", tuple(parts))
        object.__setattr__(self, "quad_order", int(quad_order))

    def support_dims(self, q):
        dims: set[int] = set()
        for p in self.parts:
            dims |= set(p.support_dims(q))
        return tuple(sorted(dims))

    def hyp_shapes(self, q):
        return {f"k{i}": p.hyp_shapes(q) for i, p in enumerate(self.parts)}

    def to_spec(self):
        return {"kind": self.kind,
                "parts": [p.to_spec() for p in self.parts],
                "quad_order": self.quad_order}


@register_kernel("sum")
@dataclass(frozen=True, init=False)
class Sum(_Combinator):
    """``k = Σ_i k_i``.  psi0/psi1 are exact by linearity; psi2 cross terms
    factor (product-of-expectations) for disjoint-dims children, else the
    composite runs the quadrature fallback."""

    def K(self, hyp, a, b):
        return sum(p.K(_sub(hyp, i), a, b) for i, p in enumerate(self.parts))

    def kdiag(self, hyp, a):
        return sum(p.kdiag(_sub(hyp, i), a)
                   for i, p in enumerate(self.parts))

    def psi0(self, hyp, mu, s):
        return sum(p.psi0(_sub(hyp, i), mu, s)
                   for i, p in enumerate(self.parts))

    def psi1(self, hyp, z, mu, s):
        return sum(p.psi1(_sub(hyp, i), z, mu, s)
                   for i, p in enumerate(self.parts))

    def psi2_per_point(self, hyp, z, mu, s):
        if not _pairwise_disjoint(self.parts):
            return psi2_per_point_quad(self, hyp, z, mu, s)
        p1s = [p.psi1(_sub(hyp, i), z, mu, s)
               for i, p in enumerate(self.parts)]
        out = sum(p.psi2_per_point(_sub(hyp, i), z, mu, s)
                  for i, p in enumerate(self.parts))
        for i in range(len(self.parts)):
            for j in range(i + 1, len(self.parts)):
                cross = p1s[i][:, :, None] * p1s[j][:, None, :]
                out = out + cross + jnp.swapaxes(cross, 1, 2)
        return out

    def analytic_psi(self):
        return (all(p.analytic_psi() for p in self.parts)
                and _pairwise_disjoint(self.parts))

    def variance_scale(self, hyp):
        return sum(p.variance_scale(_sub(hyp, i))
                   for i, p in enumerate(self.parts))

    def default_hyp(self, q, var_y=1.0):
        share = var_y / len(self.parts)
        return {f"k{i}": p.default_hyp(q, share)
                for i, p in enumerate(self.parts)}


@register_kernel("product")
@dataclass(frozen=True, init=False)
class Product(_Combinator):
    """``k = Π_i k_i``.  All psi stats factor into per-child products for
    pairwise-disjoint children (independent coordinates under diagonal
    q(X)); overlapping children run the quadrature fallback."""

    def K(self, hyp, a, b):
        out = self.parts[0].K(_sub(hyp, 0), a, b)
        for i, p in enumerate(self.parts[1:], start=1):
            out = out * p.K(_sub(hyp, i), a, b)
        return out

    def kdiag(self, hyp, a):
        out = self.parts[0].kdiag(_sub(hyp, 0), a)
        for i, p in enumerate(self.parts[1:], start=1):
            out = out * p.kdiag(_sub(hyp, i), a)
        return out

    def _prod(self, terms):
        out = terms[0]
        for t in terms[1:]:
            out = out * t
        return out

    def psi0(self, hyp, mu, s):
        if not _pairwise_disjoint(self.parts):
            return psi0_quad(self, hyp, mu, s)
        return self._prod([p.psi0(_sub(hyp, i), mu, s)
                           for i, p in enumerate(self.parts)])

    def psi1(self, hyp, z, mu, s):
        if not _pairwise_disjoint(self.parts):
            return psi1_quad(self, hyp, z, mu, s)
        return self._prod([p.psi1(_sub(hyp, i), z, mu, s)
                           for i, p in enumerate(self.parts)])

    def psi2_per_point(self, hyp, z, mu, s):
        if not _pairwise_disjoint(self.parts):
            return psi2_per_point_quad(self, hyp, z, mu, s)
        return self._prod([p.psi2_per_point(_sub(hyp, i), z, mu, s)
                           for i, p in enumerate(self.parts)])

    def analytic_psi(self):
        return (all(p.analytic_psi() for p in self.parts)
                and _pairwise_disjoint(self.parts))

    def variance_scale(self, hyp):
        return self._prod([p.variance_scale(_sub(hyp, i))
                           for i, p in enumerate(self.parts)])

    def default_hyp(self, q, var_y=1.0):
        share = var_y ** (1.0 / len(self.parts))
        return {f"k{i}": p.default_hyp(q, share)
                for i, p in enumerate(self.parts)}


# -- defaults & dispatch helpers ---------------------------------------------

SE_ARD = SEARD()


def default_kernel() -> SEARD:
    """The repo-wide default covariance (the paper's SE-ARD, full width)."""
    return SE_ARD


def as_kernel(kernel) -> Kernel:
    """Normalise a ``kernel=`` argument: None -> SE-ARD default; a spec
    string/dict -> parsed expression; an expression -> itself."""
    if kernel is None:
        return SE_ARD
    if isinstance(kernel, Kernel):
        return kernel
    if isinstance(kernel, (str, dict)):
        return kernel_from_spec(kernel)
    raise TypeError(f"not a kernel expression: {kernel!r}")


def is_fused_se(kernel) -> bool:
    """True when ``kernel`` is the full-width SE-ARD — the expression the
    fused Pallas kernels (reg_stats / psi_stats / predict) specialise; the
    ops-level dispatch shims keep the fast path exactly for this case and
    fall back to the XLA expression path otherwise."""
    kernel = as_kernel(kernel)
    return isinstance(kernel, SEARD) and kernel.dims is None


def kernel_from_spec(spec: str | dict) -> Kernel:
    """Inverse of ``Kernel.to_spec()``.  Accepts the JSON string form, and a
    bare kind name ("se", "matern32", ...) as config-file shorthand for
    that primitive at its defaults."""
    if isinstance(spec, str):
        spec = json.loads(spec) if spec.lstrip().startswith(
            ("{", "[")) else {"kind": spec}
    spec = dict(spec)
    kind = spec.pop("kind")
    try:
        cls = _REGISTRY[kind]
    except KeyError:
        raise ValueError(
            f"unknown kernel kind {kind!r}; registered: {kernel_names()}"
        ) from None
    if issubclass(cls, _Combinator):
        parts = [kernel_from_spec(p) for p in spec.pop("parts")]
        return cls(*parts, **spec)
    if spec.get("dims") is not None:
        spec["dims"] = tuple(spec["dims"])
    return cls(**spec)


def full_hyp_shapes(kernel: Kernel, q: int) -> dict:
    """The model-level hyper-parameter shape tree: the expression's subtree
    plus the noise precision (checkpoint restore templates)."""
    return {**as_kernel(kernel).hyp_shapes(q), "log_beta": ()}
