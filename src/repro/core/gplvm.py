"""Bayesian GPLVM (Titsias & Lawrence 2010) via the re-parametrised bound.

Latent inputs get a factorised Gaussian ``q(X_i) = N(mu_i, diag(S_i))``; the
psi statistics replace kernel evaluations and the KL term appears in the
bound. Optimisation follows the paper: SCG over the global parameters G =
(hyp, Z) and the local parameters L = (mu, log S). Two schedules:

  * ``fit(joint=True)``  — one SCG over (G, L) jointly (what GPy does).
  * ``fit(joint=False)`` — the paper's alternation: the central node
    optimises G while end-point nodes optimise their L_k in parallel;
    here sequentially interleaved G-steps / L-steps of SCG.

Both converge to the same stationary points; the alternating schedule is the
one that parallelises with zero extra communication (L-gradients are shard
local).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.flatten_util import ravel_pytree

from . import bound as bound_mod
from . import covariance as cov
from . import init_utils
from .posterior_cache import PosteriorCacheMixin
from .scg import scg
from .stats import partial_stats_chunked


class BayesianGPLVM(PosteriorCacheMixin):
    """``chunk_size``: if set, the map step streams rows in blocks of this
    many points (``stats.partial_stats_chunked``), bounding peak memory at
    O(chunk_size * m^2) instead of the monolithic O(n * m^2) psi2 tensor —
    the GPLVM path's dominant allocation. Same bound to float precision.

    ``batch_blocks``: default minibatch size (in blocks) for
    :meth:`fit_svi` — per-step cost O(batch_blocks * chunk_size * m²)
    instead of the exact scan's O(n * m²).  Note the per-point KL(q(X_i))
    stat is reweighted along with the data terms (it is a sum over points;
    see docs/training.md), and a step's gradients touch only the sampled
    blocks' (mu, log_s) — unsampled rows see zero gradient but still drift
    briefly under Adam's geometrically-decaying first moment until their
    block is sampled again."""

    def __init__(self, y: np.ndarray, q: int, num_inducing: int = 50,
                 jitter: float = 1e-6, seed: int = 0, s0: float = 0.5,
                 chunk_size: int | None = None,
                 batch_blocks: int | None = None,
                 kernel=None):
        self.y = jnp.asarray(y, jnp.float64)
        self.n, self.d = y.shape
        self.q = q
        self.jitter = jitter
        self.chunk_size = chunk_size
        self.batch_blocks = batch_blocks
        self.kernel = cov.as_kernel(kernel)
        mu0 = init_utils.pca(np.asarray(y), q)
        z0 = init_utils.kmeans(mu0, num_inducing, seed=seed)
        hyp0 = init_utils.default_hyp_for(self.kernel, np.asarray(y), q)
        self.params = {
            "hyp": jax.tree.map(lambda v: jnp.asarray(v, jnp.float64), hyp0),
            "z": jnp.asarray(z0, jnp.float64),
            "mu": jnp.asarray(mu0, jnp.float64),
            "log_s": jnp.full((self.n, q), np.log(s0), jnp.float64),
        }
        self._init_posterior_caches()   # stats / PredictiveState / engine

        def neg_bound(params, y_):
            st = self._map_stats(
                params["hyp"], params["z"], y_,
                params["mu"], jnp.exp(params["log_s"]))
            return -bound_mod.collapsed_bound(params["hyp"], params["z"], st,
                                              self.d, jitter=self.jitter,
                                              kernel=self.kernel)

        self._neg_vg = jax.jit(jax.value_and_grad(neg_bound))
        # Partial value+grads for the alternating (paper) schedule.
        self._neg_vg_global = jax.jit(jax.value_and_grad(
            lambda g, l, y_: neg_bound({**g, **l}, y_)))
        self._neg_vg_local = jax.jit(jax.value_and_grad(
            lambda l, g, y_: neg_bound({**g, **l}, y_)))

    def _map_stats(self, hyp, z, y, mu, s, batch_blocks=None, key=None):
        return partial_stats_chunked(hyp, z, y, mu, s=s, latent=True,
                                     block_size=self.chunk_size,
                                     batch_blocks=batch_blocks, key=key,
                                     kernel=self.kernel)

    def log_bound(self, params=None) -> float:
        params = self.params if params is None else params
        v, _ = self._neg_vg(params, self.y)
        return -float(v)

    # -- optimisation --------------------------------------------------------
    def fit(self, max_iters: int = 200, joint: bool = True,
            outer_rounds: int = 10, verbose: bool = False):
        if joint:
            return self._fit_joint(max_iters, verbose)
        return self._fit_alternating(max_iters, outer_rounds, verbose)

    def _fit_joint(self, max_iters, verbose):
        flat0, unravel = ravel_pytree(self.params)

        def fg(xf):
            p = unravel(jnp.asarray(xf))
            v, g = self._neg_vg(p, self.y)
            gf, _ = ravel_pytree(g)
            return float(v), np.asarray(gf, np.float64)

        res = scg(fg, np.asarray(flat0, np.float64), max_iters=max_iters)
        self.params = jax.tree.map(jnp.asarray, unravel(jnp.asarray(res.x)))
        self._invalidate_posterior()
        if verbose:
            print(f"GPLVM fit(joint): bound={-res.f:.4f} iters={res.n_iters}")
        return res

    def fit_svi(self, steps: int = 500, lr: float = 1e-2,
                batch_blocks: int | None = None, seed: int = 0,
                verbose: bool = False):
        """Minibatch-stochastic training of ALL parameters (hyp, Z, mu, S).

        Same estimator as ``SGPR.fit_svi`` (sample ``batch_blocks`` row
        blocks, reweight Stats by ``n_blocks / batch_blocks``), with the
        GPLVM's per-point KL reweighted alongside the data-fit stats.  A
        step only receives gradients for the sampled blocks' local
        (mu, log_s) rows; unsampled rows coast on Adam's decaying momentum
        until their block is next sampled — over many steps every block is
        visited.  Returns a ``train.svi.SVIResult``; requires
        ``chunk_size``.
        """
        from ..train.svi import svi_fit

        bb = self.batch_blocks if batch_blocks is None else batch_blocks
        if self.chunk_size is None or bb is None:
            raise ValueError(
                "fit_svi needs chunk_size and batch_blocks — e.g. "
                "BayesianGPLVM(..., chunk_size=1024, batch_blocks=4)")

        def neg(params, key):
            st = self._map_stats(params["hyp"], params["z"], self.y,
                                 params["mu"], jnp.exp(params["log_s"]),
                                 batch_blocks=bb, key=key)
            return -bound_mod.collapsed_bound(params["hyp"], params["z"], st,
                                              self.d, jitter=self.jitter,
                                              kernel=self.kernel)

        res = svi_fit(jax.jit(jax.value_and_grad(neg)), self.params,
                      jax.random.PRNGKey(seed), steps=steps, lr=lr)
        self.params = res.params
        self._invalidate_posterior()
        if verbose:
            print(f"GPLVM fit_svi: est. bound={-res.history[-1]:.4f} "
                  f"steps={res.n_steps} (B={bb} blocks/step)")
        return res

    def _fit_alternating(self, max_iters, outer_rounds, verbose):
        """Paper §3.2 schedule: alternate G-steps and (parallelisable) L-steps."""
        g = {"hyp": self.params["hyp"], "z": self.params["z"]}
        l = {"mu": self.params["mu"], "log_s": self.params["log_s"]}
        inner = max(1, max_iters // (2 * outer_rounds))
        res = None
        for r in range(outer_rounds):
            gf0, unravel_g = ravel_pytree(g)

            def fg_g(xf, _l=l, _u=unravel_g):
                p = _u(jnp.asarray(xf))
                v, gr = self._neg_vg_global(p, _l, self.y)
                grf, _ = ravel_pytree(gr)
                return float(v), np.asarray(grf, np.float64)

            res = scg(fg_g, np.asarray(gf0, np.float64), max_iters=inner)
            g = jax.tree.map(jnp.asarray, unravel_g(jnp.asarray(res.x)))

            lf0, unravel_l = ravel_pytree(l)

            def fg_l(xf, _g=g, _u=unravel_l):
                p = _u(jnp.asarray(xf))
                v, gr = self._neg_vg_local(p, _g, self.y)
                grf, _ = ravel_pytree(gr)
                return float(v), np.asarray(grf, np.float64)

            res = scg(fg_l, np.asarray(lf0, np.float64), max_iters=inner)
            l = jax.tree.map(jnp.asarray, unravel_l(jnp.asarray(res.x)))
            if verbose:
                print(f"  round {r}: bound={-res.f:.4f}")
        self.params = {**g, **l}
        self._invalidate_posterior()
        return res

    # -- posterior / diagnostics ---------------------------------------------
    def _stats(self):
        if self._stats_cache is None:
            self._stats_cache = self._map_stats(
                self.params["hyp"], self.params["z"], self.y,
                self.params["mu"], jnp.exp(self.params["log_s"]))
        return self._stats_cache

    def qu(self) -> bound_mod.QU:
        return bound_mod.optimal_qu(self.params["hyp"], self.params["z"],
                                    self._stats(), jitter=self.jitter,
                                    kernel=self.kernel)

    def predictive_state(self):
        """The frozen ``serve.PredictiveState`` for the current params —
        the q(u) factor solves done once, cached until a fit moves them."""
        if self._pstate_cache is None:
            from ..serve import state_from_model
            self._pstate_cache = state_from_model(self)
        return self._pstate_cache

    def serve_engine(self, block_size: int = 256, mesh=None,
                     data_axes=("data",), kernel_backend: str = "xla",
                     donate: bool = False):
        """A ``serve.PredictEngine`` over the current predictive state:
        queries are *latent* points (t, q) — pair with a q(X*) optimisation
        (:meth:`reconstruct`) to serve observed-space queries.  (The GPLVM
        trains through the psi-statistics path and has no regression
        ``kernel_backend`` to inherit, so the serving backend defaults to
        "xla" here.)"""
        from ..serve import PredictEngine
        return PredictEngine(self.predictive_state(), block_size=block_size,
                             mesh=mesh, data_axes=data_axes,
                             kernel_backend=kernel_backend, donate=donate)

    def ard_weights(self) -> np.ndarray:
        """1/ell^2 — the per-dimension relevance the paper inspects (fig 4/7).

        Defined for lengthscale kernels (a top-level ``log_ell``); composite
        or lengthscale-free expressions raise."""
        if "log_ell" not in self.params["hyp"]:
            raise ValueError(
                "ard_weights needs a kernel with top-level ARD lengthscales "
                f"(hyp has {sorted(self.params['hyp'])}); inspect the "
                "expression's own subtree instead")
        return np.asarray(jnp.exp(-2.0 * self.params["hyp"]["log_ell"]))

    def latent_mean(self) -> np.ndarray:
        return np.asarray(self.params["mu"])

    def reconstruct(self, y_partial: np.ndarray, observed: np.ndarray,
                    iters: int = 50):
        """Reconstruct missing dims of new points (USPS-style, paper §4.5).

        Optimises a q(X*) for each test point against the observed dims only,
        then predicts the full output via the sparse posterior.
        """
        from ..serve import posterior as serve_posterior

        obs = jnp.asarray(observed)
        yp = jnp.asarray(y_partial, jnp.float64)
        t = yp.shape[0]
        # The serving subsystem's frozen state: the q(u) factor solves happen
        # once here, not per objective evaluation inside the SCG loop.
        state = self.predictive_state()
        hyp = self.params["hyp"]

        def neg_obj(local):
            mu, log_s = local["mu"], local["log_s"]
            # Expected log-lik of observed dims under q(X*) + KL, using the
            # trained posterior mean projection (fast approximation).
            mean, var = serve_posterior.predict_mean_var(state, mu)
            beta = jnp.exp(hyp["log_beta"])
            resid = jnp.where(obs[None, :], yp - mean, 0.0)
            n_obs = jnp.sum(obs)
            ll = (-0.5 * beta * jnp.sum(resid * resid)
                  - 0.5 * beta * n_obs * jnp.sum(var)
                  + 0.5 * t * n_obs * hyp["log_beta"])
            s = jnp.exp(log_s)
            kl = 0.5 * jnp.sum(s + mu * mu - log_s - 1.0)
            return -(ll - kl)

        # Init q(X*) at the training latent whose observed dims best match —
        # more data => denser latent coverage => better reconstructions
        # (the mechanism behind the paper's §4.5 "more data helps" finding).
        d2 = jnp.sum(jnp.where(obs[None, None, :],
                               (yp[:, None, :] - self.y[None, :, :]) ** 2,
                               0.0), axis=-1)            # (t, n)
        nn = jnp.argmin(d2, axis=1)
        local = {"mu": self.params["mu"][nn],
                 "log_s": jnp.full((t, self.q), jnp.log(0.1))}
        vg = jax.jit(jax.value_and_grad(neg_obj))
        flat0, unravel = ravel_pytree(local)

        def fg(xf):
            v, g = vg(unravel(jnp.asarray(xf)))
            gf, _ = ravel_pytree(g)
            return float(v), np.asarray(gf, np.float64)

        res = scg(fg, np.asarray(flat0, np.float64), max_iters=iters)
        local = unravel(jnp.asarray(res.x))
        mean, _ = serve_posterior.predict_mean_var(state, local["mu"])
        return np.asarray(mean)
