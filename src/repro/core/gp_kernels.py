"""SE-ARD covariance and its closed-form psi statistics.

The paper (and Titsias & Lawrence 2010) use a squared-exponential ARD kernel

    k(x, x') = sf2 * exp(-0.5 * sum_q (x_q - x'_q)^2 / ell_q^2)

Under a diagonal Gaussian ``q(X_i) = N(mu_i, diag(S_i))`` over latent inputs
the kernel expectations against q — the "psi statistics" — are analytic:

    psi0_i       = <k(x_i, x_i)>_q            (scalar per point)
    Psi1[i, m]   = <k(x_i, z_m)>_q            (n x m)
    psi2_i[m,m'] = <k(x_i, z_m) k(x_i, z_m')>_q   (m x m per point)

Setting S_i = 0, mu_i = X_i recovers plain kernel evaluations — that is the
paper's unifying view of sparse GP regression as a zero-variance GPLVM.

Hyper-parameters are carried in log-space for unconstrained optimisation:
``hyp = {"log_sf2": (), "log_ell": (q,), "log_beta": ()}``.

The canonical names are now ``se_kernel`` / ``se_kdiag`` / ``se_psi0`` /
``se_psi1`` / ``se_psi2`` — the SE-ARD entry of the compositional kernel
layer (``core.covariance``).  The old ``ard_*`` / bare ``psi*`` names remain
as thin deprecation wrappers so existing code, tests, and checkpoints keep
working unchanged.
"""
from __future__ import annotations

import warnings

import jax
import jax.numpy as jnp

Array = jax.Array

_DEPRECATION_WARNED: set = set()


def _warn_deprecated(old: str, new: str) -> None:
    if old in _DEPRECATION_WARNED:
        return
    _DEPRECATION_WARNED.add(old)
    warnings.warn(
        f"repro.core.gp_kernels.{old} is deprecated; use "
        f"gp_kernels.{new} or a covariance.SEARD kernel expression",
        DeprecationWarning, stacklevel=3)


def sqdist(a: Array, b: Array) -> Array:
    """Pairwise squared distances between rows of ``a`` (n,q) and ``b`` (m,q).

    Computed in the input dtype via the expanded form — but *symmetrised*
    first: both operands are shifted by a common (gradient-stopped) anchor
    before expanding.  Squared distances are shift-invariant, and the shift
    removes the catastrophic cancellation the raw ``a²+b²-2ab`` form suffers
    for large-magnitude inputs (offset 1e4 ⇒ a²≈1e8, so f64 rounding of the
    cross term swamps O(1) distances).  Clamped after expansion: the form
    can still go slightly negative in floating point.

    The anchor is ``b``'s first row — NOT a batch mean — so each output row
    depends only on its own inputs: row-locality keeps chunked stats
    bitwise-equal to monolithic ones and padded serving batches
    bitwise-equal to unpadded ones (pad rows must never leak).
    """
    c = (jax.lax.stop_gradient(b[0]) if b.shape[0]
         else jnp.zeros(b.shape[-1:], b.dtype))
    ac = a - c
    bc = b - c
    a2 = jnp.sum(ac * ac, axis=-1)[:, None]
    b2 = jnp.sum(bc * bc, axis=-1)[None, :]
    return jnp.maximum(a2 + b2 - 2.0 * ac @ bc.T, 0.0)


def se_kernel(hyp: dict, a: Array, b: Array) -> Array:
    """K_ab for the SE-ARD kernel; a: (n,q), b: (m,q) -> (n,m)."""
    ell = jnp.exp(hyp["log_ell"])  # (q,)
    sf2 = jnp.exp(hyp["log_sf2"])
    return sf2 * jnp.exp(-0.5 * sqdist(a / ell, b / ell))


def se_kdiag(hyp: dict, a: Array) -> Array:
    """diag(K_aa) — constant sf2 for the SE kernel."""
    sf2 = jnp.exp(hyp["log_sf2"])
    return jnp.full(a.shape[:-1], sf2, dtype=a.dtype)


# ---------------------------------------------------------------------------
# Psi statistics (closed form, SE-ARD, diagonal Gaussian q(X))
# ---------------------------------------------------------------------------

def se_psi0(hyp: dict, mu: Array, s: Array) -> Array:
    """<k(x_i,x_i)> per point: (n,). For SE this is sf2 regardless of q(X)."""
    del s
    sf2 = jnp.exp(hyp["log_sf2"])
    return jnp.full(mu.shape[:-1], sf2, dtype=mu.dtype)


def se_psi1(hyp: dict, z: Array, mu: Array, s: Array) -> Array:
    """<k(x_i, z_m)>: (n, m).

    Psi1[i,m] = sf2 * prod_q (1 + S_iq/l_q^2)^(-1/2)
                    * exp(-0.5 (mu_iq - z_mq)^2 / (l_q^2 + S_iq))
    """
    ell2 = jnp.exp(2.0 * hyp["log_ell"])  # (q,)
    sf2 = jnp.exp(hyp["log_sf2"])
    denom = ell2[None, :] + s  # (n, q)
    # log-normaliser: -0.5 sum_q log(1 + S/l^2)
    lognorm = -0.5 * jnp.sum(jnp.log1p(s / ell2[None, :]), axis=-1)  # (n,)
    d = mu[:, None, :] - z[None, :, :]  # (n, m, q)
    expo = -0.5 * jnp.sum(d * d / denom[:, None, :], axis=-1)  # (n, m)
    return sf2 * jnp.exp(lognorm[:, None] + expo)


def se_psi2(hyp: dict, z: Array, mu: Array, s: Array) -> Array:
    """Sum_i <k(x_i,z_m) k(x_i,z_m')>: (m, m) — the D statistic of the paper.

    Per point:
      psi2_i[m,m'] = sf2^2 * prod_q (1 + 2 S_iq/l_q^2)^(-1/2)
          * exp(-(z_mq - z_m'q)^2 / (4 l_q^2) - (mu_iq - zbar_q)^2 / (l_q^2 + 2 S_iq))
      with zbar = (z_m + z_m') / 2.
    """
    return jnp.sum(psi2_per_point(hyp, z, mu, s), axis=0)


# ---------------------------------------------------------------------------
# Deprecated aliases (pre-compositional-kernel API; warn once, then delegate)
# ---------------------------------------------------------------------------

def ard_kernel(hyp: dict, a: Array, b: Array) -> Array:
    """Deprecated alias of :func:`se_kernel`."""
    _warn_deprecated("ard_kernel", "se_kernel")
    return se_kernel(hyp, a, b)


def ard_kdiag(hyp: dict, a: Array) -> Array:
    """Deprecated alias of :func:`se_kdiag`."""
    _warn_deprecated("ard_kdiag", "se_kdiag")
    return se_kdiag(hyp, a)


def psi0(hyp: dict, mu: Array, s: Array) -> Array:
    """Deprecated alias of :func:`se_psi0`."""
    _warn_deprecated("psi0", "se_psi0")
    return se_psi0(hyp, mu, s)


def psi1(hyp: dict, z: Array, mu: Array, s: Array) -> Array:
    """Deprecated alias of :func:`se_psi1`."""
    _warn_deprecated("psi1", "se_psi1")
    return se_psi1(hyp, z, mu, s)


def psi2(hyp: dict, z: Array, mu: Array, s: Array) -> Array:
    """Deprecated alias of :func:`se_psi2`."""
    _warn_deprecated("psi2", "se_psi2")
    return se_psi2(hyp, z, mu, s)


def psi2_per_point(hyp: dict, z: Array, mu: Array, s: Array) -> Array:
    """(n, m, m) un-summed psi2 — used by tests and the per-point oracle."""
    ell2 = jnp.exp(2.0 * hyp["log_ell"])  # (q,)
    sf2 = jnp.exp(hyp["log_sf2"])
    n, q = mu.shape
    # Static term: -(z_m - z_m')^2 / (4 l^2), summed over q -> (m, m)
    dz = z[:, None, :] - z[None, :, :]
    static = -0.25 * jnp.sum(dz * dz / ell2, axis=-1)  # (m, m)
    zbar = 0.5 * (z[:, None, :] + z[None, :, :])  # (m, m, q)
    denom = ell2[None, :] + 2.0 * s  # (n, q)
    lognorm = -0.5 * jnp.sum(jnp.log1p(2.0 * s / ell2[None, :]), axis=-1)  # (n,)
    d = mu[:, None, None, :] - zbar[None, :, :, :]  # (n, m, m, q)
    expo = -jnp.sum(d * d / denom[:, None, None, :], axis=-1)  # (n, m, m)
    return (sf2 * sf2) * jnp.exp(lognorm[:, None, None] + static[None] + expo)


def psi2_chunked(hyp: dict, z: Array, mu: Array, s: Array, chunk: int = 256) -> Array:
    """Memory-bounded psi2: fold over n in chunks of ``chunk`` (static shapes).

    Materialising the (n, m, m, q) broadcast in :func:`psi2_per_point` is the
    naive formulation the paper ascribes O(n m^2 q) cost to; this streams it.
    """
    n = mu.shape[0]
    pad = (-n) % chunk
    mu_p = jnp.pad(mu, ((0, pad), (0, 0)))
    # Pad S with ones (any positive value) and mask via a weight vector.
    s_p = jnp.pad(s, ((0, pad), (0, 0)), constant_values=1.0)
    w = jnp.pad(jnp.ones((n,), mu.dtype), (0, pad))
    nb = mu_p.shape[0] // chunk
    mu_b = mu_p.reshape(nb, chunk, -1)
    s_b = s_p.reshape(nb, chunk, -1)
    w_b = w.reshape(nb, chunk)

    def body(acc, args):
        mu_c, s_c, w_c = args
        p = psi2_per_point(hyp, z, mu_c, s_c)  # (chunk, m, m)
        return acc + jnp.einsum("c,cab->ab", w_c, p), None

    m = z.shape[0]
    init = jnp.zeros((m, m), mu.dtype)
    acc, _ = jax.lax.scan(body, init, (mu_b, s_b, w_b))
    return acc


def kl_to_standard_normal(mu: Array, s: Array) -> Array:
    """Sum_i KL(N(mu_i, diag(S_i)) || N(0, I)) — the paper's KL term."""
    return 0.5 * jnp.sum(s + mu * mu - jnp.log(s) - 1.0)


def psi2_mxu(hyp: dict, z: Array, mu: Array, s: Array, w: Array,
             chunk: int = 1024) -> Array:
    """Beyond-paper psi2: the MXU-matmul reformulation (see
    kernels/psi_stats) expressed in pure jnp — the exponent decouples data
    from inducing pairs as E = alpha_i + M_i . Zb_ab, so the O(n m^2 q)
    work becomes two (chunk x 2q) @ (2q x m^2) matmuls + exp + one
    (1 x chunk) @ (chunk x m^2) reduce per chunk. Same O() flops, MXU-
    instead of VPU-bound, and never materialises (n, m, m, q).
    """
    ell2 = jnp.exp(2.0 * hyp["log_ell"])
    sf4 = jnp.exp(2.0 * hyp["log_sf2"])
    m, q = z.shape
    n = mu.shape[0]
    zbar = 0.5 * (z[:, None, :] + z[None, :, :])                 # (m,m,q)
    zb_mat = jnp.concatenate([zbar, zbar * zbar], -1).reshape(m * m, 2 * q).T
    dz = z[:, None, :] - z[None, :, :]
    static = (-0.25 * jnp.sum(dz * dz / ell2, -1)).reshape(1, m * m)

    pad = (-n) % chunk
    mu_p = jnp.pad(mu, ((0, pad), (0, 0)))
    s_p = jnp.pad(s, ((0, pad), (0, 0)), constant_values=1.0)
    w_p = jnp.pad(w, (0, pad))
    nb = mu_p.shape[0] // chunk
    mu_b = mu_p.reshape(nb, chunk, q)
    s_b = s_p.reshape(nb, chunk, q)
    w_b = w_p.reshape(nb, chunk)

    def body(acc, args):
        mu_c, s_c, w_c = args
        den = ell2[None, :] + 2.0 * s_c
        inv = 1.0 / den
        lognorm = -0.5 * jnp.sum(jnp.log(den) - jnp.log(ell2)[None, :], 1)
        alpha = lognorm - jnp.sum(mu_c * mu_c * inv, 1)          # (chunk,)
        m_mat = jnp.concatenate([2.0 * mu_c * inv, -inv], 1)     # (chunk,2q)
        e = alpha[:, None] + m_mat @ zb_mat + static
        return acc + (w_c[None, :] @ jnp.exp(e))[0], None

    acc, _ = jax.lax.scan(body, jnp.zeros((m * m,), mu.dtype),
                          (mu_b, s_b, w_b))
    return sf4 * acc.reshape(m, m)


def psi2_mxu_sym(hyp: dict, z: Array, mu: Array, s: Array, w: Array,
                 chunk: int = 1024, tile: int = 64) -> Array:
    """psi2_mxu exploiting symmetry: Psi2 = Psi2^T, so only inducing-pair
    tiles with a <= b are computed and the strict-lower triangle is
    mirrored — ~2x less work on the dominant O(n m^2 q) term (the second
    beyond-paper step in the §Perf GP hillclimb)."""
    ell2 = jnp.exp(2.0 * hyp["log_ell"])
    sf4 = jnp.exp(2.0 * hyp["log_sf2"])
    m, q = z.shape
    n = mu.shape[0]
    pad_m = (-m) % tile
    z_p = jnp.pad(z, ((0, pad_m), (0, 0)))
    mt = z_p.shape[0]
    nt = mt // tile

    pad = (-n) % chunk
    mu_p = jnp.pad(mu, ((0, pad), (0, 0)))
    s_p = jnp.pad(s, ((0, pad), (0, 0)), constant_values=1.0)
    w_p = jnp.pad(w, (0, pad))
    nb = mu_p.shape[0] // chunk
    mu_b = mu_p.reshape(nb, chunk, q)
    s_b = s_p.reshape(nb, chunk, q)
    w_b = w_p.reshape(nb, chunk)

    out = jnp.zeros((mt, mt), mu.dtype)
    for a in range(nt):
        for b_i in range(a, nt):
            za = jax.lax.dynamic_slice_in_dim(z_p, a * tile, tile, 0)
            zb = jax.lax.dynamic_slice_in_dim(z_p, b_i * tile, tile, 0)
            zbar = 0.5 * (za[:, None, :] + zb[None, :, :])
            zb_mat = jnp.concatenate([zbar, zbar * zbar], -1)
            zb_mat = zb_mat.reshape(tile * tile, 2 * q).T
            dz = za[:, None, :] - zb[None, :, :]
            static = (-0.25 * jnp.sum(dz * dz / ell2, -1)).reshape(
                1, tile * tile)

            def body(acc, args, zb_mat=zb_mat, static=static):
                mu_c, s_c, w_c = args
                den = ell2[None, :] + 2.0 * s_c
                inv = 1.0 / den
                lognorm = -0.5 * jnp.sum(
                    jnp.log(den) - jnp.log(ell2)[None, :], 1)
                alpha = lognorm - jnp.sum(mu_c * mu_c * inv, 1)
                m_mat = jnp.concatenate([2.0 * mu_c * inv, -inv], 1)
                e = alpha[:, None] + m_mat @ zb_mat + static
                return acc + (w_c[None, :] @ jnp.exp(e))[0], None

            acc, _ = jax.lax.scan(body, jnp.zeros((tile * tile,), mu.dtype),
                                  (mu_b, s_b, w_b))
            blk = acc.reshape(tile, tile)
            out = jax.lax.dynamic_update_slice(out, blk,
                                               (a * tile, b_i * tile))
            if b_i != a:
                out = jax.lax.dynamic_update_slice(out, blk.T,
                                                   (b_i * tile, a * tile))
    return (sf4 * out)[:m, :m]
