"""Scaled Conjugate Gradient (Moller 1993) — the paper's optimiser.

The paper optimises the global parameters G (kernel hypers, noise, inducing
inputs) and the local GPLVM parameters with SCG "following the original
implementation by (Titsias & Lawrence, 2010)" — i.e. the Netlab/GPy SCG.
This is a faithful port of that algorithm operating on flat vectors, driving
a jitted ``value_and_grad`` oracle. It is a host-side loop: each iteration
costs 1-2 oracle calls, and in the distributed setting each oracle call is
one Map-Reduce round (the paper's two global steps per iteration).

Maximisation is handled by the callers negating their objective.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable

import numpy as np


@dataclass
class SCGResult:
    x: np.ndarray
    f: float
    n_iters: int
    n_evals: int
    history: list = field(default_factory=list)
    converged: bool = False


def scg(
    fg: Callable[[np.ndarray], tuple[float, np.ndarray]],
    x0: np.ndarray,
    max_iters: int = 200,
    xtol: float = 1e-8,
    ftol: float = 1e-8,
    callback: Callable | None = None,
) -> SCGResult:
    """Minimise f via Moller's SCG. ``fg(x) -> (f, grad)``."""
    sigma0 = 1.0e-4
    x = np.asarray(x0, dtype=np.float64).copy()
    fold, gradnew = fg(x)
    fnow = fold
    n_evals = 1
    gradold = gradnew.copy()
    d = -gradnew
    success = True
    nsuccess = 0
    beta, betamin, betamax = 1.0, 1.0e-15, 1.0e100
    history = [float(fold)]
    kappa = mu = theta = 0.0

    for j in range(1, max_iters + 1):
        if success:
            mu = float(d @ gradnew)
            if mu >= 0.0:
                d = -gradnew
                mu = float(d @ gradnew)
            kappa = float(d @ d)
            if kappa < 1.0e-30:
                return SCGResult(x, float(fnow), j, n_evals, history, True)
            sigma = sigma0 / np.sqrt(kappa)
            _, gplus = fg(x + sigma * d)
            n_evals += 1
            theta = float(d @ (gplus - gradnew)) / sigma
            if not np.isfinite(theta):
                # probe landed in a non-finite region: treat as very high
                # curvature so the step shrinks
                theta = beta * kappa

        # Increase effective curvature and evaluate step size alpha.
        delta = theta + beta * kappa
        if delta <= 0.0:
            delta = beta * kappa
            beta = beta - theta / kappa
        alpha = -mu / delta

        # Comparison ratio. Non-finite objective (e.g. Cholesky failure at a
        # wild hyper-parameter step) counts as a failed step and MUST grow
        # beta — NaN comparisons would otherwise freeze the step size.
        fnew, gnew_at_xnew = fg(x + alpha * d)
        n_evals += 1
        if np.isfinite(fnew) and np.all(np.isfinite(gnew_at_xnew)):
            Delta = 2.0 * (fnew - fold) / (alpha * mu)
        else:
            Delta = -1.0
        if Delta >= 0.0:
            success = True
            nsuccess += 1
            x = x + alpha * d
            fnow = fnew
        else:
            success = False
            fnow = fold

        if callback is not None:
            callback(j, x, float(fnow))
        history.append(float(fnow))

        if success:
            if (np.max(np.abs(alpha * d)) < xtol) and (abs(fnew - fold) < ftol):
                return SCGResult(x, float(fnew), j, n_evals, history, True)
            fold = fnew
            gradold = gradnew
            gradnew = gnew_at_xnew
            if float(gradnew @ gradnew) == 0.0:
                return SCGResult(x, float(fnew), j, n_evals, history, True)

        if Delta < 0.25:
            beta = min(4.0 * beta, betamax)
        if Delta > 0.75:
            beta = max(0.5 * beta, betamin)

        if nsuccess == x.size:
            d = -gradnew
            nsuccess = 0
        elif success:
            gamma = float((gradold - gradnew) @ gradnew) / mu
            d = gamma * d - gradnew

    return SCGResult(x, float(fnow), max_iters, n_evals, history, False)
