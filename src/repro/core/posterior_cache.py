"""Shared posterior-cache bookkeeping for the sequential models.

``SGPR`` and ``BayesianGPLVM`` both memoise the posterior chain — reduced
Stats → ``PredictiveState`` (the q(u) factor solves) → the jitted default
``PredictEngine`` holding that state — and every parameter- or
data-mutating path (``fit``, ``fit_svi``, ``update``, ``forget``) must
reset or refresh the whole chain together: a partially invalidated chain
is a stale-serving bug (the regression tests in tests/test_online_updates.py
pin this).  One mixin owns the attribute set so a new mutation path cannot
forget a cache that the others clear.
"""
from __future__ import annotations


class PosteriorCacheMixin:
    """Owns the model's memoised posterior chain and its invalidation."""

    #: every cached posterior quantity, in dependency order
    _POSTERIOR_CACHES = ("_stats_cache", "_pstate_cache", "_engine_cache")

    def _init_posterior_caches(self) -> None:
        for name in self._POSTERIOR_CACHES:
            setattr(self, name, None)

    def _invalidate_posterior(self) -> None:
        """New params (or new data without an incremental refresh) -> every
        cached posterior quantity is stale: the reduced Stats, the q(u)
        factor solves (PredictiveState), and the jitted engine holding that
        state.  EVERY mutation path must route through here (or through
        ``_refresh_posterior``) — never clear a subset by hand."""
        self._init_posterior_caches()

    def _refresh_posterior(self, stats, pstate) -> None:
        """The online-update alternative to invalidation: install a folded
        Stats / incrementally refreshed PredictiveState pair and swap the
        new state into the live engine (no recompilation — same shapes).
        Passing ``pstate=None`` drops the downstream caches instead (they
        rebuild lazily from the new stats)."""
        self._stats_cache = stats
        self._pstate_cache = pstate
        if pstate is None:
            self._engine_cache = None
        elif self._engine_cache is not None:
            self._engine_cache.swap_state(pstate)
