"""Initialisation helpers the paper specifies: PCA for latents, k-means for Z."""
from __future__ import annotations

import numpy as np


def pca(y: np.ndarray, q: int) -> np.ndarray:
    """PCA projection of Y (n, d) to q dims, unit-variance scaled (paper init)."""
    y = np.asarray(y, np.float64)
    yc = y - y.mean(axis=0, keepdims=True)
    # SVD of the centred data; principal components = U * S
    u, s_, _ = np.linalg.svd(yc, full_matrices=False)
    x = u[:, :q] * s_[:q]
    std = x.std(axis=0)
    std[std == 0] = 1.0
    return x / std


def kmeans(x: np.ndarray, k: int, iters: int = 20, seed: int = 0,
           noise: float = 1e-2) -> np.ndarray:
    """Lloyd's k-means centres with a dash of noise — the paper's Z init."""
    rng = np.random.default_rng(seed)
    x = np.asarray(x, np.float64)
    n = x.shape[0]
    if k >= n:
        reps = int(np.ceil(k / n))
        base = np.tile(x, (reps, 1))[:k]
        return base + noise * rng.standard_normal(base.shape)
    centres = x[rng.choice(n, size=k, replace=False)].copy()
    for _ in range(iters):
        d2 = ((x[:, None, :] - centres[None]) ** 2).sum(-1)
        assign = d2.argmin(axis=1)
        for j in range(k):
            pts = x[assign == j]
            if len(pts):
                centres[j] = pts.mean(axis=0)
    return centres + noise * rng.standard_normal(centres.shape)


def default_hyp(y: np.ndarray, q: int) -> dict:
    """Data-driven hyper-parameter init (GPy-style)."""
    var_y = float(np.var(y))
    var_y = var_y if var_y > 0 else 1.0
    return {
        "log_sf2": np.log(var_y),
        "log_ell": np.ones((q,)) * 0.5 * np.log(q),
        "log_beta": -np.log(0.01 * var_y),
    }


def default_hyp_for(kernel, y: np.ndarray, q: int) -> dict:
    """Data-driven init for any covariance expression: the kernel's own
    parameter subtree plus the model-level noise precision.  Reproduces
    :func:`default_hyp` exactly for the SE-ARD default."""
    from .covariance import as_kernel

    var_y = float(np.var(y))
    var_y = var_y if var_y > 0 else 1.0
    return {**as_kernel(kernel).default_hyp(q, var_y),
            "log_beta": -np.log(0.01 * var_y)}
