"""The collapsed variational bound (paper eq. 3.3) and the optimal q(u).

Given the reduced statistics (A, B, C, D, KL) and the inducing inputs Z the
bound is a function of constant-size quantities only:

  log p(Y) >= -nd/2 log 2pi + nd/2 log beta + d/2 log|Kmm| - d/2 log|Kmm+bD|
              - b/2 A - bd/2 B + bd/2 Tr(Kmm^-1 D)
              + b^2/2 Tr(C^T (Kmm + bD)^-1 C) - KL

Numerically we follow the Cholesky-whitened form used by GPy/GPflow: with
L = chol(Kmm) and Bmat = I + b L^-1 D L^-T,

  d/2 log|Kmm| - d/2 log|Kmm + bD| = -d/2 log|Bmat|
  Tr(C^T (Kmm+bD)^-1 C)            = || LB^-1 L^-1 C ||_F^2
  Tr(Kmm^-1 D)                      = sum((L^-1 D L^-T) diag)

which keeps everything PSD-safe under optimisation. The optimal variational
distribution over inducing values (derived analytically in the paper's
supplement) is

  q*(u) = N(u; b Kmm Sigma^-1 C,  Kmm Sigma^-1 Kmm),   Sigma = Kmm + b D

and the predictive posterior at X* follows the standard SGPR form.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import jax.scipy.linalg as jsl

from . import covariance as cov
from .stats import Stats

Array = jax.Array

DEFAULT_JITTER = 1e-6


def _chol_kmm(hyp: dict, z: Array, jitter: float,
              kernel: "cov.Kernel | None" = None) -> Array:
    kernel = cov.as_kernel(kernel)
    m = z.shape[0]
    kmm = kernel.K(hyp, z, z)
    # Jitter scaled by the kernel's signal variance (unit-free).
    vs = kernel.variance_scale(hyp)
    return jnp.linalg.cholesky(kmm + (jitter * vs + 1e-12) * jnp.eye(m, dtype=z.dtype))


def collapsed_bound(
    hyp: dict,
    z: Array,
    stats: Stats,
    d: int,
    jitter: float = DEFAULT_JITTER,
    kernel: "cov.Kernel | None" = None,
) -> Array:
    """Paper eq. 3.3 from reduced statistics. Returns a scalar lower bound."""
    beta = jnp.exp(hyp["log_beta"])
    n = stats.n
    m = z.shape[0]
    L = _chol_kmm(hyp, z, jitter, kernel)

    # W = L^-1 D L^-T   (m, m)
    LiD = jsl.solve_triangular(L, stats.D, lower=True)
    W = jsl.solve_triangular(L, LiD.T, lower=True).T
    Bmat = jnp.eye(m, dtype=z.dtype) + beta * W
    LB = jnp.linalg.cholesky(Bmat)

    # log|Bmat|
    logdet_b = 2.0 * jnp.sum(jnp.log(jnp.diagonal(LB)))
    # Tr(Kmm^-1 D)
    tr_kinv_d = jnp.trace(W)
    # c2 = LB^-1 L^-1 C  -> Tr(C^T Sigma^-1 C) = ||c2||^2 / ... :
    # Sigma = Kmm + bD = L Bmat L^T, Sigma^-1 = L^-T Bmat^-1 L^-1
    LiC = jsl.solve_triangular(L, stats.C, lower=True)      # (m, d)
    c2 = jsl.solve_triangular(LB, LiC, lower=True)          # (m, d)
    quad = jnp.sum(c2 * c2)

    return (
        -0.5 * n * d * jnp.log(2.0 * jnp.pi)
        + 0.5 * n * d * hyp["log_beta"]
        - 0.5 * d * logdet_b
        - 0.5 * beta * stats.A
        - 0.5 * beta * d * stats.B
        + 0.5 * beta * d * tr_kinv_d
        + 0.5 * beta**2 * quad
        - stats.KL
    )


class QU(NamedTuple):
    """Optimal q(u) = N(mean, cov) plus cached Cholesky factors for prediction."""

    mean: Array       # (m, d)
    cov: Array        # (m, m)
    L: Array          # chol(Kmm)
    LB: Array         # chol(I + b L^-1 D L^-T)
    c2: Array         # LB^-1 L^-1 C (whitened info vector)


def optimal_qu(hyp: dict, z: Array, stats: Stats, jitter: float = DEFAULT_JITTER,
               kernel: "cov.Kernel | None" = None) -> QU:
    """The analytically-optimal variational distribution over inducing values."""
    beta = jnp.exp(hyp["log_beta"])
    m = z.shape[0]
    L = _chol_kmm(hyp, z, jitter, kernel)
    LiD = jsl.solve_triangular(L, stats.D, lower=True)
    W = jsl.solve_triangular(L, LiD.T, lower=True).T
    Bmat = jnp.eye(m, dtype=z.dtype) + beta * W
    LB = jnp.linalg.cholesky(Bmat)
    LiC = jsl.solve_triangular(L, stats.C, lower=True)
    c2 = jsl.solve_triangular(LB, LiC, lower=True)          # (m, d)

    # mean = b Kmm Sigma^-1 C = b L LB^-T c2
    mean = beta * (L @ jsl.solve_triangular(LB.T, c2, lower=False))
    # cov = Kmm Sigma^-1 Kmm = (L LB^-T)(L LB^-T)^T
    half = jsl.solve_triangular(LB, L.T, lower=True).T      # L LB^-T : (m, m)
    cov = half @ half.T
    return QU(mean=mean, cov=cov, L=L, LB=LB, c2=c2)


def predict(
    hyp: dict,
    z: Array,
    qu: QU,
    xstar: Array,
    full_cov: bool = False,
    include_noise: bool = False,
    kernel: "cov.Kernel | None" = None,
) -> tuple[Array, Array]:
    """SGPR predictive posterior p(F*|Y) at inputs xstar (t, q).

    mean = b K*m Sigma^-1 C ; var = k** - K*m (Kmm^-1 - Sigma^-1) Km*.
    Returns (mean (t,d), var (t,) or cov (t,t)).
    """
    kernel = cov.as_kernel(kernel)
    beta = jnp.exp(hyp["log_beta"])
    ksm = kernel.K(hyp, xstar, z)                            # (t, m)
    a1 = jsl.solve_triangular(qu.L, ksm.T, lower=True)       # L^-1 Km*
    a2 = jsl.solve_triangular(qu.LB, a1, lower=True)         # LB^-1 L^-1 Km*
    mean = beta * (a2.T @ qu.c2)                             # (t, d)

    if full_cov:
        kss = kernel.K(hyp, xstar, xstar)
        covm = kss - a1.T @ a1 + a2.T @ a2
        if include_noise:
            covm = covm + jnp.eye(xstar.shape[0], dtype=covm.dtype) / beta
        return mean, covm
    kss = kernel.kdiag(hyp, xstar)
    var = kss - jnp.sum(a1 * a1, axis=0) + jnp.sum(a2 * a2, axis=0)
    if include_noise:
        var = var + 1.0 / beta
    return mean, var
