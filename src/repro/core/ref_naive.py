"""Naive O(n^3)/O(n m^2) oracles used ONLY by tests and benchmarks.

* ``exact_lml`` — the exact GP log marginal likelihood (n x n Cholesky).
  Any correct lower bound must sit below it.
* ``titsias_bound_direct`` — the regression bound in its textbook (Titsias
  2009) form  log N(Y; 0, Qnn + beta^-1 I) - beta/2 Tr(Knn - Qnn),
  computed WITHOUT the paper's re-parametrisation. The re-parametrised
  collapsed bound must match this to float precision — that is the paper's
  exactness claim ("inference using the original guarantees").

All oracles take an optional ``kernel`` expression (``core.covariance``;
None = SE-ARD) so the exactness claim can be checked for any covariance.
"""
from __future__ import annotations

import jax.numpy as jnp
import jax.scipy.linalg as jsl

from . import covariance as cov


def exact_lml(hyp: dict, x, y, jitter: float = 1e-8, kernel=None):
    """log N(Y; 0, K + beta^-1 I), summed over the d output dims."""
    kernel = cov.as_kernel(kernel)
    n, d = y.shape
    beta = jnp.exp(hyp["log_beta"])
    k = kernel.K(hyp, x, x) + (1.0 / beta + jitter) * jnp.eye(n, dtype=x.dtype)
    L = jnp.linalg.cholesky(k)
    alpha = jsl.solve_triangular(L, y, lower=True)
    logdet = 2.0 * jnp.sum(jnp.log(jnp.diagonal(L)))
    return -0.5 * d * n * jnp.log(2.0 * jnp.pi) - 0.5 * d * logdet - 0.5 * jnp.sum(alpha * alpha)


def titsias_bound_direct(hyp: dict, x, y, z, jitter: float = 1e-6, kernel=None):
    """Titsias (2009) regression bound, computed the pre-paper way."""
    kernel = cov.as_kernel(kernel)
    n, d = y.shape
    m = z.shape[0]
    beta = jnp.exp(hyp["log_beta"])
    vs = kernel.variance_scale(hyp)
    kmm = kernel.K(hyp, z, z) + (jitter * vs + 1e-12) * jnp.eye(m, dtype=x.dtype)
    knm = kernel.K(hyp, x, z)
    L = jnp.linalg.cholesky(kmm)
    v = jsl.solve_triangular(L, knm.T, lower=True)        # (m, n); Qnn = v^T v
    qnn = v.T @ v
    covn = qnn + (1.0 / beta) * jnp.eye(n, dtype=x.dtype)
    Lc = jnp.linalg.cholesky(covn + jitter * jnp.eye(n, dtype=x.dtype))
    alpha = jsl.solve_triangular(Lc, y, lower=True)
    logdet = 2.0 * jnp.sum(jnp.log(jnp.diagonal(Lc)))
    fit = -0.5 * d * n * jnp.log(2.0 * jnp.pi) - 0.5 * d * logdet - 0.5 * jnp.sum(alpha * alpha)
    trace_term = -0.5 * beta * d * (jnp.sum(kernel.kdiag(hyp, x)) - jnp.trace(qnn))
    return fit + trace_term


def exact_predict(hyp: dict, x, y, xstar, jitter: float = 1e-8, kernel=None):
    """Exact GP posterior mean/var at xstar (for small-n comparisons)."""
    kernel = cov.as_kernel(kernel)
    n = x.shape[0]
    beta = jnp.exp(hyp["log_beta"])
    k = kernel.K(hyp, x, x) + (1.0 / beta + jitter) * jnp.eye(n, dtype=x.dtype)
    L = jnp.linalg.cholesky(k)
    ks = kernel.K(hyp, xstar, x)                          # (t, n)
    a = jsl.solve_triangular(L, ks.T, lower=True)
    alpha = jsl.solve_triangular(L, y, lower=True)
    mean = a.T @ alpha
    var = kernel.kdiag(hyp, xstar) - jnp.sum(a * a, axis=0)
    return mean, var
