"""Rank-k Cholesky update/downdate — the O(m²k) serve-refresh primitive.

Given a lower-triangular ``L`` with ``L Lᵀ = A`` and a factor ``V`` (m, k),
compute the Cholesky factor of ``A ± V Vᵀ`` *without* refactorising the
full m×m matrix: a sequence of k rank-1 sweeps (the LINPACK ``dchud`` /
``dchdd`` Givens scheme), each an O(m²) ``lax.scan`` over columns.  This is
what makes an online posterior refresh (``serve.online``) cost O(m²k) per
ingested/forgotten block instead of the O(m³) of ``jnp.linalg.cholesky`` —
no call to ``cholesky`` appears anywhere in this module (property-tested in
tests/test_chol_update.py).

Downdates can fail: ``A − V Vᵀ`` may be indefinite (removing a block that
was never folded in), or positive-definite but so ill-conditioned that the
sequential sweeps lose it in float error.  Both manifest the same way — a
pivot update ``r² = d² − x²`` falls to (or below) a vanishing fraction of
``d²``.  Rather than raise inside jitted code, every function returns an
``ok`` flag alongside the factor; the sweep keeps going with a clamped
pivot so shapes stay static, and the *caller* (``serve.online``) treats
``ok=False`` as "fall back to a full refactorisation".  The threshold is
relative (``cond_tol``), so it is also a condition-number guard: a downdate
that technically succeeds but leaves ``r²/d² < cond_tol`` is flagged,
because the incremental factor's forward error scales like 1/(r/d).

Updates (``A + V Vᵀ``) always succeed mathematically (``r² ≥ d²``); they
share the flag plumbing only so both directions present one API.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax

Array = jax.Array

# Relative pivot floor for downdates: trip the guard when a pivot would
# shrink below sqrt(cond_tol) of its current magnitude.  1e-8 leaves ~8
# decimal digits in the worst pivot at f64 — conservative, because the
# caller's fallback is exact and cheap relative to serving traffic.
DEFAULT_COND_TOL = 1e-8


def _rank1_sweep(L: Array, x: Array, sign: float, cond_tol: float):
    """One rank-1 pass: chol(L Lᵀ + sign·x xᵀ).  Returns ``(L', ok)``.

    Column j's Givens (update) / hyperbolic (downdate) rotation is applied
    to the trailing columns of ``x`` as a full-vector masked op, so the scan
    is O(m) steps of O(m) work — O(m²) total, matching the dense flop count
    of the classical algorithm.
    """
    m = L.shape[0]
    rows = jnp.arange(m)

    def body(carry, j):
        Lc, xc, ok = carry
        d = Lc[j, j]
        xj = xc[j]
        r2 = d * d + sign * xj * xj
        # Guard: the pivot must stay a non-vanishing fraction of its old
        # magnitude (always true for sign=+1).  Clamp so the sweep can
        # finish with static shapes; the flag invalidates the result.
        floor = cond_tol * d * d
        ok = ok & (r2 > floor)
        r = jnp.sqrt(jnp.maximum(r2, floor))
        c = r / d
        s = xj / d
        below = rows > j
        col = Lc[:, j]
        new_col = jnp.where(below, (col + sign * s * xc) / c, col)
        new_col = new_col.at[j].set(r)
        xc = jnp.where(below, c * xc - s * new_col, xc)
        Lc = Lc.at[:, j].set(new_col)
        return (Lc, xc, ok), None

    (L, _, ok), _ = lax.scan(body, (L, x, jnp.asarray(True)), rows)
    return L, ok


def chol_update_rank_k(L: Array, V: Array,
                       cond_tol: float = DEFAULT_COND_TOL):
    """``chol(L Lᵀ + V Vᵀ)`` in O(m²k).  Returns ``(L', ok)``.

    ``V`` is (m, k) — e.g. ``√β L₀⁻¹ Knmᵀ diag(√w)`` for a newly folded
    block of k points (``serve.online``).  Zero columns (padding rows with
    zero weight) are exact no-ops.  ``ok`` is always True in exact
    arithmetic; it is returned for API symmetry with the downdate.
    """
    return _rank_k(L, V, 1.0, cond_tol)


def chol_downdate_rank_k(L: Array, V: Array,
                         cond_tol: float = DEFAULT_COND_TOL):
    """``chol(L Lᵀ − V Vᵀ)`` in O(m²k).  Returns ``(L', ok)``.

    ``ok=False`` means the downdate is indefinite or too ill-conditioned to
    trust (pivot ratio under ``cond_tol``); the returned factor is then a
    clamped artefact and must be discarded in favour of a refactorisation.
    """
    return _rank_k(L, V, -1.0, cond_tol)


def _rank_k(L: Array, V: Array, sign: float, cond_tol: float):
    V = jnp.asarray(V, L.dtype)
    if V.ndim == 1:
        V = V[:, None]
    return _rank_k_jit(L, V, sign, cond_tol)


# Jitted at module level (sign/cond_tol static) so repeated refreshes with
# the same (m, k) shapes reuse one compiled sweep — an eager lax.scan would
# re-trace per call, swamping the O(m²k) math it exists to save.
@functools.partial(jax.jit, static_argnames=("sign", "cond_tol"))
def _rank_k_jit(L: Array, V: Array, sign: float, cond_tol: float):
    def body(carry, v):
        Lc, ok = carry
        Lc, ok_i = _rank1_sweep(Lc, v, sign, cond_tol)
        return (Lc, ok & ok_i), None

    (L, ok), _ = lax.scan(body, (L, jnp.asarray(True)), V.T)
    return L, ok
