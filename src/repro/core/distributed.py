"""Distributed Map-Reduce inference engine (paper §3.2) on a JAX mesh.

The paper's two global steps per iteration map onto one SPMD program:

  map    : every shard computes partial stats (A_k, B_k, C_k, D_k, KL_k)
           from its local (Y_k, mu_k, S_k) — zero communication, O(n_k m^2 q).
  reduce : one ``lax.psum`` over the data axes — O(m^2 + m d) bytes,
           independent of n (the paper's "constant time" global step).
  global : every chip evaluates the collapsed bound from the reduced stats
           (replicated O(m^3) — trivial, and it removes the central node).

Gradients come from ``jax.grad`` through the same program: the transpose of
a psum is replication, so the backward pass is also one constant-size
collective + shard-local work — exactly the paper's step-3 scatter of
(F, dF) to the end-point nodes.

Node failure (paper §5.2): a per-shard ``failure_mask`` zeroes a shard's
contribution inside the reduce.  ``failure_mode``:
  * "drop"    — paper-faithful: surviving partial sums used as-is (noisy
                gradient; the bound's n-terms keep the full n).
  * "rescale" — beyond-paper: surviving sums scaled by n/n_live, keeping the
                statistics approximately unbiased (see benchmarks/fig7).

Streaming memory model (``chunk_size``): with ``chunk_size=None`` each
shard's map materialises all of its n_k rows' intermediates at once — for
the GPLVM path that is the O(n_k m^2) (and transiently O(n_k m^2 q)) psi2
broadcast, so per-device *memory*, not compute, caps n.  Setting
``chunk_size=B`` makes the shard-local map a ``lax.scan`` over
``ceil(n_k / B)`` fixed-size row blocks (``stats.partial_stats_chunked``),
folding each block's Stats into a constant-size carry.  Peak live memory
per shard becomes

    O(B * (m + q + d))  [one block's intermediates]  +  O(m^2 + m d) [carry]

independent of n_k, while the reduce is unchanged: still ONE psum of
O(m^2 + m d) bytes after the scan finishes (map stays zero-communication,
reduce stays constant-size — exactly the paper's cost model, now with a
bounded map footprint).  ``put_data`` pads n up to a multiple of
``n_shards * chunk_size`` so every scan step is shape-static; padded rows
carry zero weight and contribute nothing.

Minibatch-stochastic bound (``batch_blocks``, Hensman-style SVI): the same
factorisation that lets blocks stream also lets them be *subsampled* —
each shard visits ``batch_blocks`` random blocks per step and scales its
partial Stats by ``n_local_blocks / batch_blocks``, making per-step map
*compute* (not just memory) O(batch_blocks * chunk_size), independent of
n.  Shards sample independently (the step key is folded with the shard
index), the psum is unchanged, and the reweighted reduced Stats are
unbiased estimates of the exact ones.  See docs/training.md for the
derivation, which bound terms inherit exact unbiasedness, and tuning.
"""
from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from .bound import DEFAULT_JITTER, collapsed_bound
from .stats import Stats, fold_stats, partial_stats_chunked

try:  # jax >= 0.6 exposes shard_map at top level
    _shard_map_impl = jax.shard_map
except AttributeError:  # pragma: no cover
    from jax.experimental.shard_map import shard_map as _shard_map_impl  # type: ignore


def shard_map(f, mesh, in_specs, out_specs):
    """Version-compat shard_map with replication checking disabled."""
    try:
        return _shard_map_impl(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_vma=False
        )
    except TypeError:  # pragma: no cover - older kwarg name
        return _shard_map_impl(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=False
        )

Array = jax.Array


def _flat_shard_index(mesh: Mesh, axis_names: Sequence[str]) -> Array:
    """Flattened shard index along ``axis_names`` (row-major)."""
    idx = jnp.zeros((), jnp.int32)
    for ax in axis_names:
        idx = idx * mesh.shape[ax] + lax.axis_index(ax)
    return idx


def num_shards(mesh: Mesh, axis_names: Sequence[str]) -> int:
    out = 1
    for ax in axis_names:
        out *= mesh.shape[ax]
    return out


def pad_and_shard(arrs: dict, n_shards: int, block: int | None = None):
    """Pad leading dim to a multiple of n_shards; return arrays + weight vec.

    Args:
      arrs: dict of host arrays, each (n, ...) with a shared leading dim —
        e.g. ``{"y": (n, d), "mu": (n, q), "s": (n, q)}``.  Keys named
        ``"s"``/``"S"`` (q(X) variances) are padded with 1s (log-safe);
        everything else with 0s.
      n_shards: number of data shards the mesh provides; the padded n is the
        next multiple of ``n_shards`` (times ``block`` if set).
      block: the streaming chunk size (``chunk_size`` on the engines), or
        None.  When set, pads to a multiple of ``n_shards * block`` instead,
        so each shard holds a whole number of blocks and every ``lax.scan``
        step in the chunked map — and every SVI block sample — is
        shape-static.

    Returns ``(padded dict, weights)`` where ``weights`` is (n_padded,) —
    1.0 on real rows, 0.0 on padding — so padding contributes nothing to any
    statistic (see ``stats.partial_stats``).  Runs on host (numpy in, numpy
    out) before device_put.

    The padded n is always at least one full multiple: n < n_shards·block
    (including n = 0) pads up to ``n_shards * block`` rather than producing
    shard-empty (or zero-length) arrays that the shard_map programs cannot
    split.  ``unpad`` inverts the row padding.
    """
    import numpy as np

    from ..data.stream import padded_rows

    mult = n_shards * (block or 1)
    n = next(iter(arrs.values())).shape[0]
    pad = padded_rows(n, mult) - n
    out = {}
    for k, a in arrs.items():
        widths = [(0, pad)] + [(0, 0)] * (a.ndim - 1)
        # Pad q(X) variances with 1s (log-safe); everything else with 0s.
        cval = 1.0 if k in ("s", "S") else 0.0
        out[k] = np.pad(np.asarray(a), widths, constant_values=cval)
    w = np.concatenate([np.ones((n,), np.float64), np.zeros((pad,), np.float64)])
    return out, w


def unpad(arrs, n: int):
    """Strip the row padding ``pad_and_shard`` added: slice every array in
    ``arrs`` (a dict, or one array) back to its first ``n`` rows — the exact
    inverse of the padding, so ``unpad(pad_and_shard(x)[0], n) == x``."""
    if isinstance(arrs, dict):
        return {k: a[:n] for k, a in arrs.items()}
    return arrs[:n]


class DistributedGP:
    """Builds jitted distributed bound/grad programs for SGPR and GPLVM."""

    def __init__(
        self,
        mesh: Mesh,
        data_axes: Sequence[str] = ("data",),
        latent: bool = False,
        failure_mode: str = "drop",
        psi2_fn=None,
        reg_stats_fn=None,
        chunk_size: int | None = None,
        kernel_backend: str = "xla",
        batch_blocks: int | None = None,
        kernel=None,
        reduce_mode: str = "serial",
    ):
        """``kernel``: the covariance expression (``core.covariance``;
        None = SE-ARD).  Threaded through the shard-local map and the
        replicated global bound; the Pallas backend keeps its fused fast
        path for the SE-ARD default and falls back to the XLA map for
        other expressions (the ops-layer shims assert nothing — parity is
        covered by tests/test_kernel_zoo.py).

        ``chunk_size``: if set, each shard's map streams its rows in
        blocks of this many points (see the module docstring's streaming
        memory model); ``None`` (default) keeps the monolithic
        all-rows-at-once map.

        ``kernel_backend``: "xla" (default) keeps the monolithic jnp map;
        "pallas" routes the map's hot accumulation through the fused Pallas
        kernels — ``kernels.reg_stats`` on the regression path and
        ``kernels.psi_stats`` on the latent path — so the per-block kernel
        slab stays in VMEM.  Explicit ``psi2_fn``/``reg_stats_fn`` hooks
        override the backend's choice.

        ``batch_blocks``: if set (requires ``chunk_size``), switches the map
        to the minibatch-stochastic (SVI) bound: *each shard* samples
        ``batch_blocks`` of its local row blocks per step — with its own
        fold of the step key, so shards sample independently — and scales
        its partial Stats by ``n_local_blocks / batch_blocks`` before the
        psum.  Per-step map cost becomes O(batch_blocks * chunk_size) per
        shard, independent of the shard's row count; the reduce is unchanged
        (one O(m²+md) psum).  The programs returned by :meth:`bound_fn` and
        :meth:`make_value_and_grad` then take one extra trailing argument: a
        ``jax.random.PRNGKey`` (uint32 (2,)), fresh per step.  Default None
        = exact bound (every block scanned every step).

        ``reduce_mode``: how the bound/grad programs reduce the map's
        Stats across shards (requires ``chunk_size`` for the non-serial
        modes).

          * ``"serial"`` (default) — the paper-shaped structure: the whole
            shard-local scan finishes, then ONE constant-size psum.  The
            collective serialises after the map.
          * ``"overlap"`` — the overlapped reduce: each scanned block's
            constant-size Stats contribution is psummed *inside* the scan
            behind a double buffer, so block t's collective has no data
            dependence on block t+1's compute and rides behind it (the
            carry accumulates already-reduced Stats).  Bounds and grads
            match ``"serial"`` to float-reassociation (f64) tolerance —
            the cross-shard/cross-block sums associate per block instead
            of per pass, so bitwise equality to the serial path is a
            mathematical impossibility, not an implementation gap.
          * ``"overlap_eager"`` — validation mode: the same per-block
            reduce without the double buffer (block t reduced in step t).
            Bitwise-identical Stats/bound/grads to ``"overlap"`` (the
            fold order over blocks is the same — asserted in
            tests/_dist_worker.py), useful to isolate scheduling effects.

        The exact-stats programs (:meth:`reduced_stats`,
        :meth:`update_stats_fn`, the streamed ingestion family) always
        use the serial reduce: their bitwise streamed==staged contracts
        are defined against the single-psum association."""
        if chunk_size is not None and chunk_size < 1:
            raise ValueError(f"chunk_size must be >= 1, got {chunk_size}")
        if batch_blocks is not None:
            if chunk_size is None:
                raise ValueError(
                    "batch_blocks (SVI mode) requires chunk_size: the "
                    "minibatch is a subset of the streaming row blocks")
            if batch_blocks < 1:
                raise ValueError(
                    f"batch_blocks must be >= 1, got {batch_blocks}")
        if kernel_backend not in ("xla", "pallas"):
            raise ValueError(
                f"kernel_backend must be 'xla' or 'pallas', got {kernel_backend!r}")
        if reduce_mode not in ("serial", "overlap", "overlap_eager"):
            raise ValueError(
                "reduce_mode must be 'serial', 'overlap' or 'overlap_eager'"
                f", got {reduce_mode!r}")
        if reduce_mode != "serial" and chunk_size is None:
            raise ValueError(
                "reduce_mode='overlap' requires chunk_size: the per-block "
                "collective needs scan blocks to hide behind")
        from .covariance import as_kernel
        self.kernel = as_kernel(kernel)
        if kernel_backend == "pallas":
            from ..kernels.psi_stats import psi2_fn_for_engine
            from ..kernels.reg_stats import reg_stats_fn_for_engine
            psi2_fn = psi2_fn or psi2_fn_for_engine(kernel=self.kernel)
            reg_stats_fn = reg_stats_fn or reg_stats_fn_for_engine(
                kernel=self.kernel)
        self.mesh = mesh
        self.data_axes = tuple(data_axes)
        self.latent = latent
        self.failure_mode = failure_mode
        self.psi2_fn = psi2_fn
        self.reg_stats_fn = reg_stats_fn
        self.kernel_backend = kernel_backend
        self.chunk_size = chunk_size
        self.batch_blocks = batch_blocks
        self.reduce_mode = reduce_mode
        self.n_shards = num_shards(mesh, self.data_axes)
        self._data_spec = P(self.data_axes)
        self._rep_spec = P()
        self._stats_prog = None   # cached reduced_stats program (serving)
        self._stream_cache: dict = {}   # streamed-ingestion programs

    # -- sharding helpers ---------------------------------------------------
    def data_sharding(self) -> NamedSharding:
        return NamedSharding(self.mesh, self._data_spec)

    def replicated_sharding(self) -> NamedSharding:
        return NamedSharding(self.mesh, self._rep_spec)

    def put_data(self, stream=None, blocks_per_chunk: int = 1, **arrs):
        """Stage host data for the SPMD programs.

        In-memory mode (``put_data(y=..., mu=..., ...)``): pad + shard the
        arrays onto the mesh; returns ``(dict, weights)`` — the whole
        padded dataset is resident on device.

        Streaming mode (``put_data(stream=source)``): no staging happens —
        returns a ``data.stream.BlockStream`` over the source (a dict of
        host arrays, a ``MemmapSource``/``SyntheticSource``, or any
        ``(n, fields, read)`` object) cut into fixed-shape shard-major
        chunks of ``blocks_per_chunk`` scan blocks per shard.  Feed it to
        :meth:`streamed_stats` / :meth:`streamed_value_and_grad` /
        :meth:`streamed_predictive_state`, which hold O(chunk) rows on
        device at a time and reproduce the in-memory programs bitwise
        (Stats/bound) or to f64 tolerance (grads).  Requires
        ``chunk_size`` — the streaming block geometry is the scan-block
        geometry.
        """
        if stream is not None:
            if arrs:
                raise ValueError(
                    "put_data takes either stream=... or in-memory arrays, "
                    "not both")
            return self.open_stream(stream, blocks_per_chunk=blocks_per_chunk)
        padded, w = pad_and_shard(arrs, self.n_shards, block=self.chunk_size)
        sh = self.data_sharding()
        out = {k: jax.device_put(jnp.asarray(v), sh) for k, v in padded.items()}
        wdev = jax.device_put(jnp.asarray(w), sh)
        return out, wdev

    def open_stream(self, source, blocks_per_chunk: int = 1):
        """Wrap a host data source in a ``BlockStream`` with this engine's
        shard/block geometry (``n_shards`` shards, ``chunk_size`` rows per
        scan block) — the layout under which streamed ingestion is bitwise
        equal to :meth:`put_data` + the in-device scan."""
        from ..data.stream import BlockStream

        if self.chunk_size is None:
            raise ValueError(
                "streaming ingestion requires chunk_size: the host chunks "
                "are multiples of the in-device scan block")
        if isinstance(source, BlockStream):
            if (source.n_shards != self.n_shards
                    or source.block_size != self.chunk_size):
                raise ValueError(
                    f"stream geometry ({source.n_shards} shards × "
                    f"{source.block_size}-row blocks) does not match the "
                    f"engine ({self.n_shards} × {self.chunk_size}) — open "
                    "the stream through this engine")
            return source
        return BlockStream(source, n_shards=self.n_shards,
                           block_size=self.chunk_size,
                           blocks_per_chunk=blocks_per_chunk)

    # -- the SPMD program ---------------------------------------------------
    def _psum_stats(self, st: Stats) -> Stats:
        """Per-leaf constant-size cross-shard sum (the paper's reduce)."""
        return Stats(*(lax.psum(t, self.data_axes) for t in st))

    def _local_stats(self, hyp, z, y, mu, s, w, key=None, exact=False,
                     block_reduce_fn=None, reduce_buffered=True) -> Stats:
        """Shard-local map: monolithic (chunk_size=None), streamed, or —
        with ``batch_blocks`` set and a per-shard ``key`` — SVI-sampled.
        ``exact=True`` forces the full scan regardless of ``batch_blocks``
        (the posterior/prediction path).  ``block_reduce_fn`` switches to
        the overlapped per-block reduce — the returned Stats are then
        already globally reduced."""
        return partial_stats_chunked(
            hyp, z, y, mu, s,
            weights=w, latent=self.latent, psi2_fn=self.psi2_fn,
            reg_stats_fn=self.reg_stats_fn, block_size=self.chunk_size,
            batch_blocks=None if exact else self.batch_blocks, key=key,
            kernel=self.kernel, force_scan=True,
            block_reduce_fn=block_reduce_fn, reduce_buffered=reduce_buffered,
        )

    def _shard_bound(self, hyp, z, y, mu, s, w, fmask, n_full, d, key=None):
        """Runs per-shard under shard_map. Returns the (replicated) bound."""
        idx = _flat_shard_index(self.mesh, self.data_axes)
        alive = fmask[idx]
        w = w * alive

        if key is not None:
            # Per-shard sampling: every shard folds its flat index into the
            # (replicated) step key, so shards draw independent block
            # subsets.  Independence keeps the summed estimator unbiased:
            # E[psum of per-shard reweighted Stats] = psum of exact Stats.
            key = jax.random.fold_in(key, idx)
        if self.reduce_mode == "serial":
            st = self._local_stats(hyp, z, y, mu, s, w, key=key)
            # --- the reduce: one constant-size collective after the map ----
            st = self._psum_stats(st)
        else:
            # Overlapped reduce: each block's Stats contribution is psummed
            # inside the map scan (double-buffered in "overlap" so block
            # t's collective rides behind block t+1's compute); the scan
            # returns already-reduced Stats and no post-map collective
            # remains on the critical path.
            st = self._local_stats(
                hyp, z, y, mu, s, w, key=key,
                block_reduce_fn=self._psum_stats,
                reduce_buffered=(self.reduce_mode == "overlap"))

        if self.failure_mode == "rescale":
            if key is None:
                n_live = st.n
            else:
                # SVI: st.n is a stochastic reweighted count — dividing by
                # it would make a biased ratio estimator that conflates
                # sampling noise with node failure.  Rescale by the
                # deterministic pre-sampling live count instead (one cheap
                # extra scalar psum), which preserves unbiasedness: a
                # constant per-step multiplier commutes with E[.].
                n_live = lax.psum(jnp.sum(w), self.data_axes)
            live_frac = n_live / n_full
            st = Stats(
                A=st.A / live_frac, B=st.B / live_frac, C=st.C / live_frac,
                D=st.D / live_frac, KL=st.KL / live_frac, n=n_full,
            )
        else:  # "drop" (paper) — keep sums as-is, n-terms use the full n
            st = st._replace(n=n_full)
        return collapsed_bound(hyp, z, st, d, kernel=self.kernel)

    def bound_fn(self, d: int):
        """Replicated-output distributed bound.

        Signature: ``(hyp, z, y, mu, s, w, fmask, n_full) -> ()`` — plus a
        trailing per-step ``key`` when the engine was built with
        ``batch_blocks`` (SVI mode).
        """
        specs = [
            self._rep_spec,   # hyp (pytree of scalars/vectors)
            self._rep_spec,   # z
            self._data_spec,  # y
            self._data_spec,  # mu
            self._data_spec,  # s (None for regression: empty pytree)
            self._data_spec,  # w
            self._rep_spec,   # fmask
            self._rep_spec,   # n_full
        ]
        if self.batch_blocks is not None:
            specs.append(self._rep_spec)  # step key (folded per shard inside)

            def body(hyp, z, y, mu, s, w, fmask, n_full, key):
                return self._shard_bound(hyp, z, y, mu, s, w, fmask, n_full,
                                         d=d, key=key)
        else:
            body = functools.partial(self._shard_bound, d=d)
        return shard_map(body, mesh=self.mesh, in_specs=tuple(specs),
                         out_specs=self._rep_spec)

    def make_value_and_grad(self, d: int, argnums=(0, 1)):
        """Jitted (value, grad) of the NEGATIVE bound wrt chosen args.

        argnums indexes (hyp, z, mu, s): for SGPR use (0, 1); for GPLVM add
        mu and s — their gradients stay sharded with the data (the paper's
        local-parameter optimisation, no extra communication).

        The returned step is ``step(hyp, z, mu, s, y, w, fmask, n_full)``;
        in SVI mode (``batch_blocks`` set) it takes one extra trailing
        argument, a fresh ``jax.random.PRNGKey`` per step, and returns an
        unbiased stochastic estimate instead of the exact value/grad.
        """
        bound = self.bound_fn(d)

        if self.batch_blocks is not None:
            def neg_svi(hyp, z, mu, s, y, w, fmask, n_full, key):
                return -bound(hyp, z, y, mu, s, w, fmask, n_full, key)

            return jax.jit(jax.value_and_grad(neg_svi, argnums=argnums))

        def neg(hyp, z, mu, s, y, w, fmask, n_full):
            return -bound(hyp, z, y, mu, s, w, fmask, n_full)

        return jax.jit(jax.value_and_grad(neg, argnums=argnums))

    def reduced_stats(self, d: int):
        """Jitted program returning the globally-reduced Stats (for
        q(u)/predict).  Always the exact scan — posterior/prediction should
        see every point even when training ran in SVI mode."""

        def _stats(hyp, z, y, mu, s, w, fmask):
            idx = _flat_shard_index(self.mesh, self.data_axes)
            w = w * fmask[idx]
            st = self._local_stats(hyp, z, y, mu, s, w, exact=True)
            return Stats(*(lax.psum(t, self.data_axes) for t in st))

        f = shard_map(
            _stats,
            mesh=self.mesh,
            in_specs=(
                self._rep_spec, self._rep_spec, self._data_spec,
                self._data_spec, self._data_spec, self._data_spec, self._rep_spec,
            ),
            out_specs=self._rep_spec,
        )
        return jax.jit(f)

    # -- streaming ingestion (host-fed chunk loop) --------------------------
    #
    # The in-memory programs stage the whole padded dataset on device; the
    # streamed ones below hold ONE fixed-shape chunk (blocks_per_chunk scan
    # blocks per shard) at a time, threading a *sharded* Stats carry — every
    # leaf gains a leading (n_shards,) axis, spec P(data_axes) — through a
    # per-chunk fold program that contains NO collective (jaxpr-asserted in
    # tests/_dist_worker.py).  Because chunk assembly is shard-major
    # (data.stream.BlockStream) and the carry threads INTO the chunked
    # scan's own accumulator (stats.partial_stats_chunked(init=...)), each
    # shard performs the identical float-add sequence over the identical
    # block partition as the in-memory scan, and ONE final psum — the same
    # collective reduced_stats runs — collapses the carry.  Streamed Stats
    # and bound are therefore bitwise equal to the staged path, not merely
    # close (tests/test_stream_ingest.py); only gradients (recovered by a
    # second pass through the stats cotangent) carry float-reassociation
    # error at f64 tolerance.  Host + device residency stays O(chunk) in n.

    def _stream_progs(self, has_s: bool):
        """Build (once per s-structure) the jitted per-chunk fold, final
        reduce, and chunk-cotangent programs."""
        cache_key = ("progs", has_s)
        progs = self._stream_cache.get(cache_key)
        if progs is not None:
            return progs

        def _local(hyp, z, y, mu, s, w, init=None):
            return partial_stats_chunked(
                hyp, z, y, mu, s, weights=w, latent=self.latent,
                psi2_fn=self.psi2_fn, reg_stats_fn=self.reg_stats_fn,
                block_size=self.chunk_size, kernel=self.kernel, init=init,
                force_scan=True)

        def _fold(carry, hyp, z, y, mu, s, w, fmask):
            idx = _flat_shard_index(self.mesh, self.data_axes)
            w = w * fmask[idx]
            init = Stats(*(jnp.squeeze(t, 0) for t in carry))
            st = _local(hyp, z, y, mu, s, w, init=init)
            return Stats(*(t[None] for t in st))

        def _reduce(carry):
            st = Stats(*(jnp.squeeze(t, 0) for t in carry))
            return Stats(*(lax.psum(t, self.data_axes) for t in st))

        def _chunk_ip(hyp, z, y, mu, s, w, fmask, ct):
            # <this chunk's reduced Stats, cotangent ct> — pass 2 of the
            # streamed gradient differentiates this wrt (hyp, z).
            idx = _flat_shard_index(self.mesh, self.data_axes)
            w = w * fmask[idx]
            st = _local(hyp, z, y, mu, s, w)
            ip = sum(jnp.vdot(a, b) for a, b in zip(st, ct))
            return lax.psum(ip, self.data_axes)

        data, rep = self._data_spec, self._rep_spec
        fold = jax.jit(shard_map(
            _fold, mesh=self.mesh,
            in_specs=(data, rep, rep, data, data, data, data, rep),
            out_specs=data))
        reduce_ = jax.jit(shard_map(
            _reduce, mesh=self.mesh, in_specs=(data,), out_specs=rep))
        chunk_vg = jax.jit(jax.value_and_grad(shard_map(
            _chunk_ip, mesh=self.mesh,
            in_specs=(rep, rep, data, data, data, data, rep, rep),
            out_specs=rep), argnums=(0, 1)))
        progs = {"fold": fold, "reduce": reduce_, "chunk_vg": chunk_vg}
        self._stream_cache[cache_key] = progs
        return progs

    def _init_stream_carry(self, stream, hyp, z) -> Stats:
        """Zero sharded carry with the exact leaf shapes/dtypes one chunk's
        local stats produce (abstract eval — backend/kernel agnostic).
        The eval_shape re-traces the whole chunked map, so the resulting
        leaf structure is cached per (geometry, hyp/z structure) — carry
        init must stay cheap relative to one chunk's fold."""
        rows = stream.shard_chunk_rows
        key = ("carry", rows,
               tuple((k, tuple(v), str(jnp.dtype(stream.field_dtype(k))))
                     for k, v in sorted(stream.fields.items())),
               tuple(jnp.shape(t) for t in jax.tree.leaves((hyp, z))))
        shapes = self._stream_cache.get(key)
        if shapes is None:
            sds = {k: jax.ShapeDtypeStruct((rows,) + tuple(tr),
                                           jnp.dtype(stream.field_dtype(k)))
                   for k, tr in stream.fields.items()}
            wsd = jax.ShapeDtypeStruct((rows,), jnp.float64)

            def f(y, mu, s, w):
                return partial_stats_chunked(
                    hyp, z, y, mu, s, weights=w, latent=self.latent,
                    psi2_fn=self.psi2_fn, reg_stats_fn=self.reg_stats_fn,
                    block_size=self.chunk_size, kernel=self.kernel,
                    force_scan=True)

            shapes = jax.eval_shape(f, sds["y"], sds["mu"], sds.get("s"),
                                    wsd)
            self._stream_cache[key] = shapes
        carry = Stats(*(jnp.zeros((self.n_shards,) + t.shape, t.dtype)
                        for t in shapes))
        return jax.device_put(carry, self.data_sharding())

    def _stage_stream(self, stream, prefetch_depth: int, indices=None):
        """Prefetched iterator of device-staged ``(arrays, weights)`` chunks
        — chunk i+1's host assembly + H2D overlaps compute on chunk i."""
        from ..data.stream import prefetch, stage_to_device

        return prefetch(stream.chunks(indices),
                        stage_to_device(self.data_sharding()),
                        depth=prefetch_depth)

    def _stream_carry(self, hyp, z, stream, fmask, prefetch_depth: int):
        """Fold every chunk into the sharded carry (no collective yet)."""
        progs = self._stream_progs(has_s="s" in stream.fields)
        carry = self._init_stream_carry(stream, hyp, z)
        for arrs, w in self._stage_stream(stream, prefetch_depth):
            carry = progs["fold"](carry, hyp, z, arrs["y"], arrs["mu"],
                                  arrs.get("s"), w, fmask)
        return carry

    def streamed_stats(self, hyp, z, stream, fmask=None,
                       prefetch_depth: int = 2) -> Stats:
        """Exact reduced Stats from a host stream — bitwise equal to
        :meth:`reduced_stats` over the same (staged) data, with device
        residency O(chunk) instead of O(n).  ``stream`` is anything
        :meth:`open_stream` accepts."""
        stream = self.open_stream(stream)
        if fmask is None:
            fmask = jnp.ones((self.n_shards,))
        carry = self._stream_carry(hyp, z, stream, fmask, prefetch_depth)
        return self._stream_progs(has_s="s" in stream.fields)["reduce"](carry)

    def _collapse_prog(self, d: int):
        """Jitted (replicated) stats -> NEGATIVE bound with this engine's
        failure-mode n-handling — the same global math ``_shard_bound``
        runs after its psum, applied to already-reduced stats."""
        cache_key = ("collapse", d)
        prog = self._stream_cache.get(cache_key)
        if prog is not None:
            return prog

        def neg(hyp, z, st, n_full):
            if self.failure_mode == "rescale":
                live_frac = st.n / n_full
                st = Stats(A=st.A / live_frac, B=st.B / live_frac,
                           C=st.C / live_frac, D=st.D / live_frac,
                           KL=st.KL / live_frac, n=n_full)
            else:
                st = st._replace(n=n_full)
            return -collapsed_bound(hyp, z, st, d, kernel=self.kernel)

        prog = {
            "neg": jax.jit(neg),
            "vg": jax.jit(jax.value_and_grad(neg, argnums=(0, 1, 2))),
        }
        self._stream_cache[cache_key] = prog
        return prog

    def _bound_from_carry_prog(self, d: int):
        """Mesh program: sharded carry -> psum -> failure-mode n-handling ->
        replicated bound.  Structured exactly like ``_shard_bound``'s
        post-map tail (the psum feeding the global math inside one
        shard_map) so the streamed bound compiles to the same float
        sequence as the in-memory one — this is what keeps the *bound*
        bitwise, not just the Stats."""
        cache_key = ("bound_carry", d)
        prog = self._stream_cache.get(cache_key)
        if prog is not None:
            return prog

        def body(carry, hyp, z, n_full):
            st = Stats(*(jnp.squeeze(t, 0) for t in carry))
            st = Stats(*(lax.psum(t, self.data_axes) for t in st))
            if self.failure_mode == "rescale":
                live_frac = st.n / n_full
                st = Stats(A=st.A / live_frac, B=st.B / live_frac,
                           C=st.C / live_frac, D=st.D / live_frac,
                           KL=st.KL / live_frac, n=n_full)
            else:
                st = st._replace(n=n_full)
            return collapsed_bound(hyp, z, st, d, kernel=self.kernel)

        # NOT jitted: ``bound_fn`` hands back a bare shard_map, whose
        # op-by-op dispatch rounds like the eager path — jitting this tail
        # fuses the global math differently (≈1 ulp) and breaks the
        # bitwise-bound contract with the in-memory program.
        prog = shard_map(
            body, mesh=self.mesh,
            in_specs=(self._data_spec, self._rep_spec, self._rep_spec,
                      self._rep_spec),
            out_specs=self._rep_spec)
        self._stream_cache[cache_key] = prog
        return prog

    def streamed_bound(self, hyp, z, stream, d: int, fmask=None,
                       n_full=None, prefetch_depth: int = 2):
        """The distributed bound from a host stream — bitwise equal to
        :meth:`bound_fn` on the staged data (same chunk-folded Stats
        carry, same in-mesh psum + collapse tail)."""
        stream = self.open_stream(stream)
        if fmask is None:
            fmask = jnp.ones((self.n_shards,))
        n_full = float(stream.n) if n_full is None else n_full
        carry = self._stream_carry(hyp, z, stream, fmask, prefetch_depth)
        return self._bound_from_carry_prog(d)(carry, hyp, z, n_full)

    def streamed_value_and_grad(self, d: int, argnums=(0, 1)):
        """Streamed (value, grad) of the NEGATIVE bound wrt (hyp, z) —
        the exact two-pass gradient.

        Pass 1 streams the chunks once to build the reduced Stats S
        (bitwise the in-memory ones); the cotangent dS of the collapsed
        bound wrt S is one replicated O(m³) value_and_grad.  Pass 2
        streams the chunks again, accumulating the (hyp, z) gradient of
        ``<chunk stats, dS>`` per chunk — the chain rule through the
        w-linear Stats, so the total equals the in-memory
        :meth:`make_value_and_grad` up to float re-association (f64
        tolerance), at O(chunk) residency and two passes over the data.
        (For per-step training at scale prefer
        :meth:`streamed_svi_value_and_grad` — one sampled pass.)

        Returns ``step(hyp, z, stream, fmask=None, n_full=None,
        prefetch_depth=2) -> (val, grads)`` with ``grads`` ordered by
        ``argnums`` (subset of (0, 1): streamed mu/s gradients would be
        n-sized, which streaming exists to avoid).
        """
        single = not isinstance(argnums, (tuple, list))
        argnums = (argnums,) if single else tuple(argnums)
        if not set(argnums) <= {0, 1}:
            raise ValueError(
                "streamed gradients support argnums ⊆ (0, 1) (hyp, z): "
                "mu/s gradients are data-sized — stage those shards in "
                f"memory instead (got {argnums})")

        def step(hyp, z, stream, fmask=None, n_full=None,
                 prefetch_depth: int = 2):
            stream = self.open_stream(stream)
            if fmask is None:
                fmask = jnp.ones((self.n_shards,))
            n_full = float(stream.n) if n_full is None else n_full
            st = self.streamed_stats(hyp, z, stream, fmask=fmask,
                                     prefetch_depth=prefetch_depth)
            val, (g_hyp, g_z, ct) = self._collapse_prog(d)["vg"](
                hyp, z, st, n_full)
            progs = self._stream_progs(has_s="s" in stream.fields)
            for arrs, w in self._stage_stream(stream, prefetch_depth):
                _, (gh, gz) = progs["chunk_vg"](
                    hyp, z, arrs["y"], arrs["mu"], arrs.get("s"), w,
                    fmask, ct)
                g_hyp = jax.tree.map(jnp.add, g_hyp, gh)
                g_z = g_z + gz
            grads = tuple((g_hyp, g_z)[a] for a in argnums)
            return val, (grads[0] if single else grads)

        return step

    def streamed_svi_value_and_grad(self, d: int, batch_chunks: int,
                                    argnums=(0, 1)):
        """Minibatch-stochastic streamed step: sample ``batch_chunks`` of
        the stream's chunks per step (host-side, without replacement),
        stage only those, and return an unbiased (value, grad) of the
        NEGATIVE bound — one pass over O(batch_chunks · chunk) rows per
        step, independent of n.

        The sampling unit is the *chunk* (every shard visits the same
        chunk indices — the chunks partition the rows, so reweighting by
        ``n_chunks / batch_chunks`` is unbiased exactly as the in-memory
        per-shard block sampling is; the estimators differ only in their
        correlation structure).  Requires ``failure_mode="drop"`` — the
        rescale mode's deterministic pre-sampling live count would need a
        full pass over the stream.

        Returns ``step(hyp, z, stream, key, fmask=None, n_full=None) ->
        (val, grads)``; ``key`` is a fresh PRNGKey per optimiser step.
        """
        import numpy as np

        from .stats import sample_block_indices

        if isinstance(argnums, (tuple, list)):
            argnums = tuple(argnums)
        check = argnums if isinstance(argnums, tuple) else (argnums,)
        if not set(check) <= {0, 1}:
            raise ValueError(
                f"streamed gradients support argnums ⊆ (0, 1), got {argnums}")
        if batch_chunks < 1:
            raise ValueError(
                f"batch_chunks must be >= 1, got {batch_chunks}")
        if self.failure_mode == "rescale":
            raise NotImplementedError(
                "streamed SVI supports failure_mode='drop' only: rescale "
                "needs the deterministic live count, a full data pass")

        cache_key = ("svi", d, argnums)
        prog = self._stream_cache.get(cache_key)
        if prog is None:
            def _neg(hyp, z, y, mu, s, w, fmask, n_full, scale):
                # Local shapes (B, rows_per_shard_per_chunk, ...): flatten
                # the staged chunks back to contiguous rows, exact-scan
                # them, reweight — every Stats field is a per-point sum.
                idx = _flat_shard_index(self.mesh, self.data_axes)
                w = w * fmask[idx]

                def flat(a):
                    return a.reshape((a.shape[0] * a.shape[1],)
                                     + a.shape[2:])

                st = partial_stats_chunked(
                    hyp, z, flat(y), flat(mu),
                    None if s is None else flat(s), weights=flat(w),
                    latent=self.latent, psi2_fn=self.psi2_fn,
                    reg_stats_fn=self.reg_stats_fn,
                    block_size=self.chunk_size, kernel=self.kernel,
                    force_scan=True)
                st = st.scale(scale)
                st = Stats(*(lax.psum(t, self.data_axes) for t in st))
                st = st._replace(n=n_full)   # drop-mode n handling
                return -collapsed_bound(hyp, z, st, d, kernel=self.kernel)

            stk = P(None, self.data_axes)
            rep = self._rep_spec
            prog = jax.jit(jax.value_and_grad(shard_map(
                _neg, mesh=self.mesh,
                in_specs=(rep, rep, stk, stk, stk, stk, rep, rep, rep),
                out_specs=rep), argnums=argnums))
            self._stream_cache[cache_key] = prog

        stacked_sharding = NamedSharding(self.mesh, P(None, self.data_axes))

        def step(hyp, z, stream, key, fmask=None, n_full=None):
            stream = self.open_stream(stream)
            if fmask is None:
                fmask = jnp.ones((self.n_shards,))
            n_full = float(stream.n) if n_full is None else n_full
            nc = stream.n_chunks
            B = min(batch_chunks, nc)
            if B < nc:
                idxs = np.asarray(sample_block_indices(key, nc, B))
            else:
                idxs = np.arange(nc)
            chunks = [stream.chunk(int(c)) for c in idxs]
            arrs = {k: jax.device_put(
                        jnp.asarray(np.stack([c[0][k] for c in chunks])),
                        stacked_sharding)
                    for k in stream.fields}
            w = jax.device_put(jnp.asarray(np.stack([c[1] for c in chunks])),
                               stacked_sharding)
            scale = jnp.asarray(nc / B, jnp.float64)
            return prog(hyp, z, arrs["y"], arrs["mu"], arrs.get("s"), w,
                        fmask, n_full, scale)

        return step

    def streamed_predictive_state(self, hyp, z, stream, fmask=None,
                                  jitter: float = DEFAULT_JITTER,
                                  prefetch_depth: int = 2):
        """Training-to-serving handoff from a host stream: one streamed
        exact map-reduce -> the frozen ``serve.PredictiveState`` — the
        streaming analogue of :meth:`predictive_state`, bitwise the same
        state (the Stats it is extracted from are bitwise equal)."""
        from ..serve import extract_state

        st = self.streamed_stats(hyp, z, stream, fmask=fmask,
                                 prefetch_depth=prefetch_depth)
        return extract_state(hyp, z, st, jitter=jitter, kernel=self.kernel)

    # -- online updates (continual learning) --------------------------------
    def update_stats_fn(self, d: int):
        """Jitted distributed *fold*: absorb a new sharded block into
        already-reduced Stats.

        Signature: ``(base_stats, hyp, z, y_new, mu_new, s_new, w_new,
        fmask) -> Stats``.  Each shard computes the partial Stats of its
        slice of the new block locally (always the exact scan — fold /
        downdate identities need unscaled statistics), ONE psum reduces
        them (the same constant-size collective as training), and the
        replicated ``base_stats`` folds in element-wise
        (``stats.fold_stats``).  Cost is O(k_shard · m²) map + O(m² + md)
        reduce — independent of how much data the base Stats summarise,
        which is the whole point of online updates.

        To *forget* a sharded block, fold with ``base.scale(1.0)`` and
        subtract: ``downdate = stats.downdate_stats(base, delta)`` where
        ``delta`` comes from :meth:`reduced_stats` over the block — or
        simply negate the weights, since every statistic is w-linear.
        """

        def _fold(base, hyp, z, y, mu, s, w, fmask):
            idx = _flat_shard_index(self.mesh, self.data_axes)
            w = w * fmask[idx]
            st = self._local_stats(hyp, z, y, mu, s, w, exact=True)
            st = Stats(*(lax.psum(t, self.data_axes) for t in st))
            return fold_stats(base, st)

        f = shard_map(
            _fold,
            mesh=self.mesh,
            in_specs=(
                self._rep_spec,   # base_stats (replicated, constant-size)
                self._rep_spec, self._rep_spec, self._data_spec,
                self._data_spec, self._data_spec, self._data_spec,
                self._rep_spec,
            ),
            out_specs=self._rep_spec,
        )
        return jax.jit(f)

    def update_predictive_state(self, state, x_new, y_new, weights=None):
        """Serve-side incremental refresh on this engine's mesh: absorb a
        (replicated) block of k events into a served ``PredictiveState``
        in O(m²k) — rank-k factor update via ``serve.online``, no
        refactorisation, and NO collectives: the block is the same on
        every host (a serving tier ingests events, not training shards),
        so the refresh is replicated local math, the serving analogue of
        the zero-communication map (jaxpr-asserted in
        tests/_dist_worker.py).  Returns ``online.RefreshResult``.

        Training-side bookkeeping (the folded Stats for a later exact
        re-fit) is :meth:`update_stats_fn`'s job; this method only moves
        the serving factors."""
        from ..serve import online

        return online.update_state(state, x_new, y_new, weights)

    def downdate_predictive_state(self, state, x_old, y_old, weights=None):
        """Forget a (replicated) block from a served state: rank-k
        Cholesky downdate with the guarded refactorisation fallback —
        same collective-free contract as :meth:`update_predictive_state`."""
        from ..serve import online

        return online.downdate_state(state, x_old, y_old, weights)

    # -- serving ------------------------------------------------------------
    def predictive_state(self, hyp, z, y, mu, s, w, fmask=None,
                         jitter: float = DEFAULT_JITTER):
        """One exact map-reduce over the sharded data -> the frozen
        ``serve.PredictiveState`` (replicated; constant-size).  This is the
        training-to-serving handoff: after this call neither the engine nor
        the data shards are needed to answer queries — ``serve.save_state``
        the result and a server restarts from disk alone."""
        from ..serve import extract_state

        if self._stats_prog is None:
            self._stats_prog = self.reduced_stats(d=0)
        if fmask is None:
            fmask = jnp.ones((self.n_shards,))
        st = self._stats_prog(hyp, z, y, mu, s, w, fmask)
        return extract_state(hyp, z, st, jitter=jitter, kernel=self.kernel)

    def predict_engine(self, state, block_size: int = 256,
                       kernel_backend: str | None = None,
                       donate: bool = False):
        """A ``serve.PredictEngine`` sharding query batches over this
        engine's mesh/data axes (state replicated, predictions row-local —
        zero communication).  ``kernel_backend`` defaults to the training
        engine's backend."""
        from ..serve import PredictEngine

        return PredictEngine(
            state, block_size=block_size, mesh=self.mesh,
            data_axes=self.data_axes,
            kernel_backend=kernel_backend or self.kernel_backend,
            donate=donate)

    def multi_predict_engine(self, states, block_size: int = 256,
                             donate: bool = False, compute_dtype=None):
        """A ``serve.MultiPredictEngine`` serving N stacked states (an
        ensemble or A/B fleet) over this engine's mesh from one compiled
        executable: queries shard across the data axes, the stacked state
        is replicated, and — like ``predict_engine`` — predictions are
        row-local with zero collectives."""
        from ..serve import MultiPredictEngine

        return MultiPredictEngine(
            states, block_size=block_size, mesh=self.mesh,
            data_axes=self.data_axes, donate=donate,
            compute_dtype=compute_dtype)
