"""Partial sufficient statistics — the paper's Map step.

Each worker holds a shard ``(Y_k, mu_k, S_k)`` (regression: ``S_k = 0``,
``mu_k = X_k``) and computes

    A_k  = Sum_i Y_i Y_i^T            (scalar)
    B_k  = Sum_i psi0_i               (scalar)
    C_k  = Psi1_k^T Y_k               (m, d)
    D_k  = Sum_i psi2_i               (m, m)
    KL_k = Sum_i KL(q(X_i) || p(X_i)) (scalar)

These are exactly the terms the paper's end-point nodes return to the
central node (its §3.2 step 2); their size is independent of n.

``weights`` lets callers mask out padded rows (distributed padding) and
failed nodes (the paper's §5.2 drop-partial-term strategy) without changing
shapes — a zero weight removes point i from every statistic.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from . import covariance as cov

Array = jax.Array


def reg_stats_dense(hyp: dict, z: Array, x: Array, y: Array, w: Array,
                    kernel: "cov.Kernel | None" = None):
    """Monolithic XLA regression statistics ``(b, C, D)`` — the canonical
    map math shared by :func:`partial_stats` (``s is None`` branch) and the
    fused Pallas op's custom_vjp backward (``kernels.reg_stats``).
    Materialises the (n, m) kernel slab; the fused kernel is the version
    that does not.  ``kernel`` picks the covariance expression (None =
    SE-ARD, the pre-compositional default)."""
    kernel = cov.as_kernel(kernel)
    knm = kernel.K(hyp, x, z)                                  # (n, m)
    b = jnp.sum(w * kernel.kdiag(hyp, x))
    c = knm.T @ (w[:, None] * y)                               # (m, d)
    d_stat = (knm * w[:, None]).T @ knm                        # (m, m)
    return b, c, d_stat


class Stats(NamedTuple):
    """Sufficient statistics of the collapsed bound. All sums over points."""

    A: Array   # () Frobenius term  Sum Y_i Y_i^T
    B: Array   # () psi0 sum
    C: Array   # (m, d) Psi1^T Y
    D: Array   # (m, m) Psi2
    KL: Array  # () KL(q(X)||p(X))
    n: Array   # () effective number of points contributing

    def __add__(self, other: "Stats") -> "Stats":  # type: ignore[override]
        return Stats(*(a + b for a, b in zip(self, other)))

    def __sub__(self, other: "Stats") -> "Stats":
        return Stats(*(a - b for a, b in zip(self, other)))

    def scale(self, c) -> "Stats":
        return Stats(*(c * t for t in self))


def partial_stats(
    hyp: dict,
    z: Array,
    y: Array,
    mu: Array,
    s: Array | None = None,
    weights: Array | None = None,
    latent: bool = True,
    psi2_fn=None,
    reg_stats_fn=None,
    kernel: "cov.Kernel | None" = None,
) -> Stats:
    """Compute the shard-local statistics (the map function).

    Args:
      hyp: kernel/noise hyper-parameters (log-space dict).
      z: (m, q) inducing inputs.
      y: (n_k, d) outputs on this shard.
      mu: (n_k, q) q(X) means (== inputs X for regression).
      s: (n_k, q) q(X) variances, or None for regression (treated as 0).
      weights: (n_k,) 0/1 mask (padding / failed points). None = all ones.
      latent: include the KL term (GPLVM) or not (regression).
      psi2_fn: override for the psi2 accumulation (e.g. the Pallas kernel).
      reg_stats_fn: override for the regression (B, C, D) accumulation —
        ``fn(hyp, z, mu, y, w) -> (b, c, d)`` (e.g. the fused Pallas kernel,
        which never materialises the (n, m) slab in HBM).
      kernel: covariance expression (``core.covariance``); None = SE-ARD.
        Overrides *only* the default accumulations — an explicit
        ``psi2_fn`` / ``reg_stats_fn`` hook is expected to already be
        bound to the right kernel (the ops-layer shims do this).
    """
    kernel = cov.as_kernel(kernel)
    n_k = y.shape[0]
    w = jnp.ones((n_k,), y.dtype) if weights is None else weights.astype(y.dtype)

    if s is None:
        # Regression: q(X_i) is a delta at the observed inputs. Use the exact
        # kernel forms (cheaper + numerically exact) rather than S->0 limits.
        a = jnp.sum(w * jnp.sum(y * y, axis=-1))
        if reg_stats_fn is None:
            b, c, d_stat = reg_stats_dense(hyp, z, mu, y, w, kernel=kernel)
        else:
            b, c, d_stat = reg_stats_fn(hyp, z, mu, y, w)
        kl = jnp.zeros((), y.dtype)
    else:
        a = jnp.sum(w * jnp.sum(y * y, axis=-1))
        b = jnp.sum(w * kernel.psi0(hyp, mu, s))
        p1 = kernel.psi1(hyp, z, mu, s)                        # (n, m)
        c = p1.T @ (w[:, None] * y)
        if psi2_fn is None:
            d_stat = kernel.psi2(hyp, z, mu, s, w)
        else:
            d_stat = psi2_fn(hyp, z, mu, s, w)
        kl_i = 0.5 * jnp.sum(s + mu * mu - jnp.log(s) - 1.0, axis=-1)
        kl = jnp.sum(w * kl_i) if latent else jnp.zeros((), y.dtype)

    return Stats(A=a, B=b, C=c, D=d_stat, KL=kl, n=jnp.sum(w))


def zero_stats(m: int, d: int, dtype=jnp.float64) -> Stats:
    """The additive identity of the Stats monoid — a reduce/fold init for
    host-side accumulation. (The scan in ``partial_stats_chunked`` builds
    its own carry with scalars promoted to rank 1; see the note there.)"""
    zf = jnp.zeros((), dtype)
    return Stats(A=zf, B=zf, C=jnp.zeros((m, d), dtype),
                 D=jnp.zeros((m, m), dtype), KL=zf, n=zf)


def sample_block_indices(key: Array, n_blocks: int, batch_blocks: int) -> Array:
    """Uniform size-``batch_blocks`` subset of ``range(n_blocks)``, without
    replacement — the SVI block sampler.

    Sampling without replacement keeps the subset-mean identity exact:
    E[sum over sampled blocks] = (batch_blocks / n_blocks) * (sum over all
    blocks), which is what makes the ``n_blocks / batch_blocks`` reweighting
    in :func:`partial_stats_chunked` an unbiased estimator of the exact
    streamed statistics.  Returns ``(batch_blocks,)`` integer indices.
    """
    return jax.random.permutation(key, n_blocks)[:batch_blocks]


def partial_stats_chunked(
    hyp: dict,
    z: Array,
    y: Array,
    mu: Array,
    s: Array | None = None,
    weights: Array | None = None,
    latent: bool = True,
    psi2_fn=None,
    reg_stats_fn=None,
    block_size: int | None = 1024,
    batch_blocks: int | None = None,
    key: Array | None = None,
    block_indices: Array | None = None,
    kernel: "cov.Kernel | None" = None,
    init: Stats | None = None,
    force_scan: bool = False,
    block_reduce_fn=None,
    reduce_buffered: bool = True,
) -> Stats:
    """Streaming map step: ``partial_stats`` folded over fixed-size row blocks.

    Exact mode (default) scans *every* block; minibatch (SVI) mode scans a
    random size-``batch_blocks`` subset and reweights, making the per-call
    cost O(batch_blocks * block_size) — independent of ``n_k``.

    Args:
      hyp: kernel/noise hyper-parameters (log-space dict).
      z: (m, q) inducing inputs.
      y: (n_k, d) outputs on this shard.
      mu: (n_k, q) q(X) means (== the inputs X for regression).
      s: (n_k, q) q(X) variances, or None for regression.
      weights: (n_k,) 0/1 row mask (padding / failed points). None = ones.
      latent: include the per-point KL term (GPLVM) or not (regression).
      psi2_fn / reg_stats_fn: per-block accumulation hooks (e.g. the Pallas
        kernels); invoked once per scanned block on block-sized operands.
      block_size: rows per scan block (default 1024). ``None`` delegates to
        the monolithic :func:`partial_stats` — so callers can dispatch on a
        single optional chunk-size setting.
      batch_blocks: if set, enables the stochastic (SVI) map: only
        ``batch_blocks`` of the ``nb = ceil(n_k / block_size)`` blocks are
        visited, chosen uniformly without replacement, and the accumulated
        Stats are scaled by ``nb / batch_blocks``.  Because every field of
        ``Stats`` is a plain sum over points (including the per-point KL and
        the effective count ``n``), the scaled Stats — and any function that
        is linear in them — are *unbiased* estimates of the exact streamed
        values; see docs/training.md for the derivation and for which bound
        terms inherit exact unbiasedness.  ``batch_blocks >= nb`` degrades
        gracefully to the exact scan (scale 1).  Requires ``block_size``.
      key: PRNG key for the block sampler (required in SVI mode unless
        ``block_indices`` is given). Pass a fresh key per optimiser step.
      block_indices: explicit (batch_blocks,) block indices, overriding the
        sampler — deterministic replay / subset-enumeration tests / custom
        block samplers plug in here.
      init: starting carry (rank-proper Stats, e.g. a previous call's
        return) folded exactly as if this call's blocks continued that
        scan: the body keeps adding ``carry + block`` left-to-right, so a
        host-fed outer loop threading ``init`` across fixed-shape chunks
        (``data.stream``) reproduces the single in-device scan *bitwise* —
        same float-add association, same per-block program.  Leaf dtypes
        must match the block output dtypes.  Incompatible with
        ``batch_blocks`` (the SVI reweighting scales the whole
        accumulated carry, which would corrupt a prior-chunk ``init``).
      force_scan: take the ``lax.scan`` path even when the rows fit one
        block (``n_k <= block_size``), instead of the monolithic
        short-circuit.  The distributed engine sets this so the bound's
        producer is a scan boundary regardless of shard size — XLA then
        compiles the global (post-psum) math identically whether the
        stats come from an in-device map or a streamed carry, which the
        streamed/in-memory bitwise-bound contract relies on.  No-op when
        ``block_size`` is None.
      block_reduce_fn: the *overlapped reduce* hook (``Stats -> Stats``,
        e.g. a per-leaf ``lax.psum`` bound to the mesh data axes).  When
        set, the scan no longer accumulates shard-local statistics for a
        single post-scan collective: each block's constant-size Stats
        contribution is reduced across shards *inside* the scan and the
        carry accumulates already-reduced values, so the collective for
        block t rides behind block t+1's compute instead of serialising
        after the whole map.  The returned Stats are then already
        globally reduced — callers must NOT psum them again.  Requires
        ``block_size`` (there is nothing to overlap without blocks) and
        is incompatible with ``init`` (a prior-chunk carry is shard-local
        by construction).  Composes with ``batch_blocks``: the sampled
        blocks are reduced as they are scanned and the uniform
        ``nb / batch_blocks`` reweighting is applied to the reduced
        accumulator (every shard's padded geometry gives the same scale,
        so scaling before or after the cross-shard sum commutes exactly
        in real arithmetic and the estimator stays unbiased).
      reduce_buffered: scheduling of the overlapped reduce (only
        meaningful with ``block_reduce_fn``).  True (default) double-
        buffers: the carry holds block t's raw Stats as a ``pending``
        slot and folds ``block_reduce_fn(pending)`` — block t-1's
        reduction — at step t, leaving the collective with no data
        dependence on step t's block compute (XLA's scheduler can
        overlap them); one flush reduces the final pending block after
        the scan.  False reduces each block eagerly in its own step.
        Both orders fold the same reduced values left-to-right, so they
        are BITWISE equal — double-buffering is a pure scheduling
        transformation (asserted in tests/_dist_worker.py).

    Exact mode is mathematically identical to :func:`partial_stats` (every
    statistic is a plain sum over points), but ``lax.scan``s over
    ``ceil(n_k / block_size)`` blocks of ``block_size`` rows, folding each
    block's Stats into a constant-size carry.  Peak live memory is therefore
    O(block_size * (m + q + d)) + O(m^2) — *independent of n_k* — instead of
    the monolithic path's O(n_k m^2) (the GPLVM psi2 broadcast) or
    O(n_k m) (regression).  This is what lets a shard stream more rows than
    fit in its device buffer (paper §5: the 2M-record flight experiment).

    Rows are padded up to a multiple of ``block_size`` with zero weight, so
    every scan step has identical shapes and padding contributes nothing —
    in SVI mode a sampled padding-heavy final block is handled by the same
    mechanism (its rows carry zero weight; the reweighting stays unbiased
    because the scale is uniform across blocks).
    """
    n_k = y.shape[0]
    if batch_blocks is not None:
        if block_size is None:
            raise ValueError(
                "batch_blocks (SVI mode) requires block_size: the minibatch "
                "is a subset of the streaming row blocks")
        if batch_blocks < 1:
            raise ValueError(f"batch_blocks must be >= 1, got {batch_blocks}")
        if init is not None:
            raise ValueError(
                "init cannot be combined with batch_blocks: the SVI "
                "reweighting scales the whole carry, prior chunks included")
    if block_reduce_fn is not None:
        if block_size is None:
            raise ValueError(
                "block_reduce_fn (overlapped reduce) requires block_size: "
                "the per-block collective needs blocks to hide behind")
        if init is not None:
            raise ValueError(
                "init cannot be combined with block_reduce_fn: a prior-"
                "chunk carry is shard-local, the overlapped carry is "
                "already reduced")
        force_scan = True
    if block_size is None or (n_k <= block_size and not force_scan):
        # Single block (or streaming disabled) — no scan machinery needed.
        # With batch_blocks set this is the nb == 1 degenerate case: the
        # "subset" is the whole data, i.e. the exact statistics.
        st = partial_stats(hyp, z, y, mu, s, weights=weights,
                           latent=latent, psi2_fn=psi2_fn,
                           reg_stats_fn=reg_stats_fn, kernel=kernel)
        return st if init is None else fold_stats(init, st)

    w = jnp.ones((n_k,), y.dtype) if weights is None else weights.astype(y.dtype)
    pad = (-n_k) % block_size
    nb = (n_k + pad) // block_size

    def blocks(a, cval=0.0):
        widths = [(0, pad)] + [(0, 0)] * (a.ndim - 1)
        return jnp.pad(a, widths, constant_values=cval).reshape(
            (nb, block_size) + a.shape[1:])

    y_b, mu_b, w_b = blocks(y), blocks(mu), blocks(w)
    # q(X) variances padded with 1s: log-safe, and masked out by w=0 anyway.
    s_b = None if s is None else blocks(s, cval=1.0)

    xs = (y_b, mu_b, w_b) if s is None else (y_b, mu_b, s_b, w_b)

    # -- SVI: gather the sampled blocks, scan only those, reweight ----------
    # Explicit block_indices are always honored (deterministic replay or a
    # custom sampler, possibly with replacement), even at batch_blocks >= nb
    # where the key-driven sampler would degrade to the exact scan.
    scale = 1.0
    if batch_blocks is not None and (batch_blocks < nb
                                     or block_indices is not None):
        if block_indices is None:
            if key is None:
                raise ValueError(
                    "SVI mode needs a PRNG key (or explicit block_indices)")
            block_indices = sample_block_indices(key, nb, batch_blocks)
        idx = jnp.asarray(block_indices)
        if idx.shape != (batch_blocks,):
            raise ValueError(
                f"block_indices must have shape ({batch_blocks},), "
                f"got {idx.shape}")
        xs = tuple(jnp.take(a, idx, axis=0) for a in xs)
        scale = nb / batch_blocks

    def block_stats(yc, muc, sc, wc):
        return partial_stats(hyp, z, yc, muc, sc, weights=wc,
                             latent=latent, psi2_fn=psi2_fn,
                             reg_stats_fn=reg_stats_fn, kernel=kernel)

    # The carry keeps every leaf at rank >= 1 (scalars as (1,)): rank-0 scan
    # residuals trip shard_map's residual promotion on some JAX versions
    # when the chunked map runs (and is differentiated) inside the
    # distributed engine.
    def _block_of(xs_t):
        if s is None:
            yc, muc, wc = xs_t
            return block_stats(yc, muc, None, wc)
        yc, muc, sc, wc = xs_t
        return block_stats(yc, muc, sc, wc)

    def body(carry, xs_t):
        st = _block_of(xs_t)
        return Stats(*(c + jnp.atleast_1d(t) for c, t in zip(carry, st))), None

    # Carry init matches one block's output dtypes exactly (abstract eval —
    # works for any psi2_fn backend, including the Pallas kernel). A caller
    # init (host-fed chunk loop) slots in with the same rank-1 promotion,
    # so continuing a scan here adds the same bits the one-shot scan would.
    shapes = jax.eval_shape(
        block_stats, y_b[0], mu_b[0], None if s is None else s_b[0], w_b[0])

    if block_reduce_fn is not None:
        zero = Stats(*(jnp.zeros(t.shape or (1,), t.dtype) for t in shapes))

        def _fold_reduced(acc, raw):
            red = block_reduce_fn(raw)
            return Stats(*(a + jnp.atleast_1d(t) for a, t in zip(acc, red)))

        if reduce_buffered:
            # Double buffer: step t folds the reduction of block t-1's
            # pending Stats (no data dependence on block t's compute) and
            # parks block t's raw Stats as the new pending; a post-scan
            # flush reduces the last block.  The fold order over real
            # blocks is identical to the eager path's — the initial
            # pending is exact zeros and x + 0.0 == x bitwise — so the
            # two schedules produce bit-identical Stats.
            def body_ov(carry, xs_t):
                acc, pending = carry
                st = _block_of(xs_t)
                acc = _fold_reduced(acc, pending)
                pending = Stats(*(jnp.atleast_1d(t) for t in st))
                return (acc, pending), None

            (acc, pending), _ = jax.lax.scan(body_ov, (zero, zero), xs)
            acc = _fold_reduced(acc, pending)
        else:
            def body_ev(acc, xs_t):
                st = _block_of(xs_t)
                st = Stats(*(jnp.atleast_1d(t) for t in st))
                return _fold_reduced(acc, st), None

            acc, _ = jax.lax.scan(body_ev, zero, xs)
        out = Stats(*(t.reshape(sh.shape) for t, sh in zip(acc, shapes)))
        return out.scale(scale) if scale != 1.0 else out

    if init is None:
        carry0 = Stats(*(jnp.zeros(t.shape or (1,), t.dtype) for t in shapes))
    else:
        carry0 = Stats(*(jnp.atleast_1d(t) for t in init))
    out, _ = jax.lax.scan(body, carry0, xs)
    out = Stats(*(t.reshape(sh.shape) for t, sh in zip(out, shapes)))
    # Every Stats field is a per-point sum, so one uniform scale makes the
    # whole tuple (A, B, C, D, KL, n) unbiased for the exact scan. The
    # bound's global regulariser structure (log-det / quadratic in Kmm) is
    # a *function of* these stats, not itself a per-point sum — it is never
    # scaled here (docs/training.md, "which terms scale").
    return out.scale(scale) if scale != 1.0 else out


def reduce_stats(parts: list[Stats]) -> Stats:
    """Sequential reduce (the single-host analogue of the paper's reduce)."""
    out = parts[0]
    for p in parts[1:]:
        out = out + p
    return out


# -- online posterior updates (continual learning) --------------------------
#
# Every field of ``Stats`` is a plain sum over points, so the statistics of
# a union of data blocks are the element-wise sum of the blocks' statistics.
# That additivity is what the paper's map-reduce exploits *spatially*
# (across shards); ``fold_stats``/``downdate_stats`` exploit it *temporally*:
# a trained model absorbs a new block (or forgets an old one) by adding
# (subtracting) the block's partial Stats into its reduced Stats — no
# re-scan of history, cost independent of how much data came before.

def fold_stats(base: Stats, delta: Stats) -> Stats:
    """Fold a block's partial Stats into reduced Stats: ``stats(A ∪ B)``
    from ``stats(A)`` and ``stats(B)`` — exact, O(m² + md).

    Both arguments must be *exact* (unscaled) statistics for the identity
    to be exact.  SVI-reweighted Stats (``partial_stats_chunked`` with
    ``batch_blocks``) are unbiased *estimates* of the exact ones: folding
    one in yields an unbiased estimate of the folded Stats (the reweighting
    is linear, so it commutes with the fold), but ``downdate_stats`` then
    only undoes it in expectation — the online engines (``SGPR.update``,
    ``DistributedGP.update_stats_fn``) therefore always compute block
    deltas with the exact scan.  Zero-weight rows (distributed padding,
    failed points) already contribute nothing to ``delta`` and need no
    special handling here.
    """
    return base + delta


def downdate_stats(base: Stats, delta: Stats) -> Stats:
    """Remove a block's partial Stats: the exact inverse of
    :func:`fold_stats` (``downdate_stats(fold_stats(s, d), d) == s`` up to
    float addition error).

    ``delta`` must be the statistics of a block previously folded in,
    computed at the *same* hyper-parameters and inducing inputs — Stats are
    a function of (hyp, z), so a fit between fold and downdate invalidates
    the cached block deltas (recompute them from the stored rows, as
    ``SGPR.forget`` does).  Downdating a block that was never folded can
    leave ``D`` indefinite; downstream factor refreshes guard against that
    (``serve.online``).
    """
    return base - delta
