"""Partial sufficient statistics — the paper's Map step.

Each worker holds a shard ``(Y_k, mu_k, S_k)`` (regression: ``S_k = 0``,
``mu_k = X_k``) and computes

    A_k  = Sum_i Y_i Y_i^T            (scalar)
    B_k  = Sum_i psi0_i               (scalar)
    C_k  = Psi1_k^T Y_k               (m, d)
    D_k  = Sum_i psi2_i               (m, m)
    KL_k = Sum_i KL(q(X_i) || p(X_i)) (scalar)

These are exactly the terms the paper's end-point nodes return to the
central node (its §3.2 step 2); their size is independent of n.

``weights`` lets callers mask out padded rows (distributed padding) and
failed nodes (the paper's §5.2 drop-partial-term strategy) without changing
shapes — a zero weight removes point i from every statistic.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from . import gp_kernels as gpk

Array = jax.Array


def reg_stats_dense(hyp: dict, z: Array, x: Array, y: Array, w: Array):
    """Monolithic XLA regression statistics ``(b, C, D)`` — the canonical
    map math shared by :func:`partial_stats` (``s is None`` branch) and the
    fused Pallas op's custom_vjp backward (``kernels.reg_stats``).
    Materialises the (n, m) kernel slab; the fused kernel is the version
    that does not."""
    knm = gpk.ard_kernel(hyp, x, z)                            # (n, m)
    b = jnp.sum(w * gpk.ard_kdiag(hyp, x))
    c = knm.T @ (w[:, None] * y)                               # (m, d)
    d_stat = (knm * w[:, None]).T @ knm                        # (m, m)
    return b, c, d_stat


class Stats(NamedTuple):
    """Sufficient statistics of the collapsed bound. All sums over points."""

    A: Array   # () Frobenius term  Sum Y_i Y_i^T
    B: Array   # () psi0 sum
    C: Array   # (m, d) Psi1^T Y
    D: Array   # (m, m) Psi2
    KL: Array  # () KL(q(X)||p(X))
    n: Array   # () effective number of points contributing

    def __add__(self, other: "Stats") -> "Stats":  # type: ignore[override]
        return Stats(*(a + b for a, b in zip(self, other)))

    def scale(self, c) -> "Stats":
        return Stats(*(c * t for t in self))


def partial_stats(
    hyp: dict,
    z: Array,
    y: Array,
    mu: Array,
    s: Array | None = None,
    weights: Array | None = None,
    latent: bool = True,
    psi2_fn=None,
    reg_stats_fn=None,
) -> Stats:
    """Compute the shard-local statistics (the map function).

    Args:
      hyp: kernel/noise hyper-parameters (log-space dict).
      z: (m, q) inducing inputs.
      y: (n_k, d) outputs on this shard.
      mu: (n_k, q) q(X) means (== inputs X for regression).
      s: (n_k, q) q(X) variances, or None for regression (treated as 0).
      weights: (n_k,) 0/1 mask (padding / failed points). None = all ones.
      latent: include the KL term (GPLVM) or not (regression).
      psi2_fn: override for the psi2 accumulation (e.g. the Pallas kernel).
      reg_stats_fn: override for the regression (B, C, D) accumulation —
        ``fn(hyp, z, mu, y, w) -> (b, c, d)`` (e.g. the fused Pallas kernel,
        which never materialises the (n, m) slab in HBM).
    """
    n_k = y.shape[0]
    w = jnp.ones((n_k,), y.dtype) if weights is None else weights.astype(y.dtype)

    if s is None:
        # Regression: q(X_i) is a delta at the observed inputs. Use the exact
        # kernel forms (cheaper + numerically exact) rather than S->0 limits.
        a = jnp.sum(w * jnp.sum(y * y, axis=-1))
        fn = reg_stats_dense if reg_stats_fn is None else reg_stats_fn
        b, c, d_stat = fn(hyp, z, mu, y, w)
        kl = jnp.zeros((), y.dtype)
    else:
        a = jnp.sum(w * jnp.sum(y * y, axis=-1))
        b = jnp.sum(w * gpk.psi0(hyp, mu, s))
        p1 = gpk.psi1(hyp, z, mu, s)                           # (n, m)
        c = p1.T @ (w[:, None] * y)
        if psi2_fn is None:
            p2 = gpk.psi2_per_point(hyp, z, mu, s)             # (n, m, m)
            d_stat = jnp.einsum("i,iab->ab", w, p2)
        else:
            d_stat = psi2_fn(hyp, z, mu, s, w)
        kl_i = 0.5 * jnp.sum(s + mu * mu - jnp.log(s) - 1.0, axis=-1)
        kl = jnp.sum(w * kl_i) if latent else jnp.zeros((), y.dtype)

    return Stats(A=a, B=b, C=c, D=d_stat, KL=kl, n=jnp.sum(w))


def zero_stats(m: int, d: int, dtype=jnp.float64) -> Stats:
    """The additive identity of the Stats monoid — a reduce/fold init for
    host-side accumulation. (The scan in ``partial_stats_chunked`` builds
    its own carry with scalars promoted to rank 1; see the note there.)"""
    zf = jnp.zeros((), dtype)
    return Stats(A=zf, B=zf, C=jnp.zeros((m, d), dtype),
                 D=jnp.zeros((m, m), dtype), KL=zf, n=zf)


def partial_stats_chunked(
    hyp: dict,
    z: Array,
    y: Array,
    mu: Array,
    s: Array | None = None,
    weights: Array | None = None,
    latent: bool = True,
    psi2_fn=None,
    reg_stats_fn=None,
    block_size: int | None = 1024,
) -> Stats:
    """Streaming map step: ``partial_stats`` folded over fixed-size row blocks.

    ``block_size=None`` delegates to the monolithic :func:`partial_stats`
    (so callers can dispatch on a single optional chunk-size setting).

    Mathematically identical to :func:`partial_stats` (every statistic is a
    plain sum over points), but ``lax.scan``s over ``ceil(n_k / block_size)``
    blocks of ``block_size`` rows, folding each block's Stats into a
    constant-size carry.  Peak live memory is therefore
    O(block_size * (m + q + d)) + O(m^2) — *independent of n_k* — instead of
    the monolithic path's O(n_k m^2) (the GPLVM psi2 broadcast) or
    O(n_k m) (regression).  This is what lets a shard stream more rows than
    fit in its device buffer (paper §5: the 2M-record flight experiment).

    Rows are padded up to a multiple of ``block_size`` with zero weight, so
    every scan step has identical shapes and padding contributes nothing.
    ``psi2_fn`` / ``reg_stats_fn`` (e.g. the Pallas kernels) are invoked once
    per block on block-sized operands.
    """
    n_k = y.shape[0]
    if block_size is None or n_k <= block_size:
        # Single block (or streaming disabled) — no scan machinery needed.
        return partial_stats(hyp, z, y, mu, s, weights=weights,
                             latent=latent, psi2_fn=psi2_fn,
                             reg_stats_fn=reg_stats_fn)

    w = jnp.ones((n_k,), y.dtype) if weights is None else weights.astype(y.dtype)
    pad = (-n_k) % block_size
    nb = (n_k + pad) // block_size

    def blocks(a, cval=0.0):
        widths = [(0, pad)] + [(0, 0)] * (a.ndim - 1)
        return jnp.pad(a, widths, constant_values=cval).reshape(
            (nb, block_size) + a.shape[1:])

    y_b, mu_b, w_b = blocks(y), blocks(mu), blocks(w)
    # q(X) variances padded with 1s: log-safe, and masked out by w=0 anyway.
    s_b = None if s is None else blocks(s, cval=1.0)

    def block_stats(yc, muc, sc, wc):
        return partial_stats(hyp, z, yc, muc, sc, weights=wc,
                             latent=latent, psi2_fn=psi2_fn,
                             reg_stats_fn=reg_stats_fn)

    # The carry keeps every leaf at rank >= 1 (scalars as (1,)): rank-0 scan
    # residuals trip shard_map's residual promotion on some JAX versions
    # when the chunked map runs (and is differentiated) inside the
    # distributed engine.
    def body(carry, xs):
        if s is None:
            yc, muc, wc = xs
            st = block_stats(yc, muc, None, wc)
        else:
            yc, muc, sc, wc = xs
            st = block_stats(yc, muc, sc, wc)
        return Stats(*(c + jnp.atleast_1d(t) for c, t in zip(carry, st))), None

    xs = (y_b, mu_b, w_b) if s is None else (y_b, mu_b, s_b, w_b)
    # Carry init matches one block's output dtypes exactly (abstract eval —
    # works for any psi2_fn backend, including the Pallas kernel).
    shapes = jax.eval_shape(
        block_stats, y_b[0], mu_b[0], None if s is None else s_b[0], w_b[0])
    init = Stats(*(jnp.zeros(t.shape or (1,), t.dtype) for t in shapes))
    out, _ = jax.lax.scan(body, init, xs)
    return Stats(*(t.reshape(sh.shape) for t, sh in zip(out, shapes)))


def reduce_stats(parts: list[Stats]) -> Stats:
    """Sequential reduce (the single-host analogue of the paper's reduce)."""
    out = parts[0]
    for p in parts[1:]:
        out = out + p
    return out
