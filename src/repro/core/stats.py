"""Partial sufficient statistics — the paper's Map step.

Each worker holds a shard ``(Y_k, mu_k, S_k)`` (regression: ``S_k = 0``,
``mu_k = X_k``) and computes

    A_k  = Sum_i Y_i Y_i^T            (scalar)
    B_k  = Sum_i psi0_i               (scalar)
    C_k  = Psi1_k^T Y_k               (m, d)
    D_k  = Sum_i psi2_i               (m, m)
    KL_k = Sum_i KL(q(X_i) || p(X_i)) (scalar)

These are exactly the terms the paper's end-point nodes return to the
central node (its §3.2 step 2); their size is independent of n.

``weights`` lets callers mask out padded rows (distributed padding) and
failed nodes (the paper's §5.2 drop-partial-term strategy) without changing
shapes — a zero weight removes point i from every statistic.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from . import gp_kernels as gpk

Array = jax.Array


class Stats(NamedTuple):
    """Sufficient statistics of the collapsed bound. All sums over points."""

    A: Array   # () Frobenius term  Sum Y_i Y_i^T
    B: Array   # () psi0 sum
    C: Array   # (m, d) Psi1^T Y
    D: Array   # (m, m) Psi2
    KL: Array  # () KL(q(X)||p(X))
    n: Array   # () effective number of points contributing

    def __add__(self, other: "Stats") -> "Stats":  # type: ignore[override]
        return Stats(*(a + b for a, b in zip(self, other)))

    def scale(self, c) -> "Stats":
        return Stats(*(c * t for t in self))


def partial_stats(
    hyp: dict,
    z: Array,
    y: Array,
    mu: Array,
    s: Array | None = None,
    weights: Array | None = None,
    latent: bool = True,
    psi2_fn=None,
) -> Stats:
    """Compute the shard-local statistics (the map function).

    Args:
      hyp: kernel/noise hyper-parameters (log-space dict).
      z: (m, q) inducing inputs.
      y: (n_k, d) outputs on this shard.
      mu: (n_k, q) q(X) means (== inputs X for regression).
      s: (n_k, q) q(X) variances, or None for regression (treated as 0).
      weights: (n_k,) 0/1 mask (padding / failed points). None = all ones.
      latent: include the KL term (GPLVM) or not (regression).
      psi2_fn: override for the psi2 accumulation (e.g. the Pallas kernel).
    """
    n_k = y.shape[0]
    w = jnp.ones((n_k,), y.dtype) if weights is None else weights.astype(y.dtype)

    if s is None:
        # Regression: q(X_i) is a delta at the observed inputs. Use the exact
        # kernel forms (cheaper + numerically exact) rather than S->0 limits.
        knm = gpk.ard_kernel(hyp, mu, z)                       # (n, m)
        a = jnp.sum(w * jnp.sum(y * y, axis=-1))
        b = jnp.sum(w * gpk.ard_kdiag(hyp, mu))
        c = knm.T @ (w[:, None] * y)                           # (m, d)
        d_stat = (knm * w[:, None]).T @ knm                    # (m, m)
        kl = jnp.zeros((), y.dtype)
    else:
        a = jnp.sum(w * jnp.sum(y * y, axis=-1))
        b = jnp.sum(w * gpk.psi0(hyp, mu, s))
        p1 = gpk.psi1(hyp, z, mu, s)                           # (n, m)
        c = p1.T @ (w[:, None] * y)
        if psi2_fn is None:
            p2 = gpk.psi2_per_point(hyp, z, mu, s)             # (n, m, m)
            d_stat = jnp.einsum("i,iab->ab", w, p2)
        else:
            d_stat = psi2_fn(hyp, z, mu, s, w)
        kl_i = 0.5 * jnp.sum(s + mu * mu - jnp.log(s) - 1.0, axis=-1)
        kl = jnp.sum(w * kl_i) if latent else jnp.zeros((), y.dtype)

    return Stats(A=a, B=b, C=c, D=d_stat, KL=kl, n=jnp.sum(w))


def reduce_stats(parts: list[Stats]) -> Stats:
    """Sequential reduce (the single-host analogue of the paper's reduce)."""
    out = parts[0]
    for p in parts[1:]:
        out = out + p
    return out
