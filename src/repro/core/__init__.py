"""Core: the paper's contribution — re-parametrised distributed variational
inference for sparse GP regression and the Bayesian GPLVM.

Public API:
  covariance     compositional kernel expressions + psi-stat dispatch
  gp_kernels     SE-ARD closed forms (the covariance layer's SE entry)
  stats          per-shard partial sufficient statistics (the "map") plus
                 the online fold/downdate (additive Stats across blocks)
  bound          collapsed bound (paper eq. 3.3), optimal q(u), prediction
  chol_update    rank-k Cholesky update/downdate (O(m²k) online refresh)
  distributed    shard_map Map-Reduce engine (the "reduce" + global step)
  sgpr, gplvm    sequential model classes (GPy-analogue reference engines)
  scg            scaled conjugate gradient (Moller 1993)
  ref_naive      O(n^3) oracles for tests
"""
from . import (bound, chol_update, covariance, distributed, gp_kernels,
               init_utils, ref_naive, scg, stats)
from .bound import QU, collapsed_bound, optimal_qu, predict
from .chol_update import chol_downdate_rank_k, chol_update_rank_k
from .covariance import (SEARD, Linear, Matern32, Periodic, Product, Sum,
                         kernel_from_spec)
from .distributed import DistributedGP
from .gplvm import BayesianGPLVM
from .sgpr import SGPR
from .stats import (Stats, downdate_stats, fold_stats, partial_stats,
                    partial_stats_chunked, zero_stats)

__all__ = [
    "bound", "chol_update", "covariance", "distributed", "gp_kernels",
    "init_utils", "ref_naive", "scg", "stats", "QU", "collapsed_bound",
    "optimal_qu", "predict", "SEARD", "Matern32", "Linear", "Periodic",
    "Sum", "Product", "kernel_from_spec", "DistributedGP", "BayesianGPLVM",
    "SGPR", "Stats", "chol_downdate_rank_k", "chol_update_rank_k",
    "downdate_stats", "fold_stats", "partial_stats",
    "partial_stats_chunked", "zero_stats",
]
