"""Sparse GP regression (Titsias 2009) via the paper's re-parametrised bound.

The regression model is the paper's unifying special case: q(X) variance
pinned to 0, mean pinned to the observed inputs, KL term absent. One code
path (``stats.partial_stats`` + ``bound.collapsed_bound``) serves both this
and the GPLVM.

This class is the *sequential* reference engine (single device, the GPy
analogue); ``core.distributed.DistributedGP`` runs the same math sharded.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.flatten_util import ravel_pytree

from . import bound as bound_mod
from . import covariance as cov
from . import init_utils
from . import stats as stats_mod
from .posterior_cache import PosteriorCacheMixin
from .scg import scg
from .stats import partial_stats_chunked


class SGPR(PosteriorCacheMixin):
    """Sparse GP regression with inducing points Z and a pluggable
    covariance expression (``kernel=``; default SE-ARD, the paper's).

    ``kernel``: any ``core.covariance`` expression — a primitive
    (``SEARD``/``Matern32``/``Linear``/``Periodic``) or a ``Sum``/
    ``Product`` composition, or a spec string/dict.  Hyper-parameter init
    adapts to the expression's parameter tree.

    ``chunk_size``: if set, the map step streams the n rows in blocks of
    this many points (``stats.partial_stats_chunked``) so peak memory is
    O(chunk_size * m) instead of O(n * m) — same bound to float precision.

    ``kernel_backend``: "xla" (default) or "pallas" — the latter fuses the
    map's kernel-slab evaluation and both contractions into one Pallas pass
    (``kernels.reg_stats``), so the (n, m) slab never round-trips HBM.  The
    fused kernel is specialised to SE-ARD; for any other expression the
    shim transparently falls back to the XLA map (docs/kernels.md).

    ``batch_blocks``: default minibatch size (in blocks of ``chunk_size``
    rows) for :meth:`fit_svi` — the stochastic trainer whose per-step cost
    is O(batch_blocks * chunk_size), independent of n.  ``fit`` /
    ``log_bound`` / ``predict`` always use the exact scan.
    """

    def __init__(self, x: np.ndarray, y: np.ndarray, num_inducing: int = 50,
                 hyp: dict | None = None, z: np.ndarray | None = None,
                 jitter: float = 1e-6, seed: int = 0,
                 chunk_size: int | None = None,
                 kernel_backend: str = "xla",
                 batch_blocks: int | None = None,
                 kernel=None):
        self.x = jnp.asarray(x, jnp.float64)
        self.y = jnp.asarray(y, jnp.float64)
        self.n, self.q = x.shape
        self.d = y.shape[1]
        self.jitter = jitter
        self.chunk_size = chunk_size
        self.batch_blocks = batch_blocks
        self.kernel = cov.as_kernel(kernel)
        if kernel_backend not in ("xla", "pallas"):
            raise ValueError(
                f"kernel_backend must be 'xla' or 'pallas', got {kernel_backend!r}")
        self.kernel_backend = kernel_backend
        if kernel_backend == "pallas":
            from ..kernels.reg_stats import reg_stats_fn_for_engine
            self._reg_stats_fn = reg_stats_fn_for_engine(kernel=self.kernel)
        else:
            self._reg_stats_fn = None
        z0 = init_utils.kmeans(np.asarray(x), num_inducing, seed=seed) if z is None else z
        hyp0 = (init_utils.default_hyp_for(self.kernel, np.asarray(y), self.q)
                if hyp is None else hyp)
        self.params = {
            "hyp": jax.tree.map(lambda v: jnp.asarray(v, jnp.float64), hyp0),
            "z": jnp.asarray(z0, jnp.float64),
        }
        self._init_posterior_caches()   # stats / PredictiveState / engine
        # Online-update bookkeeping: [start, stop) row ranges of the data
        # blocks folded so far (block 0 = the constructor data); `forget`
        # removes by index and renumbers later blocks (list semantics).
        self._blocks: list[tuple[int, int]] = [(0, self.n)]

        def neg_bound(params, x_, y_):
            st = self._map_stats(params["hyp"], params["z"], y_, x_)
            return -bound_mod.collapsed_bound(params["hyp"], params["z"], st, self.d,
                                              jitter=self.jitter,
                                              kernel=self.kernel)

        self._neg_vg = jax.jit(jax.value_and_grad(neg_bound))

    def _map_stats(self, hyp, z, y, x, batch_blocks=None, key=None):
        return partial_stats_chunked(hyp, z, y, x, s=None, latent=False,
                                     reg_stats_fn=self._reg_stats_fn,
                                     block_size=self.chunk_size,
                                     batch_blocks=batch_blocks, key=key,
                                     kernel=self.kernel)

    # -- objective ----------------------------------------------------------
    def log_bound(self, params=None) -> float:
        params = self.params if params is None else params
        v, _ = self._neg_vg(params, self.x, self.y)
        return -float(v)

    def fit(self, max_iters: int = 200, verbose: bool = False):
        flat0, unravel = ravel_pytree(self.params)

        def fg(xf):
            p = unravel(jnp.asarray(xf))
            v, g = self._neg_vg(p, self.x, self.y)
            gf, _ = ravel_pytree(g)
            return float(v), np.asarray(gf, np.float64)

        res = scg(fg, np.asarray(flat0, np.float64), max_iters=max_iters)
        self.params = jax.tree.map(jnp.asarray, unravel(jnp.asarray(res.x)))
        self._invalidate_posterior()
        if verbose:
            print(f"SGPR fit: bound={-res.f:.4f} iters={res.n_iters} "
                  f"evals={res.n_evals} converged={res.converged}")
        return res

    def fit_svi(self, steps: int = 500, lr: float = 1e-2,
                batch_blocks: int | None = None, seed: int = 0,
                verbose: bool = False):
        """Minibatch-stochastic training (Hensman-style SVI, Adam).

        Each step samples ``batch_blocks`` of the ``ceil(n / chunk_size)``
        row blocks, reweights their Stats by ``n_blocks / batch_blocks``
        (an unbiased estimate of the exact streamed Stats — see
        docs/training.md), and takes one Adam step on the stochastic
        negative bound.  Per-step cost is O(batch_blocks * chunk_size * m),
        independent of n; ``fit`` (exact SCG) remains the right choice when
        a full scan per iteration is affordable.

        Requires ``chunk_size``; ``batch_blocks`` falls back to the value
        given at construction.  Returns a ``train.svi.SVIResult``.
        """
        from ..train.svi import svi_fit

        bb = self.batch_blocks if batch_blocks is None else batch_blocks
        if self.chunk_size is None or bb is None:
            raise ValueError(
                "fit_svi needs chunk_size (the block size) and batch_blocks "
                "(blocks per step) — e.g. SGPR(..., chunk_size=1024, "
                "batch_blocks=4)")

        def neg(params, key):
            st = self._map_stats(params["hyp"], params["z"], self.y, self.x,
                                 batch_blocks=bb, key=key)
            return -bound_mod.collapsed_bound(params["hyp"], params["z"], st,
                                              self.d, jitter=self.jitter,
                                              kernel=self.kernel)

        res = svi_fit(jax.jit(jax.value_and_grad(neg)), self.params,
                      jax.random.PRNGKey(seed), steps=steps, lr=lr)
        self.params = res.params
        self._invalidate_posterior()
        if verbose:
            print(f"SGPR fit_svi: est. bound={-res.history[-1]:.4f} "
                  f"steps={res.n_steps} (B={bb} blocks/step)")
        return res

    # -- online updates (continual learning) --------------------------------
    def update(self, x_new: np.ndarray, y_new: np.ndarray) -> int:
        """Absorb a new data block WITHOUT re-scanning history: O(k·m²).

        Folds the block's partial Stats into the cached reduced Stats
        (``stats.fold_stats`` — exact, the paper's additivity across
        blocks) and, if a ``PredictiveState`` is cached, refreshes its
        factors in place via the rank-k Cholesky update path
        (``serve.online``; O(m²k) instead of the O(m³) refactorisation),
        swapping the refreshed state into the live engine with no
        recompilation.  Parameters are untouched — call ``fit``/``fit_svi``
        afterwards to re-optimise with the enlarged dataset (warm start).

        Returns the new block's index for a later :meth:`forget`.
        """
        x_new = jnp.atleast_2d(jnp.asarray(x_new, jnp.float64))
        y_new = jnp.atleast_2d(jnp.asarray(y_new, jnp.float64))
        if x_new.shape[0] != y_new.shape[0]:
            raise ValueError(
                f"x_new/y_new row mismatch: {x_new.shape[0]} vs "
                f"{y_new.shape[0]}")
        if x_new.shape[1] != self.q or y_new.shape[1] != self.d:
            raise ValueError(
                f"expected (k, {self.q}) inputs and (k, {self.d}) outputs, "
                f"got {x_new.shape} / {y_new.shape}")
        # Stats of the history (cached or one last full scan) and of the
        # new block — both EXACT scans: fold/downdate identities only hold
        # for unscaled statistics (see stats.fold_stats).
        base = self._stats()
        delta = self._map_stats(self.params["hyp"], self.params["z"],
                                y_new, x_new)
        folded = stats_mod.fold_stats(base, delta)

        pstate = self._pstate_cache
        if pstate is not None:
            from ..serve import online
            pstate = online.update_state(pstate, x_new, y_new).state

        self.x = jnp.concatenate([self.x, x_new])
        self.y = jnp.concatenate([self.y, y_new])
        self.n = self.x.shape[0]
        self._blocks.append((self.n - x_new.shape[0], self.n))
        self._refresh_posterior(folded, pstate)
        return len(self._blocks) - 1

    def forget(self, block: int):
        """Remove a previously absorbed block (continual-learning
        counterpart of :meth:`update`): downdates the reduced Stats and the
        cached serving factors — rank-k Cholesky *downdate* with a guarded
        fallback to refactorisation when the removal is ill-conditioned —
        again without re-scanning the surviving data.

        ``block`` indexes the fold order (0 = the constructor data); later
        blocks renumber down by one, like ``list.pop``.  Returns the
        removed ``(x, y)`` arrays.
        """
        nblocks = len(self._blocks)
        if not -nblocks <= block < nblocks:
            raise IndexError(
                f"block {block} out of range ({nblocks} blocks held)")
        start, stop = self._blocks[block % nblocks]
        x_old, y_old = self.x[start:stop], self.y[start:stop]

        base = self._stats()
        delta = self._map_stats(self.params["hyp"], self.params["z"],
                                y_old, x_old)
        downdated = stats_mod.downdate_stats(base, delta)

        pstate = self._pstate_cache
        if pstate is not None:
            from ..serve import online
            pstate = online.downdate_state(pstate, x_old, y_old).state

        k = stop - start
        self.x = jnp.concatenate([self.x[:start], self.x[stop:]])
        self.y = jnp.concatenate([self.y[:start], self.y[stop:]])
        self.n = self.x.shape[0]
        del self._blocks[block % nblocks]
        self._blocks = [(s - k, e - k) if s >= stop else (s, e)
                        for s, e in self._blocks]
        self._refresh_posterior(downdated, pstate)
        return np.asarray(x_old), np.asarray(y_old)

    @property
    def num_blocks(self) -> int:
        """How many data blocks the model currently holds (fold order)."""
        return len(self._blocks)

    # -- posterior ----------------------------------------------------------
    def _stats(self):
        if self._stats_cache is None:
            self._stats_cache = self._map_stats(
                self.params["hyp"], self.params["z"], self.y, self.x)
        return self._stats_cache

    def qu(self) -> bound_mod.QU:
        return bound_mod.optimal_qu(self.params["hyp"], self.params["z"],
                                    self._stats(), jitter=self.jitter,
                                    kernel=self.kernel)

    def predictive_state(self):
        """The frozen ``serve.PredictiveState`` for the current params —
        extracted once (map-reduce + q(u) factor solves) and cached until
        ``fit``/``fit_svi`` move the parameters."""
        if self._pstate_cache is None:
            from ..serve import state_from_model
            self._pstate_cache = state_from_model(self)
        return self._pstate_cache

    def serve_engine(self, block_size: int = 256, mesh=None,
                     data_axes=("data",), kernel_backend: str | None = None,
                     donate: bool = False):
        """A ``serve.PredictEngine`` over the current predictive state (a
        fresh engine every call — callers own its lifetime; ``predict``
        keeps its own cached default).  ``kernel_backend`` defaults to the
        model's own training backend."""
        from ..serve import PredictEngine
        return PredictEngine(self.predictive_state(), block_size=block_size,
                             mesh=mesh, data_axes=data_axes,
                             kernel_backend=kernel_backend or self.kernel_backend,
                             donate=donate)

    def predict(self, xstar: np.ndarray, include_noise: bool = False,
                full_cov: bool = False):
        """Thin wrapper over the serving subsystem: the q(u)/factor solves
        are cached in the ``PredictiveState`` (not re-done per request) and
        queries run through the jitted block engine."""
        if self._engine_cache is None:
            self._engine_cache = self.serve_engine()
        out = self._engine_cache(jnp.asarray(xstar, jnp.float64),
                                 include_noise=include_noise,
                                 full_cov=full_cov)
        return tuple(np.asarray(o) for o in out)

    def sample(self, xstar: np.ndarray, num_samples: int,
               key=None, seed: int = 0, include_noise: bool = False):
        """Posterior function draws at ``xstar``: (num_samples, t, d).

        Delegates to the cached ``serve.PredictEngine.sample`` — joint
        within each query block (block size of the cached engine),
        independent across blocks.  Pass a ``jax.random`` key for explicit
        control, or a ``seed`` for convenience."""
        if self._engine_cache is None:
            self._engine_cache = self.serve_engine()
        if key is None:
            key = jax.random.PRNGKey(seed)
        smp = self._engine_cache.sample(jnp.asarray(xstar, jnp.float64),
                                        num_samples, key,
                                        include_noise=include_noise)
        return np.asarray(smp)
