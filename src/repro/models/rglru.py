"""RG-LRU recurrent block (Griffin / RecurrentGemma).

Recurrence (per channel):
    r_t = sigmoid(W_a xc_t + b_a)              recurrence gate
    i_t = sigmoid(W_i xc_t + b_i)              input gate
    a_t = exp(-c * softplus(Lambda) * r_t)     c = 8
    h_t = a_t h_{t-1} + sqrt(1 - a_t^2) * (i_t * xc_t)

where xc is the width-4 causal-conv of the linear branch. Train/prefill use
``jax.lax.associative_scan`` over time (log-depth, TPU friendly); decode
keeps an O(lru_width) state. The block multiplies the recurrence output
with a GeLU gate branch and projects back — giving the hybrid arch its
constant-memory long-context path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import ParamBuilder, sub
from .ssm import _causal_conv

Array = jax.Array
_C = 8.0


def init_rglru(pb: ParamBuilder, tree, specs, cfg):
    lru = cfg.lru_width or cfg.d_model
    t, s = sub(tree, specs, "rglru")
    pb.make(t, s, [], "w_x", (cfg.d_model, lru), ("embed", "lru"))
    pb.make(t, s, [], "w_gate", (cfg.d_model, lru), ("embed", "lru"))
    pb.make(t, s, [], "conv_w", (lru, cfg.conv_kernel), ("lru", "conv"))
    pb.make(t, s, [], "conv_b", (lru,), ("lru",), init="zeros")
    pb.make(t, s, [], "w_a", (lru, lru), ("lru", None))
    pb.make(t, s, [], "b_a", (lru,), (None,), init="zeros")
    pb.make(t, s, [], "w_i", (lru, lru), ("lru", None))
    pb.make(t, s, [], "b_i", (lru,), (None,), init="zeros")
    pb.make(t, s, [], "lam", (lru,), (None,), init="ones")
    pb.make(t, s, [], "w_out", (lru, cfg.d_model), ("lru", "embed"))


def _gates(p, xc: Array):
    r = jax.nn.sigmoid(xc @ p["w_a"].astype(xc.dtype)
                       + p["b_a"].astype(xc.dtype)).astype(jnp.float32)
    i = jax.nn.sigmoid(xc @ p["w_i"].astype(xc.dtype)
                       + p["b_i"].astype(xc.dtype)).astype(jnp.float32)
    lam = jax.nn.softplus(p["lam"].astype(jnp.float32))
    log_a = -_C * lam * r                                   # (..., lru) <= 0
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * i * xc.astype(jnp.float32)
    return a, b


def rglru_forward(cfg, p, x: Array, *, init=None):
    """x (B,T,D) -> (y (B,T,D), cache dict)."""
    xl = x @ p["w_x"].astype(x.dtype)                        # (B,T,lru)
    xc = _causal_conv(xl, p["conv_w"], p["conv_b"])
    a, b = _gates(p, xc)                                     # (B,T,lru) f32
    if init is not None:
        # Fold the carried state in as a virtual step-0 contribution.
        b = b.at[:, 0].add(a[:, 0] * init["h"].astype(jnp.float32))

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, ar * bl + br

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    gate = jax.nn.gelu(x @ p["w_gate"].astype(x.dtype))
    y = (h.astype(x.dtype) * gate) @ p["w_out"].astype(x.dtype)
    cache = {"h": h[:, -1], "conv": xl[:, -(cfg.conv_kernel - 1):, :]}
    return y, cache


def init_rglru_cache(cfg, batch: int, dtype) -> dict:
    lru = cfg.lru_width or cfg.d_model
    return {
        "h": jnp.zeros((batch, lru), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_kernel - 1, lru), dtype),
    }


def rglru_decode(cfg, p, x_t: Array, cache: dict):
    """Single-token step; x_t (B,1,D)."""
    xl = x_t @ p["w_x"].astype(x_t.dtype)                    # (B,1,lru)
    win = jnp.concatenate([cache["conv"], xl], axis=1)       # (B,K,lru)
    xc = jnp.einsum("bkc,ck->bc", win.astype(jnp.float32),
                    p["conv_w"].astype(jnp.float32))
    xc = (xc + p["conv_b"].astype(jnp.float32)).astype(x_t.dtype)
    a, b = _gates(p, xc)                                     # (B,lru)
    h = a * cache["h"] + b
    gate = jax.nn.gelu(x_t @ p["w_gate"].astype(x_t.dtype))  # (B,1,lru)
    y = (h[:, None, :].astype(x_t.dtype) * gate) @ p["w_out"].astype(x_t.dtype)
    return y, {"h": h, "conv": win[:, 1:]}
