"""LM substrate: functional model zoo for the assigned architecture pool."""
from . import attention, common, mlp, moe, rglru, ssm, transformer
