"""Attention variants: GQA (+local window), MLA (DeepSeek), cross-attention.

Train/prefill paths are memory-bounded via query-chunked attention (lax.scan
over query blocks — no (T, S) materialisation) or the Pallas flash kernel
(cfg.use_flash). Decode paths use single-token KV caches; local-window
attention uses a rolling O(window) cache; MLA decode uses the absorbed
formulation against the compressed c_kv cache.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..distributed.sharding import axis_divides, constrain
from .common import ParamBuilder, apply_rope, sub

Array = jax.Array
NEG_INF = -1.0e30


def head_dim(cfg) -> int:
    return cfg.head_dim or cfg.d_model // cfg.num_heads


# ---------------------------------------------------------------------------
# parameter creation
# ---------------------------------------------------------------------------

def init_gqa(pb: ParamBuilder, tree, specs, cfg):
    dh = head_dim(cfg)
    hq, hkv = f"heads:{dh}", f"kv_heads:{dh}"
    t, s = sub(tree, specs, "attn")
    pb.make(t, s, [], "wq", (cfg.d_model, cfg.num_heads * dh), ("embed", hq))
    pb.make(t, s, [], "wk", (cfg.d_model, cfg.num_kv_heads * dh),
            ("embed", hkv))
    pb.make(t, s, [], "wv", (cfg.d_model, cfg.num_kv_heads * dh),
            ("embed", hkv))
    pb.make(t, s, [], "wo", (cfg.num_heads * dh, cfg.d_model), (hq, "embed"))
    if cfg.qkv_bias:
        pb.make(t, s, [], "bq", (cfg.num_heads * dh,), (hq,), init="zeros")
        pb.make(t, s, [], "bk", (cfg.num_kv_heads * dh,), (hkv,), init="zeros")
        pb.make(t, s, [], "bv", (cfg.num_kv_heads * dh,), (hkv,), init="zeros")


def init_mla(pb: ParamBuilder, tree, specs, cfg):
    h = cfg.num_heads
    qk = cfg.qk_nope_head_dim + cfg.qk_rope_head_dim
    t, s = sub(tree, specs, "attn")
    pb.make(t, s, [], "wq_a", (cfg.d_model, cfg.q_lora_rank), ("embed", "rank"))
    pb.make(t, s, [], "q_norm", (cfg.q_lora_rank,), (None,), init="zeros")
    pb.make(t, s, [], "wq_b", (cfg.q_lora_rank, h * qk),
            ("rank", f"heads:{qk}"))
    pb.make(t, s, [], "wkv_a",
            (cfg.d_model, cfg.kv_lora_rank + cfg.qk_rope_head_dim),
            ("embed", "rank"))
    pb.make(t, s, [], "kv_norm", (cfg.kv_lora_rank,), (None,), init="zeros")
    pb.make(t, s, [], "wk_b", (cfg.kv_lora_rank, h * cfg.qk_nope_head_dim),
            ("rank", f"heads:{cfg.qk_nope_head_dim}"))
    pb.make(t, s, [], "wv_b", (cfg.kv_lora_rank, h * cfg.v_head_dim),
            ("rank", f"heads:{cfg.v_head_dim}"))
    pb.make(t, s, [], "wo", (h * cfg.v_head_dim, cfg.d_model),
            (f"heads:{cfg.v_head_dim}", "embed"))


def init_cross(pb: ParamBuilder, tree, specs, cfg):
    dh = head_dim(cfg)
    hq, hkv = f"heads:{dh}", f"kv_heads:{dh}"
    t, s = sub(tree, specs, "xattn")
    pb.make(t, s, [], "wq", (cfg.d_model, cfg.num_heads * dh), ("embed", hq))
    pb.make(t, s, [], "wk", (cfg.d_model, cfg.num_kv_heads * dh),
            ("embed", hkv))
    pb.make(t, s, [], "wv", (cfg.d_model, cfg.num_kv_heads * dh),
            ("embed", hkv))
    pb.make(t, s, [], "wo", (cfg.num_heads * dh, cfg.d_model), (hq, "embed"))


# ---------------------------------------------------------------------------
# chunked softmax attention core (no (T,S) materialisation)
# ---------------------------------------------------------------------------

def _attend_chunked(q, k, v, *, causal: bool, window: int | None,
                    chunk: int = 512):
    """q: (B,T,H,Dh); k/v: (B,S,Hkv,Dh). Suffix-aligned causal. -> (B,T,H,Dh)."""
    b, t, h, dh = q.shape
    s_len, hkv = k.shape[1], k.shape[2]
    dv = v.shape[-1]                                       # may differ (MLA)
    group = h // hkv
    scale = dh ** -0.5
    offset = s_len - t
    c = min(chunk, t)
    pad = (-t) % c
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nq = q.shape[1] // c
    qs = q.reshape(b, nq, c, h, dh).swapaxes(0, 1)         # (nq, B, c, H, Dh)
    kg = k.reshape(b, s_len, hkv, 1, dh)
    vg = v.reshape(b, s_len, hkv, 1, dv)
    col = jnp.arange(s_len)

    # Constraints are only asserted when H divides the TP extent — pinning
    # an indivisible layout (qwen2 12H, starcoder2 24H @ TP=16) forces XLA
    # into full replication and regresses those cells (§Perf iter 10).
    tp_ok = axis_divides("heads", h)
    cst = constrain if tp_ok else (lambda x_, _ax: x_)

    def body(_, args):
        qi, blk = args
        # FUSED-head formulation (§Perf iters 3/7/9): scores carry the full
        # H = hkv*group head dim so TP sharding divides whenever H % TP == 0
        # (kv- or group-dim alone often doesn't: llama kv=8, g=4, TP=16).
        # K/V are broadcast to H lazily — per-shard they materialise only
        # local heads. Without the explicit constraints the bwd pass
        # all-gathers O(B*H*c*S) score tensors per chunk (measured 18
        # TB/step on qwen3-moe train_4k).
        qb = cst(qi, ("batch", None, "heads", None))   # (B,c,H,dh)
        kf = jnp.broadcast_to(kg, (b, s_len, hkv, group, dh)) \
            .reshape(b, s_len, h, dh)
        vf = jnp.broadcast_to(vg, (b, s_len, hkv, group, dv)) \
            .reshape(b, s_len, h, dv)
        kf = cst(kf, ("batch", None, "heads", None))
        vf = cst(vf, ("batch", None, "heads", None))
        sc = jnp.einsum("bchd,bshd->bhcs", qb.astype(jnp.float32),
                        kf.astype(jnp.float32)) * scale     # (B,H,c,S)
        row = blk * c + jnp.arange(c) + offset              # absolute q pos
        valid = jnp.ones((c, s_len), bool)
        if causal:
            valid &= col[None, :] <= row[:, None]
        if window is not None:
            valid &= col[None, :] > row[:, None] - window
        sc = jnp.where(valid[None, None], sc, NEG_INF)
        sc = cst(sc, ("batch", "heads", None, None))
        p = jax.nn.softmax(sc, axis=-1)
        p = cst(p, ("batch", "heads", None, None))
        o = jnp.einsum("bhcs,bshd->bchd", p, vf.astype(jnp.float32))
        o = cst(o, ("batch", None, "heads", None))
        return None, o.astype(q.dtype)

    _, outs = jax.lax.scan(body, None, (qs, jnp.arange(nq)))
    out = outs.swapaxes(0, 1).reshape(b, nq * c, h, dv)
    return out[:, :t]


# ---------------------------------------------------------------------------
# GQA forward (train / prefill)
# ---------------------------------------------------------------------------

def gqa_forward(cfg, p, x: Array, positions: Array, *, causal=True,
                window=None) -> Array:
    b, t, _ = x.shape
    dh = head_dim(cfg)
    q = x @ p["wq"].astype(x.dtype)
    k = x @ p["wk"].astype(x.dtype)
    v = x @ p["wv"].astype(x.dtype)
    if cfg.qkv_bias:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    q = q.reshape(b, t, cfg.num_heads, dh)
    k = k.reshape(b, t, cfg.num_kv_heads, dh)
    v = v.reshape(b, t, cfg.num_kv_heads, dh)
    if cfg.rope_theta:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    q = constrain(q, ("batch", None, "heads", None))
    if cfg.use_flash:
        from ..kernels.flash_attention import ops as fa
        out = fa.flash_attention(q.swapaxes(1, 2), k.swapaxes(1, 2),
                                 v.swapaxes(1, 2), causal=causal)
        out = out.swapaxes(1, 2)
    else:
        out = _attend_chunked(q, k, v, causal=causal, window=window)
    out = out.reshape(b, t, cfg.num_heads * dh)
    return out @ p["wo"].astype(x.dtype)


def cross_forward(cfg, p, x: Array, enc_kv: tuple[Array, Array]) -> Array:
    """Cross attention against precomputed encoder K/V (B,S,Hkv,Dh)."""
    b, t, _ = x.shape
    dh = head_dim(cfg)
    q = (x @ p["wq"].astype(x.dtype)).reshape(b, t, cfg.num_heads, dh)
    k, v = enc_kv
    out = _attend_chunked(q, k, v, causal=False, window=None)
    return out.reshape(b, t, cfg.num_heads * dh) @ p["wo"].astype(x.dtype)


def encode_kv(cfg, p, enc_out: Array) -> tuple[Array, Array]:
    b, s_len, _ = enc_out.shape
    dh = head_dim(cfg)
    k = (enc_out @ p["wk"].astype(enc_out.dtype)).reshape(
        b, s_len, cfg.num_kv_heads, dh)
    v = (enc_out @ p["wv"].astype(enc_out.dtype)).reshape(
        b, s_len, cfg.num_kv_heads, dh)
    return k, v


# ---------------------------------------------------------------------------
# GQA decode (single token, KV cache)
# ---------------------------------------------------------------------------

def init_gqa_cache(cfg, batch: int, max_len: int, dtype) -> dict:
    dh = head_dim(cfg)
    w = cfg.local_window
    s_len = min(w, max_len) if w else max_len
    return {
        "k": jnp.zeros((batch, s_len, cfg.num_kv_heads, dh), dtype),
        "v": jnp.zeros((batch, s_len, cfg.num_kv_heads, dh), dtype),
        "pos": jnp.full((batch, s_len), -1, jnp.int32),
    }


def gqa_decode(cfg, p, x_t: Array, cache: dict, pos: Array):
    """x_t: (B, 1, D); pos: (B,) current absolute position. Rolling cache
    when cfg.local_window is set (O(window) memory for 500k contexts)."""
    b = x_t.shape[0]
    dh = head_dim(cfg)
    q = x_t @ p["wq"].astype(x_t.dtype)
    k = x_t @ p["wk"].astype(x_t.dtype)
    v = x_t @ p["wv"].astype(x_t.dtype)
    if cfg.qkv_bias:
        q = q + p["bq"].astype(x_t.dtype)
        k = k + p["bk"].astype(x_t.dtype)
        v = v + p["bv"].astype(x_t.dtype)
    q = q.reshape(b, 1, cfg.num_heads, dh)
    k = k.reshape(b, 1, cfg.num_kv_heads, dh)
    v = v.reshape(b, 1, cfg.num_kv_heads, dh)
    if cfg.rope_theta:
        q = apply_rope(q, pos[:, None], cfg.rope_theta)
        k = apply_rope(k, pos[:, None], cfg.rope_theta)

    s_len = cache["k"].shape[1]
    slot = (pos % s_len) if cfg.local_window else jnp.minimum(pos, s_len - 1)
    bidx = jnp.arange(b)
    ck = cache["k"].at[bidx, slot].set(k[:, 0])
    cv = cache["v"].at[bidx, slot].set(v[:, 0])
    cpos = cache["pos"].at[bidx, slot].set(pos)

    group = cfg.num_heads // cfg.num_kv_heads
    qb = q.reshape(b, cfg.num_kv_heads, group, dh)
    sc = jnp.einsum("bhgd,bshd->bhgs", qb.astype(jnp.float32),
                    ck.astype(jnp.float32)) * dh ** -0.5
    sc = constrain(sc, ("batch", "kv_heads", "heads_group", None))
    valid = (cpos >= 0) & (cpos <= pos[:, None])
    if cfg.local_window:
        valid &= cpos > (pos[:, None] - cfg.local_window)
    sc = jnp.where(valid[:, None, None, :], sc, NEG_INF)
    pr = jax.nn.softmax(sc, axis=-1)
    o = jnp.einsum("bhgs,bshd->bhgd", pr, cv.astype(jnp.float32))
    o = constrain(o, ("batch", "kv_heads", "heads_group", None))
    o = o.reshape(b, 1, cfg.num_heads * dh).astype(x_t.dtype)
    return o @ p["wo"].astype(x_t.dtype), {"k": ck, "v": cv, "pos": cpos}


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2): train + absorbed decode over the compressed cache
# ---------------------------------------------------------------------------

def _mla_qkv(cfg, p, x, positions):
    from .common import rmsnorm
    b, t, _ = x.shape
    h = cfg.num_heads
    nope, rope = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    cq = rmsnorm(x @ p["wq_a"].astype(x.dtype), p["q_norm"])
    q = (cq @ p["wq_b"].astype(x.dtype)).reshape(b, t, h, nope + rope)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    kv_a = x @ p["wkv_a"].astype(x.dtype)                 # (B,T,lora+rope)
    c_kv = rmsnorm(kv_a[..., : cfg.kv_lora_rank], p["kv_norm"])
    k_rope = apply_rope(kv_a[..., cfg.kv_lora_rank:], positions,
                        cfg.rope_theta)                   # (B,T,rope) shared
    return q_nope, q_rope, c_kv, k_rope


def mla_forward(cfg, p, x: Array, positions: Array) -> Array:
    b, t, _ = x.shape
    h = cfg.num_heads
    nope, rope = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    q_nope, q_rope, c_kv, k_rope = _mla_qkv(cfg, p, x, positions)
    k_nope = (c_kv @ p["wk_b"].astype(x.dtype)).reshape(b, t, h, nope)
    v = (c_kv @ p["wv_b"].astype(x.dtype)).reshape(b, t, h, cfg.v_head_dim)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (b, t, h, rope))],
        axis=-1)
    out = _attend_chunked(q, k, v, causal=True, window=None)
    out = out.reshape(b, t, h * cfg.v_head_dim)
    return out @ p["wo"].astype(x.dtype)


def init_mla_cache(cfg, batch: int, max_len: int, dtype) -> dict:
    return {
        "c_kv": jnp.zeros((batch, max_len, cfg.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, max_len, cfg.qk_rope_head_dim), dtype),
        "pos": jnp.full((batch, max_len), -1, jnp.int32),
    }


def mla_decode(cfg, p, x_t: Array, cache: dict, pos: Array):
    """Absorbed MLA decode: scores/values computed against the compressed
    c_kv cache; W_kb/W_vb folded into the query/output projections."""
    b = x_t.shape[0]
    h = cfg.num_heads
    nope, rope = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    q_nope, q_rope, c_kv_t, k_rope_t = _mla_qkv(cfg, p, x_t, pos[:, None])

    bidx = jnp.arange(b)
    slot = jnp.minimum(pos, cache["c_kv"].shape[1] - 1)
    ck = cache["c_kv"].at[bidx, slot].set(c_kv_t[:, 0])
    kr = cache["k_rope"].at[bidx, slot].set(k_rope_t[:, 0])
    cpos = cache["pos"].at[bidx, slot].set(pos)

    wk_b = p["wk_b"].reshape(cfg.kv_lora_rank, h, nope)
    # absorb: q_eff (B,H,lora) = q_nope . W_kb^T
    q_eff = jnp.einsum("bhd,lhd->bhl", q_nope[:, 0].astype(jnp.float32),
                       wk_b.astype(jnp.float32))
    sc = jnp.einsum("bhl,bsl->bhs", q_eff, ck.astype(jnp.float32))
    sc = sc + jnp.einsum("bhd,bsd->bhs", q_rope[:, 0].astype(jnp.float32),
                         kr.astype(jnp.float32))
    sc = sc * (nope + rope) ** -0.5
    valid = (cpos >= 0) & (cpos <= pos[:, None])
    sc = jnp.where(valid[:, None, :], sc, NEG_INF)
    pr = jax.nn.softmax(sc, axis=-1)
    ctx = jnp.einsum("bhs,bsl->bhl", pr, ck.astype(jnp.float32))  # (B,H,lora)
    wv_b = p["wv_b"].reshape(cfg.kv_lora_rank, h, cfg.v_head_dim)
    o = jnp.einsum("bhl,lhv->bhv", ctx, wv_b.astype(jnp.float32))
    o = o.reshape(b, 1, h * cfg.v_head_dim).astype(x_t.dtype)
    return o @ p["wo"].astype(x_t.dtype), {"c_kv": ck, "k_rope": kr,
                                           "pos": cpos}
