"""Dense MLPs: SwiGLU (llama/qwen family) and GELU (starcoder2/whisper)."""
from __future__ import annotations

import jax

from .common import ParamBuilder, sub


def init_mlp(pb: ParamBuilder, tree, specs, cfg, d_ff: int | None = None,
             name: str = "mlp"):
    d_ff = cfg.d_ff if d_ff is None else d_ff
    t, s = sub(tree, specs, name)
    if cfg.mlp_type == "swiglu":
        pb.make(t, s, [], "w_gate", (cfg.d_model, d_ff), ("embed", "mlp"))
        pb.make(t, s, [], "w_up", (cfg.d_model, d_ff), ("embed", "mlp"))
        pb.make(t, s, [], "w_down", (d_ff, cfg.d_model), ("mlp", "embed"))
    else:  # gelu
        pb.make(t, s, [], "w_up", (cfg.d_model, d_ff), ("embed", "mlp"))
        pb.make(t, s, [], "b_up", (d_ff,), ("mlp",), init="zeros")
        pb.make(t, s, [], "w_down", (d_ff, cfg.d_model), ("mlp", "embed"))
        pb.make(t, s, [], "b_down", (cfg.d_model,), (None,), init="zeros")


def mlp_forward(cfg, p: dict, x: jax.Array) -> jax.Array:
    if cfg.mlp_type == "swiglu":
        g = jax.nn.silu(x @ p["w_gate"].astype(x.dtype))
        u = x @ p["w_up"].astype(x.dtype)
        return (g * u) @ p["w_down"].astype(x.dtype)
    h = jax.nn.gelu(x @ p["w_up"].astype(x.dtype) + p["b_up"].astype(x.dtype))
    return h @ p["w_down"].astype(x.dtype) + p["b_down"].astype(x.dtype)
