"""Mixture-of-Experts with expert parallelism.

Two code paths sharing the router math:

* ``moe_dense``   — every expert applied to every token, combined by gates.
  Exact, O(E/topk) too much compute; used for tiny smoke configs and as the
  test oracle for the sharded path.
* ``moe_sharded`` — the production path: shard_map over the mesh, experts
  sharded along the ``model`` axis. Per model-shard token slice ->
  sort-based pack into a fixed-capacity (E, C, D) buffer -> all_to_all
  (dispatch) -> local expert FFN -> all_to_all (return) -> unpack/combine ->
  all_gather tokens. This is the DeepSeek-style EP schedule expressed in
  jax.lax collectives; XLA overlaps the two all_to_alls with the shared
  expert running outside.

Capacity C = ceil(topk * tokens / E * capacity_factor) tokens are kept per
expert (sorted by arrival order); overflow tokens fall back to their gate
mass being dropped (standard token-dropping MoE).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from ..distributed import sharding as shlib
from .common import ParamBuilder, sub

Array = jax.Array


def init_moe(pb: ParamBuilder, tree, specs, cfg):
    e, dff = cfg.num_experts, cfg.moe_d_ff
    t, s = sub(tree, specs, "moe")
    pb.make(t, s, [], "router", (cfg.d_model, e), ("embed", None))
    pb.make(t, s, [], "w_gate", (e, cfg.d_model, dff),
            ("experts", "moe_mlp", None))
    pb.make(t, s, [], "w_up", (e, cfg.d_model, dff),
            ("experts", "moe_mlp", None))
    pb.make(t, s, [], "w_down", (e, dff, cfg.d_model),
            ("experts", None, "moe_mlp"))


def _route(cfg, router_w, x_flat):
    """x_flat (n, D) -> (gates (n,k), eids (n,k), aux losses)."""
    logits = (x_flat @ router_w.astype(x_flat.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, eids = lax.top_k(probs, cfg.experts_per_token)
    gates = gates / jnp.sum(gates, axis=-1, keepdims=True)
    # Shazeer load-balance aux: E * sum_e f_e * P_e
    e = cfg.num_experts
    f = jnp.mean(jax.nn.one_hot(eids, e, dtype=jnp.float32), axis=(0, 1))
    pmean = jnp.mean(probs, axis=0)
    aux = e * jnp.sum(f * pmean)
    zloss = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
    return gates.astype(x_flat.dtype), eids, {"load_balance": aux,
                                              "router_z": zloss}


def moe_dense(cfg, p, x: Array):
    """(B,T,D) exact all-experts path (smoke/test oracle)."""
    b, t, d = x.shape
    xf = x.reshape(b * t, d)
    gates, eids, aux = _route(cfg, p["router"], xf)
    h_gate = jnp.einsum("nd,edf->enf", xf, p["w_gate"].astype(x.dtype))
    h_up = jnp.einsum("nd,edf->enf", xf, p["w_up"].astype(x.dtype))
    y_e = jnp.einsum("enf,efd->end", jax.nn.silu(h_gate) * h_up,
                     p["w_down"].astype(x.dtype))
    comb = jnp.zeros((xf.shape[0], cfg.num_experts), x.dtype)
    comb = comb.at[jnp.arange(xf.shape[0])[:, None], eids].add(gates)
    y = jnp.einsum("ne,end->nd", comb, y_e)
    return y.reshape(b, t, d), aux


def _capacity(cfg, n_tokens: int) -> int:
    c = math.ceil(cfg.experts_per_token * n_tokens / cfg.num_experts
                  * cfg.capacity_factor)
    return max(4, -(-c // 4) * 4)


def _pack_local(cfg, xs, gates, eids, cap):
    """Sort-based pack: xs (n,D) -> buf (E*C, D); returns buf, scatter meta."""
    n, d = xs.shape
    k = cfg.experts_per_token
    flat_e = eids.reshape(n * k)
    flat_tok = jnp.repeat(jnp.arange(n), k)
    flat_gate = gates.reshape(n * k)
    order = jnp.argsort(flat_e)
    e_sorted = flat_e[order]
    counts = jnp.bincount(flat_e, length=cfg.num_experts)
    starts = jnp.cumsum(counts) - counts
    rank = jnp.arange(n * k) - starts[e_sorted]
    keep = rank < cap
    dest = jnp.where(keep, e_sorted * cap + rank, cfg.num_experts * cap)
    buf = jnp.zeros((cfg.num_experts * cap + 1, d), xs.dtype)
    buf = buf.at[dest].add(xs[flat_tok[order]])
    return buf[:-1], (order, flat_tok, flat_gate, dest, keep)


def _unpack_local(cfg, y_buf, meta, n, d):
    order, flat_tok, flat_gate, dest, keep = meta
    y_slot = jnp.concatenate([y_buf, jnp.zeros((1, d), y_buf.dtype)], 0)[dest]
    w = jnp.where(keep, flat_gate[order], 0.0)[:, None].astype(y_buf.dtype)
    y = jnp.zeros((n, d), y_buf.dtype)
    return y.at[flat_tok[order]].add(w * y_slot)


def _plain_a2a(v, split, concat):
    return lax.all_to_all(v, "model", split_axis=split, concat_axis=concat,
                          tiled=True)


@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2))
def _qa2a(v, split, concat):
    """int8-on-the-wire all_to_all (beyond-paper EP optimisation): values are
    quantised per (expert, slot) row before the collective, scales ride
    along; the BACKWARD all_to_all is quantised the same way (custom_vjp),
    so both directions move ~2x (vs bf16) / ~4x (vs f32) fewer bytes."""
    out, _ = _qa2a_fwd(v, split, concat)
    return out


def _quant_pair(v, split, concat):
    sc = jnp.maximum(jnp.max(jnp.abs(v), axis=-1, keepdims=True), 1e-12) / 127.0
    q = jnp.clip(jnp.round(v / sc), -127, 127).astype(jnp.int8)
    q_r = lax.all_to_all(q, "model", split_axis=split, concat_axis=concat,
                         tiled=True)
    sc_r = lax.all_to_all(sc, "model", split_axis=split, concat_axis=concat,
                          tiled=True)
    return (q_r.astype(jnp.float32) * sc_r).astype(v.dtype)


def _qa2a_fwd(v, split, concat):
    return _quant_pair(v, split, concat), None


def _qa2a_bwd(split, concat, _, g):
    # transpose of all_to_all swaps split/concat; quantise the cotangent too
    return (_quant_pair(g, concat, split),)


_qa2a.defvjp(_qa2a_fwd, _qa2a_bwd)


def _expert_ffn(p, xb, dtype):
    """xb (E_loc, C', D) with local expert weights."""
    g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xb, p["w_gate"].astype(dtype)))
    u = jnp.einsum("ecd,edf->ecf", xb, p["w_up"].astype(dtype))
    return jnp.einsum("ecf,efd->ecd", g * u, p["w_down"].astype(dtype))


def moe_sharded(cfg, p, x: Array):
    """Expert-parallel MoE via shard_map + all_to_all (see module doc)."""
    mesh = shlib._CTX["mesh"]
    if mesh is None or "model" not in mesh.shape:
        return moe_dense(cfg, p, x)
    em = mesh.shape["model"]
    if cfg.num_experts % em != 0:
        return moe_dense(cfg, p, x)
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)

    x_spec = P(batch_axes, None, None)
    w_specs = {"router": P(), "w_gate": P("model"), "w_up": P("model"),
               "w_down": P("model")}

    def block(xl, pl_):
        b_loc, t, d = xl.shape
        n = b_loc * t
        pad = (-n) % em
        xf = xl.reshape(n, d)
        if pad:
            xf = jnp.pad(xf, ((0, pad), (0, 0)))
        n_p = xf.shape[0]
        per = n_p // em
        i = lax.axis_index("model")
        xs = lax.dynamic_slice_in_dim(xf, i * per, per, axis=0)   # (per, D)

        gates, eids, aux = _route(cfg, pl_["router"], xs)
        if pad:  # zero the gates of padded tokens
            tok_id = i * per + jnp.arange(per)
            gates = jnp.where((tok_id < n)[:, None], gates, 0.0)
        cap = _capacity(cfg, per)
        buf, meta = _pack_local(cfg, xs, gates, eids, cap)        # (E*C, D)
        buf = buf.reshape(cfg.num_experts, cap, d)
        a2a = (_qa2a if cfg.moe_dispatch_dtype == "int8"
               else _plain_a2a)
        recv = a2a(buf, 0, 1)                                     # (E_loc, em*C, D)
        y_loc = _expert_ffn(pl_, recv, x.dtype)
        back = a2a(y_loc, 1, 0)                                   # (E, C, D)
        y_s = _unpack_local(cfg, back.reshape(cfg.num_experts * cap, d),
                            meta, per, d)                          # (per, D)
        y_full = lax.all_gather(y_s, "model", axis=0, tiled=True)  # (n_p, D)
        y = y_full[:n].reshape(b_loc, t, d)
        aux = {k: lax.pmean(v, "model") for k, v in aux.items()}
        return y, aux

    fn = shlib_shard_map(block, mesh,
                         in_specs=(x_spec, w_specs),
                         out_specs=(x_spec, P()))
    return fn(x, {k: p[k] for k in w_specs})


def shlib_shard_map(f, mesh, in_specs, out_specs):
    # jax.shard_map only exists (with check_vma) on newer JAX; older
    # versions raise AttributeError on access or TypeError on the kwarg.
    try:
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    except (AttributeError, TypeError):  # pragma: no cover
        from jax.experimental.shard_map import shard_map
        return shard_map(f, mesh=mesh, in_specs=in_specs,
                         out_specs=out_specs, check_rep=False)


def moe_forward(cfg, p, x: Array):
    if cfg.moe_impl == "dense":
        return moe_dense(cfg, p, x)
    return moe_sharded(cfg, p, x)
