"""Shared LM building blocks: params-with-logical-axes, norms, RoPE, losses.

Everything is functional: parameters are nested dicts of arrays; every
creation site returns (param, logical_axes) through the ParamBuilder so a
parallel "spec tree" exists for the sharding rules. No framework magic.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

Array = jax.Array


class ParamBuilder:
    """Collects params + a parallel tree of logical axis tuples."""

    def __init__(self, key: jax.Array, dtype=jnp.float32):
        self._key = key
        self.dtype = dtype
        self.params: dict = {}
        self.specs: dict = {}

    def _next(self):
        self._key, sub = jax.random.split(self._key)
        return sub

    def make(self, tree: dict, specs: dict, path: list[str], name: str,
             shape, logical, init="normal", scale=None):
        if init == "zeros":
            p = jnp.zeros(shape, self.dtype)
        elif init == "ones":
            p = jnp.ones(shape, self.dtype)
        else:
            fan_in = shape[0] if len(shape) > 1 else shape[-1]
            std = (1.0 / math.sqrt(fan_in)) if scale is None else scale
            p = (std * jax.random.normal(self._next(), shape)).astype(self.dtype)
        tree[name] = p
        specs[name] = tuple(logical)
        return p


def sub(tree: dict, specs: dict, name: str):
    tree[name] = {}
    specs[name] = {}
    return tree[name], specs[name]


# ---------------------------------------------------------------------------
# norms / activations
# ---------------------------------------------------------------------------

def rmsnorm(x: Array, scale: Array, eps: float = 1e-6) -> Array:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def layernorm(x: Array, scale: Array, bias: Array, eps: float = 1e-5) -> Array:
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    out = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (out * scale.astype(jnp.float32)
            + bias.astype(jnp.float32)).astype(x.dtype)


def apply_norm(cfg, x: Array, norm_params: dict) -> Array:
    if cfg.norm_type == "layernorm":
        return layernorm(x, norm_params["scale"], norm_params["bias"])
    return rmsnorm(x, norm_params["scale"])


def make_norm(pb: ParamBuilder, tree, specs, cfg, name: str, dim: int):
    t, s = sub(tree, specs, name)
    if cfg.norm_type == "layernorm":
        pb.make(t, s, [], "scale", (dim,), (None,), init="ones")
        pb.make(t, s, [], "bias", (dim,), (None,), init="zeros")
    else:
        pb.make(t, s, [], "scale", (dim,), (None,), init="zeros")


# ---------------------------------------------------------------------------
# rotary position embeddings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: Array, positions: Array, theta: float) -> Array:
    """x: (B, T, H, Dh) or (B, T, Dh); positions: (B, T)."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)                        # (dh/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B, T, dh/2)
    if x.ndim == 4:
        angles = angles[:, :, None, :]                   # (B, T, 1, dh/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x32 = x.astype(jnp.float32)
    x1, x2 = x32[..., : dh // 2], x32[..., dh // 2:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# loss
# ---------------------------------------------------------------------------

def cross_entropy_chunked(h: Array, w_unembed: Array, labels: Array,
                          chunk: int = 512) -> Array:
    """Mean CE over tokens, computed in sequence chunks so the (B, T, V)
    logits tensor is never materialised (chunks are rematerialised in the
    backward pass via jax.checkpoint)."""
    b, t, d = h.shape
    c = min(chunk, t)
    pad = (-t) % c
    if pad:
        h = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
    tt = h.shape[1]
    h_c = h.reshape(b, tt // c, c, d).swapaxes(0, 1)          # (nc, B, c, d)
    l_c = labels.reshape(b, tt // c, c).swapaxes(0, 1)        # (nc, B, c)

    @jax.checkpoint
    def body(carry, xs):
        hc, lc = xs
        logits = jnp.einsum("bcd,dv->bcv", hc.astype(jnp.float32),
                            w_unembed.astype(jnp.float32))
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, jnp.maximum(lc, 0)[..., None], axis=-1)[..., 0]
        valid = (lc >= 0).astype(jnp.float32)
        loss = jnp.sum((lse - gold) * valid)
        return carry + jnp.stack([loss, jnp.sum(valid)]), None

    tot, _ = jax.lax.scan(body, jnp.zeros((2,), jnp.float32), (h_c, l_c))
    return tot[0] / jnp.maximum(tot[1], 1.0)
