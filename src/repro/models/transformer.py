"""Model assembly: init, train forward, prefill, and decode for all families.

Layers are organised in BlockGroups (configs/base.py). Groups with
``scan=True`` hold stacked parameters (leading ``layers`` axis) and execute
under ``jax.lax.scan`` — this keeps the HLO size and 512-device compile time
bounded for 94-layer models. Per-layer structure is pre-norm residual:

    x += mixer(norm(x));  x += ffn(norm(x))        (ffn absent for ssd)

Whisper (family=encdec) runs a non-causal encoder over stub frame
embeddings first and gives every decoder layer a cross-attention reading
the encoder output.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from ..distributed.sharding import constrain
from . import attention as attn
from . import moe as moe_mod
from . import rglru as rglru_mod
from . import ssm as ssm_mod
from .common import (ParamBuilder, apply_norm, cross_entropy_chunked,
                     make_norm, sub)
from .mlp import init_mlp, mlp_forward

Array = jax.Array


def _dtype(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16,
            "float16": jnp.float16}[name]


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _init_layer(cfg, key, mixer: str, ffn: str, cross: bool):
    pb = ParamBuilder(key, dtype=_dtype(cfg.param_dtype))
    tree, specs = {}, {}
    make_norm(pb, tree, specs, cfg, "norm1", cfg.d_model)
    if mixer in ("attn", "lattn"):
        attn.init_gqa(pb, tree, specs, cfg)
    elif mixer == "mla":
        attn.init_mla(pb, tree, specs, cfg)
    elif mixer == "ssd":
        ssm_mod.init_ssd(pb, tree, specs, cfg)
    elif mixer == "rglru":
        rglru_mod.init_rglru(pb, tree, specs, cfg)
    else:
        raise ValueError(mixer)
    if cross:
        make_norm(pb, tree, specs, cfg, "normx", cfg.d_model)
        attn.init_cross(pb, tree, specs, cfg)
    if ffn != "none":
        make_norm(pb, tree, specs, cfg, "norm2", cfg.d_model)
    if ffn == "mlp":
        init_mlp(pb, tree, specs, cfg)
    elif ffn == "moe":
        moe_mod.init_moe(pb, tree, specs, cfg)
        if cfg.num_shared_experts:
            init_mlp(pb, tree, specs, cfg,
                     d_ff=cfg.num_shared_experts * cfg.moe_d_ff,
                     name="shared_mlp")
    return tree, specs


def _stack_group(cfg, key, group, cross: bool):
    keys = jax.random.split(key, group.count)
    if group.count == 1 or not group.scan:
        layers = [
            _init_layer(cfg, k, group.mixer, group.ffn, cross) for k in keys
        ]
        params = [p for p, _ in layers]
        specs = layers[0][1]
        if not group.scan and group.count > 1:
            return {"unstacked": params}, {"unstacked": [specs] * group.count}
        return params[0], specs

    _, s0 = _init_layer(cfg, keys[0], group.mixer, group.ffn, cross)
    stacked = jax.vmap(
        lambda k: _init_layer(cfg, k, group.mixer, group.ffn, cross)[0]
    )(keys)
    specs = jax.tree.map(
        lambda sp: ("layers",) + sp, s0,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x))
    return stacked, specs


def init_params(cfg, key) -> tuple[dict, dict]:
    """Returns (params, logical-axes spec tree of identical structure)."""
    pb = ParamBuilder(key, dtype=_dtype(cfg.param_dtype))
    params: dict = {}
    specs: dict = {}
    pb.make(params, specs, [], "embed", (cfg.vocab_size, cfg.d_model),
            ("vocab", "embed"), scale=0.02)
    if not cfg.tie_embeddings:
        pb.make(params, specs, [], "lm_head", (cfg.d_model, cfg.vocab_size),
                ("embed", "vocab"))
    make_norm(pb, params, specs, cfg, "final_norm", cfg.d_model)

    if cfg.family == "encdec":
        from ..configs.base import BlockGroup
        enc, enc_s = sub(params, specs, "encoder")
        key, k2 = jax.random.split(key)
        g = BlockGroup("attn", "mlp", cfg.encoder_layers, True)
        enc["layers"], enc_s["layers"] = _stack_group(cfg, k2, g, cross=False)
        make_norm(pb, enc, enc_s, cfg, "final_norm", cfg.d_model)

    groups, groups_s = sub(params, specs, "groups")
    cross = cfg.family == "encdec"
    for gi, g in enumerate(cfg.blocks):
        key, k2 = jax.random.split(key)
        groups[f"g{gi}"], groups_s[f"g{gi}"] = _stack_group(cfg, k2, g, cross)
    return params, specs


# ---------------------------------------------------------------------------
# forward (train / prefill)
# ---------------------------------------------------------------------------

def _layer_fwd(cfg, mixer, ffn, cross, p, x, positions, enc_out,
               collect_cache: bool):
    h = apply_norm(cfg, x, p["norm1"])
    cache = None
    if mixer == "attn":
        y = attn.gqa_forward(cfg, p["attn"], h, positions, causal=True)
        if collect_cache:
            cache = _gqa_cache_from_seq(cfg, p["attn"], h, positions)
    elif mixer == "lattn":
        y = attn.gqa_forward(cfg, p["attn"], h, positions, causal=True,
                             window=cfg.local_window)
        if collect_cache:
            cache = _gqa_cache_from_seq(cfg, p["attn"], h, positions,
                                        window=cfg.local_window)
    elif mixer == "mla":
        y = attn.mla_forward(cfg, p["attn"], h, positions)
        if collect_cache:
            cache = _mla_cache_from_seq(cfg, p["attn"], h, positions)
    elif mixer == "ssd":
        y, st = ssm_mod.ssd_forward(cfg, p["ssd"], h)
        cache = st if collect_cache else None
    elif mixer == "rglru":
        y, st = rglru_mod.rglru_forward(cfg, p["rglru"], h)
        cache = st if collect_cache else None
    else:
        raise ValueError(mixer)
    x = x + y
    aux = {}
    if cross:
        hx = apply_norm(cfg, x, p["normx"])
        kv = attn.encode_kv(cfg, p["xattn"], enc_out)
        x = x + attn.cross_forward(cfg, p["xattn"], hx, kv)
        if collect_cache and cache is not None:
            cache = {**cache, "xk": kv[0], "xv": kv[1]}
    if ffn == "mlp":
        h2 = apply_norm(cfg, x, p["norm2"])
        x = x + mlp_forward(cfg, p["mlp"], h2)
    elif ffn == "moe":
        h2 = apply_norm(cfg, x, p["norm2"])
        y_moe, aux = moe_mod.moe_forward(cfg, p["moe"], h2)
        if cfg.num_shared_experts:
            y_moe = y_moe + mlp_forward(cfg, p["shared_mlp"], h2)
        x = x + y_moe
    # Megatron-style sequence parallelism: the residual stream between
    # blocks is sharded over the model axis along T (falls back to
    # replication when T == 1 or T % TP != 0).
    x = constrain(x, ("batch", "seq_model", None))
    return x, cache, aux


def _gqa_cache_from_seq(cfg, p, h, positions, window=None):
    """Build a decode cache from a prefilled sequence (train-path K/V)."""
    b, t, _ = h.shape
    dh = attn.head_dim(cfg)
    k = h @ p["wk"].astype(h.dtype)
    v = h @ p["wv"].astype(h.dtype)
    if cfg.qkv_bias:
        k = k + p["bk"].astype(h.dtype)
        v = v + p["bv"].astype(h.dtype)
    k = k.reshape(b, t, cfg.num_kv_heads, dh)
    v = v.reshape(b, t, cfg.num_kv_heads, dh)
    if cfg.rope_theta:
        k = attn.apply_rope(k, positions, cfg.rope_theta)
    pos = jnp.broadcast_to(positions, (b, t)).astype(jnp.int32)
    if window:
        w = min(window, t)
        k, v, pos = k[:, -w:], v[:, -w:], pos[:, -w:]
    return {"k": k, "v": v, "pos": pos}


def _mla_cache_from_seq(cfg, p, h, positions):
    from .common import rmsnorm
    kv_a = h @ p["wkv_a"].astype(h.dtype)
    c_kv = rmsnorm(kv_a[..., : cfg.kv_lora_rank], p["kv_norm"])
    k_rope = attn.apply_rope(kv_a[..., cfg.kv_lora_rank:], positions,
                             cfg.rope_theta)
    pos = jnp.broadcast_to(positions, h.shape[:2]).astype(jnp.int32)
    return {"c_kv": c_kv, "k_rope": k_rope, "pos": pos}


def _run_groups(cfg, params, x, positions, enc_out, collect_cache=False):
    """Run all block groups; returns (x, caches per group, aux sums)."""
    caches: dict[str, Any] = {}
    aux_tot = {"load_balance": jnp.zeros((), jnp.float32),
               "router_z": jnp.zeros((), jnp.float32)}
    cross = cfg.family == "encdec"

    for gi, g in enumerate(cfg.blocks):
        p_g = params["groups"][f"g{gi}"]

        def one(p, x, mixer=g.mixer, ffn=g.ffn):
            return _layer_fwd(cfg, mixer, ffn, cross, p, x, positions,
                              enc_out, collect_cache)

        if isinstance(p_g, dict) and "unstacked" in p_g:
            layer_caches = []
            for p in p_g["unstacked"]:
                x, c, aux = one(p, x)
                layer_caches.append(c)
                for k2 in aux:
                    aux_tot[k2] += aux[k2]
            caches[f"g{gi}"] = layer_caches
        elif g.count == 1 or not g.scan:
            x, c, aux = one(p_g, x)
            caches[f"g{gi}"] = c
            for k2 in aux:
                aux_tot[k2] += aux[k2]
        else:
            def body(xc, p):
                x_in, acc = xc
                fn = one
                if cfg.remat:
                    if cfg.remat_policy == "dots":
                        fn = jax.checkpoint(
                            one, policy=jax.checkpoint_policies
                            .dots_with_no_batch_dims_saveable)
                    else:
                        fn = jax.checkpoint(one)
                x_out, c, aux = fn(p, x_in)
                acc = {k2: acc[k2] + aux.get(k2, 0.0) for k2 in acc}
                return (x_out, acc), c

            (x, aux_tot), stacked_c = jax.lax.scan(body, (x, aux_tot), p_g)
            caches[f"g{gi}"] = stacked_c
    return x, caches, aux_tot


def _embed(cfg, params, tokens):
    cd = _dtype(cfg.compute_dtype)
    x = params["embed"][tokens].astype(cd)
    return constrain(x, ("batch", "seq_model", None))


def _unembed_weight(cfg, params):
    if cfg.tie_embeddings:
        return params["embed"].T
    return params["lm_head"]


def _encode(cfg, params, frames):
    cd = _dtype(cfg.compute_dtype)
    x = frames.astype(cd)
    pos = jnp.broadcast_to(jnp.arange(x.shape[1], dtype=jnp.int32), x.shape[:2])
    enc = params["encoder"]

    def body(x_in, p):
        h = apply_norm(cfg, x_in, p["norm1"])
        y = attn.gqa_forward(cfg, p["attn"], h, pos, causal=False)
        x_in = x_in + y
        h2 = apply_norm(cfg, x_in, p["norm2"])
        return x_in + mlp_forward(cfg, p["mlp"], h2), None

    def scan_body(c, p):
        fn = jax.checkpoint(body) if cfg.remat else body
        return fn(c, p)

    x, _ = jax.lax.scan(scan_body, x, enc["layers"])
    return apply_norm(cfg, x, enc["final_norm"])


def forward_train(cfg, params, batch) -> tuple[Array, dict]:
    """batch: tokens (B,T), labels (B,T) [, frames (B,F,D)] -> (loss, metrics)."""
    tokens = batch["tokens"]
    positions = jnp.broadcast_to(jnp.arange(tokens.shape[1], dtype=jnp.int32), tokens.shape)
    enc_out = None
    if cfg.family == "encdec":
        enc_out = _encode(cfg, params, batch["frames"])
    x = _embed(cfg, params, tokens)
    x, _, aux = _run_groups(cfg, params, x, positions, enc_out)
    x = apply_norm(cfg, x, params["final_norm"])
    loss = cross_entropy_chunked(x, _unembed_weight(cfg, params),
                                 batch["labels"])
    metrics = {"loss": loss, **aux}
    total = loss
    if cfg.num_experts:
        total = total + 0.01 * aux["load_balance"] + 1e-4 * aux["router_z"]
    return total, metrics


# ---------------------------------------------------------------------------
# prefill / decode
# ---------------------------------------------------------------------------

def forward_prefill(cfg, params, batch):
    """Prefill: full-sequence pass that returns (last-token logits, caches)."""
    tokens = batch["tokens"]
    positions = jnp.broadcast_to(jnp.arange(tokens.shape[1], dtype=jnp.int32), tokens.shape)
    enc_out = None
    if cfg.family == "encdec":
        enc_out = _encode(cfg, params, batch["frames"])
    x = _embed(cfg, params, tokens)
    x, caches, _ = _run_groups(cfg, params, x, positions, enc_out,
                               collect_cache=True)
    x = apply_norm(cfg, x[:, -1:, :], params["final_norm"])
    logits = (x[:, 0].astype(jnp.float32)
              @ _unembed_weight(cfg, params).astype(jnp.float32))
    return logits, caches


def init_decode_cache(cfg, batch: int, max_len: int):
    """Zeroed decode caches matching what forward_prefill produces."""
    cd = _dtype(cfg.compute_dtype)
    caches: dict[str, Any] = {}
    for gi, g in enumerate(cfg.blocks):
        if g.mixer in ("attn",):
            c = attn.init_gqa_cache(cfg, batch, max_len, cd)
        elif g.mixer == "lattn":
            c = attn.init_gqa_cache(cfg, batch, max_len, cd)
        elif g.mixer == "mla":
            c = attn.init_mla_cache(cfg, batch, max_len, cd)
        elif g.mixer == "ssd":
            c = ssm_mod.init_ssd_cache(cfg, batch, cd)
        elif g.mixer == "rglru":
            c = rglru_mod.init_rglru_cache(cfg, batch, cd)
        else:
            raise ValueError(g.mixer)
        if cfg.family == "encdec":
            dh = attn.head_dim(cfg)
            c["xk"] = jnp.zeros((batch, cfg.num_frames, cfg.num_kv_heads, dh),
                                cd)
            c["xv"] = jnp.zeros((batch, cfg.num_frames, cfg.num_kv_heads, dh),
                                cd)
        if g.scan and g.count > 1:
            c = jax.tree.map(
                lambda a: jnp.broadcast_to(a, (g.count,) + a.shape), c)
        elif not g.scan and g.count > 1:
            c = [jax.tree.map(jnp.copy, c) for _ in range(g.count)]
        caches[f"g{gi}"] = c
    return caches


def _layer_decode(cfg, mixer, ffn, cross, p, x_t, cache, pos, enc_out):
    del enc_out   # cross-KV is cached at prefill (xk/xv), never recomputed
    h = apply_norm(cfg, x_t, p["norm1"])
    xkv = (cache.pop("xk", None), cache.pop("xv", None)) if cross else None
    cache = dict(cache) if cross else cache
    if mixer in ("attn", "lattn"):
        y, cache = attn.gqa_decode(cfg, p["attn"], h, cache, pos)
    elif mixer == "mla":
        y, cache = attn.mla_decode(cfg, p["attn"], h, cache, pos)
    elif mixer == "ssd":
        y, cache = ssm_mod.ssd_decode(cfg, p["ssd"], h, cache)
    elif mixer == "rglru":
        y, cache = rglru_mod.rglru_decode(cfg, p["rglru"], h, cache)
    else:
        raise ValueError(mixer)
    x_t = x_t + y
    if cross:
        hx = apply_norm(cfg, x_t, p["normx"])
        x_t = x_t + attn.cross_forward(cfg, p["xattn"], hx, xkv)
        cache = {**cache, "xk": xkv[0], "xv": xkv[1]}
    if ffn == "mlp":
        h2 = apply_norm(cfg, x_t, p["norm2"])
        x_t = x_t + mlp_forward(cfg, p["mlp"], h2)
    elif ffn == "moe":
        h2 = apply_norm(cfg, x_t, p["norm2"])
        y_moe, _ = moe_mod.moe_forward(cfg, p["moe"], h2)
        if cfg.num_shared_experts:
            y_moe = y_moe + mlp_forward(cfg, p["shared_mlp"], h2)
        x_t = x_t + y_moe
    return x_t, cache


def decode_step(cfg, params, caches, tokens_t: Array, pos: Array):
    """One decode step: tokens_t (B,1), pos (B,) -> (logits (B,V), caches)."""
    x = _embed(cfg, params, tokens_t)
    enc_out = None
    cross = cfg.family == "encdec"
    new_caches = dict(caches)
    for gi, g in enumerate(cfg.blocks):
        p_g = params["groups"][f"g{gi}"]
        c_g = caches[f"g{gi}"]

        if isinstance(p_g, dict) and "unstacked" in p_g:
            outs = []
            for p, c in zip(p_g["unstacked"], c_g):
                x, c2 = _layer_decode(cfg, g.mixer, g.ffn, cross, p, x, c,
                                      pos, enc_out)
                outs.append(c2)
            new_caches[f"g{gi}"] = outs
        elif g.count == 1 or not g.scan:
            x, c2 = _layer_decode(cfg, g.mixer, g.ffn, cross, p_g, x, c_g,
                                  pos, enc_out)
            new_caches[f"g{gi}"] = c2
        else:
            def body(x_in, pc, mixer=g.mixer, ffn=g.ffn):
                p, c = pc
                x_out, c2 = _layer_decode(cfg, mixer, ffn, cross, p, x_in, c,
                                          pos, enc_out)
                return x_out, c2

            x, c2 = jax.lax.scan(body, x, (p_g, c_g))
            new_caches[f"g{gi}"] = c2
    x = apply_norm(cfg, x, params["final_norm"])
    logits = (x[:, 0].astype(jnp.float32)
              @ _unembed_weight(cfg, params).astype(jnp.float32))
    return logits, new_caches
