"""Mamba-2 SSD (state-space duality) block — chunked, attention-free.

Train/prefill use the quadratic-within-chunk / recurrent-across-chunk SSD
algorithm (port of the minimal SSD reference to JAX einsums); decode keeps a
constant-size (H, P, N) state per layer — the reason this arch RUNS the
long_500k shape while full-attention archs cannot.

Block layout (mamba2): in_proj -> [z | x | B | C | dt]; depthwise causal
conv over [x|B|C]; silu; SSD; gated RMSNorm(y * silu(z)); out_proj.
Single B/C group (n_groups=1), scalar A per head (log-parametrised).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import ParamBuilder, rmsnorm, sub

Array = jax.Array


def dims(cfg):
    d_inner = cfg.ssm_expand * cfg.d_model
    n_heads = cfg.ssm_heads or d_inner // cfg.ssm_head_dim
    return d_inner, n_heads, cfg.ssm_head_dim, cfg.ssm_state_dim


def init_ssd(pb: ParamBuilder, tree, specs, cfg):
    d_inner, h, p_dim, n = dims(cfg)
    conv_dim = d_inner + 2 * n
    t, s = sub(tree, specs, "ssd")
    pb.make(t, s, [], "w_in",
            (cfg.d_model, 2 * d_inner + 2 * n + h), ("embed", "inner"))
    pb.make(t, s, [], "conv_w", (conv_dim, cfg.conv_kernel), ("inner", "conv"))
    pb.make(t, s, [], "conv_b", (conv_dim,), ("inner",), init="zeros")
    pb.make(t, s, [], "a_log", (h,), (None,), init="zeros")
    pb.make(t, s, [], "dt_bias", (h,), (None,), init="zeros")
    pb.make(t, s, [], "d_skip", (h,), (None,), init="ones")
    pb.make(t, s, [], "norm", (d_inner,), (None,), init="zeros")
    pb.make(t, s, [], "w_out", (d_inner, cfg.d_model), ("inner", "embed"))


def _causal_conv(x: Array, w: Array, b: Array) -> Array:
    """Depthwise causal conv: x (B,T,C), w (C,K)."""
    k = w.shape[1]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = jax.lax.conv_general_dilated(
        xp.astype(jnp.float32), w.T[:, None, :].astype(jnp.float32),
        window_strides=(1,), padding="VALID",
        dimension_numbers=("NTC", "TIO", "NTC"),
        feature_group_count=x.shape[-1])
    return (out + b.astype(jnp.float32)).astype(x.dtype)


def _segsum(a: Array) -> Array:
    """a (..., T) -> (..., T, T): sum_{j<i<=t} with -inf above diagonal."""
    t = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    d = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((t, t), bool))
    return jnp.where(mask, d, -jnp.inf)


def ssd_scan(x: Array, a: Array, b_in: Array, c_in: Array, chunk: int,
             init_state: Array | None = None):
    """SSD: x (B,T,H,P), a (B,T,H) [log decay, <=0], b/c (B,T,N) shared
    across heads. Returns (y (B,T,H,P), final_state (B,H,P,N))."""
    bsz, t, h, p_dim = x.shape
    n = b_in.shape[-1]
    cs = min(chunk, t)
    pad = (-t) % cs
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0)))
        b_in = jnp.pad(b_in, ((0, 0), (0, pad), (0, 0)))
        c_in = jnp.pad(c_in, ((0, 0), (0, pad), (0, 0)))
    nc = x.shape[1] // cs
    xb = x.reshape(bsz, nc, cs, h, p_dim)
    ab = a.reshape(bsz, nc, cs, h).transpose(0, 3, 1, 2)    # (B,H,nc,cs)
    bb = b_in.reshape(bsz, nc, cs, n)
    cb = c_in.reshape(bsz, nc, cs, n)

    a32 = ab.astype(jnp.float32)
    acum = jnp.cumsum(a32, axis=-1)                          # (B,H,nc,cs)
    l_mat = jnp.exp(_segsum(a32))                            # (B,H,nc,cs,cs)

    # intra-chunk (diagonal blocks)
    y_diag = jnp.einsum("bcln,bcsn,bhcls,bcshp->bclhp",
                        cb.astype(jnp.float32), bb.astype(jnp.float32),
                        l_mat, xb.astype(jnp.float32))

    # chunk-final states
    decay_states = jnp.exp(acum[..., -1:] - acum)            # (B,H,nc,cs)
    states = jnp.einsum("bcln,bhcl,bclhp->bchpn",
                        bb.astype(jnp.float32), decay_states,
                        xb.astype(jnp.float32))              # (B,nc,H,P,N)

    # inter-chunk recurrence: S_{c+1} = exp(sum a_c) S_c + states_c
    chunk_decay = jnp.exp(acum[..., -1])                     # (B,H,nc)
    s0 = (jnp.zeros((bsz, h, p_dim, n), jnp.float32)
          if init_state is None else init_state.astype(jnp.float32))

    if jax.config.jax_enable_x64:
        # Statically unrolled: under x64 the lax.scan lowering's int64 loop
        # counter trips an XLA SPMD verifier bug (s64 index vs s32 shard
        # offset) in the partitioned backward pass. nc = ceil(T/chunk) is
        # compile-time and stays small for shipped configs (<= 32 at
        # T=4096, ssm_chunk=128), so the unrolled HLO is bounded.
        carry = s0
        prev = []
        for ci in range(nc):
            prev.append(carry)                               # state BEFORE chunk
            carry = (chunk_decay[..., ci][..., None, None] * carry
                     + states[:, ci])
        final_state = carry
        prev_states = jnp.stack(prev, axis=1)                # (B,nc,H,P,N)
    else:
        def step(c, inp):
            dec, st = inp                                    # (B,H), (B,H,P,N)
            return dec[..., None, None] * c + st, c          # emit BEFORE chunk

        final_state, prev_states = jax.lax.scan(
            step, s0, (chunk_decay.transpose(2, 0, 1),
                       states.transpose(1, 0, 2, 3, 4)))
        prev_states = prev_states.transpose(1, 0, 2, 3, 4)   # (B,nc,H,P,N)

    # inter-chunk contribution
    state_decay = jnp.exp(acum)                              # (B,H,nc,cs)
    y_off = jnp.einsum("bcln,bchpn,bhcl->bclhp",
                       cb.astype(jnp.float32), prev_states, state_decay)

    y = (y_diag + y_off).reshape(bsz, nc * cs, h, p_dim)[:, :t]
    return y.astype(x.dtype), final_state


def ssd_forward(cfg, p, x: Array, *, init=None):
    """Full block. x (B,T,D) -> (y (B,T,D), state dict)."""
    d_inner, h, p_dim, n = dims(cfg)
    proj = x @ p["w_in"].astype(x.dtype)
    z, xs, b_in, c_in, dt = jnp.split(
        proj, [d_inner, 2 * d_inner, 2 * d_inner + n, 2 * d_inner + 2 * n],
        axis=-1)
    conv_in = jnp.concatenate([xs, b_in, c_in], axis=-1)
    conv = jax.nn.silu(_causal_conv(conv_in, p["conv_w"], p["conv_b"]))
    xs, b_in, c_in = jnp.split(conv, [d_inner, d_inner + n], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))   # (B,T,H)
    a = -jnp.exp(p["a_log"].astype(jnp.float32))[None, None, :] * dt
    xh = xs.reshape(*xs.shape[:2], h, p_dim)
    xd = xh * dt[..., None].astype(xs.dtype)
    y, state = ssd_scan(xd, a, b_in, c_in, cfg.ssm_chunk,
                        init_state=init["ssd"] if init else None)
    skip = (p["d_skip"].astype(jnp.float32)[None, None, :, None]
            * xh.astype(jnp.float32))
    y = (y.astype(jnp.float32) + skip).astype(x.dtype)
    y = y.reshape(*x.shape[:2], d_inner)
    y = rmsnorm(y * jax.nn.silu(z), p["norm"])
    out = y @ p["w_out"].astype(x.dtype)
    conv_tail = conv_in[:, -(cfg.conv_kernel - 1):, :]
    return out, {"ssd": state, "conv": conv_tail}


def init_ssd_cache(cfg, batch: int, dtype) -> dict:
    d_inner, h, p_dim, n = dims(cfg)
    conv_dim = d_inner + 2 * n
    return {
        "ssd": jnp.zeros((batch, h, p_dim, n), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_kernel - 1, conv_dim), dtype),
    }


def ssd_decode(cfg, p, x_t: Array, cache: dict):
    """Single-token step. x_t (B,1,D)."""
    d_inner, h, p_dim, n = dims(cfg)
    proj = x_t @ p["w_in"].astype(x_t.dtype)
    z, xs, b_in, c_in, dt = jnp.split(
        proj, [d_inner, 2 * d_inner, 2 * d_inner + n, 2 * d_inner + 2 * n],
        axis=-1)
    conv_in = jnp.concatenate([xs, b_in, c_in], axis=-1)     # (B,1,C)
    win = jnp.concatenate([cache["conv"], conv_in], axis=1)  # (B,K,C)
    conv = jnp.einsum("bkc,ck->bc", win.astype(jnp.float32),
                      p["conv_w"].astype(jnp.float32))
    conv = jax.nn.silu(conv + p["conv_b"].astype(jnp.float32))
    conv = conv.astype(x_t.dtype)
    xs, b_in, c_in = (conv[:, :d_inner], conv[:, d_inner:d_inner + n],
                      conv[:, d_inner + n:])
    dt = jax.nn.softplus(dt[:, 0].astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))  # (B,H)
    a = jnp.exp(-jnp.exp(p["a_log"].astype(jnp.float32))[None] * dt)
    xh = xs.reshape(-1, h, p_dim).astype(jnp.float32)
    st = cache["ssd"]
    st = a[..., None, None] * st + jnp.einsum(
        "bh,bhp,bn->bhpn", dt, xh, b_in.astype(jnp.float32))
    y = jnp.einsum("bhpn,bn->bhp", st, c_in.astype(jnp.float32))
    y = y + p["d_skip"].astype(jnp.float32)[None, :, None] * xh
    y = y.reshape(-1, 1, d_inner).astype(x_t.dtype)
    y = rmsnorm(y * jax.nn.silu(z), p["norm"])
    out = y @ p["w_out"].astype(x_t.dtype)
    return out, {"ssd": st, "conv": win[:, 1:]}
