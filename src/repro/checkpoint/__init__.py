from . import checkpoint
from .checkpoint import AsyncCheckpointer, latest, restore, save
