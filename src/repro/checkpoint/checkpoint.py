"""Checkpoint/restore for arbitrary pytrees (no orbax dependency).

Format: one ``.npz`` per checkpoint holding flattened leaves keyed by their
tree path + a JSON sidecar with the treedef fingerprint and user metadata
(step, data cursor, RNG). Writes are atomic (tmp file + rename) so a crash
mid-write never corrupts the latest checkpoint; ``keep`` rotates old ones.

``async_save`` offloads serialisation to a daemon thread — the training
loop only blocks on ``jax.device_get`` (the paper's requirement 3: low
overhead in the global step).
"""
from __future__ import annotations

import json
import pathlib
import re
import threading
from typing import Any

import jax
import ml_dtypes
import numpy as np

# npz cannot round-trip ml_dtypes extension types (they reload as raw void
# bytes): store them bit-identically under a same-width integer view and
# restore via the template's dtype.  bfloat16 is the only one we ship
# (quantized serving states — see serve.posterior.PredictiveState.astype).
_BF16 = np.dtype(ml_dtypes.bfloat16)


def _flatten_with_paths(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        arr = np.asarray(leaf)
        if arr.dtype == _BF16:
            arr = arr.view(np.uint16)
        flat[key] = arr
    return flat


def save(path: str | pathlib.Path, tree, metadata: dict | None = None,
         keep: int = 3):
    """Atomic checkpoint write; returns the final path."""
    path = pathlib.Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    flat = _flatten_with_paths(jax.device_get(tree))
    tmp = path.with_suffix(".tmp.npz")
    np.savez(tmp, **flat)
    meta = {"metadata": metadata or {}, "n_leaves": len(flat)}
    tmp_meta = path.with_suffix(".tmp.json")
    tmp_meta.write_text(json.dumps(meta))
    tmp.rename(path.with_suffix(".npz"))
    tmp_meta.rename(path.with_suffix(".json"))
    _rotate(path.parent, path.stem, keep)
    return path.with_suffix(".npz")


def _rotate(d: pathlib.Path, stem: str, keep: int):
    m = re.match(r"(.*)_step(\d+)$", stem)
    if not m:
        return
    base = m.group(1)
    ckpts = sorted(
        (p for p in d.glob(f"{base}_step*.npz")),
        key=lambda p: int(re.search(r"_step(\d+)", p.stem).group(1)))
    for old in ckpts[:-keep]:
        old.unlink(missing_ok=True)
        old.with_suffix(".json").unlink(missing_ok=True)


def restore(path: str | pathlib.Path, like) -> tuple[Any, dict]:
    """Restore into the structure of ``like`` (a pytree of arrays or
    ShapeDtypeStructs). Returns (tree, metadata)."""
    path = pathlib.Path(path)
    data = np.load(path.with_suffix(".npz"))
    meta = json.loads(path.with_suffix(".json").read_text())
    paths, treedef = jax.tree_util.tree_flatten_with_path(like)
    # The sidecar records how many leaves were written; restoring into a
    # template with a different count means the checkpoint is for another
    # structure (or a partial/corrupt write) — a hot state swap must fail
    # loudly here, not silently unflatten a subset.
    n_saved = meta.get("n_leaves")
    if n_saved is not None and n_saved != len(paths):
        raise ValueError(
            f"checkpoint {path} holds {n_saved} leaves but the restore "
            f"template has {len(paths)} — wrong artifact for this tree")
    leaves = []
    for p, leaf in paths:
        key = "/".join(str(getattr(q, "key", getattr(q, "idx", q)))
                       for q in p)
        if key not in data:
            raise KeyError(
                f"checkpoint {path} is missing leaf {key!r} — wrong or "
                "partial artifact")
        arr = data[key]
        want = np.dtype(leaf.dtype) if hasattr(leaf, "dtype") else arr.dtype
        if want == _BF16 and arr.dtype == np.uint16:
            arr = arr.view(_BF16)   # bit-identical bf16 round-trip
        leaves.append(arr.astype(want, copy=False))
    return jax.tree_util.tree_unflatten(treedef, leaves), meta["metadata"]


def latest(d: str | pathlib.Path, base: str = "ckpt") -> pathlib.Path | None:
    d = pathlib.Path(d)
    ckpts = sorted(
        (p for p in d.glob(f"{base}_step*.npz")),
        key=lambda p: int(re.search(r"_step(\d+)", p.stem).group(1)))
    return ckpts[-1].with_suffix("") if ckpts else None


class AsyncCheckpointer:
    """Serialise + write on a background thread; at most one in flight."""

    def __init__(self):
        self._thread: threading.Thread | None = None

    def save(self, path, tree, metadata=None, keep: int = 3):
        self.wait()
        host_tree = jax.device_get(tree)   # block only on D2H

        def work():
            save(path, host_tree, metadata, keep)

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
