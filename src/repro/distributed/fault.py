"""Fault tolerance & straggler instrumentation (paper §5).

The paper's robustness mechanism: when a node fails mid-iteration, drop its
partial term and take a noisy gradient rather than stall the iteration
(their fig. 7). Here that generalises to any shard-sum — GP statistics or
data-parallel LM gradients:

  * ``FailureSimulator`` draws per-shard failure masks at the paper's
    failure frequencies (0/1/2% per iteration).
  * ``apply_gradient_masking`` implements drop (paper) and rescale
    (beyond-paper, n/n_live reweighting) for LM gradient shards.
  * ``StepTimer`` records per-shard wall times -> min/mean/max load
    distribution (their fig. 5) and a straggler ratio.

Elastic re-sharding lives in core.distributed (the GP statistics are data-
decoupled, so moving to a different worker count is a re-pad + re-shard of
the inputs — ``DistributedGP.put_data`` on the new mesh).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

import numpy as np


class FailureSimulator:
    """Bernoulli node-failure process at ``rate`` per iteration per node.

    ``dtype`` is the mask dtype (default float64).  The distributed engine
    folds the mask into the per-row weights, so callers running an f32
    weight path should request ``dtype=np.float32`` explicitly rather
    than rely on an implicit downcast at the fold.
    """

    def __init__(self, n_shards: int, rate: float, seed: int = 0,
                 dtype=np.float64):
        self.n_shards = n_shards
        self.rate = rate
        self.dtype = np.dtype(dtype)
        self._rng = np.random.default_rng(seed)

    def mask(self) -> np.ndarray:
        """1.0 = alive, 0.0 = failed this iteration.  At least one shard
        is always alive — even at ``rate=1.0`` (never-all-dead
        invariant; a fully-dead iteration has no statistics to reduce)."""
        alive = self._rng.uniform(size=self.n_shards) >= self.rate
        if not alive.any():          # never lose every shard
            alive[self._rng.integers(self.n_shards)] = True
        return alive.astype(self.dtype)


def apply_gradient_masking(grad_shards: list, mask: np.ndarray,
                           mode: str = "drop", rows=None):
    """Combine per-shard gradients under failures.

    grad_shards: list of pytrees (one per shard); returns the summed tree.
    rows: per-shard live row counts (len == len(grad_shards)).  None
      assumes equal-sized shards.
    drop    — paper: sum surviving shards (noisy gradient).
    rescale — beyond-paper: scale by n/n_live, the ROW-count ratio — the
      factor ``core.distributed``'s in-mesh rescale uses.  With ``rows``
      omitted the shards are assumed equal-sized, where the row ratio
      reduces to the shard-count ratio; pass ``rows`` whenever shards are
      ragged (e.g. the final shard after ``pad_and_shard``), otherwise
      the rescale is biased.
    """
    import jax

    alive = [g for g, m in zip(grad_shards, mask) if m > 0]
    if not alive:
        raise ValueError("all shards masked dead: nothing to combine")
    total = jax.tree.map(lambda *xs: sum(xs), *alive)
    if mode == "rescale":
        if rows is None:
            c = len(grad_shards) / len(alive)
        else:
            rows = np.asarray(rows, np.float64)
            if rows.shape != (len(grad_shards),):
                raise ValueError(
                    f"rows must have shape ({len(grad_shards)},), "
                    f"got {rows.shape}")
            n_live = float(sum(r for r, m in zip(rows, mask) if m > 0))
            c = float(rows.sum()) / n_live
        total = jax.tree.map(lambda x: x * c, total)
    return total


@dataclass
class StepTimer:
    """Per-shard timing -> the paper's fig. 5 load-distribution metrics."""

    records: list = field(default_factory=list)

    def record(self, shard_times: list[float]):
        """Append one iteration's per-shard wall times.  Iterations may
        record different shard counts (elastic membership); an empty
        iteration is rejected — it has no min/mean/max."""
        times = list(shard_times)
        if not times:
            raise ValueError(
                "record() needs at least one shard time: an iteration "
                "with no live shards has no load distribution")
        self.records.append(times)

    def summary(self) -> dict:
        # Per-row (per-iteration) reduces: rows may be ragged — differing
        # shard counts under elastic membership — where np.asarray would
        # build an object array and axis reduces raise.
        if not self.records:
            return {}
        mins = np.array([min(r) for r in self.records])
        means = np.array([sum(r) / len(r) for r in self.records])
        maxs = np.array([max(r) for r in self.records])
        return {
            "min": float(mins.mean()),
            "mean": float(means.mean()),
            "max": float(maxs.mean()),
            # rate-limiting overhead: how much the slowest shard exceeds
            # the mean (paper reports 3.7%)
            "straggler_overhead": float(
                (maxs / np.maximum(means, 1e-12) - 1.0).mean()),
        }

    def time_shards(self, fns: list):
        """Run shard thunks sequentially, recording wall time of each
        (single-host simulation of the paper's per-thread measurement)."""
        times = []
        outs = []
        for fn in fns:
            t0 = time.perf_counter()
            outs.append(fn())
            times.append(time.perf_counter() - t0)
        self.record(times)
        return outs
