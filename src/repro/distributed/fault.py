"""Fault tolerance & straggler instrumentation (paper §5).

The paper's robustness mechanism: when a node fails mid-iteration, drop its
partial term and take a noisy gradient rather than stall the iteration
(their fig. 7). Here that generalises to any shard-sum — GP statistics or
data-parallel LM gradients:

  * ``FailureSimulator`` draws per-shard failure masks at the paper's
    failure frequencies (0/1/2% per iteration).
  * ``apply_gradient_masking`` implements drop (paper) and rescale
    (beyond-paper, n/n_live reweighting) for LM gradient shards.
  * ``StepTimer`` records per-shard wall times -> min/mean/max load
    distribution (their fig. 5) and a straggler ratio.

Elastic re-sharding lives in core.distributed (the GP statistics are data-
decoupled, so moving to a different worker count is a re-pad + re-shard of
the inputs — ``DistributedGP.put_data`` on the new mesh).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

import numpy as np


class FailureSimulator:
    """Bernoulli node-failure process at ``rate`` per iteration per node."""

    def __init__(self, n_shards: int, rate: float, seed: int = 0):
        self.n_shards = n_shards
        self.rate = rate
        self._rng = np.random.default_rng(seed)

    def mask(self) -> np.ndarray:
        """1.0 = alive, 0.0 = failed this iteration."""
        alive = self._rng.uniform(size=self.n_shards) >= self.rate
        if not alive.any():          # never lose every shard
            alive[self._rng.integers(self.n_shards)] = True
        return alive.astype(np.float64)


def apply_gradient_masking(grad_shards: list, mask: np.ndarray,
                           mode: str = "drop"):
    """Combine per-shard gradients under failures.

    grad_shards: list of pytrees (one per shard); returns the summed tree.
    drop    — paper: sum surviving shards (noisy gradient).
    rescale — beyond-paper: scale by n/n_live (approx. unbiased).
    """
    import jax

    alive = [g for g, m in zip(grad_shards, mask) if m > 0]
    total = jax.tree.map(lambda *xs: sum(xs), *alive)
    if mode == "rescale":
        c = len(grad_shards) / max(len(alive), 1)
        total = jax.tree.map(lambda x: x * c, total)
    return total


@dataclass
class StepTimer:
    """Per-shard timing -> the paper's fig. 5 load-distribution metrics."""

    records: list = field(default_factory=list)

    def record(self, shard_times: list[float]):
        self.records.append(list(shard_times))

    def summary(self) -> dict:
        a = np.asarray(self.records)        # (iters, shards)
        if a.size == 0:
            return {}
        return {
            "min": float(a.min(axis=1).mean()),
            "mean": float(a.mean(axis=1).mean()),
            "max": float(a.max(axis=1).mean()),
            # rate-limiting overhead: how much the slowest shard exceeds
            # the mean (paper reports 3.7%)
            "straggler_overhead": float(
                (a.max(axis=1) / np.maximum(a.mean(axis=1), 1e-12) - 1.0)
                .mean()),
        }

    def time_shards(self, fns: list):
        """Run shard thunks sequentially, recording wall time of each
        (single-host simulation of the paper's per-thread measurement)."""
        times = []
        outs = []
        for fn in fns:
            t0 = time.perf_counter()
            outs.append(fn())
            times.append(time.perf_counter() - t0)
        self.record(times)
        return outs
