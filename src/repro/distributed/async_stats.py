"""Asynchronous stale-update accumulation for the Map-Reduce ELBO.

The paper's reduce is a barrier: every shard's partial Stats must arrive
before the global step runs.  But the statistics are a plain sum over
points, so the reduce tolerates *stale* contributions: keep each shard's
latest partial Stats in an accumulator and let the global step fold
whatever is there — shards refresh on their own schedule, stragglers and
failed nodes simply leave old (or no) contributions behind.  This is the
Peng et al. 2017 "Asynchronous Distributed Variational GP" execution
model (PAPERS.md) applied to Gal et al.'s collapsed-bound statistics.

Two pieces:

  * :class:`AsyncStatsAccumulator` — the bookkeeping.  Each member shard
    holds one (Stats, stamp, rows) entry; a running total is maintained
    incrementally with the w-linear ``fold_stats`` / ``downdate_stats``
    identities (O(m²+md) per push/leave event — never a rescan of the
    membership).  Reads enforce a bounded staleness S (older entries are
    downdated out) and reweight the surviving fold so its expectation is
    the exact Stats:

      - ``"drop"``    — paper §5.2: surviving sums as-is (noisy).
      - ``"rescale"`` — row-count n/n_live reweighting (the same factor
        the in-mesh ``failure_mode="rescale"`` and the fixed
        ``fault.apply_gradient_masking`` use): exact whenever per-row
        statistics are exchangeable across shards, and exactly unbiased
        when the missing set is row-uniform.
      - ``"probs"``   — Horvitz–Thompson: shard k's contribution is
        scaled by 1/p_k at push time, where p_k is its probability of
        being present in the fold.  E[fold] = exact Stats *identically*
        over the presence distribution — the property the
        subset-enumeration test (tests/test_async_stats.py) checks.

  * :class:`AsyncEngine` — a host-level barrier-free step driver over K
    single-device shard workers (the same single-host simulation idiom
    as ``fault.StepTimer.time_shards`` / benchmarks/gp_common).  Each
    step refreshes only ``refresh`` alive shards (round-robin; a
    ``fault.FailureSimulator`` vetoes dead ones), folds the rest stale,
    and recovers the gradient through the stats cotangent: the collapsed
    bound's grad wrt the folded Stats is one replicated O(m³)
    value_and_grad, and each refreshed shard recomputes its
    ``<d stats_k / d(hyp, z), ct>`` contribution — unrefreshed shards
    reuse their cached (stale-ct) contribution, the classic stale-
    gradient async scheme.  Per-step map cost is O(refresh · n_k m²)
    instead of O(K · n_k m²): the step-speedup ``benchmarks.run --only
    async`` gates on.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp

from ..core.bound import collapsed_bound
from ..core.stats import Stats, downdate_stats, fold_stats, partial_stats_chunked

Array = jax.Array


def _tree_add(a, b):
    return jax.tree.map(jnp.add, a, b)


def _tree_sub(a, b):
    return jax.tree.map(jnp.subtract, a, b)


def _tree_scale(a, c):
    return jax.tree.map(lambda t: t * c, a)


@dataclass
class _Entry:
    stats: Stats          # as folded into the running total (probs: pre-scaled)
    stamp: int
    rows: float
    prob: float


class AsyncStatsAccumulator:
    """Barrier-free Stats accumulator with bounded staleness + reweighting.

    Args:
      staleness: the bound S — at :meth:`read` with stamp t, entries with
        ``stamp < t - S`` are evicted (downdated from the running total;
        the shard stays a member and may push again).  ``S=0`` keeps only
        contributions pushed at the read stamp itself.
      reweight: ``"drop"`` | ``"rescale"`` | ``"probs"`` (module docstring).

    Membership is elastic: :meth:`push` with a new shard id joins it,
    :meth:`leave` downdates its contribution and removes it — both are a
    single ``fold_stats`` / ``downdate_stats`` on the running total, so a
    churn event costs O(m²+md) regardless of the membership size.
    """

    def __init__(self, staleness: int = 1, reweight: str = "drop"):
        if staleness < 0:
            raise ValueError(f"staleness must be >= 0, got {staleness}")
        if reweight not in ("drop", "rescale", "probs"):
            raise ValueError(
                f"reweight must be 'drop', 'rescale' or 'probs', got {reweight!r}")
        self.staleness = staleness
        self.reweight = reweight
        self._entries: dict[Any, _Entry] = {}
        self._total: Stats | None = None

    # -- membership ---------------------------------------------------------
    def members(self) -> list:
        return list(self._entries)

    def __contains__(self, shard) -> bool:
        return shard in self._entries

    def __len__(self) -> int:
        return len(self._entries)

    def _fold(self, st: Stats):
        self._total = st if self._total is None else fold_stats(self._total, st)

    def _downdate(self, st: Stats):
        self._total = downdate_stats(self._total, st)

    def push(self, shard, stats: Stats, *, stamp: int, rows: float | None = None,
             prob: float = 1.0):
        """Replace ``shard``'s contribution (joining it if new).

        ``rows``: live row count behind this contribution (defaults to
        ``stats.n`` — correct for exact unweighted maps; pass explicitly
        when ``stats`` is SVI-reweighted, whose ``n`` leaf is stochastic).
        ``prob``: presence probability for ``reweight="probs"`` — the
        contribution is folded pre-scaled by 1/prob so the running total
        is the Horvitz–Thompson estimator at all times.
        """
        if rows is None:
            rows = float(stats.n)
        if not (0.0 < prob <= 1.0):
            raise ValueError(f"prob must be in (0, 1], got {prob}")
        if self.reweight == "probs" and prob != 1.0:
            stats = stats.scale(1.0 / prob)
        old = self._entries.get(shard)
        if old is not None:
            self._downdate(old.stats)
        self._entries[shard] = _Entry(stats, int(stamp), float(rows), prob)
        self._fold(stats)

    def leave(self, shard):
        """Elastic departure: downdate the shard's contribution and drop it."""
        entry = self._entries.pop(shard, None)
        if entry is not None:
            self._downdate(entry.stats)

    # -- read ----------------------------------------------------------------
    def evict_stale(self, stamp: int) -> list:
        """Downdate entries older than the staleness bound at ``stamp``.
        Never empties the accumulator: if every entry has expired, the
        freshest stamp's entries are kept (the accumulator analogue of
        ``FailureSimulator``'s never-all-dead invariant — a fold of
        nothing has no gradient signal at all).  Returns evicted ids."""
        cut = stamp - self.staleness
        expired = [k for k, e in self._entries.items() if e.stamp < cut]
        if expired and len(expired) == len(self._entries):
            newest = max(e.stamp for e in self._entries.values())
            expired = [k for k in expired
                       if self._entries[k].stamp < newest]
        for k in expired:
            self.leave(k)
        return expired

    def rows_live(self) -> float:
        return sum(e.rows for e in self._entries.values())

    def read(self, stamp: int, n_rows: float | None = None) -> Stats:
        """The reweighted fold of all fresh-enough contributions.

        Evicts entries staler than S first.  ``n_rows`` (the full-data row
        count) is required for ``reweight="rescale"``: the fold's sums are
        scaled by ``n_rows / rows_live`` and its ``n`` leaf set to
        ``n_rows`` — exactly the in-mesh rescale handling.  The other
        modes return the (HT-weighted) running total as-is.
        """
        self.evict_stale(stamp)
        if not self._entries:
            raise ValueError("read on an empty accumulator: no shard has "
                             "pushed a contribution yet")
        total = self._total
        if self.reweight == "rescale":
            if n_rows is None:
                raise ValueError("reweight='rescale' needs n_rows (the "
                                 "full-data row count) at read time")
            live = self.rows_live()
            f = n_rows / live
            total = Stats(A=total.A * f, B=total.B * f, C=total.C * f,
                          D=total.D * f, KL=total.KL * f,
                          n=jnp.asarray(n_rows, dtype=jnp.asarray(total.n).dtype))
        return total


class AsyncEngine:
    """Barrier-free async training step over K host-simulated shards.

    Args:
      shards: list of per-shard data dicts ``{"y": (n_k, d), "mu": (n_k, q),
        optional "s": (n_k, q), optional "w": (n_k,)}`` — ragged row counts
        allowed (this is what elastic membership produces).
      d: output dimension (bound argument).
      staleness / reweight: accumulator policy (S, and drop/rescale/probs).
      refresh: shards refreshed per step (round-robin over alive shards).
      failure: optional ``fault.FailureSimulator`` — dead shards skip
        their refresh slot this step (their last contribution goes stale
        and is eventually evicted; rescue is automatic on resurrection).
      timer: optional ``fault.StepTimer`` — records per-refreshed-shard
        wall times each step (ragged by design when ``refresh`` varies
        with the alive set — the fixed ``StepTimer`` handles that).
      chunk_size: per-shard scan block size (None = monolithic map).
      batch_blocks: per-shard SVI block subsample (requires chunk_size) —
        refreshed shards push reweighted stochastic Stats; pass a fresh
        ``key`` to :meth:`step`.
      latent / kernel: as on ``DistributedGP``.
      clip: optional global-norm bound on the returned gradient.  Folds
        that mix stats from different (hyp, z) can transiently break the
        bound's Nyström-residual positivity and blow up the raw gradient
        (a real stale-update failure mode, not a numerics bug) — for
        plain SGD on the async step, set ``clip`` to roughly the exact
        gradient's norm scale.  ``None`` (default) returns the raw
        gradient: bitwise-identical to the reference when all shards are
        fresh.

    ``step(hyp, z, key=None)`` returns ``(neg_bound, (g_hyp, g_z))`` from
    the folded (partially stale) Stats; gradients are recovered via the
    stats cotangent (module docstring).  ``exact_value_and_grad`` is the
    all-fresh reference the tests compare against.
    """

    def __init__(self, shards, d: int, *, staleness: int = 2,
                 reweight: str = "drop", refresh: int = 1,
                 failure=None, timer=None, chunk_size: int | None = None,
                 batch_blocks: int | None = None, latent: bool = False,
                 kernel=None, clip: float | None = None):
        if refresh < 1:
            raise ValueError(f"refresh must be >= 1, got {refresh}")
        if clip is not None and not clip > 0:
            raise ValueError(f"clip must be positive, got {clip}")
        from ..core.covariance import as_kernel
        self.shards = list(shards)
        self.d = d
        self.refresh = refresh
        self.failure = failure
        self.timer = timer
        self.chunk_size = chunk_size
        self.batch_blocks = batch_blocks
        self.latent = latent
        self.clip = clip
        self.kernel = as_kernel(kernel)
        self.acc = AsyncStatsAccumulator(staleness=staleness, reweight=reweight)
        self.n_full = float(sum(self._rows(s) for s in self.shards))
        self._grads: dict[int, Any] = {}     # shard -> (g_hyp, g_z) at last ct
        self._rr = itertools.cycle(range(len(self.shards)))
        self._step = 0
        self._collapse_vg = jax.jit(jax.value_and_grad(
            self._neg_collapse, argnums=(0, 1, 2)))
        self._stats_jit = jax.jit(self._local_stats,
                                  static_argnames=("exact",))
        self._ip_vg = jax.jit(jax.value_and_grad(self._ip, argnums=(0, 1)))

    @staticmethod
    def _rows(shard) -> float:
        w = shard.get("w")
        if w is not None:
            import numpy as np
            return float(np.sum(w))
        return float(shard["y"].shape[0])

    # -- jitted pieces -------------------------------------------------------
    def _local_stats(self, hyp, z, y, mu, s, w, key=None, exact=False) -> Stats:
        return partial_stats_chunked(
            hyp, z, y, mu, s, weights=w, latent=self.latent,
            block_size=self.chunk_size, kernel=self.kernel,
            batch_blocks=None if exact else self.batch_blocks, key=key)

    def _neg_collapse(self, hyp, z, st):
        # n-handling mirrors the in-mesh failure modes: drop's n leaf is
        # the sum over LIVE contributions (a self-consistent bound of the
        # present subset — full n against partial sums skews the noise
        # terms and destabilises log_beta); rescale/probs already fixed
        # up n at read/push time.
        return -collapsed_bound(hyp, z, st, self.d, kernel=self.kernel)

    def _ip(self, hyp, z, y, mu, s, w, ct, key=None):
        # key=None replays the exact scan (the reference path); with a key
        # the SVI subsample is re-drawn from the SAME per-shard key the
        # stats push used, so the gradient matches the pushed estimate.
        st = self._local_stats(hyp, z, y, mu, s, w, key=key,
                               exact=key is None)
        return sum(jnp.vdot(a, b) for a, b in zip(st, ct))

    # -- the async step ------------------------------------------------------
    def _alive(self):
        if self.failure is None:
            return [True] * len(self.shards)
        return [m > 0 for m in self.failure.mask()]

    def _pick_refresh(self, alive) -> list[int]:
        picked, seen = [], 0
        while len(picked) < self.refresh and seen < len(self.shards):
            k = next(self._rr)
            seen += 1
            if alive[k] and k not in picked:
                picked.append(k)
        return picked

    def _push_shard(self, k: int, stamp: int, key=None):
        sh = self.shards[k]
        skey = None if key is None else jax.random.fold_in(key, k)
        st = self._stats_jit(self.hyp, self.z, sh["y"], sh["mu"],
                             sh.get("s"), sh.get("w"), key=skey)
        self.acc.push(k, st, stamp=stamp, rows=self._rows(sh))
        return skey

    def step(self, hyp, z, key=None):
        """One barrier-free step at the current (hyp, z).  Returns
        ``(neg_bound, (g_hyp, g_z))`` — both computed from the folded
        Stats, with ``refresh`` shards' contributions fresh and the rest
        stale up to S steps (older ones evicted)."""
        self.hyp, self.z = hyp, z
        t = self._step
        self._step += 1
        alive = self._alive()
        picked = self._pick_refresh(alive)

        skeys = {}
        thunks = [lambda k=k: skeys.__setitem__(k, self._push_shard(k, t, key))
                  for k in picked]
        if self.timer is not None and thunks:
            self.timer.time_shards(thunks)
        else:
            for fn in thunks:
                fn()

        st = self.acc.read(t, n_rows=self.n_full)
        val, (gh_d, gz_d, ct) = self._collapse_vg(hyp, z, st)

        # Second pass (refreshed shards only): the chain-rule contribution
        # <d stats_k / d(hyp, z), ct> at the CURRENT cotangent; the others
        # reuse their cached stale-ct contribution.
        for k in picked:
            sh = self.shards[k]
            _, g = self._ip_vg(hyp, z, sh["y"], sh["mu"], sh.get("s"),
                               sh.get("w"), ct, key=skeys.get(k))
            self._grads[k] = g
        members = [k for k in self.acc.members() if k in self._grads]
        gsum = None
        for k in members:
            gsum = self._grads[k] if gsum is None else _tree_add(
                gsum, self._grads[k])
        if gsum is not None:
            if self.acc.reweight == "rescale":
                gsum = _tree_scale(gsum, self.n_full / self.acc.rows_live())
            # ct is d(-F)/d(stats): the shard contributions already carry
            # the negative sign — add them to the direct term.
            gh_d = _tree_add(gh_d, gsum[0])
            gz_d = gz_d + gsum[1]
        if self.clip is not None:
            # Stale folds mix stats computed at different (hyp, z); the
            # collapsed bound's Nyström-residual terms can then transiently
            # flip sign and the raw gradient runs away through log_beta
            # (tests/test_async_stats.py pins the stabilized descent).
            # Global-norm clipping bounds the per-step parameter motion —
            # and with it the staleness window's theta span — which is the
            # standard stale-gradient stabilizer.
            gn = jnp.sqrt(sum(jnp.sum(jnp.square(g))
                              for g in jax.tree.leaves((gh_d, gz_d))))
            c = jnp.minimum(1.0, self.clip / (gn + 1e-30))
            gh_d = _tree_scale(gh_d, c)
            gz_d = gz_d * c
        return val, (gh_d, gz_d)

    # -- reference -----------------------------------------------------------
    def exact_value_and_grad(self, hyp, z):
        """The all-fresh (synchronous) value/grad over every shard — the
        reference the async step converges to when refresh >= K and S
        covers the round.  Bypasses the accumulator entirely."""
        total = None
        for sh in self.shards:
            st = self._stats_jit(hyp, z, sh["y"], sh["mu"], sh.get("s"),
                                 sh.get("w"), exact=True)
            total = st if total is None else fold_stats(total, st)
        val, (gh, gz, ct) = self._collapse_vg(hyp, z, total)
        for sh in self.shards:
            _, g = self._ip_vg(hyp, z, sh["y"], sh["mu"], sh.get("s"),
                               sh.get("w"), ct)
            gh = _tree_add(gh, g[0])
            gz = gz + g[1]
        return val, (gh, gz)
