"""Logical-axis sharding: MaxText-style rules with divisibility fallback.

Every parameter is created together with a tuple of *logical* axis names
(see models/common.py::param). At launch time the rules below resolve
logical names to mesh axes; any assignment whose dimension size is not
divisible by the mesh axis size silently falls back to replication (e.g.
kv_heads=2 under model=16).

Activation constraints use a module-level mesh context (set by the
launcher / dry-run); with no context they are identity, so smoke tests and
single-device runs never touch jax device state.
"""
from __future__ import annotations

import contextlib
from typing import Any, Sequence

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

# logical axis -> preferred mesh axis (order tried first-to-last)
DEFAULT_RULES: dict[str, tuple[str, ...]] = {
    # tensor-parallel dims
    "vocab": ("model",),
    "mlp": ("model",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "heads_group": ("model",),   # the H/Hkv group dim of unfused GQA scores
    "experts": ("model",),
    "lru": ("model",),
    "inner": ("model",),       # ssm d_inner / conv channels
    # fsdp dims (weight shards over the data axis)
    "embed": ("data",),
    "moe_mlp": ("data",),
    "qk": (), "v": (), "rank": (),   # MLA small dims: replicate
    # never sharded
    "layers": (), "state": (), "conv": (), "pos": (), "frames": (),
    # activations
    "batch": ("pod", "data"),
    "seq": (),
    "seq_model": ("model",),   # Megatron-style sequence parallelism between blocks
    # KV-cache sequence dim: prefer model (batch usually owns data); decode
    # softmax over the sharded S axis costs two small per-layer all-reduces
    # and cuts per-device cache by the TP degree.
    "seq_shard": ("model", "data"),
}

_CTX: dict[str, Any] = {"mesh": None, "rules": dict(DEFAULT_RULES)}


def set_mesh(mesh: Mesh | None, rules: dict | None = None):
    _CTX["mesh"] = mesh
    _CTX["rules"] = dict(DEFAULT_RULES) if rules is None else dict(rules)


@contextlib.contextmanager
def use_mesh(mesh: Mesh, rules: dict | None = None):
    old = dict(_CTX)
    set_mesh(mesh, rules)
    try:
        yield
    finally:
        _CTX.update(old)


def _axes_for(logical: str | None, dim_size: int, mesh: Mesh,
              rules: dict, used: set[str]) -> tuple[str, ...] | None:
    if logical is None:
        return None
    # "name:quantum" — the dim may only be split in units of ``quantum``
    # (e.g. "heads:128" keeps whole attention heads on one shard).
    name, _, quantum_s = logical.partition(":")
    quantum = int(quantum_s) if quantum_s else 1
    units = dim_size // max(quantum, 1)
    cand = rules.get(name, ())
    picked = []
    size = 1
    for ax in cand:
        if ax in used or ax not in mesh.shape:
            continue
        if units % (size * mesh.shape[ax]) == 0:
            picked.append(ax)
            size *= mesh.shape[ax]
    return tuple(picked) or None


def spec_for(logical_axes: Sequence[str | None], shape: Sequence[int],
             mesh: Mesh | None = None, rules: dict | None = None) -> P:
    """Resolve a logical-axis tuple into a PartitionSpec for ``mesh``."""
    mesh = _CTX["mesh"] if mesh is None else mesh
    rules = _CTX["rules"] if rules is None else rules
    if mesh is None:
        return P()
    used: set[str] = set()
    entries = []
    for name, dim in zip(logical_axes, shape):
        axes = _axes_for(name, dim, mesh, rules, used)
        if axes:
            used.update(axes)
            entries.append(axes if len(axes) > 1 else axes[0])
        else:
            entries.append(None)
    while entries and entries[-1] is None:
        entries.pop()
    return P(*entries)


def axis_divides(logical: str, size: int) -> bool:
    """True iff ``size`` is divisible by the mesh extent mapped to
    ``logical`` (False when no mesh/axis — caller should skip constraints
    rather than pin XLA to a worse layout)."""
    mesh = _CTX["mesh"]
    if mesh is None:
        return False
    ext = 1
    for ax in _CTX["rules"].get(logical, ()):
        if ax in mesh.shape:
            ext *= mesh.shape[ax]
    return ext > 1 and size % ext == 0


def constrain(x, logical_axes: Sequence[str | None]):
    """with_sharding_constraint against the context mesh (identity if none)."""
    mesh = _CTX["mesh"]
    if mesh is None:
        return x
    spec = spec_for(logical_axes, x.shape, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def tree_shardings(spec_tree, shape_tree, mesh: Mesh, rules: dict | None = None):
    """Map a logical-axes tree + matching ShapeDtypeStruct tree to
    NamedShardings (for jit in_shardings / device_put)."""

    def one(logical, sds):
        return NamedSharding(mesh, spec_for(logical, sds.shape, mesh, rules))

    return jax.tree.map(one, spec_tree, shape_tree,
                        is_leaf=lambda x: isinstance(x, tuple) and all(
                            isinstance(e, (str, type(None))) for e in x))
