from . import sharding
from . import fault
