from . import sharding
from . import fault
from . import async_stats
from .async_stats import AsyncEngine, AsyncStatsAccumulator
