"""chameleon-34b [vlm]: 48L d=8192 64H (GQA kv=8) d_ff=22016 vocab=65536.
Early fusion: VQ image tokens share the text vocab, so the backbone is a
dense decoder; the VQ-GAN tokenizer frontend is STUBBED (input_specs()
supplies token ids that already include image tokens). [arXiv:2405.09818;
unverified]"""
from .base import BlockGroup, ModelConfig, register

CONFIG = register(ModelConfig(
    name="chameleon-34b", family="vlm",
    num_layers=48, d_model=8192, num_heads=64, num_kv_heads=8,
    d_ff=22016, vocab_size=65536,
    blocks=(BlockGroup("attn", "mlp", 48),),
    param_dtype="bfloat16",
    source="arXiv:2405.09818; unverified",
))
