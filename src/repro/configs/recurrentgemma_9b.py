"""recurrentgemma-9b [hybrid]: 38L d=4096 16H (MQA kv=1) d_ff=12288
vocab=256000. RG-LRU + local attention (window 2048), pattern
(R, R, A) x 12 + (R, R). Sub-quadratic => runs long_500k.
[arXiv:2402.19427; unverified]"""
from .base import BlockGroup, ModelConfig, register

_PATTERN = (["rglru", "rglru", "lattn"] * 12 + ["rglru", "rglru"])
_BLOCKS = tuple(BlockGroup(m, "mlp", 1, scan=False) for m in _PATTERN)

CONFIG = register(ModelConfig(
    name="recurrentgemma-9b", family="hybrid",
    num_layers=38, d_model=4096, num_heads=16, num_kv_heads=1,
    d_ff=12288, vocab_size=256000,
    blocks=_BLOCKS,
    local_window=2048, lru_width=4096, rope_theta=10_000.0,
    tie_embeddings=True, runs_long=True, param_dtype="bfloat16",
    source="arXiv:2402.19427; unverified",
))
