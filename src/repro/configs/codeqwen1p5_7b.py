"""codeqwen1.5-7b [dense]: 32L d=4096 32H (GQA kv=32 == MHA) d_ff=13440
vocab=92416. qwen1.5-arch (QKV bias). [hf:Qwen/CodeQwen1.5-7B; hf]"""
from .base import BlockGroup, ModelConfig, register

CONFIG = register(ModelConfig(
    name="codeqwen1.5-7b", family="dense",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=32,
    d_ff=13440, vocab_size=92416,
    blocks=(BlockGroup("attn", "mlp", 32),),
    qkv_bias=True, rope_theta=1_000_000.0,
    source="hf:Qwen/CodeQwen1.5-7B; hf",
))
