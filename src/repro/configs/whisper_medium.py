"""whisper-medium [audio enc-dec]: 24+24L d=1024 16H (kv=16) d_ff=4096
vocab=51865. Conv frontend STUBBED: input_specs() supplies precomputed
(B, 1500, d) frame embeddings; decoder positions use RoPE instead of
learned-448 so the assigned 32k decode shapes are well-defined (DESIGN.md).
[arXiv:2212.04356; unverified]"""
from .base import BlockGroup, ModelConfig, register

CONFIG = register(ModelConfig(
    name="whisper-medium", family="encdec",
    num_layers=24, encoder_layers=24, d_model=1024, num_heads=16,
    num_kv_heads=16, d_ff=4096, vocab_size=51865,
    blocks=(BlockGroup("attn", "mlp", 24),),
    norm_type="layernorm", mlp_type="gelu", rope_theta=10_000.0,
    num_frames=1500, tie_embeddings=True,
    source="arXiv:2212.04356; unverified",
))
