"""Architecture registry: one module per assigned arch + the paper's own
GP workloads. ``load_all()`` imports every config module (idempotent)."""
from .base import (SHAPES, BlockGroup, ModelConfig, ShapeSpec, all_configs,
                   cells, get_config, register)
from .gp_paper import GP_CONFIGS, GPConfig

_ARCH_MODULES = [
    "qwen2_1p5b", "llama3p2_1b", "starcoder2_3b", "codeqwen1p5_7b",
    "whisper_medium", "deepseek_v2_236b", "qwen3_moe_235b", "chameleon_34b",
    "recurrentgemma_9b", "mamba2_370m",
]


def load_all():
    import importlib
    for m in _ARCH_MODULES:
        importlib.import_module(f"{__name__}.{m}")


__all__ = ["SHAPES", "BlockGroup", "ModelConfig", "ShapeSpec", "all_configs",
           "cells", "get_config", "register", "GP_CONFIGS", "GPConfig",
           "load_all"]
