"""deepseek-v2-236b [moe]: 60L d=5120 128H d_ff(routed)=1536 vocab=102400.
MLA kv_lora=512 (q_lora=1536, nope=128, rope=64, v=128); MoE 160 routed
top-6 + 2 shared experts; first layer dense (d_ff=12288).
[arXiv:2405.04434; hf]"""
from .base import BlockGroup, ModelConfig, register

CONFIG = register(ModelConfig(
    name="deepseek-v2-236b", family="moe",
    num_layers=60, d_model=5120, num_heads=128, num_kv_heads=128,
    d_ff=12288, vocab_size=102400,
    blocks=(BlockGroup("mla", "mlp", 1, scan=False),
            BlockGroup("mla", "moe", 59)),
    use_mla=True, kv_lora_rank=512, q_lora_rank=1536,
    qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128,
    num_experts=160, experts_per_token=6, moe_d_ff=1536,
    num_shared_experts=2, first_k_dense=1,
    param_dtype="bfloat16",
    source="arXiv:2405.04434; hf",
))
