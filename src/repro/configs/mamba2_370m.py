"""mamba2-370m [ssm]: 48L d=1024 attn-free, vocab=50280, ssm_state=128.
SSD (state-space duality), d_inner=2048, 32 heads x 64. Constant-state
decode => runs long_500k. [arXiv:2405.21060; unverified]"""
from .base import BlockGroup, ModelConfig, register

CONFIG = register(ModelConfig(
    name="mamba2-370m", family="ssm",
    num_layers=48, d_model=1024, num_heads=1, num_kv_heads=1,
    d_ff=0, vocab_size=50280,
    blocks=(BlockGroup("ssd", "none", 48),),
    ssm_state_dim=128, ssm_expand=2, ssm_head_dim=64, ssm_chunk=128,
    rope_theta=0.0, tie_embeddings=True, runs_long=True,
    source="arXiv:2405.21060; unverified",
))
