"""The paper's own workload configs (sparse GP / GPLVM).

These drive the GP dry-run cells and the paper-reproduction benchmarks.
Sizes follow the paper's experiments: oil-flow (1k x 12), the 100k-point
synthetic sines dataset, full USPS (4649 x 256, m=150) and a stretch
1M-point regression showing the 512-chip scaling headroom.
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class GPConfig:
    name: str
    n: int             # data points
    d: int             # output dims
    q: int             # latent / input dims
    m: int             # inducing points
    latent: bool       # GPLVM (True) or regression (False)
    # Covariance expression as a JSON spec string for core.covariance.
    # kernel_from_spec / as_kernel; "se" (full-width SE-ARD, the paper's
    # kernel) keeps the fused Pallas fast path.
    kernel: str = "se"
    source: str = ""

    def kernel_expr(self):
        """The parsed covariance expression (core.covariance.Kernel)."""
        from ..core.covariance import as_kernel
        return as_kernel(self.kernel)


GP_CONFIGS: dict[str, GPConfig] = {
    c.name: c for c in [
        GPConfig("gplvm-oilflow", n=1000, d=12, q=10, m=50, latent=True,
                 source="paper fig.4 (Titsias & Lawrence oil-flow)"),
        GPConfig("gplvm-synth-100k", n=100_000, d=3, q=2, m=100, latent=True,
                 source="paper §4.2-4.3 scaling dataset"),
        GPConfig("gplvm-usps", n=4649, d=256, q=10, m=150, latent=True,
                 source="paper §4.5 USPS"),
        GPConfig("sgpr-synth-1m", n=1_000_000, d=4, q=8, m=512, latent=False,
                 source="beyond-paper scale point (512-chip headroom)"),
        GPConfig("sgpr-zoo-trend", n=100_000, d=2, q=4, m=128, latent=False,
                 kernel='{"kind": "sum", "parts": ['
                        '{"kind": "se", "dims": [0, 1]}, '
                        '{"kind": "linear", "dims": [2, 3]}], '
                        '"quad_order": 11}',
                 source="kernel-zoo composite (smooth + linear trend), "
                        "docs/kernels.md#kernel-zoo"),
    ]
}
