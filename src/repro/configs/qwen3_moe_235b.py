"""qwen3-moe-235b-a22b [moe]: 94L d=4096 64H (GQA kv=4) d_ff(routed)=1536
vocab=151936. 128 experts top-8, no shared. [hf:Qwen/Qwen3-30B-A3B; hf]"""
from .base import BlockGroup, ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen3-moe-235b-a22b", family="moe",
    num_layers=94, d_model=4096, num_heads=64, num_kv_heads=4,
    d_ff=1536, vocab_size=151936,
    blocks=(BlockGroup("attn", "moe", 94),),
    rope_theta=1_000_000.0,
    num_experts=128, experts_per_token=8, moe_d_ff=1536,
    param_dtype="bfloat16",
    source="hf:Qwen/Qwen3-30B-A3B; hf",
))
