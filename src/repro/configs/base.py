"""Model/shape configuration schema and the architecture registry.

Each assigned architecture file instantiates ``ModelConfig`` with the exact
numbers from the assignment and registers itself; ``reduced()`` derives the
CPU smoke-test config (same family/topology, tiny dims). Input shapes are
the four assigned (seq_len, global_batch) cells; ``long_500k`` is only
``runs_long``-eligible for sub-quadratic families.
"""
from __future__ import annotations

from dataclasses import dataclass, replace

# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class BlockGroup:
    """A run of identical layers: mixer in {attn, lattn, mla, ssd, rglru},
    ffn in {mlp, moe, none}; ``scan=True`` stacks params and lax.scans."""
    mixer: str
    ffn: str
    count: int
    scan: bool = True


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | encdec | vlm | hybrid | ssm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    blocks: tuple[BlockGroup, ...]
    head_dim: int = 0                # 0 -> d_model // num_heads
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    norm_type: str = "rmsnorm"
    mlp_type: str = "swiglu"
    tie_embeddings: bool = False
    local_window: int | None = None
    # MoE
    num_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0
    num_shared_experts: int = 0
    first_k_dense: int = 0
    capacity_factor: float = 1.25
    moe_impl: str = "sharded"        # sharded | dense
    moe_dispatch_dtype: str = "native"   # native | int8 (wire format)
    # MLA
    use_mla: bool = False
    kv_lora_rank: int = 0
    q_lora_rank: int = 0
    qk_nope_head_dim: int = 0
    qk_rope_head_dim: int = 0
    v_head_dim: int = 0
    # SSM
    ssm_state_dim: int = 0
    ssm_expand: int = 2
    ssm_heads: int = 0
    ssm_head_dim: int = 64
    ssm_chunk: int = 64
    conv_kernel: int = 4
    # RG-LRU
    lru_width: int = 0
    # enc-dec (whisper)
    encoder_layers: int = 0
    num_frames: int = 1500
    # numerics / execution
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    remat: bool = True
    remat_policy: str = "full"       # full | dots (save matmul outputs)
    use_flash: bool = False
    # provenance
    source: str = ""
    runs_long: bool = False          # sub-quadratic -> long_500k eligible

    def reduced(self) -> "ModelConfig":
        """Tiny same-family config for CPU smoke tests."""
        scale = {}
        scale["d_model"] = 64
        scale["num_heads"] = 4
        scale["num_kv_heads"] = min(self.num_kv_heads, 2) or 1
        scale["head_dim"] = 16 if self.head_dim else 0
        scale["d_ff"] = 128
        scale["vocab_size"] = 512
        scale["num_frames"] = 16
        scale["param_dtype"] = "float32"
        scale["compute_dtype"] = "float32"
        scale["remat"] = False
        scale["moe_impl"] = "dense"
        if self.num_experts:
            scale["num_experts"] = 8
            scale["experts_per_token"] = min(self.experts_per_token, 2)
            scale["moe_d_ff"] = 32
        if self.use_mla:
            scale.update(kv_lora_rank=32, q_lora_rank=48, qk_nope_head_dim=16,
                         qk_rope_head_dim=8, v_head_dim=16)
        if self.ssm_state_dim:
            scale.update(ssm_state_dim=16, ssm_head_dim=16, ssm_heads=0,
                         ssm_chunk=16)
        if self.lru_width:
            scale["lru_width"] = 64
        if self.local_window:
            scale["local_window"] = 8
        # shrink the block structure but keep its shape
        blocks = []
        seen = set()
        for g in self.blocks:
            cnt = min(g.count, 2)
            blocks.append(BlockGroup(g.mixer, g.ffn, cnt, g.scan))
            seen.add((g.mixer, g.ffn))
        scale["blocks"] = tuple(blocks)
        scale["num_layers"] = sum(g.count for g in blocks)
        scale["encoder_layers"] = 2 if self.encoder_layers else 0
        return replace(self, **scale)


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # train | prefill | decode


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}

_REGISTRY: dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    if not _REGISTRY:
        from . import load_all  # lazy populate
        load_all()
    return _REGISTRY[name]


def all_configs() -> dict[str, ModelConfig]:
    if not _REGISTRY:
        from . import load_all
        load_all()
    return dict(_REGISTRY)


def cells(cfg: ModelConfig) -> list[str]:
    """The shape cells this arch runs (long_500k only if sub-quadratic)."""
    out = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.runs_long:
        out.append("long_500k")
    return out
