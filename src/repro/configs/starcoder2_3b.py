"""starcoder2-3b [dense]: 30L d=3072 24H (GQA kv=2) d_ff=12288 vocab=49152.
GQA, RoPE, layernorm + bias, plain-GELU MLP. [arXiv:2402.19173; hf]"""
from .base import BlockGroup, ModelConfig, register

CONFIG = register(ModelConfig(
    name="starcoder2-3b", family="dense",
    num_layers=30, d_model=3072, num_heads=24, num_kv_heads=2,
    d_ff=12288, vocab_size=49152,
    blocks=(BlockGroup("attn", "mlp", 30),),
    qkv_bias=True, rope_theta=100_000.0, norm_type="layernorm",
    mlp_type="gelu", tie_embeddings=True,
    source="arXiv:2402.19173; hf",
))
