"""Overlapped per-block reduce (reduce_mode="overlap"): parity contracts.

What is provable, and what is asserted:

  * With an IDENTITY reduce hook, the overlapped scan folds exactly the
    same per-block values in exactly the same order as the serial scan —
    so ``block_reduce_fn=identity`` must be BITWISE equal to the plain
    chunked scan, buffered or eager (the double buffer only re-times the
    fold: its initial pending slot is exact zeros and x + 0.0 == x).
  * On a ONE-device mesh the psum is the identity, so
    ``reduce_mode="overlap"`` must be bitwise equal to ``"serial"`` —
    bound AND grads — across backends, the latent path, and SVI.
  * On a multi-device mesh, serial (``psum(sum_t st_t)``) and overlapped
    (``sum_t psum(st_t)``) associate the cross-shard/cross-block float
    sums differently — bitwise equality is impossible there, and the
    8-device section in tests/_dist_worker.py pins tight f64 closeness
    plus the bitwise ``overlap == overlap_eager`` scheduling contract.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.distributed import DistributedGP
from repro.core.stats import Stats, partial_stats_chunked
from repro.launch.mesh import make_compat_mesh

from conftest import make_regression


def _mk_hyp(q):
    return {"log_sf2": jnp.asarray(0.2), "log_ell": jnp.full((q,), 0.1),
            "log_beta": jnp.asarray(1.0)}


def _assert_stats_bitwise(a, b):
    for name, x, y in zip(a._fields, a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                      err_msg=name)


@pytest.mark.parametrize("latent", [False, True])
@pytest.mark.parametrize("buffered", [True, False])
def test_identity_reduce_bitwise_equals_plain_scan(rng, latent, buffered):
    """block_reduce_fn=identity folds the same values in the same order as
    the serial scan — bitwise, including the padded final block."""
    n, m, q, d, block = 53, 6, 2, 3, 8          # nb = 7, last block padded
    x = jnp.asarray(rng.standard_normal((n, q)))
    y = jnp.asarray(rng.standard_normal((n, d)))
    z = jnp.asarray(rng.standard_normal((m, q)))
    s = jnp.asarray(rng.uniform(0.05, 0.6, (n, q))) if latent else None
    hyp = _mk_hyp(q)

    plain = partial_stats_chunked(hyp, z, y, x, s=s, latent=latent,
                                  block_size=block, force_scan=True)
    ov = partial_stats_chunked(hyp, z, y, x, s=s, latent=latent,
                               block_size=block,
                               block_reduce_fn=lambda st: st,
                               reduce_buffered=buffered)
    _assert_stats_bitwise(plain, ov)


def test_identity_reduce_bitwise_with_svi_subset(rng):
    """The overlapped reduce composes with the SVI block subsample: the
    sampled blocks are reduced as scanned and the nb/B reweighting applies
    to the reduced accumulator — identical values, identical order."""
    n, m, q, block, B = 41, 5, 2, 8, 3          # nb = 6
    x = jnp.asarray(rng.standard_normal((n, q)))
    y = jnp.asarray(rng.standard_normal((n, 2)))
    z = jnp.asarray(rng.standard_normal((m, q)))
    hyp = _mk_hyp(q)
    sub = jnp.asarray([0, 4, 2])

    plain = partial_stats_chunked(hyp, z, y, x, s=None, latent=False,
                                  block_size=block, batch_blocks=B,
                                  block_indices=sub, force_scan=True)
    ov = partial_stats_chunked(hyp, z, y, x, s=None, latent=False,
                               block_size=block, batch_blocks=B,
                               block_indices=sub,
                               block_reduce_fn=lambda st: st)
    _assert_stats_bitwise(plain, ov)


def test_partial_stats_chunked_overlap_validation(rng):
    y = jnp.asarray(rng.standard_normal((20, 1)))
    x = jnp.asarray(rng.standard_normal((20, 2)))
    hyp = _mk_hyp(2)
    z = jnp.asarray(rng.standard_normal((4, 2)))
    ident = lambda st: st
    with pytest.raises(ValueError, match="requires block_size"):
        partial_stats_chunked(hyp, z, y, x, s=None, latent=False,
                              block_size=None, block_reduce_fn=ident)
    init = partial_stats_chunked(hyp, z, y, x, s=None, latent=False,
                                 block_size=4)
    with pytest.raises(ValueError, match="init cannot be combined"):
        partial_stats_chunked(hyp, z, y, x, s=None, latent=False,
                              block_size=4, block_reduce_fn=ident,
                              init=Stats(*(jnp.atleast_1d(t) for t in init)))


def test_engine_reduce_mode_validation():
    mesh = make_compat_mesh((1,), ("data",))
    with pytest.raises(ValueError, match="reduce_mode must be"):
        DistributedGP(mesh, chunk_size=4, reduce_mode="async")
    with pytest.raises(ValueError, match="requires chunk_size"):
        DistributedGP(mesh, reduce_mode="overlap")


@pytest.mark.parametrize("latent", [False, True])
def test_one_device_overlap_bitwise_equals_serial(rng, latent):
    """psum on a 1-device mesh is the identity: overlap must reproduce the
    serial bound and grads BIT FOR BIT — engine-level, both tiers."""
    mesh = make_compat_mesh((1,), ("data",))
    n, m, q, d, block = 37, 5, 2, 2, 8
    x = rng.standard_normal((n, q))
    y = rng.standard_normal((n, d))
    z = jnp.asarray(rng.standard_normal((m, q)))
    s = rng.uniform(0.05, 0.6, (n, q)) if latent else None
    hyp = _mk_hyp(q)
    nf = jnp.asarray(float(n))
    fm = jnp.ones((1,))

    out = {}
    for mode in ("serial", "overlap", "overlap_eager"):
        eng = DistributedGP(mesh, latent=latent, chunk_size=block,
                            reduce_mode=mode)
        if latent:
            data, w = eng.put_data(y=y, mu=x, s=s)
            sv = data["s"]
            argnums = (0, 1, 2, 3)
        else:
            data, w = eng.put_data(y=y, mu=x)
            sv = None
            argnums = (0, 1)
        vg = eng.make_value_and_grad(d, argnums=argnums)
        out[mode] = vg(hyp, z, data["mu"], sv, data["y"], w, fm, nf)

    v0, g0 = out["serial"]
    for mode in ("overlap", "overlap_eager"):
        v, g = out[mode]
        assert float(v) == float(v0), mode
        for a, b in zip(jax.tree.leaves(g0), jax.tree.leaves(g)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                          err_msg=mode)


def test_one_device_overlap_bitwise_svi_and_rescale(rng):
    """The overlap path under SVI sampling and the rescale failure mode —
    same bitwise 1-device contract (the SVI key folding and the n/n_live
    handling sit outside the reduce restructure)."""
    mesh = make_compat_mesh((1,), ("data",))
    n, m, q, d, block = 40, 4, 2, 1, 8
    x, y = make_regression(rng, n=n, q=q, d=d)
    z = jnp.asarray(rng.standard_normal((m, q)))
    hyp = _mk_hyp(q)
    nf = jnp.asarray(float(n))
    key = jax.random.PRNGKey(3)

    vals = {}
    for mode in ("serial", "overlap"):
        eng = DistributedGP(mesh, chunk_size=block, batch_blocks=2,
                            failure_mode="rescale", reduce_mode=mode)
        data, w = eng.put_data(y=y, mu=x)
        v, (gh, gz) = eng.make_value_and_grad(d)(
            hyp, z, data["mu"], None, data["y"], w, jnp.ones((1,)), nf, key)
        vals[mode] = (v, gh, gz)
    assert float(vals["overlap"][0]) == float(vals["serial"][0])
    np.testing.assert_array_equal(np.asarray(vals["overlap"][2]),
                                  np.asarray(vals["serial"][2]))
    for k in vals["serial"][1]:
        np.testing.assert_array_equal(np.asarray(vals["overlap"][1][k]),
                                      np.asarray(vals["serial"][1][k]))


def test_one_device_overlap_pallas_backend(rng):
    """kernel_backend='pallas' (interpret mode off-TPU) under the overlapped
    reduce: the per-block hook output feeds the in-scan collective —
    1-device bitwise parity against the pallas serial path."""
    mesh = make_compat_mesh((1,), ("data",))
    n, m, q, d, block = 33, 6, 2, 1, 8
    x, y = make_regression(rng, n=n, q=q, d=d)
    z = jnp.asarray(rng.standard_normal((m, q)))
    hyp = _mk_hyp(q)
    nf = jnp.asarray(float(n))

    out = {}
    for mode in ("serial", "overlap"):
        eng = DistributedGP(mesh, chunk_size=block, kernel_backend="pallas",
                            reduce_mode=mode)
        data, w = eng.put_data(y=y, mu=x)
        out[mode] = eng.make_value_and_grad(d)(
            hyp, z, data["mu"], None, data["y"], w, jnp.ones((1,)), nf)
    assert float(out["overlap"][0]) == float(out["serial"][0])
    for a, b in zip(jax.tree.leaves(out["serial"][1]),
                    jax.tree.leaves(out["overlap"][1])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
