"""Multi-model serving: N stacked PredictiveStates, one executable.

`stack_states` batches same-shape states into one pytree;
`MultiPredictEngine` vmaps the block scan over the model axis.  The
contract: every model's row of the stacked output equals what its own
single-model engine would produce — the vmap is pure batching, not an
approximation — and the mixture helper implements the equal-weight moment
algebra exactly.
"""
import dataclasses

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import covariance as cov
from repro.core.stats import partial_stats
from repro.serve import (MultiPredictEngine, PredictEngine, extract_state,
                         mixture_moments, stack_states)


def _fleet(rng, n_models=3, n=70, m=9, q=2, d=2):
    """N states sharing shapes but not hypers/posteriors (an A/B fleet)."""
    x = jnp.asarray(rng.standard_normal((n, q)))
    y = jnp.asarray(rng.standard_normal((n, d)))
    z = jnp.asarray(rng.standard_normal((m, q)))
    states = []
    for k in range(n_models):
        hyp = {"log_sf2": jnp.asarray(0.2 + 0.1 * k),
               "log_ell": jnp.asarray(rng.uniform(-0.3, 0.3, q)),
               "log_beta": jnp.asarray(1.0 + 0.2 * k)}
        stats = partial_stats(hyp, z, y, x, s=None, latent=False)
        states.append(extract_state(hyp, z, stats))
    return states


def test_stack_states_shapes(rng):
    states = _fleet(rng)
    stacked = stack_states(states)
    assert stacked.z.shape == (3, 9, 2)
    assert stacked.g.shape == (3, 9, 9)
    assert stacked.hyp["log_beta"].shape == (3,)
    for k, s in enumerate(states):
        np.testing.assert_array_equal(np.asarray(stacked.a_mean[k]),
                                      np.asarray(s.a_mean))


@pytest.mark.parametrize("t,block", [(1, 8), (23, 4), (16, 16)])
def test_multi_engine_rows_equal_single_engines(rng, t, block):
    """Stacked row k == model k's own engine, padding and noise included."""
    states = _fleet(rng)
    eng = MultiPredictEngine(states, block_size=block)
    xs = jnp.asarray(rng.standard_normal((t, 2)))
    for noise in (False, True):
        mean, var = eng.predict(xs, include_noise=noise)
        assert mean.shape == (3, t, 2) and var.shape == (3, t)
        for k, s in enumerate(states):
            m1, v1 = PredictEngine(s, block_size=block).predict(
                xs, include_noise=noise)
            np.testing.assert_allclose(np.asarray(mean[k]), np.asarray(m1),
                                       rtol=1e-12, atol=1e-14)
            np.testing.assert_allclose(np.asarray(var[k]), np.asarray(v1),
                                       rtol=1e-12, atol=1e-14)


def test_multi_engine_accepts_prestacked(rng):
    """A stacked state (e.g. another engine's .state) builds directly."""
    states = _fleet(rng)
    stacked = stack_states(states)
    eng = MultiPredictEngine(stacked, block_size=8)
    assert eng.n_models == 3
    xs = jnp.asarray(rng.standard_normal((5, 2)))
    ref = MultiPredictEngine(states, block_size=8).predict(xs)
    out = eng.predict(xs)
    for a, b in zip(ref, out):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_mixture_moments_algebra(rng):
    """Equal-weight mixture: mean of means; mean var + spread of means."""
    states = _fleet(rng)
    eng = MultiPredictEngine(states, block_size=8)
    xs = jnp.asarray(rng.standard_normal((7, 2)))
    mean, var = eng.predict(xs)
    mu, v = mixture_moments(mean, var)
    assert mu.shape == (7, 2) and v.shape == (7, 2)
    np.testing.assert_allclose(np.asarray(mu),
                               np.asarray(mean).mean(0), rtol=1e-12)
    manual = (np.maximum(np.asarray(var), 0.0).mean(0)[:, None]
              + np.asarray(mean).var(axis=0))
    np.testing.assert_allclose(np.asarray(v), manual, rtol=1e-12)
    mu2, v2 = eng.predict_mixture(xs)
    np.testing.assert_array_equal(np.asarray(mu), np.asarray(mu2))
    np.testing.assert_array_equal(np.asarray(v), np.asarray(v2))
    # Mixture variance can only exceed the mean within-model variance.
    assert (np.asarray(v) >= np.asarray(var).mean(0)[:, None] - 1e-12).all()


def test_multi_engine_quantized_fleet(rng):
    """A bf16-stacked fleet serves through f32 accumulation and stays near
    the f64 fleet."""
    states = _fleet(rng)
    xs = jnp.asarray(rng.standard_normal((9, 2)))
    ref_mean, _ = MultiPredictEngine(states, block_size=8).predict(xs)
    q = stack_states(states).astype(jnp.bfloat16)
    eng = MultiPredictEngine(q, block_size=8)
    assert eng.compute_dtype == jnp.float32
    mean, var = eng.predict(xs)
    assert mean.dtype == jnp.float32
    assert float(jnp.max(jnp.abs(mean.astype(jnp.float64) - ref_mean))) < 0.5
    assert bool(jnp.isfinite(var).all())


def test_multi_engine_rejects_bad_inputs(rng):
    states = _fleet(rng)
    with pytest.raises(ValueError, match="at least one"):
        stack_states([])
    other = _fleet(rng, n_models=1, m=7)[0]    # different m
    with pytest.raises(ValueError, match="share leaf shapes"):
        stack_states([states[0], other])
    with pytest.raises(ValueError, match="XLA-only"):
        MultiPredictEngine(states, kernel_backend="pallas")
    with pytest.raises(ValueError, match="model axis"):
        MultiPredictEngine(states[0])          # unstacked single state
    with pytest.raises(ValueError, match="block_size"):
        MultiPredictEngine(states, block_size=0)


def test_stack_states_rejects_mismatched_trees(rng):
    """A mixed fleet fails loudly before the treedef error inside tree.map:
    dtype mismatch and kernel-spec mismatch each get a typed message."""
    states = _fleet(rng)
    quantized = states[1].astype(jnp.bfloat16)
    with pytest.raises(ValueError, match="shapes/dtypes"):
        stack_states([states[0], quantized])
    rekernel = dataclasses.replace(states[1], kernel=cov.Matern32())
    with pytest.raises(ValueError, match="kernel expression"):
        stack_states([states[0], rekernel])


def test_mixture_moments_clamps_negative_variance(rng):
    """Quantized states can round a within-model variance slightly
    negative; the mixture clamps it at 0 so the result stays a variance."""
    mean = jnp.asarray(rng.standard_normal((3, 5, 2)))
    var = jnp.asarray(rng.uniform(0.1, 1.0, (3, 5)))
    var = var.at[1, 2].set(-1e-4).at[2, 0].set(-0.5)
    mu, v = mixture_moments(mean, var)
    assert bool(jnp.isfinite(v).all()) and bool((v >= 0).all())
    np.testing.assert_allclose(np.asarray(mu), np.asarray(mean).mean(0),
                               rtol=1e-12)
    clamped = (np.maximum(np.asarray(var), 0.0).mean(0)[:, None]
               + np.asarray(mean).var(axis=0))
    np.testing.assert_allclose(np.asarray(v), clamped, rtol=1e-12)
    # the clamp floors the within-model term: v >= spread-of-means alone
    assert (np.asarray(v) >= np.asarray(mean).var(axis=0) - 1e-12).all()


def test_multi_engine_swap_state_and_slot(rng):
    """Fleet hot swap: swap_state replaces the whole fleet, swap_slot one
    model; outputs match freshly built engines, shapes are validated."""
    fleet_a = _fleet(rng)
    fleet_b = _fleet(rng)                        # same shapes, new posteriors
    eng = MultiPredictEngine(fleet_a, block_size=8)
    xs = jnp.asarray(rng.standard_normal((6, 2)))
    before = eng.predict(xs)

    eng.swap_state(fleet_b)                      # sequence form
    ref_b = MultiPredictEngine(fleet_b, block_size=8).predict(xs)
    np.testing.assert_array_equal(np.asarray(eng.predict(xs)[0]),
                                  np.asarray(ref_b[0]))

    eng.swap_state(stack_states(fleet_a))        # stacked form, back to A
    np.testing.assert_array_equal(np.asarray(eng.predict(xs)[0]),
                                  np.asarray(before[0]))

    eng.swap_slot(2, fleet_b[0])                 # one-model rollout
    mixed = [fleet_a[0], fleet_a[1], fleet_b[0]]
    ref_m = MultiPredictEngine(mixed, block_size=8).predict(xs)
    np.testing.assert_array_equal(np.asarray(eng.predict(xs)[0]),
                                  np.asarray(ref_m[0]))

    with pytest.raises(ValueError, match="out of range"):
        eng.swap_slot(3, fleet_b[0])
    wrong_m = _fleet(rng, n_models=1, m=7)[0]
    with pytest.raises(ValueError, match="per-model leaf shapes"):
        eng.swap_slot(0, wrong_m)
    with pytest.raises(ValueError, match="identical leaf shapes"):
        eng.swap_state(_fleet(rng, n_models=2))  # N=2 into an N=3 engine


def test_multi_engine_empty_batch_is_noop(rng):
    """t=0 through the fleet: (N, 0, d)/(N, 0), not a reshape error."""
    eng = MultiPredictEngine(_fleet(rng), block_size=8)
    mean, var = eng.predict(jnp.zeros((0, 2)))
    assert mean.shape == (3, 0, 2) and var.shape == (3, 0)
    assert mean.dtype == eng.compute_dtype
