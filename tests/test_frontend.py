"""The async micro-batching front-end: coalescing, SLOs, hot swap.

The core contract under test: predictions are row-local, so every response
the front-end scatters out of a coalesced batch is BITWISE what a direct
``engine.predict`` call returns for that request — regardless of batch
composition, padding, or a hot swap racing the flush (each response then
matches the state of the generation it carries).  Failure modes are typed
(`QueueFull`, `SLOExceeded`), never silent.

All tests drive the event loop through ``asyncio.run`` (no asyncio pytest
plugin in the image) and keep deadlines coarse enough for a loaded CI box.
"""
import asyncio

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.stats import partial_stats
from repro.serve import (Frontend, FrontendError, MultiPredictEngine,
                         PredictEngine, QueueFull, SLOExceeded, extract_state,
                         save_state, stack_states)


def _hyp(rng, q, shift=0.0):
    return {"log_sf2": jnp.asarray(rng.uniform(-0.5, 0.8) + shift),
            "log_ell": jnp.asarray(rng.uniform(-0.4, 0.4, q)),
            "log_beta": jnp.asarray(1.2)}


def _state(rng, n=80, m=11, q=2, d=3, shift=0.0):
    hyp = _hyp(rng, q, shift)
    x = jnp.asarray(rng.standard_normal((n, q)))
    y = jnp.asarray(rng.standard_normal((n, d)))
    z = jnp.asarray(rng.standard_normal((m, q)))
    stats = partial_stats(hyp, z, y, x, s=None, latent=False)
    return extract_state(hyp, z, stats)


def _engine(rng, block=8, **kw):
    return PredictEngine(_state(rng, **kw), block_size=block)


def test_frontend_bitwise_parity_concurrent(rng):
    """Mixed-size concurrent requests coalesce, and every response is
    bitwise the direct engine answer for its rows (noise included)."""
    eng = _engine(rng)
    xs = [rng.standard_normal((t, 2)) for t in (1, 3, 8, 5, 2, 13, 7)]

    async def main():
        async with Frontend(eng, max_wait_ms=30.0, max_batch_rows=64) as fe:
            fe.warmup()
            return await asyncio.gather(*[
                fe.submit(x, include_noise=(i % 2 == 0))
                for i, x in enumerate(xs)])

    results = asyncio.run(main())
    for i, (x, res) in enumerate(zip(xs, results)):
        m_ref, v_ref = eng.predict(x, include_noise=(i % 2 == 0))
        assert res.generation == 0
        assert res.mean.shape == (x.shape[0], 3)
        np.testing.assert_array_equal(res.mean, np.asarray(m_ref))
        np.testing.assert_array_equal(res.var, np.asarray(v_ref))


def test_frontend_coalesces_and_accounts(rng):
    """Concurrent submits land in far fewer flushes than requests, and the
    row/pad accounting in the metrics adds up exactly."""
    eng = _engine(rng)
    xs = [rng.standard_normal((3, 2)) for _ in range(12)]

    async def main():
        async with Frontend(eng, max_wait_ms=50.0, max_batch_rows=64) as fe:
            fe.warmup()
            await asyncio.gather(*[fe.submit(x) for x in xs])
            return fe.metrics.summary()

    summ = asyncio.run(main())
    c = summ["counters"]
    assert c["flushes"] < len(xs)                      # actually coalesced
    assert summ["mean_batch_requests"] > 1.0
    assert c["flushed_requests"] == len(xs)
    assert c["flushed_rows"] == 3 * len(xs)
    assert (c["flushed_rows"] + c["padded_rows"]) % 8 == 0   # staged in blocks
    assert c["completed"] == len(xs) and c["expired"] == 0


def test_frontend_deadline_expires_as_slo_exceeded(rng):
    """A deadline shorter than the batching wait fails fast and typed —
    never a silent drop — and is counted as expired."""
    eng = _engine(rng)

    async def main():
        async with Frontend(eng, max_wait_ms=120.0, max_batch_rows=800) as fe:
            fe.warmup()
            with pytest.raises(SLOExceeded, match="deadline expired"):
                await fe.submit(rng.standard_normal((4, 2)), deadline_ms=1.0)
            return fe.metrics.summary()["counters"]

    c = asyncio.run(main())
    assert c["expired"] == 1 and c["completed"] == 0


def test_frontend_queue_full_backpressure(rng):
    """Admission control: rows beyond max_queue_rows are rejected with
    QueueFull at submit time and never enqueued."""
    eng = _engine(rng)

    async def main():
        async with Frontend(eng, max_wait_ms=200.0, max_batch_rows=800,
                            max_queue_rows=16) as fe:
            fe.warmup()
            t1 = asyncio.ensure_future(fe.submit(rng.standard_normal((8, 2))))
            t2 = asyncio.ensure_future(fe.submit(rng.standard_normal((8, 2))))
            await asyncio.sleep(0)                   # let them enqueue
            assert fe.queued_rows == 16
            with pytest.raises(QueueFull, match="16 of 16"):
                await fe.submit(rng.standard_normal((1, 2)))
            counters = fe.metrics.summary()["counters"]
            r1, r2 = await asyncio.gather(t1, t2)    # drained on stop
            return counters, r1, r2

    counters, r1, r2 = asyncio.run(main())
    assert counters["rejected_queue_full"] == 1
    assert r1.mean.shape == (8, 3) and r2.mean.shape == (8, 3)


def test_frontend_empty_request_inline(rng):
    """A zero-row request is answered inline with empty, correctly shaped
    arrays (it never occupies queue or engine time)."""
    eng = _engine(rng)

    async def main():
        async with Frontend(eng) as fe:
            res = await fe.submit(np.zeros((0, 2)))
            return res, fe.metrics.summary()["counters"]

    res, c = asyncio.run(main())
    assert res.mean.shape == (0, 3) and res.var.shape == (0,)
    assert res.generation == 0
    assert c["flushes"] == 0 and c["submitted"] == 0


def test_frontend_hot_swap_mid_load_bitwise(rng):
    """swap_state mid-load: zero dropped responses, and every response is
    bitwise correct against the state of the generation it carries."""
    state_a = _state(rng)
    state_b = _state(rng, shift=0.3)
    eng = PredictEngine(state_a, block_size=8)
    states = {0: state_a}
    xs = [rng.standard_normal((3, 2)) for _ in range(40)]

    async def main():
        async with Frontend(eng, max_wait_ms=1.0, max_batch_rows=16) as fe:
            fe.warmup()

            async def load():
                out = []
                for x in xs:
                    out.append(await fe.submit(x))
                return out

            async def swapper():
                flip = [state_b, state_a]
                for k in range(4):
                    await asyncio.sleep(0.01)
                    gen = fe.swap_state(flip[k % 2])
                    states[gen] = flip[k % 2]

            results, _ = await asyncio.gather(load(), swapper())
            return results

    results = asyncio.run(main())
    assert len(results) == len(xs)                   # zero dropped
    seen_gens = {r.generation for r in results}
    ref = {g: PredictEngine(s, block_size=8) for g, s in states.items()}
    for x, res in zip(xs, results):
        m_ref, v_ref = ref[res.generation].predict(x)
        np.testing.assert_array_equal(res.mean, np.asarray(m_ref))
        np.testing.assert_array_equal(res.var, np.asarray(v_ref))
    assert len(seen_gens) > 1                        # the swap actually hit


def test_frontend_swap_from_checkpoint_path(rng, tmp_path):
    """swap_state accepts a checkpoint path: the dtype-tagged sidecar
    restores the state with no model code on the serving host."""
    state_a = _state(rng)
    state_b = _state(rng, shift=0.5)
    path = save_state(tmp_path / "swap_in", state_b)
    eng = PredictEngine(state_a, block_size=8)
    x = rng.standard_normal((5, 2))

    async def main():
        async with Frontend(eng) as fe:
            before = await fe.submit(x)
            gen = fe.swap_state(path)
            after = await fe.submit(x)
            return before, gen, after

    before, gen, after = asyncio.run(main())
    assert (before.generation, after.generation) == (0, 1) and gen == 1
    np.testing.assert_array_equal(
        before.mean, np.asarray(PredictEngine(state_a, 8).predict(x)[0]))
    np.testing.assert_array_equal(
        after.mean, np.asarray(PredictEngine(state_b, 8).predict(x)[0]))
    assert not np.array_equal(before.mean, after.mean)


def test_frontend_stop_drains_and_restarts(rng):
    """stop() answers everything already accepted, rejects new submits
    while draining, and start() brings the loop back."""
    eng = _engine(rng)

    async def main():
        fe = Frontend(eng, max_wait_ms=500.0, max_batch_rows=800).start()
        fe.warmup()
        tasks = [asyncio.ensure_future(fe.submit(rng.standard_normal((2, 2))))
                 for _ in range(5)]
        await asyncio.sleep(0)
        await fe.stop()                              # flushes the 5 waiting
        results = await asyncio.gather(*tasks)
        with pytest.raises(FrontendError, match="not running"):
            await fe.submit(rng.standard_normal((2, 2)))
        fe.start()
        again = await fe.submit(rng.standard_normal((2, 2)))
        await fe.stop()
        return results, again

    results, again = asyncio.run(main())
    assert all(r.mean.shape == (2, 3) for r in results)
    assert again.mean.shape == (2, 3)


def test_frontend_steptimer_wiring(rng):
    """Per-flush engine wall times feed the StepTimer: one record per
    flush, and load_summary() is the training loop's min/mean/max shape."""
    eng = _engine(rng)

    async def main():
        async with Frontend(eng, max_wait_ms=20.0) as fe:
            fe.warmup()
            for _ in range(3):
                await fe.submit(rng.standard_normal((4, 2)))
            return fe.metrics.summary()["counters"], fe.load_summary()

    counters, load = asyncio.run(main())
    assert set(load) >= {"min", "mean", "max", "straggler_overhead"}
    assert 0.0 < load["min"] <= load["mean"] <= load["max"]
    assert counters["flushes"] == 3                  # sequential → one each


def test_frontend_multi_engine_and_slot_swap(rng):
    """A MultiPredictEngine front-end serves (N, t, d) responses bitwise,
    and swap_state(state, slot=k) replaces one model mid-fleet."""
    fleet = [_state(rng, shift=0.1 * k) for k in range(3)]
    newcomer = _state(rng, shift=0.9)
    eng = MultiPredictEngine(stack_states(fleet), block_size=8)
    x = rng.standard_normal((6, 2))

    async def main():
        async with Frontend(eng) as fe:
            before = await fe.submit(x)
            gen = fe.swap_state(newcomer, slot=1)
            after = await fe.submit(x)
            return before, gen, after

    before, gen, after = asyncio.run(main())
    assert before.mean.shape == (3, 6, 3) and before.var.shape == (3, 6)
    ref0 = MultiPredictEngine(stack_states(fleet), block_size=8).predict(x)
    np.testing.assert_array_equal(before.mean, np.asarray(ref0[0]))
    swapped = [fleet[0], newcomer, fleet[2]]
    ref1 = MultiPredictEngine(stack_states(swapped), block_size=8).predict(x)
    np.testing.assert_array_equal(after.mean, np.asarray(ref1[0]))
    assert gen == 1 and after.generation == 1
    # slots 0 and 2 are untouched by the slot swap
    np.testing.assert_array_equal(before.mean[0], after.mean[0])
    assert not np.array_equal(before.mean[1], after.mean[1])


def test_frontend_validation(rng):
    eng = _engine(rng)
    with pytest.raises(ValueError, match="max_wait_ms"):
        Frontend(eng, max_wait_ms=-1.0)
    with pytest.raises(ValueError, match="max_queue_rows"):
        Frontend(eng, max_queue_rows=0)
    with pytest.raises(ValueError, match="max_batch_requests"):
        Frontend(eng, max_batch_requests=0)
    with pytest.raises(ValueError, match="max_batch_rows"):
        Frontend(eng, max_batch_rows=0)
    # max_batch_rows rounds UP to the engine's padding multiple
    assert Frontend(eng, max_batch_rows=9).max_batch_rows == 16

    async def main():
        fe = Frontend(eng)
        with pytest.raises(FrontendError, match="not running"):
            await fe.submit(rng.standard_normal((2, 2)))
        fe.start()
        with pytest.raises(ValueError, match=r"\(t, 2\)"):
            await fe.submit(rng.standard_normal((2, 5)))
        with pytest.raises(ValueError, match="slot"):
            fe.swap_state(_state(rng), slot=0)       # single-model engine
        await fe.stop()

    asyncio.run(main())


def test_frontend_warmup_covers_all_shapes(rng):
    """warmup() compiles one program per padded batch size the dispatch
    loop can produce (max_batch_rows / padding-multiple shapes)."""
    eng = _engine(rng)
    fe = Frontend(eng, max_batch_rows=32)            # block 8 → 4 shapes
    assert fe.warmup() == 4
