"""Analytic parameter counts (roofline model) vs abstract init (eval_shape),
and sanity vs the published model sizes."""
import jax
import pytest

from repro.configs import all_configs, load_all
from repro.launch.roofline import _active_params
from repro.models import transformer as tf

load_all()

# published total-parameter ballparks (name -> (min, max) in billions)
PUBLISHED = {
    "qwen2-1.5b": (1.2, 2.0),
    "llama3.2-1b": (1.0, 1.6),
    "starcoder2-3b": (2.5, 3.5),
    "codeqwen1.5-7b": (6.0, 8.5),   # untied 92k vocab adds ~0.76B over "7B"
    "whisper-medium": (0.6, 1.1),        # enc+dec+cross
    "deepseek-v2-236b": (200.0, 250.0),
    "qwen3-moe-235b-a22b": (200.0, 260.0),
    "chameleon-34b": (30.0, 38.0),
    "recurrentgemma-9b": (7.5, 11.0),
    "mamba2-370m": (0.3, 0.45),
}

ACTIVE = {  # active-params ballparks for the MoE archs
    "deepseek-v2-236b": (18.0, 25.0),
    "qwen3-moe-235b-a22b": (18.0, 26.0),
}


@pytest.mark.parametrize("arch", sorted(all_configs()))
def test_analytic_matches_abstract_init(arch):
    cfg = all_configs()[arch]
    shapes = jax.eval_shape(
        lambda: tf.init_params(cfg, jax.random.PRNGKey(0))[0])
    actual = sum(s.size for s in jax.tree.leaves(shapes))
    analytic, _ = _active_params(cfg)
    # analytic model ignores norm scales/biases (< 0.1% of any arch)
    assert abs(actual - analytic) / actual < 0.02, (
        f"{arch}: init={actual / 1e9:.3f}B analytic={analytic / 1e9:.3f}B")


@pytest.mark.parametrize("arch", sorted(PUBLISHED))
def test_total_params_match_published(arch):
    cfg = all_configs()[arch]
    total, active = _active_params(cfg)
    lo, hi = PUBLISHED[arch]
    assert lo <= total / 1e9 <= hi, f"{arch}: {total / 1e9:.2f}B"
    if arch in ACTIVE:
        lo, hi = ACTIVE[arch]
        assert lo <= active / 1e9 <= hi, f"{arch} active: {active / 1e9:.2f}B"
    else:
        assert active == total
