"""SCG optimiser (Moller 1993) sanity: quadratics, Rosenbrock, GP hypers."""
import numpy as np

from repro.core.scg import scg


def test_quadratic_exact():
    rng = np.random.default_rng(0)
    a = rng.standard_normal((6, 6))
    A = a @ a.T + 6 * np.eye(6)
    b = rng.standard_normal(6)

    def fg(x):
        return 0.5 * x @ A @ x - b @ x, A @ x - b

    res = scg(fg, np.zeros(6), max_iters=200)
    xstar = np.linalg.solve(A, b)
    np.testing.assert_allclose(res.x, xstar, rtol=1e-5, atol=1e-6)
    assert res.converged


def test_rosenbrock():
    def fg(x):
        f = 100.0 * (x[1] - x[0] ** 2) ** 2 + (1 - x[0]) ** 2
        g = np.array([
            -400.0 * x[0] * (x[1] - x[0] ** 2) - 2 * (1 - x[0]),
            200.0 * (x[1] - x[0] ** 2),
        ])
        return f, g

    res = scg(fg, np.array([-1.2, 1.0]), max_iters=2000)
    np.testing.assert_allclose(res.x, [1.0, 1.0], atol=2e-3)


def test_monotone_history():
    """SCG only accepts improving steps -> recorded objective is monotone."""
    rng = np.random.default_rng(1)
    A = np.diag(rng.uniform(0.5, 50.0, 10))

    def fg(x):
        return 0.5 * x @ A @ x, A @ x

    res = scg(fg, rng.standard_normal(10), max_iters=100)
    h = np.asarray(res.history)
    assert (np.diff(h) <= 1e-12).all()
