"""Rank-k Cholesky update/downdate (core.chol_update) and the guarded
incremental serve-refresh built on it (serve.online).

Three contracts:
  1. Numerics — rank-k update/downdate matches direct refactorisation of
     ``L Lᵀ ± V Vᵀ`` at f64, and the full serve refresh matches
     ``extract_state`` over the union/remainder.
  2. Guard — indefinite or ill-conditioned downdates set ``ok=False`` at
     the chol level and take the reported (not raised) refactorisation
     fallback at the serve level.
  3. Cost shape — the happy-path refresh never calls ``cholesky`` on the
     full m×m system: ``core.chol_update`` contains no cholesky at all
     (source-asserted) and the only runtime call is the k×k Woodbury
     capacitance (trace-asserted via monkeypatch).
"""
import inspect

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import chol_update
from repro.core.chol_update import chol_downdate_rank_k, chol_update_rank_k
from repro.core.stats import fold_stats, partial_stats
from repro.serve import (downdate_state, extract_state, predict_mean_var,
                         update_state)


def _spd_chol(rng, m, scale=1.0):
    a = rng.standard_normal((m, m))
    A = a @ a.T + m * np.eye(m)
    return jnp.asarray(np.linalg.cholesky(scale * A))


def _state_and_data(seed=0, n=40, m=9, q=2, d=2):
    rng = np.random.default_rng(seed)
    hyp = {"log_sf2": jnp.asarray(0.3), "log_ell": jnp.zeros(q),
           "log_beta": jnp.asarray(1.2)}
    x = jnp.asarray(rng.standard_normal((n, q)))
    y = jnp.asarray(rng.standard_normal((n, d)))
    z = jnp.asarray(rng.standard_normal((m, q)))
    st = partial_stats(hyp, z, y, x, s=None, latent=False)
    return extract_state(hyp, z, st), hyp, z, x, y, rng


def _assert_states_close(got, ref, rtol=1e-8, atol=1e-9):
    for name in ("chol_sigma", "c2", "a_mean", "g"):
        np.testing.assert_allclose(
            np.asarray(getattr(got, name)), np.asarray(getattr(ref, name)),
            rtol=rtol, atol=atol, err_msg=name)


# ---------------------------------------------------------------------------
# core.chol_update numerics
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,k", [(3, 1), (7, 2), (12, 5), (9, 9)])
def test_rank_k_update_matches_refactorization(m, k):
    rng = np.random.default_rng(m * 31 + k)
    L = _spd_chol(rng, m)
    V = jnp.asarray(rng.standard_normal((m, k)))
    Lu, ok = chol_update_rank_k(L, V)
    assert bool(ok)
    direct = np.linalg.cholesky(np.asarray(L @ L.T + V @ V.T))
    np.testing.assert_allclose(np.asarray(Lu), direct, rtol=1e-12, atol=1e-13)
    # factor is genuinely lower-triangular with positive diagonal
    assert np.allclose(np.triu(np.asarray(Lu), 1), 0.0)
    assert (np.diag(np.asarray(Lu)) > 0).all()


@pytest.mark.parametrize("m,k", [(5, 1), (9, 3), (12, 4)])
def test_rank_k_downdate_matches_refactorization(m, k):
    """Downdating columns that were previously added is PD by construction."""
    rng = np.random.default_rng(m * 17 + k)
    L0 = _spd_chol(rng, m)
    V = jnp.asarray(rng.standard_normal((m, k)))
    Lup, _ = chol_update_rank_k(L0, V)
    Ldn, ok = chol_downdate_rank_k(Lup, V)
    assert bool(ok)
    np.testing.assert_allclose(np.asarray(Ldn), np.asarray(L0),
                               rtol=1e-11, atol=1e-12)
    direct = np.linalg.cholesky(np.asarray(Lup @ Lup.T - V @ V.T))
    np.testing.assert_allclose(np.asarray(Ldn), direct, rtol=1e-10, atol=1e-11)


def test_vector_v_promoted_to_rank_1():
    rng = np.random.default_rng(3)
    L = _spd_chol(rng, 6)
    v = jnp.asarray(rng.standard_normal(6))
    L1, ok1 = chol_update_rank_k(L, v)
    L2, ok2 = chol_update_rank_k(L, v[:, None])
    assert bool(ok1) and bool(ok2)
    np.testing.assert_array_equal(np.asarray(L1), np.asarray(L2))


def test_zero_columns_are_exact_noops():
    """Zero-weight padding rows become zero V columns — bit-identical L."""
    rng = np.random.default_rng(4)
    L = _spd_chol(rng, 8)
    V = jnp.zeros((8, 3))
    for f in (chol_update_rank_k, chol_downdate_rank_k):
        Lz, ok = f(L, V)
        assert bool(ok)
        np.testing.assert_array_equal(np.asarray(Lz), np.asarray(L))


def test_indefinite_downdate_flags_not_raises():
    """Removing mass that was never added → A − VVᵀ indefinite → ok=False
    and NO exception (the flag, not an error, is the contract; the factor
    is a clamped artefact the caller must discard)."""
    rng = np.random.default_rng(5)
    L = _spd_chol(rng, 6)
    V = jnp.asarray(10.0 * rng.standard_normal((6, 2)))
    Ld, ok = chol_downdate_rank_k(L, V)
    assert not bool(ok)
    assert Ld.shape == L.shape


def test_ill_conditioned_downdate_trips_relative_guard():
    """A *legitimate* (PD) downdate whose pivot collapses below cond_tol of
    its old magnitude is flagged even though direct refactorisation would
    succeed — the guard is a condition-number guard, not just a PD check."""
    L = jnp.eye(2)
    x = jnp.asarray([np.sqrt(1.0 - 1e-10), 0.0])
    # direct factorisation of I − xxᵀ = diag(1e-10, 1) is fine...
    direct = np.linalg.cholesky(np.asarray(L @ L.T) - np.outer(x, x))
    assert np.isfinite(direct).all()
    # ...but the incremental pivot ratio r²/d² = 1e-10 < cond_tol = 1e-8.
    _, ok = chol_downdate_rank_k(L, x, cond_tol=1e-8)
    assert not bool(ok)
    # with a looser tolerance the same downdate passes
    Ld, ok2 = chol_downdate_rank_k(L, x, cond_tol=1e-12)
    assert bool(ok2)
    np.testing.assert_allclose(np.asarray(Ld), direct, rtol=1e-6, atol=1e-12)


def test_update_never_trips_guard():
    rng = np.random.default_rng(6)
    L = _spd_chol(rng, 5, scale=1e-6)           # tiny base
    V = jnp.asarray(1e3 * rng.standard_normal((5, 4)))  # huge update
    _, ok = chol_update_rank_k(L, V)
    assert bool(ok)


# ---------------------------------------------------------------------------
# serve.online: refresh parity + guarded fallback
# ---------------------------------------------------------------------------

def test_update_state_matches_union_extract():
    state, hyp, z, x, y, rng = _state_and_data()
    xb = jnp.asarray(rng.standard_normal((7, x.shape[1])))
    yb = jnp.asarray(rng.standard_normal((7, y.shape[1])))
    res = update_state(state, xb, yb)
    assert res.fallback is False
    st_union = fold_stats(partial_stats(hyp, z, y, x, s=None, latent=False),
                          partial_stats(hyp, z, yb, xb, s=None, latent=False))
    ref = extract_state(hyp, z, st_union)
    _assert_states_close(res.state, ref)
    xs = jnp.asarray(rng.standard_normal((11, x.shape[1])))
    mg, vg = predict_mean_var(res.state, xs)
    mr, vr = predict_mean_var(ref, xs)
    np.testing.assert_allclose(np.asarray(mg), np.asarray(mr),
                               rtol=1e-9, atol=1e-10)
    np.testing.assert_allclose(np.asarray(vg), np.asarray(vr),
                               rtol=1e-8, atol=1e-10)


def test_downdate_after_update_is_identity():
    state, _, _, x, y, rng = _state_and_data(seed=1)
    xb = jnp.asarray(rng.standard_normal((5, x.shape[1])))
    yb = jnp.asarray(rng.standard_normal((5, y.shape[1])))
    up = update_state(state, xb, yb)
    back = downdate_state(up.state, xb, yb)
    assert up.fallback is False and back.fallback is False
    _assert_states_close(back.state, state, rtol=1e-11, atol=1e-12)


def test_padded_block_refreshes_like_unpadded():
    """Zero-weight rows (padding) must not move the state at all relative
    to the unpadded block — the V columns they produce are exact no-ops."""
    state, _, _, x, y, rng = _state_and_data(seed=2)
    q, d = x.shape[1], y.shape[1]
    xb = jnp.asarray(rng.standard_normal((4, q)))
    yb = jnp.asarray(rng.standard_normal((4, d)))
    pad_x = jnp.concatenate([xb, jnp.asarray(rng.standard_normal((3, q)))])
    pad_y = jnp.concatenate([yb, jnp.asarray(rng.standard_normal((3, d)))])
    w = jnp.asarray([1.0] * 4 + [0.0] * 3)
    res_pad = update_state(state, pad_x, pad_y, weights=w)
    res = update_state(state, xb, yb)
    assert res_pad.fallback is False
    _assert_states_close(res_pad.state, res.state, rtol=1e-12, atol=1e-14)


def test_illegitimate_forget_takes_guarded_fallback():
    """Forgetting a block that was never folded (scaled up so B − VVᵀ goes
    indefinite) must take the fallback — reported via the flag, never
    raised.  The target system is not PD, so no method can produce a valid
    state; ``fallback=True`` is the telemetry signal that this removal was
    not a legitimate incremental downdate."""
    state, _, _, x, y, rng = _state_and_data(seed=3, n=20)
    xb = jnp.asarray(rng.standard_normal((15, x.shape[1])))
    yb = jnp.asarray(5.0 * rng.standard_normal((15, y.shape[1])))
    res = downdate_state(state, xb, yb, weights=50.0 * jnp.ones(15))
    assert res.fallback is True
    assert res.state.chol_sigma.shape == state.chol_sigma.shape


def test_legitimate_but_ill_conditioned_forget_falls_back_to_exact():
    """A forget that is mathematically valid but trips the pivot guard must
    come back via refactorisation with the EXACT answer (remainder
    extract), so callers never trade correctness for the fast path."""
    state, hyp, z, x, y, _ = _state_and_data(seed=4, n=30)
    # forget almost everything: the survivor state is legitimate but the
    # downdate removes nearly all information → tiny pivot ratios.
    xb, yb = x[2:], y[2:]
    res = downdate_state(state, xb, yb)
    ref = extract_state(hyp, z,
                        partial_stats(hyp, z, y[:2], x[:2], s=None,
                                      latent=False))
    _assert_states_close(res.state, ref, rtol=1e-6, atol=1e-8)


def test_refresh_rejects_quantized_state_and_bad_sign():
    from repro.serve.online import refresh_state

    state, _, _, x, y, rng = _state_and_data(seed=5, n=15)
    xb = jnp.asarray(rng.standard_normal((2, x.shape[1])))
    yb = jnp.asarray(rng.standard_normal((2, y.shape[1])))
    with pytest.raises(ValueError, match="sub-f32"):
        update_state(state.astype(jnp.bfloat16), xb, yb)
    with pytest.raises(ValueError, match="sign"):
        refresh_state(state, xb, yb, sign=2.0)


# ---------------------------------------------------------------------------
# cost shape: no m×m cholesky on the happy path
# ---------------------------------------------------------------------------

def test_chol_update_module_never_calls_cholesky():
    src = inspect.getsource(chol_update)
    assert "cholesky" not in src.replace("jnp.linalg.cholesky", "") or \
        "cholesky(" not in src
    assert "cholesky(" not in src


@pytest.mark.parametrize("direction", ["update", "downdate"])
def test_happy_path_refresh_never_factorizes_m_by_m(monkeypatch, direction):
    """Trace every ``jnp.linalg.cholesky`` call during a happy-path refresh:
    the only factorisation allowed is the k×k Woodbury capacitance.  An
    m×m call would mean the O(m²k) contract silently degraded to O(m³)."""
    state, _, _, x, y, rng = _state_and_data(seed=6)
    m = state.chol_sigma.shape[0]
    k = 3
    assert k != m
    xb = jnp.asarray(rng.standard_normal((k, x.shape[1])))
    yb = jnp.asarray(rng.standard_normal((k, y.shape[1])))
    if direction == "downdate":                     # fold first, then forget
        state = update_state(state, xb, yb).state

    calls: list[tuple] = []
    real = jnp.linalg.cholesky

    def spy(a, *args, **kwargs):
        calls.append(tuple(a.shape))
        return real(a, *args, **kwargs)

    monkeypatch.setattr(jnp.linalg, "cholesky", spy)
    res = (update_state if direction == "update"
           else downdate_state)(state, xb, yb)
    assert res.fallback is False
    assert calls == [(k, k)], \
        f"happy-path refresh factorised {calls}; only ({k}, {k}) allowed"


def test_fallback_path_is_the_only_m_by_m_factorization(monkeypatch):
    state, _, _, x, y, rng = _state_and_data(seed=7, n=20)
    m = state.chol_sigma.shape[0]
    xb = jnp.asarray(rng.standard_normal((15, x.shape[1])))
    yb = jnp.asarray(5.0 * rng.standard_normal((15, y.shape[1])))

    calls: list[tuple] = []
    real = jnp.linalg.cholesky

    def spy(a, *args, **kwargs):
        calls.append(tuple(a.shape))
        return real(a, *args, **kwargs)

    monkeypatch.setattr(jnp.linalg, "cholesky", spy)
    res = downdate_state(state, xb, yb, weights=50.0 * jnp.ones(15))
    assert res.fallback is True
    assert (m, m) in calls
