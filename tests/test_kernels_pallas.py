"""Pallas kernel sweeps (interpret mode on CPU) vs pure-jnp oracles."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import ops as fa_ops
from repro.kernels.flash_attention import ref as fa_ref
from repro.kernels.psi_stats import ops as ps_ops
from repro.kernels.psi_stats import ref as ps_ref


def _hyp(rng, q):
    return {"log_sf2": jnp.asarray(rng.uniform(-0.5, 0.8)),
            "log_ell": jnp.asarray(rng.uniform(-0.4, 0.4, q)),
            "log_beta": jnp.asarray(0.0)}


@pytest.mark.parametrize("n,m,q", [
    (64, 16, 2),     # tiny, exact tile fit after padding
    (100, 37, 3),    # nothing divides anything
    (257, 64, 10),   # q at paper-scale latent dim
    (32, 130, 1),    # m > block_m, q=1
])
def test_psi2_kernel_shapes(rng, n, m, q):
    hyp = _hyp(rng, q)
    z = jnp.asarray(rng.standard_normal((m, q)))
    mu = jnp.asarray(rng.standard_normal((n, q)))
    s = jnp.asarray(rng.uniform(0.05, 0.8, (n, q)))
    w = jnp.asarray((rng.uniform(size=n) > 0.1).astype(np.float64))
    out = ps_ops.psi2(hyp, z, mu, s, w, block_n=64, block_m=32)
    want = ps_ref.psi2_ref(hyp["log_sf2"], hyp["log_ell"], z, mu, s, w)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-4, atol=2e-5)


@pytest.mark.parametrize("n,m,q", [(64, 16, 2), (100, 37, 3), (130, 129, 5)])
def test_psi1_kernel_shapes(rng, n, m, q):
    hyp = _hyp(rng, q)
    z = jnp.asarray(rng.standard_normal((m, q)))
    mu = jnp.asarray(rng.standard_normal((n, q)))
    s = jnp.asarray(rng.uniform(0.0, 0.8, (n, q)))
    out = ps_ops.psi1(hyp, z, mu, s, block_n=64, block_m=64)
    want = ps_ref.psi1_ref(hyp["log_sf2"], hyp["log_ell"], z, mu, s)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-4, atol=2e-6)


def test_psi2_kernel_matches_core_engine_stats(rng):
    """Kernel is a drop-in for partial_stats' psi2_fn."""
    from repro.core.stats import partial_stats

    n, m, q, d = 90, 20, 2, 3
    hyp = _hyp(rng, q)
    z = jnp.asarray(rng.standard_normal((m, q)))
    mu = jnp.asarray(rng.standard_normal((n, q)))
    s = jnp.asarray(rng.uniform(0.05, 0.6, (n, q)))
    y = jnp.asarray(rng.standard_normal((n, d)))
    st_ref = partial_stats(hyp, z, y, mu, s=s, latent=True)
    st_k = partial_stats(hyp, z, y, mu, s=s, latent=True,
                         psi2_fn=ps_ops.psi2_fn_for_engine(64, 32))
    np.testing.assert_allclose(np.asarray(st_k.D), np.asarray(st_ref.D),
                               rtol=2e-4, atol=2e-5)


@pytest.mark.parametrize("b,h,hkv,t,s,dh,causal,dtype", [
    (2, 4, 2, 64, 64, 64, True, jnp.float32),
    (1, 8, 1, 70, 70, 64, True, jnp.float32),      # MQA, ragged t
    (1, 4, 4, 33, 90, 128, True, jnp.float32),     # cross t<s suffix align
    (2, 2, 2, 96, 48, 64, False, jnp.float32),     # non-causal, t>s
    (1, 4, 2, 64, 64, 64, True, jnp.bfloat16),     # bf16 path
    (1, 4, 4, 1, 57, 64, True, jnp.float32),       # decode-shaped (T=1)
])
def test_flash_attention_sweep(rng, b, h, hkv, t, s, dh, causal, dtype):
    q = jnp.asarray(rng.standard_normal((b, h, t, dh)), dtype)
    k = jnp.asarray(rng.standard_normal((b, hkv, s, dh)), dtype)
    v = jnp.asarray(rng.standard_normal((b, hkv, s, dh)), dtype)
    out = fa_ops.flash_attention(q, k, v, causal=causal,
                                 block_q=32, block_k=32)
    want = fa_ref.attention_ref(q, k, v, causal=causal)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_flash_attention_rows_with_no_context(rng):
    """Fully-masked rows (can happen with padding) return zeros, not NaN."""
    q = jnp.asarray(rng.standard_normal((1, 2, 8, 64)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 2, 8, 64)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, 2, 8, 64)), jnp.float32)
    out = fa_ops.flash_attention(q, k, v, causal=True, block_q=32, block_k=32)
    assert np.isfinite(np.asarray(out)).all()
