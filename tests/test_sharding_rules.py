"""Logical-axis sharding resolution (pure metadata, no devices needed)."""
from jax.sharding import PartitionSpec as P

from repro.distributed import sharding as sh


class FakeMesh:
    """Duck-typed mesh: spec_for only reads .shape (a dict)."""

    def __init__(self, **shape):
        self.shape = shape


MESH = FakeMesh(data=16, model=16)
POD = FakeMesh(pod=2, data=16, model=16)


def test_tp_and_fsdp_assignment():
    # MLP weight (d_model, d_ff): embed->data (fsdp), mlp->model (tp)
    spec = sh.spec_for(("embed", "mlp"), (1536, 8960), MESH, sh.DEFAULT_RULES)
    assert spec == P("data", "model")


def test_divisibility_fallback():
    # 12 heads of 128 dims under model=16 -> replicate (head quantum)
    spec = sh.spec_for(("embed", "heads:128"), (1536, 1536), MESH,
                       sh.DEFAULT_RULES)
    assert spec == P("data")          # trailing None trimmed
    # 32 heads shard fine
    spec = sh.spec_for(("embed", "heads:128"), (4096, 4096), MESH,
                       sh.DEFAULT_RULES)
    assert spec == P("data", "model")


def test_batch_uses_pod_then_data():
    spec = sh.spec_for(("batch", None), (256, 4096), POD, sh.DEFAULT_RULES)
    assert spec == P(("pod", "data"))
    # batch=1 (long_500k): nothing divides -> fully replicated
    spec = sh.spec_for(("batch", None), (1, 4096), POD, sh.DEFAULT_RULES)
    assert spec == P()


def test_axis_never_used_twice():
    # both dims want "model": second falls back
    spec = sh.spec_for(("mlp", "heads:64"), (1536 * 16, 64 * 16), MESH,
                       sh.DEFAULT_RULES)
    assert spec == P("model")         # second dim replicated


def test_cache_seq_prefers_model_then_data():
    # decode_32k: batch owns data; kv-cache seq goes to model
    used_batch = sh.spec_for(("batch", "seq_shard", "kv_heads", None),
                             (128, 32768, 2, 128), MESH, sh.DEFAULT_RULES)
    assert used_batch == P("data", "model")
    # long_500k B=1: batch replicated, seq takes model THEN data
    long = sh.spec_for(("batch", "seq_shard", "kv_heads", None),
                       (1, 2048, 1, 256), MESH, sh.DEFAULT_RULES)
    assert long == P(None, ("model", "data"))


def test_quantum_parsing():
    assert sh.spec_for(("kv_heads:128",), (4096,), MESH,
                       sh.DEFAULT_RULES) == P("model")
    assert sh.spec_for(("kv_heads:128",), (256,), MESH,
                       sh.DEFAULT_RULES) == P()


def test_constrain_identity_without_mesh():
    import jax.numpy as jnp
    sh.set_mesh(None)
    x = jnp.ones((4, 4))
    assert sh.constrain(x, ("batch", None)) is x
