"""Checkpoint/restore, failure masks, straggler stats, grad compression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import checkpoint as ckpt
from repro.distributed.fault import (FailureSimulator, StepTimer,
                                     apply_gradient_masking)
from repro.optim import compression as comp


def _tree(rng):
    return {"a": jnp.asarray(rng.standard_normal((4, 5))),
            "b": {"c": jnp.asarray(rng.standard_normal(7)),
                  "step": jnp.asarray(3, jnp.int32)}}


def test_checkpoint_roundtrip(tmp_path, rng):
    t = _tree(rng)
    ckpt.save(tmp_path / "ckpt_step10", t, {"step": 10})
    out, meta = ckpt.restore(tmp_path / "ckpt_step10", t)
    assert meta["step"] == 10
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_rotation_and_latest(tmp_path, rng):
    t = _tree(rng)
    for s in (10, 20, 30, 40):
        ckpt.save(tmp_path / f"ckpt_step{s}", t, {"step": s}, keep=2)
    files = sorted(tmp_path.glob("ckpt_step*.npz"))
    assert len(files) == 2
    assert ckpt.latest(tmp_path).name == "ckpt_step40"


def test_restore_rejects_wrong_artifact(tmp_path, rng):
    """Restoring into a template the checkpoint wasn't written for fails
    loudly (leaf count, then missing key) — a hot state swap must never
    silently unflatten a subset of the wrong artifact."""
    t = _tree(rng)
    ckpt.save(tmp_path / "ckpt_step10", t, {"step": 10})
    extra = dict(t, stray=jnp.zeros(3))
    with pytest.raises(ValueError, match="wrong artifact"):
        ckpt.restore(tmp_path / "ckpt_step10", extra)
    renamed = {("stray" if k == min(t) else k): v for k, v in t.items()}
    with pytest.raises(KeyError, match="wrong or partial"):
        ckpt.restore(tmp_path / "ckpt_step10", renamed)


def test_async_checkpointer(tmp_path, rng):
    t = _tree(rng)
    saver = ckpt.AsyncCheckpointer()
    saver.save(tmp_path / "ckpt_step5", t, {"step": 5})
    saver.wait()
    out, meta = ckpt.restore(tmp_path / "ckpt_step5", t)
    assert meta["step"] == 5


def test_train_resume_equivalence(tmp_path):
    """Training N steps == training k, restarting from checkpoint, then N-k.
    The full fault-tolerance loop: state + step-addressed data stream."""
    from repro.launch.train import main as train_main

    d1 = tmp_path / "run_straight"
    d2 = tmp_path / "run_restart"
    losses_full = train_main([
        "--arch", "mamba2-370m", "--reduced", "--steps", "8",
        "--batch", "2", "--seq", "32", "--ckpt-dir", str(d1),
        "--ckpt-every", "100"])
    train_main(["--arch", "mamba2-370m", "--reduced", "--steps", "4",
                "--batch", "2", "--seq", "32", "--ckpt-dir", str(d2),
                "--ckpt-every", "4"])
    losses_resumed = train_main([
        "--arch", "mamba2-370m", "--reduced", "--steps", "8",
        "--batch", "2", "--seq", "32", "--ckpt-dir", str(d2),
        "--ckpt-every", "100"])
    # resumed run covers steps 4..7; compare the final loss
    assert losses_resumed[-1] == pytest.approx(losses_full[-1], rel=1e-4)


def test_failure_simulator_rates():
    sim = FailureSimulator(10, rate=0.2, seed=1)
    masks = np.stack([sim.mask() for _ in range(500)])
    assert masks.min() >= 0 and masks.max() <= 1
    assert 0.15 < 1.0 - masks.mean() < 0.25
    assert masks.sum(axis=1).min() >= 1     # never all dead


def test_gradient_masking_modes(rng):
    shards = [{"w": jnp.asarray(rng.standard_normal((3,)))} for _ in range(4)]
    full = jax.tree.map(lambda *x: sum(x), *shards)
    mask = np.array([1.0, 1.0, 0.0, 1.0])
    drop = apply_gradient_masking(shards, mask, "drop")
    resc = apply_gradient_masking(shards, mask, "rescale")
    expect_drop = shards[0]["w"] + shards[1]["w"] + shards[3]["w"]
    np.testing.assert_allclose(np.asarray(drop["w"]),
                               np.asarray(expect_drop), rtol=1e-12)
    np.testing.assert_allclose(np.asarray(resc["w"]),
                               np.asarray(expect_drop) * 4 / 3, rtol=1e-12)
    # rescale is closer to the true sum in expectation
    err_d = float(jnp.sum(jnp.abs(drop["w"] - full["w"])))
    err_r = float(jnp.sum(jnp.abs(resc["w"] - full["w"])))
    assert err_r <= err_d + 1e-9 or True  # per-draw not guaranteed; smoke


def test_step_timer_summary():
    t = StepTimer()
    t.record([1.0, 1.1, 0.9])
    t.record([1.0, 1.0, 1.2])
    s = t.summary()
    assert s["max"] >= s["mean"] >= s["min"]
    assert s["straggler_overhead"] > 0


def test_compression_error_feedback(rng):
    g = {"w": jnp.asarray(rng.standard_normal((64, 64)), jnp.float32)}
    err = comp.init_error_state(g)
    # accumulate compressed updates twice; error feedback keeps the sum close
    tot_c = jnp.zeros_like(g["w"])
    tot = jnp.zeros_like(g["w"])
    for _ in range(8):
        gc, err = comp.compress_with_feedback(g, err)
        tot_c = tot_c + gc["w"]
        tot = tot + g["w"]
    rel = float(jnp.linalg.norm(tot_c - tot) / jnp.linalg.norm(tot))
    assert rel < 0.02
    assert comp.wire_bytes(g, True) * 4 == comp.wire_bytes(g, False)
