"""Streaming map-step engine: chunked ≡ monolithic statistics and bounds.

The chunked accumulator (stats.partial_stats_chunked) must reproduce
partial_stats exactly (same sums, different association order — float64
keeps them within ~1e-12), through jit and grad, on both the regression
and latent (GPLVM) paths, with weights and non-divisible block sizes.
Multi-device DistributedGP(chunk_size=...) parity lives in _dist_worker.py.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import BayesianGPLVM, SGPR
from repro.core.bound import collapsed_bound
from repro.core.distributed import DistributedGP, pad_and_shard
from repro.core.stats import partial_stats, partial_stats_chunked, zero_stats
from repro.launch.mesh import make_compat_mesh

from conftest import make_regression


def _mk_hyp(q):
    return {"log_sf2": jnp.asarray(0.2), "log_ell": jnp.full((q,), 0.1),
            "log_beta": jnp.asarray(1.0)}


def _assert_stats_close(a, b, rtol=1e-10, atol=1e-12):
    for name, x, y in zip(a._fields, a, b):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=rtol, atol=atol, err_msg=name)


@pytest.mark.parametrize("block", [1, 7, 16, 1000])
def test_chunked_equals_monolithic_regression(rng, block):
    n, m, q, d = 53, 6, 2, 3  # n deliberately not a multiple of any block
    x = rng.standard_normal((n, q)); y = rng.standard_normal((n, d))
    z = rng.standard_normal((m, q))
    hyp = _mk_hyp(q)
    full = partial_stats(hyp, jnp.asarray(z), jnp.asarray(y), jnp.asarray(x),
                         s=None, latent=False)
    ch = partial_stats_chunked(hyp, jnp.asarray(z), jnp.asarray(y),
                               jnp.asarray(x), s=None, latent=False,
                               block_size=block)
    _assert_stats_close(full, ch)


@pytest.mark.parametrize("block", [5, 32])
def test_chunked_equals_monolithic_latent_with_weights(rng, block):
    n, m, q, d = 41, 5, 3, 2
    x = rng.standard_normal((n, q)); y = rng.standard_normal((n, d))
    s = rng.uniform(0.05, 0.7, (n, q)); z = rng.standard_normal((m, q))
    w = np.ones(n); w[33:] = 0.0  # masked tail, as distributed padding does
    hyp = _mk_hyp(q)
    full = partial_stats(hyp, jnp.asarray(z), jnp.asarray(y), jnp.asarray(x),
                         s=jnp.asarray(s), weights=jnp.asarray(w), latent=True)
    ch = partial_stats_chunked(hyp, jnp.asarray(z), jnp.asarray(y),
                               jnp.asarray(x), s=jnp.asarray(s),
                               weights=jnp.asarray(w), latent=True,
                               block_size=block)
    _assert_stats_close(full, ch)


def test_chunked_bound_and_grad_parity(rng):
    """Bound + hyper/Z gradients through the scan match the monolithic path."""
    n, m, q, d = 60, 7, 2, 2
    x, y = make_regression(rng, n=n, q=q, d=d)
    s = rng.uniform(0.05, 0.5, (n, q)); z = rng.standard_normal((m, q))
    hyp = _mk_hyp(q)

    def neg(h, zz, chunked):
        stats_fn = (
            (lambda *a, **k: partial_stats_chunked(*a, block_size=13, **k))
            if chunked else partial_stats)
        st = stats_fn(h, zz, jnp.asarray(y), jnp.asarray(x),
                      s=jnp.asarray(s), latent=True)
        return -collapsed_bound(h, zz, st, d)

    v0, (gh0, gz0) = jax.value_and_grad(
        lambda h, zz: neg(h, zz, False), argnums=(0, 1))(hyp, jnp.asarray(z))
    v1, (gh1, gz1) = jax.jit(jax.value_and_grad(
        lambda h, zz: neg(h, zz, True), argnums=(0, 1)))(hyp, jnp.asarray(z))
    assert abs(float(v1) - float(v0)) < 1e-8 * abs(float(v0))
    np.testing.assert_allclose(np.asarray(gz1), np.asarray(gz0),
                               rtol=1e-8, atol=1e-10)
    for k in gh0:
        np.testing.assert_allclose(np.asarray(gh1[k]), np.asarray(gh0[k]),
                                   rtol=1e-8, atol=1e-10)


def test_chunked_psi2_fn_hook_per_block(rng):
    """A custom psi2 backend (the MXU jnp reformulation) plugs into each
    scan block and still reproduces the monolithic statistics."""
    from repro.core import gp_kernels as gpk

    n, m, q, d = 47, 6, 2, 2
    x = rng.standard_normal((n, q)); y = rng.standard_normal((n, d))
    s = rng.uniform(0.05, 0.5, (n, q)); z = rng.standard_normal((m, q))
    hyp = _mk_hyp(q)
    full = partial_stats(hyp, jnp.asarray(z), jnp.asarray(y), jnp.asarray(x),
                         s=jnp.asarray(s), latent=True)
    ch = partial_stats_chunked(
        hyp, jnp.asarray(z), jnp.asarray(y), jnp.asarray(x),
        s=jnp.asarray(s), latent=True,
        psi2_fn=lambda h, zz, mu, sv, w: gpk.psi2_mxu(h, zz, mu, sv, w,
                                                      chunk=8),
        block_size=16)
    _assert_stats_close(full, ch, rtol=1e-9, atol=1e-11)


def test_zero_stats_is_identity(rng):
    n, m, q, d = 9, 4, 2, 3
    x = rng.standard_normal((n, q)); y = rng.standard_normal((n, d))
    z = rng.standard_normal((m, q))
    st = partial_stats(_mk_hyp(q), jnp.asarray(z), jnp.asarray(y),
                       jnp.asarray(x), s=None, latent=False)
    _assert_stats_close(st, zero_stats(m, d) + st, rtol=0, atol=0)


def test_pad_and_shard_block_multiple():
    arrs = {"y": np.ones((101, 3)), "mu": np.zeros((101, 2)),
            "s": np.full((101, 2), 0.3)}
    out, w = pad_and_shard(arrs, n_shards=4, block=16)
    assert out["y"].shape[0] == 128  # next multiple of 4*16
    assert w.sum() == 101 and w.shape == (128,)
    assert (out["s"][101:] == 1.0).all()  # variance padding stays log-safe


@pytest.mark.parametrize("n", [0, 1, 5, 63])
def test_pad_and_shard_tiny_n_regression(n):
    """n < n_shards*block must still pad to one whole block per shard (a
    zero-row or ragged layout would break the fixed-shape scan), with the
    weights masking exactly the pad rows and unpad round-tripping."""
    from repro.core.distributed import unpad

    n_shards, block = 4, 16
    arrs = {"y": np.arange(3 * n, dtype=np.float64).reshape(n, 3),
            "mu": np.ones((n, 2))}
    out, w = pad_and_shard(arrs, n_shards=n_shards, block=block)
    assert out["y"].shape[0] == 64          # one full block per shard
    assert w.shape == (64,)
    np.testing.assert_array_equal(np.asarray(w),
                                  (np.arange(64) < n).astype(np.float64))
    back = unpad(out, n)
    np.testing.assert_array_equal(np.asarray(back["y"]), arrs["y"])
    np.testing.assert_array_equal(np.asarray(back["mu"]), arrs["mu"])
    # single-array form
    np.testing.assert_array_equal(np.asarray(unpad(out["y"], n)), arrs["y"])


def test_sgpr_gplvm_chunk_size_bound_parity(rng):
    x, y = make_regression(rng, n=70, q=2, d=2)
    mono = SGPR(x, y, num_inducing=10, seed=0)
    stream = SGPR(x, y, num_inducing=10, seed=0, chunk_size=16)
    np.testing.assert_allclose(stream.log_bound(), mono.log_bound(),
                               rtol=1e-10)
    mean0, _ = mono.predict(x[:5])
    mean1, _ = stream.predict(x[:5])
    np.testing.assert_allclose(mean1, mean0, rtol=1e-8, atol=1e-10)

    lv_mono = BayesianGPLVM(y, q=2, num_inducing=8, seed=1)
    lv_stream = BayesianGPLVM(y, q=2, num_inducing=8, seed=1, chunk_size=16)
    np.testing.assert_allclose(lv_stream.log_bound(), lv_mono.log_bound(),
                               rtol=1e-10)


def test_distributed_chunked_single_device_parity(rng):
    """chunk_size on a 1-device mesh == sequential bound (multi-device
    parity runs in the subprocess worker)."""
    mesh = make_compat_mesh((1,), ("data",))
    n, m, q, d = 37, 5, 2, 1
    x = rng.standard_normal((n, q)); y = rng.standard_normal((n, d))
    z = rng.standard_normal((m, q))
    hyp = _mk_hyp(q)
    eng = DistributedGP(mesh, data_axes=("data",), latent=False, chunk_size=8)
    data, w = eng.put_data(y=y, mu=x)
    assert data["y"].shape[0] == 40  # padded to a whole number of blocks
    vg = eng.make_value_and_grad(d)
    v, _ = vg(hyp, jnp.asarray(z), data["mu"], None, data["y"], w,
              jnp.ones((1,)), jnp.asarray(float(n)))
    st = partial_stats(hyp, jnp.asarray(z), jnp.asarray(y), jnp.asarray(x),
                       s=None, latent=False)
    ref = -collapsed_bound(hyp, jnp.asarray(z), st, d)
    assert abs(float(v) - float(ref)) < 1e-10 * max(1.0, abs(float(ref)))


def test_make_gp_train_step_smoke(rng):
    from repro.train.steps import make_gp_train_step

    mesh = make_compat_mesh((1,), ("data",))
    n, m, q, d = 24, 4, 2, 1
    x = rng.standard_normal((n, q)); y = rng.standard_normal((n, d))
    z = rng.standard_normal((m, q))
    eng, step = make_gp_train_step(mesh, d, chunk_size=8)
    data, w = eng.put_data(y=y, mu=x)
    v, (gh, gz) = step(_mk_hyp(q), jnp.asarray(z), data["mu"], None,
                       data["y"], w, jnp.ones((1,)), jnp.asarray(float(n)))
    assert np.isfinite(float(v))
    assert np.isfinite(np.asarray(gz)).all()
