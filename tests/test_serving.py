"""Posterior serving subsystem: state extraction, persistence, and the
batched block predict engine — all parity-tested against the canonical
``core.bound.predict`` to f64 precision.

The serving contract: ``extract_state`` runs every query-independent solve
once, ``PredictEngine`` answers padded fixed-size blocks through a jitted
``lax.scan``, and neither step may move mean/var away from the per-call
``optimal_qu`` + ``predict`` reference beyond float64 rounding.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import SGPR, BayesianGPLVM
from repro.core import bound as bound_mod
from repro.core.stats import partial_stats
from repro.kernels.predict import ops as p_ops
from repro.kernels.predict import ref as p_ref
from repro.serve import (PredictEngine, extract_state, load_state,
                         predict_full_cov, predict_mean_var, save_state,
                         state_from_model)

from conftest import make_regression


def _hyp(rng, q):
    return {"log_sf2": jnp.asarray(rng.uniform(-0.5, 0.8)),
            "log_ell": jnp.asarray(rng.uniform(-0.4, 0.4, q)),
            "log_beta": jnp.asarray(1.2)}


def _posterior(rng, n=90, m=13, q=2, d=3):
    hyp = _hyp(rng, q)
    x = jnp.asarray(rng.standard_normal((n, q)))
    y = jnp.asarray(rng.standard_normal((n, d)))
    z = jnp.asarray(rng.standard_normal((m, q)))
    stats = partial_stats(hyp, z, y, x, s=None, latent=False)
    return hyp, z, stats


def test_state_matches_optimal_qu_factors(rng):
    """The state's raw factors are exactly optimal_qu's (same solves)."""
    hyp, z, stats = _posterior(rng)
    state = extract_state(hyp, z, stats)
    qu = bound_mod.optimal_qu(hyp, z, stats)
    # extract_state is jitted (optimal_qu is not) — XLA fusion reorders a
    # few flops, so "exact" here is f64 rounding, not bitwise.
    np.testing.assert_allclose(np.asarray(state.chol_kmm), np.asarray(qu.L),
                               rtol=1e-11, atol=1e-13)
    np.testing.assert_allclose(np.asarray(state.chol_sigma), np.asarray(qu.LB),
                               rtol=1e-11, atol=1e-13)
    np.testing.assert_allclose(np.asarray(state.c2), np.asarray(qu.c2),
                               rtol=1e-12, atol=1e-14)
    assert (state.m, state.q, state.d) == (13, 2, 3)


def test_state_predict_parity(rng):
    """The precomputed-contraction math == the per-call solve math (f64)."""
    hyp, z, stats = _posterior(rng)
    state = extract_state(hyp, z, stats)
    qu = bound_mod.optimal_qu(hyp, z, stats)
    xs = jnp.asarray(rng.standard_normal((41, 2)))
    m_ref, v_ref = bound_mod.predict(hyp, z, qu, xs)
    mean, var = predict_mean_var(state, xs)
    np.testing.assert_allclose(np.asarray(mean), np.asarray(m_ref),
                               rtol=1e-9, atol=1e-11)
    np.testing.assert_allclose(np.asarray(var), np.asarray(v_ref),
                               rtol=1e-8, atol=1e-10)
    # full covariance mode
    m_rc, c_rc = bound_mod.predict(hyp, z, qu, xs, full_cov=True)
    mean_f, cov = predict_full_cov(state, xs)
    np.testing.assert_allclose(np.asarray(cov), np.asarray(c_rc),
                               rtol=1e-8, atol=1e-10)
    np.testing.assert_allclose(np.asarray(mean_f), np.asarray(m_ref),
                               rtol=1e-9, atol=1e-11)


@pytest.mark.parametrize("t,block", [
    (1, 8),      # single query, heavy padding
    (37, 8),     # odd count, several blocks + padded tail
    (64, 16),    # exact multiple — no padding branch
    (101, 64),   # pad nearly a whole block
])
def test_block_engine_parity_and_padding(rng, t, block):
    """Pad rows are ignored: the block engine matches bound.predict for odd
    query counts at every block size, diag var and noise variants."""
    hyp, z, stats = _posterior(rng)
    state = extract_state(hyp, z, stats)
    qu = bound_mod.optimal_qu(hyp, z, stats)
    xs = jnp.asarray(rng.standard_normal((t, 2)))
    eng = PredictEngine(state, block_size=block)
    for noise in (False, True):
        m_ref, v_ref = bound_mod.predict(hyp, z, qu, xs, include_noise=noise)
        mean, var = eng.predict(xs, include_noise=noise)
        assert mean.shape == (t, 3) and var.shape == (t,)
        np.testing.assert_allclose(np.asarray(mean), np.asarray(m_ref),
                                   rtol=1e-9, atol=1e-11)
        np.testing.assert_allclose(np.asarray(var), np.asarray(v_ref),
                                   rtol=1e-8, atol=1e-10)


def test_engine_full_cov_and_call(rng):
    hyp, z, stats = _posterior(rng)
    state = extract_state(hyp, z, stats)
    qu = bound_mod.optimal_qu(hyp, z, stats)
    xs = jnp.asarray(rng.standard_normal((9, 2)))
    eng = PredictEngine(state, block_size=4)
    m_ref, c_ref = bound_mod.predict(hyp, z, qu, xs, full_cov=True,
                                     include_noise=True)
    mean, cov = eng(xs, full_cov=True, include_noise=True)
    np.testing.assert_allclose(np.asarray(cov), np.asarray(c_ref),
                               rtol=1e-8, atol=1e-10)
    np.testing.assert_allclose(np.asarray(mean), np.asarray(m_ref),
                               rtol=1e-9, atol=1e-11)


def test_engine_rejects_bad_args(rng):
    hyp, z, stats = _posterior(rng)
    state = extract_state(hyp, z, stats)
    with pytest.raises(ValueError, match="kernel_backend"):
        PredictEngine(state, kernel_backend="cuda")
    with pytest.raises(ValueError, match="block_size"):
        PredictEngine(state, block_size=0)


def test_engine_empty_batch_is_noop(rng):
    """t=0 queries return empty, correctly typed arrays — a no-op, not a
    reshape error (regression: the block scan reshaped with -1, which
    cannot be inferred from a size-0 array)."""
    hyp, z, stats = _posterior(rng)
    state = extract_state(hyp, z, stats)
    eng = PredictEngine(state, block_size=8)
    xs = jnp.zeros((0, 2))
    for noise in (False, True):
        mean, var = eng.predict(xs, include_noise=noise)
        assert mean.shape == (0, 3) and var.shape == (0,)
        assert mean.dtype == eng.compute_dtype
        assert var.dtype == eng.compute_dtype


# -- fused Pallas predict kernel (interpret mode off-TPU) -------------------

@pytest.mark.parametrize("t,m,q,d", [
    (64, 16, 2, 1),     # exact tile fit after padding
    (100, 37, 3, 2),    # nothing divides anything
    (33, 130, 9, 5),    # m > block_m, q padded
])
def test_predict_kernel_vs_ref(rng, t, m, q, d):
    hyp = _hyp(rng, q)
    z = jnp.asarray(rng.standard_normal((m, q)))
    a_mean = jnp.asarray(rng.standard_normal((m, d)))
    g = rng.standard_normal((m, m))
    g = jnp.asarray(g + g.T)                       # symmetric like the real g
    x = jnp.asarray(rng.standard_normal((t, q)))
    mean, quad = p_ops.predict_stats(hyp, z, a_mean, g, x,
                                     block_t=32, block_m=16)
    m_ref, q_ref = p_ref.predict_ref(hyp["log_sf2"], hyp["log_ell"],
                                     z, a_mean, g, x)
    # Interpret mode runs the caller's f64 — machine-precision agreement.
    np.testing.assert_allclose(np.asarray(mean), np.asarray(m_ref),
                               rtol=1e-12, atol=1e-14)
    np.testing.assert_allclose(np.asarray(quad), np.asarray(q_ref),
                               rtol=1e-12, atol=1e-14)


def test_pallas_engine_parity(rng):
    """kernel_backend="pallas" block engine == bound.predict (interpret f64)."""
    hyp, z, stats = _posterior(rng)
    state = extract_state(hyp, z, stats)
    qu = bound_mod.optimal_qu(hyp, z, stats)
    xs = jnp.asarray(rng.standard_normal((53, 2)))
    eng = PredictEngine(state, block_size=16, kernel_backend="pallas")
    mean, var = eng.predict(xs, include_noise=True)
    m_ref, v_ref = bound_mod.predict(hyp, z, qu, xs, include_noise=True)
    np.testing.assert_allclose(np.asarray(mean), np.asarray(m_ref),
                               rtol=1e-9, atol=1e-11)
    np.testing.assert_allclose(np.asarray(var), np.asarray(v_ref),
                               rtol=1e-8, atol=1e-10)


# -- persistence ------------------------------------------------------------

def test_save_load_roundtrip(rng, tmp_path):
    """A server restarts from disk alone: the loaded state is leaf-for-leaf
    identical and predicts identically — no model, no training data."""
    hyp, z, stats = _posterior(rng)
    state = extract_state(hyp, z, stats)
    save_state(tmp_path / "pstate", state, metadata={"run": "test"})
    loaded, md = load_state(tmp_path / "pstate")
    assert md["run"] == "test" and md["m"] == state.m and md["d"] == state.d
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(loaded)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    xs = jnp.asarray(rng.standard_normal((17, 2)))
    m0, v0 = PredictEngine(state, block_size=8).predict(xs)
    m1, v1 = PredictEngine(loaded, block_size=8).predict(xs)
    np.testing.assert_array_equal(np.asarray(m0), np.asarray(m1))
    np.testing.assert_array_equal(np.asarray(v0), np.asarray(v1))
    # User metadata may not shadow the restore-template keys.
    with pytest.raises(ValueError, match="reserved"):
        save_state(tmp_path / "bad", state, metadata={"d": "note"})


# -- the model wrappers delegate (and cache) --------------------------------

def test_sgpr_predict_caches_and_invalidates(rng):
    x, y = make_regression(rng, n=60, q=2, d=2)
    model = SGPR(x, y, num_inducing=8, seed=0)
    xs = x[:11]
    qu = model.qu()
    m_ref, v_ref = bound_mod.predict(model.params["hyp"], model.params["z"],
                                     qu, jnp.asarray(xs), include_noise=True)
    mean, var = model.predict(xs, include_noise=True)
    np.testing.assert_allclose(mean, np.asarray(m_ref), rtol=1e-9, atol=1e-11)
    np.testing.assert_allclose(var, np.asarray(v_ref), rtol=1e-8, atol=1e-10)
    # The factor solves are cached, not redone per request...
    st1 = model.predictive_state()
    model.predict(xs)
    assert model.predictive_state() is st1
    assert model._engine_cache is not None
    # ...and a fit invalidates them.
    model.fit(max_iters=1)
    assert model._pstate_cache is None and model._engine_cache is None
    mean2, _ = model.predict(xs)
    assert model._pstate_cache is not None
    assert not np.allclose(mean2, mean)   # params moved, posterior moved


def test_sgpr_serve_engine_inherits_backend(rng):
    """A pallas-trained model serves through the pallas predict kernel by
    default (mirroring DistributedGP.predict_engine), and still matches."""
    x, y = make_regression(rng, n=40, q=2, d=1)
    fused = SGPR(x, y, num_inducing=6, seed=0, chunk_size=16,
                 kernel_backend="pallas")
    eng = fused.serve_engine(block_size=8)
    assert eng.kernel_backend == "pallas"
    assert fused.serve_engine(kernel_backend="xla").kernel_backend == "xla"
    xla = SGPR(x, y, num_inducing=6, seed=0)
    m0, v0 = xla.predict(x[:7])
    m1, v1 = fused.predict(x[:7])
    np.testing.assert_allclose(m1, m0, rtol=1e-8, atol=1e-10)
    np.testing.assert_allclose(v1, v0, rtol=1e-8, atol=1e-10)


def test_engine_donate_preserves_caller_buffer(rng):
    """donate=True may only eat engine-owned buffers — a caller's jnp array
    that needs no pad/cast must survive the call."""
    hyp, z, stats = _posterior(rng)
    state = extract_state(hyp, z, stats)
    eng = PredictEngine(state, block_size=8, donate=True)
    xs = jnp.asarray(rng.standard_normal((16, 2)))   # exact block multiple
    import warnings
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")              # CPU can't honour donation
        m0, v0 = eng.predict(xs)
        m1, v1 = eng.predict(xs)                     # xs must still be alive
    np.testing.assert_array_equal(np.asarray(m0), np.asarray(m1))
    np.testing.assert_array_equal(np.asarray(v0), np.asarray(v1))


def test_sgpr_predict_full_cov_wrapper(rng):
    x, y = make_regression(rng, n=50, q=2, d=1)
    model = SGPR(x, y, num_inducing=7, seed=0)
    mean, cov = model.predict(x[:6], full_cov=True)
    m_ref, c_ref = bound_mod.predict(model.params["hyp"], model.params["z"],
                                     model.qu(), jnp.asarray(x[:6]),
                                     full_cov=True)
    assert cov.shape == (6, 6)
    np.testing.assert_allclose(cov, np.asarray(c_ref), rtol=1e-8, atol=1e-10)
    np.testing.assert_allclose(mean, np.asarray(m_ref), rtol=1e-9, atol=1e-11)


def test_gplvm_state_and_reconstruct(rng):
    _, y = make_regression(rng, n=50, q=2, d=4)
    lv = BayesianGPLVM(y, q=2, num_inducing=6, seed=0)
    state = lv.predictive_state()
    assert lv.predictive_state() is state          # cached
    qu = lv.qu()
    mu = jnp.asarray(lv.params["mu"][:9])
    m_ref, v_ref = bound_mod.predict(lv.params["hyp"], lv.params["z"], qu, mu)
    mean, var = predict_mean_var(state, mu)
    np.testing.assert_allclose(np.asarray(mean), np.asarray(m_ref),
                               rtol=1e-9, atol=1e-11)
    np.testing.assert_allclose(np.asarray(var), np.asarray(v_ref),
                               rtol=1e-8, atol=1e-10)
    lv.fit(max_iters=1)
    assert lv._pstate_cache is None                # invalidated by the fit
    rec = lv.reconstruct(y[:3], observed=np.ones(4, bool), iters=3)
    assert rec.shape == (3, 4) and np.isfinite(rec).all()


def test_state_from_model_matches_manual_extraction(rng):
    x, y = make_regression(rng, n=40, q=2, d=2)
    model = SGPR(x, y, num_inducing=6, seed=0, chunk_size=16)
    state = state_from_model(model)
    manual = extract_state(model.params["hyp"], model.params["z"],
                           model._stats(), jitter=model.jitter)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(manual)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
