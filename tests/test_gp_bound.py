"""Bound correctness: the paper's exactness claim.

The re-parametrised collapsed bound must (1) equal the textbook Titsias
bound computed without the re-parametrisation, (2) never exceed the exact
log marginal likelihood, (3) become exact when Z = X, and (4) be monotone
in the inducing set.
"""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis "
                    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402

from repro.core import bound as bound_mod
from repro.core import ref_naive
from repro.core.stats import partial_stats

from conftest import make_regression


def _mk_hyp(q, log_sf2=0.2, log_ell=0.1, log_beta=1.5):
    return {
        "log_sf2": jnp.asarray(log_sf2),
        "log_ell": jnp.full((q,), log_ell),
        "log_beta": jnp.asarray(log_beta),
    }


def _bound(hyp, x, y, z, jitter=1e-10):
    st_ = partial_stats(hyp, jnp.asarray(z), jnp.asarray(y), jnp.asarray(x),
                        s=None, latent=False)
    return float(bound_mod.collapsed_bound(hyp, jnp.asarray(z), st_,
                                           y.shape[1], jitter=jitter))


def test_matches_direct_titsias_bound(rng, regression_data):
    x, y = regression_data
    z = x[rng.choice(len(x), 15, replace=False)]
    hyp = _mk_hyp(x.shape[1])
    ours = _bound(hyp, x, y, z)
    direct = float(ref_naive.titsias_bound_direct(
        hyp, jnp.asarray(x), jnp.asarray(y), jnp.asarray(z), jitter=1e-10))
    assert ours == pytest.approx(direct, rel=1e-8, abs=1e-6)


def test_never_exceeds_exact_lml(rng, regression_data):
    x, y = regression_data
    z = x[rng.choice(len(x), 10, replace=False)]
    hyp = _mk_hyp(x.shape[1])
    exact = float(ref_naive.exact_lml(hyp, jnp.asarray(x), jnp.asarray(y)))
    assert _bound(hyp, x, y, z) <= exact + 1e-6


def test_exact_when_z_equals_x(rng):
    x, y = make_regression(rng, n=30)
    hyp = _mk_hyp(x.shape[1])
    exact = float(ref_naive.exact_lml(hyp, jnp.asarray(x), jnp.asarray(y),
                                      jitter=1e-10))
    assert _bound(hyp, x, y, x) == pytest.approx(exact, rel=1e-6, abs=1e-4)


def test_monotone_in_inducing_set(rng, regression_data):
    """Adding an inducing point can only tighten the collapsed bound."""
    x, y = regression_data
    hyp = _mk_hyp(x.shape[1])
    idx = rng.permutation(len(x))
    prev = -np.inf
    for m in (5, 10, 20, 40):
        b = _bound(hyp, x, y, x[idx[:m]])
        assert b >= prev - 1e-6
        prev = b


def test_prediction_matches_exact_gp_when_z_is_x(rng):
    x, y = make_regression(rng, n=40)
    hyp = _mk_hyp(x.shape[1])
    st_ = partial_stats(hyp, jnp.asarray(x), jnp.asarray(y), jnp.asarray(x),
                        s=None, latent=False)
    qu = bound_mod.optimal_qu(hyp, jnp.asarray(x), st_, jitter=1e-10)
    xs = rng.uniform(-2, 2, size=(7, x.shape[1]))
    mean, var = bound_mod.predict(hyp, jnp.asarray(x), qu, jnp.asarray(xs))
    em, ev = ref_naive.exact_predict(hyp, jnp.asarray(x), jnp.asarray(y),
                                     jnp.asarray(xs))
    np.testing.assert_allclose(np.asarray(mean), np.asarray(em),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(var), np.asarray(ev),
                               rtol=1e-3, atol=1e-5)


@settings(max_examples=15, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    log_sf2=st.floats(-1.0, 1.5),
    log_ell=st.floats(-0.7, 1.0),
    log_beta=st.floats(-0.5, 3.0),
    n=st.integers(8, 40),
    m=st.integers(2, 8),
)
def test_property_bound_below_exact(seed, log_sf2, log_ell, log_beta, n, m):
    """For any hypers/data/Z: collapsed bound <= exact log marginal."""
    rng = np.random.default_rng(seed)
    q, d = 2, 2
    x = rng.standard_normal((n, q))
    y = rng.standard_normal((n, d))
    z = rng.standard_normal((m, q))
    hyp = _mk_hyp(q, log_sf2, log_ell, log_beta)
    b = _bound(hyp, x, y, z, jitter=1e-8)
    exact = float(ref_naive.exact_lml(hyp, jnp.asarray(x), jnp.asarray(y)))
    assert b <= exact + 1e-4 * max(1.0, abs(exact))


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_property_stats_permutation_invariant(seed):
    """Statistics (and hence the bound) are invariant to data ordering —
    the decoupling property the whole paper rests on."""
    rng = np.random.default_rng(seed)
    n, q, d, m = 25, 2, 3, 6
    x = rng.standard_normal((n, q)); y = rng.standard_normal((n, d))
    s = rng.uniform(0.05, 0.8, size=(n, q))
    z = rng.standard_normal((m, q))
    hyp = _mk_hyp(q)
    perm = rng.permutation(n)
    a = partial_stats(hyp, jnp.asarray(z), jnp.asarray(y), jnp.asarray(x),
                      s=jnp.asarray(s), latent=True)
    b = partial_stats(hyp, jnp.asarray(z), jnp.asarray(y[perm]),
                      jnp.asarray(x[perm]), s=jnp.asarray(s[perm]), latent=True)
    for ta, tb in zip(a, b):
        np.testing.assert_allclose(np.asarray(ta), np.asarray(tb),
                                   rtol=1e-9, atol=1e-9)
