"""Psi-statistic correctness: closed forms vs Monte-Carlo and limits."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis "
                    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402

from repro.core import gp_kernels as gpk


def _mk_hyp(q, rng=None):
    if rng is None:
        return {"log_sf2": jnp.asarray(0.3), "log_ell": jnp.full((q,), -0.1),
                "log_beta": jnp.asarray(1.0)}
    return {"log_sf2": jnp.asarray(rng.uniform(-1, 1)),
            "log_ell": jnp.asarray(rng.uniform(-0.5, 0.5, q)),
            "log_beta": jnp.asarray(1.0)}


def test_psi1_zero_variance_limit(rng):
    n, m, q = 20, 7, 3
    x = rng.standard_normal((n, q)); z = rng.standard_normal((m, q))
    hyp = _mk_hyp(q)
    p1 = gpk.psi1(hyp, jnp.asarray(z), jnp.asarray(x), jnp.zeros((n, q)))
    k = gpk.ard_kernel(hyp, jnp.asarray(x), jnp.asarray(z))
    np.testing.assert_allclose(np.asarray(p1), np.asarray(k), rtol=1e-12)


def test_psi2_zero_variance_limit(rng):
    n, m, q = 20, 7, 3
    x = rng.standard_normal((n, q)); z = rng.standard_normal((m, q))
    hyp = _mk_hyp(q)
    p2 = gpk.psi2(hyp, jnp.asarray(z), jnp.asarray(x), jnp.zeros((n, q)))
    k = gpk.ard_kernel(hyp, jnp.asarray(x), jnp.asarray(z))
    np.testing.assert_allclose(np.asarray(p2), np.asarray(k.T @ k),
                               rtol=1e-10, atol=1e-12)


def test_psi_monte_carlo(rng):
    """Closed forms match Monte-Carlo expectations over q(X)."""
    n, m, q, ns = 4, 5, 2, 400_000
    mu = rng.standard_normal((n, q))
    s = rng.uniform(0.1, 0.6, (n, q))
    z = rng.standard_normal((m, q))
    hyp = _mk_hyp(q, rng)
    eps = rng.standard_normal((ns, n, q))
    xs = mu[None] + np.sqrt(s)[None] * eps          # samples from q(X)
    k = np.asarray(gpk.ard_kernel(hyp, jnp.asarray(xs.reshape(-1, q)),
                                  jnp.asarray(z))).reshape(ns, n, m)
    mc_psi1 = k.mean(axis=0)
    mc_psi2 = np.einsum("sna,snb->nab", k, k) / ns
    p1 = np.asarray(gpk.psi1(hyp, jnp.asarray(z), jnp.asarray(mu), jnp.asarray(s)))
    p2 = np.asarray(gpk.psi2_per_point(hyp, jnp.asarray(z), jnp.asarray(mu),
                                       jnp.asarray(s)))
    np.testing.assert_allclose(p1, mc_psi1, rtol=0.02, atol=5e-3)
    np.testing.assert_allclose(p2, mc_psi2, rtol=0.05, atol=5e-3)


def test_psi2_chunked_equals_dense(rng):
    n, m, q = 37, 6, 3  # n not divisible by chunk
    mu = rng.standard_normal((n, q)); s = rng.uniform(0.05, 0.5, (n, q))
    z = rng.standard_normal((m, q))
    hyp = _mk_hyp(q)
    dense = gpk.psi2(hyp, jnp.asarray(z), jnp.asarray(mu), jnp.asarray(s))
    chunked = gpk.psi2_chunked(hyp, jnp.asarray(z), jnp.asarray(mu),
                               jnp.asarray(s), chunk=8)
    np.testing.assert_allclose(np.asarray(chunked), np.asarray(dense),
                               rtol=1e-10, atol=1e-12)


def test_kl_formula(rng):
    n, q = 11, 3
    mu = rng.standard_normal((n, q)); s = rng.uniform(0.1, 2.0, (n, q))
    ours = float(gpk.kl_to_standard_normal(jnp.asarray(mu), jnp.asarray(s)))
    ref = 0.5 * np.sum(s + mu**2 - np.log(s) - 1.0)
    assert ours == pytest.approx(ref, rel=1e-10)
    assert ours >= 0.0


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_property_psi2_per_point_psd(seed):
    """Each psi2_i = <k k^T> is a PSD matrix (it is a second moment)."""
    rng = np.random.default_rng(seed)
    n, m, q = 3, 6, 2
    mu = rng.standard_normal((n, q)); s = rng.uniform(0.01, 1.5, (n, q))
    z = rng.standard_normal((m, q))
    hyp = _mk_hyp(q, rng)
    p2 = np.asarray(gpk.psi2_per_point(hyp, jnp.asarray(z), jnp.asarray(mu),
                                       jnp.asarray(s)))
    for i in range(n):
        ev = np.linalg.eigvalsh(0.5 * (p2[i] + p2[i].T))
        assert ev.min() >= -1e-9


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_property_psi_bounds(seed):
    """0 < Psi1 <= sf2 and psi0 = sf2 (SE kernel facts)."""
    rng = np.random.default_rng(seed)
    n, m, q = 5, 4, 3
    mu = rng.standard_normal((n, q)); s = rng.uniform(0.0, 2.0, (n, q))
    z = rng.standard_normal((m, q))
    hyp = _mk_hyp(q, rng)
    sf2 = float(jnp.exp(hyp["log_sf2"]))
    p1 = np.asarray(gpk.psi1(hyp, jnp.asarray(z), jnp.asarray(mu), jnp.asarray(s)))
    assert (p1 > 0).all() and (p1 <= sf2 + 1e-12).all()
    p0 = np.asarray(gpk.psi0(hyp, jnp.asarray(mu), jnp.asarray(s)))
    np.testing.assert_allclose(p0, sf2, rtol=1e-12)
