"""Kernel zoo: psi-statistics parity across analytic / quadrature / Monte-
Carlo for every primitive and for Sum/Product compositions, the zero-
variance limit, spec round-trips, ops-level dispatch shims, end-to-end model
runs with a non-SE expression, and the serving-side kernel spec round-trip.
"""
import json
import warnings

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import covariance as cov
from repro.core import gp_kernels as gpk
from repro.core import init_utils
from repro.core.covariance import (SEARD, Linear, Matern32, Periodic, Product,
                                   Sum)
from repro.core.gplvm import BayesianGPLVM
from repro.core.sgpr import SGPR
from repro.serve import posterior
from repro.serve.engine import PredictEngine, stack_states

# Small problem: quadrature is O(order^|dims|) and MC needs many draws.
N, M, Q = 5, 4, 2


def _qx(rng, n=N, m=M, q=Q, s_scale=0.08):
    """A diagonal q(X) with modest variances (keeps order-11 GH accurate)."""
    mu = jnp.asarray(rng.standard_normal((n, q)))
    s = jnp.asarray(s_scale * (0.5 + rng.random((n, q))))
    z = jnp.asarray(rng.standard_normal((m, q)))
    w = jnp.asarray(0.5 + rng.random((n,)))
    return mu, s, z, w


def _hyp_for(kernel, rng):
    """Randomised (but tame) hyper-parameters for one expression."""
    def rand_tree(shapes):
        return {
            k: (rand_tree(v) if isinstance(v, dict)
                else jnp.asarray(0.2 * rng.standard_normal(v)))
            for k, v in shapes.items()
        }

    return rand_tree(kernel.hyp_shapes(Q))


def _psi_mc(kernel, hyp, z, mu, s, rng, num=60_000):
    """Monte-Carlo psi statistics under x_i ~ N(mu_i, diag(s_i))."""
    n, q = mu.shape
    eps = rng.standard_normal((num, n, q))
    xs = np.asarray(mu)[None] + np.sqrt(np.asarray(s))[None] * eps
    xs = jnp.asarray(xs.reshape(num * n, q))
    kd = kernel.kdiag(hyp, xs).reshape(num, n)
    k = kernel.K(hyp, xs, z).reshape(num, n, -1)
    psi0 = jnp.mean(kd, axis=0)
    psi1 = jnp.mean(k, axis=0)
    psi2pp = jnp.einsum("jna,jnb->nab", k, k) / num
    return psi0, psi1, psi2pp


ZOO = {
    "se": SEARD(),
    "se_dims": SEARD(dims=(0,)),
    "matern32": Matern32(dims=(0, 1), quad_order=11),
    "linear": Linear(),
    "periodic": Periodic(dims=(1,), quad_order=15),
    "sum_disjoint": Sum(SEARD(dims=(0,)), Linear(dims=(1,))),
    "prod_disjoint": Product(SEARD(dims=(0,)), Matern32(dims=(1,))),
    "sum_overlap": Sum(SEARD(dims=(0, 1)), Linear(dims=(0,)), quad_order=9),
}


# -- psi cross-checks: analytic vs quadrature vs Monte-Carlo ------------------

@pytest.mark.parametrize("name", sorted(ZOO))
def test_psi_monte_carlo_cross_check(name, rng):
    """Whatever route an expression's psi stats take (closed form, factored
    composition, or GH quadrature), they must agree with brute-force MC."""
    kernel = ZOO[name]
    mu, s, z, w = _qx(rng)
    hyp = _hyp_for(kernel, rng)

    p0 = kernel.psi0(hyp, mu, s)
    p1 = kernel.psi1(hyp, z, mu, s)
    p2pp = kernel.psi2_per_point(hyp, z, mu, s)
    mc0, mc1, mc2 = _psi_mc(kernel, hyp, z, mu, s, rng)

    scale = float(jnp.max(jnp.abs(p0))) + 1e-6
    np.testing.assert_allclose(p0, mc0, atol=3e-2 * scale)
    np.testing.assert_allclose(p1, mc1, atol=3e-2 * scale)
    np.testing.assert_allclose(p2pp, mc2, atol=5e-2 * scale * scale)

    # The weighted psi2 contraction matches its per-point definition.
    np.testing.assert_allclose(kernel.psi2(hyp, z, mu, s, w),
                               jnp.einsum("i,iab->ab", w, p2pp),
                               rtol=1e-12, atol=1e-12)


@pytest.mark.parametrize("name", ["se", "se_dims", "linear", "sum_disjoint",
                                  "prod_disjoint"])
def test_analytic_psi_vs_quadrature(name, rng):
    """Closed-form / factored psi stats agree with the generic GH fallback
    run on the same composite expression (truncation-level tolerance)."""
    kernel = ZOO[name]
    mu, s, z, _ = _qx(rng)
    hyp = _hyp_for(kernel, rng)

    q0 = cov.psi0_quad(kernel, hyp, mu, s)
    q1 = cov.psi1_quad(kernel, hyp, z, mu, s)
    q2 = cov.psi2_per_point_quad(kernel, hyp, z, mu, s)
    np.testing.assert_allclose(kernel.psi0(hyp, mu, s), q0,
                               rtol=1e-4, atol=1e-6)
    np.testing.assert_allclose(kernel.psi1(hyp, z, mu, s), q1,
                               rtol=1e-4, atol=1e-6)
    np.testing.assert_allclose(kernel.psi2_per_point(hyp, z, mu, s), q2,
                               rtol=2e-4, atol=1e-5)


@pytest.mark.parametrize("name", sorted(ZOO))
def test_zero_variance_limit(name, rng):
    """s = 0 collapses q(X) to a point mass: psi0 == kdiag, psi1 == K, and
    psi2_per_point == outer(K_i, K_i) for EVERY expression."""
    kernel = ZOO[name]
    mu, _, z, _ = _qx(rng)
    s0 = jnp.zeros_like(mu)
    hyp = _hyp_for(kernel, rng)

    k = kernel.K(hyp, mu, z)
    np.testing.assert_allclose(kernel.psi0(hyp, mu, s0),
                               kernel.kdiag(hyp, mu), rtol=1e-12, atol=1e-12)
    np.testing.assert_allclose(kernel.psi1(hyp, z, mu, s0), k,
                               rtol=1e-12, atol=1e-12)
    np.testing.assert_allclose(kernel.psi2_per_point(hyp, z, mu, s0),
                               k[:, :, None] * k[:, None, :],
                               rtol=1e-12, atol=1e-12)


# -- SE-ARD must stay the legacy path bitwise ---------------------------------

def test_se_expression_bitwise_legacy(rng):
    """The default SE-ARD expression routes through the exact same
    gp_kernels closed forms — results are bitwise-identical, so swapping
    the kernel-object plumbing in changed nothing for the default path."""
    kernel = cov.SE_ARD
    mu, s, z, w = _qx(rng)
    hyp = {"log_sf2": jnp.asarray(0.3),
           "log_ell": jnp.asarray(rng.standard_normal(Q) * 0.2)}

    assert np.array_equal(kernel.K(hyp, mu, z), gpk.se_kernel(hyp, mu, z))
    assert np.array_equal(kernel.kdiag(hyp, mu), gpk.se_kdiag(hyp, mu))
    assert np.array_equal(kernel.psi0(hyp, mu, s), gpk.se_psi0(hyp, mu, s))
    assert np.array_equal(kernel.psi1(hyp, z, mu, s),
                          gpk.se_psi1(hyp, z, mu, s))
    assert np.array_equal(
        kernel.psi2(hyp, z, mu, s, w),
        jnp.einsum("i,iab->ab", w, gpk.psi2_per_point(hyp, z, mu, s)))


def test_deprecated_wrappers_warn_once():
    hyp = {"log_sf2": jnp.asarray(0.0), "log_ell": jnp.zeros((Q,))}
    a = jnp.ones((3, Q))
    gpk._DEPRECATION_WARNED.clear()
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        out = gpk.ard_kernel(hyp, a, a)
        gpk.ard_kernel(hyp, a, a)          # second call: no new warning
    dep = [r for r in rec if issubclass(r.category, DeprecationWarning)]
    assert len(dep) == 1 and "se_kernel" in str(dep[0].message)
    assert np.array_equal(out, gpk.se_kernel(hyp, a, a))


# -- sqdist regression --------------------------------------------------------

def test_sqdist_large_offset_regression(rng):
    """Catastrophic cancellation guard: distances between points riding on a
    huge common offset must match the exact O(1) distances."""
    a = rng.standard_normal((40, 3))
    b = rng.standard_normal((30, 3))
    exact = np.sum((a[:, None, :] - b[None, :, :]) ** 2, axis=-1)
    shifted = gpk.sqdist(jnp.asarray(a + 1e4), jnp.asarray(b + 1e4))
    np.testing.assert_allclose(shifted, exact, rtol=1e-6, atol=1e-6)
    assert float(jnp.min(shifted)) >= 0.0


# -- spec round-trip & registry ----------------------------------------------

@pytest.mark.parametrize("name", sorted(ZOO))
def test_spec_round_trip(name):
    kernel = ZOO[name]
    spec = kernel.to_spec()
    json.dumps(spec)                                   # JSON-able
    rebuilt = cov.kernel_from_spec(spec)
    assert rebuilt == kernel and hash(rebuilt) == hash(kernel)
    assert cov.kernel_from_spec(str(kernel)) == kernel  # string form too


def test_registry_and_dispatch_helpers():
    assert set(cov.kernel_names()) >= {"se", "matern32", "linear", "periodic",
                                       "sum", "product"}
    assert cov.as_kernel(None) == cov.SE_ARD
    assert cov.as_kernel({"kind": "se"}) == cov.SE_ARD
    with pytest.raises(TypeError):
        cov.as_kernel(42)
    with pytest.raises(ValueError, match="unknown kernel kind"):
        cov.kernel_from_spec({"kind": "nope"})
    assert cov.is_fused_se(None) and cov.is_fused_se(cov.SE_ARD)
    assert not cov.is_fused_se(SEARD(dims=(0,)))
    assert not cov.is_fused_se(ZOO["sum_disjoint"])
    with pytest.raises(ValueError, match=">= 2"):
        Sum(SEARD())


def test_default_hyp_shapes_agree():
    def flat(tree, to_shape):
        out = []
        for k in sorted(tree):
            v = tree[k]
            if isinstance(v, dict):
                out += [(f"{k}/{kk}", sh) for kk, sh in flat(v, to_shape)]
            else:
                out.append((k, to_shape(v)))
        return out

    for kernel in ZOO.values():
        hyp = kernel.default_hyp(Q, var_y=2.0)
        shapes = kernel.hyp_shapes(Q)
        assert flat(hyp, np.shape) == flat(shapes, tuple)
        full = init_utils.default_hyp_for(kernel, np.ones((10, 3)), Q)
        assert "log_beta" in full


# -- ops-level dispatch shims -------------------------------------------------

def test_ops_shims_dispatch(rng):
    from repro.kernels.psi_stats import psi2_fn_for_engine
    from repro.kernels.reg_stats import reg_stats_fn_for_engine

    mu, s, z, w = _qx(rng)
    y = jnp.asarray(rng.standard_normal((N, 3)))
    kernel = ZOO["sum_disjoint"]
    hyp = _hyp_for(kernel, rng)

    # Non-SE expression: the fallback closures run the expression's own math.
    fn = psi2_fn_for_engine(kernel=kernel)
    np.testing.assert_allclose(fn(hyp, z, mu, s, w),
                               kernel.psi2(hyp, z, mu, s, w),
                               rtol=1e-12, atol=1e-12)
    rfn = reg_stats_fn_for_engine(kernel=kernel)
    b, c, d_stat = rfn(hyp, z, mu, y, w)
    k = kernel.K(hyp, mu, z)
    np.testing.assert_allclose(b, jnp.sum(w * kernel.kdiag(hyp, mu)),
                               rtol=1e-12)
    np.testing.assert_allclose(c, k.T @ (w[:, None] * y), rtol=1e-12)
    np.testing.assert_allclose(d_stat, (k * w[:, None]).T @ k, rtol=1e-12)

    # SE expression: the shim hands back the fused Pallas path, which must
    # match the XLA closed forms at parity tolerance.
    se_hyp = {"log_sf2": jnp.asarray(0.1),
              "log_ell": jnp.asarray(0.2 * rng.standard_normal(Q))}
    fused = psi2_fn_for_engine(kernel=cov.SE_ARD)(se_hyp, z, mu, s, w)
    # The fused psi2 op computes in f32 (MXU contract) — f32-level parity.
    np.testing.assert_allclose(
        fused, cov.SE_ARD.psi2(se_hyp, z, mu, s, w), rtol=5e-6, atol=5e-6)


# -- end-to-end: models + serving with a composite expression ----------------

@pytest.fixture(scope="module")
def composite_fit():
    rng = np.random.default_rng(7)
    n, q, d, m = 60, 2, 2, 8
    x = rng.normal(size=(n, q))
    y = np.tanh(x) @ rng.normal(size=(q, d)) + 0.05 * rng.normal(size=(n, d))
    kern = Sum(SEARD(dims=(0,)), Linear(dims=(1,)))
    model = SGPR(x, y, num_inducing=m, kernel=kern, chunk_size=16)
    lml0 = model.log_bound()
    model.fit(max_iters=12)
    return model, kern, x, y, lml0


def test_sgpr_composite_end_to_end(composite_fit):
    model, kern, x, y, lml0 = composite_fit
    assert model.log_bound() > lml0
    mu, var = model.predict(x[:9])
    assert mu.shape == (9, y.shape[1]) and np.isfinite(mu).all()
    assert np.all(np.asarray(var) > 0)

    # Pallas-backend model agrees on the bound (shim falls back to XLA).
    mp = SGPR(x, y, num_inducing=8, kernel=kern, kernel_backend="pallas",
              chunk_size=16)
    mx = SGPR(x, y, num_inducing=8, kernel=kern, chunk_size=16)
    np.testing.assert_allclose(mp.log_bound(), mx.log_bound(), rtol=1e-10)


def test_gplvm_composite_svi_smoke():
    rng = np.random.default_rng(3)
    y = np.asarray(rng.normal(size=(40, 3)))
    kern = Sum(SEARD(dims=(0,)), Linear(dims=(1,)))
    gpl = BayesianGPLVM(y, Q, num_inducing=6, kernel=kern, chunk_size=16,
                        batch_blocks=2)
    b0 = gpl.log_bound()
    gpl.fit_svi(steps=8, lr=1e-2, seed=0)
    assert np.isfinite(gpl.log_bound()) and gpl.log_bound() != b0
    with pytest.raises(ValueError, match="ARD lengthscales"):
        gpl.ard_weights()


def test_serving_composite_round_trip(composite_fit, tmp_path):
    model, kern, x, _, _ = composite_fit
    state = posterior.state_from_model(model)
    assert state.kernel == kern
    xq = jnp.asarray(x[:13])
    ref_mu, ref_var = posterior.predict_mean_var(state, xq)

    # Both engine backends serve the composite identically (pallas shim
    # falls back to the XLA block math for non-SE expressions).
    for backend in ("xla", "pallas"):
        eng = PredictEngine(state, block_size=8, kernel_backend=backend)
        emu, evar = eng.predict(np.asarray(xq))
        np.testing.assert_allclose(emu, ref_mu, rtol=1e-9)
        np.testing.assert_allclose(evar, ref_var, rtol=1e-9)

    # Save/load: the kernel spec rides in the sidecar.
    p = tmp_path / "state.npz"
    posterior.save_state(p, state)
    loaded, _ = posterior.load_state(p)
    assert loaded.kernel == kern
    lmu, _ = posterior.predict_mean_var(loaded, xq)
    np.testing.assert_array_equal(np.asarray(lmu), np.asarray(ref_mu))

    # A pre-zoo checkpoint (no kernel key in the sidecar) restores as SE.
    # The composite hyp tree would not fit the SE template, so exercise this
    # with an SE state — exactly what a pre-refactor checkpoint holds.
    se_model = SGPR(np.asarray(x), np.asarray(x[:, :1]), num_inducing=6)
    se_state = posterior.state_from_model(se_model)
    p2 = tmp_path / "se.npz"
    posterior.save_state(p2, se_state)
    side = p2.with_suffix(".json")
    md = json.loads(side.read_text())
    md["metadata"].pop("kernel")
    side.write_text(json.dumps(md))
    legacy, _ = posterior.load_state(p2)
    assert legacy.kernel == cov.SE_ARD
    se_mu, _ = posterior.predict_mean_var(se_state, xq)
    leg_mu, _ = posterior.predict_mean_var(legacy, xq)
    np.testing.assert_array_equal(np.asarray(leg_mu), np.asarray(se_mu))

    # Mixed-kernel fleets refuse to stack with a clear error.
    with pytest.raises(ValueError, match="kernel expression"):
        stack_states([state, legacy])
