"""End-to-end behaviour tests for the paper's system."""
import jax.numpy as jnp
import numpy as np

from repro.core import SGPR
from repro.core.ref_naive import exact_predict
from repro.data.synthetic import oilflow_like, sines_dataset

from conftest import make_regression


def test_sgpr_end_to_end_accuracy(rng):
    """Fit SGPR on smooth data; predictions close to the exact GP's."""
    x, y = make_regression(rng, n=120, q=2, d=1, noise=0.05)
    mdl = SGPR(x, y, num_inducing=30, seed=0)
    mdl.fit(max_iters=120)
    xs, ys = make_regression(rng, n=25, q=2, d=1, noise=0.0)
    mean, var = mdl.predict(xs)
    rmse = float(np.sqrt(np.mean((mean - ys) ** 2)))
    # exact GP at the *fitted* hypers as reference
    em, _ = exact_predict(mdl.params["hyp"], jnp.asarray(x), jnp.asarray(y),
                          jnp.asarray(xs))
    rmse_exact = float(np.sqrt(np.mean((np.asarray(em) - ys) ** 2)))
    assert rmse < max(3.0 * rmse_exact, 0.25)
    assert (var > 0).all()


def test_sgpr_noise_recovery(rng):
    """With enough inducing points the noise precision is recovered."""
    noise = 0.1
    x, y = make_regression(rng, n=150, q=2, d=1, noise=noise)
    mdl = SGPR(x, y, num_inducing=40, seed=0)
    mdl.fit(max_iters=150)
    beta = float(np.exp(mdl.params["hyp"]["log_beta"]))
    sigma = 1.0 / np.sqrt(beta)
    assert 0.3 * noise < sigma < 3.0 * noise


def test_gplvm_reconstruction_beats_prior(rng):
    """Paper §4.5 mechanism: a trained GPLVM reconstructs held-out dims far
    better than predicting the data mean. (The 'more data helps' comparison
    itself lives in benchmarks/usps_reconstruction.py where the dataset is
    hard enough for it to show.)"""
    from repro.core import BayesianGPLVM

    y_all, _ = sines_dataset(rng, n=200, noise=0.05)
    lv = BayesianGPLVM(y_all, q=2, num_inducing=12, seed=1)
    lv.fit(max_iters=100)
    observed = np.array([True, True, False])
    ytest, _ = sines_dataset(rng, n=10, noise=0.0)
    rec = lv.reconstruct(ytest * observed, observed, iters=40)
    err = float(np.mean(np.abs(rec[:, ~observed] - ytest[:, ~observed])))
    base = float(np.mean(np.abs(y_all[:, ~observed].mean(0)[None]
                                - ytest[:, ~observed])))
    assert err < 0.5 * base


def test_oilflow_like_pipeline(rng):
    y, labels = oilflow_like(rng, n=120)
    assert y.shape == (120, 12)
    assert set(np.unique(labels)) <= {0, 1, 2}
