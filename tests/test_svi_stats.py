"""Minibatch-stochastic (SVI) map step: provable unbiasedness + plumbing.

The estimator under test (``stats.partial_stats_chunked(batch_blocks=B)``):
sample B of the nb row blocks uniformly without replacement, scan only
those, scale the accumulated Stats by nb/B.  Every Stats field is a plain
sum over points, so averaging the stochastic Stats over ALL size-B subsets
must reproduce the exact streamed Stats *identically* (up to f64 summation
order) — and therefore the collapsed bound and its gradients evaluated at
the subset-averaged statistics reproduce the exact bound/gradients.  The
tests enumerate the subsets via the ``block_indices`` hook (no sampling
noise, no statistical tolerance), including padded final blocks, the
latent path's per-point KL, and independent per-shard sampling.
"""
import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import BayesianGPLVM, SGPR
from repro.core.bound import collapsed_bound
from repro.core.distributed import DistributedGP
from repro.core.stats import partial_stats_chunked, sample_block_indices
from repro.launch.mesh import make_compat_mesh

from conftest import make_regression


def _mk_hyp(q):
    return {"log_sf2": jnp.asarray(0.2), "log_ell": jnp.full((q,), 0.1),
            "log_beta": jnp.asarray(1.0)}


def _assert_stats_close(a, b, rtol=1e-10, atol=1e-12):
    for name, x, y in zip(a._fields, a, b):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=rtol, atol=atol, err_msg=name)


def _subset_average(subsets, stats_for_subset):
    subsets = list(subsets)
    acc = None
    for sub in subsets:
        st = stats_for_subset(jnp.asarray(sub))
        acc = st if acc is None else acc + st
    return acc.scale(1.0 / len(subsets))


@pytest.mark.parametrize("latent", [False, True])
def test_subset_averaged_stats_and_bound_equal_exact(rng, latent):
    """E over all size-B subsets of the reweighted Stats == exact Stats, so
    the bound (and anything else computed from the averaged statistics)
    matches the exact streamed bound to f64 — with a padded final block and
    the latent per-point KL reweighted along with the data terms."""
    n, m, q, d, block, B = 53, 6, 2, 3, 8, 3   # nb = 7, last block padded
    x = jnp.asarray(rng.standard_normal((n, q)))
    y = jnp.asarray(rng.standard_normal((n, d)))
    z = jnp.asarray(rng.standard_normal((m, q)))
    s = jnp.asarray(rng.uniform(0.05, 0.6, (n, q))) if latent else None
    hyp = _mk_hyp(q)
    nb = -(-n // block)

    exact = partial_stats_chunked(hyp, z, y, x, s=s, latent=latent,
                                  block_size=block)
    avg = _subset_average(
        itertools.combinations(range(nb), B),
        lambda sub: partial_stats_chunked(hyp, z, y, x, s=s, latent=latent,
                                          block_size=block, batch_blocks=B,
                                          block_indices=sub))
    _assert_stats_close(exact, avg)
    b_exact = float(collapsed_bound(hyp, z, exact, d))
    b_avg = float(collapsed_bound(hyp, z, avg, d))
    assert abs(b_avg - b_exact) < 1e-10 * abs(b_exact)


def test_subset_averaged_grads_equal_exact(rng):
    """Gradient unbiasedness through the sampled scan: for any loss LINEAR
    in the statistics, the subset-averaged stochastic gradients wrt (hyp, z)
    equal the exact gradients to f64 (the stochastic Stats are linear in the
    block contributions, so expectation and differentiation commute)."""
    n, m, q, d, block, B = 41, 5, 2, 2, 8, 2   # nb = 6, padded final block
    x = jnp.asarray(rng.standard_normal((n, q)))
    y = jnp.asarray(rng.standard_normal((n, d)))
    z = jnp.asarray(rng.standard_normal((m, q)))
    s = jnp.asarray(rng.uniform(0.05, 0.6, (n, q)))
    hyp = _mk_hyp(q)
    nb = -(-n // block)
    # Fixed random contraction: one scalar that touches every Stats field.
    vc = jnp.asarray(rng.standard_normal((m, d)))
    vd = jnp.asarray(rng.standard_normal((m, m)))

    def loss(h, zz, indices):
        st = partial_stats_chunked(
            h, zz, y, x, s=s, latent=True, block_size=block,
            batch_blocks=None if indices is None else B,
            block_indices=indices)
        return (st.A + 2.0 * st.B + jnp.sum(vc * st.C) + jnp.sum(vd * st.D)
                + 3.0 * st.KL + 0.5 * st.n)

    g_exact = jax.grad(loss, argnums=(0, 1))(hyp, z, None)
    subsets = list(itertools.combinations(range(nb), B))
    acc = None
    for sub in subsets:
        g = jax.grad(loss, argnums=(0, 1))(hyp, z, jnp.asarray(sub))
        acc = g if acc is None else jax.tree.map(jnp.add, acc, g)
    g_avg = jax.tree.map(lambda t: t / len(subsets), acc)
    for a, b in zip(jax.tree.leaves(g_exact), jax.tree.leaves(g_avg)):
        np.testing.assert_allclose(np.asarray(b), np.asarray(a),
                                   rtol=1e-9, atol=1e-11)


def test_full_batch_svi_equals_exact_bound_and_grads(rng):
    """batch_blocks == nb degrades to the exact scan: identical bound and
    gradients (not just unbiased — bit-for-bit the same math)."""
    n, m, q, d, block = 60, 7, 2, 2, 13    # nb = 5, padded final block
    x, y = make_regression(rng, n=n, q=q, d=d)
    z = jnp.asarray(rng.standard_normal((m, q)))
    hyp = _mk_hyp(q)
    nb = -(-n // block)

    def neg(h, zz, batch_blocks, key):
        st = partial_stats_chunked(h, zz, jnp.asarray(y), jnp.asarray(x),
                                   s=None, latent=False, block_size=block,
                                   batch_blocks=batch_blocks, key=key)
        return -collapsed_bound(h, zz, st, d)

    v0, (gh0, gz0) = jax.value_and_grad(neg, argnums=(0, 1))(
        hyp, z, None, None)
    v1, (gh1, gz1) = jax.jit(jax.value_and_grad(neg, argnums=(0, 1)),
                             static_argnums=(2,))(
        hyp, z, nb, jax.random.PRNGKey(0))
    assert abs(float(v1) - float(v0)) < 1e-10 * abs(float(v0))
    np.testing.assert_allclose(np.asarray(gz1), np.asarray(gz0),
                               rtol=1e-9, atol=1e-11)
    for k in gh0:
        np.testing.assert_allclose(np.asarray(gh1[k]), np.asarray(gh0[k]),
                                   rtol=1e-9, atol=1e-11)


@pytest.mark.statistical
def test_per_shard_sampling_unbiased(rng):
    """The distributed scheme — each shard samples ITS OWN blocks
    independently and reweights locally before the sum — stays unbiased:
    summing each shard's subset-averaged Stats equals the exact global
    Stats.  (Independence factorises the expectation per shard.)"""
    n, m, q, d, block, B, k_shards = 64, 5, 2, 2, 4, 2, 2
    x = jnp.asarray(rng.standard_normal((n, q)))
    y = jnp.asarray(rng.standard_normal((n, d)))
    z = jnp.asarray(rng.standard_normal((m, q)))
    hyp = _mk_hyp(q)

    exact = partial_stats_chunked(hyp, z, y, x, s=None, latent=False,
                                  block_size=block)
    n_local = n // k_shards
    nb_local = n_local // block
    total = None
    for sh in range(k_shards):
        sl = slice(sh * n_local, (sh + 1) * n_local)
        avg = _subset_average(
            itertools.combinations(range(nb_local), B),
            lambda sub, sl=sl: partial_stats_chunked(
                hyp, z, y[sl], x[sl], s=None, latent=False,
                block_size=block, batch_blocks=B, block_indices=sub))
        total = avg if total is None else total + avg
    _assert_stats_close(exact, total)


def test_sample_block_indices_no_replacement():
    nb, B = 11, 4
    seen = set()
    for i in range(20):
        idx = np.asarray(sample_block_indices(jax.random.PRNGKey(i), nb, B))
        assert idx.shape == (B,)
        assert len(set(idx.tolist())) == B          # without replacement
        assert idx.min() >= 0 and idx.max() < nb
        seen.add(tuple(sorted(idx.tolist())))
    assert len(seen) > 1                            # sampler actually varies


def test_svi_validation_errors(rng):
    y = jnp.asarray(rng.standard_normal((20, 1)))
    x = jnp.asarray(rng.standard_normal((20, 2)))
    hyp = _mk_hyp(2)
    z = jnp.asarray(rng.standard_normal((4, 2)))
    with pytest.raises(ValueError, match="requires block_size"):
        partial_stats_chunked(hyp, z, y, x, s=None, latent=False,
                              block_size=None, batch_blocks=2)
    with pytest.raises(ValueError, match="needs a PRNG key"):
        partial_stats_chunked(hyp, z, y, x, s=None, latent=False,
                              block_size=4, batch_blocks=2)
    with pytest.raises(ValueError, match="requires chunk_size"):
        DistributedGP(make_compat_mesh((1,), ("data",)), batch_blocks=2)


def test_distributed_svi_single_device(rng):
    """Engine plumbing on a 1-device mesh: full-batch SVI == exact bound;
    subsampled SVI is deterministic per key and varies across keys.
    (Multi-device per-shard sampling parity runs in _dist_worker.py.)"""
    mesh = make_compat_mesh((1,), ("data",))
    n, m, q, d, block = 37, 5, 2, 1, 8           # padded to 40 -> nb = 5
    x = rng.standard_normal((n, q)); y = rng.standard_normal((n, d))
    z = jnp.asarray(rng.standard_normal((m, q)))
    hyp = _mk_hyp(q)
    nf = jnp.asarray(float(n))

    eng_exact = DistributedGP(mesh, latent=False, chunk_size=block)
    data, w = eng_exact.put_data(y=y, mu=x)
    v_ref, _ = eng_exact.make_value_and_grad(d)(
        hyp, z, data["mu"], None, data["y"], w, jnp.ones((1,)), nf)

    eng_full = DistributedGP(mesh, latent=False, chunk_size=block,
                             batch_blocks=5)
    v_full, (gh, gz) = eng_full.make_value_and_grad(d)(
        hyp, z, data["mu"], None, data["y"], w, jnp.ones((1,)), nf,
        jax.random.PRNGKey(0))
    assert abs(float(v_full) - float(v_ref)) < 1e-10 * abs(float(v_ref))
    assert np.isfinite(np.asarray(gz)).all()

    eng_svi = DistributedGP(mesh, latent=False, chunk_size=block,
                            batch_blocks=2)
    vg = eng_svi.make_value_and_grad(d)
    args = (hyp, z, data["mu"], None, data["y"], w, jnp.ones((1,)), nf)
    vals = [float(vg(*args, jax.random.PRNGKey(k))[0]) for k in range(8)]
    assert all(np.isfinite(v) for v in vals)
    assert float(vg(*args, jax.random.PRNGKey(0))[0]) == vals[0]  # replayable
    assert len(set(vals)) > 1            # different keys -> different subsets


def test_make_gp_train_step_svi_smoke(rng):
    from repro.train.steps import make_gp_train_step

    mesh = make_compat_mesh((1,), ("data",))
    n, m, q, d = 24, 4, 2, 1
    x = rng.standard_normal((n, q)); y = rng.standard_normal((n, d))
    z = jnp.asarray(rng.standard_normal((m, q)))
    eng, step = make_gp_train_step(mesh, d, chunk_size=4, batch_blocks=2)
    data, w = eng.put_data(y=y, mu=x)
    v, (gh, gz) = step(_mk_hyp(q), z, data["mu"], None, data["y"], w,
                       jnp.ones((1,)), jnp.asarray(float(n)),
                       jax.random.PRNGKey(7))
    assert np.isfinite(float(v))
    assert np.isfinite(np.asarray(gz)).all()


def test_sgpr_fit_svi_improves_exact_bound(rng):
    x, y = make_regression(rng, n=160, q=1, d=1)
    gp = SGPR(x, y, num_inducing=8, seed=0, chunk_size=16, batch_blocks=3)
    b0 = gp.log_bound()
    res = gp.fit_svi(steps=120, lr=3e-2, seed=0)
    assert res.n_steps == 120 and np.isfinite(res.history).all()
    assert gp.log_bound() > b0          # exact bound, stochastic optimiser
    mean, var = gp.predict(x[:5])       # posterior path still works
    assert np.isfinite(mean).all() and np.isfinite(var).all()


def test_gplvm_fit_svi_improves_exact_bound(rng):
    y = rng.standard_normal((48, 4))
    lv = BayesianGPLVM(y, q=2, num_inducing=6, seed=0, chunk_size=8,
                       batch_blocks=2)
    b0 = lv.log_bound()
    res = lv.fit_svi(steps=80, lr=2e-2, seed=0)
    assert np.isfinite(res.history).all()
    assert lv.log_bound() > b0


def test_svi_composes_with_pallas_backend(rng):
    """kernel_backend='pallas' (interpret mode off-TPU) under SVI: the fused
    per-block hook sees only sampled blocks; full-batch == exact."""
    mesh = make_compat_mesh((1,), ("data",))
    n, m, q, d, block = 33, 6, 2, 1, 8           # padded to 40 -> nb = 5
    x = rng.standard_normal((n, q)); y = rng.standard_normal((n, d))
    z = jnp.asarray(rng.standard_normal((m, q)))
    hyp = _mk_hyp(q)
    nf = jnp.asarray(float(n))

    eng_exact = DistributedGP(mesh, latent=False, chunk_size=block)
    data, w = eng_exact.put_data(y=y, mu=x)
    v_ref, _ = eng_exact.make_value_and_grad(d)(
        hyp, z, data["mu"], None, data["y"], w, jnp.ones((1,)), nf)

    eng = DistributedGP(mesh, latent=False, chunk_size=block,
                        kernel_backend="pallas", batch_blocks=5)
    v_full, _ = eng.make_value_and_grad(d)(
        hyp, z, data["mu"], None, data["y"], w, jnp.ones((1,)), nf,
        jax.random.PRNGKey(0))
    # interpret mode computes in the caller's f64 -> f64-level parity
    assert abs(float(v_full) - float(v_ref)) < 1e-8 * abs(float(v_ref))

    eng_b = DistributedGP(mesh, latent=False, chunk_size=block,
                          kernel_backend="pallas", batch_blocks=2)
    v_b, _ = eng_b.make_value_and_grad(d)(
        hyp, z, data["mu"], None, data["y"], w, jnp.ones((1,)), nf,
        jax.random.PRNGKey(1))
    assert np.isfinite(float(v_b))
