"""Integration: the dry-run lowering path (shardings + lower + compile +
HLO analysis) on a small placeholder mesh in a subprocess."""
import os
import pathlib
import subprocess
import sys

import pytest

ROOT = pathlib.Path(__file__).resolve().parents[1]

_WORKER = r"""
import jax, jax.numpy as jnp
assert len(jax.devices()) == 4
from repro.configs import all_configs
from repro.configs.base import ShapeSpec
from repro.distributed import sharding as shlib
from repro.launch.hlo_analyzer import analyze
from repro.train import steps

from repro.launch.mesh import make_compat_mesh
mesh = make_compat_mesh((2, 2), ("data", "model"))
for arch in ("llama3.2-1b", "qwen3-moe-235b-a22b", "mamba2-370m"):
    cfg = all_configs()[arch].reduced()
    shape = ShapeSpec("tiny_train", seq_len=32, global_batch=4, kind="train")
    with shlib.use_mesh(mesh):
        state_sds, specs = steps.abstract_state(cfg)
        state_sh = shlib.tree_shardings(specs, state_sds, mesh)
        batch_sds = steps.input_specs(cfg, shape)
        b_specs = steps.batch_specs(cfg, batch_sds)
        batch_sh = shlib.tree_shardings(b_specs, batch_sds, mesh)
        fn = steps.make_train_step(cfg)
        lowered = jax.jit(fn, in_shardings=(state_sh, batch_sh),
                          out_shardings=(state_sh, None)).lower(
            state_sds, batch_sds)
        compiled = lowered.compile()
        mem = compiled.memory_analysis()
        assert mem.argument_size_in_bytes > 0
        an = analyze(compiled.as_text())
        assert an["flops"] > 0 and an["bytes"] > 0
        # decode path too
        dshape = ShapeSpec("tiny_dec", seq_len=64, global_batch=4,
                           kind="decode")
        bsd = steps.input_specs(cfg, dshape)
        bsp = shlib.tree_shardings(steps.batch_specs(cfg, bsd), bsd, mesh)
        serve = steps.make_serve_step(cfg)
        c2 = jax.jit(serve,
                     in_shardings=(state_sh["params"], bsp["caches"],
                                   bsp["tokens_t"], bsp["pos"]),
                     out_shardings=(None, bsp["caches"])).lower(
            state_sds["params"], bsd["caches"], bsd["tokens_t"],
            bsd["pos"]).compile()
        assert analyze(c2.as_text())["flops"] > 0
    print(f"{arch} OK")
print("DRYRUN-INTEGRATION-OK")
"""


@pytest.mark.slow
def test_dryrun_lowering_on_small_mesh():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = f"{ROOT / 'src'}{os.pathsep}" + env.get(
        "PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", _WORKER], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, f"stdout:{out.stdout}\nstderr:{out.stderr}"
    assert "DRYRUN-INTEGRATION-OK" in out.stdout
