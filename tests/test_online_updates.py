"""Online posterior updates: the Stats fold/downdate algebra and the
``SGPR.update`` / ``SGPR.forget`` continual-learning loop built on it.

The paper's bound depends on the data only through sufficient statistics
that are ADDITIVE across data blocks — the same decoupling that shards the
map step spatially also folds blocks temporally.  These tests pin the two
identities everything else rests on,

    fold_stats(stats(A), stats(B)) == stats(A ∪ B)           (exactness)
    downdate_stats(fold_stats(S, Δ), Δ) == S                 (invertibility)

to f64 across the kernel zoo, zero-weight padding, the latent (GPLVM)
statistics, and both kernel backends — deterministically, and (when
hypothesis is installed — the CI statistical job) over randomly drawn
block sizes and kernels.  On top of the algebra: end-to-end
``update()``-then-``predict()`` == retrain-from-scratch parity, exact
``forget`` round-trips, and the stale-cache regression tests — after an
``update()`` the serving engine must answer from the refreshed state, and
``fit``/``fit_svi`` must drop every posterior cache.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import SGPR, init_utils
from repro.core.covariance import (SEARD, Linear, Matern32, Periodic,
                                   Product, Sum)
from repro.core.stats import (Stats, downdate_stats, fold_stats,
                              partial_stats, zero_stats)

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:        # tier-1 container: deterministic tests only
    HAVE_HYPOTHESIS = False

# The PR-6 kernel zoo: primitives and compositions (disjoint + overlapping
# dims exercise every psi/reg code path that feeds the statistics).
KERNELS = {
    "se": SEARD(),
    "matern32": Matern32(dims=(0, 1), quad_order=11),
    "linear": Linear(),
    "periodic": Periodic(dims=(1,), quad_order=15),
    "sum": Sum(SEARD(dims=(0,)), Linear(dims=(1,))),
    "product": Product(SEARD(dims=(0,)), Matern32(dims=(1,))),
}


def _setup(seed, n, m=6, q=2, d=2, kernel=None, latent=False):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((n, q)))
    y = jnp.asarray(rng.standard_normal((n, d)))
    z = jnp.asarray(rng.standard_normal((m, q)))
    s = jnp.asarray(rng.uniform(0.05, 0.5, (n, q))) if latent else None
    hyp = jax.tree.map(jnp.asarray,
                       init_utils.default_hyp_for(kernel or SEARD(),
                                                  np.asarray(y), q))
    return rng, hyp, z, x, y, s


def _assert_stats_close(got: Stats, ref: Stats, rtol=1e-12, atol=1e-12):
    for name, g, r in zip(Stats._fields, got, ref):
        np.testing.assert_allclose(np.asarray(g), np.asarray(r),
                                   rtol=rtol, atol=atol, err_msg=name)


# ---------------------------------------------------------------------------
# the fold/downdate algebra (deterministic, runs everywhere)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", sorted(KERNELS))
def test_fold_equals_union_scan(name):
    """fold(stats(A), stats(B)) == stats(A∪B) for every kernel expression."""
    kern = KERNELS[name]
    _, hyp, z, x, y, _ = _setup(11, n=37, kernel=kern)
    na = 21
    st_a = partial_stats(hyp, z, y[:na], x[:na], s=None, latent=False,
                         kernel=kern)
    st_b = partial_stats(hyp, z, y[na:], x[na:], s=None, latent=False,
                         kernel=kern)
    st_union = partial_stats(hyp, z, y, x, s=None, latent=False, kernel=kern)
    _assert_stats_close(fold_stats(st_a, st_b), st_union)
    # fold is symmetric and zero_stats is its identity
    _assert_stats_close(fold_stats(st_b, st_a), st_union)
    m, d = z.shape[0], y.shape[1]
    _assert_stats_close(fold_stats(zero_stats(m, d), st_union), st_union,
                        rtol=0, atol=0)


@pytest.mark.parametrize("name", sorted(KERNELS))
def test_downdate_undoes_fold(name):
    kern = KERNELS[name]
    _, hyp, z, x, y, _ = _setup(12, n=30, kernel=kern)
    base = partial_stats(hyp, z, y[:18], x[:18], s=None, latent=False,
                         kernel=kern)
    delta = partial_stats(hyp, z, y[18:], x[18:], s=None, latent=False,
                          kernel=kern)
    back = downdate_stats(fold_stats(base, delta), delta)
    _assert_stats_close(back, base, rtol=1e-13, atol=1e-13)


def test_fold_with_zero_weight_padding_is_exact():
    """Padded blocks (zero-weight rows) fold identically to unpadded ones —
    the property the distributed fold relies on for ragged shards."""
    _, hyp, z, x, y, _ = _setup(13, n=24)
    na = 15
    pad = 5
    w_b = jnp.asarray([1.0] * (24 - na) + [0.0] * pad)
    xb = jnp.concatenate([x[na:], jnp.ones((pad, x.shape[1]))])
    yb = jnp.concatenate([y[na:], jnp.full((pad, y.shape[1]), 7.0)])
    st_a = partial_stats(hyp, z, y[:na], x[:na], s=None, latent=False)
    st_b_pad = partial_stats(hyp, z, yb, xb, s=None, weights=w_b,
                             latent=False)
    st_union = partial_stats(hyp, z, y, x, s=None, latent=False)
    _assert_stats_close(fold_stats(st_a, st_b_pad), st_union,
                        rtol=1e-14, atol=1e-14)
    assert float(st_b_pad.n) == 24 - na      # padding never counts


def test_latent_stats_fold_including_kl():
    """GPLVM-side statistics (psi moments + the KL term) are additive too."""
    _, hyp, z, x, y, s = _setup(14, n=26, latent=True)
    na = 11
    st_a = partial_stats(hyp, z, y[:na], x[:na], s=s[:na], latent=True)
    st_b = partial_stats(hyp, z, y[na:], x[na:], s=s[na:], latent=True)
    st_union = partial_stats(hyp, z, y, x, s=s, latent=True)
    folded = fold_stats(st_a, st_b)
    _assert_stats_close(folded, st_union)
    assert float(folded.KL) > 0.0
    _assert_stats_close(downdate_stats(folded, st_b), st_a,
                        rtol=1e-13, atol=1e-13)


# ---------------------------------------------------------------------------
# property tests (hypothesis — CI statistical job; deterministic twins above)
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:
    _names = sorted(KERNELS)

    @pytest.mark.statistical
    @settings(max_examples=20, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1), n_a=st.integers(1, 24),
           n_b=st.integers(1, 24), pad=st.integers(0, 5),
           ki=st.integers(0, len(_names) - 1))
    def test_property_fold_equals_union_scan(seed, n_a, n_b, pad, ki):
        """For ANY split/padding/kernel: folding block stats == one scan."""
        kern = KERNELS[_names[ki]]
        rng, hyp, z, x, y, _ = _setup(seed % 2**16, n=n_a + n_b, kernel=kern)
        xb = jnp.concatenate(
            [x[n_a:], jnp.asarray(rng.standard_normal((pad, x.shape[1])))])
        yb = jnp.concatenate(
            [y[n_a:], jnp.asarray(rng.standard_normal((pad, y.shape[1])))])
        w = jnp.asarray([1.0] * n_b + [0.0] * pad)
        st_a = partial_stats(hyp, z, y[:n_a], x[:n_a], s=None, latent=False,
                             kernel=kern)
        st_b = partial_stats(hyp, z, yb, xb, s=None, weights=w, latent=False,
                             kernel=kern)
        st_union = partial_stats(hyp, z, y, x, s=None, latent=False,
                                 kernel=kern)
        _assert_stats_close(fold_stats(st_a, st_b), st_union,
                            rtol=1e-11, atol=1e-11)

    @pytest.mark.statistical
    @settings(max_examples=20, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1), n_base=st.integers(1, 30),
           k=st.integers(1, 12), ki=st.integers(0, len(_names) - 1))
    def test_property_downdate_fold_is_identity(seed, n_base, k, ki):
        kern = KERNELS[_names[ki]]
        _, hyp, z, x, y, _ = _setup(seed % 2**16, n=n_base + k, kernel=kern)
        base = partial_stats(hyp, z, y[:n_base], x[:n_base], s=None,
                             latent=False, kernel=kern)
        delta = partial_stats(hyp, z, y[n_base:], x[n_base:], s=None,
                              latent=False, kernel=kern)
        _assert_stats_close(downdate_stats(fold_stats(base, delta), delta),
                            base, rtol=1e-11, atol=1e-11)


# ---------------------------------------------------------------------------
# end-to-end: SGPR.update / forget vs retrain-from-scratch
# ---------------------------------------------------------------------------

def _fresh_like(mdl, x, y):
    """An SGPR built from scratch on (x, y) with mdl's params — the
    full-rescan reference an incremental update must match."""
    ref = SGPR(np.asarray(x), np.asarray(y),
               num_inducing=mdl.params["z"].shape[0],
               z=np.asarray(mdl.params["z"]), kernel=mdl.kernel)
    ref.params = mdl.params
    return ref


@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_update_then_predict_matches_retrain(rng, backend):
    n, k, q, d = 48, 9, 2, 2
    x = rng.standard_normal((n, q)); y = rng.standard_normal((n, d))
    xb = rng.standard_normal((k, q)); yb = rng.standard_normal((k, d))
    mdl = SGPR(x, y, num_inducing=7, kernel_backend=backend)
    xs = rng.standard_normal((17, q))
    mdl.predict(xs)                      # warm every cache pre-update
    block = mdl.update(xb, yb)
    assert block == 1 and mdl.num_blocks == 2 and mdl.n == n + k
    ref = _fresh_like(mdl, np.vstack([x, xb]), np.vstack([y, yb]))
    m_up, v_up = mdl.predict(xs, include_noise=True)
    m_ref, v_ref = ref.predict(xs, include_noise=True)
    np.testing.assert_allclose(m_up, m_ref, rtol=1e-9, atol=1e-10)
    np.testing.assert_allclose(v_up, v_ref, rtol=1e-9, atol=1e-10)
    # the folded statistics drive the exact bound too
    assert abs(mdl.log_bound() - ref.log_bound()) < 1e-9 * abs(ref.log_bound())


@pytest.mark.parametrize("name", ["matern32", "sum"])
def test_update_composes_with_kernel_zoo(rng, name):
    kern = KERNELS[name]
    x = rng.standard_normal((30, 2)); y = rng.standard_normal((30, 2))
    xb = rng.standard_normal((6, 2)); yb = rng.standard_normal((6, 2))
    mdl = SGPR(x, y, num_inducing=6, kernel=kern)
    mdl.predict(rng.standard_normal((5, 2)))
    mdl.update(xb, yb)
    ref = _fresh_like(mdl, np.vstack([x, xb]), np.vstack([y, yb]))
    xs = rng.standard_normal((9, 2))
    np.testing.assert_allclose(mdl.predict(xs)[0], ref.predict(xs)[0],
                               rtol=1e-8, atol=1e-9)


def test_forget_roundtrip_restores_original(rng):
    x = rng.standard_normal((40, 2)); y = rng.standard_normal((40, 2))
    xb = rng.standard_normal((8, 2)); yb = rng.standard_normal((8, 2))
    mdl = SGPR(x, y, num_inducing=6)
    xs = rng.standard_normal((13, 2))
    m0, v0 = mdl.predict(xs)
    block = mdl.update(xb, yb)
    xr, yr = mdl.forget(block)
    np.testing.assert_array_equal(xr, xb)
    np.testing.assert_array_equal(yr, yb)
    assert mdl.num_blocks == 1 and mdl.n == 40
    m1, v1 = mdl.predict(xs)
    np.testing.assert_allclose(m1, m0, rtol=1e-10, atol=1e-12)
    np.testing.assert_allclose(v1, v0, rtol=1e-10, atol=1e-12)


def test_forget_renumbers_and_supports_negative_index(rng):
    x = rng.standard_normal((25, 2)); y = rng.standard_normal((25, 2))
    mdl = SGPR(x, y, num_inducing=5)
    b1x = rng.standard_normal((4, 2)); b1y = rng.standard_normal((4, 2))
    b2x = rng.standard_normal((6, 2)); b2y = rng.standard_normal((6, 2))
    mdl.update(b1x, b1y)
    mdl.update(b2x, b2y)
    assert mdl.num_blocks == 3
    xr, _ = mdl.forget(1)                # drop the middle block
    np.testing.assert_array_equal(xr, b1x)
    assert mdl.num_blocks == 2 and mdl.n == 25 + 6
    xr2, _ = mdl.forget(-1)              # negative index = newest block
    np.testing.assert_array_equal(xr2, b2x)
    assert mdl.num_blocks == 1 and mdl.n == 25
    with pytest.raises(IndexError, match="out of range"):
        mdl.forget(5)


def test_update_validates_shapes(rng):
    mdl = SGPR(rng.standard_normal((20, 2)), rng.standard_normal((20, 2)),
               num_inducing=4)
    with pytest.raises(ValueError, match="row mismatch"):
        mdl.update(rng.standard_normal((3, 2)), rng.standard_normal((4, 2)))
    with pytest.raises(ValueError, match="expected"):
        mdl.update(rng.standard_normal((3, 5)), rng.standard_normal((3, 2)))


# ---------------------------------------------------------------------------
# stale-cache regression: update/forget/fit must never serve old factors
# ---------------------------------------------------------------------------

def test_engine_serves_refreshed_state_after_update(rng):
    """The live engine after ``update()`` must (a) be the SAME engine object
    (state swapped in place — no recompilation) and (b) hold exactly the
    refreshed state, so a stale cached posterior is structurally
    impossible."""
    x = rng.standard_normal((30, 2)); y = rng.standard_normal((30, 2))
    mdl = SGPR(x, y, num_inducing=5)
    xs = rng.standard_normal((7, 2))
    stale_mean, _ = mdl.predict(xs)
    engine_before = mdl._engine_cache
    assert engine_before is not None
    mdl.update(rng.standard_normal((5, 2)), rng.standard_normal((5, 2)))
    assert mdl._engine_cache is engine_before           # swapped, not rebuilt
    assert mdl._engine_cache.state is mdl._pstate_cache  # single truth
    assert mdl._pstate_cache is not None
    fresh = _fresh_like(mdl, mdl.x, mdl.y)
    np.testing.assert_allclose(mdl.predict(xs)[0], fresh.predict(xs)[0],
                               rtol=1e-9, atol=1e-10)
    assert not np.allclose(mdl.predict(xs)[0], stale_mean)


def test_forget_also_refreshes_live_engine(rng):
    x = rng.standard_normal((30, 2)); y = rng.standard_normal((30, 2))
    mdl = SGPR(x, y, num_inducing=5)
    xs = rng.standard_normal((7, 2))
    m0, _ = mdl.predict(xs)
    b = mdl.update(rng.standard_normal((5, 2)), rng.standard_normal((5, 2)))
    eng = mdl._engine_cache
    mdl.forget(b)
    assert mdl._engine_cache is eng
    assert mdl._engine_cache.state is mdl._pstate_cache
    np.testing.assert_allclose(mdl.predict(xs)[0], m0, rtol=1e-10, atol=1e-12)


def test_fit_drops_every_posterior_cache(rng):
    x = rng.standard_normal((25, 2)); y = rng.standard_normal((25, 2))
    mdl = SGPR(x, y, num_inducing=4)
    mdl.predict(rng.standard_normal((3, 2)))
    assert mdl._stats_cache is not None and mdl._engine_cache is not None
    mdl.fit(max_iters=2)
    assert mdl._stats_cache is None
    assert mdl._pstate_cache is None
    assert mdl._engine_cache is None


def test_update_before_any_predict_needs_no_state(rng):
    """update() on a cold model folds stats only — the PredictiveState is
    built lazily on the first predict, from the folded stats."""
    x = rng.standard_normal((30, 2)); y = rng.standard_normal((30, 2))
    xb = rng.standard_normal((4, 2)); yb = rng.standard_normal((4, 2))
    mdl = SGPR(x, y, num_inducing=5)
    mdl.update(xb, yb)
    assert mdl._pstate_cache is None and mdl._engine_cache is None
    ref = _fresh_like(mdl, np.vstack([x, xb]), np.vstack([y, yb]))
    xs = rng.standard_normal((6, 2))
    np.testing.assert_allclose(mdl.predict(xs)[0], ref.predict(xs)[0],
                               rtol=1e-9, atol=1e-10)


def test_gplvm_shares_the_invalidation_helper(rng):
    """BayesianGPLVM rides the same PosteriorCacheMixin: stats memoise and
    the shared _invalidate_posterior clears them."""
    from repro.core import BayesianGPLVM

    y = rng.standard_normal((20, 3))
    mdl = BayesianGPLVM(y, 2, num_inducing=4)
    st1 = mdl._stats()
    assert mdl._stats() is st1                       # memoised
    mdl._invalidate_posterior()
    assert mdl._stats_cache is None
    st2 = mdl._stats()
    assert st2 is not st1
