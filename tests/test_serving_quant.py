"""Quantized serving states: accuracy report + dtype-tagged persistence.

The PredictiveState is the ONLY artifact shipped to servers, so its dtype
is the wire format: `astype` quantizes it, the checkpoint sidecar records
the dtype (so `load_state` needs no template), and the engine upcasts the
stored factors once to its compute dtype.  These tests pin down (1) the
round-trip is bit-exact at every dtype — including bf16, which npz cannot
natively represent — and (2) the accuracy cost of bf16 stays inside the
budget documented in docs/serving.md.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import SGPR
from repro.serve import PredictEngine, load_state, save_state

from conftest import make_regression

# The documented serving accuracy budget for a bf16-quantized state on the
# synthetic regression problem (docs/serving.md, "Quantized states"):
# measured ~5e-3 relative mean RMSE / ~6e-4 variance RMSE; budgeted at 4x.
BF16_MEAN_RMSE_BUDGET = 2e-2    # relative to std(y)
BF16_VAR_RMSE_BUDGET = 5e-3


@pytest.fixture(scope="module")
def fitted():
    """One fitted model shared by the report tests (fit cost paid once)."""
    rng = np.random.default_rng(0)
    x, y = make_regression(rng, n=120, q=2, d=2)
    model = SGPR(x, y, num_inducing=10, seed=0)
    model.fit(max_iters=40)
    xs = rng.uniform(-2.0, 2.0, size=(200, 2))
    return model, np.asarray(y), xs


@pytest.mark.parametrize("dtype", ["float64", "float32", "float16",
                                   "bfloat16"])
def test_roundtrip_records_dtype_and_is_bit_exact(fitted, tmp_path, dtype):
    """save_state/load_state at every dtype: the sidecar carries the dtype,
    every leaf survives bitwise (incl. bf16 via the uint16 npz view), and
    the restored state serves identically."""
    model, _, xs = fitted
    state = model.predictive_state().astype(dtype)
    save_state(tmp_path / f"st_{dtype}", state, metadata={"fmt": dtype})
    loaded, md = load_state(tmp_path / f"st_{dtype}")
    assert md["dtype"] == dtype and md["fmt"] == dtype
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(loaded)):
        assert a.dtype == b.dtype == jnp.dtype(dtype)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    m0, v0 = PredictEngine(state, block_size=64).predict(xs)
    m1, v1 = PredictEngine(loaded, block_size=64).predict(xs)
    np.testing.assert_array_equal(np.asarray(m0), np.asarray(m1))
    np.testing.assert_array_equal(np.asarray(v0), np.asarray(v1))


def test_bf16_serving_rmse_within_budget(fitted):
    """The accuracy report the ROADMAP asks for: bf16 state (quarter the
    f64 bytes) serves the synthetic regression problem within the
    documented RMSE budget vs the f64 reference."""
    model, y, xs = fitted
    state = model.predictive_state()
    m64, v64 = PredictEngine(state, block_size=64).predict(xs)
    q = state.astype(jnp.bfloat16)
    assert q.nbytes * 4 == state.nbytes
    eng = PredictEngine(q, block_size=64)
    assert eng.compute_dtype == jnp.float32    # storage low, accumulate f32
    mq, vq = eng.predict(xs)
    ystd = float(np.std(y))
    mean_rmse = float(np.sqrt(np.mean(
        (np.asarray(mq, np.float64) - np.asarray(m64)) ** 2))) / ystd
    var_rmse = float(np.sqrt(np.mean(
        (np.asarray(vq, np.float64) - np.asarray(v64)) ** 2)))
    assert mean_rmse < BF16_MEAN_RMSE_BUDGET, \
        f"bf16 mean RMSE {mean_rmse:.2e} blew the documented budget"
    assert var_rmse < BF16_VAR_RMSE_BUDGET, \
        f"bf16 var RMSE {var_rmse:.2e} blew the documented budget"


def test_compute_dtype_resolution(fitted):
    """Default compute dtype: f32/f64 states keep their width, sub-f32
    states lift to f32; an explicit compute_dtype always wins."""
    model, _, _ = fitted
    state = model.predictive_state()
    assert PredictEngine(state).compute_dtype == jnp.float64
    assert PredictEngine(state.astype(jnp.float32)).compute_dtype == jnp.float32
    assert PredictEngine(state.astype(jnp.bfloat16)).compute_dtype == jnp.float32
    assert PredictEngine(state.astype(jnp.float16)).compute_dtype == jnp.float32
    eng = PredictEngine(state.astype(jnp.bfloat16),
                        compute_dtype=jnp.float64)
    assert eng.compute_dtype == jnp.float64
    # The stored artifact keeps its own dtype either way.
    assert eng.state.z.dtype == jnp.bfloat16


def test_quantized_engine_outputs_compute_dtype(fitted):
    """Outputs come back in the engine's compute dtype (f32 for a bf16
    state) and stay finite/sane vs the f64 reference."""
    model, _, xs = fitted
    state = model.predictive_state()
    m64, _ = PredictEngine(state, block_size=64).predict(xs)
    eng = PredictEngine(state.astype(jnp.bfloat16), block_size=64)
    mean, var = eng.predict(xs, include_noise=True)
    assert mean.dtype == jnp.float32 and var.dtype == jnp.float32
    assert bool(jnp.isfinite(mean).all()) and bool(jnp.isfinite(var).all())
    # bf16 storage error is bounded — nothing catastrophic happened.
    assert float(jnp.max(jnp.abs(mean.astype(jnp.float64) - m64))) < 0.5


def test_quantization_error_monotone_in_mantissa(fitted):
    """Fixed-problem precision ladder (hypothesis-free twin of the property
    test in test_serving_props.py): storage error is monotone in mantissa
    bits — bf16 (7) > f16 (10) > f32 (23) > f64 (52, identically zero)."""
    model, _, xs = fitted
    state = model.predictive_state()
    m64, v64 = (jnp.asarray(a) for a in
                PredictEngine(state, block_size=64).predict(xs))
    errs = {}
    for dt in ("bfloat16", "float16", "float32", "float64"):
        mq, vq = PredictEngine(state.astype(dt), block_size=64).predict(xs)
        errs[dt] = (
            float(jnp.sqrt(jnp.mean((mq.astype(jnp.float64) - m64) ** 2))),
            float(jnp.sqrt(jnp.mean((vq.astype(jnp.float64) - v64) ** 2))))
    for kind in (0, 1):
        assert errs["bfloat16"][kind] > errs["float16"][kind] > \
            errs["float32"][kind] >= errs["float64"][kind]
    assert errs["float64"] == (0.0, 0.0)


def test_pallas_backend_serves_quantized_state(fitted):
    """kernel_backend="pallas" accepts a quantized state: the dtype-general
    tiles run at the engine's compute width (f32+ — never half precision),
    and stay close to the XLA path on the same quantized state."""
    model, _, xs = fitted
    state16 = model.predictive_state().astype(jnp.bfloat16)
    eng_p = PredictEngine(state16, block_size=32, kernel_backend="pallas")
    eng_x = PredictEngine(state16, block_size=32)
    mp, vp = eng_p.predict(xs)
    mx, vx = eng_x.predict(xs)
    # Same f32 compute width, different expression forms (the kernel's ARD
    # exponent refactoring) — agreement is f32 rounding, not bitwise.
    np.testing.assert_allclose(np.asarray(mp), np.asarray(mx),
                               rtol=1e-3, atol=1e-5)
    np.testing.assert_allclose(np.asarray(vp), np.asarray(vx),
                               rtol=1e-3, atol=1e-5)
