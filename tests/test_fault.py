"""Fault-tolerance utilities (distributed/fault.py): the failure-mask
invariants the training loop leans on.

``FailureSimulator.mask`` may kill shards but never the whole fleet (the
paper's drop mode needs at least one surviving partial sum);
``apply_gradient_masking``'s rescale is exactly drop * n/n_live; and both
are deterministic under a fixed seed — reruns of a failure experiment must
replay the same failure schedule.
"""
import numpy as np
import pytest

import jax

from repro.distributed.fault import (FailureSimulator, StepTimer,
                                     apply_gradient_masking)


@pytest.mark.parametrize("rate", [0.0, 0.3, 1.0])
def test_mask_never_all_dead(rate):
    sim = FailureSimulator(n_shards=6, rate=rate, seed=0)
    for _ in range(50):
        m = sim.mask()
        assert m.shape == (6,) and m.dtype == np.float64
        assert set(np.unique(m)) <= {0.0, 1.0}
        assert m.sum() >= 1.0, "every shard died in one iteration"
    if rate == 0.0:
        assert sim.mask().sum() == 6.0
    if rate == 1.0:
        assert sim.mask().sum() == 1.0   # exactly the resurrected survivor


def test_mask_seeded_determinism():
    a = [FailureSimulator(5, 0.4, seed=7).mask() for _ in range(1)]
    sim1, sim2 = FailureSimulator(5, 0.4, seed=7), FailureSimulator(5, 0.4,
                                                                    seed=7)
    seq1 = np.stack([sim1.mask() for _ in range(20)])
    seq2 = np.stack([sim2.mask() for _ in range(20)])
    np.testing.assert_array_equal(seq1, seq2)
    seq3 = np.stack([FailureSimulator(5, 0.4, seed=8).mask()
                     for _ in range(20)])
    assert not np.array_equal(seq1, seq3)
    assert np.array_equal(a[0], seq1[0])


def _grad_shards(rng, n_shards=5):
    return [{"w": rng.standard_normal((3, 2)),
             "b": rng.standard_normal(4)} for _ in range(n_shards)]


def test_masking_drop_sums_survivors(rng):
    shards = _grad_shards(rng)
    mask = np.array([1.0, 0.0, 1.0, 1.0, 0.0])
    out = apply_gradient_masking(shards, mask, mode="drop")
    for k in ("w", "b"):
        ref = sum(s[k] for s, m in zip(shards, mask) if m > 0)
        np.testing.assert_allclose(out[k], ref, rtol=1e-15)


def test_masking_rescale_is_drop_times_n_over_nlive(rng):
    shards = _grad_shards(rng)
    mask = np.array([1.0, 0.0, 1.0, 0.0, 1.0])
    drop = apply_gradient_masking(shards, mask, mode="drop")
    resc = apply_gradient_masking(shards, mask, mode="rescale")
    c = len(shards) / mask.sum()
    for a, b in zip(jax.tree.leaves(resc), jax.tree.leaves(drop)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b) * c,
                                   rtol=1e-15)
    # no failures: the two modes coincide
    full = np.ones(len(shards))
    d0 = apply_gradient_masking(shards, full, mode="drop")
    r0 = apply_gradient_masking(shards, full, mode="rescale")
    for a, b in zip(jax.tree.leaves(d0), jax.tree.leaves(r0)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("dtype", [np.float64, np.float32])
def test_mask_dtype_threaded_and_rate1_property(dtype):
    """Explicit mask dtype is honored, and the never-all-dead invariant
    holds under the worst case rate=1.0 for every seed (property test):
    exactly one resurrected survivor, still in the requested dtype."""
    for seed in range(40):
        sim = FailureSimulator(n_shards=7, rate=1.0, seed=seed, dtype=dtype)
        for _ in range(5):
            m = sim.mask()
            assert m.dtype == np.dtype(dtype)
            assert set(np.unique(m)) <= {0.0, 1.0}
            assert m.sum() == 1.0      # all die, one is resurrected
    # default stays float64 (back-compat with the f64 weight path)
    assert FailureSimulator(3, 0.5).mask().dtype == np.float64


def test_masking_rescale_ragged_rows_matches_in_mesh(rng):
    """Regression for the shard-count rescale bug: with ragged shards the
    factor must be the ROW ratio n/n_live — the same factor the in-mesh
    ``failure_mode='rescale'`` path applies — not the shard-count ratio."""
    shards = _grad_shards(rng, n_shards=4)
    rows = np.array([8.0, 8.0, 8.0, 3.0])   # ragged final shard
    mask = np.array([1.0, 1.0, 0.0, 1.0])
    drop = apply_gradient_masking(shards, mask, mode="drop")
    resc = apply_gradient_masking(shards, mask, mode="rescale", rows=rows)
    c = rows.sum() / (rows * mask).sum()     # n / n_live — in-mesh factor
    for a, b in zip(jax.tree.leaves(resc), jax.tree.leaves(drop)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b) * c,
                                   rtol=1e-15)
    # shard-count factor would be 4/3 — assert the bug is actually gone
    assert not np.isclose(c, len(shards) / mask.sum())
    # equal rows: row ratio degenerates to the (previously hardcoded)
    # shard-count ratio, so rows=None keeps its old equal-shard meaning
    eq = np.full(4, 5.0)
    r1 = apply_gradient_masking(shards, mask, mode="rescale", rows=eq)
    r2 = apply_gradient_masking(shards, mask, mode="rescale")
    for a, b in zip(jax.tree.leaves(r1), jax.tree.leaves(r2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-15)
    with pytest.raises(ValueError, match="rows must have shape"):
        apply_gradient_masking(shards, mask, mode="rescale",
                               rows=np.ones(3))
    with pytest.raises(ValueError, match="all shards masked dead"):
        apply_gradient_masking(shards, np.zeros(4), mode="drop")


def test_step_timer_summary():
    t = StepTimer()
    assert t.summary() == {}
    t.record([1.0, 2.0, 3.0])
    t.record([2.0, 2.0, 2.0])
    s = t.summary()
    assert s["min"] == 1.5 and s["max"] == 2.5 and s["mean"] == 2.0
    # straggler overhead: mean over iters of max/mean - 1
    np.testing.assert_allclose(s["straggler_overhead"], (0.5 + 0.0) / 2)
    outs = t.time_shards([lambda: 1, lambda: 2])
    assert outs == [1, 2] and len(t.records) == 3


def test_step_timer_ragged_records():
    """Elastic membership records different shard counts per iteration —
    summary must reduce per row instead of crashing on the object array
    np.asarray builds from ragged lists."""
    t = StepTimer()
    t.record([1.0, 2.0, 3.0])
    t.record([4.0])                       # one surviving shard
    t.record([2.0, 4.0])
    s = t.summary()
    np.testing.assert_allclose(s["min"], (1.0 + 4.0 + 2.0) / 3)
    np.testing.assert_allclose(s["max"], (3.0 + 4.0 + 4.0) / 3)
    np.testing.assert_allclose(s["mean"], (2.0 + 4.0 + 3.0) / 3)
    np.testing.assert_allclose(
        s["straggler_overhead"],
        ((3.0 / 2.0 - 1) + 0.0 + (4.0 / 3.0 - 1)) / 3)
    with pytest.raises(ValueError, match="at least one shard time"):
        t.record([])
    assert len(t.records) == 3            # the rejected row was not kept
