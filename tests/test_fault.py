"""Fault-tolerance utilities (distributed/fault.py): the failure-mask
invariants the training loop leans on.

``FailureSimulator.mask`` may kill shards but never the whole fleet (the
paper's drop mode needs at least one surviving partial sum);
``apply_gradient_masking``'s rescale is exactly drop * n/n_live; and both
are deterministic under a fixed seed — reruns of a failure experiment must
replay the same failure schedule.
"""
import numpy as np
import pytest

import jax

from repro.distributed.fault import (FailureSimulator, StepTimer,
                                     apply_gradient_masking)


@pytest.mark.parametrize("rate", [0.0, 0.3, 1.0])
def test_mask_never_all_dead(rate):
    sim = FailureSimulator(n_shards=6, rate=rate, seed=0)
    for _ in range(50):
        m = sim.mask()
        assert m.shape == (6,) and m.dtype == np.float64
        assert set(np.unique(m)) <= {0.0, 1.0}
        assert m.sum() >= 1.0, "every shard died in one iteration"
    if rate == 0.0:
        assert sim.mask().sum() == 6.0
    if rate == 1.0:
        assert sim.mask().sum() == 1.0   # exactly the resurrected survivor


def test_mask_seeded_determinism():
    a = [FailureSimulator(5, 0.4, seed=7).mask() for _ in range(1)]
    sim1, sim2 = FailureSimulator(5, 0.4, seed=7), FailureSimulator(5, 0.4,
                                                                    seed=7)
    seq1 = np.stack([sim1.mask() for _ in range(20)])
    seq2 = np.stack([sim2.mask() for _ in range(20)])
    np.testing.assert_array_equal(seq1, seq2)
    seq3 = np.stack([FailureSimulator(5, 0.4, seed=8).mask()
                     for _ in range(20)])
    assert not np.array_equal(seq1, seq3)
    assert np.array_equal(a[0], seq1[0])


def _grad_shards(rng, n_shards=5):
    return [{"w": rng.standard_normal((3, 2)),
             "b": rng.standard_normal(4)} for _ in range(n_shards)]


def test_masking_drop_sums_survivors(rng):
    shards = _grad_shards(rng)
    mask = np.array([1.0, 0.0, 1.0, 1.0, 0.0])
    out = apply_gradient_masking(shards, mask, mode="drop")
    for k in ("w", "b"):
        ref = sum(s[k] for s, m in zip(shards, mask) if m > 0)
        np.testing.assert_allclose(out[k], ref, rtol=1e-15)


def test_masking_rescale_is_drop_times_n_over_nlive(rng):
    shards = _grad_shards(rng)
    mask = np.array([1.0, 0.0, 1.0, 0.0, 1.0])
    drop = apply_gradient_masking(shards, mask, mode="drop")
    resc = apply_gradient_masking(shards, mask, mode="rescale")
    c = len(shards) / mask.sum()
    for a, b in zip(jax.tree.leaves(resc), jax.tree.leaves(drop)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b) * c,
                                   rtol=1e-15)
    # no failures: the two modes coincide
    full = np.ones(len(shards))
    d0 = apply_gradient_masking(shards, full, mode="drop")
    r0 = apply_gradient_masking(shards, full, mode="rescale")
    for a, b in zip(jax.tree.leaves(d0), jax.tree.leaves(r0)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_step_timer_summary():
    t = StepTimer()
    assert t.summary() == {}
    t.record([1.0, 2.0, 3.0])
    t.record([2.0, 2.0, 2.0])
    s = t.summary()
    assert s["min"] == 1.5 and s["max"] == 2.5 and s["mean"] == 2.0
    # straggler overhead: mean over iters of max/mean - 1
    np.testing.assert_allclose(s["straggler_overhead"], (0.5 + 0.0) / 2)
    outs = t.time_shards([lambda: 1, lambda: 2])
    assert outs == [1, 2] and len(t.records) == 3
