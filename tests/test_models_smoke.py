"""Per-arch smoke tests (reduced configs): one train step + prefill +
decode step on CPU, asserting shapes and finiteness. The FULL configs are
only exercised by the dry-run (abstract lowering, no allocation)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SHAPES, all_configs, load_all
from repro.optim.adam import AdamConfig
from repro.train import steps

load_all()
ARCHS = sorted(all_configs())
# The recurrent-scan archs pay a minutes-scale CPU compile even at reduced
# config; CI runs them in the slow/statistical job, not the tier-1 gate
# (a bare `pytest` still runs everything).
_SLOW_ARCHS = {"recurrentgemma-9b"}
ARCH_PARAMS = [pytest.param(a, marks=pytest.mark.slow) if a in _SLOW_ARCHS
               else a for a in ARCHS]


def _batch(cfg, rng, b=2, t=32):
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, t)),
                              jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, t)),
                              jnp.int32),
    }
    if cfg.family == "encdec":
        batch["frames"] = jnp.asarray(
            rng.standard_normal((b, cfg.num_frames, cfg.d_model)),
            jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_PARAMS)
def test_train_step(arch, rng):
    cfg = all_configs()[arch].reduced()
    state, _ = steps.init_train_state(cfg, jax.random.PRNGKey(0))
    ts = jax.jit(steps.make_train_step(cfg, AdamConfig(warmup_steps=2)))
    batch = _batch(cfg, rng)
    state2, m = ts(state, batch)
    assert np.isfinite(float(m["loss"])), arch
    assert np.isfinite(float(m["grad_norm"])), arch
    # params actually changed
    p0 = jax.tree.leaves(state["params"])[0]
    p1 = jax.tree.leaves(state2["params"])[0]
    assert not np.allclose(np.asarray(p0), np.asarray(p1))
    # loss decreases over a few steps on repeated data (sanity of grads)
    for _ in range(5):
        state2, m2 = ts(state2, batch)
    assert float(m2["loss"]) < float(m["loss"]), arch


@pytest.mark.parametrize("arch", ARCH_PARAMS)
def test_prefill_then_decode(arch, rng):
    cfg = all_configs()[arch].reduced()
    state, _ = steps.init_train_state(cfg, jax.random.PRNGKey(1))
    params = state["params"]
    b, t = 2, 16
    batch = _batch(cfg, rng, b=b, t=t)
    prefill = jax.jit(steps.make_prefill_step(cfg))
    logits, caches = prefill(params, batch)
    assert logits.shape == (b, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits)).all(), arch

    serve = jax.jit(steps.make_serve_step(cfg))
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
    pos = jnp.full((b,), t, jnp.int32)
    logits2, caches2 = serve(params, caches, tok, pos)
    assert logits2.shape == (b, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits2)).all(), arch
    # caches moved
    flat1 = jax.tree.leaves(
        {k: v for k, v in caches.items() if k != "enc_out"})
    flat2 = jax.tree.leaves(
        {k: v for k, v in caches2.items() if k != "enc_out"})
    assert any(not np.array_equal(np.asarray(a), np.asarray(b_))
               for a, b_ in zip(flat1, flat2))


@pytest.mark.parametrize("arch", ARCH_PARAMS)
def test_decode_matches_prefill(arch, rng):
    """Teacher-forced decode over a short sequence must reproduce the
    prefill's final logits (cache path == train path)."""
    cfg = all_configs()[arch].reduced()
    state, _ = steps.init_train_state(cfg, jax.random.PRNGKey(2))
    params = state["params"]
    b, t = 1, 8
    batch = _batch(cfg, rng, b=b, t=t)

    logits_ref, _ = jax.jit(steps.make_prefill_step(cfg))(params, batch)

    # decode token-by-token from an empty cache
    from repro.models import transformer as tf
    caches = tf.init_decode_cache(cfg, b, max_len=t + 1)
    if cfg.family == "encdec":
        # fill the cross-KV cache slots from the encoder output
        enc_out = tf._encode(cfg, params, batch["frames"])
        from repro.models import attention as attn_mod
        for gi, g in enumerate(cfg.blocks):
            p_g = params["groups"][f"g{gi}"]
            if g.scan and g.count > 1:
                k, v = jax.vmap(
                    lambda pp: attn_mod.encode_kv(cfg, pp["xattn"], enc_out)
                )(p_g)
                caches[f"g{gi}"]["xk"] = k
                caches[f"g{gi}"]["xv"] = v
            else:
                k, v = attn_mod.encode_kv(cfg, p_g["xattn"], enc_out)
                caches[f"g{gi}"]["xk"] = k
                caches[f"g{gi}"]["xv"] = v
    serve = jax.jit(steps.make_serve_step(cfg))
    logits = None
    for i in range(t):
        tok = batch["tokens"][:, i:i + 1]
        pos = jnp.full((b,), i, jnp.int32)
        logits, caches = serve(params, caches, tok, pos)
    np.testing.assert_allclose(np.asarray(logits), np.asarray(logits_ref),
                               rtol=2e-2, atol=2e-2)


def test_shapes_table_complete():
    """All 40 assigned cells are defined; long_500k runs only where legal."""
    cfgs = all_configs()
    assert len(cfgs) == 10
    assert set(SHAPES) == {"train_4k", "prefill_32k", "decode_32k",
                           "long_500k"}
    long_runners = {n for n, c in cfgs.items() if c.runs_long}
    assert long_runners == {"recurrentgemma-9b", "mamba2-370m"}
