"""Constant-memory SLO accounting: the quantile sketch and the metrics.

The sketch's contract is bounded *relative* error: any in-range quantile
it reports is within ~rel_err of the exact empirical quantile, from a
fixed-size count vector.  The metrics' contract is conservation: every
admitted request ends in exactly one terminal counter, and the derived
summary numbers are pure functions of the counters/sketches.
"""
import math

import numpy as np
import pytest

from repro.serve import QuantileSketch, SLOMetrics


def test_sketch_quantiles_within_relative_error(rng):
    """p50/p90/p99 of a lognormal stream vs np.percentile: relative error
    bounded by the bucket width (~2*rel_err, plus nearest-rank slack)."""
    vals = rng.lognormal(mean=-4.0, sigma=1.0, size=20_000)   # ~ms scale
    sk = QuantileSketch(low=1e-6, high=600.0, rel_err=0.01)
    for v in vals:
        sk.add(v)
    for q in (0.5, 0.9, 0.99):
        exact = float(np.percentile(vals, 100 * q))
        got = sk.quantile(q)
        assert abs(got - exact) / exact < 0.03, (q, got, exact)


def test_sketch_exact_moments_and_edges(rng):
    vals = rng.uniform(1e-4, 1.0, size=500)
    sk = QuantileSketch()
    for v in vals:
        sk.add(v)
    assert sk.count == 500
    np.testing.assert_allclose(sk.mean, vals.mean(), rtol=1e-12)
    assert sk.min == vals.min() and sk.max == vals.max()
    # q=0 / q=1 return the exact observed extremes, not bucket midpoints
    assert sk.quantile(0.0) == vals.min()
    assert sk.quantile(1.0) <= vals.max()
    assert sk.quantile(1.0) >= vals.max() * (1 - 2 * sk.rel_err)


def test_sketch_empty_and_invalid():
    sk = QuantileSketch()
    assert sk.count == 0
    assert math.isnan(sk.quantile(0.5)) and math.isnan(sk.mean)
    assert math.isnan(sk.min) and math.isnan(sk.max)
    with pytest.raises(ValueError, match="finite"):
        sk.add(-1.0)
    with pytest.raises(ValueError, match="finite"):
        sk.add(math.nan)
    with pytest.raises(ValueError, match="quantile"):
        sk.quantile(1.5)
    with pytest.raises(ValueError, match="low < high"):
        QuantileSketch(low=1.0, high=0.5)
    with pytest.raises(ValueError, match="rel_err"):
        QuantileSketch(rel_err=1.5)


def test_sketch_under_and_overflow_buckets():
    """Values outside [low, high) land in edge buckets reported as the
    exact running min/max — never a fabricated in-range number."""
    sk = QuantileSketch(low=1e-3, high=1.0)
    for v in (0.0, 1e-9, 5.0, 7.0):
        sk.add(v)
    assert sk.quantile(0.25) == 0.0          # underflow → exact min
    assert sk.quantile(1.0) == 7.0           # overflow → exact max
    assert sk.count == 4


def test_sketch_merge_equals_combined(rng):
    a_vals = rng.lognormal(-3.0, 0.7, size=3_000)
    b_vals = rng.lognormal(-2.0, 0.7, size=5_000)
    a, b, both = QuantileSketch(), QuantileSketch(), QuantileSketch()
    for v in a_vals:
        a.add(v)
        both.add(v)
    for v in b_vals:
        b.add(v)
        both.add(v)
    assert a.merge(b) is a
    assert a.count == both.count and a.max == both.max
    np.testing.assert_allclose(a.mean, both.mean, rtol=1e-12)
    for q in (0.5, 0.99):
        assert a.quantile(q) == both.quantile(q)     # identical counts
    with pytest.raises(ValueError, match="identical"):
        a.merge(QuantileSketch(rel_err=0.05))


def test_metrics_counter_conservation():
    """submitted == completed + expired + cancelled once all requests are
    terminal; rejected requests never enter the submitted population."""
    m = SLOMetrics()
    for _ in range(6):
        m.observe_admit()
    m.observe_reject_queue_full()
    m.observe_wait(0.002)
    m.observe_flush(n_requests=3, rows=24, pad_rows=8, engine_seconds=0.001)
    for late in (False, False, True):
        m.observe_complete(0.004, late=late)
    m.observe_expired()
    m.observe_expired()
    m.observe_cancelled()
    c = m.summary()["counters"]
    assert c["submitted"] == 6
    assert c["completed"] + c["expired"] + c["cancelled"] == 6
    assert c["late"] == 1 and c["rejected_queue_full"] == 1
    assert c["flushes"] == 1 and c["flushed_rows"] == 24


def test_metrics_summary_derived_numbers():
    m = SLOMetrics()
    for _ in range(4):
        m.observe_admit()
    m.observe_flush(n_requests=4, rows=30, pad_rows=2, engine_seconds=0.003)
    for _ in range(4):
        m.observe_complete(0.01, late=False)
    s = m.snapshot().summary()
    assert s["mean_batch_requests"] == 4.0
    np.testing.assert_allclose(s["pad_fraction"], 2 / 32)
    np.testing.assert_allclose(
        s["goodput_rps"] * s["elapsed_s"], 4.0, rtol=1e-9)
    assert s["throughput_rps"] == s["goodput_rps"]   # nothing late
    assert s["engine"]["count"] == 1 and s["e2e"]["count"] == 4


def test_metrics_snapshot_is_frozen_and_independent():
    m = SLOMetrics()
    m.observe_admit()
    m.observe_complete(0.5)
    snap = m.snapshot()
    el = snap.elapsed
    m.observe_admit()
    m.observe_complete(0.7)
    assert snap.elapsed == el                        # frozen clock
    assert snap.counters["completed"] == 1           # deep copy
    assert m.counters["completed"] == 2
    assert snap.e2e.count == 1 and m.e2e.count == 2


def test_metrics_merge_across_frontends():
    a, b = SLOMetrics(), SLOMetrics()
    for m, n in ((a, 3), (b, 5)):
        for _ in range(n):
            m.observe_admit()
            m.observe_complete(0.01)
    a.merge(b)
    assert a.counters["submitted"] == 8 and a.e2e.count == 8
