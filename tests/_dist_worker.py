"""Subprocess worker: distributed-vs-sequential parity on 8 fake devices.

Run by tests/test_distributed.py with
XLA_FLAGS=--xla_force_host_platform_device_count=8 so the main pytest
process keeps its real single-device view.
"""
import os

assert "xla_force_host_platform_device_count" in os.environ.get("XLA_FLAGS", ""), \
    "worker must be launched with a placeholder device fleet"

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import DistributedGP  # noqa: E402
from repro.core.bound import collapsed_bound  # noqa: E402
from repro.core.stats import partial_stats  # noqa: E402
from repro.launch.mesh import make_compat_mesh  # noqa: E402


def main():
    assert len(jax.devices()) == 8
    mesh = make_compat_mesh((4, 2), ("data", "model"))
    rng = np.random.default_rng(7)
    n, m, q, d = 101, 9, 2, 3  # n % 8 != 0 exercises padding
    x = rng.standard_normal((n, q)); y = rng.standard_normal((n, d))
    s = rng.uniform(0.05, 0.6, (n, q))
    z = rng.standard_normal((m, q))
    hyp = {"log_sf2": jnp.asarray(0.1), "log_ell": jnp.zeros(q),
           "log_beta": jnp.asarray(0.5)}
    nf = jnp.asarray(float(n))

    # --- regression parity (value and grads) -------------------------------
    eng = DistributedGP(mesh, data_axes=("data", "model"), latent=False)
    data, w = eng.put_data(y=y, mu=x)
    vg = eng.make_value_and_grad(d, argnums=(0, 1))
    ones = jnp.ones((eng.n_shards,))
    v, (gh, gz) = vg(hyp, jnp.asarray(z), data["mu"], None, data["y"], w, ones, nf)

    def seq_neg(h, zz):
        st = partial_stats(h, zz, jnp.asarray(y), jnp.asarray(x), s=None,
                           latent=False)
        return -collapsed_bound(h, zz, st, d)

    v_ref, (gh_ref, gz_ref) = jax.value_and_grad(seq_neg, argnums=(0, 1))(
        hyp, jnp.asarray(z))
    assert abs(float(v) - float(v_ref)) < 1e-9 * abs(float(v_ref))
    np.testing.assert_allclose(np.asarray(gz), np.asarray(gz_ref), rtol=1e-8,
                               atol=1e-10)
    for k2 in gh:
        np.testing.assert_allclose(np.asarray(gh[k2]), np.asarray(gh_ref[k2]),
                                   rtol=1e-8, atol=1e-10)

    # --- latent parity ------------------------------------------------------
    engl = DistributedGP(mesh, data_axes=("data", "model"), latent=True)
    datal, wl = engl.put_data(y=y, mu=x, s=s)
    vgl = engl.make_value_and_grad(d, argnums=(0, 1, 2, 3))
    vl, _ = vgl(hyp, jnp.asarray(z), datal["mu"], datal["s"], datal["y"],
                wl, jnp.ones((engl.n_shards,)), nf)

    def seq_neg_l(h, zz):
        st = partial_stats(h, zz, jnp.asarray(y), jnp.asarray(x),
                           s=jnp.asarray(s), latent=True)
        return -collapsed_bound(h, zz, st, d)

    vl_ref = seq_neg_l(hyp, jnp.asarray(z))
    assert abs(float(vl) - float(vl_ref)) < 1e-9 * abs(float(vl_ref))

    # --- node failure: drop vs rescale --------------------------------------
    fm = jnp.ones((engl.n_shards,)).at[2].set(0.0)
    v_drop, _ = vgl(hyp, jnp.asarray(z), datal["mu"], datal["s"], datal["y"],
                    wl, fm, nf)
    eng_r = DistributedGP(mesh, data_axes=("data", "model"), latent=True,
                          failure_mode="rescale")
    vg_r = eng_r.make_value_and_grad(d, argnums=(0,))
    v_resc, _ = vg_r(hyp, jnp.asarray(z), datal["mu"], datal["s"], datal["y"],
                     wl, fm, nf)
    # rescaled objective should be closer to the true (no-failure) value
    assert abs(float(v_resc) - float(vl_ref)) <= abs(float(v_drop) - float(vl_ref))
    assert np.isfinite(float(v_drop)) and np.isfinite(float(v_resc))

    # --- elastic re-sharding: same data on a different mesh, same bound ----
    mesh2 = make_compat_mesh((8,), ("data",))
    eng2 = DistributedGP(mesh2, data_axes=("data",), latent=False)
    data2, w2 = eng2.put_data(y=y, mu=x)
    vg2 = eng2.make_value_and_grad(d, argnums=(0,))
    v2, _ = vg2(hyp, jnp.asarray(z), data2["mu"], None, data2["y"], w2,
                jnp.ones((eng2.n_shards,)), nf)
    assert abs(float(v2) - float(v_ref)) < 1e-9 * abs(float(v_ref))

    # --- streaming map (chunk_size): distributed bound/grad parity ---------
    # Regression: chunked-vs-unchunked on the same mesh, value AND grads.
    eng_c = DistributedGP(mesh, data_axes=("data", "model"), latent=False,
                          chunk_size=4)  # n_k = 13..14 rows -> several blocks
    data_c, w_c = eng_c.put_data(y=y, mu=x)
    vg_c = eng_c.make_value_and_grad(d, argnums=(0, 1))
    v_c, (gh_c, gz_c) = vg_c(hyp, jnp.asarray(z), data_c["mu"], None,
                             data_c["y"], w_c, ones, nf)
    assert abs(float(v_c) - float(v_ref)) < 1e-9 * abs(float(v_ref))
    np.testing.assert_allclose(np.asarray(gz_c), np.asarray(gz_ref),
                               rtol=1e-8, atol=1e-10)
    for k2 in gh_c:
        np.testing.assert_allclose(np.asarray(gh_c[k2]),
                                   np.asarray(gh_ref[k2]),
                                   rtol=1e-8, atol=1e-10)
    # Latent (GPLVM) path: chunked distributed bound == sequential bound.
    engl_c = DistributedGP(mesh, data_axes=("data", "model"), latent=True,
                           chunk_size=4)
    datal_c, wl_c = engl_c.put_data(y=y, mu=x, s=s)
    vgl_c = engl_c.make_value_and_grad(d, argnums=(0, 1, 2, 3))
    vl_c, gl_c = vgl_c(hyp, jnp.asarray(z), datal_c["mu"], datal_c["s"],
                       datal_c["y"], wl_c, jnp.ones((engl_c.n_shards,)), nf)
    assert abs(float(vl_c) - float(vl_ref)) < 1e-9 * abs(float(vl_ref))
    assert all(np.isfinite(np.asarray(g)).all()
               for g in jax.tree.leaves(gl_c))

    # --- minibatch-stochastic (SVI) bound on the mesh ----------------------
    # Full-batch "SVI" (batch_blocks == every shard's block count) must hit
    # the exact distributed bound: same blocks, scale 1, plus the key
    # plumbing through shard_map/psum.  n=101 on 8 shards, chunk 4 ->
    # padded to 128 -> 16 rows = 4 blocks per shard.
    eng_svi_full = DistributedGP(mesh, data_axes=("data", "model"),
                                 latent=False, chunk_size=4, batch_blocks=4)
    data_s, w_s = eng_svi_full.put_data(y=y, mu=x)
    vg_sf = eng_svi_full.make_value_and_grad(d, argnums=(0, 1))
    v_sf, (gh_sf, gz_sf) = vg_sf(hyp, jnp.asarray(z), data_s["mu"], None,
                                 data_s["y"], w_s, ones, nf,
                                 jax.random.PRNGKey(0))
    assert abs(float(v_sf) - float(v_ref)) < 1e-9 * abs(float(v_ref))
    np.testing.assert_allclose(np.asarray(gz_sf), np.asarray(gz_ref),
                               rtol=1e-8, atol=1e-10)
    # Subsampled: deterministic per key, varies across keys (shards fold the
    # step key with their flat index, so subsets differ shard-to-shard).
    eng_svi = DistributedGP(mesh, data_axes=("data", "model"), latent=False,
                            chunk_size=4, batch_blocks=2)
    vg_s = eng_svi.make_value_and_grad(d, argnums=(0, 1))
    sargs = (hyp, jnp.asarray(z), data_s["mu"], None, data_s["y"], w_s,
             ones, nf)
    vals = [float(vg_s(*sargs, jax.random.PRNGKey(k))[0]) for k in range(6)]
    assert all(np.isfinite(v) for v in vals)
    assert float(vg_s(*sargs, jax.random.PRNGKey(0))[0]) == vals[0]
    assert len(set(vals)) > 1
    # rescale + SVI: the live fraction must come from the deterministic
    # pre-sampling weights, not the stochastic reweighted count — with a
    # failed shard, full-batch SVI rescale must equal exact-scan rescale.
    eng_rs = DistributedGP(mesh, data_axes=("data", "model"), latent=False,
                           failure_mode="rescale", chunk_size=4,
                           batch_blocks=4)
    vg_rs = eng_rs.make_value_and_grad(d, argnums=(0,))
    v_rs, _ = vg_rs(hyp, jnp.asarray(z), data_s["mu"], None, data_s["y"],
                    w_s, jnp.ones((eng_rs.n_shards,)).at[2].set(0.0), nf,
                    jax.random.PRNGKey(0))
    eng_rs_ref = DistributedGP(mesh, data_axes=("data", "model"),
                               latent=False, failure_mode="rescale",
                               chunk_size=4)
    v_rs_ref, _ = eng_rs_ref.make_value_and_grad(d, argnums=(0,))(
        hyp, jnp.asarray(z), data_s["mu"], None, data_s["y"], w_s,
        jnp.ones((eng_rs_ref.n_shards,)).at[2].set(0.0), nf)
    assert abs(float(v_rs) - float(v_rs_ref)) < 1e-9 * abs(float(v_rs_ref))
    # Subsampled rescale stays finite and key-deterministic.
    eng_rs2 = DistributedGP(mesh, data_axes=("data", "model"), latent=False,
                            failure_mode="rescale", chunk_size=4,
                            batch_blocks=2)
    vg_rs2 = eng_rs2.make_value_and_grad(d, argnums=(0,))
    v_a, _ = vg_rs2(hyp, jnp.asarray(z), data_s["mu"], None, data_s["y"],
                    w_s, jnp.ones((eng_rs2.n_shards,)).at[2].set(0.0), nf,
                    jax.random.PRNGKey(1))
    v_b, _ = vg_rs2(hyp, jnp.asarray(z), data_s["mu"], None, data_s["y"],
                    w_s, jnp.ones((eng_rs2.n_shards,)).at[2].set(0.0), nf,
                    jax.random.PRNGKey(1))
    assert np.isfinite(float(v_a)) and float(v_a) == float(v_b)

    # Latent SVI on the mesh: full-batch == exact latent bound.
    engl_svi = DistributedGP(mesh, data_axes=("data", "model"), latent=True,
                             chunk_size=4, batch_blocks=4)
    datal_s, wl_s = engl_svi.put_data(y=y, mu=x, s=s)
    vgl_s = engl_svi.make_value_and_grad(d, argnums=(0, 1, 2, 3))
    vl_s, gl_s = vgl_s(hyp, jnp.asarray(z), datal_s["mu"], datal_s["s"],
                       datal_s["y"], wl_s, jnp.ones((engl_svi.n_shards,)),
                       nf, jax.random.PRNGKey(0))
    assert abs(float(vl_s) - float(vl_ref)) < 1e-9 * abs(float(vl_ref))
    assert all(np.isfinite(np.asarray(g)).all()
               for g in jax.tree.leaves(gl_s))

    # --- overlapped reduce (reduce_mode="overlap") -------------------------
    # Serial psums the whole shard-local scan's Stats once; overlap psums
    # each block's contribution inside the scan.  The two associate the
    # cross-shard/cross-block float sums differently, so on 8 real shards
    # they agree at tight f64 — NOT bitwise (that is mathematically
    # impossible; the bitwise serial==overlap contract holds on 1-device
    # meshes, tests/test_overlap_reduce.py).  Double-buffered "overlap" vs
    # per-step "overlap_eager" is a pure scheduling change folding the same
    # reduced values in the same order — THAT pair must be bitwise.
    ov = {}
    psums = {}
    for mode in ("serial", "overlap", "overlap_eager"):
        eng_m = DistributedGP(mesh, data_axes=("data", "model"),
                              latent=False, chunk_size=4, reduce_mode=mode)
        vg_m = eng_m.make_value_and_grad(d, argnums=(0, 1))
        ov[mode] = vg_m(hyp, jnp.asarray(z), data_c["mu"], None,
                        data_c["y"], w_c, ones, nf)
        psums[mode] = str(jax.make_jaxpr(eng_m.bound_fn(d))(
            hyp, jnp.asarray(z), data_c["y"], data_c["mu"], None, w_c,
            ones, nf)).count("psum")
    v_ser, (gh_ser, gz_ser) = ov["serial"]
    v_ovl, (gh_ovl, gz_ovl) = ov["overlap"]
    assert abs(float(v_ovl) - float(v_ser)) <= 1e-12 * abs(float(v_ser))
    np.testing.assert_allclose(np.asarray(gz_ovl), np.asarray(gz_ser),
                               rtol=1e-10, atol=1e-12)
    for k2 in gh_ser:
        np.testing.assert_allclose(np.asarray(gh_ovl[k2]),
                                   np.asarray(gh_ser[k2]),
                                   rtol=1e-10, atol=1e-12)
    v_egr, (gh_egr, gz_egr) = ov["overlap_eager"]
    assert float(v_ovl) == float(v_egr), "double-buffer broke bitwise parity"
    np.testing.assert_array_equal(np.asarray(gz_ovl), np.asarray(gz_egr))
    for k2 in gh_ovl:
        np.testing.assert_array_equal(np.asarray(gh_ovl[k2]),
                                      np.asarray(gh_egr[k2]))
    # Collective structure: serial = ONE psum per Stats leaf after the map;
    # eager = the same six, relocated into the scan body; buffered overlap
    # adds the post-scan flush of the last pending block — six more.
    assert psums["serial"] == 6, psums
    assert psums["overlap_eager"] == 6, psums
    assert psums["overlap"] == 12, psums
    # Latent path + full-batch SVI ride the same restructured scan.
    engl_ov = DistributedGP(mesh, data_axes=("data", "model"), latent=True,
                            chunk_size=4, reduce_mode="overlap")
    vl_ov, _ = engl_ov.make_value_and_grad(d, argnums=(0, 1, 2, 3))(
        hyp, jnp.asarray(z), datal_c["mu"], datal_c["s"], datal_c["y"],
        wl_c, jnp.ones((engl_ov.n_shards,)), nf)
    assert abs(float(vl_ov) - float(vl_c)) <= 1e-12 * abs(float(vl_c))
    eng_svi_ov = DistributedGP(mesh, data_axes=("data", "model"),
                               latent=False, chunk_size=4, batch_blocks=4,
                               reduce_mode="overlap")
    v_svi_ov, _ = eng_svi_ov.make_value_and_grad(d, argnums=(0, 1))(
        hyp, jnp.asarray(z), data_s["mu"], None, data_s["y"], w_s, ones,
        nf, jax.random.PRNGKey(0))
    assert abs(float(v_svi_ov) - float(v_sf)) <= 1e-12 * abs(float(v_sf))

    # --- serving: sharded block predict on the mesh ------------------------
    # State extracted via the distributed exact map-reduce must equal the
    # sequential extraction, and the mesh-sharded block engine must match
    # bound.predict at an odd query count (pad rows ignored on every shard).
    from repro.core.bound import optimal_qu, predict as seq_predict
    from repro.core.stats import partial_stats as _pstats
    from repro.serve import extract_state

    state = eng.predictive_state(hyp, jnp.asarray(z), data["y"], data["mu"],
                                 None, w)
    st_seq = _pstats(hyp, jnp.asarray(z), jnp.asarray(y), jnp.asarray(x),
                     s=None, latent=False)
    state_seq = extract_state(hyp, jnp.asarray(z), st_seq)
    for a, b_l in zip(jax.tree.leaves(state), jax.tree.leaves(state_seq)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_l),
                                   rtol=1e-9, atol=1e-11)

    t = 77  # odd: pads to 96 rows = 8 shards * 3 blocks of 4
    xs = jnp.asarray(rng.standard_normal((t, q)))
    qu_ref = optimal_qu(hyp, jnp.asarray(z), st_seq)
    m_ref, v_ref = seq_predict(hyp, jnp.asarray(z), qu_ref, xs,
                               include_noise=True)
    sengine = eng.predict_engine(state, block_size=4)
    assert sengine.n_shards == 8
    mean_s, var_s = sengine.predict(xs, include_noise=True)
    assert mean_s.shape == (t, d) and var_s.shape == (t,)
    np.testing.assert_allclose(np.asarray(mean_s), np.asarray(m_ref),
                               rtol=1e-8, atol=1e-10)
    np.testing.assert_allclose(np.asarray(var_s), np.asarray(v_ref),
                               rtol=1e-8, atol=1e-10)
    # Identical results from the single-device engine over the same state.
    from repro.serve import PredictEngine
    eng_1dev = PredictEngine(state, block_size=4)
    m_1dev, v_1dev = eng_1dev.predict(xs, include_noise=True)
    np.testing.assert_allclose(np.asarray(mean_s), np.asarray(m_1dev),
                               rtol=1e-12, atol=1e-14)
    np.testing.assert_allclose(np.asarray(var_s), np.asarray(v_1dev),
                               rtol=1e-12, atol=1e-14)

    # --- serving extensions: sharded sampling ------------------------------
    # Per-block PRNG keys are fold_in(key, global_block_index) — a function
    # of the block index alone, so the 8-shard engine (77 queries pad to 96)
    # must draw BIT-IDENTICAL samples to the single-device engine (77 pad to
    # 80): the layouts agree on every real block.
    skey = jax.random.PRNGKey(5)
    smp_sh = sengine.sample(xs, 3, skey, include_noise=True)
    smp_1d = eng_1dev.sample(xs, 3, skey, include_noise=True)
    assert smp_sh.shape == (3, t, d)
    np.testing.assert_array_equal(np.asarray(smp_sh), np.asarray(smp_1d))
    assert not np.array_equal(
        np.asarray(smp_sh),
        np.asarray(sengine.sample(xs, 3, jax.random.PRNGKey(6),
                                  include_noise=True)))

    # --- serving extensions: multi-model engine on the mesh ----------------
    from repro.serve import MultiPredictEngine, extract_state as _extract
    fleet = [state,
             _extract({k2: v2 + 0.03 for k2, v2 in hyp.items()},
                      jnp.asarray(z), st_seq),
             _extract({k2: v2 - 0.05 for k2, v2 in hyp.items()},
                      jnp.asarray(z), st_seq)]
    meng = eng.multi_predict_engine(fleet, block_size=4)
    assert meng.n_shards == 8 and meng.n_models == 3
    mm_sh, vv_sh = meng.predict(xs, include_noise=True)
    assert mm_sh.shape == (3, t, d) and vv_sh.shape == (3, t)
    mm_1d, vv_1d = MultiPredictEngine(fleet, block_size=4).predict(
        xs, include_noise=True)
    np.testing.assert_allclose(np.asarray(mm_sh), np.asarray(mm_1d),
                               rtol=1e-12, atol=1e-14)
    np.testing.assert_allclose(np.asarray(vv_sh), np.asarray(vv_1d),
                               rtol=1e-12, atol=1e-14)
    # Row 0 is the original model — must match the single-model engine.
    np.testing.assert_allclose(np.asarray(mm_sh[0]), np.asarray(m_1dev),
                               rtol=1e-12, atol=1e-14)

    # --- serving extensions: the zero-collective property ------------------
    # Predictions and samples are row-local; the sharded programs must
    # contain NO psum (or any other collective reduction) — the serving
    # analogue of the paper's zero-communication map step.
    xq_p, _ = sengine.pad_queries(xs)
    jaxpr_predict = str(jax.make_jaxpr(
        lambda s_, x_: sengine._run(s_, x_))(sengine._cstate, xq_p))
    keys_p = jax.vmap(lambda i: jax.random.fold_in(skey, i))(
        jnp.arange(xq_p.shape[0] // 4))
    prog = sengine._sample_prog(3, True)
    jaxpr_sample = str(jax.make_jaxpr(
        lambda s_, x_, k_: prog(s_, x_, k_))(sengine._cstate, xq_p, keys_p))
    xq_m, _ = meng.pad_queries(xs)
    jaxpr_multi = str(jax.make_jaxpr(
        lambda s_, x_: meng._run(s_, x_))(meng._cstate, xq_m))
    for name, jx in (("predict", jaxpr_predict), ("sample", jaxpr_sample),
                     ("multi", jaxpr_multi)):
        for coll in ("psum", "all_reduce", "all_gather", "all_to_all"):
            assert coll not in jx, f"sharded {name} program contains {coll}"

    # --- online updates: distributed fold of a new sharded block -----------
    # The additive Stats decoupling works temporally as well as spatially:
    # shards map ONLY the new block, one psum reduces it, and the replicated
    # base folds in — cost independent of how much history the base holds.
    import jax.scipy.linalg as jsl
    from repro.core import chol_update
    from repro.core.stats import fold_stats, zero_stats
    from repro.serve import online

    k_new = 19  # odd → the new block pads unevenly across 8 shards
    x_new = rng.standard_normal((k_new, q))
    y_new = rng.standard_normal((k_new, d))
    new_data, w_new = eng.put_data(y=y_new, mu=x_new)
    fold = eng.update_stats_fn(d)
    red = eng.reduced_stats(d)
    mI = z.shape[0]

    # Folding into the additive identity IS the exact reduce — bitwise:
    # identical map + psum program, plus an elementwise add of zeros.
    st_zero_fold = fold(zero_stats(mI, d), hyp, jnp.asarray(z),
                        new_data["y"], new_data["mu"], None, w_new, ones)
    st_red_new = red(hyp, jnp.asarray(z), new_data["y"], new_data["mu"],
                     None, w_new, ones)
    for name, a, b_l in zip(st_zero_fold._fields, st_zero_fold, st_red_new):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b_l),
                                      err_msg=f"fold(zero) != reduce [{name}]")

    # Sharded fold == sequential fold == one scan over the union.
    base_dist = red(hyp, jnp.asarray(z), data["y"], data["mu"], None, w, ones)
    folded = fold(base_dist, hyp, jnp.asarray(z), new_data["y"],
                  new_data["mu"], None, w_new, ones)
    st_new_seq = _pstats(hyp, jnp.asarray(z), jnp.asarray(y_new),
                         jnp.asarray(x_new), s=None, latent=False)
    st_union = _pstats(hyp, jnp.asarray(z),
                       jnp.asarray(np.vstack([y, y_new])),
                       jnp.asarray(np.vstack([x, x_new])),
                       s=None, latent=False)
    seq_fold = fold_stats(base_dist, st_new_seq)
    for a, b_l, c_l in zip(folded, seq_fold, st_union):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_l),
                                   rtol=1e-12, atol=1e-12)
        np.testing.assert_allclose(np.asarray(a), np.asarray(c_l),
                                   rtol=1e-9, atol=1e-11)

    # Fold-then-extract == extract over the union scan.
    state_folded = extract_state(hyp, jnp.asarray(z), folded)
    state_union = extract_state(hyp, jnp.asarray(z), st_union)
    for a, b_l in zip(jax.tree.leaves(state_folded),
                      jax.tree.leaves(state_union)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_l),
                                   rtol=1e-8, atol=1e-9)

    # --- online updates: serve-side rank-k refresh on the mesh -------------
    xnj, ynj = jnp.asarray(x_new), jnp.asarray(y_new)
    res_up = eng.update_predictive_state(state, xnj, ynj)
    assert res_up.fallback is False
    for a, b_l in zip(jax.tree.leaves(res_up.state),
                      jax.tree.leaves(state_union)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_l),
                                   rtol=1e-8, atol=1e-9)
    res_dn = eng.downdate_predictive_state(res_up.state, xnj, ynj)
    assert res_dn.fallback is False
    for a, b_l in zip(jax.tree.leaves(res_dn.state), jax.tree.leaves(state)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_l),
                                   rtol=1e-9, atol=1e-10)

    # The refreshed state serves through the live sharded engine unchanged
    # (same executable — swap_state only moves the device buffers).
    sengine.swap_state(res_up.state)
    m_up_sh, _ = sengine.predict(xs, include_noise=True)
    eng_union = PredictEngine(state_union, block_size=4)
    m_up_ref, _ = eng_union.predict(xs, include_noise=True)
    np.testing.assert_allclose(np.asarray(m_up_sh), np.asarray(m_up_ref),
                               rtol=1e-7, atol=1e-9)

    # Zero-collective property: the ENTIRE happy-path refresh math (rank-k
    # factor update + Woodbury correction + downstream contractions) is
    # replicated local work — its jaxpr must contain no collectives, the
    # continual-learning analogue of the zero-communication serving map.
    def _refresh_math(st_, x_, y_):
        V, dC = online.block_update_factors(st_, x_, y_)
        LB_new, _ok = chol_update.chol_update_rank_k(st_.chol_sigma, V)
        y1, _, Zc = online._woodbury_correction(st_, V)
        corr, _ = online._correction_from(y1, Zc, 1.0)
        LiC = st_.chol_sigma @ st_.c2 + jsl.solve_triangular(
            st_.chol_kmm, dC, lower=True)
        return online._finish(st_, LB_new, LiC, st_.g + corr)

    jaxpr_refresh = str(jax.make_jaxpr(_refresh_math)(state, xnj, ynj))
    for coll in ("psum", "all_reduce", "all_gather", "all_to_all"):
        assert coll not in jaxpr_refresh, \
            f"serve-side refresh math contains {coll}"
    # ...while the training-side fold contains exactly the one psum family
    # it is allowed (the constant-size Stats reduction).
    assert "psum" in str(jax.make_jaxpr(
        lambda *a: fold(*a))(zero_stats(mI, d), hyp, jnp.asarray(z),
                             new_data["y"], new_data["mu"], None, w_new,
                             ones))

    # --- host-streaming ingestion on the mesh ------------------------------
    # Chunks are staged host->mesh and folded through the sharded carry;
    # the result must be BITWISE the in-memory reduction (same blocks, same
    # scan, same single psum), and the per-chunk fold program must contain
    # NO collective — all communication stays in the final constant-size
    # reduce, the streaming analogue of the zero-communication map step.
    st_inmem = eng_c.reduced_stats(d)(hyp, jnp.asarray(z), data_c["y"],
                                      data_c["mu"], None, w_c, ones)
    bstream = eng_c.put_data(stream={"y": y, "mu": x}, blocks_per_chunk=2)
    st_str = eng_c.streamed_stats(hyp, jnp.asarray(z), bstream)
    for name, a, b_l in zip(st_str._fields, st_inmem, st_str):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b_l),
                                      err_msg=f"streamed != in-memory "
                                              f"[{name}]")
    b_inmem = eng_c.bound_fn(d)(hyp, jnp.asarray(z), data_c["y"],
                                data_c["mu"], None, w_c, ones, nf)
    b_str = eng_c.streamed_bound(hyp, jnp.asarray(z), bstream, d=d,
                                 n_full=float(n))
    assert float(b_str) == float(b_inmem), "streamed bound not bitwise"
    # streamed two-pass gradient == in-memory gradient (f64 tolerance: the
    # cotangent contractions reassociate float adds)
    v_st, (gh_st, gz_st) = eng_c.streamed_value_and_grad(d, argnums=(0, 1))(
        hyp, jnp.asarray(z), bstream, n_full=float(n))
    assert abs(float(v_st) - float(v_c)) <= 1e-12 * abs(float(v_c))
    np.testing.assert_allclose(np.asarray(gz_st), np.asarray(gz_c),
                               rtol=1e-10, atol=1e-12)
    for k2 in gh_st:
        np.testing.assert_allclose(np.asarray(gh_st[k2]),
                                   np.asarray(gh_c[k2]),
                                   rtol=1e-10, atol=1e-12)
    # latent streamed parity on the mesh
    bstream_l = engl_c.put_data(stream={"y": y, "mu": x, "s": s},
                                blocks_per_chunk=3)
    st_l_inmem = engl_c.reduced_stats(d)(hyp, jnp.asarray(z), datal_c["y"],
                                         datal_c["mu"], datal_c["s"], wl_c,
                                         ones)
    st_l_str = engl_c.streamed_stats(hyp, jnp.asarray(z), bstream_l)
    for a, b_l in zip(st_l_inmem, st_l_str):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b_l))
    # zero-collective fold: only the final reduce may communicate
    progs = eng_c._stream_progs(has_s=False)
    from repro.data.stream import stage_to_device
    arrs0, w0 = stage_to_device(eng_c.data_sharding())(bstream.chunk(0))
    carry0 = eng_c._init_stream_carry(bstream, hyp, jnp.asarray(z))
    jaxpr_fold = str(jax.make_jaxpr(
        lambda *a: progs["fold"](*a))(carry0, hyp, jnp.asarray(z),
                                      arrs0["y"], arrs0["mu"], None, w0,
                                      ones))
    for coll in ("psum", "all_reduce", "all_gather", "all_to_all"):
        assert coll not in jaxpr_fold, f"streamed fold contains {coll}"
    assert "psum" in str(jax.make_jaxpr(
        lambda c: progs["reduce"](c))(carry0))

    print("DIST-WORKER-OK")


if __name__ == "__main__":
    main()
