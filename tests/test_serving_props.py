"""Property-based tests for the serve layer (hypothesis).

Random shapes and hypers: the block engine must equal the raw query math
for ANY (t, block_size, m, d) combination — padding, tail blocks, single-row
blocks and all; the diagonal of the full covariance must equal the
diag-variance path; and quantizing the state must lose accuracy
monotonically with the storage mantissa width.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

pytest.importorskip("hypothesis", reason="property tests need hypothesis "
                    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402

from repro.core.stats import partial_stats  # noqa: E402
from repro.serve import (PredictEngine, extract_state,  # noqa: E402
                         predict_mean_var)

# Randomized (hypothesis) properties: CI runs this module in the
# statistical job, where requirements-dev is installed.
pytestmark = pytest.mark.statistical


def _random_state(seed, m, d, q=2, n=30):
    rng = np.random.default_rng(seed)
    hyp = {"log_sf2": jnp.asarray(rng.uniform(-0.5, 0.8)),
           "log_ell": jnp.asarray(rng.uniform(-0.4, 0.4, q)),
           "log_beta": jnp.asarray(rng.uniform(0.5, 2.0))}
    x = jnp.asarray(rng.standard_normal((n, q)))
    y = jnp.asarray(rng.standard_normal((n, d)))
    z = jnp.asarray(rng.standard_normal((m, q)))
    stats = partial_stats(hyp, z, y, x, s=None, latent=False)
    return extract_state(hyp, z, stats), rng


@settings(max_examples=12, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    t=st.integers(1, 33),
    block=st.integers(1, 12),
    m=st.integers(2, 10),
    d=st.integers(1, 3),
)
def test_property_engine_equals_query_math(seed, t, block, m, d):
    """For any shapes: padded block-scan predict == posterior.predict_mean_var."""
    state, rng = _random_state(seed, m, d)
    xs = jnp.asarray(rng.standard_normal((t, 2)))
    eng = PredictEngine(state, block_size=block)
    mean, var = eng.predict(xs)
    m_ref, v_ref = predict_mean_var(state, xs)
    assert mean.shape == (t, d) and var.shape == (t,)
    np.testing.assert_allclose(np.asarray(mean), np.asarray(m_ref),
                               rtol=1e-9, atol=1e-11)
    np.testing.assert_allclose(np.asarray(var), np.asarray(v_ref),
                               rtol=1e-8, atol=1e-10)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), t=st.integers(1, 20))
def test_property_full_cov_diag_equals_var(seed, t):
    """diag(cov) from the full-cov path == the diag-variance path."""
    state, rng = _random_state(seed, m=7, d=2)
    xs = jnp.asarray(rng.standard_normal((t, 2)))
    eng = PredictEngine(state, block_size=8)
    _, var = eng.predict(xs)
    _, cov = eng.predict_full_cov(xs)
    np.testing.assert_allclose(np.asarray(jnp.diagonal(cov)),
                               np.asarray(var), rtol=1e-8, atol=1e-10)
    # and with noise folded in on both paths
    _, var_n = eng.predict(xs, include_noise=True)
    _, cov_n = eng.predict_full_cov(xs, include_noise=True)
    np.testing.assert_allclose(np.asarray(jnp.diagonal(cov_n)),
                               np.asarray(var_n), rtol=1e-8, atol=1e-10)


def _quant_rmse(state, xs, mean64, var64, dtype):
    eng = PredictEngine(state.astype(dtype), block_size=16)
    mean, var = eng.predict(xs)
    m_err = float(jnp.sqrt(jnp.mean(
        (mean.astype(jnp.float64) - mean64) ** 2)))
    v_err = float(jnp.sqrt(jnp.mean(
        (var.astype(jnp.float64) - var64) ** 2)))
    return m_err, v_err


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_property_quantization_error_monotone_in_mantissa(seed):
    """For any problem: quantized-state error shrinks monotonically along
    the storage-precision ladder.

    The ladder is ordered by *mantissa bits* — what storage rounding is
    made of: bf16 (7 bits) > f16 (10) > f32 (23) > f64 (52, the reference,
    where the error is identically zero).  Both 16-bit formats are the same
    2 bytes/entry on the wire; bf16 trades mantissa for exponent range, so
    on a well-scaled state f16 is strictly the more accurate 2-byte option.
    (The fixed-problem twin of this test lives in test_serving_quant.py so
    it runs even without hypothesis.)
    """
    state, rng2 = _random_state(seed, m=9, d=3)
    xs = jnp.asarray(rng2.standard_normal((40, 2)))
    mean64, var64 = (jnp.asarray(a) for a in
                     PredictEngine(state, block_size=16).predict(xs))
    errs = {dt: _quant_rmse(state, xs, mean64, var64, dt)
            for dt in ("bfloat16", "float16", "float32", "float64")}
    for kind in (0, 1):   # mean RMSE, var RMSE
        assert errs["bfloat16"][kind] > errs["float16"][kind] > \
            errs["float32"][kind] >= errs["float64"][kind]
    # f64 "quantization" is the identity — exactly zero error.
    assert errs["float64"] == (0.0, 0.0)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_property_astype_roundtrip_through_f64_is_projection(seed):
    """Quantize -> widen -> quantize is idempotent (astype is a projection
    onto the representable grid, not an accumulating perturbation)."""
    state, _ = _random_state(seed, m=5, d=2)
    once = state.astype(jnp.bfloat16)
    twice = once.astype(jnp.float64).astype(jnp.bfloat16)
    for a, b in zip(jax.tree.leaves(once), jax.tree.leaves(twice)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
