"""Distributed engine tests.

The multi-device parity checks run in a subprocess with a placeholder
device fleet (XLA_FLAGS) so this pytest process keeps jax uninitialised
at 1 device for the smoke tests, per the launch contract.
"""
import os
import pathlib
import subprocess
import sys

import jax.numpy as jnp
import numpy as np

from repro.core import DistributedGP
from repro.core.bound import collapsed_bound
from repro.core.stats import partial_stats, reduce_stats
from repro.launch.mesh import make_compat_mesh

ROOT = pathlib.Path(__file__).resolve().parents[1]


def test_multidevice_parity_subprocess():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = f"{ROOT / 'src'}{os.pathsep}" + env.get("PYTHONPATH", "")
    out = subprocess.run(
        [sys.executable, str(ROOT / "tests" / "_dist_worker.py")],
        env=env, capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    assert "DIST-WORKER-OK" in out.stdout


def test_manual_sharding_equals_sequential(rng):
    """Host-side map/reduce (no mesh needed): k partial stats sum to global."""
    n, m, q, d, k = 50, 7, 2, 2, 5
    x = rng.standard_normal((n, q)); y = rng.standard_normal((n, d))
    z = rng.standard_normal((m, q))
    hyp = {"log_sf2": jnp.asarray(0.2), "log_ell": jnp.zeros(q),
           "log_beta": jnp.asarray(1.0)}
    full = partial_stats(hyp, jnp.asarray(z), jnp.asarray(y), jnp.asarray(x),
                         s=None, latent=False)
    parts = [
        partial_stats(hyp, jnp.asarray(z), jnp.asarray(y[i::k]),
                      jnp.asarray(x[i::k]), s=None, latent=False)
        for i in range(k)
    ]
    summed = reduce_stats(parts)
    for a, b in zip(full, summed):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-10)
    b1 = collapsed_bound(hyp, jnp.asarray(z), full, d)
    b2 = collapsed_bound(hyp, jnp.asarray(z), summed, d)
    assert abs(float(b1) - float(b2)) < 1e-8


def test_single_device_mesh_runs(rng):
    """The engine degrades gracefully to a 1-device mesh (sequential)."""
    mesh = make_compat_mesh((1,), ("data",))
    eng = DistributedGP(mesh, data_axes=("data",), latent=False)
    n, m, q, d = 20, 5, 2, 1
    x = rng.standard_normal((n, q)); y = rng.standard_normal((n, d))
    z = rng.standard_normal((m, q))
    hyp = {"log_sf2": jnp.asarray(0.0), "log_ell": jnp.zeros(q),
           "log_beta": jnp.asarray(0.0)}
    data, w = eng.put_data(y=y, mu=x)
    vg = eng.make_value_and_grad(d)
    v, _ = vg(hyp, jnp.asarray(z), data["mu"], None, data["y"], w,
              jnp.ones((1,)), jnp.asarray(float(n)))
    st = partial_stats(hyp, jnp.asarray(z), jnp.asarray(y), jnp.asarray(x),
                       s=None, latent=False)
    ref = -collapsed_bound(hyp, jnp.asarray(z), st, d)
    assert abs(float(v) - float(ref)) < 1e-10 * max(1.0, abs(float(ref)))


def test_stats_weights_mask_padding(rng):
    """Zero-weight rows contribute nothing (padding/failure correctness)."""
    n, m, q, d = 16, 4, 2, 2
    x = rng.standard_normal((n, q)); y = rng.standard_normal((n, d))
    z = rng.standard_normal((m, q))
    hyp = {"log_sf2": jnp.asarray(0.0), "log_ell": jnp.zeros(q),
           "log_beta": jnp.asarray(0.0)}
    w = np.ones(n); w[10:] = 0.0
    masked = partial_stats(hyp, jnp.asarray(z), jnp.asarray(y), jnp.asarray(x),
                           s=None, weights=jnp.asarray(w), latent=False)
    truncated = partial_stats(hyp, jnp.asarray(z), jnp.asarray(y[:10]),
                              jnp.asarray(x[:10]), s=None, latent=False)
    for a, b in zip(masked, truncated):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-12)
