"""MoE expert-parallel path vs the dense oracle (subprocess, 8 devices)."""
import os
import pathlib
import subprocess
import sys

import pytest

ROOT = pathlib.Path(__file__).resolve().parents[1]

_WORKER = r"""
import dataclasses
import jax, jax.numpy as jnp, numpy as np
from repro.configs import all_configs
from repro.distributed import sharding as shlib
from repro.models import moe as moe_mod

cfg = all_configs()["qwen3-moe-235b-a22b"].reduced()
# capacity_factor high enough that no token is dropped -> exact parity
cfg = dataclasses.replace(cfg, moe_impl="sharded", num_experts=8,
                          experts_per_token=2, moe_d_ff=32,
                          capacity_factor=8.0)
from repro.launch.mesh import make_compat_mesh
mesh = make_compat_mesh((2, 4), ("data", "model"))
rng = np.random.default_rng(0)
x = jnp.asarray(rng.standard_normal((4, 16, cfg.d_model)), jnp.float32)
p = {"router": jnp.asarray(rng.standard_normal((cfg.d_model, 8)) * .1,
                           jnp.float32),
     "w_gate": jnp.asarray(rng.standard_normal((8, cfg.d_model, 32)) * .05,
                           jnp.float32),
     "w_up": jnp.asarray(rng.standard_normal((8, cfg.d_model, 32)) * .05,
                         jnp.float32),
     "w_down": jnp.asarray(rng.standard_normal((8, 32, cfg.d_model)) * .05,
                           jnp.float32)}
with shlib.use_mesh(mesh):
    y_ref, aux_ref = moe_mod.moe_dense(cfg, p, x)
    y_sh, aux_sh = jax.jit(
        lambda p_, x_: moe_mod.moe_sharded(cfg, p_, x_))(p, x)
    err = float(jnp.max(jnp.abs(y_sh - y_ref)))
    assert err < 1e-5, f"no-drop parity failed: {err}"

    # int8 wire: parity within quantisation error, grads finite
    cfg8 = dataclasses.replace(cfg, moe_dispatch_dtype="int8")
    y_q, _ = jax.jit(lambda p_, x_: moe_mod.moe_sharded(cfg8, p_, x_))(p, x)
    err8 = float(jnp.max(jnp.abs(y_q - y_ref)))
    assert err8 < 5e-2, err8
    g = jax.grad(lambda p_: jnp.sum(
        moe_mod.moe_sharded(cfg8, p_, x)[0] ** 2))(p)
    assert all(np.isfinite(np.asarray(v)).all() for v in jax.tree.leaves(g))
    gn = float(sum(jnp.sum(v**2) for v in jax.tree.leaves(g)))
    assert gn > 0.0
print("MOE-WORKER-OK")
"""


@pytest.mark.slow
def test_moe_sharded_parity_subprocess():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = f"{ROOT / 'src'}{os.pathsep}" + env.get("PYTHONPATH",
                                                                "")
    out = subprocess.run([sys.executable, "-c", _WORKER], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, f"stdout:{out.stdout}\nstderr:{out.stderr}"
    assert "MOE-WORKER-OK" in out.stdout


def test_moe_dense_gate_normalisation(rng):
    """Dense path: outputs are convex combinations when experts are equal."""
    import dataclasses

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs import all_configs
    from repro.models import moe as moe_mod

    cfg = all_configs()["deepseek-v2-236b"].reduced()
    cfg = dataclasses.replace(cfg, num_experts=4, experts_per_token=2,
                              moe_d_ff=16)
    d = cfg.d_model
    # identical experts -> MoE output must equal the single-expert output
    w_g = np.tile(rng.standard_normal((1, d, 16)) * 0.1, (4, 1, 1))
    w_u = np.tile(rng.standard_normal((1, d, 16)) * 0.1, (4, 1, 1))
    w_d = np.tile(rng.standard_normal((1, 16, d)) * 0.1, (4, 1, 1))
    p = {"router": jnp.asarray(rng.standard_normal((d, 4)), jnp.float32),
         "w_gate": jnp.asarray(w_g, jnp.float32),
         "w_up": jnp.asarray(w_u, jnp.float32),
         "w_down": jnp.asarray(w_d, jnp.float32)}
    x = jnp.asarray(rng.standard_normal((2, 8, d)), jnp.float32)
    y, aux = moe_mod.moe_dense(cfg, p, x)
    one = jax.nn.silu(x @ p["w_gate"][0]) * (x @ p["w_up"][0]) @ p["w_down"][0]
    np.testing.assert_allclose(np.asarray(y), np.asarray(one), rtol=1e-4,
                               atol=1e-5)
    assert float(aux["load_balance"]) >= 1.0 - 1e-6  # >= 1 by Cauchy-Schwarz
