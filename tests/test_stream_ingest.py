"""Host-streaming ingestion: sources, chunking, prefetch, and end-to-end
parity with the in-memory path.

The streaming contract is *bitwise*, not approximate: a BlockStream chunk
carries scan blocks [c*bpc, (c+1)*bpc) of every shard's contiguous row
range, and the per-chunk fold threads the carry into the same
``lax.scan`` the in-memory map runs — so ``streamed_stats`` must equal
``reduced_stats`` to the last bit (and ``streamed_bound`` the collapsed
bound), across block sizes, ragged n, kernel backends, and failure masks.
Gradients go through a two-pass re-streaming scheme (direct collapse grads
+ per-chunk cotangent contractions), which reassociates float adds — those
are f64-tolerance, not bitwise.  Serving parity: ``predict_stream`` /
``sample_stream`` vs the one-shot engine calls.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.distributed import DistributedGP
from repro.core.stats import Stats
from repro.data.stream import (ArraySource, BlockStream, MemmapSource,
                               SyntheticSource, as_source, open_npz_memmaps,
                               padded_rows, prefetch)
from repro.launch.mesh import make_compat_mesh


def _mk_hyp(q):
    return {"log_sf2": jnp.asarray(0.2), "log_ell": jnp.full((q,), 0.1),
            "log_beta": jnp.asarray(1.0)}


def _mk_data(rng, n, q=2, d=2, latent=False):
    arrs = {"mu": rng.standard_normal((n, q)),
            "y": rng.standard_normal((n, d))}
    if latent:
        arrs["s"] = rng.uniform(0.05, 0.6, (n, q))
    return arrs


@pytest.fixture(scope="module")
def mesh1():
    return make_compat_mesh((1,), ("data",))


@pytest.fixture(scope="module")
def eng8(mesh1):
    """Module-shared regression engine (chunk_size=8) — jit caches persist
    across tests, keeping the module cheap."""
    return DistributedGP(mesh1, data_axes=("data",), latent=False,
                         chunk_size=8)


def _assert_stats_bitwise(a: Stats, b: Stats):
    for name, x, y in zip(a._fields, a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                      err_msg=name)


# -- sources -----------------------------------------------------------------

def test_array_source_validates_and_reads(rng):
    arrs = _mk_data(rng, 11)
    src = ArraySource(arrs)
    assert src.n == 11 and src.fields == {"mu": (2,), "y": (2,)}
    out = src.read(3, 9)
    np.testing.assert_array_equal(out["y"], arrs["y"][3:9])
    with pytest.raises(ValueError):
        ArraySource({"a": np.ones((5, 2)), "b": np.ones((6, 2))})


def test_memmap_source_npy_roundtrip(rng, tmp_path):
    arrs = _mk_data(rng, 23)
    paths = {}
    for k, v in arrs.items():
        paths[k] = tmp_path / f"{k}.npy"
        np.save(paths[k], v)
    src = MemmapSource(paths)
    assert src.n == 23
    out = src.read(5, 18)
    for k in arrs:
        np.testing.assert_array_equal(out[k], arrs[k][5:18])
        assert isinstance(out[k], np.ndarray)


def test_npz_memmap_zero_copy(rng, tmp_path):
    """Uncompressed npz members are mmapped in place via their zip offsets;
    compressed ones fall back to a full (correct) load."""
    arrs = _mk_data(rng, 17)
    p_stored = tmp_path / "data.npz"
    np.savez(p_stored, **arrs)
    mm = open_npz_memmaps(p_stored)
    for k in arrs:
        assert isinstance(mm[k], np.memmap), "ZIP_STORED member must mmap"
        np.testing.assert_array_equal(np.asarray(mm[k]), arrs[k])
    src = MemmapSource.from_npz(p_stored)
    out = src.read(2, 13)
    np.testing.assert_array_equal(out["mu"], arrs["mu"][2:13])

    p_comp = tmp_path / "data_c.npz"
    np.savez_compressed(p_comp, **arrs)
    mm_c = open_npz_memmaps(p_comp)
    for k in arrs:
        np.testing.assert_array_equal(np.asarray(mm_c[k]), arrs[k])


def test_synthetic_source_pure_and_validated():
    src = SyntheticSource(100, lambda a, b: {"y": np.arange(a, b,
                                                            dtype=np.float64)
                                             [:, None]},
                          fields={"y": (1,)})
    np.testing.assert_array_equal(src.read(7, 12)["y"][:, 0],
                                  np.arange(7, 12))
    bad = SyntheticSource(100, lambda a, b: {"y": np.zeros((3, 1))},
                          fields={"y": (1,)})
    with pytest.raises(ValueError):
        bad.read(0, 5)


def test_as_source_accepts_dict_stream_and_ducks(rng):
    arrs = _mk_data(rng, 10)
    assert isinstance(as_source(arrs), ArraySource)
    src = ArraySource(arrs)
    assert as_source(src) is src

    class Duck:
        n = 10
        fields = {"y": (2,)}

        def read(self, a, b):
            return {"y": np.zeros((b - a, 2))}

    duck = Duck()
    assert as_source(duck) is duck
    with pytest.raises(TypeError):
        as_source(42)


# -- geometry ----------------------------------------------------------------

def test_padded_rows():
    assert padded_rows(10, 4) == 12
    assert padded_rows(8, 4) == 8
    assert padded_rows(1, 4) == 4
    assert padded_rows(0, 4) == 4   # never a zero-block layout


@pytest.mark.parametrize("n,n_shards,block,bpc", [
    (101, 4, 8, 1),
    (101, 4, 8, 2),
    (64, 2, 8, 100),   # bpc overshoots -> clamped to blocks_per_shard
    (5, 4, 8, 1),      # n < n_shards*block: pads up to one block per shard
])
def test_blockstream_geometry_and_coverage(rng, n, n_shards, block, bpc):
    arrs = _mk_data(rng, n)
    bs = BlockStream(ArraySource(arrs), n_shards=n_shards, block_size=block,
                     blocks_per_chunk=bpc)
    assert bs.n_pad % (n_shards * block) == 0 and bs.n_pad >= max(n, 1)
    assert bs.blocks_per_chunk <= bs.blocks_per_shard
    assert bs.n_chunks * bs.blocks_per_chunk >= bs.blocks_per_shard
    # Reassembling every chunk shard-major recovers the padded row order of
    # pad_and_shard: real rows in order, pad rows weighted 0.
    rows = np.zeros((bs.n_pad, 2))
    weights = np.zeros(bs.n_pad)
    rps = bs.rows_per_shard
    cr = bs.shard_chunk_rows
    for c, (chunk, w) in enumerate(bs):
        assert chunk["y"].shape == (bs.chunk_rows, 2)
        assert w.shape == (bs.chunk_rows,)
        for s in range(n_shards):
            lo = s * rps + c * cr
            rows[lo:lo + cr] = chunk["y"][s * cr:(s + 1) * cr]
            weights[lo:lo + cr] = w[s * cr:(s + 1) * cr]
    np.testing.assert_array_equal(rows[:n], arrs["y"])
    np.testing.assert_array_equal(weights[:n], np.ones(n))
    np.testing.assert_array_equal(weights[n:], np.zeros(bs.n_pad - n))


def test_blockstream_pads_s_log_safe(rng):
    arrs = _mk_data(rng, 5, latent=True)
    bs = BlockStream(ArraySource(arrs), n_shards=2, block_size=4)
    chunk, w = bs.chunk(0)
    pad = np.asarray(w) == 0.0
    assert pad.any()
    np.testing.assert_array_equal(chunk["s"][pad], 1.0)   # log-safe
    np.testing.assert_array_equal(chunk["y"][pad], 0.0)


# -- prefetch ----------------------------------------------------------------

def test_prefetch_preserves_order_and_maps():
    out = list(prefetch(range(20), fn=lambda i: i * i, depth=3))
    assert out == [i * i for i in range(20)]
    assert list(prefetch(iter("abc"))) == list("abc")


def test_prefetch_propagates_errors():
    def gen():
        yield 1
        yield 2
        raise RuntimeError("source died")

    it = prefetch(gen(), fn=lambda x: x, depth=2)
    assert next(it) == 1
    with pytest.raises(RuntimeError, match="source died"):
        list(it)


def test_prefetch_fn_error_propagates():
    def boom(x):
        if x == 3:
            raise ValueError("bad chunk")
        return x

    with pytest.raises(ValueError, match="bad chunk"):
        list(prefetch(range(6), fn=boom, depth=2))


# -- put_data wiring ---------------------------------------------------------

def test_put_data_stream_wiring(rng, eng8):
    arrs = _mk_data(rng, 40)
    bs = eng8.put_data(stream=arrs, blocks_per_chunk=2)
    assert isinstance(bs, BlockStream)
    assert bs.n_shards == eng8.n_shards and bs.block_size == eng8.chunk_size
    # an already-built matching BlockStream passes through
    assert eng8.open_stream(bs) is bs
    # mismatched geometry is rejected
    wrong = BlockStream(ArraySource(arrs), n_shards=eng8.n_shards + 1,
                        block_size=eng8.chunk_size)
    with pytest.raises(ValueError):
        eng8.open_stream(wrong)
    with pytest.raises(ValueError):
        eng8.put_data(stream=arrs, y=arrs["y"])   # stream XOR arrays
    eng_nochunk = DistributedGP(make_compat_mesh((1,), ("data",)),
                                data_axes=("data",), latent=False)
    with pytest.raises(ValueError):
        eng_nochunk.put_data(stream=arrs)


# -- streamed == in-memory: stats / bound / grads ----------------------------

def _inmem_reference(eng, hyp, z, arrs, d, fmask=None, n_full=None):
    data, w = eng.put_data(**arrs)
    fm = jnp.ones((eng.n_shards,)) if fmask is None else fmask
    st = eng.reduced_stats(d=d)(hyp, z, data["y"], data["mu"],
                                data.get("s"), w, fm)
    b = eng.bound_fn(d=d)(hyp, z, data["y"], data["mu"], data.get("s"), w,
                          fm, n_full if n_full is not None
                          else float(arrs["y"].shape[0]))
    return data, w, st, b


@pytest.mark.parametrize("n,bpc", [(100, 1), (100, 3), (5, 1), (16, 2)])
def test_streamed_stats_and_bound_bitwise(rng, eng8, n, bpc):
    q, d = 2, 2
    hyp = _mk_hyp(q)
    arrs = _mk_data(rng, n, q=q, d=d)
    z = jnp.asarray(rng.standard_normal((5, q)))
    _, _, st_mem, b_mem = _inmem_reference(eng8, hyp, z, arrs, d)
    bs = eng8.put_data(stream=arrs, blocks_per_chunk=bpc)
    st = eng8.streamed_stats(hyp, z, bs)
    _assert_stats_bitwise(st_mem, st)
    b = eng8.streamed_bound(hyp, z, bs, d=d, n_full=float(n))
    assert float(b) == float(b_mem)


def test_streamed_latent_bitwise(rng, mesh1):
    q, d, n = 2, 3, 57
    eng = DistributedGP(mesh1, data_axes=("data",), latent=True,
                        chunk_size=8)
    hyp = _mk_hyp(q)
    arrs = _mk_data(rng, n, q=q, d=d, latent=True)
    z = jnp.asarray(rng.standard_normal((4, q)))
    _, _, st_mem, b_mem = _inmem_reference(eng, hyp, z, arrs, d)
    bs = eng.put_data(stream=arrs, blocks_per_chunk=2)
    _assert_stats_bitwise(st_mem, eng.streamed_stats(hyp, z, bs))
    assert float(eng.streamed_bound(hyp, z, bs, d=d, n_full=float(n))) \
        == float(b_mem)


def test_streamed_pallas_backend_bitwise(rng, mesh1):
    q, d, n = 2, 1, 48
    eng = DistributedGP(mesh1, data_axes=("data",), latent=False,
                        chunk_size=8, kernel_backend="pallas")
    hyp = _mk_hyp(q)
    arrs = _mk_data(rng, n, q=q, d=d)
    z = jnp.asarray(rng.standard_normal((4, q)))
    _, _, st_mem, _ = _inmem_reference(eng, hyp, z, arrs, d)
    bs = eng.put_data(stream=arrs, blocks_per_chunk=2)
    _assert_stats_bitwise(st_mem, eng.streamed_stats(hyp, z, bs))


def test_streamed_fmask_and_rescale(rng, mesh1):
    """Failure masks kill a shard's stream contribution exactly as they kill
    its in-memory partial sums; rescale-mode bound matches too."""
    q, d, n = 2, 2, 40
    for mode in ("drop", "rescale"):
        eng = DistributedGP(mesh1, data_axes=("data",), latent=False,
                            chunk_size=8, failure_mode=mode)
        hyp = _mk_hyp(q)
        arrs = _mk_data(rng, n, q=q, d=d)
        z = jnp.asarray(rng.standard_normal((4, q)))
        fm = jnp.ones((1,))
        _, _, st_mem, b_mem = _inmem_reference(eng, hyp, z, arrs, d,
                                               fmask=fm)
        bs = eng.put_data(stream=arrs)
        _assert_stats_bitwise(st_mem,
                              eng.streamed_stats(hyp, z, bs, fmask=fm))
        assert float(eng.streamed_bound(hyp, z, bs, d=d, fmask=fm,
                                        n_full=float(n))) == float(b_mem)


def test_streamed_value_and_grad_f64(rng, eng8):
    q, d, n = 2, 2, 90
    hyp = _mk_hyp(q)
    arrs = _mk_data(rng, n, q=q, d=d)
    z = jnp.asarray(rng.standard_normal((5, q)))
    data, w, _, _ = _inmem_reference(eng8, hyp, z, arrs, d)
    ones = jnp.ones((eng8.n_shards,))
    nf = float(n)
    v_mem, g_mem = eng8.make_value_and_grad(d=d, argnums=(0, 1))(
        hyp, z, data["mu"], None, data["y"], w, ones, nf)
    bs = eng8.put_data(stream=arrs, blocks_per_chunk=2)
    v_str, g_str = eng8.streamed_value_and_grad(d=d, argnums=(0, 1))(
        hyp, z, bs, n_full=nf)
    assert abs(float(v_mem) - float(v_str)) <= 1e-12 * abs(float(v_mem))
    for a, b in zip(jax.tree.leaves(g_mem), jax.tree.leaves(g_str)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-10, atol=1e-12)
    # single-argnum variant returns a bare grad, not a tuple
    _, gz = eng8.streamed_value_and_grad(d=d, argnums=1)(hyp, z, bs,
                                                         n_full=nf)
    np.testing.assert_array_equal(np.asarray(gz),
                                  np.asarray(g_str[1]))


def test_streamed_svi_full_batch_equals_exact(rng, eng8):
    q, d, n = 2, 2, 70
    hyp = _mk_hyp(q)
    arrs = _mk_data(rng, n, q=q, d=d)
    z = jnp.asarray(rng.standard_normal((4, q)))
    bs = eng8.put_data(stream=arrs, blocks_per_chunk=1)
    svi = eng8.streamed_svi_value_and_grad(d=d, batch_chunks=bs.n_chunks)
    v_svi, g_svi = svi(hyp, z, bs, jax.random.PRNGKey(0))
    v_ex, g_ex = eng8.streamed_value_and_grad(d=d)(hyp, z, bs)
    assert abs(float(v_svi) - float(v_ex)) <= 1e-9 * abs(float(v_ex))
    for a, b in zip(jax.tree.leaves(g_svi), jax.tree.leaves(g_ex)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-8, atol=1e-10)
    # sampled steps: finite, key-deterministic, key-sensitive
    svi2 = eng8.streamed_svi_value_and_grad(d=d, batch_chunks=2)
    va, _ = svi2(hyp, z, bs, jax.random.PRNGKey(1))
    vb, _ = svi2(hyp, z, bs, jax.random.PRNGKey(1))
    vc, _ = svi2(hyp, z, bs, jax.random.PRNGKey(2))
    assert np.isfinite(float(va)) and float(va) == float(vb)
    assert float(va) != float(vc)


def test_streamed_svi_rejects_rescale(rng, mesh1):
    eng = DistributedGP(mesh1, data_axes=("data",), latent=False,
                        chunk_size=8, failure_mode="rescale")
    with pytest.raises(NotImplementedError):
        eng.streamed_svi_value_and_grad(d=1, batch_chunks=2)


def test_streamed_from_memmap_source(rng, eng8, tmp_path):
    """End to end from files on disk: mmap npz -> BlockStream -> bitwise
    parity with the in-memory ingest of the same arrays."""
    q, d, n = 2, 2, 33
    arrs = _mk_data(rng, n, q=q, d=d)
    np.savez(tmp_path / "train.npz", **arrs)
    hyp = _mk_hyp(q)
    z = jnp.asarray(rng.standard_normal((4, q)))
    _, _, st_mem, _ = _inmem_reference(eng8, hyp, z, arrs, d)
    src = MemmapSource.from_npz(tmp_path / "train.npz")
    bs = eng8.put_data(stream=src, blocks_per_chunk=2)
    _assert_stats_bitwise(st_mem, eng8.streamed_stats(hyp, z, bs))


# -- serving: query streams --------------------------------------------------

def _serve_engine(rng, n=60, m=7, q=2, d=2, block=8):
    from repro.core.stats import partial_stats
    from repro.serve import PredictEngine, extract_state

    hyp = _mk_hyp(q)
    x = jnp.asarray(rng.standard_normal((n, q)))
    y = jnp.asarray(rng.standard_normal((n, d)))
    z = jnp.asarray(rng.standard_normal((m, q)))
    state = extract_state(hyp, z, partial_stats(hyp, z, y, x, s=None,
                                                latent=False))
    return PredictEngine(state, block_size=block)


def test_predict_stream_bitwise(rng):
    eng = _serve_engine(rng)
    batches = [np.asarray(rng.standard_normal((t, 2)))
               for t in (5, 16, 1, 9)]
    outs = list(eng.predict_stream(iter(batches), include_noise=True))
    assert len(outs) == len(batches)
    for xb, (mean, var) in zip(batches, outs):
        m_ref, v_ref = eng.predict(jnp.asarray(xb), include_noise=True)
        assert mean.shape == (xb.shape[0], 2)
        np.testing.assert_array_equal(np.asarray(mean), np.asarray(m_ref))
        np.testing.assert_array_equal(np.asarray(var), np.asarray(v_ref))


def test_sample_stream_matches_one_shot(rng):
    """Streamed sampling folds the key with the *global* block index: on
    block-aligned batches the concatenated streamed samples are bitwise the
    one-shot ``sample`` of the concatenated queries."""
    eng = _serve_engine(rng, block=8)
    batches = [np.asarray(rng.standard_normal((16, 2))) for _ in range(3)]
    key = jax.random.PRNGKey(4)
    smp = list(eng.sample_stream(iter(batches), 3, key, include_noise=True))
    ref = eng.sample(jnp.asarray(np.concatenate(batches)), 3, key,
                     include_noise=True)
    np.testing.assert_array_equal(np.concatenate([np.asarray(s) for s in smp],
                                                 axis=1), np.asarray(ref))
    with pytest.raises(ValueError):
        next(iter(eng.sample_stream(iter(batches), 0, key)))


def test_streamed_predictive_state_serves(rng, eng8):
    """Train-side streamed state == in-memory state, end to end through the
    serving engine."""
    from repro.serve import PredictEngine

    q, d, n = 2, 2, 50
    hyp = _mk_hyp(q)
    arrs = _mk_data(rng, n, q=q, d=d)
    z = jnp.asarray(rng.standard_normal((5, q)))
    data, w, _, _ = _inmem_reference(eng8, hyp, z, arrs, d)
    state_mem = eng8.predictive_state(hyp, z, data["y"], data["mu"], None, w)
    bs = eng8.put_data(stream=arrs, blocks_per_chunk=2)
    state_str = eng8.streamed_predictive_state(hyp, z, bs)
    for a, b in zip(jax.tree.leaves(state_mem), jax.tree.leaves(state_str)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    xs = jnp.asarray(rng.standard_normal((9, q)))
    m0, v0 = PredictEngine(state_mem, block_size=8).predict(xs)
    m1, v1 = PredictEngine(state_str, block_size=8).predict(xs)
    np.testing.assert_array_equal(np.asarray(m0), np.asarray(m1))
    np.testing.assert_array_equal(np.asarray(v0), np.asarray(v1))


# -- property: any geometry, still bitwise -----------------------------------

@pytest.mark.statistical
def test_property_streamed_bitwise_any_geometry(eng8):
    """hypothesis: for ANY (n, bpc, seed) the streamed Stats equal the
    in-memory reduction bitwise on the shared engine geometry."""
    pytest.importorskip("hypothesis", reason="property tests need hypothesis "
                        "(pip install -r requirements-dev.txt)")
    from hypothesis import given, settings
    from hypothesis import strategies as st

    @settings(max_examples=15, deadline=None)
    @given(n=st.integers(1, 120), bpc=st.integers(1, 6),
           seed=st.integers(0, 2**31 - 1))
    def prop(n, bpc, seed):
        r = np.random.default_rng(seed)
        q, d = 2, 2
        hyp = _mk_hyp(q)
        arrs = _mk_data(r, n, q=q, d=d)
        z = jnp.asarray(r.standard_normal((5, q)))
        _, _, st_mem, b_mem = _inmem_reference(eng8, hyp, z, arrs, d)
        bs = eng8.put_data(stream=arrs, blocks_per_chunk=bpc)
        _assert_stats_bitwise(st_mem, eng8.streamed_stats(hyp, z, bs))
        assert float(eng8.streamed_bound(hyp, z, bs, d=d,
                                         n_full=float(n))) == float(b_mem)

    prop()
