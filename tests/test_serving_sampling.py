"""Statistical correctness of posterior sampling (`PredictEngine.sample`).

Sampling is stochastic, so "correct" here is statistical, not bitwise: the
empirical moments of the draws must converge to the analytic posterior
(`predict(full_cov=True)`) at the Monte-Carlo rate.  Every bound below is a
multiple of the estimator's standard error, and every test uses a fixed
PRNG key, so the draws — and hence the pass/fail — are deterministic.

The structural contracts ride along: same key => same samples, distinct
keys => independent draws, pad rows can never leak into real samples (the
lower-triangular-chol prefix property), and blocks are jointly sampled
within / independent across.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import SGPR
from repro.core.stats import partial_stats
from repro.serve import PredictEngine, extract_state, sample_joint

from conftest import make_regression

# Statistical-tolerance assertions (Monte-Carlo moments at the 1/sqrt(S)
# rate): CI runs this module in the statistical job, not the tier-1 gate.
pytestmark = pytest.mark.statistical


def _hyp(rng, q):
    return {"log_sf2": jnp.asarray(rng.uniform(-0.5, 0.8)),
            "log_ell": jnp.asarray(rng.uniform(-0.4, 0.4, q)),
            "log_beta": jnp.asarray(1.2)}


def _state(rng, n=90, m=13, q=2, d=3):
    hyp = _hyp(rng, q)
    x = jnp.asarray(rng.standard_normal((n, q)))
    y = jnp.asarray(rng.standard_normal((n, d)))
    z = jnp.asarray(rng.standard_normal((m, q)))
    stats = partial_stats(hyp, z, y, x, s=None, latent=False)
    return extract_state(hyp, z, stats)


S = 4000   # draws per statistical test; SE bounds below scale as 1/sqrt(S)


def test_sample_moments_match_full_cov(rng):
    """Empirical mean within 5 SE and empirical covariance within 6 SE of
    the analytic joint posterior, per output dim (t <= block_size, so the
    whole batch is ONE jointly-sampled block)."""
    state = _state(rng)
    eng = PredictEngine(state, block_size=8)
    xs = jnp.asarray(rng.standard_normal((8, 2)))
    mean, cov = eng.predict_full_cov(xs)
    smp = np.asarray(eng.sample(xs, S, jax.random.PRNGKey(1)))   # (S, 8, 3)
    c = np.asarray(cov)
    sd = np.sqrt(np.diag(c))

    # mean estimator: SE = sqrt(c_ii / S)
    err_mean = np.abs(smp.mean(0) - np.asarray(mean))
    assert (err_mean <= 5.0 * sd[:, None] / np.sqrt(S) + 1e-12).all()

    # cov estimator: SE(i,j) = sqrt((c_ii c_jj + c_ij^2) / S)
    se_cov = np.sqrt((np.outer(sd**2, sd**2) + c**2) / S)
    for j in range(smp.shape[2]):
        r = smp[:, :, j] - np.asarray(mean)[None, :, j]
        emp_cov = r.T @ r / S
        assert (np.abs(emp_cov - c) <= 6.0 * se_cov + 1e-12).all()


def test_same_key_deterministic(rng):
    state = _state(rng)
    eng = PredictEngine(state, block_size=8)
    xs = jnp.asarray(rng.standard_normal((11, 2)))
    a = eng.sample(xs, 16, jax.random.PRNGKey(3))
    b = eng.sample(xs, 16, jax.random.PRNGKey(3))
    assert a.shape == (16, 11, 3)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_distinct_keys_independent(rng):
    """Different keys give different draws, and the two sets are
    *uncorrelated*: the cross-moment E[r1 r2] has SE c_ii/sqrt(S)."""
    state = _state(rng)
    eng = PredictEngine(state, block_size=8)
    xs = jnp.asarray(rng.standard_normal((8, 2)))
    mean, cov = eng.predict_full_cov(xs)
    s1 = np.asarray(eng.sample(xs, S, jax.random.PRNGKey(10)))
    s2 = np.asarray(eng.sample(xs, S, jax.random.PRNGKey(11)))
    assert not np.array_equal(s1, s2)
    mu = np.asarray(mean)
    c_diag = np.diag(np.asarray(cov))
    for j in range(s1.shape[2]):
        cross = np.mean((s1[:, :, j] - mu[None, :, j]) *
                        (s2[:, :, j] - mu[None, :, j]), axis=0)
        assert (np.abs(cross) <= 5.0 * c_diag / np.sqrt(S) + 1e-12).all()


def test_pad_rows_never_leak(rng):
    """The chol factor is lower-triangular, so the leading rows of a padded
    block draw *identical* samples to an unpadded call with the same key —
    pad rows cannot influence real rows, bitwise."""
    state = _state(rng)
    eng = PredictEngine(state, block_size=8)
    xs = jnp.asarray(rng.standard_normal((8, 2)))
    full = eng.sample(xs, 32, jax.random.PRNGKey(7))        # no padding
    short = eng.sample(xs[:5], 32, jax.random.PRNGKey(7))   # 5 -> 8 padded
    assert short.shape == (32, 5, 3)
    np.testing.assert_array_equal(np.asarray(short),
                                  np.asarray(full)[:, :5, :])


def test_odd_t_multi_block_moments(rng):
    """Several blocks plus a padded tail: per-row mean/variance statistics
    still converge to the diag posterior (pad rows never contaminate)."""
    state = _state(rng)
    eng = PredictEngine(state, block_size=4)
    xs = jnp.asarray(rng.standard_normal((11, 2)))          # 11 -> 12 padded
    mean, var = eng.predict(xs)
    smp = np.asarray(eng.sample(xs, S, jax.random.PRNGKey(2)))
    assert smp.shape == (S, 11, 3)
    sd = np.sqrt(np.asarray(var))
    err_mean = np.abs(smp.mean(0) - np.asarray(mean))
    assert (err_mean <= 5.0 * sd[:, None] / np.sqrt(S) + 1e-12).all()
    # variance estimator: SE ~ sqrt(2/S) sigma^2
    emp_var = smp.var(axis=0)
    se_var = np.sqrt(2.0 / S) * np.asarray(var)
    assert (np.abs(emp_var - np.asarray(var)[:, None]) <=
            6.0 * se_var[:, None] + 1e-12).all()


def test_cross_block_independence(rng):
    """Blocks are drawn independently: the empirical covariance between a
    row of block 0 and a row of block 1 is zero to within SE (the
    block-diagonal design of the scan sampler)."""
    state = _state(rng)
    eng = PredictEngine(state, block_size=4)
    xs = jnp.asarray(rng.standard_normal((8, 2)))           # exactly 2 blocks
    mean, var = eng.predict(xs)
    smp = np.asarray(eng.sample(xs, S, jax.random.PRNGKey(4)))
    mu, sd = np.asarray(mean), np.sqrt(np.asarray(var))
    r = smp[:, :, 0] - mu[None, :, 0]
    for i in range(4):
        for j in range(4, 8):
            cross = np.mean(r[:, i] * r[:, j])
            assert abs(cross) <= 5.0 * sd[i] * sd[j] / np.sqrt(S) + 1e-12


def test_include_noise_inflates_variance(rng):
    """include_noise draws observation (not latent-f) samples: empirical
    per-row variance matches var + 1/beta within SE."""
    state = _state(rng)
    eng = PredictEngine(state, block_size=8)
    xs = jnp.asarray(rng.standard_normal((8, 2)))
    _, var = eng.predict(xs, include_noise=True)
    smp = np.asarray(eng.sample(xs, S, jax.random.PRNGKey(6),
                                include_noise=True))
    v = np.asarray(var)
    emp_var = smp.var(axis=0)
    se_var = np.sqrt(2.0 / S) * v
    assert (np.abs(emp_var - v[:, None]) <= 6.0 * se_var[:, None] + 1e-12).all()


def test_sample_joint_is_one_piece(rng):
    """posterior.sample_joint: exact joint over all queries (the small-t
    mode) — deterministic per key, mean within SE."""
    state = _state(rng)
    xs = jnp.asarray(rng.standard_normal((9, 2)))
    a = sample_joint(state, xs, jax.random.PRNGKey(0), S)
    b = sample_joint(state, xs, jax.random.PRNGKey(0), 4)
    assert a.shape == (S, 9, 3) and b.shape == (4, 9, 3)
    eng = PredictEngine(state, block_size=16)
    mean, cov = eng.predict_full_cov(xs)
    sd = np.sqrt(np.diag(np.asarray(cov)))
    err = np.abs(np.asarray(a).mean(0) - np.asarray(mean))
    assert (err <= 5.0 * sd[:, None] / np.sqrt(S) + 1e-12).all()


def test_sample_rejects_bad_args(rng):
    state = _state(rng)
    eng = PredictEngine(state, block_size=8)
    xs = jnp.asarray(rng.standard_normal((4, 2)))
    with pytest.raises(ValueError, match="num_samples"):
        eng.sample(xs, 0, jax.random.PRNGKey(0))
    lossy = PredictEngine(state.astype(jnp.bfloat16), block_size=8,
                          compute_dtype=jnp.bfloat16)
    with pytest.raises(ValueError, match="Cholesky"):
        lossy.sample(xs, 2, jax.random.PRNGKey(0))
    # Quantized *storage* also refuses: sub-f32 rounding of g can make the
    # re-factorised block covariance indefinite (serve mean/var instead).
    quant = PredictEngine(state.astype(jnp.bfloat16), block_size=8)
    with pytest.raises(ValueError, match="storage"):
        quant.sample(xs, 2, jax.random.PRNGKey(0))
    # The raw sampling functions refuse quantized states too (a silent
    # NaN-returning Cholesky would otherwise ship garbage draws).
    with pytest.raises(ValueError, match="f32/f64"):
        sample_joint(state.astype(jnp.bfloat16), xs, jax.random.PRNGKey(0), 2)


def test_sgpr_sample_wrapper(rng):
    """The model-side convenience: shapes, seed determinism, and agreement
    of the sample mean with the model's own predict to within SE."""
    x, y = make_regression(rng, n=60, q=2, d=2)
    model = SGPR(x, y, num_inducing=8, seed=0)
    xs = x[:9]
    smp = model.sample(xs, 800, seed=1)
    assert smp.shape == (800, 9, 2) and np.isfinite(smp).all()
    np.testing.assert_array_equal(smp, model.sample(xs, 800, seed=1))
    assert not np.array_equal(smp, model.sample(xs, 800, seed=2))
    mean, var = model.predict(xs)
    se = np.sqrt(var / 800.0)
    assert (np.abs(smp.mean(0) - mean) <= 5.0 * se[:, None] + 1e-12).all()
