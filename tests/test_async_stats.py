"""Async stale-update accumulation (distributed/async_stats.py):
subset-enumeration unbiasedness, in the style of tests/test_svi_stats.py.

The claims under test, each enumerated exactly (no sampling noise, no
statistical tolerance):

  * Staleness exactness: at fixed (hyp, z, data), a shard's contribution
    does not depend on WHEN it was pushed — so for every staleness
    pattern (d_1..d_K) with d_k <= S, the accumulator's read equals the
    exact fold, through arbitrary push interleavings and churn
    (leave + rejoin) events.  This pins the fold/downdate bookkeeping:
    any error in the incremental total shows up as a non-exact read.
  * Presence (Horvitz–Thompson) unbiasedness: when shard k's
    contribution is present with probability p_k and pushed with
    ``prob=p_k`` under ``reweight="probs"``, the probability-weighted
    average of the read over ALL 2^K presence subsets equals the exact
    Stats to f64 — composing with SVI block subsampling (the inner
    estimator is itself unbiased, expectations factorise) and with
    gradient flow (the accumulator is plain jnp adds, so jax.grad
    differentiates straight through push/read).
  * Engine: the barrier-free ``AsyncEngine`` step with everything fresh
    reproduces the synchronous reference; under churn
    (``FailureSimulator``) it evicts dead shards after S steps and
    re-folds them on resurrection.
"""
import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.bound import collapsed_bound
from repro.core.stats import partial_stats, partial_stats_chunked
from repro.distributed.async_stats import AsyncEngine, AsyncStatsAccumulator
from repro.distributed.fault import FailureSimulator, StepTimer


def _mk_hyp(q):
    return {"log_sf2": jnp.asarray(0.2), "log_ell": jnp.full((q,), 0.1),
            "log_beta": jnp.asarray(1.0)}


def _assert_stats_close(a, b, rtol=1e-10, atol=1e-12):
    for name, x, y in zip(a._fields, a, b):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=rtol, atol=atol, err_msg=name)


def _mk_shards(rng, K=3, nk=10, q=2, d=2, ragged=True):
    return [{"y": rng.standard_normal((nk + (2 * k if ragged else 0), d)),
             "mu": rng.standard_normal((nk + (2 * k if ragged else 0), q))}
            for k in range(K)]


def _shard_stats(hyp, z, sh, block_indices=None, batch_blocks=None,
                 block_size=None):
    return partial_stats_chunked(
        hyp, z, jnp.asarray(sh["y"]), jnp.asarray(sh["mu"]), s=None,
        latent=False, block_size=block_size, batch_blocks=batch_blocks,
        block_indices=block_indices,
        force_scan=block_size is not None)


@pytest.mark.parametrize("S", [1, 2, 4])
def test_staleness_patterns_exact_with_churn(rng, S):
    """Every staleness pattern d in {0..S}^K — with a leave/rejoin churn
    event spliced into each replay — reads back the exact fold."""
    K = 3
    shards = _mk_shards(rng, K=K)
    q = 2
    hyp = _mk_hyp(q)
    z = jnp.asarray(rng.standard_normal((5, q)))
    sts = [_shard_stats(hyp, z, sh) for sh in shards]
    exact = sts[0]
    for st in sts[1:]:
        exact = exact + st

    T = S  # read stamp: shard k pushed at T - d_k, all within the bound
    for pattern in itertools.product(range(S + 1), repeat=K):
        acc = AsyncStatsAccumulator(staleness=S, reweight="drop")
        # churn: shard 0 contributes garbage early, leaves, rejoins on
        # schedule — the downdate must wipe it from the running total.
        acc.push(0, sts[1].scale(3.0), stamp=0)
        acc.leave(0)
        for t in range(T + 1):
            for k in range(K):
                if T - pattern[k] == t:
                    acc.push(k, sts[k], stamp=t)
        # a re-push replaces (not double-folds) the contribution
        acc.push(1, sts[1], stamp=T)
        out = acc.read(T)
        _assert_stats_close(out, exact, rtol=1e-12, atol=1e-13)
        assert sorted(acc.members()) == list(range(K))


@pytest.mark.parametrize("S", [1, 2, 4])
def test_staleness_eviction_bound(rng, S):
    """Entries exactly S steps old survive a read; S+1 steps old are
    evicted (downdated) — and the never-empty guard keeps the freshest
    entries when everything has expired."""
    shards = _mk_shards(rng, K=2, ragged=False)
    hyp = _mk_hyp(2)
    z = jnp.asarray(rng.standard_normal((4, 2)))
    st0 = _shard_stats(hyp, z, shards[0])
    st1 = _shard_stats(hyp, z, shards[1])

    acc = AsyncStatsAccumulator(staleness=S, reweight="drop")
    acc.push(0, st0, stamp=0)
    acc.push(1, st1, stamp=1)
    out = acc.read(S)                     # shard 0 exactly S old: kept
    _assert_stats_close(out, st0 + st1)
    out = acc.read(S + 1)                 # now S+1 old: evicted
    _assert_stats_close(out, st1)
    assert acc.members() == [1]
    # all expired -> freshest kept rather than an empty fold
    out = acc.read(S + 100)
    _assert_stats_close(out, st1)
    acc.leave(1)
    with pytest.raises(ValueError, match="empty accumulator"):
        acc.read(0)


def test_presence_enumeration_probs_unbiased(rng):
    """Horvitz–Thompson reweighting: the probability-weighted average of
    the accumulator read over all 2^K presence subsets — heterogeneous
    p_k, absent shards contributing nothing — equals the exact Stats."""
    K = 3
    probs = [0.5, 0.7, 0.3]
    shards = _mk_shards(rng, K=K)
    hyp = _mk_hyp(2)
    z = jnp.asarray(rng.standard_normal((5, 2)))
    sts = [_shard_stats(hyp, z, sh) for sh in shards]
    exact = sts[0]
    for st in sts[1:]:
        exact = exact + st

    avg = None
    for pattern in itertools.product([0, 1], repeat=K):
        weight = float(np.prod([p if b else 1.0 - p
                                for p, b in zip(probs, pattern)]))
        if not any(pattern):
            continue        # empty fold contributes zero to the average
        acc = AsyncStatsAccumulator(staleness=0, reweight="probs")
        for k in range(K):
            if pattern[k]:
                acc.push(k, sts[k], stamp=0, prob=probs[k])
        contrib = acc.read(0).scale(weight)
        avg = contrib if avg is None else avg + contrib
    _assert_stats_close(avg, exact)


@pytest.mark.parametrize("S", [1, 2, 4])
def test_presence_and_svi_enumeration_with_staleness(rng, S):
    """Composition: per-shard SVI block subsampling INSIDE a stale,
    presence-sampled fold.  Enumerate (presence subset x per-present-shard
    block subsets) jointly; absent shards hold a STALE exact contribution
    from stamp 0 (within the bound S, so it is kept).  The expectation
    telescopes: E_presence[E_blocks[fold]] == exact Stats."""
    K = 2
    p = 0.5
    nk, blocksz, B = 12, 4, 2          # nb = 3 blocks per shard
    nb = nk // blocksz
    shards = _mk_shards(rng, K=K, nk=nk, ragged=False)
    hyp = _mk_hyp(2)
    z = jnp.asarray(rng.standard_normal((4, 2)))
    exact_sts = [_shard_stats(hyp, z, sh, block_size=blocksz)
                 for sh in shards]
    exact = exact_sts[0]
    for st in exact_sts[1:]:
        exact = exact + st

    block_subsets = list(itertools.combinations(range(nb), B))
    avg, total_w = None, 0.0
    for pattern in itertools.product([0, 1], repeat=K):
        pw = float(np.prod([p if b else 1.0 - p for b in pattern]))
        # present shards push a fresh SVI estimate at stamp S; absent
        # shards keep their exact stamp-0 contribution (staleness S keeps
        # it at the read stamp S).
        present = [k for k in range(K) if pattern[k]]
        for combo in itertools.product(block_subsets, repeat=len(present)):
            w = pw / (len(block_subsets) ** len(present))
            acc = AsyncStatsAccumulator(staleness=S, reweight="drop")
            for k in range(K):
                acc.push(k, exact_sts[k], stamp=0)
            for k, sub in zip(present, combo):
                st = _shard_stats(hyp, z, shards[k],
                                  block_indices=jnp.asarray(sub),
                                  batch_blocks=B, block_size=blocksz)
                acc.push(k, st, stamp=S)
            contrib = acc.read(S).scale(w)
            avg = contrib if avg is None else avg + contrib
            total_w += w
    assert abs(total_w - 1.0) < 1e-12
    _assert_stats_close(avg, exact)


def test_presence_enumeration_grads_to_f64(rng):
    """Gradient unbiasedness through the accumulator: for a loss LINEAR in
    the folded Stats, the presence-averaged HT gradients wrt (hyp, z)
    equal the exact gradients to f64 — jax.grad flows through push/read
    (the accumulator is jnp adds and scales)."""
    K = 3
    p = 0.6
    shards = _mk_shards(rng, K=K)
    q = 2
    hyp = _mk_hyp(q)
    z = jnp.asarray(rng.standard_normal((5, q)))
    m, d = 5, 2
    vc = jnp.asarray(rng.standard_normal((m, d)))
    vd = jnp.asarray(rng.standard_normal((m, m)))

    def contract(st):
        return (st.A + 2.0 * st.B + jnp.sum(vc * st.C)
                + jnp.sum(vd * st.D) + 0.5 * st.n)

    def loss(h, zz, pattern):
        if pattern is None:
            total = None
            for sh in shards:
                st = partial_stats(h, zz, jnp.asarray(sh["y"]),
                                   jnp.asarray(sh["mu"]), None, latent=False)
                total = st if total is None else total + st
            return contract(total)
        acc = AsyncStatsAccumulator(staleness=0, reweight="probs")
        for k in range(K):
            if pattern[k]:
                st = partial_stats(h, zz, jnp.asarray(shards[k]["y"]),
                                   jnp.asarray(shards[k]["mu"]), None,
                                   latent=False)
                acc.push(k, st, stamp=0, prob=p)
        return contract(acc.read(0))

    g_exact = jax.grad(loss, argnums=(0, 1))(hyp, z, None)
    acc = None
    for pattern in itertools.product([0, 1], repeat=K):
        if not any(pattern):
            continue
        w = float(np.prod([p if b else 1.0 - p for b in pattern]))
        g = jax.grad(loss, argnums=(0, 1))(hyp, z, pattern)
        g = jax.tree.map(lambda t: t * w, g)
        acc = g if acc is None else jax.tree.map(jnp.add, acc, g)
    for a, b in zip(jax.tree.leaves(g_exact), jax.tree.leaves(acc)):
        np.testing.assert_allclose(np.asarray(b), np.asarray(a),
                                   rtol=1e-9, atol=1e-11)


def test_rescale_read_row_count_factor(rng):
    """reweight='rescale' applies the ROW ratio n/n_live (the in-mesh and
    fixed-fault factor) and restores n to the full count."""
    shards = _mk_shards(rng, K=3, nk=8)       # rows 8, 10, 12
    hyp = _mk_hyp(2)
    z = jnp.asarray(rng.standard_normal((4, 2)))
    sts = [_shard_stats(hyp, z, sh) for sh in shards]
    n_full = sum(sh["y"].shape[0] for sh in shards)

    acc = AsyncStatsAccumulator(staleness=0, reweight="rescale")
    acc.push(0, sts[0], stamp=0)
    acc.push(2, sts[2], stamp=0)              # shard 1 (10 rows) missing
    out = acc.read(0, n_rows=float(n_full))
    f = n_full / (8.0 + 12.0)
    ref = (sts[0] + sts[2]).scale(f)
    _assert_stats_close(out._replace(n=ref.n), ref)
    assert float(out.n) == float(n_full)
    with pytest.raises(ValueError, match="needs n_rows"):
        acc.read(0)


def test_accumulator_validation():
    with pytest.raises(ValueError, match="staleness must be"):
        AsyncStatsAccumulator(staleness=-1)
    with pytest.raises(ValueError, match="reweight must be"):
        AsyncStatsAccumulator(reweight="mean")
    acc = AsyncStatsAccumulator()
    from repro.core.stats import zero_stats
    with pytest.raises(ValueError, match="prob must be"):
        acc.push(0, zero_stats(2, 1), stamp=0, prob=0.0)


def test_async_engine_all_fresh_matches_reference(rng):
    """refresh >= K with no failures: the async step IS the synchronous
    step — value exact, grads to f64 against an independently-built
    reference (collapsed bound of the summed partial stats)."""
    K, d, q = 3, 2, 2
    shards = _mk_shards(rng, K=K, d=d, q=q)
    hyp = _mk_hyp(q)
    z = jnp.asarray(rng.standard_normal((5, q)))
    n_full = float(sum(sh["y"].shape[0] for sh in shards))

    def neg(h, zz):
        total = None
        for sh in shards:
            st = partial_stats(h, zz, jnp.asarray(sh["y"]),
                               jnp.asarray(sh["mu"]), None, latent=False)
            total = st if total is None else total + st
        total = total._replace(n=jnp.asarray(n_full))
        return -collapsed_bound(h, zz, total, d)

    v_ref, (gh_ref, gz_ref) = jax.value_and_grad(neg, argnums=(0, 1))(hyp, z)

    eng = AsyncEngine(shards, d=d, staleness=1, refresh=K)
    v, (gh, gz) = eng.step(hyp, z)
    np.testing.assert_allclose(float(v), float(v_ref), rtol=1e-12)
    np.testing.assert_allclose(np.asarray(gz), np.asarray(gz_ref),
                               rtol=1e-9, atol=1e-11)
    for k in gh_ref:
        np.testing.assert_allclose(np.asarray(gh[k]), np.asarray(gh_ref[k]),
                                   rtol=1e-9, atol=1e-11)
    # and the engine's own reference path agrees with itself
    v2, _ = eng.exact_value_and_grad(hyp, z)
    np.testing.assert_allclose(float(v2), float(v_ref), rtol=1e-12)


def test_async_engine_staleness_convergence_fixed_point(rng):
    """At FIXED (hyp, z), stale contributions equal fresh ones — so after
    one full refresh round the async value sits exactly on the
    synchronous value, for any refresh schedule within the bound."""
    K, d = 4, 1
    shards = _mk_shards(rng, K=K, d=d)
    hyp = _mk_hyp(2)
    z = jnp.asarray(rng.standard_normal((4, 2)))

    eng = AsyncEngine(shards, d=d, staleness=K, refresh=1)
    v_ref, _ = eng.exact_value_and_grad(hyp, z)
    for _ in range(K):
        v, g = eng.step(hyp, z)
    np.testing.assert_allclose(float(v), float(v_ref), rtol=1e-12)
    assert all(np.isfinite(np.asarray(t)).all() for t in jax.tree.leaves(g))


def test_async_engine_churn_eviction_and_resurrection(rng):
    """FailureSimulator-driven churn: a dead shard's contribution goes
    stale and is evicted after S steps; on resurrection its refresh slot
    re-folds it.  Timer records the (ragged) per-refresh timings."""
    K, d, S = 3, 1, 2
    shards = _mk_shards(rng, K=K, d=d, ragged=False)

    class ScriptedFailure:
        """mask() scripted per step: shard 2 dies at steps 1..4."""
        def __init__(self):
            self.t = 0

        def mask(self):
            m = np.ones(K)
            if 1 <= self.t <= 4:
                m[2] = 0.0
            self.t += 1
            return m

    timer = StepTimer()
    eng = AsyncEngine(shards, d=d, staleness=S, refresh=K,
                      failure=ScriptedFailure(), timer=timer)
    hyp = _mk_hyp(2)
    z = jnp.asarray(rng.standard_normal((4, 2)))

    eng.step(hyp, z)                       # t=0: all fresh
    assert sorted(eng.acc.members()) == [0, 1, 2]
    eng.step(hyp, z)                       # t=1: shard 2 dead, still fresh
    assert 2 in eng.acc.members()
    eng.step(hyp, z)                       # t=2: stamp 0 is exactly S old
    assert 2 in eng.acc.members()
    v_degraded, _ = eng.step(hyp, z)       # t=3: evicted (3 - S > 0)
    assert sorted(eng.acc.members()) == [0, 1]
    v_back, _ = eng.step(hyp, z)           # t=4 still dead; t advances
    eng.step(hyp, z)                       # t=5: resurrected, re-folded
    assert sorted(eng.acc.members()) == [0, 1, 2]
    v_full, _ = eng.step(hyp, z)
    v_ref, _ = eng.exact_value_and_grad(hyp, z)
    np.testing.assert_allclose(float(v_full), float(v_ref), rtol=1e-12)
    assert float(v_degraded) != float(v_ref)   # the noisy period was real
    s = timer.summary()                        # ragged rows summarise fine
    assert s and np.isfinite(s["straggler_overhead"])


def test_async_engine_svi_composes(rng):
    """batch_blocks inside the async engine: refreshed shards push
    reweighted stochastic Stats; steps stay finite and keyed replay is
    deterministic."""
    K, d = 2, 1
    shards = _mk_shards(rng, K=K, nk=16, d=d, ragged=False)
    hyp = _mk_hyp(2)
    z = jnp.asarray(rng.standard_normal((4, 2)))

    def run(seed):
        eng = AsyncEngine(shards, d=d, staleness=2, refresh=K,
                          chunk_size=4, batch_blocks=2)
        return [float(eng.step(hyp, z, key=jax.random.PRNGKey(seed + t))[0])
                for t in range(3)]

    a, b = run(0), run(0)
    assert a == b                          # keyed replay
    assert all(np.isfinite(v) for v in a)
    assert run(100) != a                   # different keys, different subsets


def test_async_engine_drop_mode_partial_membership_n(rng):
    """Regression: during warm-up (or after evictions) the drop-mode bound
    must be the self-consistent bound of the PRESENT subset — n summed
    over live contributions, not the full-data n stamped onto partial
    sums (the latter skews the noise terms and destabilises log_beta)."""
    K, d = 3, 1
    shards = _mk_shards(rng, K=K, d=d)
    hyp = _mk_hyp(2)
    z = jnp.asarray(rng.standard_normal((4, 2)))
    eng = AsyncEngine(shards, d=d, staleness=K, refresh=1)
    v, _ = eng.step(hyp, z)                # only shard 0 has pushed
    st0 = _shard_stats(hyp, z, shards[0])
    assert float(st0.n) == shards[0]["y"].shape[0] != eng.n_full
    np.testing.assert_allclose(float(v),
                               -float(collapsed_bound(hyp, z, st0, d)),
                               rtol=1e-12)


def test_async_engine_clipped_descent_is_stable(rng):
    """Stale folds mix stats from different (hyp, z); plain SGD on the raw
    async gradient can run away through log_beta (the Nyström residual of
    a mixed fold may transiently go negative).  With global-norm clipping
    the descent must stay finite AND make progress on the exact bound."""
    K, d, q, m = 4, 1, 2, 6
    nk = 48
    t = rng.uniform(-2, 2, (K * nk, 1))
    x = np.hstack([t, 0.1 * rng.standard_normal((K * nk, 1))])
    y = np.sin(t) + 0.1 * rng.standard_normal((K * nk, 1))
    shards = [{"y": y[k * nk:(k + 1) * nk], "mu": x[k * nk:(k + 1) * nk]}
              for k in range(K)]
    hyp = {"log_sf2": jnp.asarray(0.0), "log_ell": jnp.zeros((q,)),
           "log_beta": jnp.asarray(0.0)}
    z = jnp.asarray(rng.standard_normal((m, q)))

    clip = 50.0
    eng = AsyncEngine(shards, d=d, staleness=2 * K, refresh=1, clip=clip)
    v0, _ = eng.exact_value_and_grad(hyp, z)
    lr = 2e-3
    for _ in range(60):
        v, (gh, gz) = eng.step(hyp, z)
        assert np.isfinite(float(v))
        gn = float(jnp.sqrt(sum(jnp.sum(g ** 2)
                                for g in jax.tree.leaves((gh, gz)))))
        assert gn <= clip * (1 + 1e-9)
        hyp = {k: hyp[k] - lr * gh[k] for k in hyp}
        z = z - lr * gz
    v1, _ = eng.exact_value_and_grad(hyp, z)
    assert float(v1) < float(v0)           # exact neg-bound decreased

    with pytest.raises(ValueError, match="clip must be positive"):
        AsyncEngine(shards, d=d, clip=0.0)
