"""Shared fixtures. NOTE: no XLA_FLAGS here on purpose — smoke tests and
benches must see the real single-device CPU; only launch/dryrun.py (and the
subprocess-based distributed tests) request placeholder device fleets."""
import numpy as np
import pytest


def pytest_configure(config):
    # Registered in pytest.ini too; duplicated here so the markers exist
    # even when the suite is run from a directory where pytest.ini is not
    # picked up (e.g. an embedded checkout) — unknown-marker warnings are
    # how marker typos rot, so registration is belt-and-braces.
    config.addinivalue_line(
        "markers", "slow: long-running test (CI statistical job)")
    config.addinivalue_line(
        "markers",
        "statistical: randomized/statistical-tolerance test "
        "(CI statistical job)")


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def make_regression(rng, n=60, q=2, d=2, noise=0.1):
    """Smooth synthetic regression data (paper-style sines over latents)."""
    x = rng.uniform(-2.0, 2.0, size=(n, q))
    w = rng.standard_normal((q, d))
    f = np.sin(x @ w) + 0.5 * np.cos(2.0 * (x @ w[:, ::-1]))
    y = f + noise * rng.standard_normal((n, d))
    return x, y


@pytest.fixture
def regression_data(rng):
    return make_regression(rng)
