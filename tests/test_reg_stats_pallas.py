"""Fused regression-stats Pallas kernel (interpret mode) vs the XLA path.

The fused kernel must be a bit-for-bit drop-in for the monolithic regression
map — same bound, same gradients — because under interpret mode off-TPU it
runs the caller's f64 math and its custom_vjp backward recomputes through
the exact XLA formulation of ``stats.partial_stats``.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import SGPR
from repro.core.bound import collapsed_bound
from repro.core.distributed import DistributedGP
from repro.core.stats import partial_stats, partial_stats_chunked
from repro.kernels.reg_stats import ops as rs_ops
from repro.kernels.reg_stats import ref as rs_ref
from repro.launch.mesh import make_compat_mesh

from conftest import make_regression


def _hyp(rng, q):
    return {"log_sf2": jnp.asarray(rng.uniform(-0.5, 0.8)),
            "log_ell": jnp.asarray(rng.uniform(-0.4, 0.4, q)),
            "log_beta": jnp.asarray(1.0)}


def _mk(rng, n, m, q, d, masked=True):
    z = jnp.asarray(rng.standard_normal((m, q)))
    x = jnp.asarray(rng.standard_normal((n, q)))
    y = jnp.asarray(rng.standard_normal((n, d)))
    w = (jnp.asarray((rng.uniform(size=n) > 0.15).astype(np.float64))
         if masked else jnp.ones((n,)))
    return z, x, y, w


@pytest.mark.parametrize("n,m,q,d", [
    (64, 16, 2, 1),     # exact tile fit after padding
    (100, 37, 3, 2),    # nothing divides anything
    (257, 64, 10, 5),   # q at paper-scale latent dim, multi-output
    (32, 130, 1, 3),    # m > block_m, q=1
])
def test_reg_stats_kernel_shapes(rng, n, m, q, d):
    hyp = _hyp(rng, q)
    z, x, y, w = _mk(rng, n, m, q, d)
    b, c, dd = rs_ops.reg_stats(hyp, z, x, y, w, block_n=64, block_m=32)
    rb, rc, rd = rs_ref.reg_stats_ref(hyp["log_sf2"], hyp["log_ell"],
                                      z, x, y, w)
    # Interpret mode runs the caller's f64 — machine-precision agreement.
    np.testing.assert_allclose(float(b), float(rb), rtol=1e-12)
    np.testing.assert_allclose(np.asarray(c), np.asarray(rc),
                               rtol=1e-12, atol=1e-14)
    np.testing.assert_allclose(np.asarray(dd), np.asarray(rd),
                               rtol=1e-12, atol=1e-14)


def test_reg_stats_f32_path(rng):
    """The TPU-precision (f32 compute) path, exercised via f32 inputs."""
    n, m, q, d = 96, 24, 3, 2
    hyp = {k: v for k, v in _hyp(rng, q).items()}
    z, x, y, w = _mk(rng, n, m, q, d)
    f32 = jnp.float32
    b, c, dd = rs_ops.reg_stats(
        {k: v.astype(f32) for k, v in hyp.items()},
        z.astype(f32), x.astype(f32), y.astype(f32), w.astype(f32),
        block_n=32, block_m=16)
    assert c.dtype == f32 and dd.dtype == f32
    rb, rc, rd = rs_ref.reg_stats_ref(hyp["log_sf2"], hyp["log_ell"],
                                      z, x, y, w)
    np.testing.assert_allclose(float(b), float(rb), rtol=2e-5)
    np.testing.assert_allclose(np.asarray(c, np.float64), np.asarray(rc),
                               rtol=2e-4, atol=2e-5)
    np.testing.assert_allclose(np.asarray(dd, np.float64), np.asarray(rd),
                               rtol=2e-4, atol=2e-5)


def test_partial_stats_hook_parity(rng):
    """reg_stats_fn plugs into partial_stats and reproduces every statistic,
    including with masked (zero-weight) rows."""
    n, m, q, d = 77, 12, 2, 3
    hyp = _hyp(rng, q)
    z, x, y, w = _mk(rng, n, m, q, d)
    st_ref = partial_stats(hyp, z, y, x, s=None, weights=w, latent=False)
    st_k = partial_stats(hyp, z, y, x, s=None, weights=w, latent=False,
                         reg_stats_fn=rs_ops.reg_stats_fn_for_engine(32, 8))
    for name, a, b in zip(st_ref._fields, st_ref, st_k):
        np.testing.assert_allclose(np.asarray(b), np.asarray(a),
                                   rtol=1e-12, atol=1e-14, err_msg=name)


def test_chunked_hook_non_multiple_blocks(rng):
    """Fused kernel under partial_stats_chunked with a block size that
    divides neither n nor the kernel tiles."""
    n, m, q, d = 53, 9, 2, 2
    hyp = _hyp(rng, q)
    z, x, y, w = _mk(rng, n, m, q, d)
    full = partial_stats(hyp, z, y, x, s=None, weights=w, latent=False)
    ch = partial_stats_chunked(
        hyp, z, y, x, s=None, weights=w, latent=False,
        reg_stats_fn=rs_ops.reg_stats_fn_for_engine(16, 8), block_size=13)
    for name, a, b in zip(full._fields, full, ch):
        np.testing.assert_allclose(np.asarray(b), np.asarray(a),
                                   rtol=1e-10, atol=1e-12, err_msg=name)


def test_bound_and_grad_parity(rng):
    """Bound + (hyp, Z) gradients through the fused chunked map match the
    monolithic XLA path to float64 precision (the custom_vjp contract)."""
    n, m, q, d = 60, 7, 2, 2
    x, y = make_regression(rng, n=n, q=q, d=d)
    z = rng.standard_normal((m, q))
    hyp = _hyp(rng, q)

    def neg(h, zz, fused):
        fn = rs_ops.reg_stats_fn_for_engine(16, 8) if fused else None
        st = partial_stats_chunked(h, zz, jnp.asarray(y), jnp.asarray(x),
                                   s=None, latent=False, reg_stats_fn=fn,
                                   block_size=16 if fused else None)
        return -collapsed_bound(h, zz, st, d)

    v0, (gh0, gz0) = jax.value_and_grad(
        lambda h, zz: neg(h, zz, False), argnums=(0, 1))(hyp, jnp.asarray(z))
    v1, (gh1, gz1) = jax.jit(jax.value_and_grad(
        lambda h, zz: neg(h, zz, True), argnums=(0, 1)))(hyp, jnp.asarray(z))
    assert abs(float(v1) - float(v0)) < 1e-8 * abs(float(v0))
    np.testing.assert_allclose(np.asarray(gz1), np.asarray(gz0),
                               rtol=1e-8, atol=1e-10)
    for k in gh0:
        np.testing.assert_allclose(np.asarray(gh1[k]), np.asarray(gh0[k]),
                                   rtol=1e-8, atol=1e-10, err_msg=k)


def test_sgpr_kernel_backend_parity(rng):
    x, y = make_regression(rng, n=70, q=2, d=2)
    xla = SGPR(x, y, num_inducing=10, seed=0)
    fused = SGPR(x, y, num_inducing=10, seed=0, chunk_size=16,
                 kernel_backend="pallas")
    np.testing.assert_allclose(fused.log_bound(), xla.log_bound(), rtol=1e-10)
    mean0, _ = xla.predict(x[:5])
    mean1, _ = fused.predict(x[:5])
    np.testing.assert_allclose(mean1, mean0, rtol=1e-8, atol=1e-10)


def test_sgpr_rejects_unknown_backend(rng):
    x, y = make_regression(rng, n=20, q=2, d=1)
    with pytest.raises(ValueError, match="kernel_backend"):
        SGPR(x, y, num_inducing=4, kernel_backend="cuda")


def test_distributed_kernel_backend_parity(rng):
    """kernel_backend='pallas' through DistributedGP: value AND grads of the
    shard_map program match the xla engine on a 1-device mesh."""
    mesh = make_compat_mesh((1,), ("data",))
    n, m, q, d = 37, 5, 2, 1
    x = rng.standard_normal((n, q)); y = rng.standard_normal((n, d))
    z = jnp.asarray(rng.standard_normal((m, q)))
    hyp = _hyp(rng, q)
    outs = {}
    for backend in ("xla", "pallas"):
        eng = DistributedGP(mesh, data_axes=("data",), latent=False,
                            chunk_size=8, kernel_backend=backend)
        data, w = eng.put_data(y=y, mu=x)
        vg = eng.make_value_and_grad(d)
        outs[backend] = vg(hyp, z, data["mu"], None, data["y"], w,
                           jnp.ones((1,)), jnp.asarray(float(n)))
    (v0, (gh0, gz0)), (v1, (gh1, gz1)) = outs["xla"], outs["pallas"]
    assert abs(float(v1) - float(v0)) < 1e-10 * max(1.0, abs(float(v0)))
    np.testing.assert_allclose(np.asarray(gz1), np.asarray(gz0),
                               rtol=1e-8, atol=1e-10)
    for k in gh0:
        np.testing.assert_allclose(np.asarray(gh1[k]), np.asarray(gh0[k]),
                                   rtol=1e-8, atol=1e-10, err_msg=k)


def test_make_gp_train_step_pallas_backend(rng):
    from repro.train.steps import make_gp_train_step

    mesh = make_compat_mesh((1,), ("data",))
    n, m, q, d = 24, 4, 2, 1
    x = rng.standard_normal((n, q)); y = rng.standard_normal((n, d))
    z = rng.standard_normal((m, q))
    eng, step = make_gp_train_step(mesh, d, chunk_size=8,
                                   kernel_backend="pallas")
    assert eng.reg_stats_fn is not None
    data, w = eng.put_data(y=y, mu=x)
    hyp = {"log_sf2": jnp.asarray(0.2), "log_ell": jnp.full((q,), 0.1),
           "log_beta": jnp.asarray(1.0)}
    v, (gh, gz) = step(hyp, jnp.asarray(z), data["mu"], None, data["y"], w,
                       jnp.ones((1,)), jnp.asarray(float(n)))
    assert np.isfinite(float(v))
    assert np.isfinite(np.asarray(gz)).all()


def test_latent_pallas_backend_grads(rng):
    """The pallas backend is grad-safe on the GPLVM path too (psi2's
    custom_vjp): engine grads match the xla backend."""
    mesh = make_compat_mesh((1,), ("data",))
    n, m, q, d = 21, 4, 2, 2
    y = rng.standard_normal((n, d))
    mu = rng.standard_normal((n, q)); s = rng.uniform(0.1, 0.5, (n, q))
    z = jnp.asarray(rng.standard_normal((m, q)))
    hyp = _hyp(rng, q)
    outs = {}
    for backend in ("xla", "pallas"):
        eng = DistributedGP(mesh, data_axes=("data",), latent=True,
                            chunk_size=8, kernel_backend=backend)
        data, w = eng.put_data(y=y, mu=mu, s=s)
        vg = eng.make_value_and_grad(d)
        outs[backend] = vg(hyp, z, data["mu"], data["s"], data["y"], w,
                           jnp.ones((1,)), jnp.asarray(float(n)))
    (v0, (gh0, gz0)), (v1, (gh1, gz1)) = outs["xla"], outs["pallas"]
    # psi2's Pallas forward runs in f32, so value parity is f32-level.
    assert abs(float(v1) - float(v0)) < 1e-4 * max(1.0, abs(float(v0)))
    np.testing.assert_allclose(np.asarray(gz1), np.asarray(gz0),
                               rtol=1e-4, atol=1e-6)
    for k in gh0:
        np.testing.assert_allclose(np.asarray(gh1[k]), np.asarray(gh0[k]),
                                   rtol=1e-4, atol=1e-6, err_msg=k)
