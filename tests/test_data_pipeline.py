"""Token stream: determinism, restart-exactness, learnable structure."""
import numpy as np

from repro.data.tokens import TokenStream


def test_batch_is_step_addressed():
    s1 = TokenStream(vocab_size=1000, seq_len=32, global_batch=4, seed=7)
    s2 = TokenStream(vocab_size=1000, seq_len=32, global_batch=4, seed=7)
    for step in (0, 5, 1000):
        a = s1.host_batch(step)
        b = s2.host_batch(step)
        np.testing.assert_array_equal(a["tokens"], b["tokens"])
        np.testing.assert_array_equal(a["labels"], b["labels"])


def test_labels_are_shifted_tokens():
    s = TokenStream(vocab_size=512, seq_len=16, global_batch=2, seed=0)
    b = s.host_batch(3)
    # labels[t] is the next token in the underlying sequence:
    # tokens[:, 1:] == labels[:, :-1]
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_steps_differ_and_in_range():
    s = TokenStream(vocab_size=300, seq_len=64, global_batch=2, seed=1)
    a = s.host_batch(0)
    b = s.host_batch(1)
    assert not np.array_equal(a["tokens"], b["tokens"])
    assert a["tokens"].min() >= 0 and a["tokens"].max() < 300


def test_copy_structure_learnable():
    """Half the rows repeat their first half — a model with context can
    beat the unigram entropy; verify the structure exists."""
    s = TokenStream(vocab_size=100, seq_len=64, global_batch=64, seed=2)
    b = s.host_batch(0)
    full = np.concatenate([b["tokens"], b["labels"][:, -1:]], axis=1)
    half = full.shape[1] // 2
    rep_rows = np.mean([
        np.array_equal(r[:half], r[half:2 * half]) for r in full])
    assert 0.3 < rep_rows < 0.7
