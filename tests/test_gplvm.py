"""GPLVM behaviour tests mirroring the paper's figures 1 & 4."""
import jax.numpy as jnp
import numpy as np

from repro.core import BayesianGPLVM
from repro.core.bound import collapsed_bound
from repro.core.stats import partial_stats
from repro.data.synthetic import sines_dataset


def test_regression_is_zero_variance_gplvm(rng):
    """Paper's unifying claim: GPLVM bound with S->0, mu=X, no KL == SGPR bound."""
    n, q, d, m = 30, 2, 2, 8
    x = rng.standard_normal((n, q)); y = rng.standard_normal((n, d))
    z = rng.standard_normal((m, q))
    hyp = {"log_sf2": jnp.asarray(0.2), "log_ell": jnp.zeros(q),
           "log_beta": jnp.asarray(1.0)}
    st_reg = partial_stats(hyp, jnp.asarray(z), jnp.asarray(y), jnp.asarray(x),
                           s=None, latent=False)
    st_lvm = partial_stats(hyp, jnp.asarray(z), jnp.asarray(y), jnp.asarray(x),
                           s=jnp.full((n, q), 1e-13), latent=False)
    b_reg = float(collapsed_bound(hyp, jnp.asarray(z), st_reg, d))
    b_lvm = float(collapsed_bound(hyp, jnp.asarray(z), st_lvm, d))
    assert abs(b_reg - b_lvm) < 1e-5 * max(1.0, abs(b_reg))


def test_recovers_1d_latent(rng):
    """Paper fig 1: 1D latent -> 3D sines; ARD should find ~1 relevant dim."""
    y, _ = sines_dataset(rng, n=200, noise=0.05)
    lv = BayesianGPLVM(y, q=2, num_inducing=16, seed=0)
    lv.fit(max_iters=150)
    w = np.sort(lv.ard_weights())[::-1]
    assert w[0] > 3.0 * w[1]  # one dominant latent dimension


def test_bound_improves_and_beats_pca_init(rng):
    y, _ = sines_dataset(rng, n=80, noise=0.1)
    lv = BayesianGPLVM(y, q=2, num_inducing=10)
    b0 = lv.log_bound()
    lv.fit(max_iters=60)
    assert lv.log_bound() > b0


def test_alternating_schedule_improves(rng):
    """The paper's parallel G/L alternation also optimises the bound."""
    y, _ = sines_dataset(rng, n=60, noise=0.1)
    lv = BayesianGPLVM(y, q=2, num_inducing=8)
    b0 = lv.log_bound()
    lv.fit(max_iters=60, joint=False, outer_rounds=5)
    assert lv.log_bound() > b0


def test_reconstruction_runs(rng):
    y, _ = sines_dataset(rng, n=60, noise=0.05)
    lv = BayesianGPLVM(y, q=2, num_inducing=10)
    lv.fit(max_iters=60)
    observed = np.array([True, True, False])
    rec = lv.reconstruct(y[:5] * observed, observed, iters=30)
    assert rec.shape == (5, 3)
    assert np.isfinite(rec).all()
