"""The trip-count-aware HLO analyzer vs known-flop programs."""
import jax
import jax.numpy as jnp
import pytest

from repro.launch.hlo_analyzer import analyze
from repro.launch.hlo_stats import normalize_cost_analysis


def _flops_of(fn, *args):
    txt = jax.jit(fn).lower(*args).compile().as_text()
    return analyze(txt)


def test_plain_matmul_flops():
    a = jax.ShapeDtypeStruct((256, 512), jnp.float32)
    b = jax.ShapeDtypeStruct((512, 128), jnp.float32)
    out = _flops_of(lambda x, y: x @ y, a, b)
    want = 2 * 256 * 512 * 128
    assert out["flops"] == pytest.approx(want, rel=0.05)


def test_scan_trip_count_weighting():
    """XLA cost_analysis counts scan bodies once; the analyzer must not."""
    x = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    ws = jax.ShapeDtypeStruct((16, 128, 128), jnp.float32)

    def f(x, ws):
        return jax.lax.scan(lambda c, w: (jnp.tanh(c @ w), None), x, ws)[0]

    out = _flops_of(f, x, ws)
    want = 16 * 2 * 64 * 128 * 128
    assert out["flops"] == pytest.approx(want, rel=0.1)
    assert out["unresolved_loops"] == 0

    # sanity: raw cost_analysis under-counts by ~trip count
    raw = normalize_cost_analysis(
        jax.jit(f).lower(x, ws).compile().cost_analysis()).get("flops", 0.0)
    assert out["flops"] / max(raw, 1) > 8


def test_nested_scan():
    x = jax.ShapeDtypeStruct((32, 64), jnp.float32)
    ws = jax.ShapeDtypeStruct((4, 64, 64), jnp.float32)

    def f(x, ws):
        def outer(c, _):
            def inner(ci, w):
                return ci @ w, None
            return jax.lax.scan(inner, c, ws)[0], None
        return jax.lax.scan(outer, x, None, length=5)[0]

    out = _flops_of(f, x, ws)
    want = 5 * 4 * 2 * 32 * 64 * 64
    assert out["flops"] == pytest.approx(want, rel=0.1)


def test_collectives_inside_scan_are_weighted():
    """A psum inside a scanned layer must count once per layer."""
    # needs >1 device to emit a real collective; use the 1-device mesh —
    # XLA elides the all-reduce, so just assert the analyzer runs clean.
    x = jax.ShapeDtypeStruct((8, 8), jnp.float32)
    out = _flops_of(lambda a: a @ a, x)
    assert "collectives" in out


def test_bytes_reasonable_for_copy():
    x = jax.ShapeDtypeStruct((1024, 1024), jnp.float32)
    out = _flops_of(lambda a: a + 1.0, x)
    # operand + result ~ 8 MB
    assert 4e6 < out["bytes"] < 4e7
